//! Bench: regenerate Table 7 (iteration counts vs CPU golden).
//!
//! The reproduction criterion: Callipepla/A100 land within a few
//! iterations of the CPU; the XcgSolver padded-accumulator model shows
//! systematic inflation.

use callipepla::accel::Accel;
use callipepla::bench_harness::tables::{self, SweepConfig};

fn main() {
    let scale: f64 = std::env::var("CALLIPEPLA_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.02);
    let full = std::env::var("CALLIPEPLA_BENCH_FULL").is_ok();
    let ids: Vec<String> = if full {
        Vec::new()
    } else {
        ["M2", "M4", "M7", "M10", "M19", "M21"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    };
    let cfg = SweepConfig { scale, max_iters: 20_000 };
    let evals = tables::eval_suite(&ids, &cfg);
    println!("{}", tables::print_table7(&evals));

    // Aggregate shape check.
    let mut cal_absdiff = 0i64;
    let mut xcg_infl = 0i64;
    let mut count = 0i64;
    for e in &evals {
        let cal = e.results.iter().find(|r| r.accel == Accel::Callipepla).unwrap();
        let xcg = e.results.iter().find(|r| r.accel == Accel::XcgSolver).unwrap();
        if !xcg.failed && e.cpu_iters < 20_000 {
            cal_absdiff += (cal.iters as i64 - e.cpu_iters as i64).abs();
            xcg_infl += xcg.iters as i64 - e.cpu_iters as i64;
            count += 1;
        }
    }
    if count > 0 {
        println!(
            "mean |Callipepla - CPU| = {:.1} iters; mean XcgSolver inflation = {:+.1} iters",
            cal_absdiff as f64 / count as f64,
            xcg_infl as f64 / count as f64
        );
        println!("paper shape: Callipepla within ~10 of CPU; XcgSolver inflated by 10-35%.");
    }
}
