//! Bench: regenerate Table 4 (solver time, 4 platforms x matrix suite).
//!
//! Default: a representative 12-matrix subset at scale 0.02 (fast);
//! set CALLIPEPLA_BENCH_FULL=1 for all 36, CALLIPEPLA_BENCH_SCALE to
//! change the matrix scale.  The paper-shape checks printed at the end
//! are the reproduction criteria of DESIGN.md §3 (E-T4).

use callipepla::bench_harness::tables::{self, SweepConfig};
use callipepla::bench_harness::timing::bench;

fn main() {
    let scale: f64 = std::env::var("CALLIPEPLA_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.02);
    let full = std::env::var("CALLIPEPLA_BENCH_FULL").is_ok();
    let ids: Vec<String> = if full {
        Vec::new()
    } else {
        ["M2", "M4", "M7", "M10", "M19", "M21", "M31"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    };
    let cfg = SweepConfig { scale, max_iters: 20_000 };
    eprintln!(
        "table4 bench: {} matrices at scale {scale}",
        if full { 36 } else { ids.len() }
    );

    let t0 = std::time::Instant::now();
    let evals = tables::eval_suite(&ids, &cfg);
    println!("{}", tables::print_table4(&evals));
    println!("sweep wall time: {:?}", t0.elapsed());
    println!(
        "paper shape: Callipepla ~3-5x XcgSolver geomean; SerpensCG ~1.2-1.5x;\n\
         A100 loses on small matrices, wins on the largest; XcgSolver FAILs M31+."
    );

    // Microbench: per-cell evaluation cost (sizes full-suite runs).
    let spec = callipepla::sparse::synth::find_spec("M7").unwrap();
    let r = bench("eval_matrix(M7, all 4 platforms)", 1, 3, || {
        std::hint::black_box(tables::eval_matrix(&spec, &cfg));
    });
    println!("{}", r.report());
}
