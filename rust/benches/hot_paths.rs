//! Hot-path microbenches (E-Perf): the numbers tracked across the
//! perf trajectory (PERF.md / BENCH_hot_paths.json).
//!
//! * native SpMV — serial CSR f64 baseline vs the engine's nnz-balanced
//!   parallel kernels at 2 / 8 threads (f64 and Mix-V3)
//! * stream-replay Mix-V3 SpMV, delay-buffer dot
//! * 10 JPCG iterations — serial baseline vs the prepared-matrix plan
//!   at 8 threads, plus an 8-RHS batch on both batch paths: the
//!   worker-per-RHS model path (`solve_batch_workers`) and the batched
//!   instruction program (`solve_batch` -> `Coordinator::solve_batch`,
//!   the multi-RHS throughput row), plus the paired block-CG rows:
//!   staged (`solve_batch_block_staged[_parallel]`: one nnz pass per
//!   batched iteration, block re-materialized around each pass) vs
//!   resident (`solve_batch_block[_parallel]`: same single pass, the
//!   lane-major block is the live representation — zero steady-state
//!   boundary moves, PERF §12), and the telemetry-overhead pair: the
//!   resident row with the PR 9 recording gate off vs on (the off row
//!   must sit within 2% of the uninstrumented row, docs/OBSERVABILITY.md)
//! * spawn overhead on a small system: the worker batch on per-call
//!   `thread::scope` spawns vs the persistent pool (PERF §7/§8)
//! * coordinator-path iterations (instruction issue + module dispatch)
//! * time-plane: the fig9/ablation-style phase graph with busy-counter
//!   fast-forwarding on vs off, a full `iteration_cycles` call, and the
//!   8-lane batched iteration + its modeled RHS-iters/s throughput
//! * one PJRT phase1 executable call (feature `pjrt`, artifacts built)
//!
//! `--json` additionally writes `BENCH_hot_paths.json` (median seconds
//! + effective GB/s per kernel) so the trajectory is machine-tracked.

use callipepla::bench_harness::timing::{bench, human_time, BenchResult};
use callipepla::coordinator::{Coordinator, CoordinatorConfig, NativeExecutor};
use callipepla::engine::{spmv_f64_parallel, spmv_parallel, PreparedMatrix, RowPartition};
use callipepla::precision::{dot_delay_buffer, Scheme};
#[cfg(feature = "pjrt")]
use callipepla::coordinator::PhaseExecutor;
#[cfg(feature = "pjrt")]
use callipepla::runtime::{default_artifact_dir, PjrtExecutor, PjrtRuntime};
use callipepla::sim::dataflow::Dataflow;
use callipepla::sim::iteration::{
    batched_iteration_cycles, batched_iteration_cycles_mode, batched_rhs_iterations_per_second,
    iteration_cycles, spmv_busy_cycles, AccelSimConfig, BatchSpmvMode,
};
use callipepla::solver::{jpcg_solve, SolveOptions};
use callipepla::sparse::{pack_nnz_streams, synth, DEP_DIST_SERPENS};

struct Rec {
    name: String,
    median_s: f64,
    mean_s: f64,
    gb_per_s: Option<f64>,
}

fn record(recs: &mut Vec<Rec>, r: &BenchResult, gb_per_s: Option<f64>) {
    match gb_per_s {
        Some(g) => println!("{}   ~{g:.2} GB/s effective", r.report()),
        None => println!("{}", r.report()),
    }
    recs.push(Rec {
        name: r.name.clone(),
        median_s: r.median_s,
        mean_s: r.mean_s,
        gb_per_s,
    });
}

/// The fig9/ablation-style phase-1 graph: big SpMV busy window feeding a
/// forked output into a tailed dot + a write-back — the shape where the
/// simulator used to burn one step() per idle busy cycle.
fn phase_graph(nb: u64, busy: u64, fast_forward: bool) -> Dataflow {
    let mut df = Dataflow::new(3);
    df.set_fast_forward(fast_forward);
    let x = df.fifo(64);
    let y_raw = df.fifo(64);
    let y_dot = df.fifo(64);
    let y_wr = df.fifo(64);
    let p2 = df.fifo(64);
    df.mem_read("rd_x", 0, nb, x);
    df.spmv("M1", x, nb, busy, nb, y_raw);
    df.pipe("fork", vec![y_raw], vec![(0, y_dot), (0, y_wr)], 1, nb);
    df.mem_read("rd_p", 1, nb, p2);
    df.dot("M2", vec![p2, y_dot], nb, 40);
    df.mem_write("wr_y", 2, nb, y_wr);
    df
}

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    // --tiny: CI smoke sizes — every kernel still runs and the JSON is
    // still written, but the whole bench finishes in seconds.
    let tiny = std::env::args().any(|a| a == "--tiny");
    let mut recs: Vec<Rec> = Vec::new();

    let (bench_n, bench_nnz) = if tiny { (2_000, 24_000) } else { (100_000, 1_200_000) };
    let a = synth::banded_spd(bench_n, bench_nnz, 1e-3, 7);
    let x: Vec<f64> = (0..a.n).map(|i| ((i % 17) as f64 - 8.0) / 8.0).collect();
    let mut y = vec![0.0; a.n];
    let nnz = a.nnz();
    let spmv_bytes = nnz as f64 * 12.0 + a.n as f64 * 16.0;
    println!("hot paths on n={} nnz={nnz}", a.n);

    // CSR FP64 SpMV: serial baseline, then the engine at 2 / 8 threads.
    let r = bench("spmv_csr_f64", 3, 20, || a.spmv_f64(&x, &mut y));
    record(&mut recs, &r, Some(spmv_bytes / r.median_s / 1e9));
    for threads in [2usize, 8] {
        let part = RowPartition::nnz_balanced(&a, threads);
        let r = bench(&format!("spmv_csr_f64_t{threads}"), 3, 20, || {
            spmv_f64_parallel(&a, &x, &mut y, &part)
        });
        record(&mut recs, &r, Some(spmv_bytes / r.median_s / 1e9));
    }

    // Mix-V3 (f32 matrix, f64 x/accumulate) at 8 threads.
    let vals32 = a.vals_f32();
    let part8 = RowPartition::nnz_balanced(&a, 8);
    let r = bench("spmv_mixv3_t8", 3, 20, || {
        spmv_parallel(&a, &vals32, &x, &mut y, Scheme::MixV3, &part8)
    });
    record(&mut recs, &r, Some((nnz as f64 * 8.0 + a.n as f64 * 16.0) / r.median_s / 1e9));

    // Stream-replay Mix-V3 SpMV (the scheduled-stream value plane).
    let stream = pack_nnz_streams(&a, DEP_DIST_SERPENS);
    let r = bench("spmv_stream_replay_mixv3", 2, 10, || {
        stream.replay_mixv3(&x, &mut y)
    });
    record(&mut recs, &r, None);

    // Delay-buffer dot.
    let b: Vec<f64> = (0..a.n).map(|i| (i as f64 * 0.001).sin()).collect();
    let r = bench("dot_delay_buffer_100k", 3, 50, || {
        std::hint::black_box(dot_delay_buffer(&x, &b));
    });
    record(&mut recs, &r, None);

    // Full native iterations (via a capped solve): serial baseline vs
    // the prepared plan at 8 threads (fused sweeps + parallel SpMV +
    // cached vals32/diag — bitwise-identical numerics).
    let mut opts = SolveOptions::callipepla();
    opts.max_iters = 10;
    let r = bench("native_jpcg_10_iters", 1, 5, || {
        std::hint::black_box(jpcg_solve(&a, None, None, &opts));
    });
    record(&mut recs, &r, None);
    println!("    => {} per iteration", human_time(r.median_s / 10.0));

    let prep8 = PreparedMatrix::new(&a, 8);
    let r = bench("native_jpcg_10_iters_t8", 1, 5, || {
        std::hint::black_box(prep8.solve(None, None, &opts));
    });
    record(&mut recs, &r, None);
    println!("    => {} per iteration", human_time(r.median_s / 10.0));

    // Batch API: 8 right-hand sides against one prepared matrix, on the
    // worker-per-RHS model path (the pre-batched-program baseline).
    let rhs: Vec<Vec<f64>> = (0..8)
        .map(|k| (0..a.n).map(|i| ((i + k * 37) % 11) as f64 / 11.0).collect())
        .collect();
    let r = bench("solve_batch_8rhs_t8_10_iters", 1, 3, || {
        std::hint::black_box(prep8.solve_batch_workers(&rhs, &opts));
    });
    record(&mut recs, &r, None);
    let prep1 = PreparedMatrix::new(&a, 1);
    let r = bench("solve_batch_8rhs_t1_10_iters", 1, 3, || {
        std::hint::black_box(prep1.solve_batch_workers(&rhs, &opts));
    });
    record(&mut recs, &r, None);

    // Spawn-overhead re-measurement (PERF §7 -> §8): the same 8-RHS
    // worker batch on a *small* system, where per-call thread::scope
    // spawns were a visible tax, against the persistent pool the batch
    // paths now run on.
    let (small_n, small_nnz) = if tiny { (2_000, 24_000) } else { (8_000, 96_000) };
    let small = synth::banded_spd(small_n, small_nnz, 1e-3, 11);
    let prep_small = PreparedMatrix::new(&small, 8);
    let rhs_small: Vec<Vec<f64>> = (0..8)
        .map(|k| (0..small.n).map(|i| ((i + k * 13) % 9) as f64 / 9.0).collect())
        .collect();
    let r = bench("solve_batch_8rhs_small_scope_10_iters", 2, 20, || {
        std::hint::black_box(prep_small.solve_batch_workers_scoped(&rhs_small, &opts));
    });
    record(&mut recs, &r, None);
    let r = bench("solve_batch_8rhs_small_pool_10_iters", 2, 20, || {
        std::hint::black_box(prep_small.solve_batch_workers(&rhs_small, &opts));
    });
    record(&mut recs, &r, None);

    // Multi-RHS throughput of the batched *program* path: the same 8
    // right-hand sides as one compiled instruction stream vectorized
    // over the batch lanes (Coordinator::solve_batch + NativeExecutor;
    // this is what PreparedMatrix::solve_batch now routes to for the
    // shipping options).  RHS-iterations/s = 8 * 10 / median_s.
    let r = bench("program_batch_8rhs_10_iters", 1, 3, || {
        std::hint::black_box(prep8.solve_batch(&rhs, &opts));
    });
    record(&mut recs, &r, None);
    println!(
        "    => {:.1} rhs-iterations/s through the batched program",
        8.0 * 10.0 / r.median_s
    );

    // Lane-parallel dispatch of the same batched program (PR 5): the 8
    // lanes fan out across the machine's workers with a serial SpMV
    // inside each lane, so whole lanes (SpMV + vector sweeps + dots)
    // run concurrently instead of just the SpMV.  Guard first: the
    // results must be bitwise the sequential row's.
    let seq = prep8.solve_batch(&rhs, &opts);
    let par = prep8.solve_batch_parallel(&rhs, &opts, None, 0);
    let bitwise = seq.iter().zip(&par).all(|(s, p)| {
        s.iters == p.iters && s.x.iter().zip(&p.x).all(|(u, v)| u.to_bits() == v.to_bits())
    });
    assert!(bitwise, "lane-parallel dispatch changed bits");
    let r = bench("program_batch_8rhs_par", 1, 3, || {
        std::hint::black_box(prep8.solve_batch_parallel(&rhs, &opts, None, 0));
    });
    record(&mut recs, &r, None);
    println!(
        "    => {:.1} rhs-iterations/s with lane-parallel dispatch",
        8.0 * 10.0 / r.median_s
    );

    // Block-CG SpMV, staged path (PR 6): the same 8-RHS batch with one
    // nnz pass per batched iteration feeding every lane through the
    // interleaved lane-major kernel — but the block is re-materialized
    // around every pass (2·n·L element moves per iteration).  Guard
    // first: block mode is an execution-strategy switch, so the results
    // must be bitwise the per-lane row's.
    let blk = prep8.solve_batch_block_staged(&rhs, &opts);
    let bitwise = seq.iter().zip(&blk).all(|(s, p)| {
        s.iters == p.iters && s.x.iter().zip(&p.x).all(|(u, v)| u.to_bits() == v.to_bits())
    });
    assert!(bitwise, "staged block-CG SpMV changed bits");
    let r = bench("program_batch_8rhs_block_10_iters", 1, 3, || {
        std::hint::black_box(prep8.solve_batch_block_staged(&rhs, &opts));
    });
    record(&mut recs, &r, None);
    println!(
        "    => {:.1} rhs-iterations/s with staged block-CG SpMV",
        8.0 * 10.0 / r.median_s
    );
    let r = bench("program_batch_8rhs_block_par", 1, 3, || {
        std::hint::black_box(prep8.solve_batch_block_staged_parallel(&rhs, &opts, None, 0));
    });
    record(&mut recs, &r, None);

    // Resident block state (PR 7): same single nnz pass, but x/p/r/ap
    // live in the lane-major arenas for the whole solve and the vector
    // trips run batch-wide through the block kernels — zero
    // block-boundary element moves per steady iteration (the paired
    // staged rows above are the measured baseline).  Bitwise-guarded
    // against the sequential row like every block row.
    let res = prep8.solve_batch_block(&rhs, &opts);
    let bitwise = seq.iter().zip(&res).all(|(s, p)| {
        s.iters == p.iters && s.x.iter().zip(&p.x).all(|(u, v)| u.to_bits() == v.to_bits())
    });
    assert!(bitwise, "resident block-CG changed bits");
    let r = bench("program_batch_8rhs_block_resident_10_iters", 1, 3, || {
        std::hint::black_box(prep8.solve_batch_block(&rhs, &opts));
    });
    record(&mut recs, &r, None);
    println!(
        "    => {:.1} rhs-iterations/s with resident block state",
        8.0 * 10.0 / r.median_s
    );
    let resident_median_s = r.median_s;
    let r = bench("program_batch_8rhs_block_resident_par", 1, 3, || {
        std::hint::black_box(prep8.solve_batch_block_parallel(&rhs, &opts, None, 0));
    });
    record(&mut recs, &r, None);

    // Telemetry overhead (PR 9): the resident row again with the
    // recording gate explicitly off (every gated instrument
    // early-returns on one relaxed load — the library's default state)
    // and then on (counters/histograms actually record).  The off row
    // is the instrumentation tax the hot path pays by default; the
    // acceptance bar is <2% against the resident row above.
    callipepla::obs::set_recording(false);
    let r_off = bench("program_batch_8rhs_block_resident_obs_off", 1, 3, || {
        std::hint::black_box(prep8.solve_batch_block(&rhs, &opts));
    });
    record(&mut recs, &r_off, None);
    callipepla::obs::set_recording(true);
    let r_on = bench("program_batch_8rhs_block_resident_obs_on", 1, 3, || {
        std::hint::black_box(prep8.solve_batch_block(&rhs, &opts));
    });
    callipepla::obs::set_recording(false);
    record(&mut recs, &r_on, None);
    println!(
        "    => telemetry overhead vs resident row: {:+.2}% gate off, {:+.2}% gate on",
        (r_off.median_s / resident_median_s - 1.0) * 100.0,
        (r_on.median_s / resident_median_s - 1.0) * 100.0
    );

    // Adaptive precision (PR 8): full solves to convergence on the
    // small system, paired static-fp64 / static-mixv3 / adaptive rows.
    // The adaptive controller starts on Mix-V3 and escalates to FP64
    // near convergence, so its wall-clock sits between the two static
    // envelopes while its modeled M1 nnz traffic stays close to the
    // Mix-V3 floor (printed from the recorded PrecisionTrace).
    {
        use callipepla::precision::adaptive::AdaptivePolicy;
        let mut full = SolveOptions::callipepla();
        full.max_iters = 20_000;
        let mut fp64_opts = full;
        fp64_opts.scheme = Scheme::Fp64;
        let r = bench("solve_full_static_fp64_small", 1, 3, || {
            std::hint::black_box(prep_small.solve(None, None, &fp64_opts));
        });
        record(&mut recs, &r, None);
        let r = bench("solve_full_static_mixv3_small", 1, 3, || {
            std::hint::black_box(prep_small.solve(None, None, &full));
        });
        record(&mut recs, &r, None);
        let mut ad_opts = full;
        ad_opts.adaptive = Some(AdaptivePolicy::default());
        let r = bench("solve_full_adaptive_small", 1, 3, || {
            std::hint::black_box(prep_small.solve(None, None, &ad_opts));
        });
        record(&mut recs, &r, None);
        let fp64 = prep_small.solve(None, None, &fp64_opts);
        let ad = prep_small.solve(None, None, &ad_opts);
        let small_nnz64 = small.nnz() as u64;
        println!(
            "    => adaptive: {} iters (fp64: {}), modeled M1 bytes {} vs fp64 {} ({:.2}x less)",
            ad.iters,
            fp64.iters,
            ad.precision.modeled_m1_bytes(small_nnz64, ad.iters),
            fp64.precision.modeled_m1_bytes(small_nnz64, fp64.iters),
            fp64.precision.modeled_m1_bytes(small_nnz64, fp64.iters) as f64
                / ad.precision.modeled_m1_bytes(small_nnz64, ad.iters) as f64
        );
    }

    // Coordinator-path iteration (instruction issue + module dispatch).
    let r = bench("coordinator_native_10_iters", 1, 5, || {
        let cfg = CoordinatorConfig { max_iters: 10, ..Default::default() };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::new(&a, Scheme::MixV3);
        let b1 = vec![1.0; a.n];
        let x0 = vec![0.0; a.n];
        std::hint::black_box(coord.solve(&mut exec, &b1, &x0));
    });
    record(&mut recs, &r, None);

    // Time plane: the same phase graph stepped cycle-by-cycle vs with
    // busy-counter fast-forwarding (results are bit-identical; only
    // wall-clock differs), plus a full iteration_cycles call as used by
    // the fig9/ablation sims.  Suite-density dims (nnz/n ~ 60, like the
    // Table-3 upper half): there the SpMV busy window dwarfs the vector
    // streams and the simulator used to idle-step through it.
    let (sim_n, sim_nnz) =
        if tiny { (4_096usize, 200_000usize) } else { (100_000usize, 6_000_000usize) };
    let nb = (sim_n as u64).div_ceil(8);
    let busy = spmv_busy_cycles(sim_nnz, Scheme::MixV3, 1.06);
    let cycles_slow = phase_graph(nb, busy, false).run(u64::MAX).unwrap().cycles;
    let cycles_fast = phase_graph(nb, busy, true).run(u64::MAX).unwrap().cycles;
    assert_eq!(cycles_slow, cycles_fast, "fast-forward changed the sim result");
    let r = bench("sim_phase_graph_step_by_step", 1, 5, || {
        std::hint::black_box(phase_graph(nb, busy, false).run(u64::MAX).unwrap());
    });
    record(&mut recs, &r, None);
    let r = bench("sim_phase_graph_fast_forward", 2, 10, || {
        std::hint::black_box(phase_graph(nb, busy, true).run(u64::MAX).unwrap());
    });
    record(&mut recs, &r, None);
    let cal = AccelSimConfig::callipepla();
    let r = bench("sim_iteration_cycles_callipepla", 2, 10, || {
        std::hint::black_box(iteration_cycles(&cal, sim_n, sim_nnz));
    });
    record(&mut recs, &r, None);

    // Time plane, multi-RHS: cycles for one 8-lane batched iteration and
    // the modeled RHS-iteration throughput it implies.
    let r = bench("sim_batched_iteration_cycles_b8", 1, 5, || {
        std::hint::black_box(batched_iteration_cycles(&cal, sim_n, sim_nnz, 8));
    });
    record(&mut recs, &r, None);
    let thr1 = batched_rhs_iterations_per_second(&cal, sim_n, sim_nnz, 1);
    let thr8 = batched_rhs_iterations_per_second(&cal, sim_n, sim_nnz, 8);
    println!(
        "    => modeled throughput: {thr8:.0} rhs-iters/s at batch 8 vs {thr1:.0} at batch 1 \
         ({:.2}x)",
        thr8 / thr1
    );
    // Modeled block-vs-per-lane split at batch 8: the block mode's
    // single nnz pass vs the time-shared matrix port.
    let blk8 = batched_iteration_cycles_mode(&cal, sim_n, sim_nnz, 8, BatchSpmvMode::Block).total;
    let per8 = batched_iteration_cycles_mode(&cal, sim_n, sim_nnz, 8, BatchSpmvMode::PerLane).total;
    println!(
        "    => modeled batch-8 iteration: {blk8} cycles block-CG vs {per8} per-lane SpMV \
         ({:.2}x)",
        per8 as f64 / blk8 as f64
    );

    // PJRT phase call, when the feature and artifacts exist.
    #[cfg(feature = "pjrt")]
    match PjrtRuntime::new(default_artifact_dir()) {
        Ok(mut rt) => {
            let small = synth::laplace2d_shifted(4_000, 0.05);
            match PjrtExecutor::new(&mut rt, &small, Scheme::MixV3) {
                Ok(mut exec) => {
                    let p: Vec<f64> = (0..small.n).map(|i| (i as f64 * 0.01).cos()).collect();
                    exec.phase1(&p); // warm compile
                    let r = bench("pjrt_phase1_call_n4096_bucket", 2, 20, || {
                        std::hint::black_box(exec.phase1(&p));
                    });
                    record(&mut recs, &r, None);
                }
                Err(e) => println!("pjrt executor unavailable: {e}"),
            }
        }
        Err(e) => println!("pjrt bench skipped: {e}"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt bench skipped: built without the `pjrt` feature");

    if json_mode {
        let mut out = String::from("{\n  \"bench\": \"hot_paths\",\n");
        out.push_str(&format!(
            "  \"matrix\": {{ \"n\": {}, \"nnz\": {} }},\n  \"results\": [\n",
            a.n, nnz
        ));
        for (k, rec) in recs.iter().enumerate() {
            let gbs = match rec.gb_per_s {
                Some(g) => format!("{g:.4}"),
                None => "null".to_string(),
            };
            out.push_str(&format!(
                "    {{ \"name\": \"{}\", \"median_s\": {:e}, \"mean_s\": {:e}, \"gb_per_s\": {} }}{}\n",
                rec.name,
                rec.median_s,
                rec.mean_s,
                gbs,
                if k + 1 < recs.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write("BENCH_hot_paths.json", &out).expect("write BENCH_hot_paths.json");
        println!("wrote BENCH_hot_paths.json ({} kernels)", recs.len());
    }
}
