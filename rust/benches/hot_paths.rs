//! Hot-path microbenches (E-Perf): the numbers tracked across the
//! EXPERIMENTS.md §Perf optimization log.
//!
//! * native SpMV (CSR f64 / stream-replay Mix-V3)
//! * delay-buffer dot product
//! * one full native JPCG iteration
//! * one PJRT phase1 executable call (if artifacts are built)

use callipepla::bench_harness::timing::{bench, human_time};
use callipepla::coordinator::{Coordinator, CoordinatorConfig, NativeExecutor, PhaseExecutor};
use callipepla::precision::{dot_delay_buffer, Scheme};
use callipepla::runtime::{default_artifact_dir, PjrtExecutor, PjrtRuntime};
use callipepla::solver::{jpcg_solve, SolveOptions};
use callipepla::sparse::{pack_nnz_streams, synth, DEP_DIST_SERPENS};

fn main() {
    let a = synth::banded_spd(100_000, 1_200_000, 1e-3, 7);
    let x: Vec<f64> = (0..a.n).map(|i| ((i % 17) as f64 - 8.0) / 8.0).collect();
    let mut y = vec![0.0; a.n];
    let nnz = a.nnz();
    println!("hot paths on n={} nnz={nnz}", a.n);

    // CSR FP64 SpMV.
    let r = bench("spmv_csr_f64", 3, 20, || a.spmv_f64(&x, &mut y));
    let gbs = (nnz as f64 * 12.0 + a.n as f64 * 16.0) / r.median_s / 1e9;
    println!("{}   ~{gbs:.2} GB/s effective", r.report());

    // Stream-replay Mix-V3 SpMV (the scheduled-stream value plane).
    let stream = pack_nnz_streams(&a, DEP_DIST_SERPENS);
    let r = bench("spmv_stream_replay_mixv3", 2, 10, || {
        stream.replay_mixv3(&x, &mut y)
    });
    println!("{}", r.report());

    // Delay-buffer dot.
    let b: Vec<f64> = (0..a.n).map(|i| (i as f64 * 0.001).sin()).collect();
    let r = bench("dot_delay_buffer_100k", 3, 50, || {
        std::hint::black_box(dot_delay_buffer(&x, &b));
    });
    println!("{}", r.report());

    // Full native iteration (via a capped solve).
    let mut opts = SolveOptions::callipepla();
    opts.max_iters = 10;
    let r = bench("native_jpcg_10_iters", 1, 5, || {
        std::hint::black_box(jpcg_solve(&a, None, None, &opts));
    });
    println!("{}   => {} per iteration", r.report(), human_time(r.median_s / 10.0));

    // Coordinator-path iteration (instruction issue + module dispatch).
    let r = bench("coordinator_native_10_iters", 1, 5, || {
        let cfg = CoordinatorConfig { max_iters: 10, ..Default::default() };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::new(&a, Scheme::MixV3);
        let b1 = vec![1.0; a.n];
        let x0 = vec![0.0; a.n];
        std::hint::black_box(coord.solve(&mut exec, &b1, &x0));
    });
    println!("{}", r.report());

    // PJRT phase call, when artifacts exist.
    match PjrtRuntime::new(default_artifact_dir()) {
        Ok(mut rt) => {
            let small = synth::laplace2d_shifted(4_000, 0.05);
            match PjrtExecutor::new(&mut rt, &small, Scheme::MixV3) {
                Ok(mut exec) => {
                    let p: Vec<f64> = (0..small.n).map(|i| (i as f64 * 0.01).cos()).collect();
                    exec.phase1(&p); // warm compile
                    let r = bench("pjrt_phase1_call_n4096_bucket", 2, 20, || {
                        std::hint::black_box(exec.phase1(&p));
                    });
                    println!("{}", r.report());
                }
                Err(e) => println!("pjrt executor unavailable: {e}"),
            }
        }
        Err(e) => println!("pjrt bench skipped: {e}"),
    }
}
