//! Service-layer bench (PERF §8): replay a synthetic multi-tenant
//! request trace through the coalescing scheduler and compare
//! end-to-end RHS-iterations/s against the no-coalescing baseline.
//!
//! Rows:
//!
//! * `service_replay_64req_8rhs` — 64 requests from 8 tenants over 4
//!   matrices, coalesced into batches of up to 8 lanes, executed on
//!   the persistent pool through the bucketed program cache.
//! * `service_coalesce_vs_sequential` — the same trace, one request at
//!   a time, each its own single-RHS program execution with no cache
//!   (the pre-service path).  The coalesced row must beat this one on
//!   RHS-iterations/s.
//! * `service_replay_64req_8rhs_block` — the coalesced replay with
//!   `ServiceConfig::block_spmv` on: every batch runs as one resident
//!   lane-major block (one nnz stream per batched iteration, zero
//!   steady-state boundary moves), bitwise the same per-ticket results.
//!
//! Iterations are capped (10 per request) so the rows measure the
//! serving machinery at a fixed, path-identical amount of numerical
//! work.  `--json` writes `BENCH_service_replay.json` (median seconds +
//! RHS-iterations/s per row); `--tiny` shrinks the matrices for the CI
//! `service-smoke` arm.

use callipepla::bench_harness::timing::{bench, BenchResult};
use callipepla::service::{
    replay_coalesced, replay_sequential, synth_trace, ServiceConfig, SolverService, TraceConfig,
};
use callipepla::sim::AccelSimConfig;
use callipepla::solver::SolveOptions;
use callipepla::sparse::synth;

struct Rec {
    name: String,
    median_s: f64,
    mean_s: f64,
    rhs_iters_per_s: f64,
}

fn record(recs: &mut Vec<Rec>, r: &BenchResult, rhs_iters: u64) {
    let per_s = rhs_iters as f64 / r.median_s;
    println!("{}   {per_s:.1} rhs-iters/s end-to-end", r.report());
    recs.push(Rec {
        name: r.name.clone(),
        median_s: r.median_s,
        mean_s: r.mean_s,
        rhs_iters_per_s: per_s,
    });
}

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    let tiny = std::env::args().any(|a| a == "--tiny");
    let mut recs: Vec<Rec> = Vec::new();

    // 4 matrices across several size buckets; capped iterations keep
    // the numerical work identical on both paths.
    let base = if tiny { 600 } else { 6_000 };
    let mut opts = SolveOptions::callipepla();
    opts.max_iters = 10;
    let cfg = ServiceConfig { max_batch: 8, opts, ..Default::default() };
    let mut svc = SolverService::new(cfg);
    let ids: Vec<_> = (0..4)
        .map(|k| svc.register(synth::laplace2d_shifted(base * (k + 1), 0.05 + 0.02 * k as f64)))
        .collect();
    for &id in &ids {
        let e = svc.registry().entry(id);
        println!("matrix {id}: n={} nnz={}", e.n(), e.nnz());
    }
    let trace_cfg = TraceConfig { requests: 64, tenants: 8, ..Default::default() };
    let trace = synth_trace(svc.registry(), &ids, &trace_cfg);

    // One untimed replay pins the workload (deterministic iteration
    // counts) and warms the program cache to serving steady state.
    let warm = replay_coalesced(&mut svc, &trace);
    let rhs_iters = warm.rhs_iterations;
    println!(
        "trace: 64 requests, {} rhs-iterations, {} batches so far",
        rhs_iters,
        svc.stats().batches
    );

    let runs = if tiny { 3 } else { 5 };
    let r = bench("service_replay_64req_8rhs", 1, runs, || {
        std::hint::black_box(replay_coalesced(&mut svc, &trace));
    });
    record(&mut recs, &r, rhs_iters);

    let r = bench("service_coalesce_vs_sequential", 1, runs, || {
        std::hint::black_box(replay_sequential(svc.registry(), &trace, &opts));
    });
    record(&mut recs, &r, rhs_iters);

    // The same coalesced trace on a block-mode service: batches execute
    // as resident lane-major blocks.  Guard that the serving layer's
    // block switch keeps every per-ticket result bitwise unchanged.
    let blk_cfg = ServiceConfig { max_batch: 8, block_spmv: true, opts, ..Default::default() };
    let mut blk_svc = SolverService::new(blk_cfg);
    let blk_ids: Vec<_> = (0..4)
        .map(|k| blk_svc.register(synth::laplace2d_shifted(base * (k + 1), 0.05 + 0.02 * k as f64)))
        .collect();
    let blk_trace = synth_trace(blk_svc.registry(), &blk_ids, &trace_cfg);
    let blk_warm = replay_coalesced(&mut blk_svc, &blk_trace);
    let bitwise = warm.results.iter().zip(&blk_warm.results).all(|(a, b)| {
        a.iters == b.iters && a.x.iter().zip(&b.x).all(|(u, v)| u.to_bits() == v.to_bits())
    });
    assert!(bitwise, "block-mode service changed per-ticket bits");
    let r = bench("service_replay_64req_8rhs_block", 1, runs, || {
        std::hint::black_box(replay_coalesced(&mut blk_svc, &blk_trace));
    });
    record(&mut recs, &r, rhs_iters);
    blk_svc.drain();

    let stats = svc.drain();
    println!(
        "program cache at exit: {} compiled, {} hits / {} misses",
        stats.compiled_programs, stats.cache_hits, stats.cache_misses
    );
    let sim_cfg = AccelSimConfig::callipepla();
    println!(
        "time plane: {:.0} modeled rhs-iters/s for the executed trace",
        stats.modeled_rhs_iterations_per_second(&sim_cfg)
    );
    let speedup = recs[0].rhs_iters_per_s / recs[1].rhs_iters_per_s;
    println!("coalesced vs sequential: {speedup:.2}x rhs-iters/s");

    if json_mode {
        let mut out = String::from("{\n  \"bench\": \"service_replay\",\n");
        out.push_str(&format!(
            "  \"trace\": {{ \"requests\": 64, \"tenants\": 8, \"matrices\": 4, \
             \"max_batch\": 8, \"rhs_iterations\": {rhs_iters} }},\n  \"results\": [\n"
        ));
        for (k, rec) in recs.iter().enumerate() {
            out.push_str(&format!(
                "    {{ \"name\": \"{}\", \"median_s\": {:e}, \"mean_s\": {:e}, \
                 \"rhs_iters_per_s\": {:.4} }}{}\n",
                rec.name,
                rec.median_s,
                rec.mean_s,
                rec.rhs_iters_per_s,
                if k + 1 < recs.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write("BENCH_service_replay.json", &out)
            .expect("write BENCH_service_replay.json");
        println!("wrote BENCH_service_replay.json ({} rows)", recs.len());
    }
}
