//! Service-layer bench (PERF §8): replay a synthetic multi-tenant
//! request trace through the coalescing scheduler and compare
//! end-to-end RHS-iterations/s against the no-coalescing baseline.
//!
//! Rows:
//!
//! * `service_replay_64req_8rhs` — 64 requests from 8 tenants over 4
//!   matrices, coalesced into batches of up to 8 lanes, executed on
//!   the persistent pool through the bucketed program cache.
//! * `service_coalesce_vs_sequential` — the same trace, one request at
//!   a time, each its own single-RHS program execution with no cache
//!   (the pre-service path).  The coalesced row must beat this one on
//!   RHS-iterations/s.
//! * `service_replay_64req_8rhs_block` — the coalesced replay with
//!   `ServiceConfig::block_spmv` on: every batch runs as one resident
//!   lane-major block (one nnz stream per batched iteration, zero
//!   steady-state boundary moves), bitwise the same per-ticket results.
//! * `service_replay_1k_capacity_deadline` — the production-knob
//!   scenario (ROADMAP item 4 acceptance): 1024 requests over 32
//!   matrices under a registry budgeted to a third of the working set
//!   (LRU eviction + readmission churn) with logical-clock deadline
//!   flushes.  Before timing, the row proves the guarantees: two
//!   independent runs of the trace render byte-identical event logs,
//!   every ticket is bitwise a lone solve, and the row's JSON carries
//!   the p99 logical queue wait (bounded by the deadline).
//!
//! Iterations are capped (10 per request; 3 on the 1k row) so the rows
//! measure the serving machinery at a fixed, path-identical amount of
//! numerical work.  `--json` writes `BENCH_service_replay.json` (median
//! seconds + RHS-iterations/s per row); `--tiny` shrinks the matrices
//! for the CI `service-smoke` arm.

use callipepla::bench_harness::timing::{bench, BenchResult};
use callipepla::service::{
    replay_coalesced, replay_sequential, synth_trace, ServiceConfig, SolverService, TraceConfig,
};
use callipepla::sim::AccelSimConfig;
use callipepla::solver::SolveOptions;
use callipepla::sparse::synth;

struct Rec {
    name: String,
    median_s: f64,
    mean_s: f64,
    rhs_iters_per_s: f64,
    queue_wait_p99: Option<u64>,
}

fn record(recs: &mut Vec<Rec>, r: &BenchResult, rhs_iters: u64, queue_wait_p99: Option<u64>) {
    let per_s = rhs_iters as f64 / r.median_s;
    println!("{}   {per_s:.1} rhs-iters/s end-to-end", r.report());
    recs.push(Rec {
        name: r.name.clone(),
        median_s: r.median_s,
        mean_s: r.mean_s,
        rhs_iters_per_s: per_s,
        queue_wait_p99,
    });
}

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    let tiny = std::env::args().any(|a| a == "--tiny");
    let mut recs: Vec<Rec> = Vec::new();

    // 4 matrices across several size buckets; capped iterations keep
    // the numerical work identical on both paths.
    let base = if tiny { 600 } else { 6_000 };
    let mut opts = SolveOptions::callipepla();
    opts.max_iters = 10;
    let cfg = ServiceConfig { max_batch: 8, opts, ..Default::default() };
    let mut svc = SolverService::new(cfg);
    let ids: Vec<_> = (0..4)
        .map(|k| svc.register(synth::laplace2d_shifted(base * (k + 1), 0.05 + 0.02 * k as f64)))
        .collect();
    for &id in &ids {
        let e = svc.registry().entry(id);
        println!("matrix {id}: n={} nnz={}", e.n(), e.nnz());
    }
    let trace_cfg = TraceConfig { requests: 64, tenants: 8, ..Default::default() };
    let trace = synth_trace(svc.registry(), &ids, &trace_cfg);

    // One untimed replay pins the workload (deterministic iteration
    // counts) and warms the program cache to serving steady state.
    let warm = replay_coalesced(&mut svc, &trace);
    let rhs_iters = warm.rhs_iterations;
    println!(
        "trace: 64 requests, {} rhs-iterations, {} batches so far",
        rhs_iters,
        svc.stats().batches
    );

    let runs = if tiny { 3 } else { 5 };
    let r = bench("service_replay_64req_8rhs", 1, runs, || {
        std::hint::black_box(replay_coalesced(&mut svc, &trace));
    });
    record(&mut recs, &r, rhs_iters, None);

    let r = bench("service_coalesce_vs_sequential", 1, runs, || {
        std::hint::black_box(replay_sequential(svc.registry(), &trace, &opts));
    });
    record(&mut recs, &r, rhs_iters, None);

    // The same coalesced trace on a block-mode service: batches execute
    // as resident lane-major blocks.  Guard that the serving layer's
    // block switch keeps every per-ticket result bitwise unchanged.
    let blk_cfg = ServiceConfig { max_batch: 8, block_spmv: true, opts, ..Default::default() };
    let mut blk_svc = SolverService::new(blk_cfg);
    let blk_ids: Vec<_> = (0..4)
        .map(|k| blk_svc.register(synth::laplace2d_shifted(base * (k + 1), 0.05 + 0.02 * k as f64)))
        .collect();
    let blk_trace = synth_trace(blk_svc.registry(), &blk_ids, &trace_cfg);
    let blk_warm = replay_coalesced(&mut blk_svc, &blk_trace);
    let bitwise = warm.results.iter().zip(&blk_warm.results).all(|(a, b)| {
        a.iters == b.iters && a.x.iter().zip(&b.x).all(|(u, v)| u.to_bits() == v.to_bits())
    });
    assert!(bitwise, "block-mode service changed per-ticket bits");
    let r = bench("service_replay_64req_8rhs_block", 1, runs, || {
        std::hint::black_box(replay_coalesced(&mut blk_svc, &blk_trace));
    });
    record(&mut recs, &r, rhs_iters, None);
    blk_svc.drain();

    // The production-knob row: 1024 requests over 32 matrices, registry
    // budgeted to a third of the working set, deadline flushes on the
    // submission clock.  Guarantees first, timing second.
    let prod_base = if tiny { 64 } else { 256 };
    let prod_sizes: Vec<usize> = (0..32).map(|k| prod_base + (prod_base / 8) * k).collect();
    let mut prod_opts = SolveOptions::callipepla();
    prod_opts.max_iters = 3;
    let deadline = 24u64;
    let build_prod = |capacity_beats: u64| {
        let mut svc = SolverService::new(ServiceConfig {
            max_batch: 8,
            deadline,
            capacity_beats,
            opts: prod_opts,
            ..Default::default()
        });
        let ids: Vec<_> = prod_sizes
            .iter()
            .map(|&n| svc.register(synth::laplace2d_shifted(n, 0.1)))
            .collect();
        (svc, ids)
    };
    // Size the budget off the actual footprints: one unbounded pass to
    // measure, then rebuild at a third of the working set.
    let (probe, probe_ids) = build_prod(0);
    let working_set: u64 =
        probe_ids.iter().map(|&id| probe.registry().entry(id).footprint_beats()).sum();
    drop(probe);
    let capacity = working_set / 3;
    let prod_trace_cfg = TraceConfig { requests: 1024, tenants: 8, ..Default::default() };

    let run_prod = || {
        let (mut svc, ids) = build_prod(capacity);
        let sink = svc.record_events();
        let trace = synth_trace(svc.registry(), &ids, &prod_trace_cfg);
        let outcome = replay_coalesced(&mut svc, &trace);
        let stats = svc.drain();
        (outcome, stats, sink.render(), trace, svc)
    };
    let (prod_warm, prod_stats, log_a, prod_trace, prod_svc) = run_prod();
    let (_, _, log_b, _, _) = run_prod();
    assert_eq!(
        callipepla::obs::first_divergence(&log_a, &log_b),
        None,
        "capacity+deadline replays must render byte-identical event logs"
    );
    assert!(
        prod_stats.registry.evictions > 0 && prod_stats.registry.readmissions > 0,
        "the third-of-working-set budget must actually churn the registry"
    );
    assert!(
        prod_stats.records.iter().any(|rec| rec.reason.name() == "deadline"),
        "the deadline threshold must actually cut batches"
    );
    // Every ticket bitwise a lone solve, through eviction churn and
    // all.  The baseline resolves the trace's ids against the registry
    // that minted them (ids are registry-tagged), readmitting evicted
    // entries on demand under the same capacity budget.
    let prod_seq = replay_sequential(prod_svc.registry(), &prod_trace, &prod_opts);
    let prod_bitwise = prod_warm.results.iter().zip(&prod_seq.results).all(|(a, b)| {
        a.iters == b.iters && a.x.iter().zip(&b.x).all(|(u, v)| u.to_bits() == v.to_bits())
    });
    assert!(prod_bitwise, "capacity+deadline service changed per-ticket bits");
    let p99 = prod_stats.queue_wait_quantile(0.99);
    assert!(
        p99 <= deadline + 8,
        "p99 logical queue wait {p99} must stay bounded by deadline {deadline} + max_batch"
    );
    println!(
        "capacity+deadline: {} batches ({} deadline cuts), {} evictions / {} readmissions, \
         p99 queue wait {p99}",
        prod_stats.batches,
        prod_stats.records.iter().filter(|rec| rec.reason.name() == "deadline").count(),
        prod_stats.registry.evictions,
        prod_stats.registry.readmissions
    );
    let prod_runs = if tiny { 2 } else { 3 };
    let r = bench("service_replay_1k_capacity_deadline", 1, prod_runs, || {
        let (mut svc, ids) = build_prod(capacity);
        let trace = synth_trace(svc.registry(), &ids, &prod_trace_cfg);
        std::hint::black_box(replay_coalesced(&mut svc, &trace));
        svc.drain();
    });
    record(&mut recs, &r, prod_warm.rhs_iterations, Some(p99));

    let stats = svc.drain();
    println!(
        "program cache at exit: {} compiled, {} hits / {} misses",
        stats.compiled_programs, stats.cache_hits, stats.cache_misses
    );
    let sim_cfg = AccelSimConfig::callipepla();
    println!(
        "time plane: {:.0} modeled rhs-iters/s for the executed trace",
        stats.modeled_rhs_iterations_per_second(&sim_cfg)
    );
    let speedup = recs[0].rhs_iters_per_s / recs[1].rhs_iters_per_s;
    println!("coalesced vs sequential: {speedup:.2}x rhs-iters/s");

    if json_mode {
        let mut out = String::from("{\n  \"bench\": \"service_replay\",\n");
        out.push_str(&format!(
            "  \"trace\": {{ \"requests\": 64, \"tenants\": 8, \"matrices\": 4, \
             \"max_batch\": 8, \"rhs_iterations\": {rhs_iters} }},\n  \"results\": [\n"
        ));
        for (k, rec) in recs.iter().enumerate() {
            let p99 = match rec.queue_wait_p99 {
                Some(v) => format!(", \"queue_wait_p99\": {v}"),
                None => String::new(),
            };
            out.push_str(&format!(
                "    {{ \"name\": \"{}\", \"median_s\": {:e}, \"mean_s\": {:e}, \
                 \"rhs_iters_per_s\": {:.4}{p99} }}{}\n",
                rec.name,
                rec.median_s,
                rec.mean_s,
                rec.rhs_iters_per_s,
                if k + 1 < recs.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write("BENCH_service_replay.json", &out)
            .expect("write BENCH_service_replay.json");
        println!("wrote BENCH_service_replay.json ({} rows)", recs.len());
    }
}
