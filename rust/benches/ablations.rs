//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * E-ABL1 — VSR + decentralized scheduling on/off (§5.5: 14 vs 19
//!   memory accesses => per-iteration cycle gap).
//! * E-ABL2 — double vs single memory channel (§5.7: rd+wr overlap).
//! * E-ABL3 — FIFO depth deadlock boundary (§5.6: fast FIFO >= L+1).
//! * E-ABL4 — precision scheme vs SpMV stream cycles (§6 / Table 1).
//! * E-ABL5 — hazard-distance padding (Serpens load-store vs XcgSolver
//!   FP-latency, §7.5.1).

use callipepla::hbm::{ChannelMode, HbmConfig};
use callipepla::precision::Scheme;
use callipepla::sim::dataflow::{Dataflow, SimError};
use callipepla::sim::iteration::{iteration_cycles, spmv_busy_cycles, AccelSimConfig, M5_DEPTH};
use callipepla::sparse::{pack_nnz_streams, synth, DEP_DIST_SERPENS, DEP_DIST_XCGSOLVER};

fn main() {
    let n = 100_000;
    let nnz = 2_000_000;

    // ---- E-ABL1: VSR on/off -------------------------------------------
    let cal = AccelSimConfig::callipepla();
    let mut no_vsr = cal;
    no_vsr.vsr = false;
    let with = iteration_cycles(&cal, n, nnz);
    let without = iteration_cycles(&no_vsr, n, nnz);
    println!("ABL1 VSR+decentralized scheduling (n={n}, nnz={nnz}):");
    println!(
        "  with VSR    {:>9} cycles/iter | without {:>9} | saving {:.2}x",
        with.total,
        without.total,
        without.total as f64 / with.total as f64
    );

    // ---- E-ABL2: double vs single channel ------------------------------
    let mut single = cal;
    single.hbm = HbmConfig { vector_mode: ChannelMode::Single, ..cal.hbm };
    let dbl = iteration_cycles(&cal, n, nnz);
    let sgl = iteration_cycles(&single, n, nnz);
    println!("ABL2 double-channel design (§5.7):");
    println!(
        "  double {:>9} cycles/iter | single {:>9} | phase3 {:>9} vs {:>9}",
        dbl.total, sgl.total, dbl.phase3, sgl.phase3
    );

    // ---- E-ABL3: FIFO depth boundary -----------------------------------
    println!("ABL3 deadlock boundary (M5 depth L={M5_DEPTH}, fast-FIFO sweep):");
    for depth in [2, M5_DEPTH / 2, M5_DEPTH, M5_DEPTH + 1, 2 * M5_DEPTH] {
        let mut df = Dataflow::new(2);
        let r_in = df.fifo(4);
        let fast = df.fifo(depth);
        let slow = df.fifo(4);
        df.mem_read("rd", 0, 1000, r_in);
        df.pipe("M5", vec![r_in], vec![(0, fast), (M5_DEPTH - 1, slow)], M5_DEPTH, 1000);
        df.dot("M6", vec![fast, slow], 1000, 0);
        let verdict = match df.run(1_000_000) {
            Ok(s) => format!("ok in {} cycles", s.cycles),
            Err(SimError::Deadlock { cycle, .. }) => format!("DEADLOCK at cycle {cycle}"),
            Err(e) => format!("{e}"),
        };
        println!("  fast-FIFO depth {depth:>3}: {verdict}");
    }

    // ---- E-ABL4: precision schemes -------------------------------------
    println!("ABL4 SpMV stream cycles per scheme (nnz={nnz}, padding 1.06):");
    for scheme in Scheme::ALL {
        println!(
            "  {:<6} {:>9} cycles ({} B/nnz)",
            scheme.name(),
            spmv_busy_cycles(nnz, scheme, 1.06),
            scheme.nnz_bytes()
        );
    }

    // ---- E-ABL5: hazard-distance padding --------------------------------
    let a = synth::banded_spd(20_000, 200_000, 1e-3, 77);
    let serp = pack_nnz_streams(&a, DEP_DIST_SERPENS);
    let xcg = pack_nnz_streams(&a, DEP_DIST_XCGSOLVER);
    println!("ABL5 scheduler padding (n={} nnz={}):", a.n, a.nnz());
    println!(
        "  serpens dist {:>2}: padding {:.3}x, {} cycles | xcg dist {:>2}: padding {:.3}x, {} cycles",
        DEP_DIST_SERPENS,
        serp.padding_factor(),
        serp.cycles(),
        DEP_DIST_XCGSOLVER,
        xcg.padding_factor(),
        xcg.cycles()
    );
}
