//! Bench: regenerate the Fig. 9 residual traces (nasa2910, gyro_k,
//! msc10848 x five precision settings) and report where each setting
//! first crosses the 1e-12 threshold.

use callipepla::bench_harness::tables::fig9_traces;
use callipepla::sparse::synth;

fn main() {
    let scale: f64 = std::env::var("CALLIPEPLA_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.05);
    std::fs::create_dir_all("traces").ok();
    for id in ["M7", "M13", "M15"] {
        let spec = synth::find_spec(id).unwrap();
        let a = spec.generate(scale);
        println!("\nFig. 9 {} ({}): n={} nnz={}", spec.id, spec.paper_name, a.n, a.nnz());
        for (label, csv) in fig9_traces(&a, 20_000) {
            let rows = csv.lines().count() - 1;
            let last = csv.lines().last().unwrap_or("0,1");
            let final_rr: f64 = last.split(',').nth(1).unwrap_or("1").parse().unwrap_or(1.0);
            println!(
                "  {label:<20} {rows:>6} rows  final |r|^2 = {final_rr:.3e}  {}",
                if final_rr < 1e-12 { "converged" } else { "NOT converged" }
            );
            std::fs::write(format!("traces/fig9_{}_{label}.csv", spec.paper_name), csv).ok();
        }
    }
    println!("\npaper shape: fp64/mixv3/onboard overlap; mixv1 & mixv2 lag or stall.");
}
