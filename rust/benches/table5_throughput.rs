//! Bench: regenerate Table 5 (throughput, fraction of peak, energy
//! efficiency) over the matrix suite.

use callipepla::bench_harness::tables::{self, SweepConfig};

fn main() {
    let scale: f64 = std::env::var("CALLIPEPLA_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.02);
    let full = std::env::var("CALLIPEPLA_BENCH_FULL").is_ok();
    let ids: Vec<String> = if full {
        Vec::new()
    } else {
        ["M2", "M4", "M7", "M10", "M19", "M21", "M31"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    };
    let cfg = SweepConfig { scale, max_iters: 20_000 };
    let evals = tables::eval_suite(&ids, &cfg);
    println!("{}", tables::print_table5(&evals));
    println!(
        "paper shape: Callipepla geomean ~3-5x XcgSolver throughput, ~2.9x energy eff.,\n\
         highest FPGA FoP; A100 max throughput highest but min lowest (launch floor)."
    );
}
