//! Cross-module integration tests: suite evaluation end-to-end, and
//! randomized property tests (proptest is unavailable offline; the
//! same sweep-style invariants run on our deterministic PRNG).

use callipepla::accel::Accel;
use callipepla::bench_harness::tables::{self, SweepConfig};
use callipepla::isa::{InstCmp, InstRdWr, InstVCtrl};
use callipepla::precision::Scheme;
use callipepla::solver::{jpcg_solve, SolveOptions};
use callipepla::sparse::{pack_nnz_streams_cfg, synth};
use callipepla::util::Rng64;


#[test]
fn suite_subset_end_to_end_shape() {
    let cfg = SweepConfig { scale: 0.01, max_iters: 1_000 };
    let evals = tables::eval_suite(
        &["M4".to_string(), "M19".to_string(), "M31".to_string()],
        &cfg,
    );
    assert_eq!(evals.len(), 3);
    for e in &evals {
        let xcg = e.results.iter().find(|r| r.accel == Accel::XcgSolver).unwrap();
        let cal = e.results.iter().find(|r| r.accel == Accel::Callipepla).unwrap();
        assert!(!cal.failed, "{}", e.spec.id);
        if e.spec.id == "M31" {
            // Table 4: XcgSolver FAILs at paper scale.
            assert!(xcg.failed, "M31 must FAIL for XcgSolver");
        } else {
            assert!(cal.solver_seconds < xcg.solver_seconds, "{}", e.spec.id);
        }
    }
    // Printers run on the real sweep output.
    let t4 = tables::print_table4(&evals);
    assert!(t4.contains("M31") && t4.contains("FAIL"));
    let t5 = tables::print_table5(&evals);
    assert!(t5.contains("Callipepla"));
    let t7 = tables::print_table7(&evals);
    assert!(t7.contains("M19"));
}

// ---------------------------------------------------------------- props

/// Property: the JPCG solver converges on any diagonally-shifted random
/// SPD matrix, and the solution satisfies A x ~ b.
#[test]
fn prop_solver_converges_on_random_spd() {
    let mut rng = Rng64::seed_from_u64(0xC0FFEE);
    for trial in 0..12 {
        let n = 200 + rng.gen_range(800);
        let nnz = 4 * n + rng.gen_range(8 * n);
        let delta = 10f64.powf(-1.0 - 3.0 * rng.gen_f64());
        let a = synth::banded_spd(n, nnz, delta, rng.next_u64());
        let res = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        assert!(res.converged, "trial {trial}: n={n} delta={delta:.2e} rr={}", res.final_rr);
        let mut ax = vec![0.0; a.n];
        a.spmv_f64(&res.x, &mut ax);
        let err = ax.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
        // Mix-V3 converges on the f32-rounded matrix; checking against
        // the f64 master leaves a residual ~ eps_f32 * |A| * |x|.
        let xmax = res.x.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let tol = 1e-6 + 1e-6 * xmax;
        assert!(err < tol, "trial {trial}: ||Ax-b||={err} tol={tol}");
    }
}

/// Property: the Serpens scheduler is a padding-only permutation — the
/// stream replay reproduces Mix-V3 SpMV for any matrix and any channel
/// geometry, and never violates the hazard distance.
#[test]
fn prop_stream_schedule_correct_for_random_geometry() {
    let mut rng = Rng64::seed_from_u64(0xBEEF);
    for trial in 0..8 {
        let n = 100 + rng.gen_range(2_000);
        let a = synth::banded_spd(n, 6 * n, 1e-2, rng.next_u64());
        let channels = 1 + rng.gen_range(16);
        let dep = 2 + rng.gen_range(16);
        let stream = pack_nnz_streams_cfg(&a, dep, channels, 8);
        assert_eq!(stream.check_hazards(), None, "trial {trial}");
        let x: Vec<f64> = (0..a.n).map(|_| rng.gen_f64() - 0.5).collect();
        let mut y = vec![0.0; a.n];
        stream.replay_mixv3(&x, &mut y);
        let mut want = vec![0.0; a.n];
        for i in 0..a.n {
            let (cols, vals) = a.row(i);
            for (c, v) in cols.iter().zip(vals) {
                want[i] += (*v as f32) as f64 * x[*c as usize];
            }
        }
        for i in 0..a.n {
            assert!(
                (y[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                "trial {trial} row {i}"
            );
        }
    }
}

/// Property: ISA encode/decode round-trips arbitrary field values.
#[test]
fn prop_isa_roundtrip_random() {
    let mut rng = Rng64::seed_from_u64(0x15A);
    for _ in 0..2_000 {
        let v = InstVCtrl {
            rd: rng.next_u64() & 1 == 1,
            wr: rng.next_u64() & 1 == 1,
            base_addr: rng.next_u64() as u32,
            len: rng.next_u64() as u32,
            q_id: (rng.next_u64() & 0b111) as u8,
            precision: Scheme::from_wire_code((rng.next_u64() & 0b11) as u8).unwrap(),
        };
        assert_eq!(InstVCtrl::decode(v.encode()), Ok(v));
        let c = InstCmp {
            len: rng.next_u64() as u32,
            alpha: f64::from_bits(rng.next_u64()),
            q_id: (rng.next_u64() & 0b111) as u8,
        };
        let d = InstCmp::decode(c.encode());
        assert_eq!(d.len, c.len);
        assert_eq!(d.q_id, c.q_id);
        assert_eq!(d.alpha.to_bits(), c.alpha.to_bits());
        let m = InstRdWr {
            rd: rng.next_u64() & 1 == 1,
            wr: rng.next_u64() & 1 == 1,
            base_addr: rng.next_u64() as u32,
            len: rng.next_u64() as u32,
        };
        assert_eq!(InstRdWr::decode(m.encode()), m);
    }
}

/// Property: scheme error ordering holds across random matrices —
/// ||y_V1 - y_fp64|| >= ||y_V2 - y_fp64|| >= ||y_V3 - y_fp64||.
#[test]
fn prop_scheme_error_ordering() {
    use callipepla::precision::{spmv_scheme, AccumulatorModel};
    let mut rng = Rng64::seed_from_u64(0xABCD);
    for trial in 0..8 {
        let n = 200 + rng.gen_range(600);
        let a = synth::banded_spd(n, 8 * n, 1e-3, rng.next_u64());
        let v32 = a.vals_f32();
        let x: Vec<f64> = (0..a.n).map(|_| rng.gen_normal()).collect();
        let mut gold = vec![0.0; a.n];
        a.spmv_f64(&x, &mut gold);
        let err = |s: Scheme| {
            let mut y = vec![0.0; a.n];
            spmv_scheme(&a, &v32, &x, &mut y, s, AccumulatorModel::Sequential, 0);
            y.iter().zip(&gold).map(|(u, v)| (u - v).powi(2)).sum::<f64>().sqrt()
        };
        let (e1, e2, e3) = (err(Scheme::MixV1), err(Scheme::MixV2), err(Scheme::MixV3));
        assert!(e1 >= e2 && e2 >= e3, "trial {trial}: {e1:.3e} {e2:.3e} {e3:.3e}");
    }
}

/// Property: solver iteration counts are scale-stable — the synthetic
/// generator's difficulty knob (delta) dominates, not the size.  This is
/// what makes scaled-down Table-7 runs representative.
#[test]
fn prop_iterations_scale_stable() {
    let spec = synth::find_spec("M10").unwrap();
    let small = jpcg_solve(&spec.generate(0.01), None, None, &SolveOptions::default());
    let large = jpcg_solve(&spec.generate(0.04), None, None, &SolveOptions::default());
    let ratio = large.iters as f64 / small.iters.max(1) as f64;
    assert!(
        (0.4..2.5).contains(&ratio),
        "iters small={} large={}",
        small.iters,
        large.iters
    );
}
