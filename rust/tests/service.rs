//! Service-layer oracle tests (ISSUE 4 acceptance criteria).
//!
//! * A replayed multi-tenant trace (>= 64 requests over <= 4 matrices)
//!   produces per-request results **bitwise identical** to lone
//!   `jpcg_solve` calls, with at most ceil(requests / max_batch)
//!   program executions per matrix.
//! * Coalescing is deterministic: the same request set yields the same
//!   batches — and bitwise the same results — regardless of how
//!   arrivals from different tenants interleave.
//! * Early-converged lanes in mixed-tenant batches exit without
//!   perturbing the slower tenants sharing the batch.
//! * A bucket program (cache path, `HbmMemoryMap` sized to the bucket
//!   ceiling, smaller n rebased into it) solves bitwise identically to
//!   the exact-n program, and a cache hit is bitwise identical to a
//!   fresh compile.

use std::sync::Arc;

use callipepla::coordinator::{Coordinator, CoordinatorConfig, NativeExecutor};
use callipepla::precision::Scheme;
use callipepla::program::{bucket_ceiling, ProgramCache};
use callipepla::service::{
    replay_coalesced, replay_sequential, synth_trace, BatchRecord, ServiceConfig, SolveRequest,
    SolverService, TraceConfig,
};
use callipepla::solver::{jpcg_solve, SolveOptions, SolveResult};
use callipepla::sparse::{synth, CsrMatrix};
use callipepla::PreparedMatrix;

fn assert_bitwise(a: &SolveResult, b: &SolveResult, what: &str) {
    assert_eq!(a.iters, b.iters, "{what}: iteration counts differ");
    assert_eq!(a.converged, b.converged, "{what}: convergence differs");
    assert_eq!(a.final_rr.to_bits(), b.final_rr.to_bits(), "{what}: final rr differs");
    assert_eq!(a.x.len(), b.x.len(), "{what}: solution lengths differ");
    assert!(
        a.x.iter().zip(&b.x).all(|(u, v)| u.to_bits() == v.to_bits()),
        "{what}: solution bits differ"
    );
}

fn test_matrices() -> Vec<CsrMatrix> {
    vec![
        synth::laplace2d_shifted(100, 0.2),
        synth::laplace2d_shifted(180, 0.15),
        synth::banded_spd(260, 2_600, 1e-3, 5),
        synth::laplace2d_shifted(330, 0.1),
    ]
}

#[test]
fn replayed_trace_is_bitwise_lone_solves_with_coalesced_executions() {
    let max_batch = 8;
    let opts = SolveOptions::callipepla();
    let mut svc =
        SolverService::new(ServiceConfig { max_batch, workers: 4, ..Default::default() });
    let matrices = test_matrices();
    let ids: Vec<_> = matrices.iter().map(|a| svc.register(a.clone())).collect();

    let cfg = TraceConfig { requests: 64, tenants: 8, ..Default::default() };
    let trace = synth_trace(svc.registry(), &ids, &cfg);
    assert_eq!(trace.len(), 64);

    let outcome = replay_coalesced(&mut svc, &trace);
    let stats = svc.drain();

    // Bitwise identity to lone jpcg_solve calls, request by request.
    for (t, res) in trace.iter().zip(&outcome.results) {
        let a = &matrices[t.request.matrix.index()];
        let lone = jpcg_solve(a, Some(&t.request.b), None, &opts);
        assert_bitwise(res, &lone, "replayed request");
        assert!(res.converged, "request failed to converge");
    }

    // Coalescing bound: at most ceil(k / max_batch) executions per
    // matrix, and every request accounted for.
    let mut total_lanes = 0u64;
    for &id in &ids {
        let submitted = trace.iter().filter(|t| t.request.matrix == id).count();
        let execs = stats.executions_for(id);
        assert!(
            execs <= submitted.div_ceil(max_batch) as u64,
            "matrix {id}: {submitted} requests took {execs} executions"
        );
        total_lanes += stats
            .records
            .iter()
            .filter(|r| r.matrix == id)
            .map(|r| r.lanes as u64)
            .sum::<u64>();
    }
    assert_eq!(total_lanes, 64, "every request rode exactly one batch");
    assert_eq!(stats.requests, 64);
    assert_eq!(stats.rhs_iterations, outcome.rhs_iterations);

    // The sequential baseline replays the same trace with the same
    // bits (it *is* the lone-solve path, request by request).
    let seq = replay_sequential(svc.registry(), &trace, &opts);
    for (a, b) in outcome.results.iter().zip(&seq.results) {
        assert_bitwise(a, b, "coalesced vs sequential");
    }
}

/// Batch composition keys for comparing two runs: (matrix, lane rhs
/// fingerprints) per executed batch, sorted into a canonical order.
fn batch_shapes(records: &[BatchRecord]) -> Vec<(u32, u32, u64)> {
    let mut shapes: Vec<(u32, u32, u64)> = records
        .iter()
        .map(|r| (r.matrix.index() as u32, r.lanes, r.rhs_iters))
        .collect();
    shapes.sort_unstable();
    shapes
}

#[test]
fn coalescing_is_deterministic_across_arrival_interleavings() {
    let matrices = test_matrices();
    let run = |interleave: bool| {
        let mut svc = SolverService::new(ServiceConfig {
            max_batch: 4,
            workers: 3,
            ..Default::default()
        });
        let ids: Vec<_> = matrices.iter().map(|a| svc.register(a.clone())).collect();
        let cfg = TraceConfig { requests: 40, tenants: 5, ..Default::default() };
        let mut trace = synth_trace(svc.registry(), &ids, &cfg);
        if interleave {
            // A different arrival interleaving with the *same* request
            // set and the same per-matrix relative order: round-robin
            // the per-matrix queues instead of replaying arrival order.
            let mut per_matrix: Vec<Vec<_>> = vec![Vec::new(); ids.len()];
            for t in trace {
                per_matrix[t.request.matrix.index()].push(t);
            }
            let mut merged = Vec::new();
            let mut row = 0;
            while merged.len() < 40 {
                for q in per_matrix.iter_mut() {
                    if row < q.len() {
                        merged.push(q[row].clone());
                    }
                }
                row += 1;
            }
            trace = merged;
        }
        let outcome = replay_coalesced(&mut svc, &trace);
        let stats = svc.drain();
        // Key results by request identity (matrix, rhs bits) so the
        // two orderings are comparable.
        let mut keyed: Vec<(usize, Vec<u64>, SolveResult)> = trace
            .iter()
            .zip(outcome.results)
            .map(|(t, r)| {
                let bits: Vec<u64> = t.request.b.iter().map(|v| v.to_bits()).collect();
                (t.request.matrix.index(), bits, r)
            })
            .collect();
        keyed.sort_by(|a, b| (a.0, &a.1).cmp(&(b.0, &b.1)));
        (batch_shapes(&stats.records), keyed)
    };
    let (shapes_a, results_a) = run(false);
    let (shapes_b, results_b) = run(true);
    assert_eq!(shapes_a, shapes_b, "same request set must coalesce into the same batches");
    assert_eq!(results_a.len(), results_b.len());
    for ((ma, ba, ra), (mb, bb, rb)) in results_a.iter().zip(&results_b) {
        assert_eq!((ma, ba), (mb, bb), "request sets diverged");
        assert_bitwise(ra, rb, "interleaving-independent result");
    }
}

#[test]
fn early_converged_lanes_do_not_perturb_mixed_tenant_batches() {
    let a = synth::laplace2d_shifted(250, 0.1);
    let opts = SolveOptions::callipepla();
    let mut svc =
        SolverService::new(ServiceConfig { max_batch: 8, workers: 2, ..Default::default() });
    let id = svc.register(a.clone());

    // One full batch of mixed tenants: lanes 0/3/6 are zero right-hand
    // sides (they converge on the merged init, iters == 0); the rest
    // are distinct slow tenants.
    let rhs: Vec<Vec<f64>> = (0..8)
        .map(|k| {
            if k % 3 == 0 {
                vec![0.0; a.n]
            } else {
                (0..a.n).map(|i| 1.0 + ((i + 17 * k) % 7) as f64 / 7.0).collect()
            }
        })
        .collect();
    let tickets: Vec<_> = rhs
        .iter()
        .enumerate()
        .map(|(k, b)| svc.submit(SolveRequest { matrix: id, b: b.clone(), tenant: k as u32 }))
        .collect();
    // max_batch lanes pending -> the batch flushed on submit already.
    let stats = svc.drain();
    assert_eq!(stats.batches, 1, "one full batch, one program execution");
    assert_eq!(stats.records[0].tenants, (0..8).collect::<Vec<u32>>());

    let results: Vec<SolveResult> = tickets.into_iter().map(|t| t.wait()).collect();
    for (k, (b, res)) in rhs.iter().zip(&results).enumerate() {
        let lone = jpcg_solve(&a, Some(b), None, &opts);
        assert_bitwise(res, &lone, "mixed-tenant lane");
        if k % 3 == 0 {
            assert_eq!(res.iters, 0, "zero rhs converges on the init trip");
        } else {
            assert!(res.iters > 0, "slow lanes keep iterating after fast lanes exit");
        }
    }
    // The batch held the device for the slowest lane, not the sum.
    let max_iters = results.iter().map(|r| r.iters).max().unwrap();
    assert_eq!(stats.records[0].max_iters, max_iters);
    assert_eq!(
        stats.records[0].rhs_iters,
        results.iter().map(|r| r.iters as u64).sum::<u64>()
    );
}

/// `ServiceConfig::block_spmv` is a pure execution-strategy switch at
/// the serving layer: a block-mode service replaying a multi-matrix,
/// multi-tenant trace hands every ticket bitwise the lone-solve result
/// — including sub-`max_batch` partial batches and the single-lane
/// tail group that short-circuits to per-lane dispatch.
#[test]
fn block_mode_service_tickets_are_bitwise_lone_solves() {
    let opts = SolveOptions::callipepla();
    let mut svc = SolverService::new(ServiceConfig {
        max_batch: 4,
        workers: 2,
        block_spmv: true,
        ..Default::default()
    });
    let matrices = test_matrices();
    let ids: Vec<_> = matrices.iter().map(|a| svc.register(a.clone())).collect();

    // 5 requests on matrix 0 (batches of 4 + a single-lane tail), 3 on
    // matrix 1 (one partial batch), 1 on matrix 2 (single-lane batch).
    let lanes_per_matrix = [5usize, 3, 1];
    let mut tickets = Vec::new();
    let mut expected = Vec::new();
    for (m, &count) in lanes_per_matrix.iter().enumerate() {
        let a = &matrices[m];
        for k in 0..count {
            let b: Vec<f64> =
                (0..a.n).map(|i| 0.25 + ((i * 13 + k * 41 + m * 7) % 23) as f64 / 23.0).collect();
            tickets.push(svc.submit(SolveRequest::new(ids[m], b.clone())));
            expected.push((m, b));
        }
    }
    let stats = svc.drain();
    assert_eq!(stats.requests, 9);
    assert_eq!(stats.batches, 4, "4+1 / 3 / 1 lanes coalesce into four batches");

    for (ticket, (m, b)) in tickets.into_iter().zip(&expected) {
        let res = ticket.wait();
        let lone = jpcg_solve(&matrices[*m], Some(b), None, &opts);
        assert_bitwise(&res, &lone, "block-mode service ticket");
        assert!(res.converged, "block-mode request failed to converge");
    }
}

#[test]
fn bucket_rebased_program_matches_exact_n_program_bitwise() {
    // n = 729 (27x27 grid) lives in the 1024 bucket: the cached
    // coordinator executes through a program whose memory map is sized
    // to the 1024 ceiling, the uncached one compiles exactly at n.
    let a = synth::laplace2d_shifted(700, 0.12);
    assert_eq!(bucket_ceiling(a.n as u32), 1024);
    assert_ne!(a.n, 1024, "the test needs a non-bucket-aligned size");
    let rhs: Vec<Vec<f64>> = (0..3)
        .map(|k| (0..a.n).map(|i| 1.0 + ((i + 5 * k) % 4) as f64).collect())
        .collect();
    let rhs_refs: Vec<&[f64]> = rhs.iter().map(Vec::as_slice).collect();
    let cfg = CoordinatorConfig::default();

    let mut exact_coord = Coordinator::new(cfg);
    let mut exec = NativeExecutor::with_threads(&a, Scheme::MixV3, 1);
    let exact = exact_coord.solve_batch(&mut exec, &rhs_refs, None);

    let cache = Arc::new(ProgramCache::new());
    let mut bucket_coord = Coordinator::with_cache(cfg, Arc::clone(&cache));
    let mut exec2 = NativeExecutor::with_threads(&a, Scheme::MixV3, 1);
    let bucketed = bucket_coord.solve_batch(&mut exec2, &rhs_refs, None);

    assert_eq!(exact.len(), bucketed.len());
    for (e, b) in exact.iter().zip(&bucketed) {
        assert_eq!(e.iters, b.iters, "bucket rebase moved an iteration count");
        assert_eq!(e.final_rr.to_bits(), b.final_rr.to_bits());
        assert!(e.x.iter().zip(&b.x).all(|(u, v)| u.to_bits() == v.to_bits()));
    }
    assert_eq!(cache.misses(), 1, "one bucket compile served the whole batch");
}

#[test]
fn cache_hit_is_bitwise_identical_to_fresh_compile() {
    let a = synth::banded_spd(900, 9_000, 1e-3, 21);
    let opts = SolveOptions::callipepla();
    let rhs: Vec<Vec<f64>> =
        (0..4).map(|k| (0..a.n).map(|i| 1.0 + ((i + k) % 6) as f64 / 6.0).collect()).collect();

    let prep = PreparedMatrix::new(&a, 1);
    let fresh = prep.solve_batch(&rhs, &opts); // compiles per call
    let cache = Arc::new(ProgramCache::new());
    let first = prep.solve_batch_with_cache(&rhs, &opts, Some(&cache));
    assert_eq!(cache.misses(), 1);
    let hits_before = cache.hits();
    let second = prep.solve_batch_with_cache(&rhs, &opts, Some(&cache));
    assert!(cache.hits() > hits_before, "the second batch must hit the cache");
    assert_eq!(cache.misses(), 1, "no recompile on the cached path");

    for ((f, x), y) in fresh.iter().zip(&first).zip(&second) {
        assert_bitwise(f, x, "fresh vs first cached");
        assert_bitwise(x, y, "cache miss vs cache hit");
    }
}

#[test]
fn pooled_worker_batches_match_scoped_batches_bitwise() {
    let a = synth::banded_spd(1_200, 10_000, 1e-4, 33);
    // The sequential-dot golden-reference options route solve_batch to
    // the worker path; the pooled and scoped variants must agree with
    // each other and with lone solves.
    let opts = SolveOptions::default();
    let rhs: Vec<Vec<f64>> =
        (0..6).map(|k| (0..a.n).map(|i| ((i + 7 * k) % 10) as f64 / 10.0).collect()).collect();
    let prep = PreparedMatrix::new(&a, 4);
    let pooled = prep.solve_batch(&rhs, &opts);
    let scoped = prep.solve_batch_workers_scoped(&rhs, &opts);
    assert_eq!(pooled.len(), scoped.len());
    for ((p, s), b) in pooled.iter().zip(&scoped).zip(&rhs) {
        assert_bitwise(p, s, "pooled vs scoped worker batch");
        let lone = jpcg_solve(&a, Some(b), None, &opts);
        assert_bitwise(p, &lone, "worker batch vs lone solve");
    }
}

#[test]
fn tickets_fail_loudly_when_the_service_is_dropped_with_queued_work() {
    let a = synth::laplace2d_shifted(100, 0.2);
    let mut svc =
        SolverService::new(ServiceConfig { max_batch: 8, workers: 1, ..Default::default() });
    let id = svc.register(a);
    let ticket = svc.submit(SolveRequest::new(id, vec![1.0; 100]));
    drop(svc); // the lane never flushed
    let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| ticket.wait()));
    assert!(panicked.is_err(), "waiting on a dropped request must not hang");
}
