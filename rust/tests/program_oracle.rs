//! The instruction-program oracle: the compiled-ISA execution path must
//! be **bitwise identical** to the monolithic reference solver
//! (`jpcg_solve`), and the compiled program itself must satisfy the
//! paper's §5 schedule invariants.

use callipepla::coordinator::{Coordinator, CoordinatorConfig, NativeExecutor};
use callipepla::hbm::ChannelMode;
use callipepla::precision::{AccumulatorModel, Scheme};
use callipepla::program::Program;
use callipepla::solver::{jpcg_solve, DotKind, SolveOptions};
use callipepla::sparse::synth;
use callipepla::vsr::{accesses_with_vsr, count_accesses, edge_legal};

/// Options matching the instruction path's hardware models: delay-buffer
/// dots + (benign) out-of-order accumulation; the SpMV is the serial
/// gather the engine kernels reproduce bitwise at any thread count.
fn oracle_opts(scheme: Scheme) -> SolveOptions {
    SolveOptions {
        scheme,
        dot: DotKind::DelayBuffer,
        accumulator: AccumulatorModel::OutOfOrder,
        ..SolveOptions::default()
    }
}

#[test]
fn instruction_driven_solve_is_bitwise_identical_to_jpcg() {
    for &(n, nnz, delta, seed) in
        &[(1_500usize, 12_000usize, 1e-4, 21u64), (900, 7_200, 1e-3, 23)]
    {
        let a = synth::banded_spd(n, nnz, delta, seed);
        for scheme in [Scheme::Fp64, Scheme::MixV3] {
            let reference = jpcg_solve(&a, None, None, &oracle_opts(scheme));
            assert!(reference.converged, "reference must converge (n={n}, {scheme:?})");
            for threads in [1usize, 8] {
                let mut coord = Coordinator::new(CoordinatorConfig {
                    record_instructions: true,
                    ..Default::default()
                });
                let mut exec = NativeExecutor::with_threads(&a, scheme, threads);
                let b = vec![1.0; a.n];
                let x0 = vec![0.0; a.n];
                let res = coord.solve(&mut exec, &b, &x0);
                assert_eq!(
                    res.iters, reference.iters,
                    "iteration count drifted ({scheme:?}, {threads} threads)"
                );
                assert_eq!(
                    res.final_rr.to_bits(),
                    reference.final_rr.to_bits(),
                    "final rr drifted ({scheme:?}, {threads} threads)"
                );
                assert!(
                    res.x.iter().zip(&reference.x).all(|(u, v)| u.to_bits() == v.to_bits()),
                    "solution bits drifted ({scheme:?}, {threads} threads)"
                );
                // The residual trace is the same run, bit for bit.
                assert_eq!(res.trace.values().len(), reference.trace.values().len());
            }
        }
    }
}

#[test]
fn warm_start_and_nonuniform_rhs_stay_bitwise() {
    // The oracle must hold for arbitrary b / x0, not just the paper's
    // ones/zeros setup — this exercises the init trip's b preload and
    // the x0 SpMV.
    let a = synth::banded_spd(1_100, 8_800, 1e-3, 77);
    let b: Vec<f64> = (0..a.n).map(|i| 0.5 + ((i * 29) % 13) as f64 / 13.0).collect();
    let x0: Vec<f64> = (0..a.n).map(|i| ((i * 7) % 5) as f64 / 50.0).collect();
    let scheme = Scheme::MixV3;
    let reference = jpcg_solve(&a, Some(&b), Some(&x0), &oracle_opts(scheme));
    let mut coord = Coordinator::new(CoordinatorConfig::default());
    let mut exec = NativeExecutor::with_threads(&a, scheme, 8);
    let res = coord.solve(&mut exec, &b, &x0);
    assert_eq!(res.iters, reference.iters);
    assert!(res.x.iter().zip(&reference.x).all(|(u, v)| u.to_bits() == v.to_bits()));
}

#[test]
fn compiled_program_reuse_edges_all_pass_vsr() {
    // Property sweep across sizes and channel modes: every reuse edge
    // of every trip is legal under the §5.1/§5.2 rules with the trip's
    // bound scalars.
    for n in [8u32, 513, 10_000, 250_007] {
        for mode in [ChannelMode::Double, ChannelMode::Single] {
            let prog = Program::compile(n, mode);
            for trip in prog.all_trips() {
                for e in &trip.reuse_edges {
                    edge_legal(
                        e.producer,
                        e.consumer,
                        e.vector,
                        e.fifo_depth,
                        e.skew,
                        trip.kind.bound_scalars(),
                    )
                    .unwrap_or_else(|b| {
                        panic!("illegal edge {e:?} in {} (n={n}): {b:?}", trip.kind.label())
                    });
                }
            }
        }
    }
}

#[test]
fn compiled_accesses_match_section_5_5_counts() {
    let prog = Program::compile(65_536, ChannelMode::Double);
    let (mut reads, mut writes) = (0, 0);
    for p in &prog.phases {
        let (r, w) = p.access_counts();
        reads += r;
        writes += w;
    }
    assert_eq!((reads, writes), count_accesses(&accesses_with_vsr()), "10 reads + 4 writes");
}

#[test]
fn no_two_live_vectors_overlap_in_any_channel() {
    for mode in [ChannelMode::Double, ChannelMode::Single] {
        let prog = Program::compile(1_437_960, mode);
        prog.mem_map.check_no_overlap().unwrap();
        // And every compiled address is non-zero (the old placeholder).
        for trip in prog.all_trips() {
            for s in &trip.vec_steps {
                assert_ne!(s.vctrl.base_addr, 0, "placeholder base_addr in {}", trip.kind.label());
            }
        }
    }
}
