//! Telemetry-plane oracle tests (ISSUE 9 acceptance criteria).
//!
//! * Two replays of the same request trace render **byte-identical**
//!   event logs — the trace is stamped with logical clocks only
//!   (submission index, flush sequence, pass index), never wall time,
//!   thread ids, or completion ordering.
//! * A sequential and a lane-parallel run of the same batch produce
//!   byte-identical value-plane logs ([`EventLog::from_solves`]):
//!   dispatch strategy is invisible to the trace because the results
//!   are bitwise identical.
//! * A genuine schedule change — a different flush order — *is*
//!   visible: the rendered logs diverge.
//! * `ServiceStats::to_json` (the `serve --stats-json` body) has a
//!   pinned shape that round-trips through `util::json`.
//! * The Prometheus exposition covers the service / coordinator /
//!   precision / pool / program / sim metric families, and the JSON
//!   exposition parses.

use callipepla::obs::{self, first_divergence, EventLog};
use callipepla::service::{
    replay_coalesced, synth_trace, ServiceConfig, SolveRequest, SolverService, TraceConfig,
};
use callipepla::sim::AccelSimConfig;
use callipepla::solver::SolveOptions;
use callipepla::sparse::{synth, CsrMatrix};
use callipepla::util::json::Json;
use callipepla::PreparedMatrix;

fn test_matrices() -> Vec<CsrMatrix> {
    vec![
        synth::laplace2d_shifted(100, 0.2),
        synth::laplace2d_shifted(180, 0.15),
        synth::banded_spd(260, 2_600, 1e-3, 5),
    ]
}

/// A deterministic per-request right-hand side (distinct per `phase`).
fn ramp_rhs(n: usize, phase: usize) -> Vec<f64> {
    (0..n).map(|i| 1.0 + ((i + phase) % 5) as f64 / 5.0).collect()
}

/// One full coalesced replay of the canonical trace, returning the
/// rendered event log.
fn replay_rendered_log() -> String {
    let mut svc =
        SolverService::new(ServiceConfig { max_batch: 4, workers: 3, ..Default::default() });
    let sink = svc.record_events();
    let ids: Vec<_> = test_matrices().into_iter().map(|a| svc.register(a)).collect();
    let cfg = TraceConfig { requests: 48, tenants: 6, ..Default::default() };
    let trace = synth_trace(svc.registry(), &ids, &cfg);
    let _ = replay_coalesced(&mut svc, &trace);
    svc.drain();
    sink.render()
}

#[test]
fn replayed_trace_event_log_is_byte_identical_across_runs() {
    let a = replay_rendered_log();
    let b = replay_rendered_log();
    assert!(!a.is_empty(), "the sink must have recorded the schedule");
    for needle in ["submit seq=", "flush seq=", "done seq="] {
        assert!(a.contains(needle), "log must contain {needle:?} events:\n{a}");
    }
    assert_eq!(
        first_divergence(&a, &b),
        None,
        "two replays of the same trace diverged:\n--- run 1 ---\n{a}\n--- run 2 ---\n{b}"
    );
    assert_eq!(a, b, "renders compare equal line-wise but not byte-wise");
}

#[test]
fn sequential_and_lane_parallel_batches_render_identical_value_plane_logs() {
    let a = synth::laplace2d_shifted(250, 0.1);
    let opts = SolveOptions::callipepla();
    let rhs: Vec<Vec<f64>> = (0..6).map(|k| ramp_rhs(a.n, 17 * k)).collect();
    let prep = PreparedMatrix::new(&a, 2);
    let seq = prep.solve_batch(&rhs, &opts);
    let par = prep.solve_batch_parallel(&rhs, &opts, None, 0);
    let log_seq = EventLog::from_solves(&seq).render();
    let log_par = EventLog::from_solves(&par).render();
    assert!(log_seq.contains("pass seq=0"), "per-pass events missing:\n{log_seq}");
    assert!(log_seq.contains("lane_done seq="), "lane retirements missing:\n{log_seq}");
    assert_eq!(
        first_divergence(&log_seq, &log_par),
        None,
        "dispatch strategy leaked into the value-plane log"
    );
    assert_eq!(log_seq, log_par);
}

#[test]
fn flush_order_mutation_shows_up_as_a_log_diff() {
    let a = synth::laplace2d_shifted(120, 0.2);
    let run = |flush_mid: bool| {
        let mut svc =
            SolverService::new(ServiceConfig { max_batch: 8, workers: 2, ..Default::default() });
        let sink = svc.record_events();
        let id = svc.register(a.clone());
        let mut tickets = Vec::new();
        for k in 0..6u32 {
            let req = SolveRequest { matrix: id, b: ramp_rhs(a.n, 3 * k as usize), tenant: k };
            tickets.push(svc.submit(req));
            if flush_mid && k == 2 {
                svc.flush(); // cut a 3-lane batch mid-trace
            }
        }
        svc.drain();
        for t in tickets {
            t.wait();
        }
        sink.render()
    };
    let baseline = run(false);
    let mutated = run(true);
    assert!(
        first_divergence(&baseline, &mutated).is_some(),
        "a changed flush order must change the rendered log:\n{baseline}"
    );
}

#[test]
fn stats_json_shape_is_pinned() {
    let a = synth::laplace2d_shifted(150, 0.15);
    let mut svc =
        SolverService::new(ServiceConfig { max_batch: 4, workers: 2, ..Default::default() });
    let id = svc.register(a.clone());
    let tickets: Vec<_> = (0..5u32)
        .map(|k| svc.submit(SolveRequest { matrix: id, b: ramp_rhs(a.n, k as usize), tenant: k }))
        .collect();
    let stats = svc.drain();
    for t in tickets {
        t.wait();
    }

    let text = stats.to_json();
    let j = Json::parse(&text).expect("stats JSON must parse");
    assert_eq!(j.get("requests").and_then(Json::as_usize), Some(5));
    assert_eq!(j.get("rejected").and_then(Json::as_usize), Some(0));
    assert_eq!(
        j.get("resident_matrices").and_then(Json::as_usize),
        Some(stats.registry.resident)
    );
    assert_eq!(j.get("registry_evictions").and_then(Json::as_usize), Some(0));
    assert_eq!(j.get("registry_readmissions").and_then(Json::as_usize), Some(0));
    assert_eq!(
        j.get("queue_wait_p99").and_then(Json::as_usize),
        Some(stats.queue_wait_quantile(0.99) as usize)
    );
    assert_eq!(j.get("batches").and_then(Json::as_usize), Some(stats.batches as usize));
    assert_eq!(
        j.get("rhs_iterations").and_then(Json::as_usize),
        Some(stats.rhs_iterations as usize)
    );
    assert_eq!(j.get("cache_hits").and_then(Json::as_usize), Some(stats.cache_hits as usize));
    assert_eq!(j.get("cache_misses").and_then(Json::as_usize), Some(stats.cache_misses as usize));
    assert_eq!(
        j.get("compiled_programs").and_then(Json::as_usize),
        Some(stats.compiled_programs as usize)
    );
    let records = j.get("records").and_then(Json::as_arr).expect("records array");
    assert_eq!(records.len(), stats.records.len());
    assert!(!records.is_empty(), "the drained run must have executed batches");
    for (rec, json) in stats.records.iter().zip(records) {
        assert_eq!(
            json.get("matrix").and_then(Json::as_str),
            Some(rec.matrix.to_string().as_str())
        );
        assert_eq!(json.get("n").and_then(Json::as_usize), Some(rec.n));
        assert_eq!(json.get("nnz").and_then(Json::as_usize), Some(rec.nnz));
        assert_eq!(json.get("lanes").and_then(Json::as_usize), Some(rec.lanes as usize));
        assert_eq!(json.get("max_iters").and_then(Json::as_usize), Some(rec.max_iters as usize));
        assert_eq!(json.get("rhs_iters").and_then(Json::as_usize), Some(rec.rhs_iters as usize));
        assert_eq!(json.get("reason").and_then(Json::as_str), Some(rec.reason.name()));
        let waits: Vec<u64> = json
            .get("waits")
            .and_then(Json::as_arr)
            .expect("waits array")
            .iter()
            .map(|w| w.as_usize().expect("wait value") as u64)
            .collect();
        assert_eq!(waits, rec.waits);
        let tenants: Vec<u32> = json
            .get("tenants")
            .and_then(Json::as_arr)
            .expect("tenants array")
            .iter()
            .map(|t| t.as_usize().expect("tenant id") as u32)
            .collect();
        assert_eq!(tenants, rec.tenants);
    }
}

/// The queue-wait clock is *per matrix*: a lane's recorded wait counts
/// only same-matrix submissions accepted between its submit and its
/// dispatch, so an idle matrix's lanes are not charged for other
/// matrices' traffic (the bug the global-clock histogram had).
#[test]
fn queue_wait_counts_same_matrix_submissions_only() {
    let a = synth::laplace2d_shifted(100, 0.2);
    let b = synth::laplace2d_shifted(180, 0.15);
    let mut svc =
        SolverService::new(ServiceConfig { max_batch: 4, workers: 2, ..Default::default() });
    let id_a = svc.register(a.clone());
    let id_b = svc.register(b.clone());

    // Three lanes park on A, then heavy traffic floods B (two full
    // batches), then the drain cuts A's partial group.
    let mut tickets = Vec::new();
    for k in 0..3usize {
        tickets.push(svc.submit(SolveRequest::new(id_a, ramp_rhs(a.n, k))));
    }
    for k in 0..8usize {
        tickets.push(svc.submit(SolveRequest::new(id_b, ramp_rhs(b.n, k))));
    }
    let stats = svc.drain();
    for t in tickets {
        t.wait();
    }

    let a_rec = stats
        .records
        .iter()
        .find(|r| r.matrix == id_a)
        .expect("A's partial group flushed on drain");
    // On the per-matrix clock A's oldest lane waited through exactly
    // its two same-matrix successors; the global clock would have
    // charged it the eight B submissions too (wait 10).
    assert_eq!(a_rec.waits, vec![2, 1, 0]);
    for rec in stats.records.iter().filter(|r| r.matrix == id_b) {
        assert!(
            rec.waits.iter().all(|&w| w < 8),
            "B's batch-full lanes wait less than one full window: {:?}",
            rec.waits
        );
    }
    assert!(stats.queue_wait_quantile(0.99) <= 7, "p99 rides the per-matrix clock");
}

#[test]
fn prometheus_dump_covers_the_required_metric_families() {
    // Open the recording gate for this run (shared process-global
    // state, so every assertion below is ">= / > 0", never "==").
    obs::set_recording(true);
    let a = synth::laplace2d_shifted(150, 0.15);
    let mut svc =
        SolverService::new(ServiceConfig { max_batch: 4, workers: 2, ..Default::default() });
    let id = svc.register(a.clone());
    let tickets: Vec<_> = (0..4u32)
        .map(|k| svc.submit(SolveRequest { matrix: id, b: ramp_rhs(a.n, k as usize), tenant: k }))
        .collect();
    let stats = svc.drain();
    for t in tickets {
        t.wait();
    }
    stats.export_time_plane_gauges(&AccelSimConfig::callipepla());
    obs::set_recording(false);

    let text = obs::prometheus_dump();
    for family in [
        "callipepla_service_requests_total",
        "callipepla_service_coalesce_width_lanes",
        "callipepla_service_queue_wait_submissions",
        "callipepla_service_flush_deadline_total",
        "callipepla_service_submit_rejected_total",
        "callipepla_service_registry_evictions_total",
        "callipepla_service_registry_readmissions_total",
        "callipepla_service_program_cache_evictions_total",
        "callipepla_service_http_requests_total",
        "callipepla_coord_phase1_trips_total",
        "callipepla_precision_matrix_value_reads_total",
        "callipepla_pool_jobs_total",
        "callipepla_program_trips_issued_total",
        "callipepla_sim_modeled_trace_cycles",
    ] {
        assert!(text.contains(&format!("# HELP {family} ")), "missing HELP for {family}");
        assert!(text.contains(&format!("# TYPE {family} ")), "missing TYPE for {family}");
    }

    let snap = obs::snapshot();
    assert!(snap.counter("callipepla_service_requests_total") >= 4);
    assert!(snap.counter("callipepla_service_batches_total") >= 1);
    assert!(snap.counter("callipepla_coord_phase1_trips_total") > 0);
    assert!(snap.counter("callipepla_coord_init_trips_total") > 0);
    // LocalCounter totals are ungated — the counter walls always count.
    assert!(snap.counter("callipepla_precision_matrix_value_reads_total") > 0);
    assert!(snap.counter("callipepla_pool_jobs_total") > 0);
    assert!(snap.counter("callipepla_program_trips_issued_total") > 0);

    // The JSON exposition of the same snapshot parses and carries the
    // same instrument names.
    let json = obs::render_json(&snap);
    let parsed = Json::parse(&json).expect("metrics JSON must parse");
    let metrics = parsed.get("metrics").and_then(Json::as_arr).expect("metrics array");
    let has_requests = metrics
        .iter()
        .any(|m| m.get("name").and_then(Json::as_str) == Some("callipepla_service_requests_total"));
    assert!(has_requests, "JSON exposition must list the service request counter");
}

#[test]
fn docs_catalog_lists_every_registered_instrument() {
    let doc_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/OBSERVABILITY.md");
    let doc = std::fs::read_to_string(doc_path).expect("docs/OBSERVABILITY.md must exist");
    for metric in callipepla::obs::catalog::all() {
        assert!(
            doc.contains(metric.name()),
            "docs/OBSERVABILITY.md is missing `{}` — update the metric catalog table",
            metric.name()
        );
    }
}
