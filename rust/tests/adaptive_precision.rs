//! The adaptive-precision correctness wall (ISSUE 8).
//!
//! The controller's decisions are a **pure function of the residual
//! sequence** — no clocks, no thread ids, no dispatch-order state — so
//! every dispatch path that produces the same rr sequence must emit the
//! same [`PrecisionTrace`], and a recorded trace must replay the solve
//! bitwise.  Three walls pin that:
//!
//! 1. **Path invariance**: randomized (matrix, policy) draws solved on
//!    {sequential walk, lane-parallel, staged block, resident block} x
//!    workers {1, 2, 8} produce identical traces *and* identical result
//!    bits, lane for lane.
//! 2. **Replay**: feeding a lane's recorded trace back through
//!    [`jpcg_solve_replay`] reproduces x, rr, iters, and the trace
//!    itself bitwise.
//! 3. **Static regression pin**: with the controller off
//!    (`opts.adaptive = None`) every scheme's solve is bitwise the
//!    fixed-scheme path on all entry points — PR 8 must not move a bit
//!    of existing behaviour.

use callipepla::engine::PreparedMatrix;
use callipepla::precision::adaptive::{AdaptivePolicy, PrecisionTrace, SwitchReason};
use callipepla::precision::Scheme;
use callipepla::solver::{jpcg_solve, jpcg_solve_replay, SolveOptions, SolveResult};
use callipepla::sparse::{synth, CsrMatrix};
use callipepla::util::rng::Rng64;

/// Randomized draws per property wall (each draw is a full multi-path
/// batch solve; keep the wall thorough but CI-sized).
const PROPERTY_DRAWS: u64 = 5;
const LANES: usize = 5;

fn make_rhs(n: usize, lanes: usize) -> Vec<Vec<f64>> {
    (0..lanes)
        .map(|k| (0..n).map(|i| 0.5 + ((i * 13 + k * 89) % 19) as f64 / 19.0).collect())
        .collect()
}

/// A random well-conditioned SPD system plus a random (but sane)
/// adaptive policy — policies that can fire both the guard-band and the
/// stall rule on systems this size.
fn draw_case(rng: &mut Rng64) -> (CsrMatrix, AdaptivePolicy) {
    let n = 300 + rng.gen_range(500);
    let nnz = n * (6 + rng.gen_range(6));
    let delta = [1e-2, 1e-3][rng.gen_range(2)];
    let a = synth::banded_spd(n, nnz, delta, 0x5EED ^ rng.next_u64());
    let (start, escalate_to) = [
        (Scheme::MixV3, Scheme::Fp64),
        (Scheme::MixV2, Scheme::Fp64),
        (Scheme::MixV1, Scheme::MixV3),
        (Scheme::MixV3, Scheme::MixV3), // degenerate: escalation is a no-op
    ][rng.gen_range(4)];
    let policy = AdaptivePolicy {
        start,
        escalate_to,
        stall_window: [4, 8, 16][rng.gen_range(3)],
        stall_ratio: [0.5, 0.9][rng.gen_range(2)],
        guard_band: [10.0, 100.0][rng.gen_range(2)],
    };
    (a, policy)
}

fn bitwise_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(u, v)| u.to_bits() == v.to_bits())
}

/// Full observable equality: solution bits, rr bits, iteration count,
/// and the precision trace itself.
fn assert_identical(want: &[SolveResult], got: &[SolveResult], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: result count");
    for (k, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.iters, g.iters, "{what}: lane {k} iters");
        assert_eq!(w.converged, g.converged, "{what}: lane {k} converged");
        assert_eq!(w.final_rr.to_bits(), g.final_rr.to_bits(), "{what}: lane {k} rr bits");
        assert!(bitwise_eq(&w.x, &g.x), "{what}: lane {k} solution bits");
        assert_eq!(w.precision, g.precision, "{what}: lane {k} precision trace");
    }
}

/// Every batch entry point the coordinator owns, against the
/// sequential-walk oracle, at several worker counts.
fn all_paths(prep: &PreparedMatrix, rhs: &[Vec<f64>], opts: &SolveOptions, what: &str) {
    let seq = prep.solve_batch(rhs, opts);
    for workers in [1usize, 2, 8] {
        let par = prep.solve_batch_parallel(rhs, opts, None, workers);
        assert_identical(&seq, &par, &format!("{what} lane-parallel w={workers}"));
        let staged = prep.solve_batch_block_staged_parallel(rhs, opts, None, workers);
        assert_identical(&seq, &staged, &format!("{what} block-staged w={workers}"));
        let resident = prep.solve_batch_block_parallel(rhs, opts, None, workers);
        assert_identical(&seq, &resident, &format!("{what} block-resident w={workers}"));
    }
    let staged = prep.solve_batch_block_staged(rhs, opts);
    assert_identical(&seq, &staged, &format!("{what} block-staged seq"));
    let resident = prep.solve_batch_block(rhs, opts);
    assert_identical(&seq, &resident, &format!("{what} block-resident seq"));
}

#[test]
fn adaptive_traces_are_invariant_across_every_dispatch_path() {
    for draw in 0..PROPERTY_DRAWS {
        let mut rng = Rng64::seed_from_u64(0xCA11_15A1 ^ (draw * 0x9E37));
        let (a, policy) = draw_case(&mut rng);
        let mut opts = SolveOptions::callipepla();
        opts.adaptive = Some(policy);
        opts.max_iters = 3_000;
        let rhs = make_rhs(a.n, LANES);
        let prep = PreparedMatrix::new(&a, 2);
        // The oracle trace must actually be adaptive (a Start event at
        // pass 0 under the policy's start scheme).
        let seq = prep.solve_batch(&rhs, &opts);
        for (k, r) in seq.iter().enumerate() {
            let first = r.precision.events().first().expect("trace never empty");
            assert_eq!(first.pass, 0, "draw {draw} lane {k}");
            assert_eq!(first.scheme, policy.start, "draw {draw} lane {k}");
            assert_eq!(first.reason, SwitchReason::Start, "draw {draw} lane {k}");
        }
        all_paths(&prep, &rhs, &opts, &format!("draw {draw}"));
    }
}

#[test]
fn lanes_escalating_at_different_passes_still_agree_across_paths() {
    // Force a *mixed-scheme block*: per-lane rhs magnitudes spread the
    // residual histories so lanes cross the guard band on different
    // passes — the staged and resident block paths must regroup lanes
    // by scheme mid-flight and still match the sequential walk bitwise.
    let a = synth::banded_spd(900, 8_100, 1e-3, 77);
    let mut rhs = make_rhs(a.n, LANES);
    for (k, r) in rhs.iter_mut().enumerate() {
        let scale = 10f64.powi(k as i32 - 2); // 1e-2 .. 1e2
        r.iter_mut().for_each(|v| *v *= scale);
    }
    let mut opts = SolveOptions::callipepla();
    opts.adaptive = Some(AdaptivePolicy::default());
    let prep = PreparedMatrix::new(&a, 2);
    let seq = prep.solve_batch(&rhs, &opts);
    // The point of the setup: at least two lanes escalate on different
    // passes (otherwise the block stays uniform and nothing is tested).
    let switch_passes: Vec<Option<u32>> =
        seq.iter().map(|r| r.precision.events().get(1).map(|e| e.pass)).collect();
    let distinct: std::collections::BTreeSet<_> =
        switch_passes.iter().flatten().copied().collect();
    assert!(
        distinct.len() >= 2,
        "setup failed to produce staggered escalations: {switch_passes:?}"
    );
    all_paths(&prep, &rhs, &opts, "staggered escalation");
}

#[test]
fn replay_reproduces_recorded_solves_bitwise() {
    let mut rng = Rng64::seed_from_u64(0xCA11_15A2);
    for draw in 0..PROPERTY_DRAWS {
        let (a, policy) = draw_case(&mut rng);
        let mut opts = SolveOptions::callipepla();
        opts.adaptive = Some(policy);
        opts.max_iters = 3_000;
        let rhs = make_rhs(a.n, 2);
        let prep = PreparedMatrix::new(&a, 2);
        for (k, live) in prep.solve_batch(&rhs, &opts).iter().enumerate() {
            let replay = jpcg_solve_replay(&a, Some(rhs[k].as_slice()), None, &opts, &live.precision);
            let what = format!("draw {draw} lane {k}");
            assert_eq!(live.iters, replay.iters, "{what} iters");
            assert_eq!(live.final_rr.to_bits(), replay.final_rr.to_bits(), "{what} rr");
            assert!(bitwise_eq(&live.x, &replay.x), "{what} solution bits");
            assert_eq!(live.precision, replay.precision, "{what} trace");
        }
    }
}

#[test]
fn replayed_csv_roundtrip_drives_the_same_solve() {
    // Serialize a live trace to CSV, parse it back, replay from the
    // parsed schedule: the full record/ship/re-run loop.
    let a = synth::banded_spd(700, 6_300, 1e-3, 99);
    let mut opts = SolveOptions::callipepla();
    opts.adaptive = Some(AdaptivePolicy::default());
    let live = jpcg_solve(&a, None, None, &opts);
    assert!(live.converged);
    let parsed = PrecisionTrace::from_csv(&live.precision.to_csv()).expect("roundtrip parses");
    assert_eq!(parsed, live.precision);
    let replay = jpcg_solve_replay(&a, None, None, &opts, &parsed);
    assert!(bitwise_eq(&live.x, &replay.x), "replay-from-CSV solution bits");
    assert_eq!(live.final_rr.to_bits(), replay.final_rr.to_bits());
}

#[test]
fn static_mode_is_bitwise_the_fixed_paths_for_every_scheme() {
    // The regression pin: adaptive machinery off (`opts.adaptive =
    // None`) must leave all four schemes' results bitwise identical to
    // the lone reference solve, on every batch entry point — and record
    // exactly one Static event naming the scheme that ran.
    let a = synth::banded_spd(800, 7_200, 1e-3, 55);
    let rhs = make_rhs(a.n, 3);
    for scheme in Scheme::ALL {
        let mut opts = SolveOptions::callipepla();
        opts.scheme = scheme;
        let lone: Vec<SolveResult> =
            rhs.iter().map(|b| jpcg_solve(&a, Some(b.as_slice()), None, &opts)).collect();
        for r in &lone {
            assert!(r.converged, "{scheme:?}: reference must converge");
            assert_eq!(r.precision.len(), 1, "{scheme:?}: one event");
            let e = r.precision.events()[0];
            assert_eq!((e.pass, e.scheme, e.reason), (0, scheme, SwitchReason::Static));
        }
        let prep = PreparedMatrix::new(&a, 2);
        let batch = prep.solve_batch(&rhs, &opts);
        assert_identical(&lone, &batch, &format!("{scheme:?} static batch"));
        all_paths(&prep, &rhs, &opts, &format!("{scheme:?} static"));
    }
}

#[test]
fn repeated_adaptive_runs_never_move_a_bit() {
    // Scheduling noise must not reach an adaptive solve: same inputs,
    // full worker fan-out, five runs, identical traces and bits.
    let a = synth::banded_spd(600, 5_400, 1e-3, 11);
    let rhs = make_rhs(a.n, 4);
    let mut opts = SolveOptions::callipepla();
    opts.adaptive = Some(AdaptivePolicy::default());
    let prep = PreparedMatrix::new(&a, 2);
    let first = prep.solve_batch_block_parallel(&rhs, &opts, None, 8);
    for run in 1..5 {
        let again = prep.solve_batch_block_parallel(&rhs, &opts, None, 8);
        assert_identical(&first, &again, &format!("run {run}"));
    }
}
