//! The batched-program oracle: one compiled instruction stream
//! vectorized over many right-hand sides must be **bitwise identical
//! per RHS** to sequential [`jpcg_solve`] calls, individual systems
//! must terminate on the fly without perturbing the rest of the batch,
//! and a freed lane's trips must stop issuing.

use callipepla::coordinator::{BlockMode, Coordinator, CoordinatorConfig, NativeExecutor};
use callipepla::engine::PreparedMatrix;
use callipepla::precision::{AccumulatorModel, Scheme};
use callipepla::solver::{jpcg_solve, DotKind, SolveOptions};
use callipepla::sparse::synth;

/// Options matching the instruction path's hardware models (see
/// `tests/program_oracle.rs`): delay-buffer dots + the value-neutral
/// out-of-order accumulator.
fn oracle_opts(scheme: Scheme) -> SolveOptions {
    SolveOptions {
        scheme,
        dot: DotKind::DelayBuffer,
        accumulator: AccumulatorModel::OutOfOrder,
        ..SolveOptions::default()
    }
}

fn bitwise_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(u, v)| u.to_bits() == v.to_bits())
}

/// Deterministic, per-lane-distinct right-hand sides.
fn make_rhs(n: usize, lanes: usize) -> Vec<Vec<f64>> {
    (0..lanes)
        .map(|k| (0..n).map(|i| 0.25 + ((i * 17 + k * 101) % 23) as f64 / 23.0).collect())
        .collect()
}

#[test]
fn batched_program_is_bitwise_identical_per_rhs() {
    let a = synth::banded_spd(1_200, 9_600, 1e-3, 19);
    let rhs = make_rhs(a.n, 5);
    for scheme in [Scheme::Fp64, Scheme::MixV3] {
        let opts = oracle_opts(scheme);
        let prep = PreparedMatrix::new(&a, 4);
        // The routed path: PreparedMatrix::solve_batch -> batched
        // program -> Coordinator::solve_batch -> NativeExecutor.
        let batch = prep.solve_batch(&rhs, &opts);
        assert_eq!(batch.len(), rhs.len());
        for (k, b) in rhs.iter().enumerate() {
            let lone = jpcg_solve(&a, Some(b), None, &opts);
            assert!(lone.converged, "reference must converge (rhs {k}, {scheme:?})");
            assert_eq!(batch[k].iters, lone.iters, "rhs {k} iteration count ({scheme:?})");
            assert_eq!(
                batch[k].final_rr.to_bits(),
                lone.final_rr.to_bits(),
                "rhs {k} final rr ({scheme:?})"
            );
            assert!(bitwise_eq(&batch[k].x, &lone.x), "rhs {k} solution bits ({scheme:?})");
            assert_eq!(batch[k].flops, lone.flops, "rhs {k} flops accounting ({scheme:?})");
        }
        // And the worker-per-RHS model path agrees bit for bit.
        let workers = prep.solve_batch_workers(&rhs, &opts);
        for (k, (p, w)) in batch.iter().zip(&workers).enumerate() {
            assert_eq!(p.iters, w.iters, "rhs {k}: paths disagree");
            assert!(bitwise_eq(&p.x, &w.x), "rhs {k}: paths disagree on bits");
        }
    }
}

#[test]
fn early_convergence_frees_the_lane_without_perturbing_survivors() {
    let a = synth::banded_spd(900, 7_200, 1e-3, 23);
    let scheme = Scheme::MixV3;
    // Lane 1 warm-starts at the solution and converges within a couple
    // of trips; lanes 0 and 2 run cold to full convergence — a
    // mixed-size batch by construction.
    let b = vec![1.0; a.n];
    let warm = jpcg_solve(&a, Some(&b), None, &oracle_opts(scheme));
    assert!(warm.converged);
    let cold = vec![0.0; a.n];
    let b2: Vec<f64> = (0..a.n).map(|i| 0.5 + ((i * 29) % 13) as f64 / 13.0).collect();
    let rhs: Vec<&[f64]> = vec![&b, &b, &b2];
    let x0s: Vec<&[f64]> = vec![&cold, &warm.x, &cold];

    let cfg = CoordinatorConfig {
        record_instructions: true,
        record_trace: true,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg);
    let mut exec = NativeExecutor::with_threads(&a, scheme, 4);
    let batch = coord.solve_batch(&mut exec, &rhs, Some(&x0s));
    assert_eq!(batch.len(), 3);
    assert!(batch.iter().all(|r| r.converged));

    // The warm lane terminated on the fly, well before the cold ones.
    assert!(
        batch[1].iters + 2 < batch[0].iters,
        "warm lane should exit early: warm={} cold={}",
        batch[1].iters,
        batch[0].iters
    );

    // Every lane — survivors included — is bitwise the lone solve of
    // the same system: the freed slot perturbed nothing.
    for (k, r) in batch.iter().enumerate() {
        let mut solo_coord = Coordinator::new(cfg);
        let mut solo_exec = NativeExecutor::with_threads(&a, scheme, 4);
        let solo = solo_coord.solve(&mut solo_exec, rhs[k], x0s[k]);
        assert_eq!(r.iters, solo.iters, "lane {k} iters");
        assert_eq!(r.final_rr.to_bits(), solo.final_rr.to_bits(), "lane {k} rr");
        assert!(bitwise_eq(&r.x, &solo.x), "lane {k} solution bits");
        let (rt, st) = (r.trace.values(), solo.trace.values());
        assert_eq!(rt.len(), st.len(), "lane {k} trace length");
        assert!(bitwise_eq(rt, st), "lane {k} residual trace bits");
    }

    // The freed slot's trips stopped issuing: per-lane instruction
    // counts scale with the lane's own iterations (one M1 per phase-1
    // trip plus the merged init), and the write-ack stream stops with
    // them (init writes 2; a full iteration 4; the converged iteration
    // 2 — ap and the exit x).
    for (k, r) in batch.iter().enumerate() {
        assert_eq!(
            r.instructions.count_for("M1") as u32,
            r.iters + 1,
            "lane {k}: M1 issues after the lane was freed"
        );
        let want_acks = if r.iters == 0 { 2 } else { 4 * r.iters };
        assert_eq!(r.mem_acks as u32, want_acks, "lane {k}: ack stream ran on");
        // The converged iteration dispatched the exit trip (M3 without
        // M7): one M7 for the init p = z copy + one per full phase-3.
        let want_m7 = if r.iters == 0 { 1 } else { r.iters };
        assert_eq!(
            r.instructions.count_for("M7") as u32,
            want_m7,
            "lane {k}: converged-exit trip should skip M7"
        );
    }
}

#[test]
fn block_kernel_retires_lanes_without_perturbing_survivors() {
    // A mixed-convergence batch under resident block mode must hand
    // every lane the iteration count (and bits) of solving it alone —
    // retired lanes leave the arenas (extraction + compaction, and the
    // final survivor's gather-out) without perturbing the survivors.
    let a = synth::banded_spd(900, 7_200, 1e-3, 23);
    let scheme = Scheme::MixV3;
    let b = vec![1.0; a.n];
    let warm = jpcg_solve(&a, Some(&b), None, &oracle_opts(scheme));
    assert!(warm.converged);
    let cold = vec![0.0; a.n];
    let b2: Vec<f64> = (0..a.n).map(|i| 0.5 + ((i * 29) % 13) as f64 / 13.0).collect();
    let rhs: Vec<&[f64]> = vec![&b, &b, &b2];
    let x0s: Vec<&[f64]> = vec![&cold, &warm.x, &cold];

    let cfg = CoordinatorConfig {
        block: BlockMode::Resident,
        record_instructions: true,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg);
    let mut exec = NativeExecutor::with_threads(&a, scheme, 4);
    let batch = coord.solve_batch(&mut exec, &rhs, Some(&x0s));
    assert_eq!(batch.len(), 3);
    assert!(batch.iter().all(|r| r.converged));
    assert!(
        batch[1].iters + 2 < batch[0].iters,
        "warm lane should retire early: warm={} cold={}",
        batch[1].iters,
        batch[0].iters
    );

    for (k, r) in batch.iter().enumerate() {
        // The lone reference: the same system through the block kernel
        // at batch 1.
        let mut solo_coord = Coordinator::new(cfg);
        let mut solo_exec = NativeExecutor::with_threads(&a, scheme, 4);
        let solo = &solo_coord.solve_batch(&mut solo_exec, &rhs[k..k + 1], Some(&x0s[k..k + 1]))[0];
        assert_eq!(r.iters, solo.iters, "lane {k} iters vs solo block solve");
        assert_eq!(r.final_rr.to_bits(), solo.final_rr.to_bits(), "lane {k} rr");
        assert!(bitwise_eq(&r.x, &solo.x), "lane {k} solution bits");
        // And the retired lane's instruction stream stopped with it.
        assert_eq!(r.instructions.count_for("M1") as u32, r.iters + 1, "lane {k} M1 count");
    }
}

#[test]
fn batch_results_are_independent_of_batch_composition() {
    // A system's result must not depend on which other systems share
    // the batch — solve lane 0 alone, in a pair, and in a quad.
    let a = synth::laplace2d_shifted(400, 0.1);
    let rhs = make_rhs(a.n, 4);
    let opts = oracle_opts(Scheme::MixV3);
    let prep = PreparedMatrix::new(&a, 2);
    let solo = prep.solve_batch(&rhs[0..1], &opts);
    let pair = prep.solve_batch(&rhs[0..2], &opts);
    let quad = prep.solve_batch(&rhs, &opts);
    for other in [&pair[0], &quad[0]] {
        assert_eq!(solo[0].iters, other.iters);
        assert!(bitwise_eq(&solo[0].x, &other.x));
    }
}

#[test]
fn zero_rhs_lane_converges_on_the_init_trip_inside_a_batch() {
    let a = synth::laplace2d_shifted(100, 0.1);
    let zero = vec![0.0; a.n];
    let one = vec![1.0; a.n];
    let rhs: Vec<&[f64]> = vec![&zero, &one];
    let cfg = CoordinatorConfig { record_instructions: true, ..Default::default() };
    let mut coord = Coordinator::new(cfg);
    let mut exec = NativeExecutor::new(&a, Scheme::MixV3);
    let batch = coord.solve_batch(&mut exec, &rhs, None);
    assert!(batch[0].converged);
    assert_eq!(batch[0].iters, 0, "zero RHS converges on the merged init alone");
    assert_eq!(batch[0].instructions.count_for("M2"), 0, "no iteration trips issued");
    assert!(batch[1].converged);
    assert!(batch[1].iters > 0);
}

#[test]
fn empty_batch_is_empty_on_the_program_path() {
    let a = synth::laplace2d_shifted(64, 0.1);
    let mut coord = Coordinator::new(CoordinatorConfig::default());
    let mut exec = NativeExecutor::new(&a, Scheme::MixV3);
    assert!(coord.solve_batch(&mut exec, &[], None).is_empty());
    let prep = PreparedMatrix::new(&a, 4);
    assert!(prep.solve_batch(&[], &oracle_opts(Scheme::MixV3)).is_empty());
    // The lane-parallel entries return just as cleanly.
    assert!(prep.solve_batch_parallel(&[], &oracle_opts(Scheme::MixV3), None, 4).is_empty());
    let mut no_execs: Vec<NativeExecutor> = Vec::new();
    assert!(coord.solve_batch_parallel(&mut no_execs, &[], None).is_empty());
}

#[test]
fn chunk_boundaries_leave_every_lane_a_lone_solve() {
    // A batch cut into compiled chunks (the max_batch seam, forced here
    // with the chunk-lane cap so it triggers at test-sized n) must
    // still hand back per-lane results bitwise identical to lone
    // reference solves — chunk composition is an addressing detail.
    let a = synth::laplace2d_shifted(200, 0.2);
    let rhs = make_rhs(a.n, 11);
    let opts = oracle_opts(Scheme::MixV3);
    for chunk in [1u32, 4, 8] {
        let cfg = CoordinatorConfig { max_chunk_lanes: chunk, ..Default::default() };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::with_threads(&a, Scheme::MixV3, 1);
        let refs: Vec<&[f64]> = rhs.iter().map(Vec::as_slice).collect();
        let batch = coord.solve_batch(&mut exec, &refs, None);
        assert_eq!(batch.len(), rhs.len());
        for (k, b) in rhs.iter().enumerate() {
            let lone = jpcg_solve(&a, Some(b), None, &opts);
            assert_eq!(batch[k].iters, lone.iters, "chunk={chunk} rhs {k}");
            assert!(bitwise_eq(&batch[k].x, &lone.x), "chunk={chunk} rhs {k} bits");
        }
    }
}

#[test]
fn one_element_system_solves_in_a_batch() {
    // n == 1 is the degenerate memory map (one beat per vector): the
    // compiled program, the dots and the left-divide must all handle a
    // single-element stream, on both dispatch paths.
    use callipepla::sparse::CooMatrix;
    let mut coo = CooMatrix::new(1);
    coo.push(0, 0, 4.0);
    let a = coo.to_csr();
    let rhs: Vec<Vec<f64>> = vec![vec![2.0], vec![-6.0], vec![0.0]];
    let opts = oracle_opts(Scheme::Fp64);
    let prep = PreparedMatrix::new(&a, 2);
    let batch = prep.solve_batch(&rhs, &opts);
    let par = prep.solve_batch_parallel(&rhs, &opts, None, 2);
    assert_eq!(batch.len(), 3);
    for (k, b) in rhs.iter().enumerate() {
        let lone = jpcg_solve(&a, Some(b), None, &opts);
        assert!(lone.converged);
        assert_eq!(batch[k].iters, lone.iters, "rhs {k}");
        assert!(bitwise_eq(&batch[k].x, &lone.x), "rhs {k}");
        assert!(bitwise_eq(&par[k].x, &lone.x), "rhs {k} (parallel)");
    }
    // 4 x = 2 -> x = 0.5 exactly (powers of two), and the zero lane
    // converges on the merged init alone.
    assert_eq!(batch[0].x[0], 0.5);
    assert_eq!(batch[2].iters, 0);
}
