//! Production-hardening oracle tests (ROADMAP item 4 / ISSUE 10):
//! the capacity-bounded registry, the logical-clock deadline flush,
//! typed admission control, and the HTTP front door.
//!
//! * Matrix ids are registry-tagged: submitting another service's id is
//!   a typed error, never a silent mis-resolution.
//! * LRU eviction and readmission under a capacity budget are
//!   bitwise-invisible — every ticket through the churn still matches a
//!   lone `jpcg_solve`, in-flight batches keep their `Arc`s, pinned
//!   entries never leave residency.
//! * Deadline flushes ride the submission-count logical clock: two runs
//!   of the same request sequence render byte-identical event logs and
//!   bitwise-identical results.
//! * Backpressure (bounded pending queue) and per-tenant quotas reject
//!   with typed errors the front door maps to 429; validation errors
//!   map to 400.
//! * Every HTTP route works through the socket-free `handle_request`
//!   seam, and one real `TcpListener` round-trip proves the wire path.

use callipepla::obs::{first_divergence, FlushReason, PROMETHEUS_CONTENT_TYPE};
use callipepla::service::{
    footprint_beats, handle_request, serve_http, RegistryError, ServiceConfig, SolveRequest,
    SolverService, SubmitError,
};
use callipepla::solver::{jpcg_solve, SolveOptions, SolveResult};
use callipepla::sparse::{synth, CsrMatrix};
use callipepla::util::json::Json;

fn ramp_rhs(n: usize, phase: usize) -> Vec<f64> {
    (0..n).map(|i| 1.0 + ((i + phase) % 5) as f64 / 5.0).collect()
}

fn assert_bitwise(a: &SolveResult, b: &SolveResult, what: &str) {
    assert_eq!(a.iters, b.iters, "{what}: iteration counts differ");
    assert_eq!(a.final_rr.to_bits(), b.final_rr.to_bits(), "{what}: final rr differs");
    assert!(
        a.x.len() == b.x.len() && a.x.iter().zip(&b.x).all(|(u, v)| u.to_bits() == v.to_bits()),
        "{what}: solution bits differ"
    );
}

#[test]
fn foreign_ids_from_another_service_are_a_typed_rejection() {
    let a = synth::laplace2d_shifted(100, 0.2);
    let mut svc1 = SolverService::new(ServiceConfig::default());
    let mut svc2 = SolverService::new(ServiceConfig::default());
    let id1 = svc1.register(a.clone());
    let id2 = svc2.register(a.clone());

    // Same slot number, different registry — before the tag this
    // resolved silently to svc2's own matrix.
    assert_eq!(id1.index(), id2.index());
    let err = svc2
        .try_submit(SolveRequest::new(id1, vec![1.0; 100]))
        .expect_err("a foreign id must not resolve");
    match &err {
        SubmitError::Registry(RegistryError::ForeignId { .. }) => {}
        other => panic!("expected a ForeignId rejection, got {other:?}"),
    }
    assert!(err.to_string().contains("minted by registry"), "diagnostic names the tag: {err}");
    // The legitimate id still works on its own service.
    let t = svc2.submit(SolveRequest::new(id2, vec![1.0; 100]));
    svc2.flush();
    assert!(t.wait().converged);
    assert_eq!(svc2.stats().rejected, 1);
}

#[test]
fn eviction_churn_under_load_is_bitwise_invisible_and_respects_pins() {
    let matrices: Vec<CsrMatrix> = vec![
        synth::laplace2d_shifted(100, 0.2),
        synth::laplace2d_shifted(180, 0.15),
        synth::laplace2d_shifted(260, 0.1),
        synth::laplace2d_shifted(330, 0.08),
    ];
    let fps: Vec<u64> = matrices.iter().map(|a| footprint_beats(a.n, a.nnz())).collect();
    // Room for the pinned matrix plus two of the largest: any single
    // readmission always fits, but the full working set never does.
    let capacity = fps[0] + 2 * fps.iter().copied().max().unwrap();
    assert!(capacity < fps.iter().sum::<u64>(), "the budget must force eviction");

    let mut svc = SolverService::new(ServiceConfig {
        max_batch: 2,
        workers: 2,
        capacity_beats: capacity,
        ..Default::default()
    });
    let ids: Vec<_> = matrices.iter().map(|a| svc.register(a.clone())).collect();
    svc.pin(ids[0]).expect("pinning an admitted matrix");

    // Round-robin across all four matrices: every submission after the
    // first few readmits something the previous ones evicted, while
    // batches from earlier rounds are still in flight on the pool.
    let opts = SolveOptions::callipepla();
    let mut tickets = Vec::new();
    let mut expected = Vec::new();
    for round in 0..6usize {
        for (m, a) in matrices.iter().enumerate() {
            let b = ramp_rhs(a.n, round * 7 + m);
            tickets.push(svc.submit(SolveRequest::new(ids[m], b.clone())));
            expected.push((m, b));
        }
    }
    let stats = svc.drain();
    assert!(stats.registry.evictions > 0, "the budget must have evicted");
    assert!(stats.registry.readmissions > 0, "evicted matrices must have come back");
    assert!(
        svc.registry().is_resident(ids[0]),
        "the pinned matrix never leaves residency through the churn"
    );
    for (ticket, (m, b)) in tickets.into_iter().zip(&expected) {
        let res = ticket.wait();
        let lone = jpcg_solve(&matrices[*m], Some(b), None, &opts);
        assert_bitwise(&res, &lone, "ticket through eviction churn");
    }
}

#[test]
fn deadline_flushes_are_deterministic_and_bitwise() {
    let matrices =
        [synth::laplace2d_shifted(100, 0.2), synth::laplace2d_shifted(180, 0.15)];
    let run = || {
        let mut svc = SolverService::new(ServiceConfig {
            max_batch: 8,
            workers: 2,
            deadline: 5,
            ..Default::default()
        });
        let sink = svc.record_events();
        let ids: Vec<_> = matrices.iter().map(|a| svc.register(a.clone())).collect();
        let tickets: Vec<_> = (0..24usize)
            .map(|k| {
                let m = k % 2;
                svc.submit(SolveRequest {
                    matrix: ids[m],
                    b: ramp_rhs(matrices[m].n, k),
                    tenant: (k % 3) as u32,
                })
            })
            .collect();
        let stats = svc.drain();
        let results: Vec<SolveResult> = tickets.into_iter().map(|t| t.wait()).collect();
        (sink.render(), stats, results)
    };
    let (log_a, stats_a, results_a) = run();
    let (log_b, _, results_b) = run();

    assert!(
        stats_a.records.iter().any(|r| r.reason == FlushReason::Deadline),
        "a 5-submission deadline under max_batch 8 must cut batches"
    );
    assert!(log_a.contains("reason=deadline"), "deadline cuts are named in the log:\n{log_a}");
    assert_eq!(
        first_divergence(&log_a, &log_b),
        None,
        "deadline flushes must replay byte-identically:\n--- a ---\n{log_a}\n--- b ---\n{log_b}"
    );
    let opts = SolveOptions::callipepla();
    for (k, (ra, rb)) in results_a.iter().zip(&results_b).enumerate() {
        assert_bitwise(ra, rb, "deadline run-to-run");
        let m = k % 2;
        let lone = jpcg_solve(&matrices[m], Some(&ramp_rhs(matrices[m].n, k)), None, &opts);
        assert_bitwise(ra, &lone, "deadline-cut ticket vs lone solve");
    }
    // Deadline waits are bounded by the threshold on every lane.
    assert!(stats_a.queue_wait_quantile(1.0) <= 5, "no lane outwaits the deadline");
}

#[test]
fn backpressure_and_tenant_quotas_reject_with_typed_errors() {
    let a = synth::laplace2d_shifted(100, 0.2);
    let mut svc = SolverService::new(ServiceConfig {
        max_batch: 8,
        workers: 1,
        pending_limit: 2,
        tenant_quota: 1,
        ..Default::default()
    });
    let id = svc.register(a.clone());
    svc.pin(id).expect("pin under load");

    let t0 = svc.submit(SolveRequest { matrix: id, b: ramp_rhs(a.n, 0), tenant: 0 });
    // Tenant 0 is at quota while its first lane is still pending.
    match svc.try_submit(SolveRequest { matrix: id, b: ramp_rhs(a.n, 1), tenant: 0 }) {
        Err(SubmitError::TenantQuotaExceeded { tenant: 0, pending: 1, quota: 1 }) => {}
        other => panic!("expected a quota rejection, got {other:?}"),
    }
    // Validation rejections are typed too and never count a request
    // (checked before the queue fills — load shedding outranks
    // validation once the bound trips).
    match svc.try_submit(SolveRequest { matrix: id, b: vec![1.0; 7], tenant: 3 }) {
        Err(SubmitError::WrongRhsLength { expected, got: 7, .. }) => assert_eq!(expected, a.n),
        other => panic!("expected a length rejection, got {other:?}"),
    }
    let t1 = svc.submit(SolveRequest { matrix: id, b: ramp_rhs(a.n, 2), tenant: 1 });
    // The queue bound trips before any per-tenant bookkeeping.
    match svc.try_submit(SolveRequest { matrix: id, b: ramp_rhs(a.n, 3), tenant: 2 }) {
        Err(SubmitError::QueueFull { pending: 2, limit: 2 }) => {}
        other => panic!("expected a queue-full rejection, got {other:?}"),
    }

    // Draining clears the backlog and the gate reopens.
    svc.flush();
    let opts = SolveOptions::callipepla();
    assert_bitwise(&t0.wait(), &jpcg_solve(&a, Some(&ramp_rhs(a.n, 0)), None, &opts), "t0");
    assert_bitwise(&t1.wait(), &jpcg_solve(&a, Some(&ramp_rhs(a.n, 2)), None, &opts), "t1");
    let t2 = svc.submit(SolveRequest { matrix: id, b: ramp_rhs(a.n, 4), tenant: 2 });
    svc.flush();
    assert!(t2.wait().converged);
    let stats = svc.drain();
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.rejected, 3);
}

#[test]
fn http_routes_cover_solve_metrics_stats_and_the_error_edges() {
    let a = synth::laplace2d_shifted(100, 0.2);
    let mut svc = SolverService::new(ServiceConfig {
        max_batch: 8,
        workers: 1,
        pending_limit: 2,
        ..Default::default()
    });
    svc.register(a.clone());

    let health = handle_request(&mut svc, "GET", "/healthz", "");
    assert_eq!((health.status, health.body.as_str()), (200, "ok\n"));

    // The synchronous solve path: default all-ones RHS, response x is
    // bitwise the lone solve (f64 Display round-trips exactly).
    let resp = handle_request(&mut svc, "POST", "/solve", r#"{"matrix": 0}"#);
    assert_eq!(resp.status, 200, "body: {}", resp.body);
    let j = Json::parse(&resp.body).expect("solve response must parse");
    assert_eq!(j.get("converged"), Some(&Json::Bool(true)));
    let x: Vec<f64> = j
        .get("x")
        .and_then(Json::as_arr)
        .expect("x array")
        .iter()
        .map(|v| v.as_f64().expect("x value"))
        .collect();
    let lone = jpcg_solve(&a, Some(&vec![1.0; a.n]), None, &SolveOptions::callipepla());
    assert_eq!(x.len(), lone.x.len());
    assert!(
        x.iter().zip(&lone.x).all(|(u, v)| u.to_bits() == v.to_bits()),
        "HTTP solution diverged from the lone solve"
    );

    // Validation edges: 400s.
    for bad in [
        "not json",
        r#"{"b": [1.0]}"#,
        r#"{"matrix": 9}"#,
        r#"{"matrix": 0, "b": [1.0, 2.0]}"#,
    ] {
        let resp = handle_request(&mut svc, "POST", "/solve", bad);
        assert_eq!(resp.status, 400, "body {bad:?} must be a 400, got {}", resp.status);
        assert!(Json::parse(&resp.body).expect("error body parses").get("error").is_some());
    }

    // Backpressure edge: fire-and-forget submissions fill the bounded
    // queue, then the door answers 429 until a flush drains it.
    for _ in 0..2 {
        let resp = handle_request(&mut svc, "POST", "/submit", r#"{"matrix": 0}"#);
        assert_eq!(resp.status, 202, "body: {}", resp.body);
    }
    let resp = handle_request(&mut svc, "POST", "/submit", r#"{"matrix": 0}"#);
    assert_eq!(resp.status, 429, "the bounded queue must shed load: {}", resp.body);
    let resp = handle_request(&mut svc, "POST", "/flush", "");
    assert_eq!(resp.status, 200);
    let j = Json::parse(&resp.body).expect("flush body parses");
    assert_eq!(j.get("pending").and_then(Json::as_usize), Some(0));

    // Observability surfaces.
    let metrics = handle_request(&mut svc, "GET", "/metrics", "");
    assert_eq!((metrics.status, metrics.content_type), (200, PROMETHEUS_CONTENT_TYPE));
    for family in [
        "callipepla_service_http_requests_total",
        "callipepla_service_submit_rejected_total",
        "callipepla_service_flush_deadline_total",
        "callipepla_service_registry_evictions_total",
    ] {
        assert!(metrics.body.contains(family), "metrics dump is missing {family}");
    }
    let stats = handle_request(&mut svc, "GET", "/stats", "");
    let j = Json::parse(&stats.body).expect("stats body parses");
    assert_eq!(j.get("rejected").and_then(Json::as_usize), Some(1));

    // Routing edges and the shutdown signal.
    assert_eq!(handle_request(&mut svc, "GET", "/nope", "").status, 404);
    assert_eq!(handle_request(&mut svc, "DELETE", "/solve", "").status, 405);
    let bye = handle_request(&mut svc, "POST", "/shutdown", "");
    assert!(bye.shutdown && bye.status == 200);
    svc.drain();
}

#[test]
fn the_front_door_answers_over_a_real_socket() {
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    let a = synth::laplace2d_shifted(100, 0.2);
    let mut svc = SolverService::new(ServiceConfig { workers: 1, ..Default::default() });
    svc.register(a);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind an ephemeral port");
    let addr = listener.local_addr().expect("local addr");

    let client = std::thread::spawn(move || {
        let mut read_one = |req: &str| {
            let mut s = TcpStream::connect(addr).expect("connect");
            s.write_all(req.as_bytes()).expect("send");
            let mut resp = String::new();
            s.read_to_string(&mut resp).expect("recv");
            resp
        };
        let health = read_one("GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
        let body = r#"{"matrix": 0}"#;
        let solve = read_one(&format!(
            "POST /solve HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ));
        (health, solve)
    });
    let served = serve_http(&mut svc, &listener, 2).expect("serve");
    assert_eq!(served, 2);
    let (health, solve) = client.join().expect("client thread");
    assert!(health.starts_with("HTTP/1.1 200 OK"), "health: {health}");
    assert!(health.ends_with("ok\n"), "health body: {health}");
    assert!(solve.starts_with("HTTP/1.1 200 OK"), "solve: {solve}");
    assert!(solve.contains("\"converged\":true"), "solve body: {solve}");
    svc.drain();
}
