//! Engine acceptance tests: the parallel SpMV must be *bitwise* equal to
//! the serial path for every scheme and thread count, the nnz
//! partitioner must balance skewed matrices, and the prepared-matrix
//! batch API must reproduce sequential solves exactly.

use callipepla::engine::{spmv_parallel, PreparedMatrix, RowPartition};
use callipepla::precision::{spmv_scheme, AccumulatorModel, Scheme};
use callipepla::solver::{jpcg_solve, SolveOptions};
use callipepla::sparse::{synth, CooMatrix, CsrMatrix};
use callipepla::util::Rng64;

fn bitwise_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(u, v)| u.to_bits() == v.to_bits())
}

/// Parallel SpMV vs serial `spmv_scheme`, all four schemes x {1, 2, 8}
/// threads, on an irregular matrix.
#[test]
fn parallel_spmv_bitwise_identical_all_schemes_and_threads() {
    let a = synth::banded_spd(3_000, 30_000, 1e-3, 71);
    let vals32 = a.vals_f32();
    let x: Vec<f64> = (0..a.n).map(|i| ((i * 29) % 83) as f64 / 83.0 - 0.5).collect();
    for scheme in Scheme::ALL {
        let mut serial = vec![0.0; a.n];
        spmv_scheme(&a, &vals32, &x, &mut serial, scheme, AccumulatorModel::Sequential, 0);
        for threads in [1usize, 2, 8] {
            let part = RowPartition::nnz_balanced(&a, threads);
            let mut par = vec![0.0; a.n];
            spmv_parallel(&a, &vals32, &x, &mut par, scheme, &part);
            assert!(
                bitwise_eq(&serial, &par),
                "scheme {scheme:?} at {threads} threads is not bitwise identical"
            );
        }
    }
}

/// A strongly skewed synthetic matrix (row density ramps 1 -> ~60):
/// nnz-balanced cuts must keep the largest block within ~1.2x the mean,
/// where an equal-rows split would be ~2x off.
#[test]
fn partitioner_balances_skewed_matrix() {
    let n = 6_000usize;
    let mut coo = CooMatrix::new(n);
    for i in 0..n {
        coo.push(i, i, 4.0);
        let fan = 1 + (i * 60) / n; // skew: later rows much denser
        for d in 1..=fan {
            let j = (i + d * 13) % n;
            if j != i {
                coo.push(i, j, -1e-3);
            }
        }
    }
    let a: CsrMatrix = coo.to_csr();
    for parts in [2usize, 4, 8] {
        let p = RowPartition::nnz_balanced(&a, parts);
        let max = p.max_part_nnz(&a) as f64;
        let mean = p.mean_part_nnz(&a);
        assert!(
            max <= 1.2 * mean,
            "parts={parts}: max={max} mean={mean:.0} ratio={:.3}",
            max / mean
        );
        // And the skew is real: an equal-rows split would be unbalanced.
        let rows_per = n / parts;
        let naive_last = (a.indptr[n] - a.indptr[n - rows_per]) as f64;
        assert!(naive_last > 1.35 * mean, "test matrix lost its skew");
    }
}

/// `solve_batch` against one prepared matrix == one `jpcg_solve` per
/// right-hand side, in order, bit for bit.
#[test]
fn solve_batch_matches_sequential_solves() {
    let a = synth::banded_spd(1_200, 9_600, 1e-3, 19);
    let mut rng = Rng64::seed_from_u64(0xBA7C4);
    let rhs: Vec<Vec<f64>> = (0..5)
        .map(|_| (0..a.n).map(|_| rng.gen_f64() - 0.5).collect())
        .collect();
    let opts = SolveOptions::callipepla();
    let prep = PreparedMatrix::new(&a, 4);
    let batch = prep.solve_batch(&rhs, &opts);
    assert_eq!(batch.len(), rhs.len());
    for (k, b) in rhs.iter().enumerate() {
        let lone = jpcg_solve(&a, Some(b), None, &opts);
        assert_eq!(batch[k].iters, lone.iters, "rhs {k}");
        assert_eq!(batch[k].final_rr.to_bits(), lone.final_rr.to_bits(), "rhs {k}");
        assert!(bitwise_eq(&batch[k].x, &lone.x), "rhs {k} solution drifted");
    }
}

/// Parallel in-solve SpMV (threads inside one solve) must leave the
/// XcgSolver perturbation model untouched too: the accumulator
/// perturbation is applied whole-vector after the row blocks join.
#[test]
fn parallel_solve_preserves_padded_unstable_model() {
    let a = synth::banded_spd(1_000, 8_000, 1e-4, 91);
    let opts = SolveOptions::xcgsolver();
    let reference = jpcg_solve(&a, None, None, &opts);
    let prep = PreparedMatrix::new(&a, 8);
    let par = prep.solve(None, None, &opts);
    assert_eq!(par.iters, reference.iters);
    assert!(bitwise_eq(&par.x, &reference.x));
}

/// Thread counts beyond n (tiny matrix) and repeated prepared solves.
#[test]
fn prepared_matrix_edge_cases() {
    let a = synth::laplace2d_shifted(25, 0.2);
    let prep = PreparedMatrix::new(&a, 64);
    let opts = SolveOptions::default();
    let r1 = prep.solve(None, None, &opts);
    let r2 = prep.solve(None, None, &opts);
    let lone = jpcg_solve(&a, None, None, &opts);
    assert!(r1.converged && r2.converged);
    assert_eq!(r1.iters, lone.iters);
    assert!(bitwise_eq(&r1.x, &lone.x));
    assert!(bitwise_eq(&r1.x, &r2.x));
}
