//! Integration tests over the PJRT runtime: the full L3 -> artifact
//! (L2/L1) path.  These require `make artifacts`; they are skipped with
//! a notice when the artifact directory is missing, and the Makefile's
//! `test` target always builds artifacts first.
//!
//! The whole file is gated on the `pjrt` feature: without it the crate
//! has no `runtime` module (and no `xla` dependency), so offline
//! `cargo test` never touches libxla_extension.
//!
//! The native reference values are computed straight from the module
//! implementations (`modules::compute`) + the prepared-matrix plan —
//! the same operations the instruction interpreter dispatches.
#![cfg(feature = "pjrt")]

use callipepla::coordinator::{Coordinator, CoordinatorConfig, PhaseExecutor};
use callipepla::engine::PreparedMatrix;
use callipepla::modules::compute::{AxpyModule, DotModule, LeftDivideModule, UpdatePModule};
use callipepla::precision::Scheme;
use callipepla::runtime::{default_artifact_dir, PjrtExecutor, PjrtRuntime};
use callipepla::solver::{jpcg_solve, SolveOptions};
use callipepla::sparse::synth;

fn runtime_or_skip() -> Option<PjrtRuntime> {
    match PjrtRuntime::new(default_artifact_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (artifacts not built): {e}");
            None
        }
    }
}

#[test]
fn pjrt_phase1_matches_native_numerics() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = synth::banded_spd(900, 8_000, 1e-3, 17);
    let mut exec = PjrtExecutor::new(&mut rt, &a, Scheme::MixV3).unwrap();
    let prep = PreparedMatrix::new(&a, 1);
    let p: Vec<f64> = (0..a.n).map(|i| ((i * 31) % 101) as f64 / 101.0 - 0.5).collect();
    let (ap_p, pap_p) = exec.phase1(&p);
    let mut ap_n = vec![0.0; a.n];
    prep.spmv(Scheme::MixV3, &p, &mut ap_n);
    let pap_n = DotModule.run(&p, &ap_n);
    for i in 0..a.n {
        assert!(
            (ap_p[i] - ap_n[i]).abs() <= 1e-9 * ap_n[i].abs().max(1.0),
            "ap[{i}]: {} vs {}",
            ap_p[i],
            ap_n[i]
        );
    }
    assert!((pap_p - pap_n).abs() <= 1e-9 * pap_n.abs().max(1.0));
}

#[test]
fn pjrt_phase2_and_phase3_match_native() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = synth::laplace2d_shifted(1_000, 0.05);
    let mut exec = PjrtExecutor::new(&mut rt, &a, Scheme::MixV3).unwrap();
    let prep = PreparedMatrix::new(&a, 1);
    let n = a.n;
    let r: Vec<f64> = (0..n).map(|i| ((i * 13) % 37) as f64 / 37.0 - 0.5).collect();
    let ap: Vec<f64> = (0..n).map(|i| ((i * 7) % 23) as f64 / 23.0 - 0.5).collect();
    let (r1p, rzp, rrp) = exec.phase2(&r, &ap, 0.37);
    // Native: M4 axpy, M5 left-divide, M6/M8 dots.
    let mut r1n = r.clone();
    AxpyModule.run(-0.37, &ap, &mut r1n);
    let mut zn = vec![0.0; n];
    LeftDivideModule.run(&r1n, prep.diag(), &mut zn);
    let rzn = DotModule.run(&r1n, &zn);
    let rrn = DotModule.run(&r1n, &r1n);
    for i in 0..n {
        assert!((r1p[i] - r1n[i]).abs() <= 1e-12 * r1n[i].abs().max(1.0));
    }
    assert!((rzp - rzn).abs() <= 1e-9 * rzn.abs().max(1e-12), "{rzp} vs {rzn}");
    assert!((rrp - rrn).abs() <= 1e-9 * rrn.abs().max(1e-12));

    let p: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
    let x = vec![0.25; n];
    let (p1p, x1p) = exec.phase3(&r, &p, &x, 0.3, 0.9);
    // Native: M5 recompute z from r, M3 axpy on old p, M7 update p.
    let mut z3 = vec![0.0; n];
    LeftDivideModule.run(&r, prep.diag(), &mut z3);
    let mut x1n = x.clone();
    AxpyModule.run(0.3, &p, &mut x1n);
    let mut p1n = p.clone();
    UpdatePModule.run(0.9, &z3, &mut p1n);
    for i in 0..n {
        assert!((p1p[i] - p1n[i]).abs() <= 1e-12 * p1n[i].abs().max(1.0));
        assert!((x1p[i] - x1n[i]).abs() <= 1e-12 * x1n[i].abs().max(1.0));
    }
}

#[test]
fn pjrt_full_solve_agrees_with_reference() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = synth::laplace2d_shifted(2_500, 0.05);
    let mut exec = PjrtExecutor::new(&mut rt, &a, Scheme::MixV3).unwrap();
    let mut coord = Coordinator::new(CoordinatorConfig::default());
    let b = vec![1.0; a.n];
    let x0 = vec![0.0; a.n];
    let res = coord.solve(&mut exec, &b, &x0);
    assert!(res.converged, "rr={}", res.final_rr);

    let reference = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
    assert!(
        (res.iters as i64 - reference.iters as i64).abs() <= 3,
        "pjrt={} native={}",
        res.iters,
        reference.iters
    );
    // Ground truth.
    let mut ax = vec![0.0; a.n];
    a.spmv_f64(&res.x, &mut ax);
    let err = ax.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
    assert!(err < 1e-4, "||Ax-b||={err}");
}

#[test]
fn pjrt_fp64_scheme_also_works() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = synth::laplace2d_shifted(900, 0.1);
    let mut exec = PjrtExecutor::new(&mut rt, &a, Scheme::Fp64).unwrap();
    let mut coord = Coordinator::new(CoordinatorConfig::default());
    let b = vec![1.0; a.n];
    let res = coord.solve(&mut exec, &b, &vec![0.0; a.n]);
    assert!(res.converged);
}

#[test]
fn pjrt_rejects_oversized_problem_with_clear_error() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // Largest bucket is n=16384: a bigger matrix must be refused.
    let a = synth::laplace2d_shifted(20_000, 0.1);
    let err = match PjrtExecutor::new(&mut rt, &a, Scheme::MixV3) {
        Err(e) => e,
        Ok(_) => panic!("oversized problem unexpectedly accepted"),
    };
    assert!(err.to_string().contains("bucket"), "{err}");
}

#[test]
fn pjrt_mixv1_scheme_has_no_artifacts() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = synth::laplace2d_shifted(500, 0.1);
    assert!(PjrtExecutor::new(&mut rt, &a, Scheme::MixV1).is_err());
}
