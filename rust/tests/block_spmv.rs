//! The block-CG contract: one nnz pass per batched iteration feeds
//! every active lane (measured by the instrumented matrix-value read
//! counter), the resident lane-major arenas move **zero** vector
//! elements across the block boundary in steady state while the staged
//! baseline pays `2·n·L` per iteration (measured by the vector
//! element-move counter), per-lane numerics stay bitwise the serial
//! path on every entry point, and the Table-7-style iteration-count
//! gate holds across the synthetic matrix family.

use callipepla::engine::PreparedMatrix;
use callipepla::precision::{stats, AccumulatorModel, Scheme};
use callipepla::solver::{jpcg_solve, DotKind, SolveOptions};
use callipepla::sparse::{suite36, synth};

/// Options matching the instruction path's hardware models (see
/// `tests/program_oracle.rs`).
fn oracle_opts(scheme: Scheme) -> SolveOptions {
    SolveOptions {
        scheme,
        dot: DotKind::DelayBuffer,
        accumulator: AccumulatorModel::OutOfOrder,
        ..SolveOptions::default()
    }
}

fn bitwise_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(u, v)| u.to_bits() == v.to_bits())
}

/// Deterministic, per-lane-distinct right-hand sides.
fn make_rhs(n: usize, lanes: usize) -> Vec<Vec<f64>> {
    (0..lanes)
        .map(|k| (0..n).map(|i| 0.125 + ((i * 31 + k * 97) % 29) as f64 / 29.0).collect())
        .collect()
}

/// The tentpole's measured claim: matrix-value reads per batched solve
/// are **independent of the lane count** under block mode, and exactly
/// `lanes x` that under per-lane dispatch.  Everything runs on one
/// thread (plan threads = 1, sequential dispatch) so the thread-local
/// counter sees every read of this solve and nothing else.
#[test]
fn block_solve_streams_the_nnz_arrays_once_per_iteration() {
    let a = synth::banded_spd(600, 4_800, 1e-3, 7);
    let nnz = a.nnz() as u64;
    let opts = oracle_opts(Scheme::MixV3);
    let prep = PreparedMatrix::new(&a, 1);
    let b: Vec<f64> = (0..a.n).map(|i| 0.5 + ((i * 11) % 17) as f64 / 17.0).collect();

    let reads_of = |f: &mut dyn FnMut() -> u32| {
        let before = stats::matrix_value_reads();
        let iters = f();
        (stats::matrix_value_reads() - before, iters)
    };

    // Identical RHS in every lane, so per-lane iteration counts match
    // by the bitwise contract and read counts are directly comparable.
    let (base_reads, iters) =
        reads_of(&mut || prep.solve_batch_block(&vec![b.clone(); 1], &opts)[0].iters);
    assert!(iters > 0, "the probe system must iterate");
    // One block pass on the merged init + one per iteration.
    assert_eq!(base_reads, nnz * (iters as u64 + 1), "block batch-1 read count");

    for lanes in [3usize, 8] {
        let (reads, it) = reads_of(&mut || {
            let rs = prep.solve_batch_block(&vec![b.clone(); lanes], &opts);
            assert!(rs.iter().all(|r| r.iters == rs[0].iters));
            rs[0].iters
        });
        assert_eq!(it, iters, "lanes={lanes}: iteration count drifted");
        assert_eq!(reads, base_reads, "lanes={lanes}: block mode re-streamed the matrix");
    }

    // The per-lane path pays the matrix stream once per lane per trip.
    let (per_lane_reads, _) =
        reads_of(&mut || prep.solve_batch(&vec![b.clone(); 3], &opts)[0].iters);
    assert_eq!(per_lane_reads, 3 * base_reads, "per-lane dispatch read count");
}

/// Block mode is a pure execution-strategy switch: every entry point
/// hands back bitwise the per-lane-dispatch results, for all four
/// precision schemes — the reason the Table-7 gate below cannot drift.
#[test]
fn block_entry_points_are_bitwise_the_per_lane_path() {
    let a = synth::banded_spd(1_000, 8_000, 1e-3, 13);
    let rhs = make_rhs(a.n, 5);
    for scheme in Scheme::ALL {
        let opts = oracle_opts(scheme);
        let prep = PreparedMatrix::new(&a, 4);
        let serial = prep.solve_batch(&rhs, &opts);
        let block = prep.solve_batch_block(&rhs, &opts);
        let block_par = prep.solve_batch_block_parallel(&rhs, &opts, None, 2);
        let staged = prep.solve_batch_block_staged(&rhs, &opts);
        let staged_par = prep.solve_batch_block_staged_parallel(&rhs, &opts, None, 2);
        for k in 0..rhs.len() {
            for (label, r) in [
                ("block", &block[k]),
                ("block_par", &block_par[k]),
                ("staged", &staged[k]),
                ("staged_par", &staged_par[k]),
            ] {
                assert_eq!(r.iters, serial[k].iters, "rhs {k} iters ({scheme:?}, {label})");
                assert_eq!(
                    r.final_rr.to_bits(),
                    serial[k].final_rr.to_bits(),
                    "rhs {k} final rr ({scheme:?}, {label})"
                );
                assert!(
                    bitwise_eq(&r.x, &serial[k].x),
                    "rhs {k} solution bits ({scheme:?}, {label})"
                );
            }
        }
    }
}

/// Table-7-style convergence gate: block-CG per-scheme iteration
/// counts must sit within a small tolerance band (2%, minimum 1
/// iteration) of the serial reference counts across the synthetic
/// matrix family.  The block kernel keeps each lane's accumulation
/// chain in nnz order, so in practice the counts are *equal* — the
/// band is the contract CI enforces, not the slack the kernel uses.
#[test]
fn table7_iteration_gate_holds_for_the_synth_family() {
    for spec in suite36().into_iter().take(4) {
        let a = spec.generate(0.01);
        let rhs = make_rhs(a.n, 4);
        for scheme in [Scheme::Fp64, Scheme::MixV3] {
            let opts = SolveOptions { max_iters: 600, ..oracle_opts(scheme) };
            let prep = PreparedMatrix::new(&a, 2);
            let block = prep.solve_batch_block(&rhs, &opts);
            for (k, b) in rhs.iter().enumerate() {
                let lone = jpcg_solve(&a, Some(b), None, &opts);
                let band = (lone.iters / 50).max(1);
                let diff = block[k].iters.abs_diff(lone.iters);
                assert!(
                    diff <= band,
                    "{} rhs {k} ({scheme:?}): block {} vs serial {} exceeds band {band}",
                    spec.id,
                    block[k].iters,
                    lone.iters
                );
            }
        }
    }
}

/// The PR 8 adaptive gate, Table-7 style: across the synthetic matrix
/// family, a solve under the default adaptive policy must (a) reach the
/// same residual tolerance, (b) spend at most 10% more iterations than
/// the static FP64 reference, and (c) stream **strictly fewer** modeled
/// M1 nnz bytes than static FP64 — the mixed-precision bargain the
/// paper's Table 7 sells, now enforced by CI (the bench-smoke arm runs
/// this gate by name).
#[test]
fn adaptive_gate_holds_for_the_synth_family() {
    use callipepla::precision::adaptive::AdaptivePolicy;
    for spec in suite36().into_iter().take(4) {
        let a = spec.generate(0.01);
        let nnz = a.nnz() as u64;
        let base = SolveOptions { max_iters: 5_000, ..oracle_opts(Scheme::Fp64) };
        let fp64 = jpcg_solve(&a, None, None, &base);
        assert!(fp64.converged, "{}: static fp64 reference must converge", spec.id);
        let mut opts = base;
        opts.adaptive = Some(AdaptivePolicy::default());
        let adaptive = jpcg_solve(&a, None, None, &opts);
        // (a) same tolerance reached.
        assert!(
            adaptive.converged && adaptive.final_rr <= opts.tol,
            "{}: adaptive rr {:.3e} missed tol {:.3e}",
            spec.id,
            adaptive.final_rr,
            opts.tol
        );
        // (b) iteration count within +10% of the static FP64 reference.
        let cap = fp64.iters + fp64.iters.div_ceil(10);
        assert!(
            adaptive.iters <= cap,
            "{}: adaptive {} iters vs fp64 {} (cap {cap})",
            spec.id,
            adaptive.iters,
            fp64.iters
        );
        // (c) strictly fewer modeled M1 bytes than static FP64.
        let ad_bytes = adaptive.precision.modeled_m1_bytes(nnz, adaptive.iters);
        let fp_bytes = fp64.precision.modeled_m1_bytes(nnz, fp64.iters);
        assert!(
            ad_bytes < fp_bytes,
            "{}: adaptive streamed {ad_bytes} modeled M1 bytes vs fp64 {fp_bytes}",
            spec.id
        );
    }
}

/// A batch wider than the chunk-lane cap crosses the compiled-chunk
/// seam with block mode on: each chunk restarts its own block state
/// (the 9-lane batch under a 4-lane cap even produces a single-lane
/// tail chunk, exercising the L = 1 short-circuit) and every lane must
/// still be bitwise a lone solve — in both block modes.
#[test]
fn block_mode_survives_the_chunk_seam() {
    use callipepla::coordinator::{BlockMode, Coordinator, CoordinatorConfig, NativeExecutor};
    let a = synth::laplace2d_shifted(200, 0.2);
    let rhs = make_rhs(a.n, 9);
    let opts = oracle_opts(Scheme::MixV3);
    for block in [BlockMode::Staged, BlockMode::Resident] {
        let cfg = CoordinatorConfig { max_chunk_lanes: 4, block, ..Default::default() };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::with_threads(&a, Scheme::MixV3, 1);
        let refs: Vec<&[f64]> = rhs.iter().map(Vec::as_slice).collect();
        let batch = coord.solve_batch(&mut exec, &refs, None);
        assert_eq!(batch.len(), rhs.len());
        for (k, b) in rhs.iter().enumerate() {
            let lone = jpcg_solve(&a, Some(b), None, &opts);
            assert_eq!(batch[k].iters, lone.iters, "{block:?} rhs {k}");
            assert!(bitwise_eq(&batch[k].x, &lone.x), "{block:?} rhs {k} bits");
        }
    }
}

/// The Serpens-stream executor declines `batch_spmv`, so both block
/// modes over it must fall back to per-lane dispatch gracefully (the
/// resident request bails before issuing anything) and still match the
/// stream-mode per-lane results bit for bit.
#[test]
fn stream_executor_declines_block_mode_and_falls_back() {
    use callipepla::coordinator::{BlockMode, Coordinator, CoordinatorConfig, NativeExecutor};
    let a = synth::laplace2d_shifted(150, 0.2);
    let rhs = make_rhs(a.n, 3);
    let refs: Vec<&[f64]> = rhs.iter().map(Vec::as_slice).collect();
    let solve = |block: BlockMode| {
        let cfg = CoordinatorConfig { block, ..Default::default() };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::with_serpens_stream(&a);
        coord.solve_batch(&mut exec, &refs, None)
    };
    let plain = solve(BlockMode::PerLane);
    for block in [BlockMode::Staged, BlockMode::Resident] {
        let blocked = solve(block);
        for (k, (p, b)) in plain.iter().zip(&blocked).enumerate() {
            assert_eq!(p.iters, b.iters, "{block:?} rhs {k}");
            assert!(bitwise_eq(&p.x, &b.x), "{block:?} rhs {k} bits");
        }
    }
}

/// The tentpole's second measured claim: on the resident path a
/// steady-state iteration moves **zero** vector elements across the
/// block boundary, while the staged baseline re-materializes the block
/// around every pass — `2·n·L` moves per iteration.  Measured as a
/// delta between two iteration caps (tol = 0 keeps every lane busy to
/// the cap), so batch entry and retirement — the only legitimate
/// boundary traffic — cancel out; the resident entry + exit total is
/// then pinned exactly.
#[test]
fn resident_arenas_move_zero_elements_per_steady_iteration() {
    let a = synth::banded_spd(600, 4_800, 1e-3, 7);
    let (n, lanes) = (a.n, 4usize);
    let b: Vec<f64> = (0..n).map(|i| 0.5 + ((i * 11) % 17) as f64 / 17.0).collect();
    let rhs = vec![b; lanes];
    let prep = PreparedMatrix::new(&a, 1);
    let moves_at = |resident: bool, max_iters: u32| {
        let opts = SolveOptions { max_iters, tol: 0.0, ..oracle_opts(Scheme::MixV3) };
        let before = stats::vector_element_moves();
        let rs = if resident {
            prep.solve_batch_block(&rhs, &opts)
        } else {
            prep.solve_batch_block_staged(&rhs, &opts)
        };
        assert!(rs.iter().all(|r| !r.converged && r.iters == max_iters), "probe must stay busy");
        stats::vector_element_moves() - before
    };
    let (m1, m2) = (6u32, 14u32);
    let per_iter = 2 * (n * lanes) as u64;
    assert_eq!(
        moves_at(false, m2) - moves_at(false, m1),
        (m2 - m1) as u64 * per_iter,
        "staged mode must pay a gather + scatter (2·n·L) per iteration"
    );
    assert_eq!(
        moves_at(true, m2) - moves_at(true, m1),
        0,
        "resident steady-state iterations must move zero elements"
    );
    // Boundary traffic only: 2·n·L in at entry, n per lane out at
    // retirement (all lanes cap together, so no compaction repack).
    assert_eq!(moves_at(true, m1), (2 * n * lanes + n * lanes) as u64);
}

/// A single-lane batch has nothing to amortize a block over: both
/// block modes short-circuit to per-lane dispatch — zero boundary
/// moves — and return bitwise the per-lane batch.
#[test]
fn single_lane_batches_short_circuit_to_per_lane_dispatch() {
    let a = synth::laplace2d_shifted(200, 0.2);
    let rhs = make_rhs(a.n, 1);
    let opts = oracle_opts(Scheme::MixV3);
    let prep = PreparedMatrix::new(&a, 1);
    let base = prep.solve_batch(&rhs, &opts);
    let before = stats::vector_element_moves();
    let resident = prep.solve_batch_block(&rhs, &opts);
    let staged = prep.solve_batch_block_staged(&rhs, &opts);
    assert_eq!(stats::vector_element_moves(), before, "single-lane block solves moved elements");
    for r in [&resident[0], &staged[0]] {
        assert_eq!(r.iters, base[0].iters);
        assert!(bitwise_eq(&r.x, &base[0].x));
    }
}
