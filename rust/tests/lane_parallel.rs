//! The lane-parallel determinism wall (ISSUE 5): fanning a batched
//! program's trips across workers is a *scheduling* refactor, so every
//! (scheme, batch, worker-count) combination must be **bitwise
//! identical** to the sequential trip-major/lane-minor oracle walk —
//! including across the `max_batch` chunking seam — and repeated runs
//! of the same inputs must never move a bit.

use callipepla::coordinator::{CoordResult, Coordinator, CoordinatorConfig, NativeExecutor};
use callipepla::engine::{pool, PreparedMatrix};
use callipepla::precision::{AccumulatorModel, Scheme};
use callipepla::solver::{DotKind, SolveOptions};
use callipepla::sparse::{synth, CsrMatrix};

/// Deterministic, per-lane-distinct right-hand sides.
fn make_rhs(n: usize, lanes: usize) -> Vec<Vec<f64>> {
    (0..lanes)
        .map(|k| (0..n).map(|i| 0.5 + ((i * 13 + k * 89) % 19) as f64 / 19.0).collect())
        .collect()
}

/// The sequential oracle walk (`Coordinator::solve_batch`), with an
/// optional chunk-lane cap to exercise the batch-splitting seam.
fn solve_seq(a: &CsrMatrix, scheme: Scheme, rhs: &[Vec<f64>], chunk: u32) -> Vec<CoordResult> {
    let cfg = CoordinatorConfig {
        record_instructions: true,
        record_trace: true,
        max_chunk_lanes: chunk,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg);
    let mut exec = NativeExecutor::with_threads(a, scheme, 1);
    let refs: Vec<&[f64]> = rhs.iter().map(Vec::as_slice).collect();
    coord.solve_batch(&mut exec, &refs, None)
}

/// The lane-parallel walk at an explicit worker budget.
fn solve_par(
    a: &CsrMatrix,
    scheme: Scheme,
    rhs: &[Vec<f64>],
    workers: usize,
    chunk: u32,
) -> Vec<CoordResult> {
    let cfg = CoordinatorConfig {
        record_instructions: true,
        record_trace: true,
        lane_workers: workers,
        max_chunk_lanes: chunk,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg);
    let mut execs: Vec<NativeExecutor> =
        rhs.iter().map(|_| NativeExecutor::with_threads(a, scheme, 1)).collect();
    let refs: Vec<&[f64]> = rhs.iter().map(Vec::as_slice).collect();
    coord.solve_batch_parallel(&mut execs, &refs, None)
}

fn bitwise_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(u, v)| u.to_bits() == v.to_bits())
}

/// Everything observable must match: solution bits, residual-trace
/// bits, iteration counts, converged flags, instruction counts, acks.
fn assert_identical(seq: &[CoordResult], par: &[CoordResult], what: &str) {
    assert_eq!(seq.len(), par.len(), "{what}: result count");
    for (k, (s, p)) in seq.iter().zip(par).enumerate() {
        assert_eq!(s.iters, p.iters, "{what}: lane {k} iters");
        assert_eq!(s.converged, p.converged, "{what}: lane {k} converged");
        assert_eq!(s.final_rr.to_bits(), p.final_rr.to_bits(), "{what}: lane {k} rr bits");
        assert!(bitwise_eq(&s.x, &p.x), "{what}: lane {k} solution bits");
        assert!(bitwise_eq(s.trace.values(), p.trace.values()), "{what}: lane {k} trace bits");
        assert_eq!(s.mem_acks, p.mem_acks, "{what}: lane {k} write acks");
        assert_eq!(
            s.instructions.issued.len(),
            p.instructions.issued.len(),
            "{what}: lane {k} instruction count"
        );
    }
}

#[test]
fn parallel_dispatch_is_bitwise_pinned_to_the_sequential_walk() {
    let a = synth::laplace2d_shifted(300, 0.15);
    for scheme in [Scheme::Fp64, Scheme::MixV3] {
        for lanes in [1usize, 3, 8, 17] {
            // Batch 17 is forced across the chunking seam (chunks of
            // 8, 8, 1); the seam itself is pinned separately below.
            let chunk = if lanes == 17 { 8 } else { 0 };
            let rhs = make_rhs(a.n, lanes);
            let seq = solve_seq(&a, scheme, &rhs, chunk);
            assert!(seq.iter().all(|r| r.converged), "oracle must converge");
            for workers in [1usize, 2, 8] {
                let par = solve_par(&a, scheme, &rhs, workers, chunk);
                let what = format!("{scheme:?} batch={lanes} workers={workers}");
                assert_identical(&seq, &par, &what);
            }
        }
    }
}

#[test]
fn chunk_seam_is_invariant_under_both_dispatch_paths() {
    // The same 17-lane batch cut at different chunk caps (and not cut
    // at all) must produce identical bits — lanes are independent, so
    // where the compiled chunk boundary falls can never matter.
    let a = synth::laplace2d_shifted(200, 0.2);
    let rhs = make_rhs(a.n, 17);
    let baseline = solve_seq(&a, Scheme::MixV3, &rhs, 0);
    for chunk in [1u32, 3, 8, 16] {
        let seq = solve_seq(&a, Scheme::MixV3, &rhs, chunk);
        assert_identical(&baseline, &seq, &format!("sequential chunk={chunk}"));
        let par = solve_par(&a, Scheme::MixV3, &rhs, 4, chunk);
        assert_identical(&baseline, &par, &format!("parallel chunk={chunk}"));
    }
}

#[test]
fn repeated_parallel_runs_are_bit_stable() {
    // Same inputs, ten runs, full worker fan-out: scheduling noise
    // (which lanes land on which pool threads, in which order) must
    // never reach the results.
    let a = synth::laplace2d_shifted(250, 0.15);
    let rhs = make_rhs(a.n, 8);
    let first = solve_par(&a, Scheme::MixV3, &rhs, 8, 0);
    for run in 1..10 {
        let again = solve_par(&a, Scheme::MixV3, &rhs, 8, 0);
        assert_identical(&first, &again, &format!("run {run}"));
    }
}

#[test]
fn prepared_matrix_parallel_batch_matches_the_sequential_entry() {
    // The shipping entry points: PreparedMatrix::solve_batch (sequential
    // dispatch, threaded SpMV inside each lane) vs solve_batch_parallel
    // (lane fan-out, serial SpMV inside each lane).  The SpMV is
    // thread-count-invariant and the lanes are independent, so the two
    // must agree bit for bit — including flops accounting.
    let a = synth::banded_spd(1_000, 8_000, 1e-3, 29);
    let rhs = make_rhs(a.n, 6);
    let opts = SolveOptions {
        scheme: Scheme::MixV3,
        dot: DotKind::DelayBuffer,
        accumulator: AccumulatorModel::OutOfOrder,
        ..SolveOptions::default()
    };
    let prep = PreparedMatrix::new(&a, 4);
    let seq = prep.solve_batch(&rhs, &opts);
    for workers in [0usize, 1, 2, 8] {
        let par = prep.solve_batch_parallel(&rhs, &opts, None, workers);
        assert_eq!(seq.len(), par.len());
        for (k, (s, p)) in seq.iter().zip(&par).enumerate() {
            assert_eq!(s.iters, p.iters, "workers={workers} lane {k}");
            assert_eq!(s.flops, p.flops, "workers={workers} lane {k} flops");
            assert_eq!(s.final_rr.to_bits(), p.final_rr.to_bits(), "workers={workers} lane {k}");
            assert!(bitwise_eq(&s.x, &p.x), "workers={workers} lane {k} bits");
        }
    }
}

#[test]
fn lane_grouped_parallel_dot_is_bitwise_the_delay_buffer_dot() {
    // PERF §7's bit-exact half: the delay buffer's 8-lane partition is
    // fixed, so splitting the lanes across workers must not move a bit
    // of any dot — at any worker count, on vectors long enough to
    // actually engage the parallel path.
    use callipepla::engine::DOT_PARALLEL_MIN_LEN;
    use callipepla::precision::dot_delay_buffer;
    let n = DOT_PARALLEL_MIN_LEN + 1_237;
    let a: Vec<f64> = (0..n).map(|i| 0.1 + ((i * 7) % 101) as f64 / 101.0).collect();
    let b: Vec<f64> = (0..n).map(|i| -0.3 + ((i * 11) % 97) as f64 / 97.0).collect();
    let want = dot_delay_buffer(&a, &b);
    for workers in [1usize, 2, 8] {
        let got = callipepla::engine::dot_delay_parallel(&a, &b, workers);
        assert_eq!(want.to_bits(), got.to_bits(), "workers={workers}");
    }
}

#[test]
fn parallel_dots_leave_every_scheme_solve_bitwise_pinned() {
    // The executor's M2/M6/M8 dots now run lane-grouped across the
    // plan's threads; a solve at any thread count must stay bitwise
    // the single-threaded walk, for all four precision schemes.  The
    // system is sized past DOT_PARALLEL_MIN_LEN so the parallel dot
    // path genuinely engages inside the solve.
    let a = synth::banded_spd(10_000, 80_000, 1e-3, 31);
    let rhs = make_rhs(a.n, 2);
    let refs: Vec<&[f64]> = rhs.iter().map(Vec::as_slice).collect();
    let solve = |threads: usize, scheme: Scheme| {
        let cfg = CoordinatorConfig { record_trace: true, ..Default::default() };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::with_threads(&a, scheme, threads);
        coord.solve_batch(&mut exec, &refs, None)
    };
    for scheme in Scheme::ALL {
        let base = solve(1, scheme);
        assert!(base.iter().all(|r| r.converged), "{scheme:?}: oracle must converge");
        for threads in [2usize, 8] {
            let multi = solve(threads, scheme);
            for (k, (s, m)) in base.iter().zip(&multi).enumerate() {
                assert_eq!(s.iters, m.iters, "{scheme:?} threads={threads} lane {k}");
                assert_eq!(
                    s.final_rr.to_bits(),
                    m.final_rr.to_bits(),
                    "{scheme:?} threads={threads} lane {k} rr"
                );
                assert!(bitwise_eq(&s.x, &m.x), "{scheme:?} threads={threads} lane {k} bits");
                assert!(
                    bitwise_eq(s.trace.values(), m.trace.values()),
                    "{scheme:?} threads={threads} lane {k} trace"
                );
            }
        }
    }
}

#[test]
fn non_program_options_fall_back_to_the_worker_path() {
    // Sequential-dot options model a different machine; the parallel
    // entry must route them to solve_batch_workers, bitwise the lone
    // reference solves.
    let a = synth::laplace2d_shifted(150, 0.2);
    let rhs = make_rhs(a.n, 3);
    let opts = SolveOptions::default(); // sequential dots
    let prep = PreparedMatrix::new(&a, 2);
    let want = prep.solve_batch_workers(&rhs, &opts);
    let got = prep.solve_batch_parallel(&rhs, &opts, None, 4);
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.iters, g.iters);
        assert!(bitwise_eq(&w.x, &g.x));
    }
}

#[test]
fn empty_batches_return_cleanly_on_every_entry_point() {
    let a = synth::laplace2d_shifted(64, 0.1);
    let opts = SolveOptions::callipepla();
    let prep = PreparedMatrix::new(&a, 2);
    assert!(prep.solve_batch_parallel(&[], &opts, None, 4).is_empty());
    let mut coord = Coordinator::new(CoordinatorConfig::default());
    let mut execs: Vec<NativeExecutor> = Vec::new();
    assert!(coord.solve_batch_parallel(&mut execs, &[], None).is_empty());
}

#[test]
fn a_panicking_scoped_job_does_not_wedge_later_parallel_solves() {
    // A panic in unrelated scoped work on the process-wide pool (the
    // same pool the lane fan-out rides) must re-raise at its call site
    // and leave subsequent lane-parallel solves bitwise intact.
    let caught = std::panic::catch_unwind(|| {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|k| {
                Box::new(move || {
                    if k == 1 {
                        panic!("boom");
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::global().run_scoped(jobs);
    });
    assert!(caught.is_err(), "the scope re-raises the panic");
    let a = synth::laplace2d_shifted(150, 0.2);
    let rhs = make_rhs(a.n, 4);
    let seq = solve_seq(&a, Scheme::MixV3, &rhs, 0);
    let par = solve_par(&a, Scheme::MixV3, &rhs, 4, 0);
    assert_identical(&seq, &par, "after a pool panic");
}
