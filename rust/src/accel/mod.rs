//! Comparator accelerator/platform models: the four rows of Table 2,
//! the Table 6 resource model, and the per-accelerator solve pipeline
//! that combines the value plane (iteration counts) with the time plane
//! (cycle model) for Tables 4/5.

pub mod resources;

use crate::precision::Scheme;
use crate::sim::{self, AccelSimConfig};
use crate::solver::{jpcg_solve, SolveOptions, SolveResult};
use crate::sparse::CsrMatrix;

/// The four evaluated accelerators/platforms (Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Accel {
    /// The Vitis-library CG solver FPGA baseline.
    XcgSolver,
    /// Serpens SpMV + CG assembled as a solver.
    SerpensCG,
    /// The paper's accelerator.
    Callipepla,
    /// NVIDIA A100 (cuSPARSE/cuBLAS analytic model).
    A100,
}

impl Accel {
    /// Every evaluated platform, in Table-2 order.
    pub const ALL: [Accel; 4] = [Accel::XcgSolver, Accel::SerpensCG, Accel::Callipepla, Accel::A100];

    /// Display name (table headers).
    pub fn name(self) -> &'static str {
        match self {
            Accel::XcgSolver => "XcgSolver",
            Accel::SerpensCG => "SerpensCG",
            Accel::Callipepla => "Callipepla",
            Accel::A100 => "A100",
        }
    }

    /// Table 2 row.
    pub fn spec(self) -> PlatformSpec {
        match self {
            Accel::XcgSolver => PlatformSpec {
                process_nm: 16,
                freq_hz: 250e6,
                mem_gb: 8,
                bandwidth_bps: 331e9,
                power_w: 49.0,
                peak_gflops: 410.0,
            },
            Accel::SerpensCG => PlatformSpec {
                process_nm: 16,
                freq_hz: 238e6,
                mem_gb: 8,
                bandwidth_bps: 345e9,
                power_w: 43.0,
                peak_gflops: 410.0,
            },
            Accel::Callipepla => PlatformSpec {
                process_nm: 16,
                freq_hz: 221e6,
                mem_gb: 8,
                bandwidth_bps: 374e9,
                power_w: 56.0,
                peak_gflops: 410.0,
            },
            Accel::A100 => PlatformSpec {
                process_nm: 7,
                freq_hz: 1.41e9,
                mem_gb: 40,
                bandwidth_bps: 1.56e12,
                power_w: 243.0,
                peak_gflops: 29_200.0, // paper sums CUDA + tensor cores
            },
        }
    }

    /// Solver-precision configuration for the value plane (Table 7 rows).
    pub fn solve_options(self) -> SolveOptions {
        match self {
            Accel::XcgSolver => SolveOptions::xcgsolver(),
            Accel::SerpensCG => SolveOptions::serpenscg(),
            Accel::Callipepla => SolveOptions::callipepla(),
            Accel::A100 => SolveOptions::gpu(),
        }
    }

    /// Time-plane configuration (None for the GPU: analytic model).
    pub fn sim_config(self) -> Option<AccelSimConfig> {
        match self {
            Accel::XcgSolver => Some(AccelSimConfig::xcgsolver()),
            Accel::SerpensCG => Some(AccelSimConfig::serpenscg()),
            Accel::Callipepla => Some(AccelSimConfig::callipepla()),
            Accel::A100 => None,
        }
    }

    /// The XcgSolver out-of-memory failure mode (§7.5.1, Table 4 FAIL
    /// rows), evaluated at *paper-scale* dimensions (scaled-down bench
    /// matrices still FAIL where the real matrix would).  Model: the
    /// in-order zero-padded FP64 stream is duplicated across memory
    /// banks with double-buffering (4 copies) and a single XRT bank
    /// allocation is capped at 2 GB (8 GB HBM / 4 banks).  This captures
    /// the six largest FAIL rows (M31-M36); M23/M28 fail on the real
    /// system for structure-dependent padding our synthetic stand-ins do
    /// not reproduce — documented in EXPERIMENTS.md.
    pub fn fails_oom_dims(self, _n: usize, nnz: usize) -> bool {
        match self {
            Accel::XcgSolver => {
                let padded_nnz = nnz as f64 * 1.35;
                padded_nnz * 16.0 * 4.0 > 2.0e9
            }
            _ => false,
        }
    }

    /// OOM check against an in-memory matrix's own dimensions.
    pub fn fails_oom(self, a: &CsrMatrix) -> bool {
        self.fails_oom_dims(a.n, a.nnz())
    }
}

/// Table 2 specification record.
#[derive(Debug, Clone, Copy)]
pub struct PlatformSpec {
    /// Process node in nm.
    pub process_nm: u32,
    /// Achieved clock in Hz.
    pub freq_hz: f64,
    /// Device memory in GiB.
    pub mem_gb: u32,
    /// Achievable bandwidth in bytes/s.
    pub bandwidth_bps: f64,
    /// Measured board/device power in W.
    pub power_w: f64,
    /// Peak FP64 throughput in GFLOP/s.
    pub peak_gflops: f64,
}

/// One accelerator x matrix evaluation: value plane + time plane.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// The platform evaluated.
    pub accel: Accel,
    /// Value-plane iteration count.
    pub iters: u32,
    /// Whether the solve converged within the cap.
    pub converged: bool,
    /// OOM cell (Table 4 "FAIL").
    pub failed: bool,
    /// Time-plane solver seconds.
    pub solver_seconds: f64,
    /// FLOPs executed by the solve.
    pub flops: u64,
    /// Throughput in GFLOP/s.
    pub gflops: f64,
    /// Energy efficiency in GFLOP/J.
    pub gflops_per_joule: f64,
}

/// An OOM-failure cell (Table 4 "FAIL").
pub fn fail_result(accel: Accel) -> EvalResult {
    EvalResult {
        accel,
        iters: 0,
        converged: false,
        failed: true,
        solver_seconds: f64::NAN,
        flops: 0,
        gflops: f64::NAN,
        gflops_per_joule: f64::NAN,
    }
}

/// Evaluate one accelerator on one matrix (a Table 4 cell).
///
/// `iters_override` allows reusing a previously computed iteration count
/// (the benches sweep accelerators over one matrix without re-solving).
pub fn evaluate(accel: Accel, a: &CsrMatrix, iters_override: Option<&SolveResult>) -> EvalResult {
    if accel.fails_oom(a) {
        return fail_result(accel);
    }
    let owned;
    let solve = match iters_override {
        Some(s) => s,
        None => {
            owned = jpcg_solve(a, None, None, &accel.solve_options());
            &owned
        }
    };
    evaluate_dims(accel, a.n, a.nnz(), solve)
}

/// Time-plane evaluation at explicit dimensions.  The suite sweeps call
/// this with the *paper-scale* (n, nnz) even when the value-plane matrix
/// is scaled down: iteration counts are scale-calibrated, while solver
/// time / throughput are properties of the full-size problem on the
/// modeled hardware (Table 4/5 report paper-size runs).
pub fn evaluate_dims(accel: Accel, n: usize, nnz: usize, solve: &SolveResult) -> EvalResult {
    let seconds = match accel.sim_config() {
        Some(cfg) => sim::solver_seconds(&cfg, n, nnz, solve.iters),
        None => sim::iteration::gpu_solver_seconds(n, nnz, solve.iters),
    };
    // FLOPs at the modeled problem size.
    let flops = (solve.iters as u64 + 1) * crate::solver::jpcg::flops_per_iter(n, nnz);
    let spec = accel.spec();
    let gflops = flops as f64 / seconds / 1e9;
    EvalResult {
        accel,
        iters: solve.iters,
        converged: solve.converged,
        failed: false,
        solver_seconds: seconds,
        flops,
        gflops,
        gflops_per_joule: gflops / spec.power_w,
    }
}

/// Scheme actually streamed by each accelerator's SpMV.
pub fn spmv_scheme(accel: Accel) -> Scheme {
    match accel {
        Accel::Callipepla => Scheme::MixV3,
        _ => Scheme::Fp64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth;

    #[test]
    fn table2_specs_match_paper() {
        let c = Accel::Callipepla.spec();
        assert_eq!(c.freq_hz, 221e6);
        assert_eq!(c.power_w, 56.0);
        let g = Accel::A100.spec();
        assert!((g.bandwidth_bps / c.bandwidth_bps - 4.17).abs() < 0.05,
            "A100 has ~4.17x Callipepla's bandwidth (§7.6)");
    }

    #[test]
    fn callipepla_outperforms_xcgsolver_on_medium_matrix() {
        let a = synth::banded_spd(5_000, 120_000, 1e-4, 31);
        let cal = evaluate(Accel::Callipepla, &a, None);
        let xcg = evaluate(Accel::XcgSolver, &a, None);
        assert!(!cal.failed && !xcg.failed);
        let speedup = xcg.solver_seconds / cal.solver_seconds;
        assert!(speedup > 2.0, "speedup={speedup}");
        assert!(cal.gflops > xcg.gflops);
        assert!(cal.gflops_per_joule > xcg.gflops_per_joule);
    }

    #[test]
    fn xcgsolver_fails_oom_on_table4_fail_rows() {
        use crate::sparse::suite36;
        // Paper Table 4: XcgSolver fails on M31..M36 (plus M23/M28 for
        // structure-specific reasons the model does not capture).
        let suite = suite36();
        for s in &suite {
            let fails = Accel::XcgSolver.fails_oom_dims(s.n, s.nnz);
            let expected = matches!(s.id, "M31" | "M32" | "M33" | "M34" | "M35" | "M36");
            assert_eq!(fails, expected, "{} ({} nnz)", s.id, s.nnz);
            assert!(!Accel::Callipepla.fails_oom_dims(s.n, s.nnz), "{}", s.id);
        }
    }

    #[test]
    fn gpu_wins_energy_only_sometimes() {
        // On a small matrix the GPU's launch floor destroys efficiency.
        let a = synth::banded_spd(3_000, 90_000, 1e-3, 32);
        let cal = evaluate(Accel::Callipepla, &a, None);
        let gpu = evaluate(Accel::A100, &a, None);
        assert!(cal.gflops_per_joule > gpu.gflops_per_joule,
            "cal={} gpu={}", cal.gflops_per_joule, gpu.gflops_per_joule);
    }
}
