//! FPGA resource model (Table 6).
//!
//! Callipepla's utilization is *derived* from a per-module cost model
//! (so ablations can price design variants); the XcgSolver / SerpensCG
//! rows are the paper's measured totals, kept as reference points.  The
//! derived Callipepla totals are pinned to Table 6 by tests within a
//! tolerance, which validates the per-module model.

/// U280 LUT total (Alveo U280 data sheet).
pub const U280_LUT: u64 = 1_303_680;
/// U280 flip-flop total.
pub const U280_FF: u64 = 2_607_360;
/// U280 DSP-slice total.
pub const U280_DSP: u64 = 9_024;
/// U280 BRAM-36 total.
pub const U280_BRAM: u64 = 2_016;
/// U280 URAM total.
pub const U280_URAM: u64 = 960;

/// One module's resource cost.
#[derive(Debug, Clone, Copy, Default)]
pub struct Resources {
    /// Look-up tables.
    pub lut: u64,
    /// Flip-flops.
    pub ff: u64,
    /// DSP slices.
    pub dsp: u64,
    /// BRAM-36 blocks.
    pub bram: u64,
    /// UltraRAM blocks.
    pub uram: u64,
}

impl Resources {
    /// Component-wise sum.
    pub fn add(self, o: Resources) -> Resources {
        Resources {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            dsp: self.dsp + o.dsp,
            bram: self.bram + o.bram,
            uram: self.uram + o.uram,
        }
    }

    /// Component-wise multiply (k instances of a module).
    pub fn scale(self, k: u64) -> Resources {
        Resources {
            lut: self.lut * k,
            ff: self.ff * k,
            dsp: self.dsp * k,
            bram: self.bram * k,
            uram: self.uram * k,
        }
    }

    /// Percent-of-U280 row, as printed in Table 6.
    pub fn utilization(&self) -> [(&'static str, u64, f64); 5] {
        [
            ("LUT", self.lut, 100.0 * self.lut as f64 / U280_LUT as f64),
            ("FF", self.ff, 100.0 * self.ff as f64 / U280_FF as f64),
            ("DSP", self.dsp, 100.0 * self.dsp as f64 / U280_DSP as f64),
            ("BRAM", self.bram, 100.0 * self.bram as f64 / U280_BRAM as f64),
            ("URAM", self.uram, 100.0 * self.uram as f64 / U280_URAM as f64),
        ]
    }
}

/// Per-module cost model for the Callipepla build.
///
/// Anchors: an FP64 mul+add pipe ~ 11 DSP (5.5 DSP/FLOP, §7.3); a
/// 512-bit HBM port + AXI burst logic ~ 5K LUT / 7K FF; SpMV PE = cast +
/// mul + accum + URAM port.
pub fn module_cost(name: &str) -> Resources {
    match name {
        // Per SpMV channel: 8 PEs x (f32->f64 cast, FP64 mul, FP64 acc)
        // + X-memory BRAMs + scheduling logic.
        "spmv_channel" => Resources { lut: 14_000, ff: 15_000, dsp: 88, bram: 32, uram: 24 },
        // Dot product: 8-lane delay buffer (8 FP64 MACs) + tail adder.
        "dot" => Resources { lut: 9_000, ff: 11_000, dsp: 99, bram: 4, uram: 0 },
        // axpy / update-p: 8-lane FP64 mul-add.
        "axpy" => Resources { lut: 8_000, ff: 9_000, dsp: 88, bram: 2, uram: 0 },
        // left divide: 8-lane FP64 divider (divider is LUT-hungry).
        "left_divide" => Resources { lut: 22_000, ff: 16_000, dsp: 16, bram: 2, uram: 0 },
        // Vector control module + FIFOs.
        "vecctrl" => Resources { lut: 3_500, ff: 3_500, dsp: 0, bram: 6, uram: 0 },
        // Memory read/write module (one HBM port).
        "memio" => Resources { lut: 3_000, ff: 4_800, dsp: 0, bram: 2, uram: 0 },
        // Global controller + scalar unit.
        "controller" => Resources { lut: 12_000, ff: 10_000, dsp: 33, bram: 4, uram: 0 },
        // Xilinx platform/add-on region (HBM controllers etc.).
        "platform" => Resources { lut: 90_000, ff: 120_000, dsp: 4, bram: 120, uram: 0 },
        _ => Resources::default(),
    }
}

/// Derived Callipepla build: 16 SpMV channels, 3 dots, 2 axpy, 1 divide
/// (+1 recompute instance), 5 vector controls, 26 memory ports, 1
/// controller + platform.
pub fn callipepla_build() -> Resources {
    module_cost("spmv_channel")
        .scale(16)
        .add(module_cost("dot").scale(3))
        .add(module_cost("axpy").scale(2))
        .add(module_cost("left_divide").scale(2))
        .add(module_cost("vecctrl").scale(5))
        .add(module_cost("memio").scale(26))
        .add(module_cost("controller"))
        .add(module_cost("platform"))
}

/// Table 6 measured rows for the two baselines.
pub fn measured(accel: &str) -> Resources {
    match accel {
        "XcgSolver" => Resources { lut: 503_000, ff: 878_000, dsp: 1_196, bram: 595, uram: 128 },
        "SerpensCG" => Resources { lut: 399_000, ff: 445_000, dsp: 1_236, bram: 460, uram: 384 },
        "Callipepla" => Resources { lut: 509_000, ff: 557_000, dsp: 1_940, bram: 716, uram: 384 },
        _ => Resources::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn within(actual: u64, target: u64, tol: f64) -> bool {
        (actual as f64 - target as f64).abs() <= tol * target as f64
    }

    #[test]
    fn derived_callipepla_matches_table6() {
        let d = callipepla_build();
        let t = measured("Callipepla");
        assert!(within(d.lut, t.lut, 0.15), "LUT {} vs {}", d.lut, t.lut);
        assert!(within(d.ff, t.ff, 0.20), "FF {} vs {}", d.ff, t.ff);
        assert!(within(d.dsp, t.dsp, 0.15), "DSP {} vs {}", d.dsp, t.dsp);
        assert!(within(d.bram, t.bram, 0.20), "BRAM {} vs {}", d.bram, t.bram);
        assert_eq!(d.uram, t.uram, "URAM is exactly the 16-channel Y memory");
    }

    #[test]
    fn callipepla_uses_more_dsp_than_xcgsolver() {
        // §7.4: more DSPs == higher compute capacity.
        assert!(measured("Callipepla").dsp > measured("XcgSolver").dsp);
    }

    #[test]
    fn utilization_percentages_match_paper() {
        let u = measured("Callipepla").utilization();
        let lut_pct = u[0].2;
        assert!((lut_pct - 39.0).abs() < 1.0, "LUT% = {lut_pct}");
        let dsp_pct = u[2].2;
        assert!((dsp_pct - 21.5).abs() < 0.5, "DSP% = {dsp_pct}");
    }

    #[test]
    fn everything_fits_on_u280() {
        let d = callipepla_build();
        assert!(d.lut < U280_LUT && d.ff < U280_FF && d.dsp < U280_DSP);
        assert!(d.bram < U280_BRAM && d.uram < U280_URAM);
    }
}
