//! Deterministic PRNG (xoshiro256++ seeded via splitmix64) — the
//! replacement for `rand_chacha` in matrix generation.  Quality is far
//! beyond what SPD-pattern sampling needs, and the stream is stable
//! across platforms, which keeps every generated matrix reproducible.

/// xoshiro256++ state.
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

impl Rng64 {
    /// Seed the generator deterministically from one u64.
    pub fn seed_from_u64(seed: u64) -> Self {
        // splitmix64 expansion, as recommended by the xoshiro authors.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let res = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in [0, bound) without modulo bias worth caring about here.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (one value per call; fine here).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng64::seed_from_u64(7);
        let mut b = Rng64::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng64::seed_from_u64(8);
        assert_ne!(Rng64::seed_from_u64(7).next_u64(), c.next_u64());
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng64::seed_from_u64(1);
        for _ in 0..1000 {
            let v = r.gen_range(17);
            assert!(v < 17);
            let f = r.gen_f64_range(2.0, 3.0);
            assert!((2.0..3.0).contains(&f));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng64::seed_from_u64(2);
        let mean: f64 = (0..10_000).map(|_| r.gen_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::seed_from_u64(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05 && (var - 1.0).abs() < 0.1);
    }
}
