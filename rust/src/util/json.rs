//! Minimal JSON reader/writer (serde_json is unavailable offline).
//!
//! The reader handles the subset emitted by `python/compile/aot.py`'s
//! manifest and by our own writer: objects, arrays, strings (with basic
//! escapes), numbers, booleans, null.  The writer is string-building
//! helpers used by the bench harness and CLI to emit result JSON.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The array contents, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The string contents, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The number, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.ws();
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected EOF"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        self.ws();
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        self.ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self
                .b
                .get(self.i)
                .ok_or_else(|| anyhow::anyhow!("EOF in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.b[self.i];
                    self.i += 1;
                    out.push(match e {
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'/' => '/',
                        b'\\' => '\\',
                        b'"' => '"',
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            self.i += 4;
                            char::from_u32(u32::from_str_radix(hex, 16)?)
                                .unwrap_or('\u{FFFD}')
                        }
                        other => bail!("bad escape \\{}", other as char),
                    });
                }
                _ => out.push(c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.eat(b':')?;
            map.insert(k, self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }
}

/// Escape and quote a string for JSON output.
pub fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            _ => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Tiny object writer for result emission.
#[derive(Default)]
pub struct ObjWriter {
    fields: Vec<String>,
}

impl ObjWriter {
    /// An empty object.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a string field (value quoted and escaped).
    pub fn field_str(&mut self, k: &str, v: &str) -> &mut Self {
        self.fields.push(format!("{}:{}", quote(k), quote(v)));
        self
    }

    /// Add a numeric field.
    pub fn field_num(&mut self, k: &str, v: f64) -> &mut Self {
        let mut s = String::new();
        let _ = write!(s, "{}:{}", quote(k), v);
        self.fields.push(s);
        self
    }

    /// Add a pre-serialized field (nested object/array).
    pub fn field_raw(&mut self, k: &str, v: &str) -> &mut Self {
        self.fields.push(format!("{}:{}", quote(k), v));
        self
    }

    /// Serialize the accumulated object.
    pub fn finish(&self) -> String {
        format!("{{{}}}", self.fields.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"buckets": [[1024, 16384]], "artifacts": [
            {"file": "a.hlo.txt", "n": 1024, "params": [{"shape": [16384], "dtype": "float32"}]}
        ]}"#;
        let j = Json::parse(src).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("file").unwrap().as_str(), Some("a.hlo.txt"));
        assert_eq!(arts[0].get("n").unwrap().as_usize(), Some(1024));
        let p0 = &arts[0].get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.get("dtype").unwrap().as_str(), Some("float32"));
    }

    #[test]
    fn parses_escapes_and_numbers() {
        let j = Json::parse(r#"{"s": "a\nb", "x": -1.5e-3, "t": true, "z": null}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("a\nb"));
        assert_eq!(j.get("x").unwrap().as_f64(), Some(-1.5e-3));
        assert_eq!(j.get("t"), Some(&Json::Bool(true)));
        assert_eq!(j.get("z"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{]").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn writer_roundtrips_through_parser() {
        let mut w = ObjWriter::new();
        w.field_str("name", "M1\"x\"").field_num("iters", 42.0);
        let j = Json::parse(&w.finish()).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("M1\"x\""));
        assert_eq!(j.get("iters").unwrap().as_f64(), Some(42.0));
    }
}
