//! Small self-contained utilities standing in for crates unavailable in
//! this offline environment (rand, serde_json, clap, criterion).

pub mod json;
pub mod rng;

pub use rng::Rng64;
