//! Callipepla CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands map 1:1 to the experiments of DESIGN.md §3:
//!
//! ```text
//! callipepla solve  --matrix M7 [--scheme mixv3] [--scale 0.05] [--pjrt]
//! callipepla solve  --mtx path/to/file.mtx [--pjrt]
//! callipepla suite  --list
//! callipepla table4 [--scale 0.02] [--matrices M1,M2,...]
//! callipepla table5 [--scale 0.02] [--matrices ...]
//! callipepla table6
//! callipepla table7 [--scale 0.02] [--matrices ...]
//! callipepla fig9   [--out traces/] [--scale 0.05]
//! callipepla sim    --matrix M7 [--scale 0.05] [--batch 8]   (cycle breakdown)
//! callipepla program [--n 16384] [--mode double] [--batch 8] (compiled ISA dump)
//! callipepla serve  [--requests 64] [--matrices 4] [--max-batch 8]
//! ```
//!
//! `solve --batch N` runs N right-hand sides through one compiled
//! batched program (the multi-RHS path of `PreparedMatrix::solve_batch`).
//! `serve` replays a synthetic multi-tenant request trace through the
//! service layer (registry + bucketed program cache + coalescing
//! scheduler, `docs/SERVICE.md`) and reports end-to-end RHS-iterations/s
//! against the no-coalescing baseline, plus the time-plane pricing of
//! the same trace.  `serve --http <port>` instead binds the
//! dependency-free HTTP front door (POST `/solve`, GET `/metrics` and
//! `/stats` — `docs/SERVICE.md` §10); `--deadline`, `--capacity-beats`,
//! `--pending-limit`, and `--tenant-quota` set the production knobs in
//! either mode.  `serve --metrics-dump` additionally emits the whole
//! telemetry registry in Prometheus text form and `--stats-json` the
//! full `ServiceStats` as JSON; `solve --profile` prints the registry
//! counter deltas for one solve (`docs/OBSERVABILITY.md`).
//!
//! (Arg parsing is hand-rolled: clap is not available offline.)

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use callipepla::bench_harness::tables::{self, SweepConfig};
use callipepla::coordinator::{Coordinator, CoordinatorConfig, NativeExecutor};
use callipepla::engine::PreparedMatrix;
use callipepla::precision::adaptive::{AdaptivePolicy, PrecisionMode, PrecisionTrace};
use callipepla::precision::Scheme;
#[cfg(feature = "pjrt")]
use callipepla::runtime::{default_artifact_dir, PjrtExecutor, PjrtRuntime};
use callipepla::sim::{self, AccelSimConfig};
use callipepla::solver::{jpcg_solve, SolveOptions};
use callipepla::sparse::{self, suite36, CsrMatrix};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let flags = parse_flags(&args[1..]);
    let r = match cmd.as_str() {
        "solve" => cmd_solve(&flags),
        "suite" => cmd_suite(&flags),
        "table4" => cmd_table(&flags, 4),
        "table5" => cmd_table(&flags, 5),
        "table6" => {
            println!("{}", tables::print_table6());
            Ok(())
        }
        "table7" => cmd_table(&flags, 7),
        "tables" => cmd_all_tables(&flags),
        "fig9" => cmd_fig9(&flags),
        "sim" => cmd_sim(&flags),
        "program" => cmd_program(&flags),
        "serve" => cmd_serve(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}")),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "callipepla — stream-centric ISA + mixed-precision JPCG (FPGA'23 reproduction)\n\
         commands: solve suite table4 table5 table6 table7 fig9 sim program serve\n\
         common flags: --matrix <Mxx|name>  --mtx <file>  --scale <f>  --scheme <fp64|mixv1|mixv2|mixv3>\n\
         \u{20}                --matrices M1,M2  --max-iters <n>  --threads <n>  --pjrt  --out <dir>\n\
         \u{20}                solve: --coordinator [--serpens-stream]  --batch <rhs>  --lane-workers <w>\n\
         \u{20}                       --block-spmv (resident block-CG)  --block-staged (PR 6 staged path)\n\
         \u{20}                       --adaptive (per-pass precision controller, docs/PRECISION.md)\n\
         \u{20}                       --tiny (built-in small matrix, for smoke runs)\n\
         \u{20}                       --profile (telemetry counter deltas, docs/OBSERVABILITY.md)\n\
         \u{20}                program: --n <len>  --mode <double|single>  --batch <rhs>\n\
         \u{20}                sim: --batch <rhs>  --lane-workers <w>  (w = 0: machine default)\n\
         \u{20}                serve: --requests <n>  --matrices <k>  --tenants <t>  --max-batch <b>\n\
         \u{20}                       --workers <w>  --seed <s>  --block-spmv  --adaptive\n\
         \u{20}                       --deadline <subs>  (logical-clock flush deadline, 0 = off)\n\
         \u{20}                       --capacity-beats <beats>  (registry LRU budget, 0 = unbounded)\n\
         \u{20}                       --pending-limit <lanes>  --tenant-quota <lanes>  (backpressure)\n\
         \u{20}                       --http <port>  --http-max-conns <n>  (HTTP front door)\n\
         \u{20}                       --metrics-dump (Prometheus text)  --stats-json\n\
         \u{20}                       (plus --scale/--scheme/--max-iters)"
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    m
}

fn flag_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> f64 {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn flag_u32(flags: &HashMap<String, String>, key: &str, default: u32) -> u32 {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn load_matrix(flags: &HashMap<String, String>) -> Result<(String, CsrMatrix)> {
    if flags.contains_key("tiny") {
        // A built-in small SPD system: lets smoke runs (CI) exercise a
        // full solve without naming a matrix or touching the suite.
        return Ok(("tiny (laplace2d 400)".to_string(), sparse::synth::laplace2d_shifted(400, 0.1)));
    }
    if let Some(path) = flags.get("mtx") {
        let a = sparse::mtx::read_mtx(std::path::Path::new(path))?;
        return Ok((path.clone(), a));
    }
    let key = flags
        .get("matrix")
        .ok_or_else(|| anyhow!("need --matrix <Mxx|name> or --mtx <file>"))?;
    let spec = sparse::synth::find_spec(key)
        .ok_or_else(|| anyhow!("unknown matrix {key:?} (see `callipepla suite`)"))?;
    let scale = flag_f64(flags, "scale", 0.05);
    Ok((format!("{} ({})", spec.id, spec.paper_name), spec.generate(scale)))
}

fn parse_scheme(flags: &HashMap<String, String>) -> Result<Scheme> {
    Ok(match flags.get("scheme").map(String::as_str) {
        None | Some("mixv3") => Scheme::MixV3,
        Some("fp64") => Scheme::Fp64,
        Some("mixv1") => Scheme::MixV1,
        Some("mixv2") => Scheme::MixV2,
        Some(other) => bail!("unknown scheme {other:?}"),
    })
}

/// Print a recorded precision schedule plus its modeled M1 traffic
/// against the static-FP64 envelope and the trace-aware time-plane
/// seconds.
fn report_trace(trace: &PrecisionTrace, n: usize, nnz: usize, iters: u32) {
    let events: Vec<String> = trace
        .events()
        .iter()
        .map(|e| format!("pass {}: {} ({})", e.pass, e.scheme.name(), e.reason.name()))
        .collect();
    println!("  precision trace: {}", events.join(" -> "));
    let adaptive_bytes = trace.modeled_m1_bytes(nnz as u64, iters);
    let fp64_bytes = (iters as u64 + 1) * nnz as u64 * Scheme::Fp64.nnz_bytes();
    let secs = sim::traced_solver_seconds(&AccelSimConfig::callipepla(), n, nnz, iters, trace);
    println!(
        "  modeled M1 nnz traffic: {adaptive_bytes} bytes ({:.2}x less than static fp64's \
         {fp64_bytes}), traced time plane: {:.3} ms",
        fp64_bytes as f64 / adaptive_bytes as f64,
        secs * 1e3
    );
}

/// Print the solve's telemetry-plane breakdown (`--profile`): deltas of
/// the `callipepla_*` registry counters across the run just finished —
/// per-phase trip counts, lane retirements, the precision plane's data
/// movement, and the program-bus / pool activity (docs/OBSERVABILITY.md).
fn report_profile(before: &callipepla::obs::Snapshot, after: &callipepla::obs::Snapshot) {
    let d = |name: &str| after.counter(name).saturating_sub(before.counter(name));
    println!("profile (telemetry-plane counter deltas):");
    println!(
        "  trips: init={} phase1={} phase2={} phase3={} exit={}",
        d("callipepla_coord_init_trips_total"),
        d("callipepla_coord_phase1_trips_total"),
        d("callipepla_coord_phase2_trips_total"),
        d("callipepla_coord_phase3_trips_total"),
        d("callipepla_coord_exit_trips_total"),
    );
    println!(
        "  lanes: converged={} iteration-capped={}",
        d("callipepla_coord_lanes_converged_total"),
        d("callipepla_coord_lanes_iteration_capped_total"),
    );
    println!(
        "  precision plane: matrix_value_reads={} vector_element_moves={} escalations={}",
        d("callipepla_precision_matrix_value_reads_total"),
        d("callipepla_precision_vector_element_moves_total"),
        d("callipepla_precision_escalations_total"),
    );
    println!(
        "  program bus: trips_issued={} write_acks={}   pool: jobs={} scoped_fanouts={}",
        d("callipepla_program_trips_issued_total"),
        d("callipepla_program_write_acks_total"),
        d("callipepla_pool_jobs_total"),
        d("callipepla_pool_scoped_fanouts_total"),
    );
}

fn cmd_solve(flags: &HashMap<String, String>) -> Result<()> {
    let (name, a) = load_matrix(flags)?;
    let scheme = parse_scheme(flags)?;
    let max_iters = flag_u32(flags, "max-iters", 20_000);
    // --profile turns the recording gate on for this run and reports the
    // registry counter deltas once the solve finishes.
    let profile_before = if flags.contains_key("profile") {
        callipepla::obs::set_recording(true);
        Some(callipepla::obs::snapshot())
    } else {
        None
    };
    // --adaptive turns on the per-pass precision controller
    // (docs/PRECISION.md): start on the CLI scheme's family default
    // (Mix-V3), escalate to FP64 on stall or near convergence, and
    // record a replayable PrecisionTrace.
    let adaptive = if flags.contains_key("adaptive") {
        if flags.contains_key("pjrt") || flags.contains_key("serpens-stream") {
            bail!(
                "--adaptive binds the precision scheme per pass at issue time; the pjrt \
                 artifacts and the serpens stream replay are compiled to one scheme"
            );
        }
        Some(AdaptivePolicy::default())
    } else {
        None
    };
    // --batch is its own execution path; reject malformed or conflicting
    // uses instead of silently falling through to a single solve.
    let batch = match flags.get("batch") {
        Some(v) => {
            let b: usize = v
                .parse()
                .ok()
                .filter(|b| *b > 0)
                .ok_or_else(|| anyhow!("--batch needs a positive integer, got {v:?}"))?;
            if flags.contains_key("coordinator")
                || flags.contains_key("pjrt")
                || flags.contains_key("serpens-stream")
            {
                bail!(
                    "--batch is not combinable with --coordinator/--pjrt/--serpens-stream \
                     (the batch path already runs through the coordinator, on the engine SpMV)"
                );
            }
            Some(b)
        }
        None => None,
    };
    if batch.is_none() && flags.contains_key("lane-workers") {
        bail!("--lane-workers configures the batched program path; pair it with --batch <rhs>");
    }
    for block_flag in ["block-spmv", "block-staged"] {
        if batch.is_none() && flags.contains_key(block_flag) {
            bail!("--{block_flag} configures the batched program path; pair it with --batch <rhs>");
        }
    }
    println!("solving {name}: n={} nnz={} scheme={}", a.n, a.nnz(), scheme.name());
    let t0 = std::time::Instant::now();
    if flags.contains_key("pjrt") {
        #[cfg(not(feature = "pjrt"))]
        bail!(
            "this binary was built without the `pjrt` feature; enabling it needs the \
             `xla` crate + libxla_extension (see the dependency note in rust/Cargo.toml), \
             then `cargo build --features pjrt`"
        );
        // Three-layer path: coordinator -> PJRT artifacts (L2/L1).
        #[cfg(feature = "pjrt")]
        {
            let mut rt = PjrtRuntime::new(default_artifact_dir())?;
            let mut exec = PjrtExecutor::new(&mut rt, &a, scheme)?;
            let cfg = CoordinatorConfig { max_iters, ..Default::default() };
            let mut coord = Coordinator::new(cfg);
            let b = vec![1.0; a.n];
            let x0 = vec![0.0; a.n];
            let res = coord.solve(&mut exec, &b, &x0);
            println!(
                "pjrt path: converged={} iters={} rr={:.3e} executable_calls={} wall={:?}",
                res.converged,
                res.iters,
                res.final_rr,
                exec.calls,
                t0.elapsed()
            );
        }
    } else if flags.contains_key("coordinator") {
        // Native instruction-interpreter path through the compiled ISA
        // program.  --serpens-stream additionally replays the scheduled
        // Serpens nnz streams for the SpMV (Mix-V3 only) instead of the
        // bitwise-oracle engine kernels.
        let cfg = CoordinatorConfig {
            max_iters,
            record_instructions: true,
            precision: match adaptive {
                Some(p) => PrecisionMode::Adaptive(p),
                None => PrecisionMode::Static(scheme),
            },
            ..Default::default()
        };
        let mut coord = Coordinator::new(cfg);
        let serpens = flags.contains_key("serpens-stream");
        if serpens && scheme != Scheme::MixV3 {
            bail!("--serpens-stream replays the Mix-V3 nnz streams; use --scheme mixv3");
        }
        let mut exec = if serpens {
            NativeExecutor::with_serpens_stream(&a)
        } else {
            NativeExecutor::new(&a, scheme)
        };
        let b = vec![1.0; a.n];
        let x0 = vec![0.0; a.n];
        let res = coord.solve(&mut exec, &b, &x0);
        println!(
            "coordinator path ({}): converged={} iters={} rr={:.3e} instructions={} acks={} wall={:?}",
            if serpens { "serpens-stream" } else { "engine" },
            res.converged,
            res.iters,
            res.final_rr,
            res.instructions.issued.len(),
            res.mem_acks,
            t0.elapsed()
        );
        if adaptive.is_some() {
            report_trace(&res.precision, a.n, a.nnz(), res.iters);
        }
    } else if let Some(batch) = batch {
        // Multi-RHS: `batch` deterministic right-hand sides through one
        // compiled batched instruction program (per-RHS results bitwise
        // identical to lone solves; early lanes exit on the fly).
        // --lane-workers <w> fans each trip's lanes across w workers
        // (0 = machine default) — same bits, more cores.
        let mut opts = SolveOptions::callipepla();
        opts.scheme = scheme;
        opts.max_iters = max_iters;
        opts.adaptive = adaptive;
        let threads = flag_u32(flags, "threads", 0).max(1) as usize;
        let lane_workers = match flags.get("lane-workers") {
            None => None,
            Some(v) => match v.parse::<usize>() {
                Ok(w) => Some(w),
                Err(_) => bail!("--lane-workers needs a non-negative integer, got {v:?}"),
            },
        };
        let prep = PreparedMatrix::new(&a, threads);
        let rhs: Vec<Vec<f64>> = (0..batch)
            .map(|k| (0..a.n).map(|i| 1.0 + ((i + 31 * k) % 7) as f64 / 7.0).collect())
            .collect();
        // --block-spmv streams the matrix once per batched iteration
        // and keeps the vector plane resident in lane-major arenas —
        // zero block-boundary element moves per steady iteration (PERF
        // §12).  --block-staged retains the PR 6 staged path: the same
        // single nnz stream, but the block is re-materialized around
        // every pass (2·n·L moves per iteration).  Same bits either way.
        let resident = flags.contains_key("block-spmv");
        let staged = flags.contains_key("block-staged");
        if resident && staged {
            bail!("--block-spmv (resident) and --block-staged are mutually exclusive");
        }
        let results = match (lane_workers, resident, staged) {
            (Some(w), false, false) => prep.solve_batch_parallel(&rhs, &opts, None, w),
            (Some(w), true, _) => prep.solve_batch_block_parallel(&rhs, &opts, None, w),
            (Some(w), _, true) => prep.solve_batch_block_staged_parallel(&rhs, &opts, None, w),
            (None, false, false) => prep.solve_batch(&rhs, &opts),
            (None, true, _) => prep.solve_batch_block(&rhs, &opts),
            (None, _, true) => prep.solve_batch_block_staged(&rhs, &opts),
        };
        for (k, r) in results.iter().enumerate() {
            println!(
                "  rhs {k}: converged={} iters={} rr={:.3e}",
                r.converged, r.iters, r.final_rr
            );
            if adaptive.is_some() {
                report_trace(&r.precision, a.n, a.nnz(), r.iters);
            }
        }
        let total_iters: u64 = results.iter().map(|r| r.iters as u64).sum();
        let mut dispatch = match lane_workers {
            Some(0) => "lane-parallel (machine default)".to_string(),
            Some(w) => format!("lane-parallel ({w} workers)"),
            None => "sequential dispatch".to_string(),
        };
        if resident {
            dispatch.push_str(", resident block-CG");
        } else if staged {
            dispatch.push_str(", staged block-CG");
        }
        println!(
            "batched program path ({dispatch}): {batch} rhs, {total_iters} rhs-iterations, wall={:?}",
            t0.elapsed()
        );
    } else {
        let mut opts = SolveOptions::callipepla();
        opts.scheme = scheme;
        opts.max_iters = max_iters;
        opts.adaptive = adaptive;
        // --threads N runs the prepared-matrix parallel engine (0/absent
        // = serial reference path); the numerics are bitwise identical.
        let threads = flag_u32(flags, "threads", 0) as usize;
        let res = if threads > 1 {
            let prep = PreparedMatrix::new(&a, threads);
            prep.solve(None, None, &opts)
        } else {
            jpcg_solve(&a, None, None, &opts)
        };
        println!(
            "native path ({}): converged={} iters={} rr={:.3e} flops={} wall={:?}",
            if threads > 1 { format!("{threads} threads") } else { "serial".to_string() },
            res.converged,
            res.iters,
            res.final_rr,
            res.flops,
            t0.elapsed()
        );
        if adaptive.is_some() {
            report_trace(&res.precision, a.n, a.nnz(), res.iters);
        }
    }
    if let Some(before) = profile_before {
        let after = callipepla::obs::snapshot();
        callipepla::obs::set_recording(false);
        report_profile(&before, &after);
    }
    Ok(())
}

fn cmd_suite(_flags: &HashMap<String, String>) -> Result<()> {
    println!("{}", tables::print_table3());
    Ok(())
}

fn sweep_config(flags: &HashMap<String, String>) -> SweepConfig {
    SweepConfig {
        scale: flag_f64(flags, "scale", 0.02),
        max_iters: flag_u32(flags, "max-iters", 20_000),
    }
}

fn matrix_filter(flags: &HashMap<String, String>) -> Vec<String> {
    flags
        .get("matrices")
        .map(|s| s.split(',').map(str::to_string).collect())
        .unwrap_or_default()
}

fn cmd_table(flags: &HashMap<String, String>, which: u8) -> Result<()> {
    let cfg = sweep_config(flags);
    let ids = matrix_filter(flags);
    eprintln!(
        "evaluating {} matrices at scale {} (use --matrices / --scale to adjust)...",
        if ids.is_empty() { suite36().len() } else { ids.len() },
        cfg.scale
    );
    let evals = tables::eval_suite(&ids, &cfg);
    match which {
        4 => println!("{}", tables::print_table4(&evals)),
        5 => println!("{}", tables::print_table5(&evals)),
        7 => println!("{}", tables::print_table7(&evals)),
        _ => unreachable!(),
    }
    Ok(())
}

/// One sweep, all three value/time tables — saves re-solving the suite.
fn cmd_all_tables(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = sweep_config(flags);
    let ids = matrix_filter(flags);
    eprintln!("evaluating {} matrices at scale {} ...",
        if ids.is_empty() { suite36().len() } else { ids.len() }, cfg.scale);
    let evals = tables::eval_suite(&ids, &cfg);
    println!("{}", tables::print_table4(&evals));
    println!("{}", tables::print_table5(&evals));
    println!("{}", tables::print_table6());
    println!("{}", tables::print_table7(&evals));
    Ok(())
}

fn cmd_fig9(flags: &HashMap<String, String>) -> Result<()> {
    // Paper Fig. 9 uses nasa2910 (M7), gyro_k (M13), msc10848 (M15).
    let out_dir = flags.get("out").cloned().unwrap_or_else(|| "traces".to_string());
    std::fs::create_dir_all(&out_dir)?;
    let scale = flag_f64(flags, "scale", 0.05);
    let max_iters = flag_u32(flags, "max-iters", 20_000);
    for id in ["M7", "M13", "M15"] {
        let spec = sparse::synth::find_spec(id).unwrap();
        let a = spec.generate(scale);
        eprintln!("tracing {} ({}) n={} nnz={}", id, spec.paper_name, a.n, a.nnz());
        for (label, csv) in tables::fig9_traces(&a, max_iters) {
            let path = format!("{out_dir}/fig9_{}_{label}.csv", spec.paper_name);
            std::fs::write(&path, csv)?;
            println!("wrote {path}");
        }
    }
    Ok(())
}

/// Dump the compiled instruction program: the five trips with their
/// Type-I/II/III steps, real HBM addresses, and validated reuse edges.
fn cmd_program(flags: &HashMap<String, String>) -> Result<()> {
    use callipepla::hbm::ChannelMode;
    use callipepla::program::{short_name, HbmMemoryMap, Program};

    let n = flag_u32(flags, "n", 16_384);
    let batch = flag_u32(flags, "batch", 1).max(1);
    if batch > HbmMemoryMap::max_batch(n) {
        bail!(
            "{batch} lanes of {n} elems exceed a 256 MiB channel window \
             (max_batch = {})",
            HbmMemoryMap::max_batch(n)
        );
    }
    let mode = match flags.get("mode").map(String::as_str) {
        None | Some("double") => ChannelMode::Double,
        Some("single") => ChannelMode::Single,
        Some(other) => bail!("unknown channel mode {other:?}"),
    };
    let program = Program::compile_batched(n, mode, batch);
    println!("compiled program: n={n} mode={mode:?} batch={batch}");
    println!("\nmemory map (addresses in 64-byte beats):");
    for r in program.mem_map.regions() {
        println!(
            "  {:<3} channels {:?}  base 0x{:08x}  {} beats",
            r.vector.name(),
            r.channels,
            r.rd_addr(0),
            r.beats()
        );
    }
    if batch > 1 {
        println!(
            "  batch axis: {} RHS lanes per channel pair, lane stride {} beats;\n\
             \u{20} lane k rebases ap/p/x/r addresses by k * stride at issue time\n\
             \u{20} (M and the nnz streams are shared — one matrix serves every lane)",
            batch, program.mem_map.lane_stride_beats
        );
    }
    for trip in program.all_trips() {
        let (reads, writes) = trip.access_counts();
        println!(
            "\n[{}] {} vector-control steps ({reads} rd / {writes} wr), \
             {} compute steps, {} reuse edges",
            trip.kind.label(),
            trip.vec_steps.len(),
            trip.comp_steps.len(),
            trip.reuse_edges.len()
        );
        for s in &trip.vec_steps {
            let v = s.vctrl;
            println!(
                "  I   {:<11} rd={} wr={} base=0x{:08x} len={} q_id={}",
                s.name, v.rd as u8, v.wr as u8, v.base_addr, v.len, v.q_id
            );
            if let Some(rd) = s.rd_inst {
                let (nm, ch) = (s.mem_name, s.rd_channel);
                println!("  III {nm:<11} rd ch{ch:<2} base=0x{:08x}", rd.base_addr);
            }
            if let Some(wr) = s.wr_inst {
                let (nm, ch) = (s.mem_name, s.wr_channel);
                println!("  III {nm:<11} wr ch{ch:<2} base=0x{:08x}", wr.base_addr);
            }
        }
        for c in &trip.comp_steps {
            println!(
                "  II  {:<11} len={} bind={:?} q_id={}",
                c.target, c.inst.len, c.bind, c.inst.q_id
            );
        }
        for e in &trip.reuse_edges {
            println!(
                "  edge {} -> {} ({}) skew={} fifo={}",
                short_name(e.producer),
                short_name(e.consumer),
                e.vector.name(),
                e.skew,
                e.fifo_depth
            );
        }
    }
    Ok(())
}

/// Replay a synthetic multi-tenant request trace through the solver
/// service (registry + bucketed program cache + coalescing scheduler)
/// and report end-to-end RHS-iterations/s against the no-coalescing
/// baseline, plus the time plane's pricing of the same trace.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    use callipepla::service::{
        replay_coalesced, replay_sequential, synth_trace, ServiceConfig, SolverService,
        TraceConfig,
    };

    let requests = flag_u32(flags, "requests", 64).max(1) as usize;
    let num_matrices = flag_u32(flags, "matrices", 4).max(1) as usize;
    let tenants = flag_u32(flags, "tenants", 8).max(1);
    let max_batch = flag_u32(flags, "max-batch", 8).max(1) as usize;
    let workers = flag_u32(flags, "workers", 0) as usize; // 0 = machine default
    let scale = flag_f64(flags, "scale", 0.02);
    let seed = flags.get("seed").and_then(|v| v.parse().ok()).unwrap_or(0xCA111_9E91A_u64);
    let scheme = parse_scheme(flags)?;
    let max_iters = flag_u32(flags, "max-iters", 20_000);

    let mut opts = SolveOptions::callipepla();
    opts.scheme = scheme;
    opts.max_iters = max_iters;
    // --adaptive serves every ticket under the per-pass precision
    // controller; traces are a pure function of each lane's residual
    // sequence, so the coalesced/sequential bitwise check still holds.
    if flags.contains_key("adaptive") {
        opts.adaptive = Some(AdaptivePolicy::default());
    }
    // --block-spmv runs every coalesced batch as one resident
    // lane-major block (same per-ticket bits, one nnz stream per
    // batched iteration, zero steady-state boundary moves).
    let block_spmv = flags.contains_key("block-spmv");
    // --metrics-dump opens the recording gate for the replay and prints
    // the Prometheus text exposition after the drain; --stats-json
    // serializes the full ServiceStats (records included) as JSON.
    let metrics_dump = flags.contains_key("metrics-dump");
    let stats_json = flags.contains_key("stats-json");
    // Production knobs (docs/SERVICE.md §8–§10): the logical-clock
    // deadline flush, the capacity-bounded registry, bounded admission,
    // and the HTTP front door.
    let deadline: u64 = flags.get("deadline").and_then(|v| v.parse().ok()).unwrap_or(0);
    let capacity_beats: u64 =
        flags.get("capacity-beats").and_then(|v| v.parse().ok()).unwrap_or(0);
    let pending_limit = flag_u32(flags, "pending-limit", 0) as usize;
    let tenant_quota = flag_u32(flags, "tenant-quota", 0) as usize;
    let http_port = flags.get("http").and_then(|v| v.parse::<u16>().ok());
    let http_max_conns: u64 =
        flags.get("http-max-conns").and_then(|v| v.parse().ok()).unwrap_or(0);
    if metrics_dump {
        callipepla::obs::set_recording(true);
    }
    let mut cfg = ServiceConfig {
        max_batch,
        block_spmv,
        deadline,
        pending_limit,
        tenant_quota,
        capacity_beats,
        opts,
        ..Default::default()
    };
    if workers > 0 {
        cfg.workers = workers;
    }
    let mut svc = SolverService::new(cfg);

    // Few matrices, sizes spread so several land in different buckets.
    let ids: Vec<_> = (0..num_matrices)
        .map(|k| {
            let n = (((k + 1) as f64) * 60_000.0 * scale).round().max(64.0) as usize;
            let a = sparse::synth::laplace2d_shifted(n, 0.05 + 0.02 * k as f64);
            let id = svc.register(a);
            let e = svc.registry().entry(id);
            println!("registered {id}: n={} nnz={}", e.n(), e.nnz());
            id
        })
        .collect();

    // --http turns the replay harness into a live ingress: bind the
    // dependency-free front door and serve until POST /shutdown (or
    // --http-max-conns requests).  Recording is forced on so GET
    // /metrics reflects traffic.
    if let Some(port) = http_port {
        callipepla::obs::set_recording(true);
        let listener = std::net::TcpListener::bind(("127.0.0.1", port))
            .map_err(|e| anyhow!("cannot bind 127.0.0.1:{port}: {e}"))?;
        let addr = listener.local_addr()?;
        println!(
            "front door on http://{addr}  (POST /solve /submit /flush /shutdown; \
             GET /healthz /metrics /stats)"
        );
        let served = callipepla::service::serve_http(&mut svc, &listener, http_max_conns)?;
        let stats = svc.drain();
        println!(
            "front door closed after {served} HTTP requests: {} accepted, {} rejected, \
             {} batches, {} rhs-iterations",
            stats.requests, stats.rejected, stats.batches, stats.rhs_iterations
        );
        if stats_json {
            println!("{}", stats.to_json());
        }
        if metrics_dump {
            stats.export_time_plane_gauges(&AccelSimConfig::callipepla());
            println!("{}", callipepla::obs::prometheus_dump());
        }
        callipepla::obs::set_recording(false);
        return Ok(());
    }

    let trace_cfg = TraceConfig { requests, tenants, rate: 1.0, seed };
    let trace = synth_trace(svc.registry(), &ids, &trace_cfg);
    println!(
        "replaying {requests} requests from {tenants} tenants over {num_matrices} matrices \
         (max_batch={max_batch}, workers={}, seed={seed:#x})",
        svc.config().workers
    );

    let coal = replay_coalesced(&mut svc, &trace);
    let stats = svc.drain();
    let seq = replay_sequential(svc.registry(), &trace, &opts);

    let identical = coal.results.iter().zip(&seq.results).all(|(a, b)| {
        a.iters == b.iters
            && a.final_rr.to_bits() == b.final_rr.to_bits()
            && a.x.iter().zip(&b.x).all(|(u, v)| u.to_bits() == v.to_bits())
    });
    println!(
        "coalesced:  {:>10.1} rhs-iters/s  ({} rhs-iterations in {:.3}s, {} batches)",
        coal.rhs_iterations_per_second(),
        coal.rhs_iterations,
        coal.wall_s,
        stats.batches
    );
    println!(
        "sequential: {:>10.1} rhs-iters/s  ({} rhs-iterations in {:.3}s, {} program runs)",
        seq.rhs_iterations_per_second(),
        seq.rhs_iterations,
        seq.wall_s,
        requests
    );
    println!(
        "speedup: {:.2}x   per-request results bitwise identical to lone solves: {identical}",
        coal.rhs_iterations_per_second() / seq.rhs_iterations_per_second().max(1e-12)
    );
    println!(
        "program cache: {} compiled, {} hits / {} misses",
        stats.compiled_programs, stats.cache_hits, stats.cache_misses
    );
    for &id in &ids {
        let submitted = trace.iter().filter(|t| t.request.matrix == id).count();
        let execs = stats.executions_for(id);
        println!(
            "  {id}: {submitted} requests -> {execs} batch executions \
             (bound: ceil({submitted}/{max_batch}) = {})",
            submitted.div_ceil(max_batch)
        );
    }
    let sim_cfg = AccelSimConfig::callipepla();
    println!(
        "time plane: {} modeled cycles for the executed trace, {:.0} modeled rhs-iters/s",
        stats.modeled_cycles(&sim_cfg),
        stats.modeled_rhs_iterations_per_second(&sim_cfg)
    );
    if stats_json {
        println!("{}", stats.to_json());
    }
    if metrics_dump {
        // Land the modeled time plane on the sim gauges so the dump
        // shows it next to the value-plane counters, then emit the
        // whole registry in Prometheus text form.
        stats.export_time_plane_gauges(&sim_cfg);
        println!("{}", callipepla::obs::prometheus_dump());
        callipepla::obs::set_recording(false);
    }
    if !identical {
        bail!("coalesced results diverged from the sequential baseline");
    }
    Ok(())
}

fn cmd_sim(flags: &HashMap<String, String>) -> Result<()> {
    let (name, a) = load_matrix(flags)?;
    println!("cycle model for {name}: n={} nnz={}", a.n, a.nnz());
    for (label, cfg) in [
        ("Callipepla", AccelSimConfig::callipepla()),
        ("SerpensCG", AccelSimConfig::serpenscg()),
        ("XcgSolver", AccelSimConfig::xcgsolver()),
    ] {
        let b = sim::iteration_cycles(&cfg, a.n, a.nnz());
        println!(
            "{label:<11} phase1 {:>9}  phase2 {:>9}  phase3 {:>9}  total {:>10} cycles  ({:.3} us/iter @ {:.0} MHz)",
            b.phase1,
            b.phase2,
            b.phase3,
            b.total,
            b.total as f64 * cfg.hbm.cycle_time() * 1e6,
            cfg.hbm.freq_hz / 1e6,
        );
    }
    println!(
        "A100 (analytic): {:.3} us/iter",
        sim::iteration::gpu_iteration_seconds(a.n, a.nnz()) * 1e6
    );
    if flags.contains_key("lane-workers") && !flags.contains_key("batch") {
        bail!("--lane-workers prices the batched dispatch; pair it with --batch <rhs>");
    }
    if let Some(v) = flags.get("batch") {
        let batch: u32 = v
            .parse()
            .ok()
            .filter(|b| *b > 0)
            .ok_or_else(|| anyhow!("--batch needs a positive integer, got {v:?}"))?;
        let cfg = AccelSimConfig::callipepla();
        let b1 = sim::iteration::batched_rhs_iterations_per_second(&cfg, a.n, a.nnz(), 1);
        let bb = sim::iteration::batched_rhs_iterations_per_second(&cfg, a.n, a.nnz(), batch);
        let cyc = sim::iteration::batched_iteration_cycles(&cfg, a.n, a.nnz(), batch);
        println!(
            "batched program (batch={batch}): {} cycles/batched-iter, \
             {:.0} rhs-iters/s (1 rhs: {:.0}, {:.2}x throughput)",
            cyc.total,
            bb,
            b1,
            bb / b1
        );
        let staged = sim::iteration::batched_iteration_cycles_mode(
            &cfg,
            a.n,
            a.nnz(),
            batch,
            sim::iteration::BatchSpmvMode::Staged,
        );
        println!(
            "staged block boundary: +{} cycles/batched-iter over the resident block path \
             (the gather/scatter the resident arenas remove)",
            staged.total - cyc.total
        );
        if let Some(v) = flags.get("lane-workers") {
            let workers: usize = v
                .parse()
                .map_err(|_| anyhow!("--lane-workers needs a non-negative integer, got {v:?}"))?;
            let w = if workers == 0 {
                callipepla::engine::pool::default_lane_workers()
            } else {
                workers
            };
            let cyc = sim::lane_parallel_iteration_cycles(&cfg, a.n, a.nnz(), batch, w);
            let thr = sim::lane_parallel_rhs_iterations_per_second(&cfg, a.n, a.nnz(), batch, w);
            println!(
                "lane-parallel dispatch ({w} workers): {} cycles/batched-iter, \
                 {thr:.0} rhs-iters/s ({:.2}x the sequential lane walk)",
                cyc.total,
                thr / sim::lane_parallel_rhs_iterations_per_second(&cfg, a.n, a.nnz(), batch, 1)
            );
        }
    }
    Ok(())
}
