//! Exposition: render one registry [`Snapshot`] as Prometheus text or
//! JSON.
//!
//! Both renderers consume the same snapshot, so the two surfaces can
//! never disagree about a value; and because a snapshot is name-sorted,
//! both outputs are deterministic given deterministic counters (which
//! the logical-clock rules of [`crate::obs::trace`] guarantee for
//! everything the replay tests cover).
//!
//! ```
//! use callipepla::obs::{render_json, render_prometheus, Sample, SampleValue, Snapshot};
//! let snap = Snapshot {
//!     samples: vec![Sample {
//!         name: "callipepla_demo_total",
//!         help: "demo",
//!         value: SampleValue::Counter(3),
//!     }],
//! };
//! assert!(render_prometheus(&snap).contains("callipepla_demo_total 3"));
//! assert!(render_json(&snap).contains("\"callipepla_demo_total\""));
//! ```

use std::fmt::Write;

use super::registry::{Sample, SampleValue, Snapshot};
use crate::util::json::ObjWriter;

/// The `Content-Type` the Prometheus text exposition is served under
/// (what the HTTP front door's `/metrics` handler sends).
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Render a snapshot as Prometheus text exposition (`# HELP` / `# TYPE`
/// headers, histograms as cumulative `_bucket{le=...}` series plus
/// `_sum` / `_count`).
pub fn render_prometheus(snap: &Snapshot) -> String {
    let mut out = String::new();
    for s in &snap.samples {
        let _ = writeln!(out, "# HELP {} {}", s.name, s.help);
        match &s.value {
            SampleValue::Counter(v) => {
                let _ = writeln!(out, "# TYPE {} counter", s.name);
                let _ = writeln!(out, "{} {v}", s.name);
            }
            SampleValue::Gauge(v) => {
                let _ = writeln!(out, "# TYPE {} gauge", s.name);
                let _ = writeln!(out, "{} {v}", s.name);
            }
            SampleValue::Histogram { buckets, sum, count } => {
                let _ = writeln!(out, "# TYPE {} histogram", s.name);
                for (le, cum) in buckets {
                    match le {
                        Some(b) => {
                            let _ = writeln!(out, "{}_bucket{{le=\"{b}\"}} {cum}", s.name);
                        }
                        None => {
                            let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {cum}", s.name);
                        }
                    }
                }
                let _ = writeln!(out, "{}_sum {sum}", s.name);
                let _ = writeln!(out, "{}_count {count}", s.name);
            }
        }
    }
    out
}

fn json_sample(s: &Sample) -> String {
    let mut w = ObjWriter::new();
    w.field_str("name", s.name);
    w.field_str("help", s.help);
    match &s.value {
        SampleValue::Counter(v) => {
            w.field_str("kind", "counter");
            w.field_raw("value", &v.to_string());
        }
        SampleValue::Gauge(v) => {
            w.field_str("kind", "gauge");
            w.field_num("value", *v);
        }
        SampleValue::Histogram { buckets, sum, count } => {
            w.field_str("kind", "histogram");
            w.field_raw("sum", &sum.to_string());
            w.field_raw("count", &count.to_string());
            let mut arr = String::from("[");
            for (i, (le, cum)) in buckets.iter().enumerate() {
                if i > 0 {
                    arr.push(',');
                }
                let mut b = ObjWriter::new();
                match le {
                    Some(v) => b.field_str("le", &v.to_string()),
                    None => b.field_str("le", "+Inf"),
                }
                b.field_raw("count", &cum.to_string());
                arr.push_str(&b.finish());
            }
            arr.push(']');
            w.field_raw("buckets", &arr);
        }
    }
    w.finish()
}

/// Render a snapshot as one JSON object: `{"metrics":[...]}`, each
/// entry carrying `name`, `help`, `kind`, and the kind's value fields.
/// Round-trips through [`crate::util::json::Json::parse`].
pub fn render_json(snap: &Snapshot) -> String {
    let mut arr = String::from("[");
    for (i, s) in snap.samples.iter().enumerate() {
        if i > 0 {
            arr.push(',');
        }
        arr.push_str(&json_sample(s));
    }
    arr.push(']');
    let mut w = ObjWriter::new();
    w.field_raw("metrics", &arr);
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn demo_snapshot() -> Snapshot {
        Snapshot {
            samples: vec![
                Sample {
                    name: "callipepla_a_total",
                    help: "a counter",
                    value: SampleValue::Counter(42),
                },
                Sample { name: "callipepla_b", help: "a gauge", value: SampleValue::Gauge(2.5) },
                Sample {
                    name: "callipepla_c_width",
                    help: "a histogram",
                    value: SampleValue::Histogram {
                        buckets: vec![(Some(0), 0), (Some(1), 2), (None, 3)],
                        sum: 9,
                        count: 3,
                    },
                },
            ],
        }
    }

    #[test]
    fn prometheus_text_has_headers_series_and_histogram_tail() {
        let text = render_prometheus(&demo_snapshot());
        assert!(text.contains("# HELP callipepla_a_total a counter"));
        assert!(text.contains("# TYPE callipepla_a_total counter"));
        assert!(text.contains("callipepla_a_total 42"));
        assert!(text.contains("callipepla_b 2.5"));
        assert!(text.contains("callipepla_c_width_bucket{le=\"1\"} 2"));
        assert!(text.contains("callipepla_c_width_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("callipepla_c_width_sum 9"));
        assert!(text.contains("callipepla_c_width_count 3"));
    }

    #[test]
    fn json_roundtrips_and_carries_every_sample() {
        let text = render_json(&demo_snapshot());
        let parsed = Json::parse(&text).expect("exposition JSON must parse");
        let metrics = parsed.get("metrics").and_then(Json::as_arr).expect("metrics array");
        assert_eq!(metrics.len(), 3);
        assert_eq!(metrics[0].get("kind").and_then(Json::as_str), Some("counter"));
        assert_eq!(metrics[0].get("value").and_then(Json::as_f64), Some(42.0));
        assert_eq!(metrics[2].get("count").and_then(Json::as_f64), Some(3.0));
    }
}
