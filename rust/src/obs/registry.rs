//! The metrics registry: atomic instruments registered by static name.
//!
//! Three ordinary instruments — [`Counter`], [`Gauge`], and the
//! fixed-log2-bucket [`Histogram`] — plus [`LocalCounter`], the
//! registry-backed replacement for the `precision::stats` thread-local
//! counters (see its docs for the dual local/total view).  Instruments
//! live as `static` items next to the code they instrument (the crate
//! catalog is [`crate::obs::catalog`]) and cost one relaxed atomic load
//! and a predictable branch when recording is off — the default — so
//! the instrumented hot paths stay bitwise and within noise of their
//! uninstrumented timings (`benches/hot_paths.rs` pins this with the
//! `*_obs_off` / `*_obs_on` row pair).
//!
//! A [`Snapshot`] is one consistent-enough read of every registered
//! instrument, sorted by name, and is the sole input to the exposition
//! renderers in [`crate::obs::expo`] — Prometheus text and JSON render
//! the same snapshot, so the two surfaces can never disagree.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::thread::LocalKey;

/// Process-global recording switch.  Off by default: every gated
/// instrument ([`Counter`], [`Gauge`], [`Histogram`]) early-returns on
/// a relaxed load, which is the "no sink installed" near-zero-cost
/// path.  [`LocalCounter`] ignores this switch — its thread-local delta
/// semantics are load-bearing for the counter-wall tests.
static RECORDING: AtomicBool = AtomicBool::new(false);

/// Turn metric recording on or off (process-global).  `serve
/// --metrics-dump`, `solve --profile`, and the observability tests turn
/// it on; everything else runs with the switch off.
pub fn set_recording(on: bool) {
    RECORDING.store(on, Ordering::Relaxed);
}

/// Whether gated instruments are currently recording.
pub fn recording() -> bool {
    RECORDING.load(Ordering::Relaxed)
}

/// A monotonically increasing counter (`*_total` by convention).
#[derive(Debug)]
pub struct Counter {
    name: &'static str,
    help: &'static str,
    value: AtomicU64,
}

impl Counter {
    /// A new counter; `name` must be unique across the catalog.
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Self { name, help, value: AtomicU64::new(0) }
    }

    /// Add one (no-op while recording is off).
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n` (no-op while recording is off).
    pub fn add(&self, n: u64) {
        if recording() {
            self.value.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// The registered name.
    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// A last-write-wins gauge holding an `f64` (stored as bits).
#[derive(Debug)]
pub struct Gauge {
    name: &'static str,
    help: &'static str,
    bits: AtomicU64,
}

impl Gauge {
    /// A new gauge; `name` must be unique across the catalog.
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Self { name, help, bits: AtomicU64::new(0) }
    }

    /// Set the gauge (no-op while recording is off).
    pub fn set(&self, v: f64) {
        if recording() {
            self.bits.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    /// The registered name.
    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// Bucket count for [`Histogram`]: slot 0 holds zero observations, slot
/// `i` in `1..=31` holds `2^(i-1) ..= 2^i - 1`, and the last slot is
/// the `+Inf` overflow.
pub const HIST_BUCKETS: usize = 33;

/// A histogram over `u64` observations with fixed log2 buckets — no
/// configuration, so every histogram in the catalog shares one bucket
/// layout and snapshots render without per-instrument schema.
#[derive(Debug)]
pub struct Histogram {
    name: &'static str,
    help: &'static str,
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    /// A new histogram; `name` must be unique across the catalog.
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        // A `const` item is the MSRV-stable way to array-repeat a
        // non-`Copy` zero (each array element gets its own atomic; the
        // const is never read back, so interior mutability is moot).
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Self {
            name,
            help,
            buckets: [ZERO; HIST_BUCKETS],
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// The log2 bucket slot an observation lands in.
    pub fn slot(v: u64) -> usize {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }

    /// The inclusive upper bound of slot `i`, or `None` for `+Inf`.
    pub fn upper_bound(i: usize) -> Option<u64> {
        if i + 1 < HIST_BUCKETS {
            Some((1u64 << i) - 1)
        } else {
            None
        }
    }

    /// Record one observation (no-op while recording is off).
    pub fn observe(&self, v: u64) {
        if recording() {
            self.buckets[Self::slot(v)].fetch_add(1, Ordering::Relaxed);
            self.sum.fetch_add(v, Ordering::Relaxed);
            self.count.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// The registered name.
    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// The registry-backed form of the `precision::stats` counters: a
/// thread-local cell (the delta-semantics view the PR 6/7 counter walls
/// read through `matrix_value_reads()` / `vector_element_moves()`) plus
/// a process-global total (the exposition view).  `add` bumps both and
/// is **not** gated on [`recording`] — the counter walls measure real
/// traffic deltas and must keep counting with no sink installed, and
/// the thread-local bump already dominates the cost.
#[derive(Debug)]
pub struct LocalCounter {
    name: &'static str,
    help: &'static str,
    cell: &'static LocalKey<Cell<u64>>,
    total: AtomicU64,
}

impl LocalCounter {
    /// A new local counter over the given thread-local cell.
    pub const fn new(
        name: &'static str,
        help: &'static str,
        cell: &'static LocalKey<Cell<u64>>,
    ) -> Self {
        Self { name, help, cell, total: AtomicU64::new(0) }
    }

    /// Add `n` to both the calling thread's cell and the global total.
    pub fn add(&self, n: u64) {
        self.cell.with(|c| c.set(c.get() + n));
        self.total.fetch_add(n, Ordering::Relaxed);
    }

    /// The calling thread's cumulative count (delta semantics: callers
    /// subtract two reads around the work they meter).
    pub fn local(&self) -> u64 {
        self.cell.with(Cell::get)
    }

    /// The process-wide cumulative count (what the exposition renders).
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// The registered name.
    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// A `'static` reference to any registered instrument.
#[derive(Debug, Clone, Copy)]
pub enum Metric {
    /// A [`Counter`].
    Counter(&'static Counter),
    /// A [`LocalCounter`] (rendered as a counter from its total).
    Local(&'static LocalCounter),
    /// A [`Gauge`].
    Gauge(&'static Gauge),
    /// A [`Histogram`].
    Histogram(&'static Histogram),
}

impl Metric {
    /// The instrument's registered name.
    pub fn name(&self) -> &'static str {
        match self {
            Metric::Counter(c) => c.name,
            Metric::Local(c) => c.name,
            Metric::Gauge(g) => g.name,
            Metric::Histogram(h) => h.name,
        }
    }

    /// The instrument's help line.
    pub fn help(&self) -> &'static str {
        match self {
            Metric::Counter(c) => c.help,
            Metric::Local(c) => c.help,
            Metric::Gauge(g) => g.help,
            Metric::Histogram(h) => h.help,
        }
    }
}

/// One instrument's value as read at snapshot time.
#[derive(Debug, Clone)]
pub enum SampleValue {
    /// Counter (or local-counter total) value.
    Counter(u64),
    /// Gauge value.
    Gauge(f64),
    /// Histogram buckets as `(upper_bound, cumulative_count)` pairs —
    /// the last entry is the `+Inf` bucket (`upper_bound == None`) —
    /// plus the sum and count.
    Histogram {
        /// Cumulative per-bucket counts.
        buckets: Vec<(Option<u64>, u64)>,
        /// Sum of observations.
        sum: u64,
        /// Number of observations.
        count: u64,
    },
}

/// One named sample in a [`Snapshot`].
#[derive(Debug, Clone)]
pub struct Sample {
    /// Instrument name.
    pub name: &'static str,
    /// Instrument help line.
    pub help: &'static str,
    /// The value read at snapshot time.
    pub value: SampleValue,
}

/// A point-in-time read of every registered instrument, sorted by name
/// so both renderers emit deterministic output.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    /// The samples, sorted by name.
    pub samples: Vec<Sample>,
}

impl Snapshot {
    /// Look up a sample by name.
    pub fn get(&self, name: &str) -> Option<&Sample> {
        self.samples.iter().find(|s| s.name == name)
    }

    /// A counter's value by name (0 when absent or not a counter) —
    /// the convenient form for before/after deltas in tests and
    /// `solve --profile`.
    pub fn counter(&self, name: &str) -> u64 {
        match self.get(name).map(|s| &s.value) {
            Some(SampleValue::Counter(v)) => *v,
            _ => 0,
        }
    }
}

/// The instrument registry: a name-keyed list of [`Metric`] references.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<Vec<Metric>>,
}

impl Registry {
    /// An empty registry (tests; production code uses [`global`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an instrument.  A duplicate name is ignored — the first
    /// registration wins, so re-registering the catalog is harmless.
    pub fn register(&self, m: Metric) {
        let mut v = self.metrics.lock().unwrap();
        if v.iter().all(|e| e.name() != m.name()) {
            v.push(m);
        }
    }

    /// Read every instrument into a name-sorted [`Snapshot`].
    pub fn snapshot(&self) -> Snapshot {
        let metrics = self.metrics.lock().unwrap();
        let mut samples: Vec<Sample> = metrics
            .iter()
            .map(|m| {
                let value = match m {
                    Metric::Counter(c) => SampleValue::Counter(c.get()),
                    Metric::Local(c) => SampleValue::Counter(c.total()),
                    Metric::Gauge(g) => SampleValue::Gauge(g.get()),
                    Metric::Histogram(h) => {
                        let mut cum = 0u64;
                        let buckets = (0..HIST_BUCKETS)
                            .map(|i| {
                                cum += h.buckets[i].load(Ordering::Relaxed);
                                (Histogram::upper_bound(i), cum)
                            })
                            .collect();
                        SampleValue::Histogram { buckets, sum: h.sum(), count: h.count() }
                    }
                };
                Sample { name: m.name(), help: m.help(), value }
            })
            .collect();
        samples.sort_by_key(|s| s.name);
        Snapshot { samples }
    }
}

/// The process-global registry, pre-loaded with the crate catalog
/// ([`crate::obs::catalog::all`]) on first use.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let r = Registry::new();
        for m in crate::obs::catalog::all() {
            r.register(m);
        }
        r
    })
}

/// A snapshot of the global registry — the input both `serve
/// --metrics-dump` and `solve --profile` render.
pub fn snapshot() -> Snapshot {
    global().snapshot()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_slots_and_bounds() {
        assert_eq!(Histogram::slot(0), 0);
        assert_eq!(Histogram::slot(1), 1);
        assert_eq!(Histogram::slot(2), 2);
        assert_eq!(Histogram::slot(3), 2);
        assert_eq!(Histogram::slot(4), 3);
        assert_eq!(Histogram::slot(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(Histogram::upper_bound(0), Some(0));
        assert_eq!(Histogram::upper_bound(1), Some(1));
        assert_eq!(Histogram::upper_bound(2), Some(3));
        assert_eq!(Histogram::upper_bound(HIST_BUCKETS - 1), None);
    }

    #[test]
    fn gated_instruments_are_inert_until_recording() {
        static C: Counter = Counter::new("test_gate_total", "gate test");
        // Tests share the process-global switch; force it off locally.
        let was = recording();
        set_recording(false);
        C.inc();
        assert_eq!(C.get(), 0, "counter must not move while recording is off");
        set_recording(true);
        C.add(3);
        assert_eq!(C.get(), 3);
        set_recording(was);
    }

    #[test]
    fn duplicate_registration_is_idempotent() {
        static C: Counter = Counter::new("test_dup_total", "dup test");
        let r = Registry::new();
        r.register(Metric::Counter(&C));
        r.register(Metric::Counter(&C));
        assert_eq!(r.snapshot().samples.len(), 1);
    }
}
