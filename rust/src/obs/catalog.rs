//! The crate's metric catalog: every instrument, declared in one place
//! and registered into [`crate::obs::registry::global`] on first use.
//!
//! Names follow Prometheus conventions — `callipepla_<family>_<what>`
//! with `_total` on counters — and the family prefix names the layer
//! that owns the site: `service` (scheduler + program cache), `coord`
//! (controller trips, retirements, block degrade ladder), `precision`
//! (value-plane traffic + escalations), `pool` (engine worker pool),
//! `program` (instruction bus), and `sim` (time-plane gauges).  The
//! full human-readable catalog lives in `docs/OBSERVABILITY.md`; a test
//! there is pinned against [`all`] so the doc and the code cannot
//! drift silently.

use std::cell::Cell;

use super::registry::{Counter, Gauge, Histogram, LocalCounter, Metric};

// ---------------- service family (scheduler + program cache) --------

/// Requests accepted by [`crate::service::SolverService::submit`].
pub static SERVICE_REQUESTS: Counter =
    Counter::new("callipepla_service_requests_total", "RHS requests accepted by the service");

/// Batches dispatched to the pool.
pub static SERVICE_BATCHES: Counter =
    Counter::new("callipepla_service_batches_total", "Batches dispatched to the engine pool");

/// Flushes forced by a full per-matrix queue.
pub static SERVICE_FLUSH_BATCH_FULL: Counter = Counter::new(
    "callipepla_service_flush_batch_full_total",
    "Dispatches triggered by a full per-matrix batch",
);

/// Flushes from an explicit `flush`/`drain`.
pub static SERVICE_FLUSH_DRAINED: Counter = Counter::new(
    "callipepla_service_flush_queue_drained_total",
    "Dispatches triggered by an explicit flush or drain",
);

/// Flushes forced by the logical-clock deadline
/// ([`crate::service::ServiceConfig::deadline`]).
pub static SERVICE_FLUSH_DEADLINE: Counter = Counter::new(
    "callipepla_service_flush_deadline_total",
    "Dispatches triggered by the submissions-since-join deadline",
);

/// Submissions rejected before joining a queue (backpressure, tenant
/// quota, unknown/foreign id, wrong-length RHS).
pub static SERVICE_SUBMIT_REJECTED: Counter = Counter::new(
    "callipepla_service_submit_rejected_total",
    "Submissions rejected by validation, backpressure, or tenant quota",
);

/// Batches whose solve panicked (tickets failed, worker recovered).
pub static SERVICE_BATCH_PANICS: Counter = Counter::new(
    "callipepla_service_batch_panics_total",
    "Batches failed by a panic in the solve (tickets err, pool recovers)",
);

/// Lanes per dispatched batch.
pub static SERVICE_COALESCE_WIDTH: Histogram = Histogram::new(
    "callipepla_service_coalesce_width_lanes",
    "Lanes coalesced into each dispatched batch",
);

/// Logical queue wait per lane: submissions **to the lane's own
/// matrix** accepted between its submit and its dispatch (a per-matrix
/// logical clock, never wall time — deterministic across replays, and
/// a lane on an idle matrix no longer inherits inflated wait from
/// other matrices' traffic).
pub static SERVICE_QUEUE_WAIT: Histogram = Histogram::new(
    "callipepla_service_queue_wait_submissions",
    "Same-matrix submissions accepted between a request's submit and its dispatch",
);

/// Batched-program cache hits ([`crate::program::ProgramCache`]).
pub static SERVICE_CACHE_HITS: Counter =
    Counter::new("callipepla_service_program_cache_hits_total", "Program cache hits");

/// Batched-program cache misses (compiles).
pub static SERVICE_CACHE_MISSES: Counter = Counter::new(
    "callipepla_service_program_cache_misses_total",
    "Program cache misses (programs compiled)",
);

/// Compiled programs dropped by
/// [`ProgramCache::evict_bucket`](crate::program::ProgramCache::evict_bucket)
/// when a bucket's last resident matrix was evicted.
pub static SERVICE_CACHE_EVICTIONS: Counter = Counter::new(
    "callipepla_service_program_cache_evictions_total",
    "Compiled programs dropped with their bucket's last resident matrix",
);

/// Registry evictions (derived solve state dropped under the capacity
/// budget; the host matrix is retained).
pub static SERVICE_REGISTRY_EVICTIONS: Counter = Counter::new(
    "callipepla_service_registry_evictions_total",
    "Matrix entries evicted from the registry's resident set",
);

/// Registry readmissions (derived state rebuilt on demand — bitwise
/// identical to the evicted state).
pub static SERVICE_REGISTRY_READMISSIONS: Counter = Counter::new(
    "callipepla_service_registry_readmissions_total",
    "Matrix entries re-derived on demand after eviction",
);

/// HTTP requests handled by the front door (every status).
pub static SERVICE_HTTP_REQUESTS: Counter = Counter::new(
    "callipepla_service_http_requests_total",
    "HTTP requests handled by the serve front door",
);

// ---------------- coordinator family --------------------------------

/// Merged-init trips issued (per lane; both dispatch paths).
pub static COORD_TRIPS_INIT: Counter =
    Counter::new("callipepla_coord_init_trips_total", "Merged-init trips issued");

/// Phase-1 (SpMV) trips issued.
pub static COORD_TRIPS_PHASE1: Counter =
    Counter::new("callipepla_coord_phase1_trips_total", "Phase-1 (SpMV) trips issued");

/// Phase-2 trips issued.
pub static COORD_TRIPS_PHASE2: Counter =
    Counter::new("callipepla_coord_phase2_trips_total", "Phase-2 trips issued");

/// Phase-3 trips issued.
pub static COORD_TRIPS_PHASE3: Counter =
    Counter::new("callipepla_coord_phase3_trips_total", "Phase-3 trips issued");

/// Converged-exit trips issued.
pub static COORD_TRIPS_EXIT: Counter =
    Counter::new("callipepla_coord_exit_trips_total", "Converged-exit trips issued");

/// Lanes retired converged (at init or via the exit trip).
pub static COORD_LANES_CONVERGED: Counter =
    Counter::new("callipepla_coord_lanes_converged_total", "Lanes retired converged");

/// Lanes retired at the iteration cap.
pub static COORD_LANES_CAPPED: Counter = Counter::new(
    "callipepla_coord_lanes_iteration_capped_total",
    "Lanes retired at the iteration cap",
);

/// Chunks that entered resident block mode.
pub static COORD_BLOCK_RESIDENT_CHUNKS: Counter = Counter::new(
    "callipepla_coord_block_resident_chunks_total",
    "Chunks that entered resident block mode",
);

/// Resident requests degraded to the staged pass (backend lacks the
/// block vector ops; its batch SpMV may still serve).
pub static COORD_BLOCK_DEGRADE_STAGED: Counter = Counter::new(
    "callipepla_coord_block_degrade_to_staged_total",
    "Resident requests degraded to the staged block pass",
);

/// Block mode dropped to per-lane SpMV (batch kernel declined).
pub static COORD_BLOCK_DEGRADE_PER_LANE: Counter = Counter::new(
    "callipepla_coord_block_degrade_to_per_lane_total",
    "Block mode dropped to per-lane SpMV (batch kernel declined)",
);

/// Lanes gathered out of the resident arenas mid-solve.
pub static COORD_BLOCK_GATHER_OUT_LANES: Counter = Counter::new(
    "callipepla_coord_block_gather_out_lanes_total",
    "Lanes gathered out of the resident arenas mid-solve",
);

// ---------------- precision family ----------------------------------

thread_local! {
    static MATRIX_VALUE_READS_CELL: Cell<u64> = const { Cell::new(0) };
    static VECTOR_ELEMENT_MOVES_CELL: Cell<u64> = const { Cell::new(0) };
}

/// Matrix values decoded by the value plane (the PR 6 counter wall;
/// `precision::stats::matrix_value_reads` reads the thread-local view).
pub static PRECISION_MATRIX_VALUE_READS: LocalCounter = LocalCounter::new(
    "callipepla_precision_matrix_value_reads_total",
    "Matrix values decoded by the value plane",
    &MATRIX_VALUE_READS_CELL,
);

/// Vector elements moved across the block boundary (the PR 7 wall;
/// `precision::stats::vector_element_moves` reads the thread-local
/// view).
pub static PRECISION_VECTOR_ELEMENT_MOVES: LocalCounter = LocalCounter::new(
    "callipepla_precision_vector_element_moves_total",
    "Vector elements moved across the block boundary",
    &VECTOR_ELEMENT_MOVES_CELL,
);

/// Adaptive-precision escalations committed by the controller.
pub static PRECISION_ESCALATIONS: Counter = Counter::new(
    "callipepla_precision_escalations_total",
    "Adaptive-precision escalations committed by the controller",
);

// ---------------- pool family ---------------------------------------

/// One-shot jobs run by pool workers ([`crate::engine::WorkerPool`]).
pub static POOL_JOBS: Counter =
    Counter::new("callipepla_pool_jobs_total", "One-shot jobs run by pool workers");

/// Non-empty scoped runs (`run_scoped*` / `run_scoped_indexed`).
pub static POOL_SCOPED_FANOUTS: Counter =
    Counter::new("callipepla_pool_scoped_fanouts_total", "Scoped-run fan-outs through the pool");

/// Panics caught by a worker (the worker survives; scoped panics
/// re-raise at the caller after the scope drains).
pub static POOL_PANICS_RECOVERED: Counter =
    Counter::new("callipepla_pool_panics_recovered_total", "Panics caught by pool workers");

// ---------------- program family (instruction bus) ------------------

/// Compiled trips issued on an instruction bus (dispatch and
/// bookkeeping-only resident issues both count — same wire format).
pub static PROGRAM_TRIPS_ISSUED: Counter =
    Counter::new("callipepla_program_trips_issued_total", "Compiled trips issued on a bus");

/// Type-III write-back acknowledgements collected (§4.2 handshake).
pub static PROGRAM_WRITE_ACKS: Counter =
    Counter::new("callipepla_program_write_acks_total", "Type-III write-back acks collected");

// ---------------- sim family (time plane) ---------------------------

/// Modeled accelerator cycles for the service's replayed trace
/// ([`crate::service::ServiceStats::modeled_cycles`]).
pub static SIM_MODELED_TRACE_CYCLES: Gauge = Gauge::new(
    "callipepla_sim_modeled_trace_cycles",
    "Modeled accelerator cycles for the replayed trace",
);

/// Modeled RHS-iteration throughput of the replayed trace.
pub static SIM_MODELED_RHS_ITERS_PER_SECOND: Gauge = Gauge::new(
    "callipepla_sim_modeled_rhs_iters_per_second",
    "Modeled RHS iterations per second for the replayed trace",
);

/// Every instrument in the crate, in declaration order.  This is what
/// [`crate::obs::registry::global`] registers; keep it in sync with the
/// statics above (the `catalog_covers_every_family` test counts it).
pub fn all() -> Vec<Metric> {
    vec![
        Metric::Counter(&SERVICE_REQUESTS),
        Metric::Counter(&SERVICE_BATCHES),
        Metric::Counter(&SERVICE_FLUSH_BATCH_FULL),
        Metric::Counter(&SERVICE_FLUSH_DRAINED),
        Metric::Counter(&SERVICE_FLUSH_DEADLINE),
        Metric::Counter(&SERVICE_SUBMIT_REJECTED),
        Metric::Counter(&SERVICE_BATCH_PANICS),
        Metric::Histogram(&SERVICE_COALESCE_WIDTH),
        Metric::Histogram(&SERVICE_QUEUE_WAIT),
        Metric::Counter(&SERVICE_CACHE_HITS),
        Metric::Counter(&SERVICE_CACHE_MISSES),
        Metric::Counter(&SERVICE_CACHE_EVICTIONS),
        Metric::Counter(&SERVICE_REGISTRY_EVICTIONS),
        Metric::Counter(&SERVICE_REGISTRY_READMISSIONS),
        Metric::Counter(&SERVICE_HTTP_REQUESTS),
        Metric::Counter(&COORD_TRIPS_INIT),
        Metric::Counter(&COORD_TRIPS_PHASE1),
        Metric::Counter(&COORD_TRIPS_PHASE2),
        Metric::Counter(&COORD_TRIPS_PHASE3),
        Metric::Counter(&COORD_TRIPS_EXIT),
        Metric::Counter(&COORD_LANES_CONVERGED),
        Metric::Counter(&COORD_LANES_CAPPED),
        Metric::Counter(&COORD_BLOCK_RESIDENT_CHUNKS),
        Metric::Counter(&COORD_BLOCK_DEGRADE_STAGED),
        Metric::Counter(&COORD_BLOCK_DEGRADE_PER_LANE),
        Metric::Counter(&COORD_BLOCK_GATHER_OUT_LANES),
        Metric::Local(&PRECISION_MATRIX_VALUE_READS),
        Metric::Local(&PRECISION_VECTOR_ELEMENT_MOVES),
        Metric::Counter(&PRECISION_ESCALATIONS),
        Metric::Counter(&POOL_JOBS),
        Metric::Counter(&POOL_SCOPED_FANOUTS),
        Metric::Counter(&POOL_PANICS_RECOVERED),
        Metric::Counter(&PROGRAM_TRIPS_ISSUED),
        Metric::Counter(&PROGRAM_WRITE_ACKS),
        Metric::Gauge(&SIM_MODELED_TRACE_CYCLES),
        Metric::Gauge(&SIM_MODELED_RHS_ITERS_PER_SECOND),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn catalog_names_are_unique_and_cover_every_family() {
        let metrics = all();
        let names: BTreeSet<&str> = metrics.iter().map(|m| m.name()).collect();
        assert_eq!(names.len(), metrics.len(), "duplicate metric name in the catalog");
        for family in ["service", "coord", "precision", "pool", "program", "sim"] {
            let prefix = format!("callipepla_{family}_");
            assert!(
                names.iter().any(|n| n.starts_with(&prefix)),
                "catalog is missing the {family} family"
            );
        }
        for m in &metrics {
            assert!(m.name().starts_with("callipepla_"), "{} lacks the crate prefix", m.name());
            assert!(!m.help().is_empty(), "{} lacks a help line", m.name());
        }
    }

    #[test]
    fn local_counters_track_both_views() {
        let before_local = PRECISION_MATRIX_VALUE_READS.local();
        let before_total = PRECISION_MATRIX_VALUE_READS.total();
        PRECISION_MATRIX_VALUE_READS.add(7);
        assert_eq!(PRECISION_MATRIX_VALUE_READS.local() - before_local, 7);
        assert!(PRECISION_MATRIX_VALUE_READS.total() - before_total >= 7);
    }
}
