//! The deterministic event trace: structured events stamped with
//! *logical* clocks — pass index, trip index, scheduler flush sequence
//! — and never wall time, thread ids, or pointers.
//!
//! That stamping rule is the whole design: two replays of the same
//! request trace produce byte-identical rendered logs
//! (`tests/observability.rs` pins this, the way the counter walls pin
//! traffic), and a genuine schedule change — a different flush order, a
//! different coalescing — shows up as a textual diff.  Completion
//! events arrive from pool workers in nondeterministic order, so
//! [`EventLog::render`] canonicalizes: events sort by `(seq, kind
//! rank, lane)` before rendering.  The rendered order is canonical,
//! not causal — it is a comparison key, not a timeline.
//!
//! ```
//! use callipepla::obs::{Event, EventKind, EventLog, FlushReason};
//! let mut log = EventLog::default();
//! log.push(Event {
//!     seq: 0,
//!     lane: 0,
//!     kind: EventKind::Flush { matrix: 0, lanes: 4, reason: FlushReason::BatchFull },
//! });
//! log.push(Event { seq: 0, lane: 0, kind: EventKind::Submit { matrix: 0, tenant: 3 } });
//! // Submit ranks ahead of Flush at equal seq, whatever the push order.
//! assert!(log.render().starts_with("submit"));
//! ```

use std::sync::Mutex;

use crate::solver::SolveResult;

/// Why the scheduler cut a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// A per-matrix pending group reached `max_batch` lanes.
    BatchFull,
    /// An explicit `flush`/`drain` swept the queues.
    QueueDrained,
    /// The group's oldest lane aged past the deadline threshold —
    /// measured on the *submission-count* logical clock
    /// ([`ServiceConfig::deadline`](crate::service::ServiceConfig::deadline)),
    /// never wall time, so deadline cuts replay byte-identically.
    Deadline,
}

impl FlushReason {
    /// Stable label used in rendered logs and metric docs.
    pub fn name(&self) -> &'static str {
        match self {
            FlushReason::BatchFull => "batch-full",
            FlushReason::QueueDrained => "queue-drained",
            FlushReason::Deadline => "deadline",
        }
    }
}

/// What happened.  Service-side kinds (`Submit`/`Flush`/`BatchDone`)
/// are stamped with scheduler clocks; per-solve kinds
/// (`Pass`/`LaneDone`) with pass-index clocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A request joined its matrix's pending group.  `seq` is the
    /// submission index (requests accepted so far).
    Submit {
        /// Registry slot of the matrix.
        matrix: usize,
        /// The submitting tenant.
        tenant: u32,
    },
    /// The scheduler cut a batch.  `seq` is the flush sequence.
    Flush {
        /// Registry slot of the matrix.
        matrix: usize,
        /// Lanes coalesced into the batch.
        lanes: u32,
        /// What triggered the cut.
        reason: FlushReason,
    },
    /// A dispatched batch finished.  `seq` is the flush sequence of its
    /// dispatch — the clock that makes completions comparable even
    /// though workers finish in nondeterministic order.
    BatchDone {
        /// Registry slot of the matrix.
        matrix: usize,
        /// Lanes the batch carried.
        lanes: u32,
        /// RHS-iterations the batch retired.
        rhs_iters: u64,
    },
    /// One matrix pass of one lane's solve.  `seq` is the pass index.
    Pass {
        /// The precision scheme the pass streamed under.
        scheme: &'static str,
    },
    /// A lane's solve finished.  `seq` is the final pass index.
    LaneDone {
        /// Main-loop iterations executed.
        iters: u32,
        /// Whether rr reached the threshold.
        converged: bool,
        /// Bit pattern of the final rr — bitwise, not approximate, so
        /// a seq-vs-parallel pair must agree exactly.
        rr_bits: u64,
    },
}

impl EventKind {
    /// Tie-break rank at equal `seq` (stable across kinds that share a
    /// clock domain: submit before flush, pass before lane-done).
    fn rank(&self) -> u8 {
        match self {
            EventKind::Submit { .. } | EventKind::Pass { .. } => 0,
            EventKind::Flush { .. } | EventKind::LaneDone { .. } => 1,
            EventKind::BatchDone { .. } => 2,
        }
    }
}

/// One logged event: a logical-clock stamp plus its [`EventKind`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Primary logical clock (submission index, flush sequence, or
    /// pass index — see the kind's docs).
    pub seq: u64,
    /// Secondary clock: the lane index (0 for service-wide events).
    pub lane: u32,
    /// What happened.
    pub kind: EventKind,
}

impl Event {
    fn render_line(&self, out: &mut String) {
        use std::fmt::Write;
        match self.kind {
            EventKind::Submit { matrix, tenant } => {
                let _ = writeln!(out, "submit seq={} matrix=A{matrix} tenant={tenant}", self.seq);
            }
            EventKind::Flush { matrix, lanes, reason } => {
                let _ = writeln!(
                    out,
                    "flush seq={} matrix=A{matrix} lanes={lanes} reason={}",
                    self.seq,
                    reason.name()
                );
            }
            EventKind::BatchDone { matrix, lanes, rhs_iters } => {
                let _ = writeln!(
                    out,
                    "done seq={} matrix=A{matrix} lanes={lanes} rhs_iters={rhs_iters}",
                    self.seq
                );
            }
            EventKind::Pass { scheme } => {
                let _ = writeln!(out, "pass seq={} lane={} scheme={scheme}", self.seq, self.lane);
            }
            EventKind::LaneDone { iters, converged, rr_bits } => {
                let _ = writeln!(
                    out,
                    "lane_done seq={} lane={} iters={iters} converged={converged} \
                     rr=0x{rr_bits:016x}",
                    self.seq,
                    self.lane
                );
            }
        }
    }
}

/// An append-only log of [`Event`]s with a canonical byte-stable
/// rendering.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    events: Vec<Event>,
}

impl EventLog {
    /// Append one event.
    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    /// The events in insertion order.
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Number of events logged.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether the log is empty.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The canonical text form: one line per event, sorted by
    /// `(seq, kind rank, lane)`.  Two runs of the same schedule render
    /// byte-identically; any schedule difference renders differently.
    pub fn render(&self) -> String {
        let mut order: Vec<&Event> = self.events.iter().collect();
        order.sort_by_key(|e| (e.seq, e.kind.rank(), e.lane));
        let mut out = String::new();
        for e in order {
            e.render_line(&mut out);
        }
        out
    }

    /// The value-plane event log of a finished batch: per-lane `pass`
    /// events (passes `0..=iters`, the [`PrecisionTrace`] pass
    /// convention of `modeled_m1_bytes`) and a closing `lane_done`
    /// carrying the bit pattern of the final rr.  Bitwise-equal result
    /// sets — e.g. a sequential and a lane-parallel run of the same
    /// batch — therefore produce byte-identical logs.
    ///
    /// [`PrecisionTrace`]: crate::precision::PrecisionTrace
    pub fn from_solves(results: &[SolveResult]) -> Self {
        let mut log = EventLog::default();
        for (k, r) in results.iter().enumerate() {
            for pass in 0..=r.iters {
                log.push(Event {
                    seq: pass as u64,
                    lane: k as u32,
                    kind: EventKind::Pass { scheme: r.precision.scheme_at(pass).name() },
                });
            }
            log.push(Event {
                seq: r.iters as u64,
                lane: k as u32,
                kind: EventKind::LaneDone {
                    iters: r.iters,
                    converged: r.converged,
                    rr_bits: r.final_rr.to_bits(),
                },
            });
        }
        log
    }
}

/// First line (1-based) where two rendered logs differ — `None` when
/// byte-identical.  A missing line (one log is a prefix of the other)
/// counts as a difference at the first absent line.
pub fn first_divergence(a: &str, b: &str) -> Option<usize> {
    let mut la = a.lines();
    let mut lb = b.lines();
    let mut n = 0;
    loop {
        n += 1;
        match (la.next(), lb.next()) {
            (None, None) => return None,
            (x, y) if x == y => {}
            _ => return Some(n),
        }
    }
}

/// A shared, thread-safe event sink the service writes through.
/// Install one with [`crate::service::SolverService::record_events`];
/// the scheduler pushes `submit`/`flush` events from the caller thread
/// and `done` events from pool workers (stamped with the dispatch's
/// flush sequence, so rendering stays canonical).
#[derive(Debug, Default)]
pub struct EventSink {
    log: Mutex<EventLog>,
}

impl EventSink {
    /// Append one event.
    pub fn push(&self, e: Event) {
        self.log.lock().expect("event sink poisoned").push(e);
    }

    /// Render the canonical text form of everything logged so far.
    pub fn render(&self) -> String {
        self.log.lock().expect("event sink poisoned").render()
    }

    /// Take the log, leaving the sink empty.
    pub fn take(&self) -> EventLog {
        std::mem::take(&mut *self.log.lock().expect("event sink poisoned"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_is_insertion_order_independent() {
        let a = Event { seq: 0, lane: 0, kind: EventKind::Submit { matrix: 0, tenant: 1 } };
        let b = Event {
            seq: 0,
            lane: 0,
            kind: EventKind::Flush { matrix: 0, lanes: 2, reason: FlushReason::BatchFull },
        };
        let c = Event {
            seq: 0,
            lane: 0,
            kind: EventKind::BatchDone { matrix: 0, lanes: 2, rhs_iters: 7 },
        };
        let mut fwd = EventLog::default();
        let mut rev = EventLog::default();
        for e in [a, b, c] {
            fwd.push(e);
        }
        for e in [c, b, a] {
            rev.push(e);
        }
        assert_eq!(fwd.render(), rev.render());
        assert_eq!(first_divergence(&fwd.render(), &rev.render()), None);
    }

    #[test]
    fn divergence_points_at_the_first_differing_line() {
        let a = "x\ny\nz\n";
        let b = "x\nY\nz\n";
        assert_eq!(first_divergence(a, b), Some(2));
        assert_eq!(first_divergence(a, "x\ny\n"), Some(3));
        assert_eq!(first_divergence(a, a), None);
    }
}
