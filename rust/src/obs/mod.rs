//! The telemetry plane (PR 9): one dependency-free observability
//! subsystem for the whole solver stack.
//!
//! Three layers:
//!
//! 1. **[`registry`]** — atomic instruments (counters, gauges,
//!    fixed-log2-bucket histograms, and the thread-local-backed
//!    [`LocalCounter`] that absorbed `precision::stats`) registered by
//!    static name in [`catalog`].  With recording off (the default)
//!    every gated instrument is one relaxed load and a branch, which is
//!    what keeps the instrumented hot paths inside the <2% bench gate.
//! 2. **[`trace`]** — the deterministic event log: structured events
//!    stamped with logical clocks (pass index, flush sequence — never
//!    wall time), byte-identical across replays of the same schedule.
//! 3. **[`expo`]** — Prometheus-text and JSON renderers over one
//!    registry [`Snapshot`], wired into `serve --metrics-dump`,
//!    `serve --stats-json`, and `solve --profile`.
//!
//! The metric catalog, clock rules, and exposition formats are
//! documented in `docs/OBSERVABILITY.md`.

pub mod catalog;
pub mod expo;
pub mod registry;
pub mod trace;

pub use expo::{render_json, render_prometheus, PROMETHEUS_CONTENT_TYPE};
pub use registry::{
    global, recording, set_recording, snapshot, Counter, Gauge, Histogram, LocalCounter, Metric,
    Registry, Sample, SampleValue, Snapshot,
};
pub use trace::{first_divergence, Event, EventKind, EventLog, EventSink, FlushReason};

/// Prometheus text for the global registry — the `serve --metrics-dump`
/// body.
pub fn prometheus_dump() -> String {
    render_prometheus(&snapshot())
}
