//! Vector Streaming Reuse (VSR) and the three-phase schedule (paper §5).
//!
//! VSR is the paper's central data-flow idea: a vector produced by one
//! processing module can be *streamed* into the next module through an
//! on-chip FIFO instead of bouncing off HBM — but only when no scalar
//! dependency forces the consumer to wait for the *whole* vector.  This
//! module encodes:
//!
//! * the JPCG data-flow graph (producers/consumers of every vector and
//!   scalar per Algorithm-1 line),
//! * the legality rules of §5.1 (when can / cannot VSR),
//! * the resulting three-phase partition (Fig. 5) with its per-phase
//!   reuse edges and memory accesses (§5.4),
//! * the access-count accounting of §5.5 (19 accesses centralized vs
//!   14 decentralized), and
//! * the FIFO-depth deadlock rule of §5.6.

use std::collections::BTreeSet;

/// The named long vectors of Algorithm 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Vector {
    /// Search direction p.
    P,
    /// SpMV product ap = A p.
    Ap,
    /// Residual r.
    R,
    /// Preconditioned residual z = M^-1 r (on-chip only, §5.3).
    Z,
    /// Solution iterate x.
    X,
    /// The Jacobi diagonal M (read-only).
    M,
}

impl Vector {
    /// Every Algorithm-1 vector.
    pub const ALL: [Vector; 6] = [
        Vector::P,
        Vector::Ap,
        Vector::R,
        Vector::Z,
        Vector::X,
        Vector::M,
    ];

    /// Short lowercase id used in traces and dumps.
    pub fn name(self) -> &'static str {
        match self {
            Vector::P => "p",
            Vector::Ap => "ap",
            Vector::R => "r",
            Vector::Z => "z",
            Vector::X => "x",
            Vector::M => "M",
        }
    }
}

/// The eight computation modules (Fig. 1 / §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Module {
    /// SpMV: ap = A p (line 7)
    M1,
    /// dot alpha: pap = p . ap (line 8)
    M2,
    /// update x: x += alpha p (line 9)
    M3,
    /// update r: r -= alpha ap (line 10)
    M4,
    /// left divide: z = M^-1 r (line 11)
    M5,
    /// dot rz (line 12)
    M6,
    /// update p: p = z + beta p (line 13)
    M7,
    /// dot rr (line 15)
    M8,
}

impl Module {
    /// Every computation module, in Fig. 1 order.
    pub const ALL: [Module; 8] = [
        Module::M1,
        Module::M2,
        Module::M3,
        Module::M4,
        Module::M5,
        Module::M6,
        Module::M7,
        Module::M8,
    ];

    /// Long descriptive id ("M5:left-divide" style).
    pub fn name(self) -> &'static str {
        match self {
            Module::M1 => "M1:spmv",
            Module::M2 => "M2:dot-alpha",
            Module::M3 => "M3:update-x",
            Module::M4 => "M4:update-r",
            Module::M5 => "M5:left-divide",
            Module::M6 => "M6:dot-rz",
            Module::M7 => "M7:update-p",
            Module::M8 => "M8:dot-rr",
        }
    }

    /// Vectors this module consumes / produces, and whether it reduces
    /// to a scalar (dot modules): the raw data-flow facts of Alg. 1.
    pub fn io(self) -> ModuleIo {
        use Vector::*;
        match self {
            Module::M1 => ModuleIo::new(&[P], &[Ap], false),
            Module::M2 => ModuleIo::new(&[P, Ap], &[], true),
            Module::M3 => ModuleIo::new(&[X, P], &[X], false),
            Module::M4 => ModuleIo::new(&[R, Ap], &[R], false),
            Module::M5 => ModuleIo::new(&[R, M], &[Z], false),
            Module::M6 => ModuleIo::new(&[R, Z], &[], true),
            Module::M7 => ModuleIo::new(&[Z, P], &[P], false),
            Module::M8 => ModuleIo::new(&[R], &[], true),
        }
    }
}

/// Data-flow signature of a module.
#[derive(Debug, Clone)]
pub struct ModuleIo {
    /// Vectors streamed in.
    pub consumes: Vec<Vector>,
    /// Vectors streamed out.
    pub produces: Vec<Vector>,
    /// Scalar-reducing module: its output depends on the *whole* input
    /// vector, which is exactly the VSR-blocking condition of §5.1.
    pub reduces_to_scalar: bool,
}

impl ModuleIo {
    fn new(c: &[Vector], p: &[Vector], s: bool) -> Self {
        Self { consumes: c.to_vec(), produces: p.to_vec(), reduces_to_scalar: s }
    }
}

/// The three phases of Fig. 5.  Phase-1 splits into 1.1 (M1) and 1.2
/// (M2) in the paper; we keep them as ordered stages within phase 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// M1 SpMV then M2 dot (Fig. 5 stages 1.1 / 1.2).
    Phase1,
    /// The consume-and-send chain M4 -> M5 -> M6 -> M8.
    Phase2,
    /// M4/M5 rerun (z recompute) feeding M7 and M3.
    Phase3,
}

/// Why two modules cannot share a stream (§5.1 "when can not VSR").
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VsrBlock {
    /// Consumer depends on a scalar computed from the producer's whole
    /// output (e.g. M4 needs alpha = f(whole ap)).
    ScalarDependency { scalar: &'static str },
    /// Producer emits only after consuming its whole input (SpMV),
    /// so the input vector cannot be forwarded.
    FullConsumption,
    /// Index skew exceeds the FIFO budget.
    IndexSkew { skew: usize, budget: usize },
}

/// VSR legality between a producer stream and a consumer module, given
/// the scalar dependencies of Alg. 1 (§5.2's analysis, mechanized).
pub fn can_vsr(
    producer: Module,
    consumer: Module,
    fifo_budget: usize,
    skew: usize,
) -> Result<(), VsrBlock> {
    // Rule 3 (§5.1): index skew must fit in the FIFO budget.
    if skew > fifo_budget {
        return Err(VsrBlock::IndexSkew { skew, budget: fifo_budget });
    }
    use Module::*;
    match (producer, consumer) {
        // M2 produces pap -> alpha; M3/M4 consume alpha. Anything
        // streamed from before M2's completion into M3/M4 is illegal
        // within the same phase (rule 1).
        (M1, M4) | (M1, M3) | (M2, M4) | (M2, M3) => {
            Err(VsrBlock::ScalarDependency { scalar: "alpha" })
        }
        // M6 produces rz_new -> beta; M7 consumes beta (rule 1).
        (M5, M7) | (M6, M7) => Err(VsrBlock::ScalarDependency { scalar: "beta" }),
        // M1 (SpMV) only emits ap after consuming all of p: p cannot be
        // forwarded through M1 to M2 (§5.4 Phase-1 discussion, rule 2).
        (M1, M2) => Err(VsrBlock::FullConsumption),
        _ => Ok(()),
    }
}

/// Legality of one *compiled* reuse edge (`crate::program`), given which
/// controller scalars are already bound when the trip starts.  This is
/// §5.2 mechanized for a schedule rather than for a module pair in
/// isolation: [`can_vsr`]'s raw verdict is waived when
///
/// * the blocking scalar is bound before the phase begins — the Fig. 5
///   phase split exists precisely to create these bindings (beta is
///   known by Phase-3 because M6 ran in Phase-2; the merged-init trip
///   pre-binds alpha = 1 and beta = 0), or
/// * the forwarded vector is the producer's own *output* — rule 2
///   (full consumption) only forbids forwarding such a producer's
///   input stream onward (p through M1), never the stream it emits
///   (ap out of M1).
pub fn edge_legal(
    producer: Module,
    consumer: Module,
    vector: Vector,
    fifo_budget: usize,
    skew: usize,
    bound_scalars: &[&str],
) -> Result<(), VsrBlock> {
    match can_vsr(producer, consumer, fifo_budget, skew) {
        Err(VsrBlock::ScalarDependency { scalar }) if bound_scalars.contains(&scalar) => Ok(()),
        Err(VsrBlock::FullConsumption) if producer.io().produces.contains(&vector) => Ok(()),
        other => other,
    }
}

/// Phase assignment of Fig. 5.
pub fn phase_of(m: Module) -> Vec<Phase> {
    use Module::*;
    match m {
        M1 | M2 => vec![Phase::Phase1],
        // M4 and M5 run in Phase-2 *and* rerun in Phase-3 to recompute z
        // (§5.3 recompute-to-save-memory).
        M4 | M5 => vec![Phase::Phase2, Phase::Phase3],
        M6 | M8 => vec![Phase::Phase2],
        M7 | M3 => vec![Phase::Phase3],
    }
}

/// One vector's memory activity within a phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Access {
    /// The vector accessed.
    pub vector: Vector,
    /// Streamed in from HBM.
    pub read: bool,
    /// Written back to HBM.
    pub write: bool,
}

/// The per-phase off-chip accesses of §5.4 *with* VSR + decentralized
/// scheduling (10 reads + 4 writes = 14).
pub fn accesses_with_vsr() -> Vec<(Phase, Vec<Access>)> {
    use Vector::*;
    let a = |vector, read, write| Access { vector, read, write };
    vec![
        // Phase 1: read p for M1 (the nnz stream is charged separately),
        // read p again for M2, write ap. ap reused on-chip M1->M2.
        (Phase::Phase1, vec![a(P, true, false), a(P, true, false), a(Ap, false, true)]),
        // Phase 2: read r once (consume-and-send chain M4->M5->M6->M8),
        // read M, read ap. Updated r stays on chip, z recomputed later.
        (Phase::Phase2, vec![a(R, true, false), a(M, true, false), a(Ap, true, false)]),
        // Phase 3: M4+M5 re-run (needs r, ap, M again), M7/M3 read p, x;
        // write back r, p, x. z recomputed on chip, never stored.
        (
            Phase::Phase3,
            vec![
                a(R, true, true),
                a(Ap, true, false),
                a(M, true, false),
                a(P, true, true),
                a(X, true, true),
            ],
        ),
    ]
}

/// Baseline accesses without decentralized VSR (§5.5: 14 reads + 5
/// writes = 19): every module reads its inputs from memory and every
/// produced vector is written back (z included).
pub fn accesses_without_vsr() -> Vec<(Phase, Vec<Access>)> {
    use Vector::*;
    let a = |vector, read, write| Access { vector, read, write };
    vec![
        // M1 reads p, writes ap; M2 reads p and ap back from memory.
        (
            Phase::Phase1,
            vec![a(P, true, false), a(P, true, false), a(Ap, true, true)],
        ),
        // M4 reads r + ap, writes r; M5 reads r + M, writes z; M6 reads
        // r + z; M8 reads r.  Every hop round-trips through HBM.
        (
            Phase::Phase2,
            vec![
                a(R, true, true),
                a(Ap, true, false),
                a(R, true, false),
                a(M, true, false),
                a(Z, false, true),
                a(R, true, false),
                a(Z, true, false),
                a(R, true, false),
            ],
        ),
        // M7 reads z + p, writes p; M3 reads p + x, writes x.
        (
            Phase::Phase3,
            vec![
                a(Z, true, false),
                a(P, true, true),
                a(P, true, false),
                a(X, true, true),
            ],
        ),
    ]
}

/// Count (reads, writes) in an access table.
pub fn count_accesses(table: &[(Phase, Vec<Access>)]) -> (usize, usize) {
    let mut r = 0;
    let mut w = 0;
    for (_, list) in table {
        for a in list {
            r += a.read as usize;
            w += a.write as usize;
        }
    }
    (r, w)
}

/// §5.6: minimum depth of the *fast* FIFO so that a module with pipeline
/// depth `l` consuming a slow and a fast stream cannot deadlock:
/// depth >= L + 1.
pub fn min_fast_fifo_depth(pipeline_depth: usize) -> usize {
    pipeline_depth + 1
}

/// Vectors that live purely on-chip under the Fig. 5 schedule (only z:
/// recomputed in Phase-3 instead of stored, §5.3) — saving one memory
/// channel pair.
pub fn onchip_only_vectors() -> BTreeSet<Vector> {
    let stored: BTreeSet<Vector> = accesses_with_vsr()
        .iter()
        .flat_map(|(_, l)| l.iter().map(|a| a.vector))
        .collect();
    Vector::ALL.iter().copied().filter(|v| !stored.contains(v)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn access_counts_match_paper_section_5_5() {
        let (r, w) = count_accesses(&accesses_with_vsr());
        assert_eq!((r, w), (10, 4), "decentralized VSR: 10 reads + 4 writes");
        let (r0, w0) = count_accesses(&accesses_without_vsr());
        assert_eq!((r0, w0), (14, 5), "centralized baseline: 14 reads + 5 writes");
    }

    #[test]
    fn z_is_the_only_onchip_vector() {
        let s = onchip_only_vectors();
        assert_eq!(s.len(), 1);
        assert!(s.contains(&Vector::Z));
    }

    #[test]
    fn scalar_dependencies_block_vsr() {
        // ap from M1 cannot stream to M4 (alpha dependency) — the exact
        // §5.2 example.
        assert_eq!(
            can_vsr(Module::M1, Module::M4, 1024, 0),
            Err(VsrBlock::ScalarDependency { scalar: "alpha" })
        );
        // beta blocks M5->M7 within one phase.
        assert_eq!(
            can_vsr(Module::M5, Module::M7, 1024, 0),
            Err(VsrBlock::ScalarDependency { scalar: "beta" })
        );
    }

    #[test]
    fn spmv_blocks_forwarding_p() {
        assert_eq!(can_vsr(Module::M1, Module::M2, 1024, 0), Err(VsrBlock::FullConsumption));
    }

    #[test]
    fn legal_reuse_chains_of_fig5() {
        // Phase-2 consume-and-send chain M4 -> M5 -> M6 -> M8 on r.
        assert!(can_vsr(Module::M4, Module::M5, 64, 1).is_ok());
        assert!(can_vsr(Module::M5, Module::M6, 64, 1).is_ok());
        assert!(can_vsr(Module::M6, Module::M8, 64, 1).is_ok());
        // Phase-3: M4 -> M5(recompute z) -> M7 is legal because beta is
        // already known when Phase-3 starts (M6 ran in Phase-2).
        assert!(can_vsr(Module::M4, Module::M7, 64, 1).is_ok());
        // Phase-3 p reuse M7 -> M3.
        assert!(can_vsr(Module::M7, Module::M3, 64, 1).is_ok());
    }

    #[test]
    fn edge_legal_waives_bound_scalars_and_output_forwarding() {
        // ap out of M1 into M2 is the stream M1 *produces*: legal even
        // though forwarding p through M1 is not.
        assert!(edge_legal(Module::M1, Module::M2, Vector::Ap, 64, 0, &[]).is_ok());
        // z M5 -> M7 is illegal while beta is unbound...
        assert!(edge_legal(Module::M5, Module::M7, Vector::Z, 64, 0, &[]).is_err());
        // ...and legal in Phase-3, where beta was bound in Phase-2.
        assert!(edge_legal(Module::M5, Module::M7, Vector::Z, 64, 0, &["alpha", "beta"]).is_ok());
        // Binding scalars never waives a FIFO overflow.
        assert!(edge_legal(Module::M4, Module::M5, Vector::R, 8, 16, &["alpha", "beta"]).is_err());
        // Forwarding p *through* M1 stays illegal: p is M1's input.
        assert!(edge_legal(Module::M1, Module::M2, Vector::P, 64, 0, &["alpha", "beta"]).is_err());
    }

    #[test]
    fn index_skew_beyond_budget_blocks() {
        assert_eq!(
            can_vsr(Module::M4, Module::M5, 16, 33),
            Err(VsrBlock::IndexSkew { skew: 33, budget: 16 })
        );
    }

    #[test]
    fn phases_match_fig5() {
        assert_eq!(phase_of(Module::M1), vec![Phase::Phase1]);
        assert_eq!(phase_of(Module::M2), vec![Phase::Phase1]);
        assert_eq!(phase_of(Module::M4), vec![Phase::Phase2, Phase::Phase3]);
        assert_eq!(phase_of(Module::M5), vec![Phase::Phase2, Phase::Phase3]);
        assert_eq!(phase_of(Module::M6), vec![Phase::Phase2]);
        assert_eq!(phase_of(Module::M8), vec![Phase::Phase2]);
        assert_eq!(phase_of(Module::M7), vec![Phase::Phase3]);
        assert_eq!(phase_of(Module::M3), vec![Phase::Phase3]);
    }

    #[test]
    fn fifo_depth_rule() {
        // Fig. 7: M5 pipeline depth L=33 needs fast FIFO >= 34.
        assert_eq!(min_fast_fifo_depth(33), 34);
    }

    #[test]
    fn module_io_covers_all_vectors() {
        let mut seen = BTreeSet::new();
        for m in Module::ALL {
            let io = m.io();
            seen.extend(io.consumes.iter().copied());
            seen.extend(io.produces.iter().copied());
        }
        assert_eq!(seen.len(), Vector::ALL.len());
    }
}
