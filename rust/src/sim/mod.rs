//! The time plane: a cycle-approximate discrete-event simulator of the
//! Callipepla accelerator (DESIGN.md §5).
//!
//! [`dataflow`] is a token-level FIFO/pipeline engine with the exact
//! stall semantics of an HLS dataflow design — a write to a full FIFO
//! freezes the whole pipeline, which is what makes the Fig. 7 deadlock
//! reproducible (and the §5.6 depth rule checkable).
//! [`Dataflow::from_program`] derives a phase graph from one trip of
//! the compiled instruction program (`crate::program`) — the same
//! Type-I/II/III steps the value plane executes — and [`iteration`]
//! runs those graphs to produce cycles-per-iteration for each
//! accelerator configuration (the no-VSR baseline keeps hand-built
//! per-module passes: it models the machine *without* the ISA
//! schedule).

pub mod dataflow;
pub mod iteration;

/// Export a modeled schedule's time-plane figures to the telemetry
/// plane ([`crate::obs`]): modeled cycles and RHS-iteration throughput
/// land on the `callipepla_sim_*` gauges, so `serve --metrics-dump`
/// shows the time plane next to the value-plane counters (both derive
/// from the same compiled program — the invariant this module exists
/// to keep).  No-op while recording is off, like every gauge.
pub fn export_modeled_gauges(cycles: u64, rhs_iters_per_second: f64) {
    crate::obs::catalog::SIM_MODELED_TRACE_CYCLES.set(cycles as f64);
    crate::obs::catalog::SIM_MODELED_RHS_ITERS_PER_SECOND.set(rhs_iters_per_second);
}

pub use dataflow::{Dataflow, FifoId, NodeId, SimError, SimStats};
pub use iteration::{
    batched_iteration_cycles, batched_iteration_cycles_mode, batched_rhs_iterations_per_second,
    iteration_cycles, lane_parallel_iteration_cycles, lane_parallel_rhs_iterations_per_second,
    schedule_cycles, solver_seconds, traced_solver_cycles, traced_solver_seconds, AccelSimConfig,
    BatchSpmvMode, IterationBreakdown, ScheduledBatch,
};
