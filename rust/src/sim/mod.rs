//! The time plane: a cycle-approximate discrete-event simulator of the
//! Callipepla accelerator (DESIGN.md §5).
//!
//! [`dataflow`] is a token-level FIFO/pipeline engine with the exact
//! stall semantics of an HLS dataflow design — a write to a full FIFO
//! freezes the whole pipeline, which is what makes the Fig. 7 deadlock
//! reproducible (and the §5.6 depth rule checkable).
//! [`Dataflow::from_program`] derives a phase graph from one trip of
//! the compiled instruction program (`crate::program`) — the same
//! Type-I/II/III steps the value plane executes — and [`iteration`]
//! runs those graphs to produce cycles-per-iteration for each
//! accelerator configuration (the no-VSR baseline keeps hand-built
//! per-module passes: it models the machine *without* the ISA
//! schedule).

pub mod dataflow;
pub mod iteration;

pub use dataflow::{Dataflow, FifoId, NodeId, SimError, SimStats};
pub use iteration::{
    batched_iteration_cycles, batched_iteration_cycles_mode, batched_rhs_iterations_per_second,
    iteration_cycles, lane_parallel_iteration_cycles, lane_parallel_rhs_iterations_per_second,
    schedule_cycles, solver_seconds, traced_solver_cycles, traced_solver_seconds, AccelSimConfig,
    BatchSpmvMode, IterationBreakdown, ScheduledBatch,
};
