//! The time plane: a cycle-approximate discrete-event simulator of the
//! Callipepla accelerator (DESIGN.md §5).
//!
//! [`dataflow`] is a token-level FIFO/pipeline engine with the exact
//! stall semantics of an HLS dataflow design — a write to a full FIFO
//! freezes the whole pipeline, which is what makes the Fig. 7 deadlock
//! reproducible (and the §5.6 depth rule checkable).  [`iteration`]
//! builds the Fig. 5 per-phase graphs on top of it and produces
//! cycles-per-iteration for each accelerator configuration.

pub mod dataflow;
pub mod iteration;

pub use dataflow::{Dataflow, FifoId, NodeId, SimError, SimStats};
pub use iteration::{iteration_cycles, solver_seconds, AccelSimConfig, IterationBreakdown};
