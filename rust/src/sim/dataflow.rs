//! Token-level dataflow simulator with HLS stall semantics.
//!
//! Tokens are 64-byte *beats* (8 f64 lanes), matching one channel beat
//! per cycle (§4.2 rate matching).  Nodes:
//!
//! * `MemRead` / `MemWrite` — one beat per cycle, arbitrated round-robin
//!   per HBM channel (two streams on one channel halve each other's
//!   rate — the single- vs double-channel effect of §5.7).
//! * `Pipe` — an II=1 processing pipeline of fixed depth with outputs
//!   tapped at given stages.  A blocked emission (full FIFO) freezes the
//!   *entire* pipeline: exactly the HLS behaviour behind the Fig. 7
//!   deadlock.
//! * `Dot` — consumes streams, emits nothing; finishes `tail` cycles
//!   after the last beat (the II=5 Phase-II fold of footnote 1).
//! * `Spmv` — consumes the x vector, stays busy for the scheduled nnz
//!   stream length, then streams the output vector.
//!
//! The engine detects deadlock as a cycle in which no node progressed
//! while work remains.
//!
//! §Perf (see PERF.md): `step` is allocation-free (the per-cycle
//! `order`/`channel_used` scratch of the original implementation is
//! gone / hoisted into the engine), and `run` *fast-forwards* through
//! stretches where the only possible progress is an `Spmv::busy_left`
//! or `Dot::tail_left` countdown: such cycles change no FIFO, so k of
//! them collapse into one bulk decrement.  Cycle counts, per-node
//! completion times and deadlock verdicts are bit-for-bit those of the
//! cycle-by-cycle run (asserted in the tests below); only wall-clock
//! changes — SpMV-dominated phase graphs simulate orders of magnitude
//! faster.

use std::collections::VecDeque;

/// Index of a FIFO in a [`Dataflow`] graph.
pub type FifoId = usize;
/// Index of a node in a [`Dataflow`] graph.
pub type NodeId = usize;

#[derive(Debug, Clone)]
struct Fifo {
    cap: usize,
    len: usize,
}

/// Stall-freeze pipeline: slot index == pipeline stage.
#[derive(Debug, Clone)]
struct PipeState {
    /// slots[s] == true: a token occupies stage s.
    slots: VecDeque<bool>,
    consumed: u64,
}

#[derive(Debug, Clone)]
enum NodeKind {
    MemRead { channel: usize, beats: u64, done: u64, out: FifoId },
    MemWrite { channel: usize, beats: u64, done: u64, input: FifoId },
    Pipe {
        ins: Vec<FifoId>,
        /// (stage, fifo) output taps; stage < depth.
        outs: Vec<(usize, FifoId)>,
        depth: usize,
        expect: u64,
        state: PipeState,
    },
    Dot { ins: Vec<FifoId>, expect: u64, consumed: u64, tail: u64, tail_left: u64 },
    Spmv { x_in: FifoId, x_beats: u64, busy: u64, out_beats: u64, out: FifoId, consumed: u64, busy_left: u64, emitted: u64 },
}

#[derive(Debug, Clone)]
struct Node {
    name: String,
    kind: NodeKind,
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimStats {
    /// Total cycles until every node finished.
    pub cycles: u64,
    /// Per-node completion cycle; `None` while unfinished.  A node that
    /// is already complete before the first step (e.g. zero beats)
    /// reports `Some(0)` — `0` is a real completion time here, not the
    /// unset sentinel it used to be.
    pub node_done_at: Vec<Option<u64>>,
}

/// Why a simulation run did not complete.
#[derive(Debug, Clone)]
pub enum SimError {
    /// No progress while nodes are unfinished: the Fig. 7 condition.
    Deadlock {
        /// Cycle at which progress stopped.
        cycle: u64,
        /// Names of the unfinished nodes.
        stuck: Vec<String>,
    },
    /// Safety valve.
    CycleLimit(u64),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock { cycle, stuck } => {
                write!(f, "deadlock at cycle {cycle}: stuck nodes {stuck:?}")
            }
            SimError::CycleLimit(c) => write!(f, "cycle limit {c} exceeded"),
        }
    }
}

impl std::error::Error for SimError {}

/// What one simulated cycle did — drives the fast-forward decision.
struct StepOutcome {
    /// Any node progressed (countdowns included).
    progressed: bool,
    /// `Some(min_left)`: the ONLY progress this cycle was busy/tail
    /// countdown decrements, and every decremented counter still holds
    /// >= `min_left` cycles.  The next `min_left - 1` cycles are then
    /// provably identical decrements and can be applied in bulk.
    countdown_min: Option<u64>,
}

/// Builder + engine.
#[derive(Debug, Clone)]
pub struct Dataflow {
    fifos: Vec<Fifo>,
    nodes: Vec<Node>,
    num_channels: usize,
    fast_forward: bool,
    /// Per-cycle channel arbitration scratch, reused across steps.
    channel_used: Vec<bool>,
}

impl Default for Dataflow {
    fn default() -> Self {
        Self::new(0)
    }
}

impl Dataflow {
    /// An empty graph arbitrating `num_channels` HBM channels.
    pub fn new(num_channels: usize) -> Self {
        Self {
            fifos: Vec::new(),
            nodes: Vec::new(),
            num_channels,
            fast_forward: true,
            channel_used: vec![false; num_channels],
        }
    }

    /// Toggle busy-counter fast-forwarding (on by default).  Results are
    /// identical either way; the cycle-by-cycle mode exists for the
    /// equivalence tests and for debugging.
    pub fn set_fast_forward(&mut self, on: bool) {
        self.fast_forward = on;
    }

    /// Allocate a FIFO of capacity `cap` tokens.
    pub fn fifo(&mut self, cap: usize) -> FifoId {
        self.fifos.push(Fifo { cap, len: 0 });
        self.fifos.len() - 1
    }

    /// A memory-read node: one beat per cycle from `channel` into `out`.
    pub fn mem_read(&mut self, name: &str, channel: usize, beats: u64, out: FifoId) -> NodeId {
        assert!(channel < self.num_channels);
        self.push(name, NodeKind::MemRead { channel, beats, done: 0, out })
    }

    /// A memory-write node: one beat per cycle from `input` to `channel`.
    pub fn mem_write(&mut self, name: &str, channel: usize, beats: u64, input: FifoId) -> NodeId {
        assert!(channel < self.num_channels);
        self.push(name, NodeKind::MemWrite { channel, beats, done: 0, input })
    }

    /// II=1 pipeline of `depth` stages; `outs` are (stage, fifo) taps.
    pub fn pipe(
        &mut self,
        name: &str,
        ins: Vec<FifoId>,
        outs: Vec<(usize, FifoId)>,
        depth: usize,
        expect: u64,
    ) -> NodeId {
        for (s, _) in &outs {
            assert!(*s < depth, "tap stage beyond pipeline depth");
        }
        let state = PipeState { slots: VecDeque::from(vec![false; depth]), consumed: 0 };
        self.push(name, NodeKind::Pipe { ins, outs, depth, expect, state })
    }

    /// Dot-product consumer with a fixed post-stream tail.
    pub fn dot(&mut self, name: &str, ins: Vec<FifoId>, expect: u64, tail: u64) -> NodeId {
        self.push(name, NodeKind::Dot { ins, expect, consumed: 0, tail, tail_left: tail })
    }

    /// SpMV: consume `x_beats` of the input vector, stay busy for the
    /// scheduled nnz-stream cycles, then emit `out_beats`.
    pub fn spmv(
        &mut self,
        name: &str,
        x_in: FifoId,
        x_beats: u64,
        busy: u64,
        out_beats: u64,
        out: FifoId,
    ) -> NodeId {
        self.push(
            name,
            NodeKind::Spmv {
                x_in,
                x_beats,
                busy,
                out_beats,
                out,
                consumed: 0,
                busy_left: busy,
                emitted: 0,
            },
        )
    }

    fn push(&mut self, name: &str, kind: NodeKind) -> NodeId {
        self.nodes.push(Node { name: name.to_string(), kind });
        self.nodes.len() - 1
    }

    fn node_finished(&self, n: &Node) -> bool {
        match &n.kind {
            NodeKind::MemRead { beats, done, .. } => done >= beats,
            NodeKind::MemWrite { beats, done, .. } => done >= beats,
            NodeKind::Pipe { expect, state, .. } => {
                state.consumed >= *expect && state.slots.iter().all(|s| !s)
            }
            NodeKind::Dot { expect, consumed, tail_left, .. } => {
                consumed >= expect && *tail_left == 0
            }
            NodeKind::Spmv { out_beats, emitted, .. } => emitted >= out_beats,
        }
    }

    /// Run to completion. Returns cycle statistics or a deadlock report.
    pub fn run(&mut self, cycle_limit: u64) -> Result<SimStats, SimError> {
        let mut cycle = 0u64;
        let n_nodes = self.nodes.len();
        let mut done_at: Vec<Option<u64>> = vec![None; n_nodes];
        // Pre-scan: nodes complete before the first step finish at 0
        // (the old u64 representation conflated this with "unset").
        for (i, n) in self.nodes.iter().enumerate() {
            if self.node_finished(n) {
                done_at[i] = Some(0);
            }
        }
        loop {
            if self.nodes.iter().all(|n| self.node_finished(n)) {
                return Ok(SimStats { cycles: cycle, node_done_at: done_at });
            }
            if cycle >= cycle_limit {
                return Err(SimError::CycleLimit(cycle_limit));
            }
            let outcome = self.step(cycle);
            for (i, n) in self.nodes.iter().enumerate() {
                if done_at[i].is_none() && self.node_finished(n) {
                    done_at[i] = Some(cycle + 1);
                }
            }
            if !outcome.progressed {
                let stuck = self
                    .nodes
                    .iter()
                    .filter(|n| !self.node_finished(n))
                    .map(|n| n.name.clone())
                    .collect();
                return Err(SimError::Deadlock { cycle, stuck });
            }
            cycle += 1;
            // Fast-forward: the next min_left - 1 cycles would only
            // repeat the same decrements (no FIFO/pipe state changed, so
            // no other node can wake until a counter reaches zero).
            // Nothing finishes inside the skipped stretch — counters
            // stay > 0 — so done_at bookkeeping is unaffected.
            if self.fast_forward {
                if let Some(min_left) = outcome.countdown_min {
                    if min_left > 1 {
                        let skip = (min_left - 1).min(cycle_limit.saturating_sub(cycle));
                        if skip > 0 {
                            self.bulk_countdown(skip);
                            cycle += skip;
                        }
                    }
                }
            }
        }
    }

    /// Apply `k` cycles' worth of pure countdown decrements at once.
    /// Callers guarantee every active counter holds > `k` cycles.
    fn bulk_countdown(&mut self, k: u64) {
        for node in &mut self.nodes {
            match &mut node.kind {
                NodeKind::Spmv { busy_left, .. } if *busy_left > 0 => {
                    debug_assert!(*busy_left > k);
                    *busy_left -= k;
                }
                NodeKind::Dot { expect, consumed, tail_left, .. }
                    if *consumed >= *expect && *tail_left > 0 =>
                {
                    debug_assert!(*tail_left > k);
                    *tail_left -= k;
                }
                _ => {}
            }
        }
    }

    /// Build a phase graph from one trip of a compiled instruction
    /// program: every node, FIFO, channel and beat count derives from
    /// the same Type-I/II/III instructions the value plane executed —
    /// the time plane can no longer drift from the ISA.
    ///
    /// Mapping rules (module micro-architecture comes from
    /// `crate::program`'s depth/tap tables; the *schedule* — who reads
    /// and writes what, where, how much — comes from the instructions):
    ///
    /// * a Type-III read becomes a `MemRead` on its compiled channel,
    ///   feeding the module its Type-I `q_id` routes to;
    /// * a Type-II step becomes an `Spmv` (M1), a `Dot` (pure scalar
    ///   modules), or a stall-freeze `Pipe` whose taps sit at the
    ///   compiled output stages, with FIFO depths from the §5.6 rule;
    /// * an output vector with several sinks streams through a depth-1
    ///   fork (the vector-control module's copy, §4.2);
    /// * a Type-III write becomes a `MemWrite` on its compiled channel.
    ///
    /// Node order is canonical — per computation step: its memory
    /// reads (input order), the module, its forks; all memory writes
    /// last in vector-control order — so cycle counts are reproducible
    /// and pinned by the hand-built-graph equality tests.
    pub fn from_program(prog: &crate::program::PhaseProgram, spmv_busy: u64) -> Dataflow {
        Self::from_batched_program(prog, 1, spmv_busy)
    }

    /// [`Dataflow::from_program`] vectorized over `batch` RHS lanes: the
    /// one compiled trip is instantiated once per lane (lane-major node
    /// order, lane-0 names unsuffixed so the single-lane graph is
    /// byte-identical to `from_program`'s).
    ///
    /// Pricing model for the batch axis:
    ///
    /// * every lane's vector streams land on the **shared** compiled
    ///   channels, so the round-robin channel arbitration prices the
    ///   contention — per-RHS vector traffic scales with the batch;
    /// * each lane carries its own `Spmv` busy window and the windows
    ///   overlap — the nnz stream is read once and applied to every
    ///   lane (the block-CG matrix-traffic amortization the batch axis
    ///   exists for, implemented in the value plane by
    ///   `precision::spmv_scheme_rows_block` under
    ///   `CoordinatorConfig::block`), so SpMV time does *not*
    ///   scale with the batch while the §6 PE array has headroom;
    ///   callers model the per-lane fallback by widening `spmv_busy`
    ///   (`sim::iteration::BatchSpmvMode::PerLane`);
    /// * per-trip control overhead is charged once per batched trip,
    ///   not once per lane (`sim::iteration` adds it outside).
    pub fn from_batched_program(
        prog: &crate::program::PhaseProgram,
        batch: crate::program::BatchId,
        spmv_busy: u64,
    ) -> Dataflow {
        let mut df = Dataflow::new(crate::program::TOTAL_CHANNELS);
        for lane in 0..batch.max(1) {
            Self::add_program_lane(&mut df, prog, spmv_busy, lane);
        }
        df
    }

    /// Append one RHS lane's instantiation of a compiled trip to `df` —
    /// the per-lane body of [`Dataflow::from_batched_program`].
    fn add_program_lane(
        df: &mut Dataflow,
        prog: &crate::program::PhaseProgram,
        spmv_busy: u64,
        lane: crate::program::BatchId,
    ) {
        use crate::modules::fsm::Endpoint;
        use crate::program::{
            edge_fifo_depth, pipe_depth, short_name, tap_stage, STREAM_FIFO_DEPTH,
        };
        use crate::vsr::{Module, Vector};

        const BEAT_LANES: u64 = 8;
        let beats = |len: u32| (len as u64).div_ceil(BEAT_LANES);

        // Lane-0 names match the single-RHS graph exactly; later lanes
        // get a `#k` suffix.
        let tag = move |base: String| -> String {
            if lane == 0 {
                base
            } else {
                format!("{base}#{lane}")
            }
        };

        // Pass 1: allocate the stream FIFOs every producer output
        // feeds, in step order (FIFO ids are passive; only node order
        // affects arbitration).
        struct OutEdge {
            producer: Module,
            vector: Vector,
            sink: Endpoint,
            fifo: FifoId,
        }
        struct ForkSpec {
            vector: Vector,
            input: FifoId,
            taps: Vec<FifoId>,
        }
        let n_steps = prog.comp_steps.len();
        let mut out_edges: Vec<OutEdge> = Vec::new();
        let mut prod_taps: Vec<Vec<(Vector, FifoId)>> =
            (0..n_steps).map(|_| Vec::new()).collect();
        let mut fork_specs: Vec<Vec<ForkSpec>> = (0..n_steps).map(|_| Vec::new()).collect();
        for (ci, step) in prog.comp_steps.iter().enumerate() {
            let mut seen: Vec<Vector> = Vec::new();
            for (v, _) in &step.outputs {
                if seen.contains(v) {
                    continue;
                }
                seen.push(*v);
                let sinks: Vec<Endpoint> = step
                    .outputs
                    .iter()
                    .filter(|(ov, _)| ov == v)
                    .map(|(_, e)| *e)
                    .collect();
                if sinks.len() == 1 {
                    let f = df.fifo(edge_fifo_depth(step, *v));
                    out_edges
                        .push(OutEdge { producer: step.module, vector: *v, sink: sinks[0], fifo: f });
                    prod_taps[ci].push((*v, f));
                } else {
                    let fin = df.fifo(edge_fifo_depth(step, *v));
                    prod_taps[ci].push((*v, fin));
                    let mut taps = Vec::new();
                    for s in sinks {
                        let f = df.fifo(STREAM_FIFO_DEPTH);
                        out_edges
                            .push(OutEdge { producer: step.module, vector: *v, sink: s, fifo: f });
                        taps.push(f);
                    }
                    fork_specs[ci].push(ForkSpec { vector: *v, input: fin, taps });
                }
            }
        }
        let find_edge = |edges: &[OutEdge], p: Module, v: Vector, sink: Endpoint| -> FifoId {
            edges
                .iter()
                .find(|e| e.producer == p && e.vector == v && e.sink == sink)
                .map(|e| e.fifo)
                .unwrap_or_else(|| {
                    panic!("no compiled stream {} -> {sink:?} for {}", short_name(p), v.name())
                })
        };

        // Pass 2: nodes in canonical order.
        let mut rd_used = vec![false; prog.vec_steps.len()];
        for (ci, step) in prog.comp_steps.iter().enumerate() {
            let nb = beats(step.inst.len);
            let mut ins: Vec<FifoId> = Vec::new();
            for (v, ep) in &step.inputs {
                match ep {
                    Endpoint::Memory => {
                        let (vi, vs) = prog
                            .vec_steps
                            .iter()
                            .enumerate()
                            .find(|(vi, vs)| {
                                !rd_used[*vi]
                                    && vs.vector == *v
                                    && vs.rd_to == Some(step.module)
                            })
                            .unwrap_or_else(|| {
                                panic!(
                                    "no compiled read of {} for {}",
                                    v.name(),
                                    short_name(step.module)
                                )
                            });
                        rd_used[vi] = true;
                        let f = df.fifo(STREAM_FIFO_DEPTH);
                        let rd = vs.rd_inst.expect("read step carries a Type-III read");
                        df.mem_read(
                            &tag(format!("rd_{}@{}", v.name(), short_name(step.module))),
                            vs.rd_channel,
                            beats(rd.len),
                            f,
                        );
                        ins.push(f);
                    }
                    Endpoint::Module(src) => {
                        ins.push(find_edge(&out_edges, *src, *v, Endpoint::Module(step.module)));
                    }
                    Endpoint::Controller => {}
                }
            }
            let name = tag(short_name(step.module).to_string());
            match step.module {
                Module::M1 => {
                    let out = prod_taps[ci][0].1;
                    df.spmv(&name, ins[0], nb, spmv_busy, nb, out);
                }
                Module::M2 | Module::M8 => {
                    df.dot(&name, ins, nb, super::iteration::DOT_TAIL);
                }
                _ => {
                    let depth = pipe_depth(step.module);
                    let outs: Vec<(usize, FifoId)> = prod_taps[ci]
                        .iter()
                        .map(|(v, f)| (tap_stage(step.module, *v), *f))
                        .collect();
                    df.pipe(&name, ins, outs, depth, nb);
                }
            }
            for fork in &fork_specs[ci] {
                let outs: Vec<(usize, FifoId)> = fork.taps.iter().map(|f| (0usize, *f)).collect();
                df.pipe(
                    &tag(format!("fork_{}", fork.vector.name())),
                    vec![fork.input],
                    outs,
                    1,
                    nb,
                );
            }
        }
        for vs in &prog.vec_steps {
            if let Some(wr) = vs.wr_inst {
                let m = vs.wr_from.expect("write step has a producing module");
                let f = find_edge(&out_edges, m, vs.vector, Endpoint::Memory);
                df.mem_write(
                    &tag(format!("wr_{}", vs.vector.name())),
                    vs.wr_channel,
                    beats(wr.len),
                    f,
                );
            }
        }
    }

    /// One simulated cycle; reports what progressed.
    fn step(&mut self, cycle: u64) -> StepOutcome {
        // `other` — progress that changes FIFO/pipe/transfer state;
        // `countdown` — progress that only decrements busy/tail counters.
        let mut other = false;
        let mut any_countdown = false;
        let mut min_left = u64::MAX;
        let n_nodes = self.nodes.len();
        // Channel arbitration: one beat per channel per cycle,
        // round-robin by (cycle + node index) so co-located streams
        // interleave fairly.  The scratch buffer is struct-owned and the
        // rotation is computed inline: no per-cycle allocation.
        for used in self.channel_used.iter_mut() {
            *used = false;
        }
        let rotate = |k: usize| (k + cycle as usize) % n_nodes;

        // Phase A: memory reads (producers) — capped one per channel.
        for k in 0..n_nodes {
            let i = rotate(k);
            if let NodeKind::MemRead { channel, beats, done, out } = self.nodes[i].kind {
                if done < beats
                    && !self.channel_used[channel]
                    && self.fifos[out].len < self.fifos[out].cap
                {
                    self.fifos[out].len += 1;
                    if let NodeKind::MemRead { done, .. } = &mut self.nodes[i].kind {
                        *done += 1;
                    }
                    self.channel_used[channel] = true;
                    other = true;
                }
            }
        }

        // Phase B: compute nodes.
        for k in 0..n_nodes {
            let i = rotate(k);
            let node = &mut self.nodes[i];
            match &mut node.kind {
                NodeKind::Pipe { ins, outs, state, expect, .. } => {
                    // 1. Emission check: every occupied tap stage must be
                    // able to write. A single blocked tap freezes the pipe.
                    let mut blocked = false;
                    for &(stage, f) in outs.iter() {
                        if state.slots[stage] && self.fifos[f].len >= self.fifos[f].cap {
                            blocked = true;
                            break;
                        }
                    }
                    if blocked {
                        continue;
                    }
                    // Will a new token enter stage 0?
                    let can_consume = state.consumed < *expect
                        && ins.iter().all(|&f| self.fifos[f].len > 0);
                    let any_token = state.slots.iter().any(|&s| s) || can_consume;
                    if !any_token {
                        continue;
                    }
                    // 2. Emit from taps (token passes the tap stage now).
                    for &(stage, f) in outs.iter() {
                        if state.slots[stage] {
                            self.fifos[f].len += 1;
                        }
                    }
                    // 3. Advance pipeline. A token leaving the last stage
                    // just retires (all its writes happened at taps).
                    state.slots.pop_back();
                    state.slots.push_front(false);
                    // 4. Consume.
                    if can_consume {
                        for &f in ins.iter() {
                            self.fifos[f].len -= 1;
                        }
                        state.consumed += 1;
                        state.slots[0] = true;
                    }
                    other = true;
                }
                NodeKind::Dot { ins, expect, consumed, tail_left, .. } => {
                    if *consumed < *expect {
                        if ins.iter().all(|&f| self.fifos[f].len > 0) {
                            for &f in ins.iter() {
                                self.fifos[f].len -= 1;
                            }
                            *consumed += 1;
                            other = true;
                        }
                    } else if *tail_left > 0 {
                        *tail_left -= 1;
                        any_countdown = true;
                        min_left = min_left.min(*tail_left);
                    }
                }
                NodeKind::Spmv {
                    x_in,
                    x_beats,
                    busy_left,
                    out_beats,
                    out,
                    consumed,
                    emitted,
                    ..
                } => {
                    // x load and nnz streaming overlap (prefetch, §4.2);
                    // output starts once both complete.
                    if *consumed < *x_beats && self.fifos[*x_in].len > 0 {
                        self.fifos[*x_in].len -= 1;
                        *consumed += 1;
                        other = true;
                    }
                    if *busy_left > 0 {
                        *busy_left -= 1;
                        any_countdown = true;
                        min_left = min_left.min(*busy_left);
                    }
                    if *consumed >= *x_beats
                        && *busy_left == 0
                        && *emitted < *out_beats
                        && self.fifos[*out].len < self.fifos[*out].cap
                    {
                        self.fifos[*out].len += 1;
                        *emitted += 1;
                        other = true;
                    }
                }
                _ => {}
            }
        }

        // Phase C: memory writes (consumers) — capped one per channel.
        for k in 0..n_nodes {
            let i = rotate(k);
            if let NodeKind::MemWrite { channel, beats, done, input } = self.nodes[i].kind {
                if done < beats && !self.channel_used[channel] && self.fifos[input].len > 0 {
                    self.fifos[input].len -= 1;
                    if let NodeKind::MemWrite { done, .. } = &mut self.nodes[i].kind {
                        *done += 1;
                    }
                    self.channel_used[channel] = true;
                    other = true;
                }
            }
        }
        StepOutcome {
            progressed: other || any_countdown,
            countdown_min: if !other && any_countdown { Some(min_left) } else { None },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// mem -> pipe -> mem: cycles ~ beats + pipeline depth.
    #[test]
    fn straight_pipe_latency() {
        let mut df = Dataflow::new(2);
        let a = df.fifo(4);
        let b = df.fifo(4);
        df.mem_read("rd", 0, 100, a);
        df.pipe("axpy", vec![a], vec![(2, b)], 3, 100);
        df.mem_write("wr", 1, 100, b);
        let stats = df.run(10_000).unwrap();
        assert!((100..120).contains(&stats.cycles), "cycles={}", stats.cycles);
    }

    /// Two streams sharing one channel run at half rate; on separate
    /// channels they overlap — the §5.7 single/double channel effect.
    #[test]
    fn channel_contention_halves_rate() {
        let run = |same_channel: bool| {
            let mut df = Dataflow::new(2);
            let a = df.fifo(4);
            let b = df.fifo(4);
            df.mem_read("rd_v", 0, 200, a);
            df.mem_read("rd_w", if same_channel { 0 } else { 1 }, 200, b);
            df.dot("sink", vec![a, b], 200, 0);
            df.run(100_000).unwrap().cycles
        };
        let contended = run(true);
        let parallel = run(false);
        assert!(contended >= 2 * parallel - 10, "contended={contended} parallel={parallel}");
    }

    /// Fig. 7(a): shallow fast FIFO + deep pipeline deadlocks.
    #[test]
    fn fig7_deadlock_with_shallow_fifo() {
        let depth_l = 33;
        let mut df = Dataflow::new(2);
        let r_in = df.fifo(4);
        let r_fast = df.fifo(2); // default depth 2: deadlocks
        let z_slow = df.fifo(2);
        df.mem_read("rd_r", 0, 100, r_in);
        // M5: forwards r at stage 0, emits z at stage L-1.
        df.pipe("M5", vec![r_in], vec![(0, r_fast), (depth_l - 1, z_slow)], depth_l, 100);
        df.dot("M6", vec![r_fast, z_slow], 100, 0);
        match df.run(100_000) {
            Err(SimError::Deadlock { .. }) => {}
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    /// Fig. 7(b): fast FIFO depth >= L+1 resolves the deadlock.
    #[test]
    fn fig7_resolved_with_depth_l_plus_1() {
        let depth_l = 33;
        let mut df = Dataflow::new(2);
        let r_in = df.fifo(4);
        let r_fast = df.fifo(depth_l + 1);
        let z_slow = df.fifo(2);
        df.mem_read("rd_r", 0, 100, r_in);
        df.pipe("M5", vec![r_in], vec![(0, r_fast), (depth_l - 1, z_slow)], depth_l, 100);
        df.dot("M6", vec![r_fast, z_slow], 100, 0);
        let stats = df.run(100_000).unwrap();
        assert!(stats.cycles < 200, "cycles={}", stats.cycles);
    }

    /// Dot tail is charged after the stream ends (footnote 1).
    #[test]
    fn dot_tail_extends_completion() {
        let mut df = Dataflow::new(1);
        let a = df.fifo(4);
        df.mem_read("rd", 0, 50, a);
        df.dot("dot", vec![a], 50, 40);
        let stats = df.run(10_000).unwrap();
        assert!(stats.cycles >= 90, "cycles={}", stats.cycles);
    }

    /// SpMV node: output held until busy window and x load both finish.
    #[test]
    fn spmv_waits_for_busy_window() {
        let mut df = Dataflow::new(2);
        let x = df.fifo(8);
        let y = df.fifo(8);
        df.mem_read("rd_x", 0, 10, x);
        df.spmv("M1", x, 10, 500, 10, y);
        df.mem_write("wr_y", 1, 10, y);
        let stats = df.run(10_000).unwrap();
        assert!(stats.cycles >= 500, "cycles={}", stats.cycles);
        assert!(stats.cycles < 600, "cycles={}", stats.cycles);
    }

    /// Cycle limit trips instead of hanging.
    #[test]
    fn cycle_limit_guards() {
        let mut df = Dataflow::new(1);
        let a = df.fifo(1);
        df.mem_read("rd", 0, 10, a); // no consumer: fills and stalls
        match df.run(100) {
            Err(SimError::Deadlock { .. }) | Err(SimError::CycleLimit(_)) => {}
            other => panic!("expected stall, got {other:?}"),
        }
    }

    /// A node with zero work reports completion at cycle 0 — the old
    /// `done_at == 0` sentinel could never distinguish this.
    #[test]
    fn zero_beat_node_done_at_cycle_zero() {
        let mut df = Dataflow::new(2);
        let a = df.fifo(4);
        let b = df.fifo(4);
        df.mem_read("rd_empty", 0, 0, a); // finished before the first step
        df.mem_read("rd_real", 1, 20, b);
        df.dot("sink", vec![b], 20, 0);
        let stats = df.run(10_000).unwrap();
        assert_eq!(stats.node_done_at[0], Some(0));
        assert!(matches!(stats.node_done_at[1], Some(c) if c >= 20));
        assert!(stats.node_done_at.iter().all(|d| d.is_some()));
    }

    /// Build the Fig.-5-like phase-1 shape used by the iteration model:
    /// large SpMV busy window + dot tail — the fast-forward sweet spot.
    fn spmv_phase_graph(busy: u64) -> Dataflow {
        let mut df = Dataflow::new(3);
        let x = df.fifo(8);
        let y_raw = df.fifo(8);
        let y_dot = df.fifo(8);
        let y_wr = df.fifo(8);
        let p2 = df.fifo(8);
        df.mem_read("rd_x", 0, 64, x);
        df.spmv("M1", x, 64, busy, 64, y_raw);
        df.pipe("fork", vec![y_raw], vec![(0, y_dot), (0, y_wr)], 1, 64);
        df.mem_read("rd_p", 1, 64, p2);
        df.dot("M2", vec![p2, y_dot], 64, 40);
        df.mem_write("wr_y", 2, 64, y_wr);
        df
    }

    /// Fast-forward must not move a single number: cycles and per-node
    /// completion times match the cycle-by-cycle run exactly.
    #[test]
    fn fast_forward_is_bit_identical_to_stepping() {
        for busy in [0, 1, 7, 500, 20_000] {
            let mut ff = spmv_phase_graph(busy);
            let mut slow = ff.clone();
            slow.set_fast_forward(false);
            let sf = ff.run(1_000_000).unwrap();
            let ss = slow.run(1_000_000).unwrap();
            assert_eq!(sf.cycles, ss.cycles, "busy={busy}");
            assert_eq!(sf.node_done_at, ss.node_done_at, "busy={busy}");
        }
    }

    /// Fast-forward preserves deadlock verdicts (cycle and stuck set).
    #[test]
    fn fast_forward_preserves_deadlock_verdict() {
        let build = || {
            let depth_l = 33;
            let mut df = Dataflow::new(2);
            let r_in = df.fifo(4);
            let r_fast = df.fifo(2);
            let z_slow = df.fifo(2);
            df.mem_read("rd_r", 0, 100, r_in);
            df.pipe("M5", vec![r_in], vec![(0, r_fast), (depth_l - 1, z_slow)], depth_l, 100);
            df.dot("M6", vec![r_fast, z_slow], 100, 0);
            df
        };
        let mut ff = build();
        let mut slow = build();
        slow.set_fast_forward(false);
        match (ff.run(100_000), slow.run(100_000)) {
            (
                Err(SimError::Deadlock { cycle: c1, stuck: s1 }),
                Err(SimError::Deadlock { cycle: c2, stuck: s2 }),
            ) => {
                assert_eq!(c1, c2);
                assert_eq!(s1, s2);
            }
            other => panic!("expected matching deadlocks, got {other:?}"),
        }
    }

    /// Fast-forward preserves the cycle-limit verdict.
    #[test]
    fn fast_forward_preserves_cycle_limit() {
        // SpMV busy window far beyond the limit: the run must trip the
        // limit, not silently jump past it.
        let mut df = Dataflow::new(1);
        let x = df.fifo(8);
        let y = df.fifo(8);
        df.mem_read("rd_x", 0, 4, x);
        df.spmv("M1", x, 4, 1_000_000, 4, y);
        match df.run(500) {
            Err(SimError::CycleLimit(500)) => {}
            other => panic!("expected cycle limit, got {other:?}"),
        }
    }
}
