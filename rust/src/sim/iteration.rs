//! Per-iteration cycle model: builds the Fig. 5 phase graphs on the
//! dataflow engine and turns (matrix, accelerator config) into
//! cycles/iteration and solver seconds.
//!
//! Channel map (a U280 has 32): 0-15 nnz streams, 16 the Jacobi diagonal
//! M, then one or two channels per long vector depending on the §5.7
//! channel mode.  The VSR flag switches between the Fig. 5 reuse graphs
//! and the store-everything baseline (§5.5), which also serializes the
//! per-module memory round-trips the way XcgSolver's kernel-sequential
//! execution does.

use crate::hbm::{ChannelMode, HbmConfig};
use crate::precision::Scheme;
use crate::sparse::{NUM_CHANNELS, PES_PER_CHANNEL};

use super::dataflow::{Dataflow, SimError};

/// f64 lanes per 64-byte beat.
const LANES: u64 = 8;
/// M5 left-divide pipeline depth (Fig. 7: L = 33).
pub const M5_DEPTH: usize = 33;
/// Dot-product Phase-II tail: II=5 over the 8-lane delay buffer.
pub const DOT_TAIL: u64 = 5 * 8;
/// Per-phase control overhead (instruction issue + FSM transitions).
pub const PHASE_OVERHEAD: u64 = 32;

/// Simulation-facing accelerator description.
#[derive(Debug, Clone, Copy)]
pub struct AccelSimConfig {
    pub hbm: HbmConfig,
    /// Vector streaming reuse + decentralized scheduling (§5) on?
    pub vsr: bool,
    /// SpMV precision scheme (drives nnz stream bytes).
    pub scheme: Scheme,
    /// nnz-stream padding factor from the hazard scheduler
    /// (sparse::NnzStream::padding_factor, or an estimate).
    pub nnz_padding: f64,
    /// Fixed overhead per module *invocation* (kernel-sequential designs
    /// like XcgSolver pay this 8x per iteration; streaming designs ~0).
    pub invoke_overhead: u64,
}

impl AccelSimConfig {
    pub fn callipepla() -> Self {
        Self {
            hbm: HbmConfig::callipepla(),
            vsr: true,
            scheme: Scheme::MixV3,
            nnz_padding: 1.06,
            invoke_overhead: 0,
        }
    }

    pub fn serpenscg() -> Self {
        Self {
            hbm: HbmConfig::serpenscg(),
            vsr: false,
            scheme: Scheme::Fp64,
            nnz_padding: 1.06,
            // Without decentralized scheduling the central controller
            // sequences each module's memory-to-memory pass; the
            // per-pass turnaround is what VSR + the FSMs remove.
            // Calibrated against Table 4 M4: ~98 us/iter at n=10605.
            invoke_overhead: 1300,
        }
    }

    pub fn xcgsolver() -> Self {
        Self {
            hbm: HbmConfig::xcgsolver(),
            vsr: false,
            scheme: Scheme::Fp64,
            // FP-add-latency zero padding (§7.5.1) costs more slots.
            nnz_padding: 1.35,
            // Vitis kernel-sequential invocation overhead, per module
            // (calibrated: Table 4 M4 gives ~98 us/iter at n=10605).
            invoke_overhead: 1300,
        }
    }
}

/// Cycle breakdown of one JPCG iteration.
#[derive(Debug, Clone, Copy, Default)]
pub struct IterationBreakdown {
    pub phase1: u64,
    pub phase2: u64,
    pub phase3: u64,
    pub total: u64,
}

fn beats(n: usize) -> u64 {
    (n as u64).div_ceil(LANES)
}

/// Scheduled SpMV busy cycles: nnz spread over 16 channels x 8 PEs with
/// the hazard-padding factor; FP64 nnz occupy two 64-bit slots (§2.3.3),
/// halving effective PE throughput.
pub fn spmv_busy_cycles(nnz: usize, scheme: Scheme, padding: f64) -> u64 {
    let slot_factor = if scheme.matrix_f32() { 1.0 } else { 2.0 };
    let lanes = (NUM_CHANNELS * PES_PER_CHANNEL) as f64;
    (nnz as f64 * padding * slot_factor / lanes).ceil() as u64
}

// Channel ids.
const CH_M: usize = 16;
const CH_AP: usize = 17;
const CH_AP2: usize = 18;
const CH_P: usize = 19;
const CH_P2: usize = 20;
const CH_X: usize = 21;
const CH_X2: usize = 22;
const CH_R: usize = 23;
const CH_R2: usize = 24;
const TOTAL_CH: usize = 32;

/// Second channel of a pair under the §5.7 ping-pong, or the same
/// channel when the build is single-channel.
fn wr_ch(cfg: &AccelSimConfig, rd: usize, pair: usize) -> usize {
    match cfg.hbm.vector_mode {
        ChannelMode::Double => pair,
        ChannelMode::Single => rd,
    }
}

const FIFO_DEPTH: usize = 64; // default stream FIFO depth
const LIMIT: u64 = 500_000_000;

/// Phase-1 with VSR: M1 (SpMV) streams ap into a fork feeding both M2
/// (dot-alpha) and the ap write-back; p read twice (M1, then M2).
fn phase1_vsr(cfg: &AccelSimConfig, n: usize, nnz: usize) -> u64 {
    let nb = beats(n);
    let busy = spmv_busy_cycles(nnz, cfg.scheme, cfg.nnz_padding);
    let mut df = Dataflow::new(TOTAL_CH);
    let p1 = df.fifo(FIFO_DEPTH);
    let ap_raw = df.fifo(FIFO_DEPTH);
    let ap_dot = df.fifo(FIFO_DEPTH);
    let ap_wr = df.fifo(FIFO_DEPTH);
    let p2 = df.fifo(FIFO_DEPTH);
    df.mem_read("rd_p_m1", CH_P, nb, p1);
    df.spmv("M1", p1, nb, busy, nb, ap_raw);
    // VecCtrl-ap forks the stream: one copy to M2, one to memory.
    df.pipe("fork_ap", vec![ap_raw], vec![(0, ap_dot), (0, ap_wr)], 1, nb);
    df.mem_read("rd_p_m2", CH_P2, nb, p2);
    df.dot("M2", vec![p2, ap_dot], nb, DOT_TAIL);
    df.mem_write("wr_ap", wr_ch(cfg, CH_AP, CH_AP2), nb, ap_wr);
    run_phase(df)
}

/// Phase-2 with VSR: the consume-and-send chain M4 -> M5 -> M6 -> M8 on
/// one memory read of r; M5's z FIFO is deep (L+1) per §5.6.
fn phase2_vsr(_cfg: &AccelSimConfig, n: usize) -> u64 {
    let nb = beats(n);
    let mut df = Dataflow::new(TOTAL_CH);
    let r_in = df.fifo(FIFO_DEPTH);
    let ap_in = df.fifo(FIFO_DEPTH);
    let m_in = df.fifo(FIFO_DEPTH);
    let r_m4 = df.fifo(FIFO_DEPTH);
    let r_m5 = df.fifo(M5_DEPTH + 1); // fast FIFO, Fig. 7(b)
    let z_m5 = df.fifo(FIFO_DEPTH);
    let r_m6 = df.fifo(FIFO_DEPTH);
    df.mem_read("rd_r", CH_R, nb, r_in);
    df.mem_read("rd_ap", CH_AP, nb, ap_in);
    df.mem_read("rd_m", CH_M, nb, m_in);
    // M4: r' = r - alpha*ap, forwards r' (depth ~ FP mul-add pipe).
    df.pipe("M4", vec![r_in, ap_in], vec![(7, r_m4)], 8, nb);
    // M5: consume-and-send r' fast, z after the divide pipeline.
    df.pipe("M5", vec![r_m4, m_in], vec![(0, r_m5), (M5_DEPTH - 1, z_m5)], M5_DEPTH, nb);
    // M6: dot rz, forwarding r to M8 (tail folded into M8's).
    df.pipe("M6", vec![r_m5, z_m5], vec![(4, r_m6)], 5, nb);
    df.dot("M8", vec![r_m6], nb, DOT_TAIL);
    run_phase(df)
}

/// Phase-3 with VSR: M4+M5 recompute z (r, ap, M re-read), M7 updates p
/// (streamed on to M3 and memory), M3 updates x.
fn phase3_vsr(cfg: &AccelSimConfig, n: usize) -> u64 {
    let nb = beats(n);
    let mut df = Dataflow::new(TOTAL_CH);
    let r_in = df.fifo(FIFO_DEPTH);
    let ap_in = df.fifo(FIFO_DEPTH);
    let m_in = df.fifo(FIFO_DEPTH);
    let p_in = df.fifo(FIFO_DEPTH);
    let x_in = df.fifo(FIFO_DEPTH);
    let r_m4 = df.fifo(FIFO_DEPTH);
    let r_wr = df.fifo(M5_DEPTH + 1);
    let z_m5 = df.fifo(FIFO_DEPTH);
    let p_fork_in = df.fifo(FIFO_DEPTH);
    let p_m3 = df.fifo(FIFO_DEPTH);
    let p_wr = df.fifo(FIFO_DEPTH);
    let x_wr = df.fifo(FIFO_DEPTH);
    df.mem_read("rd_r", CH_R, nb, r_in);
    df.mem_read("rd_ap", CH_AP, nb, ap_in);
    df.mem_read("rd_m", CH_M, nb, m_in);
    df.mem_read("rd_p", CH_P, nb, p_in);
    df.mem_read("rd_x", CH_X, nb, x_in);
    df.pipe("M4", vec![r_in, ap_in], vec![(7, r_m4)], 8, nb);
    // M5 recompute: r forwarded to memory write, z into M7.
    df.pipe("M5", vec![r_m4, m_in], vec![(0, r_wr), (M5_DEPTH - 1, z_m5)], M5_DEPTH, nb);
    df.mem_write("wr_r", wr_ch(cfg, CH_R, CH_R2), nb, r_wr);
    // M7: p' = z + beta p; forks to M3 and memory.
    df.pipe("M7", vec![z_m5, p_in], vec![(7, p_fork_in)], 8, nb);
    df.pipe("fork_p", vec![p_fork_in], vec![(0, p_m3), (0, p_wr)], 1, nb);
    df.mem_write("wr_p", wr_ch(cfg, CH_P, CH_P2), nb, p_wr);
    // M3: x' = x + alpha p_old ... the stream M7 forwards carries the
    // old-p lane alongside; modelled as consuming the forked stream.
    df.pipe("M3", vec![x_in, p_m3], vec![(7, x_wr)], 8, nb);
    df.mem_write("wr_x", wr_ch(cfg, CH_X, CH_X2), nb, x_wr);
    run_phase(df)
}

/// Without VSR (§5.5 baseline): every module is its own memory-to-memory
/// pass, serialized (XcgSolver's kernel-sequential execution; also the
/// SerpensCG data path, which has the ISA but not the reuse graph).
fn iteration_no_vsr(cfg: &AccelSimConfig, n: usize, nnz: usize) -> IterationBreakdown {
    let nb = beats(n);
    let busy = spmv_busy_cycles(nnz, cfg.scheme, cfg.nnz_padding);
    let ov = cfg.invoke_overhead;

    // Phase 1: M1 (rd p -> wr ap), then M2 (rd p, rd ap -> scalar).
    let m1 = {
        let mut df = Dataflow::new(TOTAL_CH);
        let p = df.fifo(FIFO_DEPTH);
        let ap = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_p", CH_P, nb, p);
        df.spmv("M1", p, nb, busy, nb, ap);
        df.mem_write("wr_ap", CH_AP, nb, ap);
        run_phase(df)
    };
    let m2 = {
        let mut df = Dataflow::new(TOTAL_CH);
        let p = df.fifo(FIFO_DEPTH);
        let ap = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_p", CH_P, nb, p);
        df.mem_read("rd_ap", CH_AP, nb, ap);
        df.dot("M2", vec![p, ap], nb, DOT_TAIL);
        run_phase(df)
    };
    let phase1 = m1 + m2 + 2 * ov;

    // Phase 2: M4 (rd r, rd ap -> wr r), M5 (rd r, rd M -> wr z),
    // M6 (rd r, rd z -> scalar), M8 (rd r -> scalar).
    let two_read_map = |ch_a: usize, ch_b: usize, ch_o: usize, depth: usize| {
        let mut df = Dataflow::new(TOTAL_CH);
        let a = df.fifo(FIFO_DEPTH);
        let b = df.fifo(FIFO_DEPTH);
        let o = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_a", ch_a, nb, a);
        df.mem_read("rd_b", ch_b, nb, b);
        df.pipe("map", vec![a, b], vec![(depth - 1, o)], depth, nb);
        df.mem_write("wr_o", ch_o, nb, o);
        run_phase(df)
    };
    // z lives in ap's spare channel in the no-VSR design (it must be
    // stored somewhere; the paper's point is it costs a channel).
    let ch_z = CH_AP2;
    let m4 = two_read_map(CH_R, CH_AP, CH_R, 8);
    let m5 = two_read_map(CH_R, CH_M, ch_z, M5_DEPTH);
    let m6 = {
        let mut df = Dataflow::new(TOTAL_CH);
        let r = df.fifo(FIFO_DEPTH);
        let z = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_r", CH_R, nb, r);
        df.mem_read("rd_z", ch_z, nb, z);
        df.dot("M6", vec![r, z], nb, DOT_TAIL);
        run_phase(df)
    };
    let m8 = {
        let mut df = Dataflow::new(TOTAL_CH);
        let r = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_r", CH_R, nb, r);
        df.dot("M8", vec![r], nb, DOT_TAIL);
        run_phase(df)
    };
    let phase2 = m4 + m5 + m6 + m8 + 4 * ov;

    // Phase 3: M7 (rd z, rd p -> wr p), M3 (rd p, rd x -> wr x).
    let m7 = two_read_map(ch_z, CH_P, CH_P, 8);
    let m3 = two_read_map(CH_P, CH_X, CH_X, 8);
    let phase3 = m7 + m3 + 2 * ov;

    IterationBreakdown { phase1, phase2, phase3, total: phase1 + phase2 + phase3 }
}

fn run_phase(mut df: Dataflow) -> u64 {
    match df.run(LIMIT) {
        Ok(stats) => stats.cycles,
        Err(SimError::Deadlock { cycle, stuck }) => {
            panic!("phase graph deadlocked at {cycle}: {stuck:?}")
        }
        Err(e) => panic!("phase simulation failed: {e}"),
    }
}

/// Cycles for one JPCG iteration under a configuration.
pub fn iteration_cycles(cfg: &AccelSimConfig, n: usize, nnz: usize) -> IterationBreakdown {
    if cfg.vsr {
        let p1 = phase1_vsr(cfg, n, nnz) + PHASE_OVERHEAD;
        let p2 = phase2_vsr(cfg, n) + PHASE_OVERHEAD;
        let p3 = phase3_vsr(cfg, n) + PHASE_OVERHEAD;
        IterationBreakdown { phase1: p1, phase2: p2, phase3: p3, total: p1 + p2 + p3 }
    } else {
        let mut b = iteration_no_vsr(cfg, n, nnz);
        b.phase1 += PHASE_OVERHEAD;
        b.phase2 += PHASE_OVERHEAD;
        b.phase3 += PHASE_OVERHEAD;
        b.total = b.phase1 + b.phase2 + b.phase3;
        b
    }
}

/// FPGA solver seconds: per-iteration cycles x iteration count, plus the
/// Alg. 1 init pass (~ one iteration).
pub fn solver_seconds(cfg: &AccelSimConfig, n: usize, nnz: usize, iters: u32) -> f64 {
    let per_iter = iteration_cycles(cfg, n, nnz).total;
    let cycles = per_iter as f64 * (iters as f64 + 1.0);
    cycles * cfg.hbm.cycle_time()
}

// --------------------------------------------------------------------
// A100 GPU analytic model (§7.2.2's explanation, quantified).
// --------------------------------------------------------------------

/// A100 JPCG iteration time: 8 kernel launches (cuSPARSE SpMV + 3 cuBLAS
/// dots + 3 axpy-class + 1 copy/scal), each bandwidth-bound with a fixed
/// launch overhead — the small-matrix floor the paper observes.
pub fn gpu_iteration_seconds(n: usize, nnz: usize) -> f64 {
    const BW: f64 = 1.56e12; // Table 2
    const LAUNCH: f64 = 6.0e-6; // CUDA launch + sync overhead
    let vec_bytes = 8.0 * n as f64;
    // cuSPARSE CSR FP64 SpMV: vals 8B + col 4B per nnz, row ptr, x + y.
    let spmv = LAUNCH + (12.0 * nnz as f64 + 3.0 * vec_bytes) / BW;
    let dot = LAUNCH + 2.0 * vec_bytes / BW;
    let axpy = LAUNCH + 3.0 * vec_bytes / BW;
    spmv + 3.0 * dot + 4.0 * axpy
}

/// A100 solver seconds.
pub fn gpu_solver_seconds(n: usize, nnz: usize, iters: u32) -> f64 {
    gpu_iteration_seconds(n, nnz) * (iters as f64 + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 16_384;
    const NNZ: usize = 320_000;

    #[test]
    fn vsr_phases_complete_without_deadlock() {
        let cfg = AccelSimConfig::callipepla();
        let b = iteration_cycles(&cfg, N, NNZ);
        assert!(b.phase1 > 0 && b.phase2 > 0 && b.phase3 > 0);
        assert_eq!(b.total, b.phase1 + b.phase2 + b.phase3);
    }

    #[test]
    fn vsr_beats_no_vsr() {
        // §5.5: 14 vs 19 accesses + overlap => fewer cycles per iteration.
        let cal = AccelSimConfig::callipepla();
        let mut no_vsr = cal;
        no_vsr.vsr = false;
        let with = iteration_cycles(&cal, N, NNZ).total;
        let without = iteration_cycles(&no_vsr, N, NNZ).total;
        assert!(
            (without as f64) > 1.3 * with as f64,
            "with={with} without={without}"
        );
    }

    #[test]
    fn mixed_precision_halves_spmv_cycles() {
        let fp64 = spmv_busy_cycles(1_000_000, Scheme::Fp64, 1.0) as i64;
        let mixed = spmv_busy_cycles(1_000_000, Scheme::MixV3, 1.0) as i64;
        assert!((fp64 - 2 * mixed).abs() <= 2, "fp64={fp64} mixed={mixed}");
    }

    #[test]
    fn callipepla_faster_than_xcgsolver_per_iteration() {
        let cal = AccelSimConfig::callipepla();
        let xcg = AccelSimConfig::xcgsolver();
        let tc = iteration_cycles(&cal, N, NNZ).total as f64 * cal.hbm.cycle_time();
        let tx = iteration_cycles(&xcg, N, NNZ).total as f64 * xcg.hbm.cycle_time();
        let speedup = tx / tc;
        // Table 4 geomean per-iteration gap is ~2-4x (the rest of the
        // solver-time gap comes from iteration counts).
        assert!(speedup > 1.5 && speedup < 8.0, "speedup={speedup}");
    }

    #[test]
    fn gpu_has_launch_floor_on_small_problems() {
        // ~8 launches x 6us: small problems cannot go below ~48us/iter.
        let t_small = gpu_iteration_seconds(3_000, 100_000);
        assert!(t_small > 45e-6, "t={t_small}");
        // Large problems are bandwidth-dominated.
        let t_large = gpu_iteration_seconds(1_500_000, 100_000_000);
        assert!(t_large > 5.0 * t_small, "t_large={t_large}");
    }

    #[test]
    fn gpu_vs_fpga_crossover_matches_table4() {
        // Small matrix (M7-like): Callipepla wins.
        let cal = AccelSimConfig::callipepla();
        let fpga_small = solver_seconds(&cal, 2_910, 174_296, 1_705);
        let gpu_small = gpu_solver_seconds(2_910, 174_296, 1_716);
        assert!(fpga_small < gpu_small, "fpga={fpga_small} gpu={gpu_small}");
        // Large matrix (M33-like): A100 wins.
        let fpga_large = solver_seconds(&cal, 1_437_960, 60_236_322, 2_053);
        let gpu_large = gpu_solver_seconds(1_437_960, 60_236_322, 2_052);
        assert!(gpu_large < fpga_large, "fpga={fpga_large} gpu={gpu_large}");
    }

    #[test]
    fn solver_seconds_scale_with_iterations() {
        let cfg = AccelSimConfig::callipepla();
        let t1 = solver_seconds(&cfg, N, NNZ, 100);
        let t2 = solver_seconds(&cfg, N, NNZ, 200);
        assert!((t2 / t1 - 2.0).abs() < 0.02);
    }
}
