//! Per-iteration cycle model: turns (matrix, accelerator config) into
//! cycles/iteration and solver seconds.
//!
//! The VSR (Fig. 5) phase graphs are **derived from the compiled
//! instruction program** via [`Dataflow::from_program`] — the same
//! Type-I/II/III steps the value plane executes, so the cycle model's
//! node/FIFO graph, channels and issue counts cannot drift from the
//! ISA.  Channels and addresses come from the program's
//! [`HbmMemoryMap`](crate::program::HbmMemoryMap): 0-15 nnz streams,
//! 16 the Jacobi diagonal M, then a channel pair per long vector under
//! the §5.7 policy.  The no-VSR baseline (§5.5 store-everything,
//! kernel-sequential like XcgSolver) is deliberately *not* program
//! driven — it models the machine that lacks the ISA schedule — and
//! keeps its hand-built per-module passes.

use crate::hbm::HbmConfig;
use crate::precision::Scheme;
use crate::program::{BatchId, Program};
use crate::sparse::{NUM_CHANNELS, PES_PER_CHANNEL};
use crate::vsr::Phase;

use super::dataflow::{Dataflow, SimError};

/// f64 lanes per 64-byte beat.
const LANES: u64 = 8;
/// M5 left-divide pipeline depth (Fig. 7: L = 33) — canonically defined
/// next to the other module micro-architecture tables in `program`.
pub use crate::program::M5_DEPTH;
/// Dot-product Phase-II tail: II=5 over the 8-lane delay buffer.
pub const DOT_TAIL: u64 = 5 * 8;
/// Per-phase control overhead (instruction issue + FSM transitions).
pub const PHASE_OVERHEAD: u64 = 32;

/// Simulation-facing accelerator description.
#[derive(Debug, Clone, Copy)]
pub struct AccelSimConfig {
    /// HBM channel count, frequency, and channel policy (Table 2).
    pub hbm: HbmConfig,
    /// Vector streaming reuse + decentralized scheduling (§5) on?
    pub vsr: bool,
    /// SpMV precision scheme (drives nnz stream bytes).
    pub scheme: Scheme,
    /// nnz-stream padding factor from the hazard scheduler
    /// (sparse::NnzStream::padding_factor, or an estimate).
    pub nnz_padding: f64,
    /// Fixed overhead per module *invocation* (kernel-sequential designs
    /// like XcgSolver pay this 8x per iteration; streaming designs ~0).
    pub invoke_overhead: u64,
}

impl AccelSimConfig {
    /// The Callipepla build: VSR + Mix-V3 + double channels.
    pub fn callipepla() -> Self {
        Self {
            hbm: HbmConfig::callipepla(),
            vsr: true,
            scheme: Scheme::MixV3,
            nnz_padding: 1.06,
            invoke_overhead: 0,
        }
    }

    /// The SerpensCG comparator: FP64 stream, no VSR reuse graph.
    pub fn serpenscg() -> Self {
        Self {
            hbm: HbmConfig::serpenscg(),
            vsr: false,
            scheme: Scheme::Fp64,
            nnz_padding: 1.06,
            // Without decentralized scheduling the central controller
            // sequences each module's memory-to-memory pass; the
            // per-pass turnaround is what VSR + the FSMs remove.
            // Calibrated against Table 4 M4: ~98 us/iter at n=10605.
            invoke_overhead: 1300,
        }
    }

    /// The XcgSolver comparator: kernel-sequential, padded accumulator.
    pub fn xcgsolver() -> Self {
        Self {
            hbm: HbmConfig::xcgsolver(),
            vsr: false,
            scheme: Scheme::Fp64,
            // FP-add-latency zero padding (§7.5.1) costs more slots.
            nnz_padding: 1.35,
            // Vitis kernel-sequential invocation overhead, per module
            // (calibrated: Table 4 M4 gives ~98 us/iter at n=10605).
            invoke_overhead: 1300,
        }
    }
}

/// Cycle breakdown of one JPCG iteration.
#[derive(Debug, Clone, Copy, Default)]
pub struct IterationBreakdown {
    /// Fig. 5 phase-1 cycles (SpMV + pap dot).
    pub phase1: u64,
    /// Phase-2 cycles (r update / rr / z / rz chain).
    pub phase2: u64,
    /// Phase-3 cycles (z recompute + p / x updates).
    pub phase3: u64,
    /// Whole-iteration cycles (the three phases summed).
    pub total: u64,
}

fn beats(n: usize) -> u64 {
    (n as u64).div_ceil(LANES)
}

/// Scheduled SpMV busy cycles: nnz spread over 16 channels x 8 PEs with
/// the hazard-padding factor; FP64 nnz occupy two 64-bit slots (§2.3.3),
/// halving effective PE throughput.
pub fn spmv_busy_cycles(nnz: usize, scheme: Scheme, padding: f64) -> u64 {
    let slot_factor = if scheme.matrix_f32() { 1.0 } else { 2.0 };
    let lanes = (NUM_CHANNELS * PES_PER_CHANNEL) as f64;
    (nnz as f64 * padding * slot_factor / lanes).ceil() as u64
}

// Channel ids for the *no-VSR* baseline machine (the VSR graphs get
// their channels from the compiled program's memory map).
const CH_M: usize = 16;
const CH_AP: usize = 17;
const CH_AP2: usize = 18;
const CH_P: usize = 19;
const CH_X: usize = 21;
const CH_R: usize = 23;
const TOTAL_CH: usize = 32;

const FIFO_DEPTH: usize = 64; // default stream FIFO depth
const LIMIT: u64 = 500_000_000;

/// One VSR iteration: the three Fig. 5 phase graphs, each derived from
/// the compiled instruction program (same steps as the value plane).
fn iteration_vsr(cfg: &AccelSimConfig, n: usize, nnz: usize) -> IterationBreakdown {
    batched_iteration_cycles(cfg, n, nnz, 1)
}

/// How a batched iteration's Type-II SpMV trips price in the time
/// plane — mirroring the three execution modes the value plane
/// implements for `Coordinator::solve_batch*`
/// (`CoordinatorConfig::block`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum BatchSpmvMode {
    /// Resident block-CG execution (`BlockMode::Resident`): the nnz
    /// stream is decoded **once per batched iteration** and every
    /// active lane's y is fed from that single pass
    /// (`precision::spmv_scheme_rows_block`), so the per-lane SpMV busy
    /// windows genuinely overlap — and the lane-major block is the
    /// resident vector representation, so no elements cross the block
    /// boundary in steady state (PERF §12).  This is the default and
    /// the pricing [`batched_iteration_cycles`] has always used —
    /// previously an *assumption* about the batch axis, now earned by
    /// the value plane's `batch_spmv` kernel and resident arenas.
    #[default]
    Block,
    /// Staged block-CG execution (`BlockMode::Staged`): one nnz pass
    /// per iteration like [`BatchSpmvMode::Block`], but the lane-major
    /// block is re-materialized around every pass — a gather of p and a
    /// scatter of ap, `2·n·batch` element moves per iteration, priced
    /// as `2·beats(n)·batch` extra phase-1 cycles.  A single-lane batch
    /// short-circuits to per-lane dispatch in the value plane, so at
    /// `batch == 1` this prices identically to the other modes.
    Staged,
    /// Per-lane execution (block mode off): each lane's M1 streams the
    /// nnz arrays on its own trip, so the matrix port is time-shared
    /// and the iteration carries `batch` back-to-back SpMV busy
    /// windows instead of one.
    PerLane,
}

/// Cycles for one **batched** VSR iteration: the three phase graphs of
/// a program compiled over `batch` RHS lanes
/// ([`Dataflow::from_batched_program`]).  Lane vector streams contend
/// on the shared channel pairs while the SpMV busy windows overlap (the
/// nnz stream prices once per iteration — [`BatchSpmvMode::Block`],
/// the execution mode the value plane's block-CG kernel implements),
/// and the per-trip control overhead is paid once per batched trip —
/// the instruction-stream amortization the batch axis buys.
///
/// A non-VSR config has no compiled program to batch: `batch` must be
/// 1 there, and the call falls back to [`iteration_cycles`]'s
/// kernel-sequential pricing (so the two APIs always agree at the
/// single-RHS base case).
pub fn batched_iteration_cycles(
    cfg: &AccelSimConfig,
    n: usize,
    nnz: usize,
    batch: BatchId,
) -> IterationBreakdown {
    batched_iteration_cycles_mode(cfg, n, nnz, batch, BatchSpmvMode::Block)
}

/// [`batched_iteration_cycles`] with the SpMV execution mode explicit.
/// [`BatchSpmvMode::Block`] reproduces it exactly;
/// [`BatchSpmvMode::Staged`] adds the gather/scatter boundary traffic
/// of the staged block path (`2·beats(n)·batch` phase-1 cycles);
/// [`BatchSpmvMode::PerLane`] widens the SpMV busy window to
/// `batch x spmv_busy_cycles` — the matrix port is time-shared across
/// the lanes' M1 trips, so batching still amortizes the instruction
/// stream and control overhead but not the nnz traffic.  All three
/// modes agree at `batch == 1`.
pub fn batched_iteration_cycles_mode(
    cfg: &AccelSimConfig,
    n: usize,
    nnz: usize,
    batch: BatchId,
    mode: BatchSpmvMode,
) -> IterationBreakdown {
    if !cfg.vsr {
        assert!(
            batch <= 1,
            "batched trips require the compiled VSR program (cfg.vsr); \
             the kernel-sequential baseline has no batch axis"
        );
        return iteration_cycles(cfg, n, nnz);
    }
    let batch = batch.max(1);
    let program = Program::compile_batched(n as u32, cfg.hbm.vector_mode, batch);
    let mut busy = spmv_busy_cycles(nnz, cfg.scheme, cfg.nnz_padding);
    if mode == BatchSpmvMode::PerLane {
        busy *= batch as u64;
    }
    let cycles =
        |p: Phase| run_phase(Dataflow::from_batched_program(program.phase(p), program.batch, busy));
    let mut p1 = cycles(Phase::Phase1) + PHASE_OVERHEAD;
    if mode == BatchSpmvMode::Staged && batch > 1 {
        // Re-materializing the lane-major block around the pass: gather
        // p in, scatter ap out — one channel beat per 8 lanes' worth of
        // elements, per lane (mirrors the value plane's 2·n·L counter).
        p1 += 2 * beats(n) * batch as u64;
    }
    let p2 = cycles(Phase::Phase2) + PHASE_OVERHEAD;
    let p3 = cycles(Phase::Phase3) + PHASE_OVERHEAD;
    IterationBreakdown { phase1: p1, phase2: p2, phase3: p3, total: p1 + p2 + p3 }
}

/// Cycles for one batched iteration under **lane-parallel dispatch**:
/// the controller fans each trip's per-lane instruction streams across
/// `workers` issue slots, so lanes advance in waves of at most
/// `workers` lanes.  A wave's lanes execute concurrently and contend on
/// the shared channel pairs — priced exactly as
/// [`batched_iteration_cycles`] of the wave size — while the waves of
/// one trip serialize, and the trip barrier is preserved (the Fig. 4
/// schedule is unchanged, matching the value plane's
/// `Coordinator::solve_batch_parallel`).  `workers >= batch` is the
/// fully-parallel case and equals [`batched_iteration_cycles`];
/// `workers == 1` prices the sequential lane walk of the oracle path.
pub fn lane_parallel_iteration_cycles(
    cfg: &AccelSimConfig,
    n: usize,
    nnz: usize,
    batch: BatchId,
    workers: usize,
) -> IterationBreakdown {
    let batch = batch.max(1);
    let mut per_wave = workers.max(1) as BatchId;
    if per_wave > batch {
        per_wave = batch;
    }
    // Memoize per wave shape: 17 lanes at 8 workers is waves of
    // 8, 8, 1 — two simulations, not three.
    let mut shapes: std::collections::HashMap<BatchId, IterationBreakdown> =
        std::collections::HashMap::new();
    let mut out = IterationBreakdown::default();
    let mut left = batch;
    while left > 0 {
        let wave = left.min(per_wave);
        let b = *shapes.entry(wave).or_insert_with(|| batched_iteration_cycles(cfg, n, nnz, wave));
        out.phase1 += b.phase1;
        out.phase2 += b.phase2;
        out.phase3 += b.phase3;
        out.total += b.total;
        left -= wave;
    }
    out
}

/// Modeled RHS-iterations/s under lane-parallel dispatch
/// ([`lane_parallel_iteration_cycles`]): `batch` lanes retire one JPCG
/// iteration each per batched trip sequence.
pub fn lane_parallel_rhs_iterations_per_second(
    cfg: &AccelSimConfig,
    n: usize,
    nnz: usize,
    batch: BatchId,
    workers: usize,
) -> f64 {
    let cycles = lane_parallel_iteration_cycles(cfg, n, nnz, batch, workers).total;
    batch.max(1) as f64 / (cycles as f64 * cfg.hbm.cycle_time())
}

/// Multi-RHS throughput of a batched program: right-hand-side
/// iterations retired per second (`batch` lanes advance one JPCG
/// iteration per batched trip sequence).
pub fn batched_rhs_iterations_per_second(
    cfg: &AccelSimConfig,
    n: usize,
    nnz: usize,
    batch: BatchId,
) -> f64 {
    let cycles = batched_iteration_cycles(cfg, n, nnz, batch).total;
    batch.max(1) as f64 / (cycles as f64 * cfg.hbm.cycle_time())
}

/// One executed scheduler batch to price on the time plane: `lanes`
/// right-hand sides of an (n, nnz) system advancing together for
/// `trips` batched JPCG iterations (the slowest lane's count — freed
/// lanes stop issuing but the batch retires with its stragglers).
/// The [`service`](crate::service) layer records one of these per
/// executed batch (`BatchRecord::scheduled`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScheduledBatch {
    /// Vector length of the batch's matrix.
    pub n: usize,
    /// Nonzeros of the batch's matrix.
    pub nnz: usize,
    /// Right-hand-side lanes the batch ran.
    pub lanes: BatchId,
    /// Batched iterations the batch executed (max over its lanes).
    pub trips: u64,
}

/// Price a whole scheduler trace: total modeled cycles to execute the
/// given batches back-to-back on one accelerator (batches of one
/// service run on one device, so they serialize).  Per-shape cycle
/// counts are memoized across the trace — a serving trace repeats few
/// (matrix, lane) shapes many times, the same redundancy the value
/// plane's [`ProgramCache`](crate::program::ProgramCache) removes.
pub fn schedule_cycles(cfg: &AccelSimConfig, batches: &[ScheduledBatch]) -> u64 {
    let mut per_shape: std::collections::HashMap<(usize, usize, BatchId), u64> =
        std::collections::HashMap::new();
    batches
        .iter()
        .map(|b| {
            let cycles = *per_shape
                .entry((b.n, b.nnz, b.lanes))
                .or_insert_with(|| batched_iteration_cycles(cfg, b.n, b.nnz, b.lanes).total);
            cycles * b.trips
        })
        .sum()
}

/// Without VSR (§5.5 baseline): every module is its own memory-to-memory
/// pass, serialized (XcgSolver's kernel-sequential execution; also the
/// SerpensCG data path, which has the ISA but not the reuse graph).
fn iteration_no_vsr(cfg: &AccelSimConfig, n: usize, nnz: usize) -> IterationBreakdown {
    let nb = beats(n);
    let busy = spmv_busy_cycles(nnz, cfg.scheme, cfg.nnz_padding);
    let ov = cfg.invoke_overhead;

    // Phase 1: M1 (rd p -> wr ap), then M2 (rd p, rd ap -> scalar).
    let m1 = {
        let mut df = Dataflow::new(TOTAL_CH);
        let p = df.fifo(FIFO_DEPTH);
        let ap = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_p", CH_P, nb, p);
        df.spmv("M1", p, nb, busy, nb, ap);
        df.mem_write("wr_ap", CH_AP, nb, ap);
        run_phase(df)
    };
    let m2 = {
        let mut df = Dataflow::new(TOTAL_CH);
        let p = df.fifo(FIFO_DEPTH);
        let ap = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_p", CH_P, nb, p);
        df.mem_read("rd_ap", CH_AP, nb, ap);
        df.dot("M2", vec![p, ap], nb, DOT_TAIL);
        run_phase(df)
    };
    let phase1 = m1 + m2 + 2 * ov;

    // Phase 2: M4 (rd r, rd ap -> wr r), M5 (rd r, rd M -> wr z),
    // M6 (rd r, rd z -> scalar), M8 (rd r -> scalar).
    let two_read_map = |ch_a: usize, ch_b: usize, ch_o: usize, depth: usize| {
        let mut df = Dataflow::new(TOTAL_CH);
        let a = df.fifo(FIFO_DEPTH);
        let b = df.fifo(FIFO_DEPTH);
        let o = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_a", ch_a, nb, a);
        df.mem_read("rd_b", ch_b, nb, b);
        df.pipe("map", vec![a, b], vec![(depth - 1, o)], depth, nb);
        df.mem_write("wr_o", ch_o, nb, o);
        run_phase(df)
    };
    // z lives in ap's spare channel in the no-VSR design (it must be
    // stored somewhere; the paper's point is it costs a channel).
    let ch_z = CH_AP2;
    let m4 = two_read_map(CH_R, CH_AP, CH_R, 8);
    let m5 = two_read_map(CH_R, CH_M, ch_z, M5_DEPTH);
    let m6 = {
        let mut df = Dataflow::new(TOTAL_CH);
        let r = df.fifo(FIFO_DEPTH);
        let z = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_r", CH_R, nb, r);
        df.mem_read("rd_z", ch_z, nb, z);
        df.dot("M6", vec![r, z], nb, DOT_TAIL);
        run_phase(df)
    };
    let m8 = {
        let mut df = Dataflow::new(TOTAL_CH);
        let r = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_r", CH_R, nb, r);
        df.dot("M8", vec![r], nb, DOT_TAIL);
        run_phase(df)
    };
    let phase2 = m4 + m5 + m6 + m8 + 4 * ov;

    // Phase 3: M7 (rd z, rd p -> wr p), M3 (rd p, rd x -> wr x).
    let m7 = two_read_map(ch_z, CH_P, CH_P, 8);
    let m3 = two_read_map(CH_P, CH_X, CH_X, 8);
    let phase3 = m7 + m3 + 2 * ov;

    IterationBreakdown { phase1, phase2, phase3, total: phase1 + phase2 + phase3 }
}

fn run_phase(mut df: Dataflow) -> u64 {
    match df.run(LIMIT) {
        Ok(stats) => stats.cycles,
        Err(SimError::Deadlock { cycle, stuck }) => {
            panic!("phase graph deadlocked at {cycle}: {stuck:?}")
        }
        Err(e) => panic!("phase simulation failed: {e}"),
    }
}

/// Cycles for one JPCG iteration under a configuration.
pub fn iteration_cycles(cfg: &AccelSimConfig, n: usize, nnz: usize) -> IterationBreakdown {
    if cfg.vsr {
        iteration_vsr(cfg, n, nnz)
    } else {
        let mut b = iteration_no_vsr(cfg, n, nnz);
        b.phase1 += PHASE_OVERHEAD;
        b.phase2 += PHASE_OVERHEAD;
        b.phase3 += PHASE_OVERHEAD;
        b.total = b.phase1 + b.phase2 + b.phase3;
        b
    }
}

/// FPGA solver seconds: per-iteration cycles x iteration count, plus the
/// Alg. 1 init pass (~ one iteration).
pub fn solver_seconds(cfg: &AccelSimConfig, n: usize, nnz: usize, iters: u32) -> f64 {
    let per_iter = iteration_cycles(cfg, n, nnz).total;
    let cycles = per_iter as f64 * (iters as f64 + 1.0);
    cycles * cfg.hbm.cycle_time()
}

/// Total modeled cycles for a solve whose per-pass precision followed a
/// recorded [`PrecisionTrace`]: pass `p` (0 = the Alg. 1 init SpMV,
/// `1..=iters` the Phase-1 trips) is priced with its **active scheme's**
/// nnz stream width — `trace.scheme_at(p)` overrides `cfg.scheme` for
/// that pass, so an adaptive solve that ran most passes in Mix-V3 and
/// escalated to FP64 late pays the wide M1 beats only for the FP64
/// tail.  A static trace (one event) degenerates to
/// `(iters + 1) x iteration_cycles` of that scheme.  Per-scheme
/// iteration cycles are memoized, so a solve with `k` distinct schemes
/// runs `k` phase-graph simulations, not `iters + 1`.
pub fn traced_solver_cycles(
    cfg: &AccelSimConfig,
    n: usize,
    nnz: usize,
    iters: u32,
    trace: &crate::precision::adaptive::PrecisionTrace,
) -> u64 {
    // Scheme has no Hash; index the memo by its 3-bit wire code.
    let mut per_scheme: [Option<u64>; 4] = [None; 4];
    let mut total = 0u64;
    for pass in 0..=iters {
        let scheme = trace.scheme_at(pass);
        let slot = &mut per_scheme[scheme.wire_code() as usize];
        let cycles = match *slot {
            Some(c) => c,
            None => {
                let mut pass_cfg = *cfg;
                pass_cfg.scheme = scheme;
                let c = iteration_cycles(&pass_cfg, n, nnz).total;
                *slot = Some(c);
                c
            }
        };
        total += cycles;
    }
    total
}

/// [`traced_solver_cycles`] in seconds — the trace-aware counterpart of
/// [`solver_seconds`].  With a single-scheme trace matching
/// `cfg.scheme` the two agree exactly.
pub fn traced_solver_seconds(
    cfg: &AccelSimConfig,
    n: usize,
    nnz: usize,
    iters: u32,
    trace: &crate::precision::adaptive::PrecisionTrace,
) -> f64 {
    traced_solver_cycles(cfg, n, nnz, iters, trace) as f64 * cfg.hbm.cycle_time()
}

// --------------------------------------------------------------------
// A100 GPU analytic model (§7.2.2's explanation, quantified).
// --------------------------------------------------------------------

/// A100 JPCG iteration time: 8 kernel launches (cuSPARSE SpMV + 3 cuBLAS
/// dots + 3 axpy-class + 1 copy/scal), each bandwidth-bound with a fixed
/// launch overhead — the small-matrix floor the paper observes.
pub fn gpu_iteration_seconds(n: usize, nnz: usize) -> f64 {
    const BW: f64 = 1.56e12; // Table 2
    const LAUNCH: f64 = 6.0e-6; // CUDA launch + sync overhead
    let vec_bytes = 8.0 * n as f64;
    // cuSPARSE CSR FP64 SpMV: vals 8B + col 4B per nnz, row ptr, x + y.
    let spmv = LAUNCH + (12.0 * nnz as f64 + 3.0 * vec_bytes) / BW;
    let dot = LAUNCH + 2.0 * vec_bytes / BW;
    let axpy = LAUNCH + 3.0 * vec_bytes / BW;
    spmv + 3.0 * dot + 4.0 * axpy
}

/// A100 solver seconds.
pub fn gpu_solver_seconds(n: usize, nnz: usize, iters: u32) -> f64 {
    gpu_iteration_seconds(n, nnz) * (iters as f64 + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::ChannelMode;

    const N: usize = 16_384;
    const NNZ: usize = 320_000;

    #[test]
    fn vsr_phases_complete_without_deadlock() {
        let cfg = AccelSimConfig::callipepla();
        let b = iteration_cycles(&cfg, N, NNZ);
        assert!(b.phase1 > 0 && b.phase2 > 0 && b.phase3 > 0);
        assert_eq!(b.total, b.phase1 + b.phase2 + b.phase3);
    }

    #[test]
    fn vsr_beats_no_vsr() {
        // §5.5: 14 vs 19 accesses + overlap => fewer cycles per iteration.
        let cal = AccelSimConfig::callipepla();
        let mut no_vsr = cal;
        no_vsr.vsr = false;
        let with = iteration_cycles(&cal, N, NNZ).total;
        let without = iteration_cycles(&no_vsr, N, NNZ).total;
        assert!(
            (without as f64) > 1.3 * with as f64,
            "with={with} without={without}"
        );
    }

    #[test]
    fn mixed_precision_halves_spmv_cycles() {
        let fp64 = spmv_busy_cycles(1_000_000, Scheme::Fp64, 1.0) as i64;
        let mixed = spmv_busy_cycles(1_000_000, Scheme::MixV3, 1.0) as i64;
        assert!((fp64 - 2 * mixed).abs() <= 2, "fp64={fp64} mixed={mixed}");
    }

    #[test]
    fn callipepla_faster_than_xcgsolver_per_iteration() {
        let cal = AccelSimConfig::callipepla();
        let xcg = AccelSimConfig::xcgsolver();
        let tc = iteration_cycles(&cal, N, NNZ).total as f64 * cal.hbm.cycle_time();
        let tx = iteration_cycles(&xcg, N, NNZ).total as f64 * xcg.hbm.cycle_time();
        let speedup = tx / tc;
        // Table 4 geomean per-iteration gap is ~2-4x (the rest of the
        // solver-time gap comes from iteration counts).
        assert!(speedup > 1.5 && speedup < 8.0, "speedup={speedup}");
    }

    #[test]
    fn gpu_has_launch_floor_on_small_problems() {
        // ~8 launches x 6us: small problems cannot go below ~48us/iter.
        let t_small = gpu_iteration_seconds(3_000, 100_000);
        assert!(t_small > 45e-6, "t={t_small}");
        // Large problems are bandwidth-dominated.
        let t_large = gpu_iteration_seconds(1_500_000, 100_000_000);
        assert!(t_large > 5.0 * t_small, "t_large={t_large}");
    }

    #[test]
    fn gpu_vs_fpga_crossover_matches_table4() {
        // Small matrix (M7-like): Callipepla wins.
        let cal = AccelSimConfig::callipepla();
        let fpga_small = solver_seconds(&cal, 2_910, 174_296, 1_705);
        let gpu_small = gpu_solver_seconds(2_910, 174_296, 1_716);
        assert!(fpga_small < gpu_small, "fpga={fpga_small} gpu={gpu_small}");
        // Large matrix (M33-like): A100 wins.
        let fpga_large = solver_seconds(&cal, 1_437_960, 60_236_322, 2_053);
        let gpu_large = gpu_solver_seconds(1_437_960, 60_236_322, 2_052);
        assert!(gpu_large < fpga_large, "fpga={fpga_large} gpu={gpu_large}");
    }

    #[test]
    fn solver_seconds_scale_with_iterations() {
        let cfg = AccelSimConfig::callipepla();
        let t1 = solver_seconds(&cfg, N, NNZ, 100);
        let t2 = solver_seconds(&cfg, N, NNZ, 200);
        assert!((t2 / t1 - 2.0).abs() < 0.02);
    }

    // ------------------------------------------------------------------
    // Program-derived graphs vs hand-built equivalents.  The hand
    // graphs below replicate the compiled Fig. 5 topologies (channels,
    // FIFO depths, canonical node order) with raw Dataflow primitives;
    // cycle counts must match exactly — this pins `from_program`'s
    // wiring as a contract.
    // ------------------------------------------------------------------

    fn run(mut df: Dataflow) -> (u64, Vec<Option<u64>>) {
        let stats = df.run(LIMIT).unwrap();
        (stats.cycles, stats.node_done_at)
    }

    fn hand_phase1(nb: u64, busy: u64) -> Dataflow {
        let mut df = Dataflow::new(TOTAL_CH);
        let ap_fork_in = df.fifo(FIFO_DEPTH);
        let ap_m2 = df.fifo(FIFO_DEPTH);
        let ap_wr = df.fifo(FIFO_DEPTH);
        let p_m1 = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_p@M1", 19, nb, p_m1);
        df.spmv("M1", p_m1, nb, busy, nb, ap_fork_in);
        df.pipe("fork_ap", vec![ap_fork_in], vec![(0, ap_m2), (0, ap_wr)], 1, nb);
        let p_m2 = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_p@M2", 20, nb, p_m2);
        df.dot("M2", vec![p_m2, ap_m2], nb, DOT_TAIL);
        df.mem_write("wr_ap", 18, nb, ap_wr);
        df
    }

    fn hand_phase2(nb: u64) -> Dataflow {
        let mut df = Dataflow::new(TOTAL_CH);
        // Pass-1 FIFOs in comp order M4, M8, M5, M6.
        let r_m4_m5 = df.fifo(FIFO_DEPTH);
        let z_m5_m6 = df.fifo(FIFO_DEPTH);
        let r_m5_m6 = df.fifo(M5_DEPTH + 1); // fast FIFO, Fig. 7(b)
        let r_m6_m8 = df.fifo(FIFO_DEPTH);
        // Pass-2 nodes: reads precede their consumer; M8 hoisted.
        let r_in = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_r@M4", 23, nb, r_in);
        let ap_in = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_ap@M4", 17, nb, ap_in);
        df.pipe("M4", vec![r_in, ap_in], vec![(7, r_m4_m5)], 8, nb);
        df.dot("M8", vec![r_m6_m8], nb, DOT_TAIL);
        let m_in = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_M@M5", 16, nb, m_in);
        df.pipe(
            "M5",
            vec![m_in, r_m4_m5],
            vec![(M5_DEPTH - 1, z_m5_m6), (0, r_m5_m6)],
            M5_DEPTH,
            nb,
        );
        df.pipe("M6", vec![r_m5_m6, z_m5_m6], vec![(4, r_m6_m8)], 5, nb);
        df
    }

    fn hand_phase3(nb: u64) -> Dataflow {
        let mut df = Dataflow::new(TOTAL_CH);
        // Pass-1 FIFOs in comp order M4, M5, M7, M3.
        let r_m4_m5 = df.fifo(FIFO_DEPTH);
        let z_m5_m7 = df.fifo(FIFO_DEPTH);
        let r_m5_wr = df.fifo(M5_DEPTH + 1);
        let p_fork_in = df.fifo(FIFO_DEPTH);
        let p_m3 = df.fifo(FIFO_DEPTH);
        let p_wr = df.fifo(FIFO_DEPTH);
        let x_wr = df.fifo(FIFO_DEPTH);
        // Pass-2 nodes.
        let r_in = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_r@M4", 23, nb, r_in);
        let ap_in = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_ap@M4", 17, nb, ap_in);
        df.pipe("M4", vec![r_in, ap_in], vec![(7, r_m4_m5)], 8, nb);
        let m_in = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_M@M5", 16, nb, m_in);
        df.pipe(
            "M5",
            vec![m_in, r_m4_m5],
            vec![(M5_DEPTH - 1, z_m5_m7), (0, r_m5_wr)],
            M5_DEPTH,
            nb,
        );
        let p_in = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_p@M7", 19, nb, p_in);
        df.pipe("M7", vec![z_m5_m7, p_in], vec![(7, p_fork_in)], 8, nb);
        df.pipe("fork_p", vec![p_fork_in], vec![(0, p_m3), (0, p_wr)], 1, nb);
        let x_in = df.fifo(FIFO_DEPTH);
        df.mem_read("rd_x@M3", 21, nb, x_in);
        df.pipe("M3", vec![x_in, p_m3], vec![(7, x_wr)], 8, nb);
        // Writes last, in vector-control order (p, r, x).
        df.mem_write("wr_p", 20, nb, p_wr);
        df.mem_write("wr_r", 24, nb, r_m5_wr);
        df.mem_write("wr_x", 22, nb, x_wr);
        df
    }

    #[test]
    fn from_program_matches_hand_built_graphs() {
        let n = 16_384usize;
        let nb = beats(n);
        let busy = spmv_busy_cycles(320_000, Scheme::MixV3, 1.06);
        let program = Program::compile(n as u32, ChannelMode::Double);
        for (phase, hand) in [
            (Phase::Phase1, hand_phase1(nb, busy)),
            (Phase::Phase2, hand_phase2(nb)),
            (Phase::Phase3, hand_phase3(nb)),
        ] {
            let derived = Dataflow::from_program(program.phase(phase), busy);
            let (dc, dd) = run(derived);
            let (hc, hd) = run(hand);
            assert_eq!(dc, hc, "{phase:?} cycle count drifted from hand-built graph");
            assert_eq!(dd, hd, "{phase:?} per-node completion drifted");
        }
    }

    #[test]
    fn batched_iteration_amortizes_the_instruction_stream() {
        let cfg = AccelSimConfig::callipepla();
        let single = batched_iteration_cycles(&cfg, N, NNZ, 1);
        assert_eq!(single.total, iteration_cycles(&cfg, N, NNZ).total, "batch=1 is the base case");
        let b4 = batched_iteration_cycles(&cfg, N, NNZ, 4);
        // Four lanes cost more than one (the vector streams contend on
        // the shared channel pairs) but less than four full iterations:
        // the SpMV busy window overlaps across lanes and the per-trip
        // overhead is paid once per batched trip.
        assert!(b4.total > single.total, "b4={} single={}", b4.total, single.total);
        assert!(
            b4.total < 4 * single.total,
            "no amortization: b4={} 4x single={}",
            b4.total,
            4 * single.total
        );
        // Which is exactly a throughput win per right-hand side.
        let t1 = batched_rhs_iterations_per_second(&cfg, N, NNZ, 1);
        let t4 = batched_rhs_iterations_per_second(&cfg, N, NNZ, 4);
        assert!(t4 > t1, "t4={t4} t1={t1}");
    }

    #[test]
    fn per_lane_mode_prices_the_time_shared_matrix_port() {
        let cfg = AccelSimConfig::callipepla();
        // Block mode is the default pricing, bit for bit.
        for batch in [1, 4, 8] {
            let block = batched_iteration_cycles_mode(&cfg, N, NNZ, batch, BatchSpmvMode::Block);
            assert_eq!(block.total, batched_iteration_cycles(&cfg, N, NNZ, batch).total);
        }
        // The two modes agree at batch 1 (one lane, one nnz pass either
        // way) and diverge as soon as lanes share the matrix port.
        let b1_block = batched_iteration_cycles_mode(&cfg, N, NNZ, 1, BatchSpmvMode::Block);
        let b1_per = batched_iteration_cycles_mode(&cfg, N, NNZ, 1, BatchSpmvMode::PerLane);
        assert_eq!(b1_block.total, b1_per.total);
        for batch in [2, 4, 8] {
            let block = batched_iteration_cycles_mode(&cfg, N, NNZ, batch, BatchSpmvMode::Block);
            let per = batched_iteration_cycles_mode(&cfg, N, NNZ, batch, BatchSpmvMode::PerLane);
            assert!(
                per.total > block.total,
                "batch={batch}: per-lane {} !> block {}",
                per.total,
                block.total
            );
        }
    }

    #[test]
    fn staged_mode_prices_the_block_boundary_traffic() {
        let cfg = AccelSimConfig::callipepla();
        // All three modes agree at batch 1: a single-lane batch
        // short-circuits to per-lane dispatch in the value plane.
        let b1 = batched_iteration_cycles_mode(&cfg, N, NNZ, 1, BatchSpmvMode::Block);
        for mode in [BatchSpmvMode::Staged, BatchSpmvMode::PerLane] {
            let other = batched_iteration_cycles_mode(&cfg, N, NNZ, 1, mode);
            assert_eq!(b1.total, other.total, "{mode:?} diverged at batch 1");
        }
        // Staged = resident + exactly the gather/scatter beats, in
        // phase 1 — the traffic the resident arenas remove.
        for batch in [2, 4, 8] {
            let res = batched_iteration_cycles_mode(&cfg, N, NNZ, batch, BatchSpmvMode::Block);
            let staged = batched_iteration_cycles_mode(&cfg, N, NNZ, batch, BatchSpmvMode::Staged);
            let boundary = 2 * beats(N) * batch as u64;
            assert_eq!(staged.phase1, res.phase1 + boundary, "batch={batch} phase1");
            assert_eq!(staged.phase2, res.phase2, "batch={batch} phase2");
            assert_eq!(staged.phase3, res.phase3, "batch={batch} phase3");
            assert_eq!(staged.total, res.total + boundary, "batch={batch} total");
        }
    }

    #[test]
    fn batched_cycles_agree_with_iteration_cycles_for_non_vsr() {
        // A non-VSR machine has no batch axis: the batched API must fall
        // back to the same kernel-sequential pricing, not silently build
        // a VSR graph the config says the machine lacks.
        for cfg in [AccelSimConfig::xcgsolver(), AccelSimConfig::serpenscg()] {
            let batched = batched_iteration_cycles(&cfg, N, NNZ, 1);
            let base = iteration_cycles(&cfg, N, NNZ);
            assert_eq!(batched.total, base.total);
        }
    }

    #[test]
    fn batched_graphs_simulate_all_trips_cleanly() {
        // Every trip of a batched program — init and exit included —
        // must complete without deadlock at several lane counts.
        let program = Program::compile_batched(4_096, ChannelMode::Double, 3);
        let busy = spmv_busy_cycles(80_000, Scheme::MixV3, 1.06);
        for trip in program.all_trips() {
            let cycles = run_phase(Dataflow::from_batched_program(trip, program.batch, busy));
            assert!(cycles > 0, "{}", trip.kind.label());
        }
    }

    #[test]
    fn from_program_respects_channel_mode() {
        // Single-channel builds turn the read channel around for the
        // write-back; the phase-3 r/p/x round trips serialize and the
        // phase gets slower (§5.7's motivation).
        let program_d = Program::compile(N as u32, ChannelMode::Double);
        let program_s = Program::compile(N as u32, ChannelMode::Single);
        let p3d = run_phase(Dataflow::from_program(program_d.phase(Phase::Phase3), 0));
        let p3s = run_phase(Dataflow::from_program(program_s.phase(Phase::Phase3), 0));
        assert!(p3s > p3d, "single={p3s} double={p3d}");
    }

    #[test]
    fn lane_parallel_pricing_brackets_sequential_and_fully_batched() {
        let cfg = AccelSimConfig::callipepla();
        // workers >= batch degenerates to the fully batched dispatch.
        let full = batched_iteration_cycles(&cfg, N, NNZ, 8);
        assert_eq!(lane_parallel_iteration_cycles(&cfg, N, NNZ, 8, 8).total, full.total);
        assert_eq!(lane_parallel_iteration_cycles(&cfg, N, NNZ, 8, 16).total, full.total);
        // workers == 1 is the sequential lane walk: batch x one lane.
        let single = batched_iteration_cycles(&cfg, N, NNZ, 1).total;
        let seq = lane_parallel_iteration_cycles(&cfg, N, NNZ, 8, 1);
        assert_eq!(seq.total, 8 * single);
        // In between, waves serialize but amortize within themselves.
        let mid = lane_parallel_iteration_cycles(&cfg, N, NNZ, 8, 4);
        assert!(mid.total <= seq.total, "mid={} seq={}", mid.total, seq.total);
        assert!(mid.total >= full.total, "mid={} full={}", mid.total, full.total);
        // A 17-lane batch at 8 workers prices waves of 8, 8, 1.
        let b17 = lane_parallel_iteration_cycles(&cfg, N, NNZ, 17, 8).total;
        let want = 2 * batched_iteration_cycles(&cfg, N, NNZ, 8).total + single;
        assert_eq!(b17, want);
        // More workers -> more modeled throughput per right-hand side.
        let t1 = lane_parallel_rhs_iterations_per_second(&cfg, N, NNZ, 8, 1);
        let t8 = lane_parallel_rhs_iterations_per_second(&cfg, N, NNZ, 8, 8);
        assert!(t8 > t1, "t8={t8} t1={t1}");
    }

    #[test]
    fn schedule_pricing_sums_and_memoizes_batches() {
        let cfg = AccelSimConfig::callipepla();
        let one = ScheduledBatch { n: N, nnz: NNZ, lanes: 4, trips: 10 };
        let per_iter = batched_iteration_cycles(&cfg, N, NNZ, 4).total;
        assert_eq!(schedule_cycles(&cfg, &[one]), 10 * per_iter);
        // Repeated shapes price identically (memo hit) and sum linearly.
        let trace = [one, ScheduledBatch { trips: 3, ..one }];
        assert_eq!(schedule_cycles(&cfg, &trace), 13 * per_iter);
        assert_eq!(schedule_cycles(&cfg, &[]), 0);
    }

    #[test]
    fn deadline_narrowed_batches_price_worse_per_rhs_iteration() {
        // The latency/throughput trade the scheduler's deadline flush
        // (ServiceConfig::deadline) makes, priced on the time plane: a
        // deadline that cuts one full batch of 8 into two of 4 retires
        // the same RHS-iterations but pays the fixed per-trip costs
        // (invoke overhead, fill/drain) twice, so the narrowed schedule
        // is strictly more cycles — sub-linear lane scaling is the whole
        // reason coalescing wide is worth waiting for.
        let cfg = AccelSimConfig::callipepla();
        let wide = [ScheduledBatch { n: N, nnz: NNZ, lanes: 8, trips: 10 }];
        let narrowed = [
            ScheduledBatch { n: N, nnz: NNZ, lanes: 4, trips: 10 },
            ScheduledBatch { n: N, nnz: NNZ, lanes: 4, trips: 10 },
        ];
        let wide_cycles = schedule_cycles(&cfg, &wide);
        let narrowed_cycles = schedule_cycles(&cfg, &narrowed);
        assert!(
            narrowed_cycles > wide_cycles,
            "narrowed={narrowed_cycles} wide={wide_cycles}"
        );
        // But both beat serving the lanes one at a time — a deadline
        // flush still coalesces, it just bounds how long it waits.
        let singles: Vec<ScheduledBatch> =
            (0..8).map(|_| ScheduledBatch { n: N, nnz: NNZ, lanes: 1, trips: 10 }).collect();
        assert!(narrowed_cycles < schedule_cycles(&cfg, &singles));
    }

    #[test]
    fn traced_pricing_matches_static_and_brackets_adaptive() {
        use crate::precision::adaptive::{PrecisionEvent, PrecisionTrace, SwitchReason};
        let cfg = AccelSimConfig::callipepla();
        let iters = 200u32;

        // A single-event trace at the config's own scheme is exactly
        // the untraced pricing.
        let mut static_mix = PrecisionTrace::default();
        static_mix.push(PrecisionEvent {
            pass: 0,
            scheme: Scheme::MixV3,
            reason: SwitchReason::Static,
        });
        let mix_cycles = traced_solver_cycles(&cfg, N, NNZ, iters, &static_mix);
        let untraced = iteration_cycles(&cfg, N, NNZ).total * (iters as u64 + 1);
        assert_eq!(mix_cycles, untraced);
        let secs = traced_solver_seconds(&cfg, N, NNZ, iters, &static_mix);
        assert!((secs - solver_seconds(&cfg, N, NNZ, iters)).abs() < 1e-12);

        // Static FP64 pays the wide M1 beats every pass.
        let mut static_fp64 = PrecisionTrace::default();
        static_fp64.push(PrecisionEvent {
            pass: 0,
            scheme: Scheme::Fp64,
            reason: SwitchReason::Static,
        });
        let fp64_cycles = traced_solver_cycles(&cfg, N, NNZ, iters, &static_fp64);
        assert!(fp64_cycles > mix_cycles, "fp64={fp64_cycles} mix={mix_cycles}");

        // An adaptive trace that escalates at pass 150 lands strictly
        // between the two static envelopes.
        let mut adaptive = static_mix.clone();
        adaptive.push(PrecisionEvent {
            pass: 150,
            scheme: Scheme::Fp64,
            reason: SwitchReason::Stall,
        });
        let ad_cycles = traced_solver_cycles(&cfg, N, NNZ, iters, &adaptive);
        assert!(
            mix_cycles < ad_cycles && ad_cycles < fp64_cycles,
            "mix={mix_cycles} adaptive={ad_cycles} fp64={fp64_cycles}"
        );
        // And is exactly the per-pass sum of the two scheme prices.
        let mix_iter = mix_cycles / (iters as u64 + 1);
        let fp64_iter = fp64_cycles / (iters as u64 + 1);
        assert_eq!(ad_cycles, 150 * mix_iter + 51 * fp64_iter);
    }

    #[test]
    fn init_and_exit_trips_simulate_cleanly() {
        // The merged-init and converged-exit trips are programs too —
        // their graphs must complete without deadlock.
        let program = Program::compile(N as u32, ChannelMode::Double);
        let busy = spmv_busy_cycles(NNZ, Scheme::MixV3, 1.06);
        let init = run_phase(Dataflow::from_program(&program.init, busy));
        assert!(init > 0);
        let exit = run_phase(Dataflow::from_program(&program.exit, 0));
        assert!(exit > 0);
    }
}
