//! Residual-trace recording (Fig. 9) with CSV/JSON export.


/// rr = |r|^2 per iteration (index 0 is the initial residual).
#[derive(Debug, Clone, Default)]
pub struct ResidualTrace {
    enabled: bool,
    values: Vec<f64>,
}

impl ResidualTrace {
    /// A trace that records only when `enabled`.
    pub fn new(enabled: bool) -> Self {
        Self { enabled, values: Vec::new() }
    }

    /// Append one iteration's rr (no-op when disabled).
    pub fn push(&mut self, rr: f64) {
        if self.enabled {
            self.values.push(rr);
        }
    }

    /// The recorded rr values, oldest first.
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// First iteration at which rr dropped below `thresh` (None if never).
    pub fn first_below(&self, thresh: f64) -> Option<usize> {
        self.values.iter().position(|&v| v < thresh)
    }

    /// Emit `iter,rr` CSV rows, subsampled to at most `max_rows` (keeps
    /// Fig.-9 exports small for 20K-iteration traces).
    pub fn to_csv(&self, max_rows: usize) -> String {
        let stride = (self.values.len() / max_rows.max(1)).max(1);
        let mut out = String::from("iter,rr\n");
        for (i, v) in self.values.iter().enumerate() {
            if i % stride == 0 || i + 1 == self.values.len() {
                out.push_str(&format!("{i},{v:.6e}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = ResidualTrace::new(false);
        t.push(1.0);
        assert!(t.is_empty());
    }

    #[test]
    fn first_below_finds_crossing() {
        let mut t = ResidualTrace::new(true);
        for v in [1.0, 0.1, 0.01, 1e-13] {
            t.push(v);
        }
        assert_eq!(t.first_below(1e-12), Some(3));
        assert_eq!(t.first_below(1e-20), None);
    }

    #[test]
    fn csv_subsamples_but_keeps_last() {
        let mut t = ResidualTrace::new(true);
        for i in 0..1000 {
            t.push(1.0 / (i + 1) as f64);
        }
        let csv = t.to_csv(10);
        let rows = csv.lines().count() - 1;
        assert!(rows <= 12, "rows={rows}");
        assert!(csv.trim_end().ends_with("e-3") || csv.contains("999,"));
    }
}
