//! The Jacobi-preconditioned CG iteration (Algorithm 1), phase-split as
//! in Fig. 5 so the arithmetic (and its rounding) matches what the
//! accelerator executes module by module.


use crate::precision::{
    dot_delay_buffer, dot_sequential, spmv_scheme, AccumulatorModel, Scheme,
};
use crate::sparse::CsrMatrix;

use super::trace::ResidualTrace;

/// Which dot-product hardware to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DotKind {
    /// Sequential accumulation: the CPU golden reference.
    #[default]
    Sequential,
    /// The FPGA's 8-lane cyclic delay buffer (footnote 1).
    DelayBuffer,
}

/// Solver configuration. Defaults reproduce the paper's evaluation setup
/// (§7.1.1): b = ones, x0 = 0, |r|^2 < 1e-12, max 20 000 iterations.
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    pub scheme: Scheme,
    pub accumulator: AccumulatorModel,
    pub dot: DotKind,
    /// Convergence threshold tau on rr = |r|^2.
    pub tol: f64,
    pub max_iters: u32,
    /// Record rr per iteration (Fig. 9 traces).
    pub record_trace: bool,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            scheme: Scheme::Fp64,
            accumulator: AccumulatorModel::Sequential,
            dot: DotKind::Sequential,
            tol: 1e-12,
            max_iters: 20_000,
            record_trace: false,
        }
    }
}

impl SolveOptions {
    /// The shipping Callipepla configuration: Mix-V3 + delay-buffer dots.
    pub fn callipepla() -> Self {
        Self {
            scheme: Scheme::MixV3,
            dot: DotKind::DelayBuffer,
            accumulator: AccumulatorModel::OutOfOrder,
            ..Self::default()
        }
    }

    /// XcgSolver: FP64 but padded-unstable accumulation (§7.5.1).
    pub fn xcgsolver() -> Self {
        Self {
            scheme: Scheme::Fp64,
            dot: DotKind::DelayBuffer,
            accumulator: AccumulatorModel::XCGSOLVER,
            ..Self::default()
        }
    }

    /// SerpensCG: FP64 everywhere, Serpens out-of-order SpMV.
    pub fn serpenscg() -> Self {
        Self {
            scheme: Scheme::Fp64,
            dot: DotKind::DelayBuffer,
            accumulator: AccumulatorModel::OutOfOrder,
            ..Self::default()
        }
    }

    /// A100 / cuSPARSE-style: FP64, sequential-ish accumulation.
    pub fn gpu() -> Self {
        Self::default()
    }
}

/// Outcome of a solve, including everything the metrics/time planes need.
#[derive(Debug, Clone)]
pub struct SolveResult {
    pub x: Vec<f64>,
    /// Main-loop iterations executed (Table 7).
    pub iters: u32,
    pub converged: bool,
    /// Final rr = |r|^2.
    pub final_rr: f64,
    /// rr after each iteration, if requested (Fig. 9).
    pub trace: ResidualTrace,
    /// Floating-point operations executed (throughput metric, Table 5).
    pub flops: u64,
}

/// FLOPs of one main-loop iteration: SpMV (2 nnz) + three dots (2n each)
/// + two axpys (2n each) + update-p (2n) + left-divide (n).
pub fn flops_per_iter(n: usize, nnz: usize) -> u64 {
    2 * nnz as u64 + 13 * n as u64
}

/// Solve A x = b with JPCG. `b` defaults to ones and `x0` to zeros when
/// `None`, matching the paper's setup.
pub fn jpcg_solve(
    a: &CsrMatrix,
    b: Option<&[f64]>,
    x0: Option<&[f64]>,
    opts: &SolveOptions,
) -> SolveResult {
    let n = a.n;
    let ones;
    let b = match b {
        Some(b) => b,
        None => {
            ones = vec![1.0; n];
            &ones
        }
    };
    let mut x = x0.map(<[f64]>::to_vec).unwrap_or_else(|| vec![0.0; n]);
    let m = a.jacobi_diag();
    let vals32 = a.vals_f32();

    let dot: fn(&[f64], &[f64]) -> f64 = match opts.dot {
        DotKind::Sequential => dot_sequential,
        DotKind::DelayBuffer => dot_delay_buffer,
    };

    let mut r = vec![0.0; n];
    let mut ap = vec![0.0; n];
    let mut z = vec![0.0; n];
    let mut p = vec![0.0; n];

    // Lines 1-5: r = b - A x0; z = M^-1 r; p = z; rz = r.z; rr = r.r.
    // The initial SpMV runs on the same hardware as the main loop, so it
    // uses the same scheme/accumulator.
    spmv_scheme(a, &vals32, &x, &mut ap, opts.scheme, opts.accumulator, 0);
    for i in 0..n {
        r[i] = b[i] - ap[i];
        z[i] = r[i] / m[i];
        p[i] = z[i];
    }
    let mut rz = dot(&r, &z);
    let mut rr = dot(&r, &r);

    let mut trace = ResidualTrace::new(opts.record_trace);
    trace.push(rr);

    let mut iters = 0u32;
    let mut flops = 2 * a.nnz() as u64 + 6 * n as u64;
    // Line 6: for (0 <= i < N_max and rr > tau)
    while iters < opts.max_iters && rr > opts.tol {
        // --- Phase 1: M1 ap = A p ; M2 pap = p . ap --------------------
        spmv_scheme(a, &vals32, &p, &mut ap, opts.scheme, opts.accumulator, iters as u64 + 1);
        let pap = dot(&p, &ap);
        let alpha = rz / pap;

        // --- Phase 2: M4 r -= alpha ap ; M5 z = r/m ; M6 rz ; M8 rr ---
        // (M8 ordered before M5-M7 in the controller, Fig. 4 opt (2); the
        // arithmetic is unaffected.)
        for i in 0..n {
            r[i] -= alpha * ap[i];
        }
        rr = dot(&r, &r);
        for i in 0..n {
            z[i] = r[i] / m[i];
        }
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;

        // --- Phase 3: M3 x += alpha p (old p) ; M7 p = z + beta p ------
        for i in 0..n {
            x[i] += alpha * p[i];
            p[i] = z[i] + beta * p[i];
        }

        flops += flops_per_iter(n, a.nnz());
        iters += 1;
        trace.push(rr);
    }

    SolveResult { x, iters, converged: rr <= opts.tol, final_rr: rr, trace, flops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth;

    fn poisson(n: usize) -> CsrMatrix {
        synth::laplace2d_shifted(n, 0.05)
    }

    #[test]
    fn converges_on_poisson_fp64() {
        let a = poisson(900);
        let res = jpcg_solve(&a, None, None, &SolveOptions::default());
        assert!(res.converged, "rr={}", res.final_rr);
        // Verify the actual solution: ||A x - b||_inf small.
        let mut ax = vec![0.0; a.n];
        a.spmv_f64(&res.x, &mut ax);
        let err = ax.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-5, "err={err}");
    }

    #[test]
    fn mixv3_iterations_close_to_fp64() {
        // Table 7: Callipepla (Mix-V3) lands within a few iterations of
        // the CPU FP64 reference.
        let a = synth::banded_spd(2000, 16_000, 1e-4, 5);
        let gold = jpcg_solve(&a, None, None, &SolveOptions::default());
        let calli = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        assert!(gold.converged && calli.converged);
        let diff = (calli.iters as i64 - gold.iters as i64).abs();
        assert!(
            diff <= (gold.iters / 20 + 10) as i64,
            "gold={} calli={}",
            gold.iters,
            calli.iters
        );
    }

    #[test]
    fn xcgsolver_model_inflates_iterations() {
        // §7.5.1: XcgSolver shows "significant iteration increases".
        let a = synth::banded_spd(2000, 16_000, 1e-5, 6);
        let gold = jpcg_solve(&a, None, None, &SolveOptions::default());
        let xcg = jpcg_solve(&a, None, None, &SolveOptions::xcgsolver());
        assert!(gold.converged);
        assert!(
            xcg.iters >= gold.iters,
            "xcg={} gold={}",
            xcg.iters,
            gold.iters
        );
    }

    #[test]
    fn mixv1_pays_for_f32_on_hard_problem() {
        // Fig. 9 (gyro_k): Mix-V1 either fails to converge within the
        // cap or needs meaningfully more iterations than FP64 — the f32
        // SpMV error must be visible.  (Our synthetic stand-ins are
        // better conditioned in the f32-dynamic-range sense than the
        // real gyro_k MEMS matrix, so outright divergence is not
        // guaranteed; the iteration penalty is.)
        let a = synth::banded_spd(3000, 24_000, 1e-7, 7);
        let gold = jpcg_solve(&a, None, None, &SolveOptions::default());
        let opts = SolveOptions { scheme: Scheme::MixV1, ..Default::default() };
        let v1 = jpcg_solve(&a, None, None, &opts);
        assert!(
            !v1.converged || v1.iters as f64 >= 1.10 * gold.iters as f64,
            "v1: converged={} iters={} vs gold {}",
            v1.converged,
            v1.iters,
            gold.iters
        );
    }

    #[test]
    fn respects_max_iters_cap() {
        let a = synth::banded_spd(500, 4000, 1e-9, 8);
        let opts = SolveOptions { max_iters: 17, ..Default::default() };
        let res = jpcg_solve(&a, None, None, &opts);
        assert_eq!(res.iters, 17);
        assert!(!res.converged);
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let a = poisson(100);
        let b = vec![0.0; a.n];
        let res = jpcg_solve(&a, Some(&b), None, &SolveOptions::default());
        assert_eq!(res.iters, 0);
        assert!(res.converged);
        assert!(res.x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn trace_records_monotone_tail() {
        let a = poisson(400);
        let opts = SolveOptions { record_trace: true, ..Default::default() };
        let res = jpcg_solve(&a, None, None, &opts);
        let tr = res.trace.values();
        assert_eq!(tr.len() as u32, res.iters + 1);
        assert!(tr.last().unwrap() < &1e-12);
    }

    #[test]
    fn flops_accounting_matches_formula() {
        let a = poisson(256);
        let res = jpcg_solve(&a, None, None, &SolveOptions::default());
        let expect = 2 * a.nnz() as u64
            + 6 * a.n as u64
            + res.iters as u64 * flops_per_iter(a.n, a.nnz());
        assert_eq!(res.flops, expect);
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let a = poisson(900);
        let cold = jpcg_solve(&a, None, None, &SolveOptions::default());
        // Start from the solution: should converge in ~0 iterations.
        let warm = jpcg_solve(&a, None, Some(&cold.x), &SolveOptions::default());
        assert!(warm.iters <= 2, "warm={}", warm.iters);
    }
}
