//! The Jacobi-preconditioned CG iteration (Algorithm 1), phase-split as
//! in Fig. 5 so the arithmetic (and its rounding) matches what the
//! accelerator executes module by module.
//!
//! §Perf (see PERF.md): the per-iteration vector work runs as two fused
//! n-length sweeps instead of five — Phase 2 folds the r-update, the
//! z-divide and both dots (M4/M8/M5/M6) into one pass; Phase 3 was
//! already one pass (M3/M7).  The dots accumulate through
//! [`DotAccumulator`]s that reproduce the whole-array reductions
//! product-for-product in element order, so fusion is *bitwise*
//! invisible: iteration counts cannot drift.  The SpMV is pluggable
//! ([`jpcg_solve_with_spmv`]) so the parallel engine ([`crate::engine`])
//! can substitute its nnz-balanced multithreaded kernels, and the
//! matrix-derived caches (`vals_f32`, `jacobi_diag`) are injectable
//! ([`jpcg_solve_cached`]) so repeated solves stop re-deriving them.

use crate::precision::adaptive::{AdaptivePolicy, PrecisionController, PrecisionTrace};
use crate::precision::{
    dot_with, spmv_scheme, AccumulatorModel, DelayDot, DotAccumulator, Scheme, SeqDot,
};
use crate::sparse::CsrMatrix;

use super::trace::ResidualTrace;

/// Which dot-product hardware to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DotKind {
    /// Sequential accumulation: the CPU golden reference.
    #[default]
    Sequential,
    /// The FPGA's 8-lane cyclic delay buffer (footnote 1).
    DelayBuffer,
}

/// Solver configuration. Defaults reproduce the paper's evaluation setup
/// (§7.1.1): b = ones, x0 = 0, |r|^2 < 1e-12, max 20 000 iterations.
#[derive(Debug, Clone, Copy)]
pub struct SolveOptions {
    /// SpMV precision scheme (Table 1).
    pub scheme: Scheme,
    /// SpMV accumulator-architecture model (§7.5.1).
    pub accumulator: AccumulatorModel,
    /// Dot-product hardware model.
    pub dot: DotKind,
    /// Convergence threshold tau on rr = |r|^2.
    pub tol: f64,
    /// Iteration cap (paper setup: 20 000).
    pub max_iters: u32,
    /// Record rr per iteration (Fig. 9 traces).
    pub record_trace: bool,
    /// Adaptive precision governance (PR 8).  `None` pins
    /// [`SolveOptions::scheme`] for the whole solve (every prior
    /// behavior, bit for bit).  `Some(policy)` starts on the policy's
    /// start scheme — `scheme` is then ignored — and escalates when the
    /// residual history triggers the policy; the decision sequence is
    /// recorded in [`SolveResult::precision`] and is a pure function of
    /// the rr sequence.
    pub adaptive: Option<AdaptivePolicy>,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            scheme: Scheme::Fp64,
            accumulator: AccumulatorModel::Sequential,
            dot: DotKind::Sequential,
            tol: 1e-12,
            max_iters: 20_000,
            record_trace: false,
            adaptive: None,
        }
    }
}

impl SolveOptions {
    /// The shipping Callipepla configuration: Mix-V3 + delay-buffer dots.
    pub fn callipepla() -> Self {
        Self {
            scheme: Scheme::MixV3,
            dot: DotKind::DelayBuffer,
            accumulator: AccumulatorModel::OutOfOrder,
            ..Self::default()
        }
    }

    /// XcgSolver: FP64 but padded-unstable accumulation (§7.5.1).
    pub fn xcgsolver() -> Self {
        Self {
            scheme: Scheme::Fp64,
            dot: DotKind::DelayBuffer,
            accumulator: AccumulatorModel::XCGSOLVER,
            ..Self::default()
        }
    }

    /// SerpensCG: FP64 everywhere, Serpens out-of-order SpMV.
    pub fn serpenscg() -> Self {
        Self {
            scheme: Scheme::Fp64,
            dot: DotKind::DelayBuffer,
            accumulator: AccumulatorModel::OutOfOrder,
            ..Self::default()
        }
    }

    /// A100 / cuSPARSE-style: FP64, sequential-ish accumulation.
    pub fn gpu() -> Self {
        Self::default()
    }
}

/// Outcome of a solve, including everything the metrics/time planes need.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// The solution iterate.
    pub x: Vec<f64>,
    /// Main-loop iterations executed (Table 7).
    pub iters: u32,
    /// Whether rr reached the threshold within the cap.
    pub converged: bool,
    /// Final rr = |r|^2.
    pub final_rr: f64,
    /// rr after each iteration, if requested (Fig. 9).
    pub trace: ResidualTrace,
    /// Floating-point operations executed (throughput metric, Table 5).
    pub flops: u64,
    /// The precision schedule that produced `x` (PR 8): which scheme
    /// governed each SpMV pass (pass 0 = init, pass k = iteration k)
    /// and why.  Fixed-scheme solves carry one event; an adaptive
    /// schedule replays bitwise through [`super::jpcg_solve_replay`].
    pub precision: PrecisionTrace,
}

/// Reusable per-solve scratch vectors (r, ap, z, p).  A batch server
/// keeps one per worker thread so back-to-back solves against the same
/// [`crate::engine::PreparedMatrix`] allocate nothing but the returned x.
#[derive(Debug, Clone, Default)]
pub struct SolveWorkspace {
    r: Vec<f64>,
    ap: Vec<f64>,
    z: Vec<f64>,
    p: Vec<f64>,
}

impl SolveWorkspace {
    /// Empty workspace; vectors are sized on first use.
    pub fn new() -> Self {
        Self::default()
    }

    fn resize(&mut self, n: usize) {
        for v in [&mut self.r, &mut self.ap, &mut self.z, &mut self.p] {
            v.clear();
            v.resize(n, 0.0);
        }
    }
}

/// FLOPs of one main-loop iteration: SpMV (2 nnz) + three dots (2n each)
/// + two axpys (2n each) + update-p (2n) + left-divide (n).
pub fn flops_per_iter(n: usize, nnz: usize) -> u64 {
    2 * nnz as u64 + 13 * n as u64
}

/// Solve A x = b with JPCG. `b` defaults to ones and `x0` to zeros when
/// `None`, matching the paper's setup.
pub fn jpcg_solve(
    a: &CsrMatrix,
    b: Option<&[f64]>,
    x0: Option<&[f64]>,
    opts: &SolveOptions,
) -> SolveResult {
    let m = a.jacobi_diag();
    // An adaptive solve may run mixed schemes at either end of its
    // policy, so the f32 view is derived whenever any reachable scheme
    // streams the matrix in f32.
    let needs_f32 =
        opts.scheme.matrix_f32() || opts.adaptive.is_some_and(|p| p.needs_f32());
    let vals32 = if needs_f32 { a.vals_f32() } else { Vec::new() };
    jpcg_solve_cached(a, &vals32, &m, b, x0, opts)
}

/// Re-run a solve under a recorded precision schedule: pass `k` uses
/// `schedule.scheme_at(k)` with **no** residual inspection, so the
/// replay is a pure function of the schedule — it reproduces the
/// original adaptive solve bit for bit (x, iteration count, rr trace)
/// from the trace alone.  `opts.scheme` / `opts.adaptive` are ignored;
/// everything else (dot model, accumulator, tol, cap) must match the
/// recording run.
pub fn jpcg_solve_replay(
    a: &CsrMatrix,
    b: Option<&[f64]>,
    x0: Option<&[f64]>,
    opts: &SolveOptions,
    schedule: &PrecisionTrace,
) -> SolveResult {
    let m = a.jacobi_diag();
    let needs_f32 = schedule.events().iter().any(|e| e.scheme.matrix_f32());
    let vals32 = if needs_f32 { a.vals_f32() } else { Vec::new() };
    let mut ws = SolveWorkspace::new();
    let ctrl = PrecisionController::replay(schedule);
    let acc = opts.accumulator;
    jpcg_solve_with_spmv_ctrl(a.n, a.nnz(), &m, b, x0, opts, &mut ws, ctrl, |x, y, s, salt| {
        spmv_scheme(a, &vals32, x, y, s, acc, salt)
    })
}

/// [`jpcg_solve`] with the matrix-derived caches supplied by the caller:
/// `vals32` the f32 view of `a.vals` (may be empty for `Scheme::Fp64`)
/// and `m` the Jacobi diagonal with zeros already mapped to 1.0 (as
/// [`CsrMatrix::jacobi_diag`] produces).  This is what a prepared-matrix
/// server calls per right-hand side — deriving both is O(nnz + n) and
/// used to be paid on every solve.
pub fn jpcg_solve_cached(
    a: &CsrMatrix,
    vals32: &[f32],
    m: &[f64],
    b: Option<&[f64]>,
    x0: Option<&[f64]>,
    opts: &SolveOptions,
) -> SolveResult {
    let mut ws = SolveWorkspace::new();
    jpcg_solve_cached_ws(a, vals32, m, b, x0, opts, &mut ws)
}

/// [`jpcg_solve_cached`] with an explicit scratch workspace (reused
/// across solves; only the solution vector is allocated).
pub fn jpcg_solve_cached_ws(
    a: &CsrMatrix,
    vals32: &[f32],
    m: &[f64],
    b: Option<&[f64]>,
    x0: Option<&[f64]>,
    opts: &SolveOptions,
    ws: &mut SolveWorkspace,
) -> SolveResult {
    let acc = opts.accumulator;
    jpcg_solve_with_spmv(a.n, a.nnz(), m, b, x0, opts, ws, |x, y, s, salt| {
        spmv_scheme(a, vals32, x, y, s, acc, salt)
    })
}

/// The solver loop with a pluggable SpMV: `spmv(x, y, scheme, salt)`
/// must write y = A x under the given scheme + the configured
/// accumulator model (`salt` is 0 for the init pass and `iteration + 1`
/// afterwards, feeding the PaddedUnstable perturbation; `scheme` is the
/// precision controller's decision for this pass — constant
/// `opts.scheme` unless `opts.adaptive` is set).  The engine's parallel
/// kernels and the serial path share this one loop, so their numerics
/// cannot diverge by construction.
#[allow(clippy::too_many_arguments)]
pub fn jpcg_solve_with_spmv<F>(
    n: usize,
    nnz: usize,
    m: &[f64],
    b: Option<&[f64]>,
    x0: Option<&[f64]>,
    opts: &SolveOptions,
    ws: &mut SolveWorkspace,
    spmv: F,
) -> SolveResult
where
    F: FnMut(&[f64], &mut [f64], Scheme, u64),
{
    let ctrl = match opts.adaptive {
        Some(policy) => PrecisionController::adaptive(policy, opts.tol),
        None => PrecisionController::fixed(opts.scheme),
    };
    jpcg_solve_with_spmv_ctrl(n, nnz, m, b, x0, opts, ws, ctrl, spmv)
}

/// [`jpcg_solve_with_spmv`] with an explicit precision controller —
/// the seam [`jpcg_solve_replay`] uses to substitute a recorded
/// schedule for live residual inspection.
#[allow(clippy::too_many_arguments)]
pub fn jpcg_solve_with_spmv_ctrl<F>(
    n: usize,
    nnz: usize,
    m: &[f64],
    b: Option<&[f64]>,
    x0: Option<&[f64]>,
    opts: &SolveOptions,
    ws: &mut SolveWorkspace,
    ctrl: PrecisionController,
    spmv: F,
) -> SolveResult
where
    F: FnMut(&[f64], &mut [f64], Scheme, u64),
{
    let ones;
    let b = match b {
        Some(b) => b,
        None => {
            ones = vec![1.0; n];
            &ones
        }
    };
    match opts.dot {
        DotKind::Sequential => solve_impl::<SeqDot, F>(n, nnz, m, b, x0, opts, ws, ctrl, spmv),
        DotKind::DelayBuffer => solve_impl::<DelayDot, F>(n, nnz, m, b, x0, opts, ws, ctrl, spmv),
    }
}

#[allow(clippy::too_many_arguments)]
fn solve_impl<D, F>(
    n: usize,
    nnz: usize,
    m: &[f64],
    b: &[f64],
    x0: Option<&[f64]>,
    opts: &SolveOptions,
    ws: &mut SolveWorkspace,
    mut ctrl: PrecisionController,
    mut spmv: F,
) -> SolveResult
where
    D: DotAccumulator,
    F: FnMut(&[f64], &mut [f64], Scheme, u64),
{
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(m.len(), n);
    let mut x = x0.map(<[f64]>::to_vec).unwrap_or_else(|| vec![0.0; n]);
    ws.resize(n);
    let SolveWorkspace { r, ap, z, p } = ws;
    let (r, ap, z, p) = (&mut r[..], &mut ap[..], &mut z[..], &mut p[..]);

    // Lines 1-5 (merged init): r = b - A x0; z = M^-1 r; p = z;
    // rz = r.z; rr = r.r.  The initial SpMV runs on the same hardware as
    // the main loop, so it uses the same scheme/accumulator; the divide,
    // copy and both dots are one fused sweep (accumulation order per dot
    // unchanged — see precision::DotAccumulator).
    spmv(&x, ap, ctrl.current(), 0);
    let mut rz_acc = D::default();
    let mut rr_acc = D::default();
    for i in 0..n {
        r[i] = b[i] - ap[i];
        z[i] = r[i] / m[i];
        p[i] = z[i];
        rz_acc.add(r[i], z[i]);
        rr_acc.add(r[i], r[i]);
    }
    let mut rz = rz_acc.finish();
    let mut rr = rr_acc.finish();

    let mut trace = ResidualTrace::new(opts.record_trace);
    trace.push(rr);
    // The controller observes a pass's rr only when the solve goes on
    // to another pass — the final rr of a converged or capped solve is
    // never observed.  The coordinator's note_init / note_phase3 gate
    // identically, which is what makes the traces path-invariant.
    if rr > opts.tol && opts.max_iters > 0 {
        ctrl.observe(rr);
    }

    let mut iters = 0u32;
    let mut flops = 2 * nnz as u64 + 6 * n as u64;
    // Line 6: for (0 <= i < N_max and rr > tau)
    while iters < opts.max_iters && rr > opts.tol {
        // --- Phase 1: M1 ap = A p ; M2 pap = p . ap --------------------
        spmv(p, ap, ctrl.current(), iters as u64 + 1);
        let pap = dot_with::<D>(p, ap);
        let alpha = rz / pap;

        // --- Phase 2, fused: M4 r -= alpha ap ; M8 rr ; M5 z = r/m ;
        // M6 rz — one sweep over n instead of four.  (M8 ordered before
        // M5-M7 in the controller, Fig. 4 opt (2); the arithmetic is
        // unaffected.)
        let mut rr_acc = D::default();
        let mut rz_acc = D::default();
        for i in 0..n {
            r[i] -= alpha * ap[i];
            rr_acc.add(r[i], r[i]);
            z[i] = r[i] / m[i];
            rz_acc.add(r[i], z[i]);
        }
        rr = rr_acc.finish();
        let rz_new = rz_acc.finish();
        let beta = rz_new / rz;
        rz = rz_new;

        // --- Phase 3: M3 x += alpha p (old p) ; M7 p = z + beta p ------
        for i in 0..n {
            x[i] += alpha * p[i];
            p[i] = z[i] + beta * p[i];
        }

        flops += flops_per_iter(n, nnz);
        iters += 1;
        trace.push(rr);
        if rr > opts.tol && iters < opts.max_iters {
            ctrl.observe(rr);
        }
    }

    SolveResult {
        x,
        iters,
        converged: rr <= opts.tol,
        final_rr: rr,
        trace,
        flops,
        precision: ctrl.into_trace(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::{dot_delay_buffer, dot_sequential};
    use crate::sparse::synth;

    fn poisson(n: usize) -> CsrMatrix {
        synth::laplace2d_shifted(n, 0.05)
    }

    #[test]
    fn converges_on_poisson_fp64() {
        let a = poisson(900);
        let res = jpcg_solve(&a, None, None, &SolveOptions::default());
        assert!(res.converged, "rr={}", res.final_rr);
        // Verify the actual solution: ||A x - b||_inf small.
        let mut ax = vec![0.0; a.n];
        a.spmv_f64(&res.x, &mut ax);
        let err = ax.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-5, "err={err}");
    }

    #[test]
    fn mixv3_iterations_close_to_fp64() {
        // Table 7: Callipepla (Mix-V3) lands within a few iterations of
        // the CPU FP64 reference.
        let a = synth::banded_spd(2000, 16_000, 1e-4, 5);
        let gold = jpcg_solve(&a, None, None, &SolveOptions::default());
        let calli = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        assert!(gold.converged && calli.converged);
        let diff = (calli.iters as i64 - gold.iters as i64).abs();
        assert!(
            diff <= (gold.iters / 20 + 10) as i64,
            "gold={} calli={}",
            gold.iters,
            calli.iters
        );
    }

    #[test]
    fn xcgsolver_model_inflates_iterations() {
        // §7.5.1: XcgSolver shows "significant iteration increases".
        let a = synth::banded_spd(2000, 16_000, 1e-5, 6);
        let gold = jpcg_solve(&a, None, None, &SolveOptions::default());
        let xcg = jpcg_solve(&a, None, None, &SolveOptions::xcgsolver());
        assert!(gold.converged);
        assert!(
            xcg.iters >= gold.iters,
            "xcg={} gold={}",
            xcg.iters,
            gold.iters
        );
    }

    #[test]
    fn mixv1_pays_for_f32_on_hard_problem() {
        // Fig. 9 (gyro_k): Mix-V1 either fails to converge within the
        // cap or needs meaningfully more iterations than FP64 — the f32
        // SpMV error must be visible.  (Our synthetic stand-ins are
        // better conditioned in the f32-dynamic-range sense than the
        // real gyro_k MEMS matrix, so outright divergence is not
        // guaranteed; the iteration penalty is.)
        let a = synth::banded_spd(3000, 24_000, 1e-7, 7);
        let gold = jpcg_solve(&a, None, None, &SolveOptions::default());
        let opts = SolveOptions { scheme: Scheme::MixV1, ..Default::default() };
        let v1 = jpcg_solve(&a, None, None, &opts);
        assert!(
            !v1.converged || v1.iters as f64 >= 1.10 * gold.iters as f64,
            "v1: converged={} iters={} vs gold {}",
            v1.converged,
            v1.iters,
            gold.iters
        );
    }

    #[test]
    fn respects_max_iters_cap() {
        let a = synth::banded_spd(500, 4000, 1e-9, 8);
        let opts = SolveOptions { max_iters: 17, ..Default::default() };
        let res = jpcg_solve(&a, None, None, &opts);
        assert_eq!(res.iters, 17);
        assert!(!res.converged);
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let a = poisson(100);
        let b = vec![0.0; a.n];
        let res = jpcg_solve(&a, Some(&b), None, &SolveOptions::default());
        assert_eq!(res.iters, 0);
        assert!(res.converged);
        assert!(res.x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn trace_records_monotone_tail() {
        let a = poisson(400);
        let opts = SolveOptions { record_trace: true, ..Default::default() };
        let res = jpcg_solve(&a, None, None, &opts);
        let tr = res.trace.values();
        assert_eq!(tr.len() as u32, res.iters + 1);
        assert!(tr.last().unwrap() < &1e-12);
    }

    #[test]
    fn flops_accounting_matches_formula() {
        let a = poisson(256);
        let res = jpcg_solve(&a, None, None, &SolveOptions::default());
        let expect = 2 * a.nnz() as u64
            + 6 * a.n as u64
            + res.iters as u64 * flops_per_iter(a.n, a.nnz());
        assert_eq!(res.flops, expect);
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let a = poisson(900);
        let cold = jpcg_solve(&a, None, None, &SolveOptions::default());
        // Start from the solution: should converge in ~0 iterations.
        let warm = jpcg_solve(&a, None, Some(&cold.x), &SolveOptions::default());
        assert!(warm.iters <= 2, "warm={}", warm.iters);
    }

    /// The pre-fusion solver, kept verbatim as a test oracle: five
    /// separate n-length passes + whole-array dots per iteration.
    fn reference_unfused(
        a: &CsrMatrix,
        b: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> SolveResult {
        let n = a.n;
        let ones;
        let b = match b {
            Some(b) => b,
            None => {
                ones = vec![1.0; n];
                &ones
            }
        };
        let mut x = vec![0.0; n];
        let m = a.jacobi_diag();
        let vals32 = a.vals_f32();
        let dot: fn(&[f64], &[f64]) -> f64 = match opts.dot {
            DotKind::Sequential => dot_sequential,
            DotKind::DelayBuffer => dot_delay_buffer,
        };
        let mut r = vec![0.0; n];
        let mut ap = vec![0.0; n];
        let mut z = vec![0.0; n];
        let mut p = vec![0.0; n];
        spmv_scheme(a, &vals32, &x, &mut ap, opts.scheme, opts.accumulator, 0);
        for i in 0..n {
            r[i] = b[i] - ap[i];
            z[i] = r[i] / m[i];
            p[i] = z[i];
        }
        let mut rz = dot(&r, &z);
        let mut rr = dot(&r, &r);
        let mut trace = ResidualTrace::new(opts.record_trace);
        trace.push(rr);
        let mut iters = 0u32;
        let mut flops = 2 * a.nnz() as u64 + 6 * n as u64;
        while iters < opts.max_iters && rr > opts.tol {
            spmv_scheme(a, &vals32, &p, &mut ap, opts.scheme, opts.accumulator, iters as u64 + 1);
            let pap = dot(&p, &ap);
            let alpha = rz / pap;
            for i in 0..n {
                r[i] -= alpha * ap[i];
            }
            rr = dot(&r, &r);
            for i in 0..n {
                z[i] = r[i] / m[i];
            }
            let rz_new = dot(&r, &z);
            let beta = rz_new / rz;
            rz = rz_new;
            for i in 0..n {
                x[i] += alpha * p[i];
                p[i] = z[i] + beta * p[i];
            }
            flops += flops_per_iter(n, a.nnz());
            iters += 1;
            trace.push(rr);
        }
        SolveResult {
            x,
            iters,
            converged: rr <= opts.tol,
            final_rr: rr,
            trace,
            flops,
            precision: PrecisionTrace::default(),
        }
    }

    #[test]
    fn fused_sweeps_are_bitwise_identical_to_unfused() {
        // The load-bearing claim of the fusion: not "close", identical.
        let a = synth::banded_spd(900, 7_200, 1e-3, 23);
        for opts in [
            SolveOptions::default(),
            SolveOptions::callipepla(),
            SolveOptions::xcgsolver(),
            SolveOptions { scheme: Scheme::MixV2, dot: DotKind::DelayBuffer, ..Default::default() },
        ] {
            let fused = jpcg_solve(&a, None, None, &opts);
            let unfused = reference_unfused(&a, None, &opts);
            assert_eq!(fused.iters, unfused.iters, "{opts:?}");
            assert_eq!(fused.final_rr.to_bits(), unfused.final_rr.to_bits(), "{opts:?}");
            assert_eq!(fused.flops, unfused.flops, "{opts:?}");
            assert!(
                fused
                    .x
                    .iter()
                    .zip(&unfused.x)
                    .all(|(u, v)| u.to_bits() == v.to_bits()),
                "solution drifted under fusion for {opts:?}"
            );
        }
    }

    #[test]
    fn fixed_solves_record_a_single_event_schedule() {
        let a = poisson(400);
        let res = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        assert_eq!(res.precision.events().len(), 1);
        assert_eq!(res.precision.scheme_at(0), Scheme::MixV3);
        assert_eq!(res.precision.scheme_at(res.iters), Scheme::MixV3);
    }

    #[test]
    fn adaptive_solve_replays_bitwise_from_its_trace() {
        let a = synth::banded_spd(1200, 9_600, 1e-5, 33);
        let opts = SolveOptions {
            adaptive: Some(AdaptivePolicy::default()),
            record_trace: true,
            ..SolveOptions::callipepla()
        };
        let live = jpcg_solve(&a, None, None, &opts);
        assert!(live.converged, "rr={}", live.final_rr);
        let replay = jpcg_solve_replay(&a, None, None, &opts, &live.precision);
        assert_eq!(replay.iters, live.iters);
        assert_eq!(replay.final_rr.to_bits(), live.final_rr.to_bits());
        assert!(replay.x.iter().zip(&live.x).all(|(u, v)| u.to_bits() == v.to_bits()));
        // The replay re-records the schedule it was fed.
        assert_eq!(replay.precision, live.precision);
    }

    #[test]
    fn adaptive_none_is_bitwise_the_fixed_path() {
        // `adaptive: None` must not move a bit relative to the
        // pre-controller solver (same loop, fixed controller inlined).
        let a = synth::banded_spd(900, 7_200, 1e-3, 23);
        let fixed = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        let unfused = reference_unfused(&a, None, &SolveOptions::callipepla());
        assert_eq!(fixed.iters, unfused.iters);
        assert!(fixed.x.iter().zip(&unfused.x).all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn workspace_reuse_is_bitwise_stable() {
        let a = synth::banded_spd(700, 5_600, 1e-3, 41);
        let m = a.jacobi_diag();
        let vals32 = a.vals_f32();
        let opts = SolveOptions::callipepla();
        let mut ws = SolveWorkspace::new();
        let first = jpcg_solve_cached_ws(&a, &vals32, &m, None, None, &opts, &mut ws);
        let second = jpcg_solve_cached_ws(&a, &vals32, &m, None, None, &opts, &mut ws);
        let fresh = jpcg_solve(&a, None, None, &opts);
        assert_eq!(first.iters, fresh.iters);
        assert_eq!(second.iters, fresh.iters);
        assert!(first.x.iter().zip(&fresh.x).all(|(u, v)| u.to_bits() == v.to_bits()));
        assert!(second.x.iter().zip(&fresh.x).all(|(u, v)| u.to_bits() == v.to_bits()));
    }
}
