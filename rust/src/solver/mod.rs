//! Native JPCG solver (Algorithm 1) — the *value plane* reference.
//!
//! This is the same phase-split iteration the Rust coordinator drives
//! through the PJRT artifacts, but with the numerics inlined so the full
//! 36-matrix suite (Tables 4/5/7, Fig. 9) runs fast.  Every knob that
//! changes floating-point behaviour on the real accelerators is
//! reproduced: the SpMV precision scheme (Table 1), the accumulator
//! model (§7.5.1), and the delay-buffer dot product (footnote 1).

pub mod jpcg;
pub mod trace;

pub use jpcg::{
    jpcg_solve, jpcg_solve_cached, jpcg_solve_cached_ws, jpcg_solve_replay, jpcg_solve_with_spmv,
    jpcg_solve_with_spmv_ctrl, DotKind, SolveOptions, SolveResult, SolveWorkspace,
};
pub use trace::ResidualTrace;
