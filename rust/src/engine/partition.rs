//! Contiguous nnz-balanced row partitions — the work-splitting layer of
//! the engine.  Cutting on the nnz prefix sum (not row count) is what
//! keeps skewed matrices from serializing on one hot thread, exactly as
//! HBM SpMV accelerators split the nonzero stream, not the row space,
//! across channel groups.

use crate::sparse::CsrMatrix;

/// A partition of `0..a.n` into contiguous row blocks with near-equal
/// nonzero counts.  Blocks never split a row, which is the bitwise-
/// safety invariant of the parallel SpMV: each output element is still
/// produced by one serial per-row accumulation in the serial order.
#[derive(Debug, Clone)]
pub struct RowPartition {
    /// `bounds[k]..bounds[k+1]` is block k; `bounds.len() == parts + 1`.
    bounds: Vec<usize>,
}

impl RowPartition {
    /// Partition by binary search on the nnz prefix sum: block k ends at
    /// the first row whose prefix reaches `nnz * (k+1) / parts`.  Every
    /// block therefore holds at most `nnz/parts + max_row_nnz` nonzeros.
    pub fn nnz_balanced(a: &CsrMatrix, parts: usize) -> Self {
        Self { bounds: a.nnz_balanced_bounds(parts) }
    }

    /// Trivial single-block partition (the serial plan).
    pub fn serial(a: &CsrMatrix) -> Self {
        Self { bounds: vec![0, a.n] }
    }

    /// Number of row blocks.
    pub fn num_parts(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Row range of block `k`.
    pub fn range(&self, k: usize) -> std::ops::Range<usize> {
        self.bounds[k]..self.bounds[k + 1]
    }

    /// The raw boundaries (`parts + 1` entries, first 0, last n).
    pub fn bounds(&self) -> &[usize] {
        &self.bounds
    }

    /// Nonzeros inside block `k` of matrix `a` (the partition stores row
    /// indices only, so it is valid for any matrix sharing `a`'s shape).
    pub fn part_nnz(&self, a: &CsrMatrix, k: usize) -> usize {
        (a.indptr[self.bounds[k + 1]] - a.indptr[self.bounds[k]]) as usize
    }

    /// Largest per-block nonzero count — the balance figure of merit.
    pub fn max_part_nnz(&self, a: &CsrMatrix) -> usize {
        (0..self.num_parts()).map(|k| self.part_nnz(a, k)).max().unwrap_or(0)
    }

    /// Mean per-block nonzero count.
    pub fn mean_part_nnz(&self, a: &CsrMatrix) -> f64 {
        a.nnz() as f64 / self.num_parts() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth;

    #[test]
    fn partition_covers_all_rows_once() {
        let a = synth::banded_spd(2_000, 16_000, 1e-3, 9);
        for parts in [1, 2, 5, 8] {
            let p = RowPartition::nnz_balanced(&a, parts);
            assert_eq!(p.num_parts(), parts);
            assert_eq!(p.range(0).start, 0);
            assert_eq!(p.range(parts - 1).end, a.n);
            let covered: usize = (0..parts).map(|k| p.range(k).len()).sum();
            assert_eq!(covered, a.n);
            let nnz: usize = (0..parts).map(|k| p.part_nnz(&a, k)).sum();
            assert_eq!(nnz, a.nnz());
        }
    }

    #[test]
    fn balance_beats_naive_row_split_on_skew() {
        // Skewed density: later rows are ~40x denser than early ones.
        // An equal-rows split would overload the last block; the nnz
        // split keeps max/mean tight.
        let mut coo = crate::sparse::CooMatrix::new(4_000);
        for i in 0..4_000usize {
            coo.push(i, i, 2.0);
            let fan = 1 + (i * 40) / 4_000;
            for d in 1..=fan {
                let j = (i + d * 7) % 4_000;
                if j != i {
                    coo.push(i, j, -0.01);
                }
            }
        }
        let a = coo.to_csr();
        let p = RowPartition::nnz_balanced(&a, 8);
        let ratio = p.max_part_nnz(&a) as f64 / p.mean_part_nnz(&a);
        assert!(ratio <= 1.2, "max/mean = {ratio:.3}");
    }
}
