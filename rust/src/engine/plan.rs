//! The prepared-matrix solve plan: derive everything reusable once,
//! then serve solves — singly (parallel SpMV inside one solve) or in
//! batches (solves spread across workers, serial SpMV inside each).

use std::sync::{Arc, OnceLock};

use crate::coordinator::{BlockMode, CoordinatorConfig};
use crate::precision::adaptive::PrecisionMode;
use crate::precision::{apply_accumulator_model, Scheme};
use crate::program::ProgramCache;
use crate::solver::{
    jpcg_solve_cached_ws, jpcg_solve_with_spmv, SolveOptions, SolveResult, SolveWorkspace,
};
use crate::sparse::CsrMatrix;

use super::{pool, spmv_block_parallel, spmv_parallel, RowPartition};

/// A matrix prepared for repeated solving: cached f32 value view
/// (derived lazily, on the first Mix-scheme use — a pure-FP64 plan
/// never pays the O(nnz) conversion), cached Jacobi diagonal, an
/// nnz-balanced [`RowPartition`] sized to the thread budget, and the
/// scheme-independent glue to run the fused JPCG loop over the parallel
/// SpMV.  Everything a solve needs besides the right-hand side.
///
/// The derived state sits behind `Arc`s, so `clone()` is cheap and
/// every clone (and every view the
/// [service registry](crate::service::MatrixRegistry) hands out)
/// shares one copy — including the lazy f32 view: whichever plan
/// derives it first fills it for all.
#[derive(Debug, Clone)]
pub struct PreparedMatrix<'a> {
    a: &'a CsrMatrix,
    vals32: Arc<OnceLock<Vec<f32>>>,
    diag: Arc<Vec<f64>>,
    partition: Arc<RowPartition>,
    threads: usize,
}

impl<'a> PreparedMatrix<'a> {
    /// Prepare with an explicit thread budget (>= 1).
    pub fn new(a: &'a CsrMatrix, threads: usize) -> Self {
        let threads = threads.max(1);
        Self {
            a,
            vals32: Arc::new(OnceLock::new()),
            diag: Arc::new(a.jacobi_diag()),
            partition: Arc::new(RowPartition::nnz_balanced(a, threads)),
            threads,
        }
    }

    /// A plan over caches that were derived elsewhere (the service
    /// registry's matrix entries own them and hand out borrowing views
    /// without re-deriving or copying anything).
    pub(crate) fn from_shared(
        a: &'a CsrMatrix,
        diag: Arc<Vec<f64>>,
        vals32: Arc<OnceLock<Vec<f32>>>,
        partition: Arc<RowPartition>,
        threads: usize,
    ) -> Self {
        Self { a, vals32, diag, partition, threads: threads.max(1) }
    }

    /// Prepare with one block per available hardware thread.
    pub fn with_default_threads(a: &'a CsrMatrix) -> Self {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(a, threads)
    }

    /// A view of this plan with a different SpMV thread budget: the
    /// shared caches (f32 view, diagonal) are the same `Arc`s — so
    /// deriving the f32 view through either plan fills it for both —
    /// and only the row partition is re-cut.  The lane-parallel batch
    /// path takes a 1-thread view so each lane runs the serial SpMV
    /// while the parallelism lives *across* lanes (bitwise identical
    /// either way — the SpMV is thread-count-invariant).
    pub fn reshaped(&self, threads: usize) -> PreparedMatrix<'a> {
        let threads = threads.max(1);
        if threads == self.threads {
            return self.clone();
        }
        Self {
            a: self.a,
            vals32: Arc::clone(&self.vals32),
            diag: Arc::clone(&self.diag),
            partition: Arc::new(RowPartition::nnz_balanced(self.a, threads)),
            threads,
        }
    }

    /// The borrowed matrix this plan serves (the full `'a` borrow, so a
    /// wrapper like `NativeExecutor` can hold both plan and matrix).
    pub fn matrix(&self) -> &'a CsrMatrix {
        self.a
    }

    /// The plan's worker-thread budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The nnz-balanced row partition the SpMV runs on.
    pub fn partition(&self) -> &RowPartition {
        &self.partition
    }

    /// Cached f32 view of the value stream (what HBM holds under
    /// Mix-*), derived on first use.
    pub fn vals32(&self) -> &[f32] {
        self.vals32.get_or_init(|| self.a.vals_f32())
    }

    /// The f32 view if `scheme` streams one, else the empty slice the
    /// FP64 kernels ignore — without forcing the lazy derivation.
    fn vals32_for(&self, scheme: Scheme) -> &[f32] {
        if scheme.matrix_f32() {
            self.vals32()
        } else {
            &[]
        }
    }

    /// [`PreparedMatrix::vals32_for`] over a whole option set: an
    /// adaptive solve can reach either end of its policy, so the f32
    /// view is derived whenever any reachable scheme streams it.  (The
    /// FP64 kernels ignore the slice, so handing it to every pass of a
    /// mixed solve is free.)
    fn vals32_for_opts(&self, opts: &SolveOptions) -> &[f32] {
        let needs =
            opts.scheme.matrix_f32() || opts.adaptive.is_some_and(|p| p.needs_f32());
        if needs {
            self.vals32()
        } else {
            &[]
        }
    }

    /// Cached Jacobi diagonal (zeros mapped to 1.0).
    pub fn diag(&self) -> &[f64] {
        &self.diag
    }

    /// y = A x under `scheme`, on the plan's partition/threads.  Bitwise
    /// identical to the serial `spmv_scheme` path.
    pub fn spmv(&self, scheme: Scheme, x: &[f64], y: &mut [f64]) {
        spmv_parallel(self.a, self.vals32_for(scheme), x, y, scheme, &self.partition);
    }

    /// Block-CG SpMV: `ys = A xs` for `lanes` interleaved lane-major
    /// right-hand sides (`xs[col * lanes + lane]`) in **one pass** over
    /// the nnz structure, on the plan's partition/threads
    /// ([`crate::engine::spmv_block_parallel`]).  Per lane the output
    /// is bitwise [`PreparedMatrix::spmv`] of that lane's vector.
    pub fn spmv_block(&self, scheme: Scheme, xs: &[f64], ys: &mut [f64], lanes: usize) {
        spmv_block_parallel(
            self.a,
            self.vals32_for(scheme),
            xs,
            ys,
            lanes,
            scheme,
            &self.partition,
        );
    }

    /// Solve one right-hand side (`None` = ones, paper setup) with the
    /// parallel SpMV inside the fused JPCG loop.  Numerics are bitwise
    /// identical to [`crate::solver::jpcg_solve`] at any thread count.
    ///
    /// ```
    /// use callipepla::{PreparedMatrix, SolveOptions};
    /// use callipepla::sparse::synth;
    ///
    /// let a = synth::laplace2d_shifted(100, 0.2);
    /// let prep = PreparedMatrix::new(&a, 2);
    /// let res = prep.solve(None, None, &SolveOptions::callipepla());
    /// assert!(res.converged);
    /// ```
    pub fn solve(
        &self,
        b: Option<&[f64]>,
        x0: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> SolveResult {
        let mut ws = SolveWorkspace::new();
        self.solve_ws(b, x0, opts, &mut ws)
    }

    /// [`PreparedMatrix::solve`] with a caller-held workspace, for
    /// allocation-free repeated solves.
    pub fn solve_ws(
        &self,
        b: Option<&[f64]>,
        x0: Option<&[f64]>,
        opts: &SolveOptions,
        ws: &mut SolveWorkspace,
    ) -> SolveResult {
        let vals32 = self.vals32_for_opts(opts);
        if self.threads <= 1 {
            return jpcg_solve_cached_ws(self.a, vals32, &self.diag, b, x0, opts, ws);
        }
        let acc = opts.accumulator;
        jpcg_solve_with_spmv(self.a.n, self.a.nnz(), &self.diag, b, x0, opts, ws, |x, y, s, salt| {
            spmv_parallel(self.a, vals32, x, y, s, &self.partition);
            apply_accumulator_model(y, acc, salt);
        })
    }

    /// Solve many right-hand sides against this one prepared matrix.
    ///
    /// When the options match the instruction path's hardware models
    /// (delay-buffer dots, a value-neutral accumulator — i.e. the
    /// shipping [`SolveOptions::callipepla`] family), the batch runs as
    /// **one compiled batched program** through
    /// [`Coordinator::solve_batch`](crate::coordinator::Coordinator::solve_batch)
    /// + [`NativeExecutor`](crate::coordinator::NativeExecutor): one
    /// instruction stream vectorized over the RHS lanes, per-lane
    /// scalars bound at issue, per-lane converged exit.  Options that
    /// model *other* machines (sequential golden-reference dots, the
    /// XcgSolver padded-unstable accumulator) fall back to
    /// [`PreparedMatrix::solve_batch_workers`], which exists precisely
    /// for those model studies.
    ///
    /// Either way every result is bitwise the result of a lone
    /// [`crate::solver::jpcg_solve`] call, in input order.
    ///
    /// ```
    /// use callipepla::{PreparedMatrix, SolveOptions};
    /// use callipepla::sparse::synth;
    ///
    /// let a = synth::laplace2d_shifted(100, 0.2);
    /// let prep = PreparedMatrix::new(&a, 2);
    /// let rhs: Vec<Vec<f64>> = (0..3)
    ///     .map(|k| (0..a.n).map(|i| 1.0 + ((i + k) % 5) as f64).collect())
    ///     .collect();
    /// // Shipping options -> one compiled batched instruction stream.
    /// let results = prep.solve_batch(&rhs, &SolveOptions::callipepla());
    /// assert_eq!(results.len(), 3);
    /// assert!(results.iter().all(|r| r.converged));
    /// ```
    pub fn solve_batch(&self, rhs: &[Vec<f64>], opts: &SolveOptions) -> Vec<SolveResult> {
        self.solve_batch_with_cache(rhs, opts, None)
    }

    /// [`PreparedMatrix::solve_batch`] drawing its compiled program
    /// from a shared [`ProgramCache`]: the batch executes through the
    /// bucket program for this matrix's size class, so repeated batches
    /// (and other matrices in the same bucket) stop recompiling.  This
    /// is the execution path of every [`service`](crate::service)
    /// worker.  Results are bitwise identical to the uncached path —
    /// the cache changes compile traffic, not one bit of arithmetic.
    pub fn solve_batch_with_cache(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
        cache: Option<&Arc<ProgramCache>>,
    ) -> Vec<SolveResult> {
        if rhs.is_empty() {
            return Vec::new();
        }
        if Self::program_family(opts) {
            return self.solve_batch_program(rhs, opts, cache, BlockMode::PerLane);
        }
        self.solve_batch_workers(rhs, opts)
    }

    /// [`PreparedMatrix::solve_batch`] under **resident block-CG**
    /// ([`BlockMode::Resident`]): the batch's vector plane lives in
    /// interleaved lane-major arenas for the whole solve — each
    /// iteration streams the matrix **once** for every live lane
    /// straight between the arenas ([`PreparedMatrix::spmv_block`], no
    /// gather or scatter), runs the M2–M8 vector trips batch-wide on
    /// the engine's block kernels, and commits by swapping arenas, so
    /// steady-state iterations move zero vector elements across the
    /// block boundary.  Every kernel preserves each lane's accumulation
    /// chain exactly, so results are **bitwise identical** to
    /// [`PreparedMatrix::solve_batch`] (and hence to lone
    /// [`crate::solver::jpcg_solve`] calls); the Table-7-style
    /// convergence gate in `tests/block_spmv.rs` documents the
    /// tolerance contract any future layout change must still meet.
    /// Options outside the program family fall back to
    /// [`PreparedMatrix::solve_batch_workers`] (no batch axis there).
    pub fn solve_batch_block(&self, rhs: &[Vec<f64>], opts: &SolveOptions) -> Vec<SolveResult> {
        self.solve_batch_block_with_cache(rhs, opts, None)
    }

    /// [`PreparedMatrix::solve_batch_block`] drawing its compiled
    /// program from a shared [`ProgramCache`] (see
    /// [`PreparedMatrix::solve_batch_with_cache`]).
    pub fn solve_batch_block_with_cache(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
        cache: Option<&Arc<ProgramCache>>,
    ) -> Vec<SolveResult> {
        if rhs.is_empty() {
            return Vec::new();
        }
        if Self::program_family(opts) {
            return self.solve_batch_program(rhs, opts, cache, BlockMode::Resident);
        }
        self.solve_batch_workers(rhs, opts)
    }

    /// [`PreparedMatrix::solve_batch`] under the **staged** block-CG
    /// SpMV ([`BlockMode::Staged`], the PR 6 path): one matrix pass per
    /// iteration feeds every live lane, but the lane-major block is
    /// gathered and scattered around it (`2·n·L` element moves per
    /// iteration) and the vector sweeps stay per-lane.  Kept as the
    /// measured baseline the resident rows pair against in
    /// `benches/hot_paths.rs`; results are bitwise identical to every
    /// other entry point of the program family.
    pub fn solve_batch_block_staged(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
    ) -> Vec<SolveResult> {
        if rhs.is_empty() {
            return Vec::new();
        }
        if Self::program_family(opts) {
            return self.solve_batch_program(rhs, opts, None, BlockMode::Staged);
        }
        self.solve_batch_workers(rhs, opts)
    }

    /// [`PreparedMatrix::solve_batch_with_cache`] with **lane-parallel
    /// dispatch**: the batch still executes as one compiled instruction
    /// stream, but each trip's per-lane streams are fanned across up to
    /// `lane_workers` workers (`0` = machine default), one 1-thread
    /// executor per lane over a shared serial-SpMV view of this plan —
    /// the parallelism moves from inside each lane's SpMV to across
    /// whole lanes (SpMV, vector sweeps, and dots alike).  Results are
    /// **bitwise identical** to [`PreparedMatrix::solve_batch`] at any
    /// worker count (`tests/lane_parallel.rs`); options outside the
    /// program family fall back to
    /// [`PreparedMatrix::solve_batch_workers`], which is already
    /// lane-parallel by construction.  This is the execution path of
    /// every [`service`](crate::service) worker.
    pub fn solve_batch_parallel(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
        cache: Option<&Arc<ProgramCache>>,
        lane_workers: usize,
    ) -> Vec<SolveResult> {
        self.solve_batch_parallel_impl(rhs, opts, cache, lane_workers, BlockMode::PerLane)
    }

    /// [`PreparedMatrix::solve_batch_parallel`] under **resident
    /// block-CG** (see [`PreparedMatrix::solve_batch_block`]): the
    /// batch-wide SpMV and vector rounds run between the trip barriers
    /// on this plan's full thread budget (the block kernels parallelize
    /// over row ranges and dot lanes internally), while any lanes that
    /// gather out fan across `lane_workers` workers.  Bitwise identical
    /// to every other entry point of the program family.
    pub fn solve_batch_block_parallel(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
        cache: Option<&Arc<ProgramCache>>,
        lane_workers: usize,
    ) -> Vec<SolveResult> {
        self.solve_batch_parallel_impl(rhs, opts, cache, lane_workers, BlockMode::Resident)
    }

    /// [`PreparedMatrix::solve_batch_parallel`] under the **staged**
    /// block-CG SpMV (see [`PreparedMatrix::solve_batch_block_staged`]):
    /// the batch-wide matrix pass runs between the trip barriers on this
    /// plan's full thread budget, while the non-SpMV trips still fan
    /// across `lane_workers` lanes.  The resident path's measured
    /// baseline; bitwise identical to it.
    pub fn solve_batch_block_staged_parallel(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
        cache: Option<&Arc<ProgramCache>>,
        lane_workers: usize,
    ) -> Vec<SolveResult> {
        self.solve_batch_parallel_impl(rhs, opts, cache, lane_workers, BlockMode::Staged)
    }

    fn solve_batch_parallel_impl(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
        cache: Option<&Arc<ProgramCache>>,
        lane_workers: usize,
        block: BlockMode,
    ) -> Vec<SolveResult> {
        use crate::coordinator::{Coordinator, NativeExecutor};
        if rhs.is_empty() {
            return Vec::new();
        }
        if !Self::program_family(opts) {
            return self.solve_batch_workers(rhs, opts);
        }
        // Force the lazy f32 derivation once, outside the fan-out, so
        // lanes never serialize on the OnceLock's first fill (adaptive
        // solves may reach an f32 scheme on any lane at any pass).
        let _ = self.vals32_for_opts(opts);
        let lane_plan = self.reshaped(1);
        let cfg = CoordinatorConfig { lane_workers, block, ..Self::coord_cfg(opts) };
        let mut coord = match cache {
            Some(cache) => Coordinator::with_cache(cfg, Arc::clone(cache)),
            None => Coordinator::new(cfg),
        };
        // Under block dispatch the batch-wide work runs on the *first*
        // executor; give it the full-thread plan so the one matrix pass
        // (and, resident, the block vector rounds) uses the machine,
        // while the per-lane fallback work stays on serial-SpMV views.
        let mut execs: Vec<NativeExecutor> = rhs
            .iter()
            .enumerate()
            .map(|(k, _)| {
                if block != BlockMode::PerLane && k == 0 {
                    NativeExecutor::with_plan(self, opts.scheme)
                } else {
                    NativeExecutor::with_plan(&lane_plan, opts.scheme)
                }
            })
            .collect();
        let rhs_refs: Vec<&[f64]> = rhs.iter().map(Vec::as_slice).collect();
        let results = coord.solve_batch_parallel(&mut execs, &rhs_refs, None);
        self.to_solve_results(results)
    }

    /// Whether an option set matches the instruction path's hardware
    /// models (delay-buffer dots, a value-neutral accumulator — the
    /// shipping [`SolveOptions::callipepla`] family) and therefore runs
    /// through the compiled batched program.
    fn program_family(opts: &SolveOptions) -> bool {
        use crate::precision::AccumulatorModel;
        use crate::solver::DotKind;
        opts.dot == DotKind::DelayBuffer
            && !matches!(opts.accumulator, AccumulatorModel::PaddedUnstable { .. })
    }

    /// The coordinator configuration a batch under `opts` runs with.
    fn coord_cfg(opts: &SolveOptions) -> CoordinatorConfig {
        CoordinatorConfig {
            tol: opts.tol,
            max_iters: opts.max_iters,
            record_trace: opts.record_trace,
            precision: match opts.adaptive {
                Some(policy) => PrecisionMode::Adaptive(policy),
                None => PrecisionMode::Static(opts.scheme),
            },
            ..Default::default()
        }
    }

    /// Map the coordinator's per-lane results into [`SolveResult`]s,
    /// mirroring the reference solver's FLOP accounting: init pass +
    /// one full iteration's FLOPs per executed iteration.
    fn to_solve_results(&self, results: Vec<crate::coordinator::CoordResult>) -> Vec<SolveResult> {
        use crate::solver::jpcg::flops_per_iter;
        let (n, nnz) = (self.a.n, self.a.nnz());
        results
            .into_iter()
            .map(|r| SolveResult {
                x: r.x,
                iters: r.iters,
                converged: r.converged,
                final_rr: r.final_rr,
                trace: r.trace,
                flops: 2 * nnz as u64 + 6 * n as u64 + r.iters as u64 * flops_per_iter(n, nnz),
                precision: r.precision,
            })
            .collect()
    }

    /// The batched-program execution path: one
    /// [`Program`](crate::program::Program) compiled over the RHS lanes
    /// (or fetched from `cache`), dispatched through the coordinator's
    /// instruction bus to the native executor (engine SpMV inside).
    /// Callers normally reach this through
    /// [`PreparedMatrix::solve_batch`].
    fn solve_batch_program(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
        cache: Option<&Arc<ProgramCache>>,
        block: BlockMode,
    ) -> Vec<SolveResult> {
        use crate::coordinator::{Coordinator, NativeExecutor};
        let cfg = CoordinatorConfig { block, ..Self::coord_cfg(opts) };
        let mut coord = match cache {
            Some(cache) => Coordinator::with_cache(cfg, Arc::clone(cache)),
            None => Coordinator::new(cfg),
        };
        // The executor borrows this plan, so the cached f32 view /
        // diagonal / partition are shared, not copied — and a lazily
        // derived f32 cache persists on `self` across batch calls.
        let mut exec = NativeExecutor::with_plan(self, opts.scheme);
        let rhs_refs: Vec<&[f64]> = rhs.iter().map(Vec::as_slice).collect();
        let results = coord.solve_batch(&mut exec, &rhs_refs, None);
        self.to_solve_results(results)
    }

    /// The worker-per-RHS-chunk batch path: parallelism goes *across*
    /// solves (serial SpMV inside each), which also overlaps the vector
    /// sweeps.  This is the execution model for option sets the
    /// instruction path does not model (sequential dots, the XcgSolver
    /// accumulator) and the baseline the batched-program bench rows
    /// compare against.  The chunks run on the persistent
    /// [`pool::global`] worker pool (PERF §7: no per-call thread spawn
    /// cost).  Results are bitwise those of lone
    /// [`crate::solver::jpcg_solve`] calls, in input order.
    pub fn solve_batch_workers(&self, rhs: &[Vec<f64>], opts: &SolveOptions) -> Vec<SolveResult> {
        if rhs.is_empty() {
            return Vec::new();
        }
        let workers = self.threads.min(rhs.len()).max(1);
        let vals32 = self.vals32_for_opts(opts);
        if workers == 1 {
            let mut ws = SolveWorkspace::new();
            return rhs
                .iter()
                .map(|b| {
                    jpcg_solve_cached_ws(self.a, vals32, &self.diag, Some(b), None, opts, &mut ws)
                })
                .collect();
        }
        let chunk = rhs.len().div_ceil(workers);
        let mut out: Vec<Option<SolveResult>> = Vec::with_capacity(rhs.len());
        out.resize_with(rhs.len(), || None);
        let (a, diag) = (self.a, self.diag.as_slice());
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(workers);
        for (out_chunk, rhs_chunk) in out.chunks_mut(chunk).zip(rhs.chunks(chunk)) {
            jobs.push(Box::new(move || {
                let mut ws = SolveWorkspace::new();
                for (slot, b) in out_chunk.iter_mut().zip(rhs_chunk) {
                    *slot =
                        Some(jpcg_solve_cached_ws(a, vals32, diag, Some(b), None, opts, &mut ws));
                }
            }));
        }
        pool::global().run_scoped(jobs);
        out.into_iter().map(|r| r.expect("every batch slot solved")).collect()
    }

    /// [`PreparedMatrix::solve_batch_workers`] on per-call
    /// `std::thread::scope` spawns — the pre-pool execution, kept as
    /// the spawn-overhead baseline for the
    /// `solve_batch_8rhs_small_{scope,pool}_10_iters` bench rows
    /// (PERF §7/§8).  Semantics and results are identical to the pooled
    /// path.
    pub fn solve_batch_workers_scoped(
        &self,
        rhs: &[Vec<f64>],
        opts: &SolveOptions,
    ) -> Vec<SolveResult> {
        if rhs.is_empty() {
            return Vec::new();
        }
        let workers = self.threads.min(rhs.len()).max(1);
        let vals32 = self.vals32_for_opts(opts);
        let chunk = rhs.len().div_ceil(workers);
        let mut out: Vec<Option<SolveResult>> = Vec::with_capacity(rhs.len());
        out.resize_with(rhs.len(), || None);
        std::thread::scope(|s| {
            for (out_chunk, rhs_chunk) in out.chunks_mut(chunk).zip(rhs.chunks(chunk)) {
                s.spawn(move || {
                    let mut ws = SolveWorkspace::new();
                    for (slot, b) in out_chunk.iter_mut().zip(rhs_chunk) {
                        *slot = Some(jpcg_solve_cached_ws(
                            self.a,
                            vals32,
                            &self.diag,
                            Some(b),
                            None,
                            opts,
                            &mut ws,
                        ));
                    }
                });
            }
        });
        out.into_iter().map(|r| r.expect("every batch slot solved")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::jpcg_solve;
    use crate::sparse::synth;

    #[test]
    fn prepared_solve_matches_plain_solver_bitwise() {
        let a = synth::banded_spd(1_500, 12_000, 1e-4, 33);
        let reference = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        for threads in [1, 2, 8] {
            let prep = PreparedMatrix::new(&a, threads);
            let res = prep.solve(None, None, &SolveOptions::callipepla());
            assert_eq!(res.iters, reference.iters, "threads={threads}");
            assert_eq!(res.final_rr.to_bits(), reference.final_rr.to_bits());
            assert!(
                res.x.iter().zip(&reference.x).all(|(u, v)| u.to_bits() == v.to_bits()),
                "solution drifted at {threads} threads"
            );
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let a = synth::laplace2d_shifted(64, 0.1);
        let prep = PreparedMatrix::new(&a, 4);
        assert!(prep.solve_batch(&[], &SolveOptions::default()).is_empty());
    }
}
