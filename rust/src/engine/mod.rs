//! Parallel execution engine: the software analogue of spreading the
//! nnz stream across parallel compute units (paper §6 / Fig. 8 — 16 HBM
//! channels × 8 PEs; same partition-by-nonzeros lesson as the related
//! HBM SpMV designs of Hogervorst et al. and Korcyl & Korcyl).
//!
//! Three pieces, layered bottom-up:
//!
//! * [`RowPartition`] — contiguous nnz-balanced row blocks over a
//!   [`CsrMatrix`](crate::sparse::CsrMatrix), cut on the `indptr` prefix
//!   sum so every block carries ~nnz/parts work.
//! * [`spmv_parallel`] — multithreaded SpMV (std scoped threads, no
//!   dependencies) for all four precision [`Scheme`](crate::precision::Scheme)s.
//!   Row-parallel CSR never splits a row, so per-row accumulation order
//!   is untouched and the output is **bitwise identical** to the serial
//!   kernels — Table-7 iteration counts cannot drift (asserted in
//!   `tests/engine_parallel.rs`).  [`spmv_block_parallel`] is its
//!   block-CG extension: one nnz pass feeds every RHS lane of an
//!   interleaved lane-major batch, with the same per-lane bit contract,
//!   and [`dot_delay_parallel`] splits the delay-buffer dot's fixed
//!   8-lane partition across workers without moving a bit.
//! * [`PreparedMatrix`] — a solve plan that derives `vals_f32`, the
//!   Jacobi diagonal and the partition once (behind `Arc`s, so clones
//!   and the [`service`](crate::service) registry share one copy), then
//!   serves any number of solves: [`PreparedMatrix::solve`] runs one
//!   right-hand side with the parallel SpMV inside the fused JPCG loop,
//!   and [`PreparedMatrix::solve_batch`] runs many right-hand sides
//!   through one compiled batched program — the batching story for
//!   serving concurrent solve requests.
//! * [`pool`] — the persistent [`WorkerPool`] (std mpsc) that replaces
//!   per-call `thread::scope` spawns on the batch paths and executes
//!   the service layer's coalesced batches.

mod partition;
mod plan;
pub mod pool;
mod spmv;

pub use partition::RowPartition;
pub use plan::PreparedMatrix;
pub use pool::WorkerPool;
pub use spmv::{
    axpy_block_parallel, dot_block_parallel, dot_delay_parallel, left_divide_block_parallel,
    spmv_block_parallel, spmv_f64_parallel, spmv_parallel, update_p_block_parallel,
    BLOCK_VEC_PARALLEL_MIN_LEN, DOT_PARALLEL_MIN_LEN,
};
