//! Multithreaded SpMV kernels over an nnz-balanced [`RowPartition`].
//!
//! Each worker runs the *serial* row-block kernel
//! ([`crate::precision::spmv_scheme_rows`]) on its own disjoint slice of
//! y.  No row is ever split across workers, so every y\[i\] is computed
//! by exactly the serial per-row loop — the parallel output is bitwise
//! identical to the serial one for all four schemes, at any thread
//! count.  That invariant is what allows the solver to go parallel
//! without moving a single Table-7 iteration count.

use crate::precision::{
    axpy_block, dot_block, dot_block_lane, dot_delay_buffer, left_divide_block, spmv_scheme_rows,
    spmv_scheme_rows_block, update_p_block, Scheme, DELAY_LANES,
};
use crate::sparse::CsrMatrix;

use super::RowPartition;

/// Split an interleaved lane-major buffer into the partition's disjoint
/// row blocks, each widened by the lane stride (the `mem::take` slab
/// idiom: every split's loan lands on a dead temporary, which is the
/// borrowck-clean way to carve a `&mut` slice in a loop).
fn split_lane_major<'y>(
    ys: &'y mut [f64],
    lanes: usize,
    part: &RowPartition,
) -> Vec<(usize, &'y mut [f64])> {
    let mut blocks: Vec<(usize, &mut [f64])> = Vec::with_capacity(part.num_parts());
    let mut rest = ys;
    let mut offset = 0usize;
    for k in 0..part.num_parts() {
        let range = part.range(k);
        let slab = std::mem::take(&mut rest);
        let (head, tail) = slab.split_at_mut((range.end - offset) * lanes);
        if !head.is_empty() {
            blocks.push((range.start, head));
        }
        rest = tail;
        offset = range.end;
    }
    blocks
}

/// y = A x under `scheme`, one scoped thread per partition block.
/// `vals32` must be the f32 view of `a.vals` (may be empty for
/// [`Scheme::Fp64`]).  Blocks of zero rows spawn nothing; a one-block
/// partition runs inline with no thread overhead.
pub fn spmv_parallel(
    a: &CsrMatrix,
    vals32: &[f32],
    x: &[f64],
    y: &mut [f64],
    scheme: Scheme,
    part: &RowPartition,
) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    if part.num_parts() <= 1 {
        spmv_scheme_rows(a, vals32, x, y, 0, scheme);
        return;
    }
    // Split y into the partition's disjoint row blocks (mem::take keeps
    // each split's loan on a dead temporary, the borrowck-clean idiom
    // for carving a &mut slice in a loop).
    let mut blocks: Vec<(usize, &mut [f64])> = Vec::with_capacity(part.num_parts());
    let mut rest = y;
    let mut offset = 0usize;
    for k in 0..part.num_parts() {
        let range = part.range(k);
        let slab = std::mem::take(&mut rest);
        let (head, tail) = slab.split_at_mut(range.end - offset);
        if !head.is_empty() {
            blocks.push((range.start, head));
        }
        rest = tail;
        offset = range.end;
    }
    std::thread::scope(|s| {
        // First block runs on the calling thread: parts-1 spawns, not
        // parts, and the caller is never idle.
        let mut iter = blocks.into_iter();
        let first = iter.next();
        for (row_start, y_rows) in iter {
            s.spawn(move || spmv_scheme_rows(a, vals32, x, y_rows, row_start, scheme));
        }
        if let Some((row_start, y_rows)) = first {
            spmv_scheme_rows(a, vals32, x, y_rows, row_start, scheme);
        }
    });
}

/// FP64 convenience wrapper (the `spmv_csr_f64` hot path).
pub fn spmv_f64_parallel(a: &CsrMatrix, x: &[f64], y: &mut [f64], part: &RowPartition) {
    spmv_parallel(a, &[], x, y, Scheme::Fp64, part);
}

/// Block-CG SpMV over the partition: `ys = A xs` for `lanes`
/// interleaved lane-major right-hand sides in **one pass** over the nnz
/// structure per row block (see
/// [`spmv_scheme_rows_block`](crate::precision::spmv_scheme_rows_block)).
/// Each worker's disjoint slice of `ys` is `lanes` f64s per row, so the
/// row-boundary split of the single-lane kernel scales by the lane
/// stride and nothing else.  Per lane the output is bitwise the serial
/// per-lane SpMV at any thread count — the same invariant as
/// [`spmv_parallel`], extended along the batch axis.
pub fn spmv_block_parallel(
    a: &CsrMatrix,
    vals32: &[f32],
    xs: &[f64],
    ys: &mut [f64],
    lanes: usize,
    scheme: Scheme,
    part: &RowPartition,
) {
    debug_assert_eq!(xs.len(), a.n * lanes);
    debug_assert_eq!(ys.len(), a.n * lanes);
    if lanes == 0 {
        return;
    }
    if part.num_parts() <= 1 {
        spmv_scheme_rows_block(a, vals32, xs, ys, 0, lanes, scheme);
        return;
    }
    let blocks = split_lane_major(ys, lanes, part);
    std::thread::scope(|s| {
        let mut iter = blocks.into_iter();
        let first = iter.next();
        for (row_start, y_rows) in iter {
            s.spawn(move || spmv_scheme_rows_block(a, vals32, xs, y_rows, row_start, lanes, scheme));
        }
        if let Some((row_start, y_rows)) = first {
            spmv_scheme_rows_block(a, vals32, xs, y_rows, row_start, lanes, scheme);
        }
    });
}

/// Below this many total elements a parallel block vector op's spawn
/// cost outweighs the O(1)-flop-per-element work; the `*_block_parallel`
/// element-wise wrappers stay on the serial block kernels.
pub const BLOCK_VEC_PARALLEL_MIN_LEN: usize = 16_384;

/// Block axpy over the partition's row blocks: the resident block-CG
/// M3/M4 sweep, every lane updated from one pass over the interleaved
/// arenas.  Element-wise ops never cross rows, so the row split cannot
/// touch any lane's op order — per lane the output is bitwise the
/// serial `AxpyModule` at any thread count (the sub-range cover is
/// pinned in `precision`'s tests, the parallel grid below).
pub fn axpy_block_parallel(alphas: &[f64], xs: &[f64], ys: &mut [f64], part: &RowPartition) {
    debug_assert_eq!(xs.len(), ys.len());
    let lanes = alphas.len();
    if part.num_parts() <= 1 || ys.len() < BLOCK_VEC_PARALLEL_MIN_LEN {
        axpy_block(alphas, xs, ys);
        return;
    }
    let blocks = split_lane_major(ys, lanes, part);
    std::thread::scope(|s| {
        let mut iter = blocks.into_iter();
        let first = iter.next();
        for (row_start, y_rows) in iter {
            let xr = &xs[row_start * lanes..row_start * lanes + y_rows.len()];
            s.spawn(move || axpy_block(alphas, xr, y_rows));
        }
        if let Some((row_start, y_rows)) = first {
            let xr = &xs[row_start * lanes..row_start * lanes + y_rows.len()];
            axpy_block(alphas, xr, y_rows);
        }
    });
}

/// Block left divide (M5) over the partition's row blocks; `m` is the
/// shared per-row Jacobi diagonal (length n).  Same bit contract as
/// [`axpy_block_parallel`].
pub fn left_divide_block_parallel(
    rs: &[f64],
    m: &[f64],
    zs: &mut [f64],
    lanes: usize,
    part: &RowPartition,
) {
    debug_assert_eq!(rs.len(), zs.len());
    debug_assert_eq!(rs.len(), m.len() * lanes);
    if part.num_parts() <= 1 || zs.len() < BLOCK_VEC_PARALLEL_MIN_LEN {
        left_divide_block(rs, m, zs, lanes);
        return;
    }
    let blocks = split_lane_major(zs, lanes, part);
    std::thread::scope(|s| {
        let mut iter = blocks.into_iter();
        let first = iter.next();
        for (row_start, z_rows) in iter {
            let rr = &rs[row_start * lanes..row_start * lanes + z_rows.len()];
            let mr = &m[row_start..row_start + z_rows.len() / lanes];
            s.spawn(move || left_divide_block(rr, mr, z_rows, lanes));
        }
        if let Some((row_start, z_rows)) = first {
            let rr = &rs[row_start * lanes..row_start * lanes + z_rows.len()];
            let mr = &m[row_start..row_start + z_rows.len() / lanes];
            left_divide_block(rr, mr, z_rows, lanes);
        }
    });
}

/// Block update-p (M7) over the partition's row blocks.  Same bit
/// contract as [`axpy_block_parallel`].
pub fn update_p_block_parallel(betas: &[f64], zs: &[f64], ps: &mut [f64], part: &RowPartition) {
    debug_assert_eq!(zs.len(), ps.len());
    let lanes = betas.len();
    if part.num_parts() <= 1 || ps.len() < BLOCK_VEC_PARALLEL_MIN_LEN {
        update_p_block(betas, zs, ps);
        return;
    }
    let blocks = split_lane_major(ps, lanes, part);
    std::thread::scope(|s| {
        let mut iter = blocks.into_iter();
        let first = iter.next();
        for (row_start, p_rows) in iter {
            let zr = &zs[row_start * lanes..row_start * lanes + p_rows.len()];
            s.spawn(move || update_p_block(betas, zr, p_rows));
        }
        if let Some((row_start, p_rows)) = first {
            let zr = &zs[row_start * lanes..row_start * lanes + p_rows.len()];
            update_p_block(betas, zr, p_rows);
        }
    });
}

/// Block dot (M2/M6/M8) with the *lane* axis split across up to
/// `workers` threads — a row split would reassociate a lane's
/// delay-buffer chain, but lanes are independent chains, so each
/// `out[j]` is computed by exactly
/// [`dot_block_lane`](crate::precision::dot_block_lane) no matter which
/// worker runs it: bitwise the serial per-lane delay-buffer dot at any
/// worker count.
pub fn dot_block_parallel(a: &[f64], b: &[f64], out: &mut [f64], workers: usize) {
    debug_assert_eq!(a.len(), b.len());
    let lanes = out.len();
    if workers <= 1 || lanes <= 1 || a.len() < DOT_PARALLEL_MIN_LEN {
        dot_block(a, b, out);
        return;
    }
    let per = lanes.div_ceil(workers.min(lanes));
    std::thread::scope(|s| {
        let mut chunks = out.chunks_mut(per).enumerate();
        let first = chunks.next();
        for (ci, chunk) in chunks {
            s.spawn(move || {
                for (j, o) in chunk.iter_mut().enumerate() {
                    *o = dot_block_lane(a, b, lanes, ci * per + j);
                }
            });
        }
        if let Some((ci, chunk)) = first {
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = dot_block_lane(a, b, lanes, ci * per + j);
            }
        }
    });
}

/// Below this length a parallel dot's spawn cost outweighs the work;
/// [`dot_delay_parallel`] stays on the serial delay-buffer kernel.
pub const DOT_PARALLEL_MIN_LEN: usize = 8_192;

/// The delay-buffer dot with its 8 lanes split across up to `workers`
/// threads — **bitwise identical** to
/// [`dot_delay_buffer`](crate::precision::dot_delay_buffer) at every
/// worker count, because the delay-buffer grouping is a *fixed
/// partition*: element `i` belongs to lane `i % 8` no matter who
/// computes it, each worker walks its lanes' stride-8 index sequences
/// in increasing order (the exact per-lane chains of the serial
/// kernel), and the final fold is the same left-to-right lane sum.
/// This is the bit-exact half of PERF §7: an L-way reduction that never
/// reassociates.
pub fn dot_delay_parallel(a: &[f64], b: &[f64], workers: usize) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if workers <= 1 || a.len() < DOT_PARALLEL_MIN_LEN {
        return dot_delay_buffer(a, b);
    }
    let mut lanes = [0.0f64; DELAY_LANES];
    let per = DELAY_LANES.div_ceil(workers.min(DELAY_LANES));
    std::thread::scope(|s| {
        let mut chunks = lanes.chunks_mut(per).enumerate();
        let first = chunks.next();
        for (ci, lane_chunk) in chunks {
            s.spawn(move || fill_lane_chunk(a, b, ci * per, lane_chunk));
        }
        if let Some((ci, lane_chunk)) = first {
            fill_lane_chunk(a, b, ci * per, lane_chunk);
        }
    });
    lanes.iter().sum()
}

/// One worker's share of [`dot_delay_parallel`]: the delay-buffer lanes
/// `lane_start..lane_start + chunk.len()`, each walked in index order.
fn fill_lane_chunk(a: &[f64], b: &[f64], lane_start: usize, chunk: &mut [f64]) {
    for (j, lane) in chunk.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        let mut i = lane_start + j;
        while i < a.len() {
            acc += a[i] * b[i];
            i += DELAY_LANES;
        }
        *lane = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth;

    #[test]
    fn parallel_matches_serial_bitwise_all_schemes() {
        let a = synth::banded_spd(1_200, 9_600, 1e-3, 13);
        let vals32 = a.vals_f32();
        let x: Vec<f64> = (0..a.n).map(|i| (i as f64 * 0.17).cos()).collect();
        for scheme in Scheme::ALL {
            let mut serial = vec![0.0; a.n];
            spmv_scheme_rows(&a, &vals32, &x, &mut serial, 0, scheme);
            for threads in [1, 2, 8] {
                let part = RowPartition::nnz_balanced(&a, threads);
                let mut par = vec![0.0; a.n];
                spmv_parallel(&a, &vals32, &x, &mut par, scheme, &part);
                assert!(
                    serial.iter().zip(&par).all(|(u, v)| u.to_bits() == v.to_bits()),
                    "scheme {scheme:?} diverged at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn block_parallel_matches_serial_per_lane_bitwise() {
        let a = synth::banded_spd(1_000, 8_000, 1e-3, 17);
        let vals32 = a.vals_f32();
        let lanes = 5usize;
        let per_lane: Vec<Vec<f64>> = (0..lanes)
            .map(|k| (0..a.n).map(|i| (i as f64 * 0.11 + k as f64).sin()).collect())
            .collect();
        let mut xs = vec![0.0; a.n * lanes];
        for (k, x) in per_lane.iter().enumerate() {
            for i in 0..a.n {
                xs[i * lanes + k] = x[i];
            }
        }
        for scheme in Scheme::ALL {
            let mut want: Vec<Vec<f64>> = Vec::new();
            for x in &per_lane {
                let mut y = vec![0.0; a.n];
                spmv_scheme_rows(&a, &vals32, x, &mut y, 0, scheme);
                want.push(y);
            }
            for threads in [1, 2, 8] {
                let part = RowPartition::nnz_balanced(&a, threads);
                let mut ys = vec![f64::NAN; a.n * lanes];
                spmv_block_parallel(&a, &vals32, &xs, &mut ys, lanes, scheme, &part);
                for (k, w) in want.iter().enumerate() {
                    assert!(
                        (0..a.n).all(|i| ys[i * lanes + k].to_bits() == w[i].to_bits()),
                        "{scheme:?} lane {k} diverged at {threads} threads"
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_dot_is_bitwise_the_delay_buffer_dot() {
        use crate::precision::dot_delay_buffer;
        // Lengths straddling the parallel threshold, awkward tails, and
        // a magnitude spread that would expose any reassociation.
        for n in [0usize, 7, 1_003, DOT_PARALLEL_MIN_LEN - 1, DOT_PARALLEL_MIN_LEN + 5, 40_003] {
            let a: Vec<f64> =
                (0..n).map(|i| ((i * 37) % 101) as f64 * 10f64.powi((i % 7) as i32 - 3)).collect();
            let b: Vec<f64> = (0..n).map(|i| ((i * 53) % 97) as f64 - 48.0).collect();
            let want = dot_delay_buffer(&a, &b);
            for workers in [1usize, 2, 3, 8, 16] {
                let got = dot_delay_parallel(&a, &b, workers);
                assert_eq!(got.to_bits(), want.to_bits(), "n={n} workers={workers}");
            }
        }
    }

    #[test]
    fn parallel_block_vector_ops_are_bitwise_serial_at_any_width() {
        use crate::precision::{axpy_block, dot_block, left_divide_block, update_p_block};
        // n chosen to straddle BLOCK_VEC_PARALLEL_MIN_LEN / lanes so both
        // the serial short-circuit and the threaded split are exercised.
        for n in [257usize, 6_000] {
            for lanes in [1usize, 3, 8] {
                let mk = |salt: usize| -> Vec<f64> {
                    (0..n * lanes)
                        .map(|i| ((i * 37 + salt) % 101) as f64 * 10f64.powi((i % 7) as i32 - 3))
                        .collect()
                };
                let (xs, ys, zs, ps) = (mk(0), mk(1), mk(2), mk(3));
                let m: Vec<f64> = (0..n).map(|i| 1.0 + ((i * 13) % 17) as f64).collect();
                let alphas: Vec<f64> = (0..lanes).map(|k| 0.5 - 0.4 * k as f64).collect();
                // Serial references.
                let mut want_y = ys.clone();
                axpy_block(&alphas, &xs, &mut want_y);
                let mut want_z = vec![0.0; n * lanes];
                left_divide_block(&ys, &m, &mut want_z, lanes);
                let mut want_p = ps.clone();
                update_p_block(&alphas, &zs, &mut want_p);
                let mut want_d = vec![0.0; lanes];
                dot_block(&xs, &ys, &mut want_d);
                // Synthetic matrix only to cut a partition over n rows.
                let a = synth::banded_spd(n, 4 * n, 1e-2, 9);
                for threads in [1usize, 2, 8] {
                    let part = RowPartition::nnz_balanced(&a, threads);
                    let mut y = ys.clone();
                    axpy_block_parallel(&alphas, &xs, &mut y, &part);
                    assert!(y.iter().zip(&want_y).all(|(u, v)| u.to_bits() == v.to_bits()));
                    let mut z = vec![f64::NAN; n * lanes];
                    left_divide_block_parallel(&ys, &m, &mut z, lanes, &part);
                    assert!(z.iter().zip(&want_z).all(|(u, v)| u.to_bits() == v.to_bits()));
                    let mut p = ps.clone();
                    update_p_block_parallel(&alphas, &zs, &mut p, &part);
                    assert!(p.iter().zip(&want_p).all(|(u, v)| u.to_bits() == v.to_bits()));
                    let mut d = vec![f64::NAN; lanes];
                    dot_block_parallel(&xs, &ys, &mut d, threads);
                    assert!(d.iter().zip(&want_d).all(|(u, v)| u.to_bits() == v.to_bits()));
                }
            }
        }
    }

    #[test]
    fn more_parts_than_rows_is_safe() {
        let a = synth::laplace2d_shifted(9, 0.1);
        let part = RowPartition::nnz_balanced(&a, 16);
        let x = vec![1.0; a.n];
        let mut y = vec![0.0; a.n];
        spmv_f64_parallel(&a, &x, &mut y, &part);
        let mut want = vec![0.0; a.n];
        a.spmv_f64(&x, &mut want);
        assert_eq!(y, want);
    }
}
