//! Multithreaded SpMV kernels over an nnz-balanced [`RowPartition`].
//!
//! Each worker runs the *serial* row-block kernel
//! ([`crate::precision::spmv_scheme_rows`]) on its own disjoint slice of
//! y.  No row is ever split across workers, so every y\[i\] is computed
//! by exactly the serial per-row loop — the parallel output is bitwise
//! identical to the serial one for all four schemes, at any thread
//! count.  That invariant is what allows the solver to go parallel
//! without moving a single Table-7 iteration count.

use crate::precision::{spmv_scheme_rows, Scheme};
use crate::sparse::CsrMatrix;

use super::RowPartition;

/// y = A x under `scheme`, one scoped thread per partition block.
/// `vals32` must be the f32 view of `a.vals` (may be empty for
/// [`Scheme::Fp64`]).  Blocks of zero rows spawn nothing; a one-block
/// partition runs inline with no thread overhead.
pub fn spmv_parallel(
    a: &CsrMatrix,
    vals32: &[f32],
    x: &[f64],
    y: &mut [f64],
    scheme: Scheme,
    part: &RowPartition,
) {
    debug_assert_eq!(x.len(), a.n);
    debug_assert_eq!(y.len(), a.n);
    if part.num_parts() <= 1 {
        spmv_scheme_rows(a, vals32, x, y, 0, scheme);
        return;
    }
    // Split y into the partition's disjoint row blocks (mem::take keeps
    // each split's loan on a dead temporary, the borrowck-clean idiom
    // for carving a &mut slice in a loop).
    let mut blocks: Vec<(usize, &mut [f64])> = Vec::with_capacity(part.num_parts());
    let mut rest = y;
    let mut offset = 0usize;
    for k in 0..part.num_parts() {
        let range = part.range(k);
        let slab = std::mem::take(&mut rest);
        let (head, tail) = slab.split_at_mut(range.end - offset);
        if !head.is_empty() {
            blocks.push((range.start, head));
        }
        rest = tail;
        offset = range.end;
    }
    std::thread::scope(|s| {
        // First block runs on the calling thread: parts-1 spawns, not
        // parts, and the caller is never idle.
        let mut iter = blocks.into_iter();
        let first = iter.next();
        for (row_start, y_rows) in iter {
            s.spawn(move || spmv_scheme_rows(a, vals32, x, y_rows, row_start, scheme));
        }
        if let Some((row_start, y_rows)) = first {
            spmv_scheme_rows(a, vals32, x, y_rows, row_start, scheme);
        }
    });
}

/// FP64 convenience wrapper (the `spmv_csr_f64` hot path).
pub fn spmv_f64_parallel(a: &CsrMatrix, x: &[f64], y: &mut [f64], part: &RowPartition) {
    spmv_parallel(a, &[], x, y, Scheme::Fp64, part);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth;

    #[test]
    fn parallel_matches_serial_bitwise_all_schemes() {
        let a = synth::banded_spd(1_200, 9_600, 1e-3, 13);
        let vals32 = a.vals_f32();
        let x: Vec<f64> = (0..a.n).map(|i| (i as f64 * 0.17).cos()).collect();
        for scheme in Scheme::ALL {
            let mut serial = vec![0.0; a.n];
            spmv_scheme_rows(&a, &vals32, &x, &mut serial, 0, scheme);
            for threads in [1, 2, 8] {
                let part = RowPartition::nnz_balanced(&a, threads);
                let mut par = vec![0.0; a.n];
                spmv_parallel(&a, &vals32, &x, &mut par, scheme, &part);
                assert!(
                    serial.iter().zip(&par).all(|(u, v)| u.to_bits() == v.to_bits()),
                    "scheme {scheme:?} diverged at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn more_parts_than_rows_is_safe() {
        let a = synth::laplace2d_shifted(9, 0.1);
        let part = RowPartition::nnz_balanced(&a, 16);
        let x = vec![1.0; a.n];
        let mut y = vec![0.0; a.n];
        spmv_f64_parallel(&a, &x, &mut y, &part);
        let mut want = vec![0.0; a.n];
        a.spmv_f64(&x, &mut want);
        assert_eq!(y, want);
    }
}
