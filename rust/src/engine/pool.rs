//! The persistent worker pool (PERF §7 follow-up): a fixed set of
//! threads fed over std `mpsc`, replacing per-call `thread::scope`
//! spawns on the batch paths.  Spawning an OS thread costs tens of
//! microseconds; on small systems (n ≲ 10k) a whole 10-iteration solve
//! is of that order, so per-call spawning was a measurable tax
//! (`solve_batch_8rhs_small_*` rows in `BENCH_hot_paths.json`).
//!
//! Two entry points:
//!
//! * [`WorkerPool::spawn`] — fire-and-forget `'static` jobs, what the
//!   [`service`](crate::service) scheduler uses to execute coalesced
//!   batches (results come back through its completion handles).
//! * [`WorkerPool::run_scoped`] — a `thread::scope` replacement for
//!   *borrowing* jobs: blocks until every job has run.  The caller
//!   participates in draining its own job queue, so the call makes
//!   progress even when every pool thread is busy (or when called from
//!   *inside* a pool job) — submission never deadlocks on pool
//!   capacity.
//!
//! A process-wide pool sized to the machine is available via
//! [`global`]; the engine's
//! [`solve_batch_workers`](crate::engine::PreparedMatrix::solve_batch_workers)
//! runs on it.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::obs::catalog as obs;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// A lifetime-erased scoped job (see the safety notes in
/// [`WorkerPool::run_scoped`]).
type ScopedJob = Box<dyn FnOnce() + Send + 'static>;

/// One `run_scoped` call's shared state: the job queue, the count of
/// jobs not yet finished, and the panic flag.
struct ScopeState {
    queue: Mutex<VecDeque<ScopedJob>>,
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl ScopeState {
    /// Pop and run one queued job; `false` when the queue is empty.
    /// Panics inside the job are caught and flagged, so this never
    /// unwinds into the worker loop.
    fn run_one(&self) -> bool {
        let job = self.queue.lock().expect("scope queue poisoned").pop_front();
        let Some(job) = job else { return false };
        if catch_unwind(AssertUnwindSafe(job)).is_err() {
            obs::POOL_PANICS_RECOVERED.inc();
            self.panicked.store(true, Ordering::SeqCst);
        }
        self.finish_one();
        true
    }

    fn finish_one(&self) {
        let mut p = self.pending.lock().expect("scope counter poisoned");
        *p -= 1;
        if *p == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every job of the scope has finished.
    fn wait(&self) {
        let mut p = self.pending.lock().expect("scope counter poisoned");
        while *p > 0 {
            p = self.done.wait(p).expect("scope counter poisoned");
        }
    }
}

/// What travels down the pool channel.
enum Task {
    /// A fire-and-forget job.
    Once(Box<dyn FnOnce() + Send + 'static>),
    /// An invitation to help drain one scoped call's queue.
    Scope(Arc<ScopeState>),
}

/// A fixed-size persistent thread pool (std `mpsc`, no dependencies).
/// Dropping the pool closes the channel; workers finish every job
/// already submitted, then exit, and the drop joins them.
pub struct WorkerPool {
    tx: Option<Sender<Task>>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("workers", &self.workers).finish()
    }
}

impl WorkerPool {
    /// A pool of `workers` threads (>= 1).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|k| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("callipepla-pool-{k}"))
                    .spawn(move || Self::worker_loop(&rx))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Some(tx), handles, workers }
    }

    /// A pool with one thread per available hardware thread.
    pub fn with_default_threads() -> Self {
        Self::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    fn worker_loop(rx: &Mutex<Receiver<Task>>) {
        loop {
            // Hold the lock only for the blocking recv; the channel
            // disconnects (Err) when the pool is dropped.
            let task = match rx.lock().expect("pool receiver poisoned").recv() {
                Ok(t) => t,
                Err(_) => return,
            };
            match task {
                Task::Once(job) => {
                    // A panicking fire-and-forget job must not kill the
                    // worker; the submitter observes failure through its
                    // own completion channel (e.g. service tickets).
                    obs::POOL_JOBS.inc();
                    if catch_unwind(AssertUnwindSafe(job)).is_err() {
                        obs::POOL_PANICS_RECOVERED.inc();
                    }
                }
                Task::Scope(scope) => while scope.run_one() {},
            }
        }
    }

    /// Worker-thread count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    fn sender(&self) -> &Sender<Task> {
        self.tx.as_ref().expect("pool channel open until drop")
    }

    /// Submit a fire-and-forget job.  A panic inside the job is caught
    /// by the worker (the pool survives); deliver failure through the
    /// job's own result channel.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.sender().send(Task::Once(Box::new(job))).expect("pool workers alive");
    }

    /// Run borrowing jobs to completion — the persistent-pool
    /// replacement for per-call `std::thread::scope`.  Blocks until
    /// every job has finished; pool threads help, and the calling
    /// thread drains its own queue too, so the call completes even
    /// with zero free workers (including when called from inside a
    /// pool job — nested use cannot deadlock).
    ///
    /// Like `thread::scope`, panics in jobs are collected and re-raised
    /// here (as one panic) after every job has ended.
    pub fn run_scoped<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        self.run_scoped_capped(jobs, usize::MAX);
    }

    /// [`WorkerPool::run_scoped`] inviting at most `helpers` pool
    /// threads.  The caller always participates, so `helpers == 0` runs
    /// every job on the calling thread, in submission order — which is
    /// how an explicit worker budget (e.g. the coordinator's lane-worker
    /// count) is honored on the shared [`global`] pool without resizing
    /// it: a budget of `w` workers is the caller plus `w - 1` helpers.
    pub fn run_scoped_capped<'env>(
        &self,
        jobs: Vec<Box<dyn FnOnce() + Send + 'env>>,
        helpers: usize,
    ) {
        if jobs.is_empty() {
            return;
        }
        obs::POOL_SCOPED_FANOUTS.inc();
        let n = jobs.len();
        // SAFETY: the 'env borrows captured by the jobs outlive this
        // call, and this function does not return (or unwind — nothing
        // below panics outside the caught job closures) until
        // `pending == 0`, i.e. until every erased job has been consumed
        // and finished.  No job can run after return, so no borrow is
        // ever used past its lifetime.
        let erased: VecDeque<ScopedJob> = jobs
            .into_iter()
            .map(|j| unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, ScopedJob>(j)
            })
            .collect();
        let scope = Arc::new(ScopeState {
            queue: Mutex::new(erased),
            pending: Mutex::new(n),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        // Invite up to one helper per remaining job (bounded by the
        // caller's cap); the caller runs jobs too, so n == 1 needs no
        // helper at all.
        for _ in 0..self.workers.min(n.saturating_sub(1)).min(helpers) {
            self.sender().send(Task::Scope(Arc::clone(&scope))).expect("pool workers alive");
        }
        while scope.run_one() {}
        scope.wait();
        if scope.panicked.load(Ordering::SeqCst) {
            panic!("a job submitted to WorkerPool::run_scoped panicked");
        }
    }

    /// Run `job(0..count)` across the caller plus up to `helpers` pool
    /// threads, indices handed out through one shared atomic cursor —
    /// the allocation-light fan-out for hot per-trip dispatch
    /// (PERF §11): where [`WorkerPool::run_scoped_capped`] boxes one
    /// closure **per item**, this boxes one small drain loop **per
    /// participating worker**, so a batched solve's per-trip allocation
    /// count is bounded by the worker budget instead of the lane count.
    /// Each index is claimed by exactly one worker (the cursor is a
    /// fetch-add), which is what lets a caller hand out disjoint
    /// `&mut` state per index.  `helpers == 0` degenerates to the
    /// caller-only walk in index order, allocation-free.  Panics in
    /// `job` re-raise here after every claimed index has finished, like
    /// [`WorkerPool::run_scoped`].
    pub fn run_scoped_indexed<'env>(
        &self,
        count: usize,
        helpers: usize,
        job: &(dyn Fn(usize) + Sync + 'env),
    ) {
        if count == 0 {
            return;
        }
        obs::POOL_SCOPED_FANOUTS.inc();
        let invite = self.workers.min(helpers).min(count.saturating_sub(1));
        if invite == 0 {
            for i in 0..count {
                job(i);
            }
            return;
        }
        let cursor = std::sync::atomic::AtomicUsize::new(0);
        // invite + 1 drain loops: one per invited helper plus one for
        // the caller to pick up (workers that arrive after the cursor
        // is spent exit immediately).
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..invite + 1)
            .map(|_| {
                let cursor = &cursor;
                Box::new(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= count {
                        break;
                    }
                    job(i);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        self.run_scoped_capped(jobs, invite);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channel lets workers drain what was already
        // submitted, then exit; joining makes shutdown deterministic.
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The process-wide pool (one thread per hardware thread), created on
/// first use.  The engine's batch paths run on it so back-to-back batch
/// calls stop paying per-call spawn cost.
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(WorkerPool::with_default_threads)
}

/// The lane-worker budget a configuration value of 0 ("machine
/// default") resolves to: the `CALLIPEPLA_LANE_WORKERS` environment
/// variable when set to a positive integer (the CI thread-matrix arm
/// pins it to 1 and to the core count so scheduling-order bugs cannot
/// hide behind one lucky default), otherwise one worker per available
/// hardware thread.
pub fn default_lane_workers() -> usize {
    std::env::var("CALLIPEPLA_LANE_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|w| *w >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn scoped_jobs_all_run_and_borrow_locals() {
        let pool = WorkerPool::new(4);
        let mut outputs = vec![0usize; 64];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = outputs
            .iter_mut()
            .enumerate()
            .map(|(k, slot)| Box::new(move || *slot = k + 1) as Box<dyn FnOnce() + Send + '_>)
            .collect();
        pool.run_scoped(jobs);
        assert!(outputs.iter().enumerate().all(|(k, v)| *v == k + 1));
    }

    #[test]
    fn indexed_scope_visits_every_index_exactly_once() {
        let pool = WorkerPool::new(4);
        for count in [0usize, 1, 2, 17, 256] {
            for helpers in [0usize, 1, 3, 8] {
                let visits: Vec<AtomicUsize> =
                    (0..count).map(|_| AtomicUsize::new(0)).collect();
                pool.run_scoped_indexed(count, helpers, &|i| {
                    visits[i].fetch_add(1, Ordering::SeqCst);
                });
                assert!(
                    visits.iter().all(|v| v.load(Ordering::SeqCst) == 1),
                    "count={count} helpers={helpers}"
                );
            }
        }
    }

    #[test]
    fn scoped_call_completes_with_a_single_worker_and_nested_scopes() {
        // One worker, nested run_scoped on the *same* pool from inside
        // a scoped job: the callers drain their own queues, so this
        // cannot deadlock on pool capacity.
        let pool = WorkerPool::new(1);
        let count = AtomicUsize::new(0);
        let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let (pool, count) = (&pool, &count);
                let job = move || {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| {
                            let job = move || {
                                count.fetch_add(1, Ordering::SeqCst);
                            };
                            Box::new(job) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    pool.run_scoped(inner);
                };
                Box::new(job) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(outer);
        assert_eq!(count.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn global_pool_is_shared_and_alive() {
        let before = global().workers();
        assert!(before >= 1);
        let flag = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
            .map(|_| {
                let flag = &flag;
                Box::new(move || {
                    flag.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        global().run_scoped(jobs);
        assert_eq!(flag.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn spawn_runs_detached_jobs() {
        let pool = WorkerPool::new(2);
        let (tx, rx) = channel();
        for k in 0..8 {
            let tx = tx.clone();
            pool.spawn(move || tx.send(k).expect("receiver alive"));
        }
        let mut got: Vec<i32> = (0..8).map(|_| rx.recv().expect("job ran")).collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_panic_is_propagated_after_all_jobs_finish() {
        let pool = WorkerPool::new(2);
        let ran = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..6)
                .map(|k| {
                    Box::new(move || {
                        if k == 3 {
                            panic!("boom");
                        }
                        ran.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }));
        assert!(result.is_err(), "the scope re-raises the job panic");
        assert_eq!(ran.load(Ordering::SeqCst), 5, "the other jobs still ran");
    }

    #[test]
    fn capped_scope_with_zero_helpers_runs_on_the_caller_in_order() {
        let pool = WorkerPool::new(4);
        let me = std::thread::current().id();
        let log = Mutex::new(Vec::new());
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|k| {
                let log = &log;
                Box::new(move || {
                    log.lock().unwrap().push((k, std::thread::current().id()));
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped_capped(jobs, 0);
        let log = log.into_inner().unwrap();
        assert_eq!(log.iter().map(|(k, _)| *k).collect::<Vec<_>>(), (0..8).collect::<Vec<_>>());
        assert!(log.iter().all(|(_, id)| *id == me), "zero helpers means caller-only");
    }

    #[test]
    fn nested_run_scoped_from_a_worker_thread_completes() {
        // Two outer jobs rendezvous on a barrier, so one of them is
        // necessarily running on a pool worker (the other on the
        // caller); both then issue a nested run_scoped on the same
        // pool.  Workers drain scope queues they are invited to and the
        // nested callers drain their own, so this cannot wedge.
        let pool = WorkerPool::new(2);
        let barrier = std::sync::Barrier::new(2);
        let count = AtomicUsize::new(0);
        let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                let (pool, barrier, count) = (&pool, &barrier, &count);
                Box::new(move || {
                    barrier.wait();
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| {
                            Box::new(move || {
                                count.fetch_add(1, Ordering::SeqCst);
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    pool.run_scoped(inner);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(outer);
        assert_eq!(count.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn a_panicking_spawned_job_leaves_the_workers_serving() {
        let pool = WorkerPool::new(1);
        pool.spawn(|| panic!("boom"));
        let (tx, rx) = channel();
        pool.spawn(move || tx.send(42).expect("receiver alive"));
        assert_eq!(rx.recv().expect("the one worker survived the panic"), 42);
    }

    #[test]
    fn global_pool_survives_a_panicking_scoped_job() {
        // A panic inside one scoped job must re-raise at the call site
        // without wedging the scope or poisoning the process-wide pool
        // for whoever scopes next (e.g. a subsequent batch solve).
        let result = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|k| {
                    Box::new(move || {
                        if k == 2 {
                            panic!("boom");
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            global().run_scoped(jobs);
        }));
        assert!(result.is_err(), "the scope re-raises the job panic");
        let after = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
            .map(|_| {
                let after = &after;
                Box::new(move || {
                    after.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        global().run_scoped(jobs);
        assert_eq!(after.load(Ordering::SeqCst), 3, "the global pool still serves scopes");
    }

    #[test]
    fn lane_worker_default_is_at_least_one() {
        // (The env override is exercised by the CI thread-matrix arm,
        // which runs the whole suite under CALLIPEPLA_LANE_WORKERS.)
        assert!(default_lane_workers() >= 1);
    }

    #[test]
    fn dropping_the_pool_finishes_submitted_work() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(2);
            for _ in 0..16 {
                let c = Arc::clone(&counter);
                pool.spawn(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop joins the workers after the queue drains
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }
}
