//! Synthetic request-trace replay: the service's end-to-end benchmark
//! scenario (many tenants, few matrices, Poisson-ish arrivals) and the
//! no-coalescing baseline it is measured against.
//!
//! The trace generator draws everything from the deterministic
//! [`Rng64`](crate::util::rng::Rng64) stream, so a (seed, shape) pair
//! names one exact workload on every platform: per request an
//! exponential inter-arrival gap (that is the Poisson part — arrival
//! *order* across tenants is what it shapes; the replay submits in
//! arrival order at full speed), a tenant, a matrix drawn from the few
//! registered ones, and a right-hand side derived from (tenant,
//! sequence number) — so the same logical request always carries the
//! same bits no matter how the trace interleaves.

use crate::solver::SolveResult;
use crate::util::rng::Rng64;

use super::registry::{MatrixId, MatrixRegistry};
use super::scheduler::{SolveRequest, SolverService};

/// Shape of a synthetic request trace.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Total requests in the trace.
    pub requests: usize,
    /// Distinct tenants issuing them.
    pub tenants: u32,
    /// Mean arrivals per unit time (only shapes the recorded arrival
    /// stamps; the replay submits in arrival order).
    pub rate: f64,
    /// PRNG seed naming this exact trace.
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { requests: 64, tenants: 8, rate: 1.0, seed: 0xCA111_9E91A }
    }
}

/// One generated request plus its arrival stamp.
#[derive(Debug, Clone)]
pub struct TracedRequest {
    /// Poisson-ish arrival time (unit-free; monotone over the trace).
    pub arrival: f64,
    /// The request itself.
    pub request: SolveRequest,
}

/// The right-hand side tenant `tenant`'s `seq`-th request carries
/// against an `n`-vector system: deterministic, per-tenant distinct,
/// independent of arrival interleaving.
pub fn tenant_rhs(n: usize, tenant: u32, seq: u32) -> Vec<f64> {
    let phase = (tenant as usize * 31 + seq as usize * 7) % 13;
    (0..n).map(|i| 1.0 + ((i + phase) % 11) as f64 / 11.0).collect()
}

/// Generate a trace over the registered `matrices` (every request's
/// matrix is drawn uniformly from this slice).  Requests come back in
/// arrival order.
pub fn synth_trace(
    registry: &MatrixRegistry,
    matrices: &[MatrixId],
    cfg: &TraceConfig,
) -> Vec<TracedRequest> {
    assert!(!matrices.is_empty(), "a trace needs at least one matrix");
    let mut rng = Rng64::seed_from_u64(cfg.seed);
    let mut clock = 0.0f64;
    let mut seq_per_tenant = vec![0u32; cfg.tenants.max(1) as usize];
    (0..cfg.requests)
        .map(|_| {
            // Exponential inter-arrival gap: -ln(u) / rate.
            clock += -(rng.gen_f64().max(1e-12)).ln() / cfg.rate.max(1e-9);
            let tenant = rng.gen_range(cfg.tenants.max(1) as usize) as u32;
            let matrix = matrices[rng.gen_range(matrices.len())];
            let seq = seq_per_tenant[tenant as usize];
            seq_per_tenant[tenant as usize] += 1;
            let b = tenant_rhs(registry.entry(matrix).n(), tenant, seq);
            TracedRequest { arrival: clock, request: SolveRequest { matrix, b, tenant } }
        })
        .collect()
}

/// Outcome of one replay run.
#[derive(Debug)]
pub struct ReplayOutcome {
    /// Per-request results, in submission order.
    pub results: Vec<SolveResult>,
    /// End-to-end wall-clock seconds (submit of the first request to
    /// the last result).
    pub wall_s: f64,
    /// RHS-iterations retired.
    pub rhs_iterations: u64,
}

impl ReplayOutcome {
    /// End-to-end RHS-iterations/s — the serving throughput metric.
    pub fn rhs_iterations_per_second(&self) -> f64 {
        self.rhs_iterations as f64 / self.wall_s.max(1e-12)
    }
}

/// Replay a trace through the coalescing service: submit every request
/// in arrival order, flush the queue-drained remainder, wait for all
/// tickets.  Results come back in submission order, each bitwise a lone
/// [`jpcg_solve`](crate::solver::jpcg_solve).
pub fn replay_coalesced(svc: &mut SolverService, trace: &[TracedRequest]) -> ReplayOutcome {
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = trace.iter().map(|t| svc.submit(t.request.clone())).collect();
    svc.flush();
    let results: Vec<SolveResult> = tickets.into_iter().map(|t| t.wait()).collect();
    let wall_s = t0.elapsed().as_secs_f64();
    let rhs_iterations = results.iter().map(|r| r.iters as u64).sum();
    ReplayOutcome { results, wall_s, rhs_iterations }
}

/// The no-coalescing baseline: the same trace, one request at a time,
/// each as its own single-RHS program execution with **no** program
/// cache (what calling the solver per request looked like before the
/// service existed).  Prepared-matrix state is still shared via the
/// registry, and `opts` should match the service's so both paths do
/// identical numerical work — the baseline is honest about everything
/// except the serving layer under test.
pub fn replay_sequential(
    registry: &MatrixRegistry,
    trace: &[TracedRequest],
    opts: &crate::solver::SolveOptions,
) -> ReplayOutcome {
    let t0 = std::time::Instant::now();
    let results: Vec<SolveResult> = trace
        .iter()
        .map(|t| {
            let entry = registry.entry(t.request.matrix);
            let batch_of_one = vec![t.request.b.clone()];
            entry.plan().solve_batch(&batch_of_one, opts).pop().expect("one lane in, one out")
        })
        .collect();
    let wall_s = t0.elapsed().as_secs_f64();
    let rhs_iterations = results.iter().map(|r| r.iters as u64).sum();
    ReplayOutcome { results, wall_s, rhs_iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth;

    #[test]
    fn traces_are_deterministic_and_arrival_ordered() {
        let mut reg = MatrixRegistry::new();
        let ids = vec![
            reg.admit(synth::laplace2d_shifted(100, 0.2), 1),
            reg.admit(synth::laplace2d_shifted(150, 0.2), 1),
        ];
        let cfg = TraceConfig { requests: 32, tenants: 4, ..Default::default() };
        let a = synth_trace(&reg, &ids, &cfg);
        let b = synth_trace(&reg, &ids, &cfg);
        assert_eq!(a.len(), 32);
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.matrix, y.request.matrix);
            assert_eq!(x.request.tenant, y.request.tenant);
            assert_eq!(x.request.b, y.request.b);
        }
        // A different seed reshuffles the trace.
        let c = synth_trace(&reg, &ids, &TraceConfig { seed: 1, ..cfg });
        assert!(a.iter().zip(&c).any(|(x, y)| {
            x.request.matrix != y.request.matrix || x.request.tenant != y.request.tenant
        }));
    }

    #[test]
    fn tenant_rhs_depends_on_identity_not_arrival() {
        let r1 = tenant_rhs(64, 3, 5);
        let r2 = tenant_rhs(64, 3, 5);
        assert_eq!(r1, r2);
        assert_ne!(tenant_rhs(64, 3, 6), r1);
        assert_ne!(tenant_rhs(64, 4, 5), r1);
    }
}
