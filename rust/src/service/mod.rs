//! The solver **service layer**: what turns the repo from "a solver you
//! call" into "a solver you run".
//!
//! Callipepla's premise is sustained throughput — one compiled
//! instruction stream drives the whole solve, and since PR 3 it
//! amortizes over many right-hand sides.  A production deployment adds
//! one more axis: many *requests* against few *matrices* (the reservoir
//! simulator of Hogervorst et al., arXiv:2101.01745, and the repeated
//! Dirac-operator solves of Korcyl & Korcyl, arXiv:2001.05218, are both
//! this shape).  This module is that serving layer, in four pieces:
//!
//! * [`MatrixRegistry`] — admit a matrix once, derive its
//!   [`PreparedMatrix`](crate::engine::PreparedMatrix) state once,
//!   share it (`Arc`-held entries, zero-copy plan views) for every
//!   solve that follows.  Under a capacity budget
//!   ([`MatrixRegistry::with_capacity`], in HBM beats) derived state is
//!   LRU-evicted and readmitted on demand — bitwise-invisibly, with
//!   pinning for latency-critical matrices and `Arc` lifetimes keeping
//!   in-flight batches safe.
//! * a **bucketed program cache**
//!   ([`ProgramCache`](crate::program::ProgramCache)) — one compiled
//!   [`Program`](crate::program::Program) per (size bucket, channel
//!   mode, lane bucket), with smaller systems rebased into the bucket's
//!   memory map; solves stop recompiling per call.
//! * the **coalescing scheduler** ([`SolverService`]) — a submission
//!   queue that groups pending right-hand sides by matrix into lanes of
//!   one batched program (up to `max_batch`), flushing deterministically
//!   on batch-full, queue-drain, or a *logical-clock* latency deadline
//!   ([`ServiceConfig::deadline`]); typed admission control
//!   ([`SubmitError`]: validation, a bounded pending queue, per-tenant
//!   quotas); per-request [`SolveTicket`] completion handles; at most
//!   ⌈requests / max_batch⌉ program executions per matrix.  Every
//!   result stays **bitwise identical** to a lone
//!   [`jpcg_solve`](crate::solver::jpcg_solve) call.
//! * the **HTTP front door** ([`http`]) — a dependency-free
//!   `TcpListener` ingress (`callipepla serve --http <port>`): POST
//!   `/solve`/`/submit`, `/metrics` (Prometheus text), `/stats`
//!   (the [`ServiceStats::to_json`] snapshot), with rejections mapped
//!   to 400 (validation) and 429 (backpressure, quota).
//! * execution on the persistent
//!   [`WorkerPool`](crate::engine::WorkerPool) (no per-solve thread
//!   spawns), with [`replay`] providing the synthetic multi-tenant
//!   trace scenario, the no-coalescing baseline, and — through
//!   [`ServiceStats::modeled_cycles`] — the time-plane pricing of the
//!   same serving trace via
//!   [`sim::schedule_cycles`](crate::sim::schedule_cycles).
//!
//! Since PR 9 the scheduler also feeds the telemetry plane
//! ([`crate::obs`]): `callipepla_service_*` instruments (flush reasons,
//! coalesce width, logical queue wait, cache traffic) and — once a sink
//! is installed with [`SolverService::record_events`] — a deterministic
//! event trace of the schedule, stamped with submission/flush logical
//! clocks and byte-identical across replays of the same request trace.
//!
//! Design notes, the flush policy, and the bucket sizing rule live in
//! `docs/SERVICE.md` (telemetry in `docs/OBSERVABILITY.md`); the CLI
//! front-end is `callipepla serve`.
//!
//! ```
//! use callipepla::service::{ServiceConfig, SolveRequest, SolverService};
//! use callipepla::sparse::synth;
//!
//! let mut svc = SolverService::new(ServiceConfig::default());
//! let id = svc.register(synth::laplace2d_shifted(100, 0.2));
//! let ticket = svc.submit(SolveRequest::new(id, vec![1.0; 100]));
//! svc.flush(); // queue-drained flush (the batch was not full)
//! assert!(ticket.wait().converged);
//! ```

pub mod http;
pub mod registry;
pub mod replay;
pub mod scheduler;

pub use http::{handle_request, serve_http, HttpResponse};
pub use registry::{
    footprint_beats, EvictionNotice, MatrixEntry, MatrixId, MatrixRegistry, RegistryError,
    RegistryStats,
};
pub use replay::{
    replay_coalesced, replay_sequential, synth_trace, ReplayOutcome, TraceConfig, TracedRequest,
};
pub use scheduler::{
    BatchRecord, ServiceConfig, ServiceStats, SolveRequest, SolveTicket, SolverService,
    SubmitError,
};
