//! The coalescing batch scheduler: submission queue, deterministic
//! flush policy, persistent-pool execution, per-request completion
//! handles.
//!
//! **Coalescing rule.**  Pending requests are grouped *per matrix* in
//! per-matrix submission order and cut into batches of at most
//! `max_batch` lanes.  A group flushes when it reaches `max_batch`
//! (batch-full), when the caller drains the queue
//! ([`SolverService::flush`] / [`SolverService::drain`]), or — with
//! [`ServiceConfig::deadline`] set — when its oldest lane has waited
//! through that many subsequent submissions (deadline).  The deadline
//! is a **logical clock**, never a wall timer: batch composition stays
//! a pure function of the request sequence, so the same request set
//! produces the same batches (and, since every lane is bitwise a lone
//! [`jpcg_solve`](crate::solver::jpcg_solve), bitwise the same results)
//! no matter how arrivals from different tenants interleave or how
//! fast they come.
//!
//! **Admission control.**  [`SolverService::try_submit`] rejects with a
//! typed [`SubmitError`] instead of panicking: unknown/foreign ids and
//! wrong-length right-hand sides (validation), a full pending queue
//! ([`ServiceConfig::pending_limit`] — the backpressure the HTTP front
//! door maps to 429), and per-tenant quotas
//! ([`ServiceConfig::tenant_quota`]).  [`SolverService::submit`] is the
//! panicking wrapper for in-process callers that consider rejection a
//! bug.
//!
//! **Execution.**  A flushed batch becomes one fire-and-forget job on
//! the service's [`WorkerPool`]: build a zero-copy plan view from the
//! registry entry, fetch the bucket program from the shared
//! [`ProgramCache`], run
//! [`PreparedMatrix::solve_batch_parallel`](crate::engine::PreparedMatrix::solve_batch_parallel)
//! (the batch's lanes fan out across
//! [`ServiceConfig::lane_workers`] — bitwise the sequential dispatch,
//! PERF §9), fulfill each lane's [`SolveTicket`].  One job per batch
//! means at most ⌈requests / max_batch⌉ program executions per matrix
//! — the serving-layer amortization the ROADMAP asked for.  The job
//! holds its own `Arc<MatrixEntry>`, so a registry eviction mid-batch
//! (capacity pressure, see [`MatrixRegistry`]) never touches a running
//! solve.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

use crate::engine::WorkerPool;
use crate::obs::catalog as obs;
use crate::obs::{Event, EventKind, EventSink, FlushReason};
use crate::program::ProgramCache;
use crate::sim::{schedule_cycles, AccelSimConfig, ScheduledBatch};
use crate::solver::{SolveOptions, SolveResult};
use crate::sparse::CsrMatrix;
use crate::util::json::ObjWriter;

use super::registry::{MatrixEntry, MatrixId, MatrixRegistry, RegistryError, RegistryStats};

/// One queued solve: a right-hand side against an admitted matrix.
/// (`x0` is always zero in the serving path, the paper's setup.)
#[derive(Debug, Clone)]
pub struct SolveRequest {
    /// The admitted matrix to solve against.
    pub matrix: MatrixId,
    /// The right-hand side (length must match the matrix).
    pub b: Vec<f64>,
    /// Submitting tenant — a label carried into the batch records and
    /// counted against [`ServiceConfig::tenant_quota`]; never affects
    /// scheduling order or results.
    pub tenant: u32,
}

impl SolveRequest {
    /// A request from the anonymous tenant 0.
    pub fn new(matrix: MatrixId, b: Vec<f64>) -> Self {
        Self { matrix, b, tenant: 0 }
    }
}

/// Why [`SolverService::try_submit`] refused a request.  The HTTP front
/// door maps validation errors to 400 and load errors to 429.
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    /// The matrix id did not resolve (foreign/unknown id, or a capacity
    /// budget that cannot make it resident).
    Registry(RegistryError),
    /// The right-hand side length does not match the matrix.
    WrongRhsLength {
        /// The target matrix.
        matrix: MatrixId,
        /// Its vector length.
        expected: usize,
        /// The submitted length.
        got: usize,
    },
    /// The bounded pending queue is full
    /// ([`ServiceConfig::pending_limit`]) — retry after a flush.
    QueueFull {
        /// Lanes currently pending.
        pending: usize,
        /// The configured bound.
        limit: usize,
    },
    /// The tenant already has its quota of pending lanes
    /// ([`ServiceConfig::tenant_quota`]).
    TenantQuotaExceeded {
        /// The over-quota tenant.
        tenant: u32,
        /// Its pending lanes.
        pending: usize,
        /// The configured quota.
        quota: usize,
    },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Registry(e) => write!(f, "{e}"),
            SubmitError::WrongRhsLength { matrix, expected, got } => write!(
                f,
                "right-hand side length {got} does not match matrix {matrix} (n = {expected})"
            ),
            SubmitError::QueueFull { pending, limit } => {
                write!(f, "pending queue is full ({pending} lanes, limit {limit})")
            }
            SubmitError::TenantQuotaExceeded { tenant, pending, quota } => write!(
                f,
                "tenant {tenant} has {pending} pending lanes (quota {quota})"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

impl From<RegistryError> for SubmitError {
    fn from(e: RegistryError) -> Self {
        SubmitError::Registry(e)
    }
}

/// How one request ended.  `Failed` and `Taken` are terminal; `Done`
/// transitions to `Taken` exactly once, when the result is handed out.
#[derive(Debug)]
enum CompletionState {
    Pending,
    Done(SolveResult),
    /// The result was already handed out through
    /// [`SolveTicket::try_take`].
    Taken,
    /// The batch job panicked or the service was dropped before flush.
    Failed(&'static str),
}

#[derive(Debug)]
struct Completion {
    state: Mutex<CompletionState>,
    cv: Condvar,
}

impl Completion {
    fn new() -> Arc<Self> {
        Arc::new(Self { state: Mutex::new(CompletionState::Pending), cv: Condvar::new() })
    }

    /// Deliver the result.  Terminal states are sticky in **both**
    /// directions: a slot that already failed (service dropped, racing
    /// failure path) keeps its diagnostic — a late fulfill must not
    /// resurrect it — and a delivered/taken result is never overwritten.
    fn fulfill(&self, res: SolveResult) {
        let mut s = self.state.lock().expect("completion poisoned");
        if matches!(*s, CompletionState::Pending) {
            *s = CompletionState::Done(res);
            self.cv.notify_all();
        }
    }

    fn fail(&self, why: &'static str) {
        let mut s = self.state.lock().expect("completion poisoned");
        if matches!(*s, CompletionState::Pending) {
            *s = CompletionState::Failed(why);
            self.cv.notify_all();
        }
    }
}

/// Completion handle for one submitted request.
#[derive(Debug)]
pub struct SolveTicket {
    slot: Arc<Completion>,
}

impl SolveTicket {
    /// Block until the request's batch has executed and take the
    /// result (bitwise the result of a lone
    /// [`jpcg_solve`](crate::solver::jpcg_solve) on the same system).
    /// A ticket only resolves after its batch is flushed — call
    /// [`SolverService::flush`] (or `drain`) before waiting on
    /// requests that haven't filled a batch.
    ///
    /// Panics if the executing batch job panicked, the service was
    /// dropped with the request still queued, or the result was
    /// already taken through [`SolveTicket::try_take`].
    pub fn wait(self) -> SolveResult {
        let mut s = self.slot.state.lock().expect("completion poisoned");
        loop {
            match std::mem::replace(&mut *s, CompletionState::Taken) {
                CompletionState::Done(res) => return res,
                CompletionState::Failed(why) => {
                    // Failure is terminal: keep it visible to any other
                    // observer of this slot.
                    *s = CompletionState::Failed(why);
                    panic!("solve request failed: {why}");
                }
                CompletionState::Taken => panic!("solve result was already taken"),
                CompletionState::Pending => {
                    *s = CompletionState::Pending;
                    s = self.slot.cv.wait(s).expect("completion poisoned");
                }
            }
        }
    }

    /// Non-blocking take: the result if the batch already executed
    /// (`None` while pending, and `None` again after a successful
    /// take — the result is handed out exactly once).  Panics on a
    /// failed request, like [`SolveTicket::wait`].
    pub fn try_take(&self) -> Option<SolveResult> {
        let mut s = self.slot.state.lock().expect("completion poisoned");
        match std::mem::replace(&mut *s, CompletionState::Taken) {
            CompletionState::Done(res) => Some(res),
            CompletionState::Failed(why) => {
                *s = CompletionState::Failed(why);
                panic!("solve request failed: {why}");
            }
            CompletionState::Taken => None,
            CompletionState::Pending => {
                *s = CompletionState::Pending;
                None
            }
        }
    }
}

/// One executed batch, as recorded by the worker that ran it.
#[derive(Debug, Clone)]
pub struct BatchRecord {
    /// The matrix the batch solved against.
    pub matrix: MatrixId,
    /// Vector length of that matrix.
    pub n: usize,
    /// Nonzeros of that matrix.
    pub nnz: usize,
    /// Right-hand-side lanes the batch carried.
    pub lanes: u32,
    /// Tenants the lanes belonged to, in lane order.
    pub tenants: Vec<u32>,
    /// What cut the batch (batch-full, queue-drained, deadline).
    pub reason: FlushReason,
    /// Per-lane logical queue waits, in lane order: same-matrix
    /// submissions accepted between each lane's submit and the
    /// dispatch (the per-matrix clock of the queue-wait histogram).
    pub waits: Vec<u64>,
    /// Slowest lane's iteration count (how long the batch held the
    /// device).
    pub max_iters: u32,
    /// Sum of lane iteration counts (RHS-iterations retired).
    pub rhs_iters: u64,
}

impl BatchRecord {
    /// The time-plane view of this batch, ready for
    /// [`schedule_cycles`].
    pub fn scheduled(&self) -> ScheduledBatch {
        ScheduledBatch { n: self.n, nnz: self.nnz, lanes: self.lanes, trips: self.max_iters as u64 }
    }

    /// Serialize as one JSON object — an entry of the `records` array
    /// in [`ServiceStats::to_json`].
    pub fn to_json(&self) -> String {
        let tenants: Vec<String> = self.tenants.iter().map(u32::to_string).collect();
        let waits: Vec<String> = self.waits.iter().map(u64::to_string).collect();
        let mut w = ObjWriter::new();
        w.field_str("matrix", &self.matrix.to_string());
        w.field_raw("n", &self.n.to_string());
        w.field_raw("nnz", &self.nnz.to_string());
        w.field_raw("lanes", &self.lanes.to_string());
        w.field_raw("tenants", &format!("[{}]", tenants.join(",")));
        w.field_str("reason", self.reason.name());
        w.field_raw("waits", &format!("[{}]", waits.join(",")));
        w.field_raw("max_iters", &self.max_iters.to_string());
        w.field_raw("rhs_iters", &self.rhs_iters.to_string());
        w.finish()
    }
}

/// Shared mutable scheduler state the workers report into.
#[derive(Debug, Default)]
struct StatsInner {
    records: Mutex<Vec<BatchRecord>>,
    /// Batches dispatched but not yet finished.
    active: Mutex<u64>,
    idle: Condvar,
}

impl StatsInner {
    fn batch_started(&self) {
        *self.active.lock().expect("stats poisoned") += 1;
    }

    fn batch_finished(&self, record: Option<BatchRecord>) {
        if let Some(r) = record {
            self.records.lock().expect("stats poisoned").push(r);
        }
        let mut a = self.active.lock().expect("stats poisoned");
        *a -= 1;
        if *a == 0 {
            self.idle.notify_all();
        }
    }

    fn wait_idle(&self) {
        let mut a = self.active.lock().expect("stats poisoned");
        while *a > 0 {
            a = self.idle.wait(a).expect("stats poisoned");
        }
    }
}

/// A snapshot of the service's counters (complete once
/// [`SolverService::drain`] has returned).
#[derive(Debug, Clone)]
pub struct ServiceStats {
    /// Requests submitted so far.
    pub requests: u64,
    /// Submissions rejected by [`SolverService::try_submit`]
    /// (validation, backpressure, quota).
    pub rejected: u64,
    /// Batches executed (== program executions issued by the service).
    pub batches: u64,
    /// RHS-iterations retired across all executed batches.
    pub rhs_iterations: u64,
    /// Program-cache hits across all workers.
    pub cache_hits: u64,
    /// Program-cache misses (fresh compiles).
    pub cache_misses: u64,
    /// Distinct compiled programs held by the cache.
    pub compiled_programs: usize,
    /// The registry's residency bookkeeping (admitted/resident/pinned,
    /// beats used, evictions, readmissions).
    pub registry: RegistryStats,
    /// Every executed batch, in completion order (sort by matrix/lane
    /// content for deterministic comparisons).
    pub records: Vec<BatchRecord>,
}

impl ServiceStats {
    /// Batches executed for one matrix — the acceptance bound is
    /// ⌈requests(matrix) / max_batch⌉.
    pub fn executions_for(&self, id: MatrixId) -> u64 {
        self.records.iter().filter(|r| r.matrix == id).count() as u64
    }

    /// The `q`-quantile (0 < q <= 1) of the per-lane logical queue
    /// waits across every recorded batch — `queue_wait_quantile(0.99)`
    /// is the bounded-p99 figure the replay bench reports.  Returns 0
    /// for an empty record set.
    pub fn queue_wait_quantile(&self, q: f64) -> u64 {
        let mut waits: Vec<u64> =
            self.records.iter().flat_map(|r| r.waits.iter().copied()).collect();
        if waits.is_empty() {
            return 0;
        }
        waits.sort_unstable();
        let rank = ((waits.len() as f64 * q).ceil() as usize).clamp(1, waits.len());
        waits[rank - 1]
    }

    /// Modeled cycles for the recorded trace on the given accelerator
    /// (the time plane pricing the same serving scenario the value
    /// plane just executed).
    pub fn modeled_cycles(&self, cfg: &AccelSimConfig) -> u64 {
        let batches: Vec<ScheduledBatch> =
            self.records.iter().map(BatchRecord::scheduled).collect();
        schedule_cycles(cfg, &batches)
    }

    /// Modeled RHS-iterations/s for the recorded trace: retired
    /// RHS-iterations over the modeled wall time of
    /// [`ServiceStats::modeled_cycles`].
    pub fn modeled_rhs_iterations_per_second(&self, cfg: &AccelSimConfig) -> f64 {
        let cycles = self.modeled_cycles(cfg);
        if cycles == 0 {
            return 0.0;
        }
        self.rhs_iterations as f64 / (cycles as f64 * cfg.hbm.cycle_time())
    }

    /// Serialize the full snapshot — per-batch `records` included, in
    /// their stored order — as one JSON object.  This is the
    /// `serve --stats-json` body and the front door's `/stats` body;
    /// the shape is pinned in `tests/observability.rs`, so extend it
    /// there too.
    pub fn to_json(&self) -> String {
        let records: Vec<String> = self.records.iter().map(BatchRecord::to_json).collect();
        let mut w = ObjWriter::new();
        w.field_raw("requests", &self.requests.to_string());
        w.field_raw("rejected", &self.rejected.to_string());
        w.field_raw("batches", &self.batches.to_string());
        w.field_raw("rhs_iterations", &self.rhs_iterations.to_string());
        w.field_raw("cache_hits", &self.cache_hits.to_string());
        w.field_raw("cache_misses", &self.cache_misses.to_string());
        w.field_raw("compiled_programs", &self.compiled_programs.to_string());
        w.field_raw("resident_matrices", &self.registry.resident.to_string());
        w.field_raw("registry_evictions", &self.registry.evictions.to_string());
        w.field_raw("registry_readmissions", &self.registry.readmissions.to_string());
        w.field_raw("queue_wait_p99", &self.queue_wait_quantile(0.99).to_string());
        w.field_raw("records", &format!("[{}]", records.join(",")));
        w.finish()
    }

    /// Push the snapshot's time-plane figures onto the telemetry plane
    /// ([`crate::sim::export_modeled_gauges`]) so `serve
    /// --metrics-dump` shows modeled cycles and throughput next to the
    /// value-plane counters.
    pub fn export_time_plane_gauges(&self, cfg: &AccelSimConfig) {
        crate::sim::export_modeled_gauges(
            self.modeled_cycles(cfg),
            self.modeled_rhs_iterations_per_second(cfg),
        );
    }
}

/// Service configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Most lanes a coalesced batch carries (the flush threshold).
    pub max_batch: usize,
    /// Worker-pool threads executing batches.
    pub workers: usize,
    /// SpMV thread budget of the registry's derived plans.  Since the
    /// lane-parallel dispatch (PR 5) this only governs the *worker*
    /// fallback path (option sets outside the program family): batches
    /// on the program path always run serial SpMV inside each lane and
    /// spread whole lanes across [`ServiceConfig::lane_workers`]
    /// instead.  Parallelism in a service lives across lanes and
    /// batches first, so the default is 1.
    pub spmv_threads: usize,
    /// Lanes dispatched concurrently *inside* each batch execution (the
    /// lane-parallel value plane; `0` = machine default, see
    /// [`pool::default_lane_workers`](crate::engine::pool::default_lane_workers)).
    /// Per-request results are bitwise unchanged at any setting — only
    /// throughput moves.
    pub lane_workers: usize,
    /// Run batches in block mode: one resident lane-major block per
    /// coalesced batch — a single matrix stream feeds every lane per
    /// iteration and the vector plane never leaves the block between
    /// issue and exit (zero steady-state element moves, PERF §12).
    /// Falls back per the coordinator's degrade ladder (staged, then
    /// per-lane) on backends that cannot batch, and single-lane batches
    /// short-circuit to per-lane dispatch either way, so per-ticket
    /// results stay bitwise unchanged at any setting.
    pub block_spmv: bool,
    /// Latency-bounded flush threshold on the **submission-count
    /// logical clock**: a pending group is cut once its oldest lane has
    /// seen this many subsequent submissions (any matrix) accepted.
    /// `0` disables the deadline.  Because the clock is submissions
    /// rather than wall time, deadline cuts are deterministic and
    /// replay byte-identically (recorded as
    /// [`FlushReason::Deadline`]).
    pub deadline: u64,
    /// Bound on total pending (unflushed) lanes; a submission past it
    /// is rejected with [`SubmitError::QueueFull`] — the backpressure
    /// the HTTP front door maps to 429.  `0` = unbounded.
    pub pending_limit: usize,
    /// Per-tenant bound on pending lanes
    /// ([`SubmitError::TenantQuotaExceeded`] past it).  `0` =
    /// unbounded.
    pub tenant_quota: usize,
    /// Registry capacity budget in HBM beats
    /// ([`MatrixRegistry::with_capacity`]); resident derived state is
    /// LRU-evicted to stay under it.  `0` = unbounded.
    pub capacity_beats: u64,
    /// Solve options every request runs under.  Options outside the
    /// batched-program family (sequential dots, the XcgSolver
    /// accumulator) execute on the worker-per-RHS model path instead —
    /// either way each result is bitwise a lone solve.
    pub opts: SolveOptions,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            spmv_threads: 1,
            lane_workers: 0,
            block_spmv: false,
            deadline: 0,
            pending_limit: 0,
            tenant_quota: 0,
            capacity_beats: 0,
            opts: SolveOptions::callipepla(),
        }
    }
}

/// One pending lane: the right-hand side plus its completion slot.
#[derive(Debug)]
struct Lane {
    b: Vec<f64>,
    tenant: u32,
    slot: Arc<Completion>,
    /// Global submission index (0-based) when the request was accepted
    /// — the clock behind `submit` trace events and the deadline.
    seq: u64,
    /// Per-matrix submission index — the clock behind the queue-wait
    /// histogram (so idle-matrix lanes don't inherit other matrices'
    /// traffic).
    mseq: u64,
}

/// The solver service: registry + program cache + coalescing queue +
/// worker pool.  See the [module docs](self) for the flush policy and
/// the execution path.
///
/// ```
/// use callipepla::service::{ServiceConfig, SolveRequest, SolverService};
/// use callipepla::sparse::synth;
///
/// let mut svc = SolverService::new(ServiceConfig { max_batch: 4, ..Default::default() });
/// let id = svc.register(synth::laplace2d_shifted(100, 0.2));
/// let tickets: Vec<_> = (0..6)
///     .map(|k| svc.submit(SolveRequest::new(id, vec![1.0 + k as f64; 100])))
///     .collect();
/// svc.flush(); // 6 requests, max_batch 4 -> batches of 4 and 2
/// let results: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
/// assert!(results.iter().all(|r| r.converged));
/// assert_eq!(svc.drain().batches, 2);
/// ```
#[derive(Debug)]
pub struct SolverService {
    cfg: ServiceConfig,
    registry: MatrixRegistry,
    cache: Arc<ProgramCache>,
    pool: WorkerPool,
    /// Pending lanes per matrix id (indexed by registry slot).
    pending: Vec<Vec<Lane>>,
    /// Per-matrix submission counts (the queue-wait clock), indexed by
    /// registry slot.
    msubmitted: Vec<u64>,
    /// This service's ids in admission order (slot-indexed — the
    /// deadline sweep and `flush` iterate these without re-deriving
    /// them from the registry).
    matrix_ids: Vec<MatrixId>,
    stats: Arc<StatsInner>,
    submitted: u64,
    rejected: u64,
    /// Total pending (unflushed) lanes across all groups.
    pending_lanes: usize,
    /// Pending lanes per tenant (entries removed at zero).
    pending_per_tenant: HashMap<u32, usize>,
    /// Batches dispatched so far — the flush-sequence logical clock
    /// stamped onto `flush`/`done` trace events.
    flushes: u64,
    /// Installed event sink ([`SolverService::record_events`]).
    events: Option<Arc<EventSink>>,
}

impl SolverService {
    /// Start a service: spawns the worker pool, creates the program
    /// cache and a registry budgeted to
    /// [`ServiceConfig::capacity_beats`], and wires the registry's
    /// eviction hook to drop bucket programs whose last resident
    /// matrix went with the eviction.
    pub fn new(cfg: ServiceConfig) -> Self {
        assert!(cfg.max_batch >= 1, "a batch needs at least one lane");
        let cache = Arc::new(ProgramCache::new());
        let mut registry = MatrixRegistry::with_capacity(cfg.capacity_beats);
        let hook_cache = Arc::clone(&cache);
        registry.set_evict_hook(Box::new(move |notice| {
            if !notice.bucket_still_resident {
                hook_cache.evict_bucket(notice.bucket);
            }
        }));
        Self {
            cfg,
            registry,
            cache,
            pool: WorkerPool::new(cfg.workers),
            pending: Vec::new(),
            msubmitted: Vec::new(),
            matrix_ids: Vec::new(),
            stats: Arc::new(StatsInner::default()),
            submitted: 0,
            rejected: 0,
            pending_lanes: 0,
            pending_per_tenant: HashMap::new(),
            flushes: 0,
            events: None,
        }
    }

    /// Install (or return the already-installed) deterministic event
    /// sink.  From here on the scheduler logs `submit` and `flush`
    /// events from the caller thread and `done` events from the batch
    /// workers, all stamped with logical clocks — render the sink after
    /// [`SolverService::drain`] for a byte-stable transcript of the
    /// schedule (see `docs/OBSERVABILITY.md`).
    pub fn record_events(&mut self) -> Arc<EventSink> {
        Arc::clone(self.events.get_or_insert_with(|| Arc::new(EventSink::default())))
    }

    /// Admit a matrix (derives its solve state once — see
    /// [`MatrixRegistry`]).  Panics if the capacity budget cannot hold
    /// it even after evicting everything evictable.
    pub fn register(&mut self, a: CsrMatrix) -> MatrixId {
        let id = self.registry.admit(a, self.cfg.spmv_threads);
        self.pending.push(Vec::new());
        self.msubmitted.push(0);
        self.matrix_ids.push(id);
        id
    }

    /// The matrix registry.
    pub fn registry(&self) -> &MatrixRegistry {
        &self.registry
    }

    /// Pin a matrix resident (exempt from eviction) until
    /// [`SolverService::unpin`].
    pub fn pin(&self, id: MatrixId) -> Result<(), RegistryError> {
        self.registry.pin(id)
    }

    /// Return a pinned matrix to the LRU pool.
    pub fn unpin(&self, id: MatrixId) -> Result<(), RegistryError> {
        self.registry.unpin(id)
    }

    /// The shared bucketed program cache.
    pub fn cache(&self) -> &Arc<ProgramCache> {
        &self.cache
    }

    /// The service configuration.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// This service's matrix ids in admission order (what the HTTP
    /// front door indexes client-supplied matrix numbers into).
    pub fn matrix_ids(&self) -> &[MatrixId] {
        &self.matrix_ids
    }

    /// Requests accepted so far (the global submission clock).
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Lanes currently pending (unflushed).
    pub fn pending_lanes(&self) -> usize {
        self.pending_lanes
    }

    /// Queue one solve.  The request joins its matrix's pending group;
    /// a full group (`max_batch` lanes) flushes immediately, and — with
    /// a deadline configured — groups whose oldest lane aged past the
    /// threshold flush right after.  The returned ticket resolves once
    /// the batch has executed.
    ///
    /// Rejections (validation, backpressure, quota) come back as typed
    /// [`SubmitError`]s; [`SolverService::submit`] is the panicking
    /// wrapper.
    pub fn try_submit(&mut self, req: SolveRequest) -> Result<SolveTicket, SubmitError> {
        // Load shedding first — it must not depend on (or pay for)
        // registry residency work.
        if self.cfg.pending_limit > 0 && self.pending_lanes >= self.cfg.pending_limit {
            self.reject();
            return Err(SubmitError::QueueFull {
                pending: self.pending_lanes,
                limit: self.cfg.pending_limit,
            });
        }
        if self.cfg.tenant_quota > 0 {
            let held = self.pending_per_tenant.get(&req.tenant).copied().unwrap_or(0);
            if held >= self.cfg.tenant_quota {
                self.reject();
                return Err(SubmitError::TenantQuotaExceeded {
                    tenant: req.tenant,
                    pending: held,
                    quota: self.cfg.tenant_quota,
                });
            }
        }
        // Validation: resolve the id (readmitting an evicted entry on
        // demand) and check the RHS length against it.
        let entry = match self.registry.try_entry(req.matrix) {
            Ok(e) => e,
            Err(e) => {
                self.reject();
                return Err(SubmitError::Registry(e));
            }
        };
        let n = entry.n();
        if req.b.len() != n {
            self.reject();
            return Err(SubmitError::WrongRhsLength {
                matrix: req.matrix,
                expected: n,
                got: req.b.len(),
            });
        }
        drop(entry);
        let seq = self.submitted;
        self.submitted += 1;
        obs::SERVICE_REQUESTS.inc();
        if let Some(sink) = &self.events {
            sink.push(Event {
                seq,
                lane: 0,
                kind: EventKind::Submit { matrix: req.matrix.index(), tenant: req.tenant },
            });
        }
        let mseq = self.msubmitted[req.matrix.index()];
        self.msubmitted[req.matrix.index()] += 1;
        self.pending_lanes += 1;
        *self.pending_per_tenant.entry(req.tenant).or_insert(0) += 1;
        let slot = Completion::new();
        let ticket = SolveTicket { slot: Arc::clone(&slot) };
        self.pending[req.matrix.index()].push(Lane {
            b: req.b,
            tenant: req.tenant,
            slot,
            seq,
            mseq,
        });
        if self.pending[req.matrix.index()].len() >= self.cfg.max_batch {
            self.dispatch(req.matrix, FlushReason::BatchFull);
        }
        self.flush_deadlines();
        Ok(ticket)
    }

    /// Queue one solve, panicking on rejection (the in-process API;
    /// see [`SolverService::try_submit`] for the typed form the HTTP
    /// front door uses).
    pub fn submit(&mut self, req: SolveRequest) -> SolveTicket {
        self.try_submit(req).unwrap_or_else(|e| panic!("solve submission rejected: {e}"))
    }

    fn reject(&mut self) {
        self.rejected += 1;
        obs::SERVICE_SUBMIT_REJECTED.inc();
    }

    /// Cut every group whose oldest lane has aged past the deadline
    /// threshold, in matrix-admission order (deterministic — the sweep
    /// runs on the caller thread right after each accepted submission).
    fn flush_deadlines(&mut self) {
        let d = self.cfg.deadline;
        if d == 0 {
            return;
        }
        for ix in 0..self.matrix_ids.len() {
            let id = self.matrix_ids[ix];
            while self.pending[ix].first().is_some_and(|l| self.submitted - 1 - l.seq >= d) {
                self.dispatch(id, FlushReason::Deadline);
            }
        }
    }

    /// Queue-drained flush: dispatch every pending partial batch, in
    /// matrix-admission order (deterministic).
    pub fn flush(&mut self) {
        for ix in 0..self.matrix_ids.len() {
            let id = self.matrix_ids[ix];
            while !self.pending[ix].is_empty() {
                self.dispatch(id, FlushReason::QueueDrained);
            }
        }
    }

    /// Flush one matrix's pending group (all of it, in `max_batch`
    /// cuts) without touching other groups — what the front door's
    /// synchronous `/solve` path uses so one caller's flush doesn't
    /// disturb other matrices' coalescing windows.
    pub fn flush_matrix(&mut self, id: MatrixId) {
        assert!(
            self.matrix_ids.get(id.index()) == Some(&id),
            "matrix id {id} was not registered on this service"
        );
        while !self.pending[id.index()].is_empty() {
            self.dispatch(id, FlushReason::QueueDrained);
        }
    }

    /// Flush, then block until every in-flight batch has finished, and
    /// return the (now complete) statistics snapshot.
    pub fn drain(&mut self) -> ServiceStats {
        self.flush();
        self.stats.wait_idle();
        self.stats_snapshot()
    }

    /// The current statistics snapshot (complete only after
    /// [`SolverService::drain`]).
    pub fn stats(&self) -> ServiceStats {
        self.stats_snapshot()
    }

    fn stats_snapshot(&self) -> ServiceStats {
        let records = self.stats.records.lock().expect("stats poisoned").clone();
        ServiceStats {
            requests: self.submitted,
            rejected: self.rejected,
            batches: records.len() as u64,
            rhs_iterations: records.iter().map(|r| r.rhs_iters).sum(),
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            compiled_programs: self.cache.len(),
            registry: self.registry.stats(),
            records,
        }
    }

    /// Cut one batch (up to `max_batch` oldest lanes) off a matrix's
    /// pending group and hand it to the pool.  Runs on the caller
    /// thread, so the flush sequence it stamps is a deterministic
    /// function of the request sequence.
    fn dispatch(&mut self, id: MatrixId, reason: FlushReason) {
        let group = &mut self.pending[id.index()];
        if group.is_empty() {
            return;
        }
        let take = group.len().min(self.cfg.max_batch);
        let lanes: Vec<Lane> = group.drain(..take).collect();
        self.pending_lanes -= lanes.len();
        for lane in &lanes {
            if let Some(held) = self.pending_per_tenant.get_mut(&lane.tenant) {
                *held -= 1;
                if *held == 0 {
                    self.pending_per_tenant.remove(&lane.tenant);
                }
            }
        }
        let flush_seq = self.flushes;
        self.flushes += 1;
        obs::SERVICE_BATCHES.inc();
        match reason {
            FlushReason::BatchFull => obs::SERVICE_FLUSH_BATCH_FULL.inc(),
            FlushReason::QueueDrained => obs::SERVICE_FLUSH_DRAINED.inc(),
            FlushReason::Deadline => obs::SERVICE_FLUSH_DEADLINE.inc(),
        }
        obs::SERVICE_COALESCE_WIDTH.observe(lanes.len() as u64);
        // Logical queue wait on the *per-matrix* clock: submissions to
        // this matrix accepted after each lane joined its group.  A
        // lane on an idle matrix therefore waits 0, no matter how much
        // traffic other matrices saw in between.
        let now_m = self.msubmitted[id.index()];
        let waits: Vec<u64> = lanes.iter().map(|l| now_m - 1 - l.mseq).collect();
        for w in &waits {
            obs::SERVICE_QUEUE_WAIT.observe(*w);
        }
        if let Some(sink) = &self.events {
            sink.push(Event {
                seq: flush_seq,
                lane: 0,
                kind: EventKind::Flush { matrix: id.index(), lanes: lanes.len() as u32, reason },
            });
        }
        let job = BatchJob {
            id,
            entry: self.registry.entry(id),
            cache: Arc::clone(&self.cache),
            stats: Arc::clone(&self.stats),
            opts: self.cfg.opts,
            lanes,
            lane_workers: self.cfg.lane_workers,
            block: self.cfg.block_spmv,
            flush_seq,
            reason,
            waits,
            events: self.events.clone(),
        };
        job.stats.batch_started();
        self.pool.spawn(move || job.run());
    }
}

impl Drop for SolverService {
    fn drop(&mut self) {
        // Jobs already dispatched drain inside the pool's Drop; lanes
        // never flushed can no longer run — fail their tickets so
        // waiters get a diagnostic instead of a deadlock.
        for group in &self.pending {
            for lane in group {
                lane.slot.fail("service dropped before the request's batch was flushed");
            }
        }
    }
}

/// One dispatched batch, self-contained for the pool: plan view →
/// cached bucket program → lane-parallel dispatch → per-lane results →
/// tickets.  The job owns its `Arc<MatrixEntry>`, so a registry
/// eviction while it runs changes nothing; the lane fan-out rides the
/// process-wide [`pool::global`](crate::engine::pool::global) pool
/// (this worker participates and drains its own queue, so a fully busy
/// service cannot wedge on it); results are bitwise those of the
/// sequential dispatch the pre-lane-parallel service used.  With
/// [`ServiceConfig::block_spmv`] the lanes instead run as one resident
/// block (same bitwise results, one matrix stream per iteration).
#[derive(Debug)]
struct BatchJob {
    id: MatrixId,
    entry: Arc<MatrixEntry>,
    cache: Arc<ProgramCache>,
    stats: Arc<StatsInner>,
    opts: SolveOptions,
    lanes: Vec<Lane>,
    lane_workers: usize,
    block: bool,
    flush_seq: u64,
    reason: FlushReason,
    waits: Vec<u64>,
    events: Option<Arc<EventSink>>,
}

impl BatchJob {
    fn run(self) {
        let BatchJob {
            id,
            entry,
            cache,
            stats,
            opts,
            lanes,
            lane_workers,
            block,
            flush_seq,
            reason,
            waits,
            events,
        } = self;
        let mut bs = Vec::with_capacity(lanes.len());
        let mut tenants = Vec::with_capacity(lanes.len());
        let mut slots = Vec::with_capacity(lanes.len());
        for lane in lanes {
            bs.push(lane.b);
            tenants.push(lane.tenant);
            slots.push(lane.slot);
        }
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            let plan = entry.plan();
            if block {
                plan.solve_batch_block_parallel(&bs, &opts, Some(&cache), lane_workers)
            } else {
                plan.solve_batch_parallel(&bs, &opts, Some(&cache), lane_workers)
            }
        }));
        match outcome {
            Ok(results) => {
                debug_assert_eq!(results.len(), slots.len());
                let record = BatchRecord {
                    matrix: id,
                    n: entry.n(),
                    nnz: entry.nnz(),
                    lanes: slots.len() as u32,
                    tenants,
                    reason,
                    waits,
                    max_iters: results.iter().map(|r| r.iters).max().unwrap_or(0),
                    rhs_iters: results.iter().map(|r| r.iters as u64).sum(),
                };
                if let Some(sink) = &events {
                    // Stamped with the dispatch's flush sequence:
                    // workers finish in nondeterministic order, but the
                    // rendered log sorts on this clock, so the
                    // transcript does not depend on completion timing.
                    sink.push(Event {
                        seq: flush_seq,
                        lane: 0,
                        kind: EventKind::BatchDone {
                            matrix: id.index(),
                            lanes: record.lanes,
                            rhs_iters: record.rhs_iters,
                        },
                    });
                }
                for (slot, res) in slots.iter().zip(results) {
                    slot.fulfill(res);
                }
                stats.batch_finished(Some(record));
            }
            Err(_) => {
                obs::SERVICE_BATCH_PANICS.inc();
                for slot in &slots {
                    slot.fail("the batch job executing this request panicked");
                }
                stats.batch_finished(None);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::jpcg_solve;
    use crate::sparse::synth;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn tiny_result() -> SolveResult {
        jpcg_solve(
            &synth::laplace2d_shifted(16, 0.5),
            None,
            None,
            &SolveOptions { max_iters: 3, ..SolveOptions::callipepla() },
        )
    }

    #[test]
    fn fail_then_fulfill_keeps_the_failure_sticky() {
        // The race this pins: the service drops (failing queued slots)
        // while a worker is about to deliver — whichever terminal state
        // lands first must win in *both* orders.
        let slot = Completion::new();
        slot.fail("service dropped before the request's batch was flushed");
        slot.fulfill(tiny_result());
        let ticket = SolveTicket { slot };
        let panic = catch_unwind(AssertUnwindSafe(|| ticket.try_take()))
            .expect_err("a failed slot must stay failed after a late fulfill");
        let msg = panic.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("service dropped"), "original diagnostic survives: {msg}");
    }

    #[test]
    fn fulfill_then_fail_keeps_the_result() {
        let slot = Completion::new();
        slot.fulfill(tiny_result());
        slot.fail("late failure must not clobber a delivered result");
        let ticket = SolveTicket { slot };
        let res = ticket.try_take().expect("result survives the late fail");
        let expect: Vec<u64> = tiny_result().x.iter().map(|v| v.to_bits()).collect();
        let got: Vec<u64> = res.x.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, expect, "the delivered result is the solver's, bit for bit");
    }

    #[test]
    fn double_fulfill_keeps_the_first_result() {
        let slot = Completion::new();
        let first = tiny_result();
        let first_bits: Vec<u64> = first.x.iter().map(|v| v.to_bits()).collect();
        slot.fulfill(first);
        let mut second = tiny_result();
        second.x.iter_mut().for_each(|v| *v = 0.0);
        slot.fulfill(second);
        let ticket = SolveTicket { slot };
        let res = ticket.try_take().expect("first result delivered");
        let bits: Vec<u64> = res.x.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, first_bits);
    }
}
