//! The coalescing batch scheduler: submission queue, deterministic
//! flush policy, persistent-pool execution, per-request completion
//! handles.
//!
//! **Coalescing rule.**  Pending requests are grouped *per matrix* in
//! per-matrix submission order and cut into batches of at most
//! `max_batch` lanes.  A group flushes when it reaches `max_batch`
//! (batch-full) or when the caller drains the queue
//! ([`SolverService::flush`] / [`SolverService::drain`]) — there is no
//! timer, so batch composition is a pure function of the per-matrix
//! request sequence: the same request set produces the same batches
//! (and, since every lane is bitwise a lone
//! [`jpcg_solve`](crate::solver::jpcg_solve), bitwise the same results)
//! no matter how arrivals from different tenants interleave.
//!
//! **Execution.**  A flushed batch becomes one fire-and-forget job on
//! the service's [`WorkerPool`]: build a zero-copy plan view from the
//! registry entry, fetch the bucket program from the shared
//! [`ProgramCache`], run
//! [`PreparedMatrix::solve_batch_parallel`](crate::engine::PreparedMatrix::solve_batch_parallel)
//! (the batch's lanes fan out across
//! [`ServiceConfig::lane_workers`] — bitwise the sequential dispatch,
//! PERF §9), fulfill each lane's [`SolveTicket`].  One job per batch
//! means at most ⌈requests / max_batch⌉ program executions per matrix
//! — the serving-layer amortization the ROADMAP asked for.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

use crate::engine::WorkerPool;
use crate::obs::catalog as obs;
use crate::obs::{Event, EventKind, EventSink, FlushReason};
use crate::program::ProgramCache;
use crate::sim::{schedule_cycles, AccelSimConfig, ScheduledBatch};
use crate::solver::{SolveOptions, SolveResult};
use crate::sparse::CsrMatrix;
use crate::util::json::ObjWriter;

use super::registry::{MatrixEntry, MatrixId, MatrixRegistry};

/// One queued solve: a right-hand side against an admitted matrix.
/// (`x0` is always zero in the serving path, the paper's setup.)
#[derive(Debug, Clone)]
pub struct SolveRequest {
    /// The admitted matrix to solve against.
    pub matrix: MatrixId,
    /// The right-hand side (length must match the matrix).
    pub b: Vec<f64>,
    /// Submitting tenant — a label carried into the batch records so
    /// traces and fairness studies can attribute lanes; never affects
    /// scheduling or results.
    pub tenant: u32,
}

impl SolveRequest {
    /// A request from the anonymous tenant 0.
    pub fn new(matrix: MatrixId, b: Vec<f64>) -> Self {
        Self { matrix, b, tenant: 0 }
    }
}

/// How one request ended.  `Failed` and `Taken` are terminal; `Done`
/// transitions to `Taken` exactly once, when the result is handed out.
#[derive(Debug)]
enum CompletionState {
    Pending,
    Done(SolveResult),
    /// The result was already handed out through
    /// [`SolveTicket::try_take`].
    Taken,
    /// The batch job panicked or the service was dropped before flush.
    Failed(&'static str),
}

#[derive(Debug)]
struct Completion {
    state: Mutex<CompletionState>,
    cv: Condvar,
}

impl Completion {
    fn new() -> Arc<Self> {
        Arc::new(Self { state: Mutex::new(CompletionState::Pending), cv: Condvar::new() })
    }

    fn fulfill(&self, res: SolveResult) {
        *self.state.lock().expect("completion poisoned") = CompletionState::Done(res);
        self.cv.notify_all();
    }

    fn fail(&self, why: &'static str) {
        let mut s = self.state.lock().expect("completion poisoned");
        if matches!(*s, CompletionState::Pending) {
            *s = CompletionState::Failed(why);
            self.cv.notify_all();
        }
    }
}

/// Completion handle for one submitted request.
#[derive(Debug)]
pub struct SolveTicket {
    slot: Arc<Completion>,
}

impl SolveTicket {
    /// Block until the request's batch has executed and take the
    /// result (bitwise the result of a lone
    /// [`jpcg_solve`](crate::solver::jpcg_solve) on the same system).
    /// A ticket only resolves after its batch is flushed — call
    /// [`SolverService::flush`] (or `drain`) before waiting on
    /// requests that haven't filled a batch.
    ///
    /// Panics if the executing batch job panicked, the service was
    /// dropped with the request still queued, or the result was
    /// already taken through [`SolveTicket::try_take`].
    pub fn wait(self) -> SolveResult {
        let mut s = self.slot.state.lock().expect("completion poisoned");
        loop {
            match std::mem::replace(&mut *s, CompletionState::Taken) {
                CompletionState::Done(res) => return res,
                CompletionState::Failed(why) => {
                    // Failure is terminal: keep it visible to any other
                    // observer of this slot.
                    *s = CompletionState::Failed(why);
                    panic!("solve request failed: {why}");
                }
                CompletionState::Taken => panic!("solve result was already taken"),
                CompletionState::Pending => {
                    *s = CompletionState::Pending;
                    s = self.slot.cv.wait(s).expect("completion poisoned");
                }
            }
        }
    }

    /// Non-blocking take: the result if the batch already executed
    /// (`None` while pending, and `None` again after a successful
    /// take — the result is handed out exactly once).  Panics on a
    /// failed request, like [`SolveTicket::wait`].
    pub fn try_take(&self) -> Option<SolveResult> {
        let mut s = self.slot.state.lock().expect("completion poisoned");
        match std::mem::replace(&mut *s, CompletionState::Taken) {
            CompletionState::Done(res) => Some(res),
            CompletionState::Failed(why) => {
                *s = CompletionState::Failed(why);
                panic!("solve request failed: {why}");
            }
            CompletionState::Taken => None,
            CompletionState::Pending => {
                *s = CompletionState::Pending;
                None
            }
        }
    }
}

/// One executed batch, as recorded by the worker that ran it.
#[derive(Debug, Clone)]
pub struct BatchRecord {
    /// The matrix the batch solved against.
    pub matrix: MatrixId,
    /// Vector length of that matrix.
    pub n: usize,
    /// Nonzeros of that matrix.
    pub nnz: usize,
    /// Right-hand-side lanes the batch carried.
    pub lanes: u32,
    /// Tenants the lanes belonged to, in lane order.
    pub tenants: Vec<u32>,
    /// Slowest lane's iteration count (how long the batch held the
    /// device).
    pub max_iters: u32,
    /// Sum of lane iteration counts (RHS-iterations retired).
    pub rhs_iters: u64,
}

impl BatchRecord {
    /// The time-plane view of this batch, ready for
    /// [`schedule_cycles`].
    pub fn scheduled(&self) -> ScheduledBatch {
        ScheduledBatch { n: self.n, nnz: self.nnz, lanes: self.lanes, trips: self.max_iters as u64 }
    }

    /// Serialize as one JSON object — an entry of the `records` array
    /// in [`ServiceStats::to_json`].
    pub fn to_json(&self) -> String {
        let tenants: Vec<String> = self.tenants.iter().map(u32::to_string).collect();
        let mut w = ObjWriter::new();
        w.field_str("matrix", &self.matrix.to_string());
        w.field_raw("n", &self.n.to_string());
        w.field_raw("nnz", &self.nnz.to_string());
        w.field_raw("lanes", &self.lanes.to_string());
        w.field_raw("tenants", &format!("[{}]", tenants.join(",")));
        w.field_raw("max_iters", &self.max_iters.to_string());
        w.field_raw("rhs_iters", &self.rhs_iters.to_string());
        w.finish()
    }
}

/// Shared mutable scheduler state the workers report into.
#[derive(Debug, Default)]
struct StatsInner {
    records: Mutex<Vec<BatchRecord>>,
    /// Batches dispatched but not yet finished.
    active: Mutex<u64>,
    idle: Condvar,
}

impl StatsInner {
    fn batch_started(&self) {
        *self.active.lock().expect("stats poisoned") += 1;
    }

    fn batch_finished(&self, record: Option<BatchRecord>) {
        if let Some(r) = record {
            self.records.lock().expect("stats poisoned").push(r);
        }
        let mut a = self.active.lock().expect("stats poisoned");
        *a -= 1;
        if *a == 0 {
            self.idle.notify_all();
        }
    }

    fn wait_idle(&self) {
        let mut a = self.active.lock().expect("stats poisoned");
        while *a > 0 {
            a = self.idle.wait(a).expect("stats poisoned");
        }
    }
}

/// A snapshot of the service's counters (complete once
/// [`SolverService::drain`] has returned).
#[derive(Debug, Clone)]
pub struct ServiceStats {
    /// Requests submitted so far.
    pub requests: u64,
    /// Batches executed (== program executions issued by the service).
    pub batches: u64,
    /// RHS-iterations retired across all executed batches.
    pub rhs_iterations: u64,
    /// Program-cache hits across all workers.
    pub cache_hits: u64,
    /// Program-cache misses (fresh compiles).
    pub cache_misses: u64,
    /// Distinct compiled programs held by the cache.
    pub compiled_programs: usize,
    /// Every executed batch, in completion order (sort by matrix/lane
    /// content for deterministic comparisons).
    pub records: Vec<BatchRecord>,
}

impl ServiceStats {
    /// Batches executed for one matrix — the acceptance bound is
    /// ⌈requests(matrix) / max_batch⌉.
    pub fn executions_for(&self, id: MatrixId) -> u64 {
        self.records.iter().filter(|r| r.matrix == id).count() as u64
    }

    /// Modeled cycles for the recorded trace on the given accelerator
    /// (the time plane pricing the same serving scenario the value
    /// plane just executed).
    pub fn modeled_cycles(&self, cfg: &AccelSimConfig) -> u64 {
        let batches: Vec<ScheduledBatch> =
            self.records.iter().map(BatchRecord::scheduled).collect();
        schedule_cycles(cfg, &batches)
    }

    /// Modeled RHS-iterations/s for the recorded trace: retired
    /// RHS-iterations over the modeled wall time of
    /// [`ServiceStats::modeled_cycles`].
    pub fn modeled_rhs_iterations_per_second(&self, cfg: &AccelSimConfig) -> f64 {
        let cycles = self.modeled_cycles(cfg);
        if cycles == 0 {
            return 0.0;
        }
        self.rhs_iterations as f64 / (cycles as f64 * cfg.hbm.cycle_time())
    }

    /// Serialize the full snapshot — per-batch `records` included, in
    /// their stored order — as one JSON object.  This is the
    /// `serve --stats-json` body; the shape is pinned in
    /// `tests/observability.rs`, so extend it there too.
    pub fn to_json(&self) -> String {
        let records: Vec<String> = self.records.iter().map(BatchRecord::to_json).collect();
        let mut w = ObjWriter::new();
        w.field_raw("requests", &self.requests.to_string());
        w.field_raw("batches", &self.batches.to_string());
        w.field_raw("rhs_iterations", &self.rhs_iterations.to_string());
        w.field_raw("cache_hits", &self.cache_hits.to_string());
        w.field_raw("cache_misses", &self.cache_misses.to_string());
        w.field_raw("compiled_programs", &self.compiled_programs.to_string());
        w.field_raw("records", &format!("[{}]", records.join(",")));
        w.finish()
    }

    /// Push the snapshot's time-plane figures onto the telemetry plane
    /// ([`crate::sim::export_modeled_gauges`]) so `serve
    /// --metrics-dump` shows modeled cycles and throughput next to the
    /// value-plane counters.
    pub fn export_time_plane_gauges(&self, cfg: &AccelSimConfig) {
        crate::sim::export_modeled_gauges(
            self.modeled_cycles(cfg),
            self.modeled_rhs_iterations_per_second(cfg),
        );
    }
}

/// Service configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Most lanes a coalesced batch carries (the flush threshold).
    pub max_batch: usize,
    /// Worker-pool threads executing batches.
    pub workers: usize,
    /// SpMV thread budget of the registry's derived plans.  Since the
    /// lane-parallel dispatch (PR 5) this only governs the *worker*
    /// fallback path (option sets outside the program family): batches
    /// on the program path always run serial SpMV inside each lane and
    /// spread whole lanes across [`ServiceConfig::lane_workers`]
    /// instead.  Parallelism in a service lives across lanes and
    /// batches first, so the default is 1.
    pub spmv_threads: usize,
    /// Lanes dispatched concurrently *inside* each batch execution (the
    /// lane-parallel value plane; `0` = machine default, see
    /// [`pool::default_lane_workers`](crate::engine::pool::default_lane_workers)).
    /// Per-request results are bitwise unchanged at any setting — only
    /// throughput moves.
    pub lane_workers: usize,
    /// Run batches in block mode: one resident lane-major block per
    /// coalesced batch — a single matrix stream feeds every lane per
    /// iteration and the vector plane never leaves the block between
    /// issue and exit (zero steady-state element moves, PERF §12).
    /// Falls back per the coordinator's degrade ladder (staged, then
    /// per-lane) on backends that cannot batch, and single-lane batches
    /// short-circuit to per-lane dispatch either way, so per-ticket
    /// results stay bitwise unchanged at any setting.
    pub block_spmv: bool,
    /// Solve options every request runs under.  Options outside the
    /// batched-program family (sequential dots, the XcgSolver
    /// accumulator) execute on the worker-per-RHS model path instead —
    /// either way each result is bitwise a lone solve.
    pub opts: SolveOptions,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            spmv_threads: 1,
            lane_workers: 0,
            block_spmv: false,
            opts: SolveOptions::callipepla(),
        }
    }
}

/// One pending lane: the right-hand side plus its completion slot.
#[derive(Debug)]
struct Lane {
    b: Vec<f64>,
    tenant: u32,
    slot: Arc<Completion>,
    /// Submission index (0-based) when the request was accepted — the
    /// logical clock behind the queue-wait histogram and the `submit`
    /// trace events.
    seq: u64,
}

/// The solver service: registry + program cache + coalescing queue +
/// worker pool.  See the [module docs](self) for the flush policy and
/// the execution path.
///
/// ```
/// use callipepla::service::{ServiceConfig, SolveRequest, SolverService};
/// use callipepla::sparse::synth;
///
/// let mut svc = SolverService::new(ServiceConfig { max_batch: 4, ..Default::default() });
/// let id = svc.register(synth::laplace2d_shifted(100, 0.2));
/// let tickets: Vec<_> = (0..6)
///     .map(|k| svc.submit(SolveRequest::new(id, vec![1.0 + k as f64; 100])))
///     .collect();
/// svc.flush(); // 6 requests, max_batch 4 -> batches of 4 and 2
/// let results: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
/// assert!(results.iter().all(|r| r.converged));
/// assert_eq!(svc.drain().batches, 2);
/// ```
#[derive(Debug)]
pub struct SolverService {
    cfg: ServiceConfig,
    registry: MatrixRegistry,
    cache: Arc<ProgramCache>,
    pool: WorkerPool,
    /// Pending lanes per matrix id (indexed by registry slot).
    pending: Vec<Vec<Lane>>,
    stats: Arc<StatsInner>,
    submitted: u64,
    /// Batches dispatched so far — the flush-sequence logical clock
    /// stamped onto `flush`/`done` trace events.
    flushes: u64,
    /// Installed event sink ([`SolverService::record_events`]).
    events: Option<Arc<EventSink>>,
}

impl SolverService {
    /// Start a service: spawns the worker pool, creates an empty
    /// registry and program cache.
    pub fn new(cfg: ServiceConfig) -> Self {
        assert!(cfg.max_batch >= 1, "a batch needs at least one lane");
        Self {
            cfg,
            registry: MatrixRegistry::new(),
            cache: Arc::new(ProgramCache::new()),
            pool: WorkerPool::new(cfg.workers),
            pending: Vec::new(),
            stats: Arc::new(StatsInner::default()),
            submitted: 0,
            flushes: 0,
            events: None,
        }
    }

    /// Install (or return the already-installed) deterministic event
    /// sink.  From here on the scheduler logs `submit` and `flush`
    /// events from the caller thread and `done` events from the batch
    /// workers, all stamped with logical clocks — render the sink after
    /// [`SolverService::drain`] for a byte-stable transcript of the
    /// schedule (see `docs/OBSERVABILITY.md`).
    pub fn record_events(&mut self) -> Arc<EventSink> {
        Arc::clone(self.events.get_or_insert_with(|| Arc::new(EventSink::default())))
    }

    /// Admit a matrix (derives its solve state once — see
    /// [`MatrixRegistry`]).
    pub fn register(&mut self, a: CsrMatrix) -> MatrixId {
        let id = self.registry.admit(a, self.cfg.spmv_threads);
        self.pending.push(Vec::new());
        id
    }

    /// The matrix registry.
    pub fn registry(&self) -> &MatrixRegistry {
        &self.registry
    }

    /// The shared bucketed program cache.
    pub fn cache(&self) -> &Arc<ProgramCache> {
        &self.cache
    }

    /// The service configuration.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Queue one solve.  The request joins its matrix's pending group;
    /// a full group (`max_batch` lanes) flushes immediately.  The
    /// returned ticket resolves once the batch has executed.
    pub fn submit(&mut self, req: SolveRequest) -> SolveTicket {
        let n = self.registry.entry(req.matrix).n();
        assert_eq!(
            req.b.len(),
            n,
            "right-hand side length must match matrix {} (n = {n})",
            req.matrix
        );
        let seq = self.submitted;
        self.submitted += 1;
        obs::SERVICE_REQUESTS.inc();
        if let Some(sink) = &self.events {
            sink.push(Event {
                seq,
                lane: 0,
                kind: EventKind::Submit { matrix: req.matrix.index(), tenant: req.tenant },
            });
        }
        let slot = Completion::new();
        let ticket = SolveTicket { slot: Arc::clone(&slot) };
        self.pending[req.matrix.index()].push(Lane { b: req.b, tenant: req.tenant, slot, seq });
        if self.pending[req.matrix.index()].len() >= self.cfg.max_batch {
            self.dispatch(req.matrix, FlushReason::BatchFull);
        }
        ticket
    }

    /// Queue-drained flush: dispatch every pending partial batch, in
    /// matrix-admission order (deterministic).
    pub fn flush(&mut self) {
        for id in self.registry.ids().collect::<Vec<_>>() {
            while !self.pending[id.index()].is_empty() {
                self.dispatch(id, FlushReason::QueueDrained);
            }
        }
    }

    /// Flush, then block until every in-flight batch has finished, and
    /// return the (now complete) statistics snapshot.
    pub fn drain(&mut self) -> ServiceStats {
        self.flush();
        self.stats.wait_idle();
        self.stats_snapshot()
    }

    /// The current statistics snapshot (complete only after
    /// [`SolverService::drain`]).
    pub fn stats(&self) -> ServiceStats {
        self.stats_snapshot()
    }

    fn stats_snapshot(&self) -> ServiceStats {
        let records = self.stats.records.lock().expect("stats poisoned").clone();
        ServiceStats {
            requests: self.submitted,
            batches: records.len() as u64,
            rhs_iterations: records.iter().map(|r| r.rhs_iters).sum(),
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            compiled_programs: self.cache.len(),
            records,
        }
    }

    /// Cut one batch (up to `max_batch` oldest lanes) off a matrix's
    /// pending group and hand it to the pool.  Runs on the caller
    /// thread, so the flush sequence it stamps is a deterministic
    /// function of the request sequence.
    fn dispatch(&mut self, id: MatrixId, reason: FlushReason) {
        let group = &mut self.pending[id.index()];
        if group.is_empty() {
            return;
        }
        let take = group.len().min(self.cfg.max_batch);
        let lanes: Vec<Lane> = group.drain(..take).collect();
        let flush_seq = self.flushes;
        self.flushes += 1;
        obs::SERVICE_BATCHES.inc();
        match reason {
            FlushReason::BatchFull => obs::SERVICE_FLUSH_BATCH_FULL.inc(),
            FlushReason::QueueDrained => obs::SERVICE_FLUSH_DRAINED.inc(),
        }
        obs::SERVICE_COALESCE_WIDTH.observe(lanes.len() as u64);
        for lane in &lanes {
            // Logical queue wait: submissions accepted after this lane
            // joined its group (never wall time).
            obs::SERVICE_QUEUE_WAIT.observe(self.submitted - 1 - lane.seq);
        }
        if let Some(sink) = &self.events {
            sink.push(Event {
                seq: flush_seq,
                lane: 0,
                kind: EventKind::Flush { matrix: id.index(), lanes: lanes.len() as u32, reason },
            });
        }
        let entry = Arc::clone(self.registry.entry(id));
        let cache = Arc::clone(&self.cache);
        let stats = Arc::clone(&self.stats);
        let opts = self.cfg.opts;
        let lane_workers = self.cfg.lane_workers;
        let block = self.cfg.block_spmv;
        let events = self.events.clone();
        stats.batch_started();
        self.pool.spawn(move || {
            run_batch(id, entry, cache, stats, opts, lanes, lane_workers, block, flush_seq, events)
        });
    }
}

impl Drop for SolverService {
    fn drop(&mut self) {
        // Jobs already dispatched drain inside the pool's Drop; lanes
        // never flushed can no longer run — fail their tickets so
        // waiters get a diagnostic instead of a deadlock.
        for group in &self.pending {
            for lane in group {
                lane.slot.fail("service dropped before the request's batch was flushed");
            }
        }
    }
}

/// Execute one coalesced batch on a pool worker: plan view → cached
/// bucket program → lane-parallel dispatch → per-lane results →
/// tickets.  The lane fan-out rides the process-wide
/// [`pool::global`](crate::engine::pool::global) pool (this worker
/// participates and drains its own queue, so a fully busy service
/// cannot wedge on it); results are bitwise those of the sequential
/// dispatch the pre-lane-parallel service used.  With
/// [`ServiceConfig::block_spmv`] the lanes instead run as one resident
/// block (same bitwise results, one matrix stream per iteration).
#[allow(clippy::too_many_arguments)]
fn run_batch(
    id: MatrixId,
    entry: Arc<MatrixEntry>,
    cache: Arc<ProgramCache>,
    stats: Arc<StatsInner>,
    opts: SolveOptions,
    lanes: Vec<Lane>,
    lane_workers: usize,
    block: bool,
    flush_seq: u64,
    events: Option<Arc<EventSink>>,
) {
    let mut bs = Vec::with_capacity(lanes.len());
    let mut tenants = Vec::with_capacity(lanes.len());
    let mut slots = Vec::with_capacity(lanes.len());
    for lane in lanes {
        bs.push(lane.b);
        tenants.push(lane.tenant);
        slots.push(lane.slot);
    }
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        let plan = entry.plan();
        if block {
            plan.solve_batch_block_parallel(&bs, &opts, Some(&cache), lane_workers)
        } else {
            plan.solve_batch_parallel(&bs, &opts, Some(&cache), lane_workers)
        }
    }));
    match outcome {
        Ok(results) => {
            debug_assert_eq!(results.len(), slots.len());
            let record = BatchRecord {
                matrix: id,
                n: entry.n(),
                nnz: entry.nnz(),
                lanes: slots.len() as u32,
                tenants,
                max_iters: results.iter().map(|r| r.iters).max().unwrap_or(0),
                rhs_iters: results.iter().map(|r| r.iters as u64).sum(),
            };
            if let Some(sink) = &events {
                // Stamped with the dispatch's flush sequence: workers
                // finish in nondeterministic order, but the rendered
                // log sorts on this clock, so the transcript does not
                // depend on completion timing.
                sink.push(Event {
                    seq: flush_seq,
                    lane: 0,
                    kind: EventKind::BatchDone {
                        matrix: id.index(),
                        lanes: record.lanes,
                        rhs_iters: record.rhs_iters,
                    },
                });
            }
            for (slot, res) in slots.iter().zip(results) {
                slot.fulfill(res);
            }
            stats.batch_finished(Some(record));
        }
        Err(_) => {
            obs::SERVICE_BATCH_PANICS.inc();
            for slot in &slots {
                slot.fail("the batch job executing this request panicked");
            }
            stats.batch_finished(None);
        }
    }
}
