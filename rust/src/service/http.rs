//! The HTTP front door: a dependency-free ingress over
//! [`SolverService`] built on `std::net::TcpListener` — no async
//! runtime, no HTTP crate, one connection at a time.
//!
//! The split matters more than the sockets: [`handle_request`] is the
//! whole route table as a pure(-ish) function from `(method, path,
//! body)` to an [`HttpResponse`], so every route — including the 400 /
//! 404 / 429 edges — is unit-testable without binding a port
//! (`tests/front_door.rs`), and [`serve_http`] is only the socket
//! plumbing around it.  A sequential accept loop is the right shape
//! here for the same reason the scheduler runs its flush sweep on the
//! caller thread: admissions stay a deterministic function of arrival
//! order, which keeps the replay guarantees of `docs/SERVICE.md`
//! intact even when requests arrive over the wire.
//!
//! Routes:
//!
//! | method & path    | behavior |
//! |------------------|----------|
//! | `GET /healthz`   | liveness: `ok` |
//! | `GET /metrics`   | Prometheus text exposition of the global registry |
//! | `GET /stats`     | [`ServiceStats::to_json`] snapshot |
//! | `POST /solve`    | submit + flush that matrix + wait: the solution vector, bitwise a lone [`jpcg_solve`](crate::solver::jpcg_solve) |
//! | `POST /submit`   | submit only (`202`): joins the coalescing window, result discarded |
//! | `POST /flush`    | queue-drained flush of every pending group |
//! | `POST /shutdown` | stop the accept loop after this response |
//!
//! Solve/submit bodies are JSON: `{"matrix": <index>, "b": [..],
//! "tenant": <id>}` — `matrix` indexes this service's admission order
//! ([`SolverService::matrix_ids`]), `b` defaults to all-ones, `tenant`
//! to 0.  Typed rejections map onto status codes: validation errors
//! ([`SubmitError::Registry`], [`SubmitError::WrongRhsLength`], parse
//! failures) are 400s; load shedding ([`SubmitError::QueueFull`],
//! [`SubmitError::TenantQuotaExceeded`]) is a 429 the client should
//! back off and retry — the backpressure contract the bounded queue
//! ([`ServiceConfig::pending_limit`](super::ServiceConfig::pending_limit))
//! exists to enforce.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};

use crate::obs::catalog as obs;
use crate::obs::{prometheus_dump, PROMETHEUS_CONTENT_TYPE};
use crate::util::json::{Json, ObjWriter};

use super::scheduler::{SolveRequest, SolverService, SubmitError};

/// Largest request body the parser will read (16 MiB — a dense f64 RHS
/// for n = 10^6 serialized as text fits; anything bigger is a client
/// bug and the connection is dropped instead of allocated for).
pub const MAX_BODY_BYTES: usize = 16 << 20;

const JSON: &str = "application/json";
const TEXT: &str = "text/plain; charset=utf-8";

/// One rendered response: status, content type, body, and whether the
/// accept loop should stop after sending it.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpResponse {
    /// HTTP status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Response body.
    pub body: String,
    /// Set by `POST /shutdown`: send this response, then return from
    /// [`serve_http`].
    pub shutdown: bool,
}

impl HttpResponse {
    fn new(status: u16, content_type: &'static str, body: String) -> Self {
        Self { status, content_type, body, shutdown: false }
    }

    fn error(status: u16, msg: &str) -> Self {
        let mut w = ObjWriter::new();
        w.field_str("error", msg);
        Self::new(status, JSON, w.finish())
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            413 => "Payload Too Large",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            _ => "Unknown",
        }
    }

    /// Serialize as an HTTP/1.1 response (always `Connection: close`;
    /// one request per connection keeps the loop stateless).
    pub fn render(&self) -> String {
        format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len(),
            self.body
        )
    }
}

/// A parsed solve/submit body.
struct SolveBody {
    matrix_index: usize,
    b: Option<Vec<f64>>,
    tenant: u32,
}

fn parse_solve_body(body: &str) -> Result<SolveBody, String> {
    let doc = if body.trim().is_empty() {
        return Err("a JSON body with a \"matrix\" field is required".into());
    } else {
        Json::parse(body).map_err(|e| format!("body is not valid JSON: {e}"))?
    };
    let matrix_index = doc
        .get("matrix")
        .and_then(Json::as_f64)
        .ok_or_else(|| "\"matrix\" must be a number (admission index)".to_string())?;
    if matrix_index < 0.0 || matrix_index.fract() != 0.0 {
        return Err("\"matrix\" must be a non-negative integer".into());
    }
    let b = match doc.get("b") {
        None | Some(Json::Null) => None,
        Some(Json::Arr(vals)) => {
            let mut out = Vec::with_capacity(vals.len());
            for v in vals {
                out.push(v.as_f64().ok_or_else(|| "\"b\" must contain only numbers".to_string())?);
            }
            Some(out)
        }
        Some(_) => return Err("\"b\" must be an array of numbers".into()),
    };
    let tenant = match doc.get("tenant") {
        None | Some(Json::Null) => 0,
        Some(v) => {
            let t = v.as_f64().ok_or_else(|| "\"tenant\" must be a number".to_string())?;
            if t < 0.0 || t.fract() != 0.0 {
                return Err("\"tenant\" must be a non-negative integer".into());
            }
            t as u32
        }
    };
    Ok(SolveBody { matrix_index: matrix_index as usize, b, tenant })
}

fn submit_status(e: &SubmitError) -> u16 {
    match e {
        // Load shedding: the request was well-formed, the service is
        // full — retryable, so 429.
        SubmitError::QueueFull { .. } | SubmitError::TenantQuotaExceeded { .. } => 429,
        // Validation: resubmitting the same request cannot succeed.
        SubmitError::Registry(_) | SubmitError::WrongRhsLength { .. } => 400,
    }
}

/// Build the request, run the shared submit path, and hand back either
/// the accepted ticket-and-request or the mapped error response.
fn try_submit_body(
    svc: &mut SolverService,
    body: &str,
) -> Result<(super::scheduler::SolveTicket, super::MatrixId), HttpResponse> {
    let parsed = parse_solve_body(body).map_err(|msg| HttpResponse::error(400, &msg))?;
    let id = match svc.matrix_ids().get(parsed.matrix_index) {
        Some(id) => *id,
        None => {
            return Err(HttpResponse::error(
                400,
                &format!(
                    "matrix index {} is out of range ({} matrices admitted)",
                    parsed.matrix_index,
                    svc.matrix_ids().len()
                ),
            ));
        }
    };
    let b = match parsed.b {
        Some(b) => b,
        None => {
            let n = match svc.registry().try_entry(id) {
                Ok(e) => e.n(),
                Err(e) => return Err(HttpResponse::error(400, &e.to_string())),
            };
            vec![1.0; n]
        }
    };
    let req = SolveRequest { matrix: id, b, tenant: parsed.tenant };
    match svc.try_submit(req) {
        Ok(ticket) => Ok((ticket, id)),
        Err(e) => Err(HttpResponse::error(submit_status(&e), &e.to_string())),
    }
}

fn solve_response(svc: &mut SolverService, body: &str) -> HttpResponse {
    let (ticket, id) = match try_submit_body(svc, body) {
        Ok(pair) => pair,
        Err(resp) => return resp,
    };
    // Synchronous path: cut only this matrix's group so one caller's
    // wait does not disturb other matrices' coalescing windows, then
    // block on the ticket.
    svc.flush_matrix(id);
    let res = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| ticket.wait())) {
        Ok(res) => res,
        Err(_) => return HttpResponse::error(500, "the batch executing this request failed"),
    };
    let x: Vec<String> = res.x.iter().map(|v| v.to_string()).collect();
    let mut w = ObjWriter::new();
    w.field_str("matrix", &id.to_string());
    w.field_raw("converged", if res.converged { "true" } else { "false" });
    w.field_raw("iters", &res.iters.to_string());
    w.field_num("final_rr", res.final_rr);
    w.field_raw("x", &format!("[{}]", x.join(",")));
    HttpResponse::new(200, JSON, w.finish())
}

fn submit_response(svc: &mut SolverService, body: &str) -> HttpResponse {
    match try_submit_body(svc, body) {
        // Fire-and-forget: the ticket drops here; the lane still rides
        // its coalescing window and fulfills into the dropped slot.
        Ok((_ticket, id)) => {
            let mut w = ObjWriter::new();
            w.field_raw("accepted", "true");
            w.field_str("matrix", &id.to_string());
            w.field_raw("pending", &svc.pending_lanes().to_string());
            HttpResponse { status: 202, content_type: JSON, body: w.finish(), shutdown: false }
        }
        Err(resp) => resp,
    }
}

/// The route table: map one parsed request onto the service.  Pure of
/// sockets — `tests/front_door.rs` drives every route (including the
/// error edges) through this directly.
pub fn handle_request(
    svc: &mut SolverService,
    method: &str,
    path: &str,
    body: &str,
) -> HttpResponse {
    obs::SERVICE_HTTP_REQUESTS.inc();
    // Route target only — ignore any query string.
    let route = path.split('?').next().unwrap_or(path);
    match (method, route) {
        ("GET", "/healthz") => HttpResponse::new(200, TEXT, "ok\n".into()),
        ("GET", "/metrics") => {
            HttpResponse::new(200, PROMETHEUS_CONTENT_TYPE, prometheus_dump())
        }
        ("GET", "/stats") => HttpResponse::new(200, JSON, svc.stats().to_json()),
        ("POST", "/solve") => solve_response(svc, body),
        ("POST", "/submit") => submit_response(svc, body),
        ("POST", "/flush") => {
            svc.flush();
            let mut w = ObjWriter::new();
            w.field_raw("flushed", "true");
            w.field_raw("pending", &svc.pending_lanes().to_string());
            HttpResponse::new(200, JSON, w.finish())
        }
        ("POST", "/shutdown") => {
            let mut w = ObjWriter::new();
            w.field_raw("shutting_down", "true");
            HttpResponse { status: 200, content_type: JSON, body: w.finish(), shutdown: true }
        }
        ("GET" | "POST", _) => HttpResponse::error(404, &format!("no route for {route}")),
        _ => HttpResponse::error(405, &format!("method {method} is not supported")),
    }
}

/// Read one HTTP/1.1 request off a connection: request line, headers
/// (only `Content-Length` matters), body.  Returns `None` on a
/// malformed request (the connection is just dropped — a front door,
/// not a proxy).
fn read_request(stream: &mut TcpStream) -> Option<(String, String, String)> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).ok()?;
    let mut parts = line.split_whitespace();
    let method = parts.next()?.to_string();
    let path = parts.next()?.to_string();
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).ok()?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().ok()?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return None;
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).ok()?;
    Some((method, path, String::from_utf8(body).ok()?))
}

/// Serve the front door on an already-bound listener: accept one
/// connection at a time, answer one request per connection, stop on
/// `POST /shutdown` or after `max_requests` requests (`0` =
/// unlimited).  Returns the number of requests answered.
///
/// Sequential on purpose: every admission decision (backpressure,
/// quota, deadline sweep) happens in arrival order on this thread, so
/// the schedule an HTTP trace produces is as deterministic as one
/// produced by in-process submission.  Solve execution still fans out
/// on the service's worker pool underneath.
pub fn serve_http(
    svc: &mut SolverService,
    listener: &TcpListener,
    max_requests: u64,
) -> std::io::Result<u64> {
    let mut served = 0u64;
    for stream in listener.incoming() {
        let mut stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let Some((method, path, body)) = read_request(&mut stream) else {
            continue;
        };
        let resp = handle_request(svc, &method, &path, &body);
        let _ = stream.write_all(resp.render().as_bytes());
        let _ = stream.flush();
        served += 1;
        if resp.shutdown || (max_requests > 0 && served >= max_requests) {
            break;
        }
    }
    Ok(served)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn responses_render_with_status_line_and_length() {
        let r = HttpResponse::new(200, TEXT, "ok\n".into());
        let text = r.render();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 3\r\n"));
        assert!(text.ends_with("\r\n\r\nok\n"));
        assert!(HttpResponse::error(429, "full").render().starts_with("HTTP/1.1 429 Too Many"));
    }

    #[test]
    fn solve_bodies_parse_with_defaults_and_reject_garbage() {
        let ok = parse_solve_body(r#"{"matrix": 2, "b": [1.0, 2.5], "tenant": 7}"#).unwrap();
        assert_eq!(ok.matrix_index, 2);
        assert_eq!(ok.b.as_deref(), Some(&[1.0, 2.5][..]));
        assert_eq!(ok.tenant, 7);
        let defaults = parse_solve_body(r#"{"matrix": 0}"#).unwrap();
        assert!(defaults.b.is_none());
        assert_eq!(defaults.tenant, 0);
        assert!(parse_solve_body("").is_err());
        assert!(parse_solve_body("not json").is_err());
        assert!(parse_solve_body(r#"{"b": [1.0]}"#).is_err(), "matrix is required");
        assert!(parse_solve_body(r#"{"matrix": -1}"#).is_err());
        assert!(parse_solve_body(r#"{"matrix": 0, "b": ["x"]}"#).is_err());
    }
}
