//! The matrix registry: admit a matrix once, keep its derived solve
//! state **resident** while it earns its memory windows, evict it when
//! it does not — without ever changing a result bit.
//!
//! A serving deployment sees many solves against few matrices (the
//! reservoir-simulation and lattice-QCD deployments of arXiv:2101.01745
//! and arXiv:2001.05218 are exactly this shape), so everything a solve
//! needs besides the right-hand side — the Jacobi diagonal, the
//! nnz-balanced row partition, the lazy f32 value view — is derived at
//! admission and shared from then on.  Entries are `Arc`-held so worker
//! threads keep a matrix alive for as long as its batches run, even
//! across an eviction of the registry's own reference.
//!
//! **The registry is a managed resource** (ROADMAP item 4a).  The HBM
//! memory map gives every resident matrix a concrete footprint in
//! 64-byte beats ([`footprint_beats`]); [`MatrixRegistry::with_capacity`]
//! bounds the sum.  Admission and [`MatrixRegistry::try_entry`] evict
//! the least-recently-used unpinned resident entries to make room, and
//! an evicted matrix is *readmitted on demand*: the host-side
//! [`CsrMatrix`] is always retained, and [`MatrixEntry::new`] is a pure
//! function of it, so the rederived diagonal, partition, and f32 view
//! are bit-for-bit the originals — eviction and readmission are
//! invisible to results (pinned in the tests below and in
//! `tests/front_door.rs`).  [`MatrixRegistry::pin`] exempts an entry
//! from eviction (and [`MatrixRegistry::unpin`] re-admits it to the LRU
//! pool); a capacity that cannot be met even after evicting everything
//! evictable is a typed [`RegistryError::CapacityExhausted`].
//!
//! **Ids are stamped.**  A [`MatrixId`] carries a per-registry tag, so
//! an id minted by one registry can never silently resolve to another
//! registry's matrix that happens to share the slot index — resolution
//! through a foreign id is a typed [`RegistryError::ForeignId`]
//! (or a clear panic through the [`MatrixRegistry::entry`] wrapper).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::engine::{PreparedMatrix, RowPartition};
use crate::obs::catalog as obs;
use crate::program::cache::bucket_ceiling;
use crate::sparse::CsrMatrix;

/// Source of per-registry id tags: every registry in the process gets a
/// distinct one, so foreign-id detection works across services too.
static NEXT_REGISTRY_TAG: AtomicU32 = AtomicU32::new(1);

/// Handle to an admitted matrix: a slot index (stable for the
/// registry's lifetime, eviction included) stamped with the minting
/// registry's tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct MatrixId {
    pub(crate) tag: u32,
    pub(crate) slot: u32,
}

impl MatrixId {
    /// The registry slot this id names.
    pub fn index(self) -> usize {
        self.slot as usize
    }
}

impl std::fmt::Display for MatrixId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "A{}", self.slot)
    }
}

/// The modeled HBM footprint of one resident matrix, in 64-byte beats:
/// six vector windows (x, r, p, ap, z, and the Jacobi diagonal — eight
/// f64 per beat) plus the fp64 nonzero value stream and the lazy fp32
/// view (sixteen f32 per beat).  This is the unit
/// [`MatrixRegistry::with_capacity`] budgets in — the same beat
/// currency the memory map and the time plane already price.
pub fn footprint_beats(n: usize, nnz: usize) -> u64 {
    let vec_beats = (n as u64).div_ceil(8);
    6 * vec_beats + (nnz as u64).div_ceil(8) + (nnz as u64).div_ceil(16)
}

/// Why an id failed to resolve (or a matrix failed to become resident).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegistryError {
    /// The id was minted by a *different* registry: slot indices are
    /// meaningless across registries, so resolution refuses instead of
    /// silently returning whatever matrix shares the index.
    ForeignId {
        /// The offending id.
        id: MatrixId,
        /// Tag of the registry asked to resolve it.
        registry_tag: u32,
    },
    /// The tag matches but the slot was never admitted here.
    UnknownId {
        /// The offending id.
        id: MatrixId,
        /// Matrices admitted so far.
        admitted: usize,
    },
    /// The capacity budget cannot hold this matrix even after evicting
    /// every unpinned resident entry.
    CapacityExhausted {
        /// The matrix that needed room.
        id: MatrixId,
        /// Beats it needs.
        needed: u64,
        /// Beats currently free (after evicting everything evictable).
        free: u64,
        /// The configured capacity.
        capacity: u64,
    },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::ForeignId { id, registry_tag } => write!(
                f,
                "matrix id {id} was minted by registry #{} and cannot resolve on registry \
                 #{registry_tag} — ids are only valid on the registry (service) that admitted \
                 the matrix",
                id.tag
            ),
            RegistryError::UnknownId { id, admitted } => write!(
                f,
                "matrix id {id} names slot {} but only {admitted} matrices are admitted",
                id.slot
            ),
            RegistryError::CapacityExhausted { id, needed, free, capacity } => write!(
                f,
                "matrix {id} needs {needed} beats but only {free} of {capacity} are \
                 reclaimable (pinned entries hold the rest)"
            ),
        }
    }
}

impl std::error::Error for RegistryError {}

/// What the registry tells its eviction hook (the service wires this to
/// [`ProgramCache::evict_bucket`](crate::program::ProgramCache::evict_bucket)
/// so bucket programs with no remaining resident tenant are dropped
/// with the matrix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvictionNotice {
    /// The evicted matrix.
    pub id: MatrixId,
    /// Its vector length.
    pub n: usize,
    /// Its program-cache bucket ceiling.
    pub bucket: u32,
    /// Whether another *resident* matrix still shares that bucket (if
    /// so, the bucket's compiled programs are still earning their keep).
    pub bucket_still_resident: bool,
}

/// Callback invoked (on the evicting caller's thread, registry lock
/// held) for every eviction.
pub type EvictHook = Box<dyn Fn(&EvictionNotice) + Send + Sync>;

/// One admitted matrix plus its derived solve state.  [`MatrixEntry::plan`]
/// hands out borrowing [`PreparedMatrix`] views whose caches are the
/// entry's own `Arc`s — building a view is O(1) and the lazy f32 view,
/// once derived by any worker, is filled for all.
#[derive(Debug)]
pub struct MatrixEntry {
    a: Arc<CsrMatrix>,
    diag: Arc<Vec<f64>>,
    vals32: Arc<OnceLock<Vec<f32>>>,
    partition: Arc<RowPartition>,
    threads: usize,
}

impl MatrixEntry {
    /// Derive the solve state for `a` with an SpMV thread budget of
    /// `threads` (>= 1) per plan view.  This is a *pure* function of
    /// `(a, threads)` — the property that makes registry eviction and
    /// readmission bitwise-invisible to results.
    pub fn new(a: Arc<CsrMatrix>, threads: usize) -> Self {
        let threads = threads.max(1);
        let diag = Arc::new(a.jacobi_diag());
        let partition = Arc::new(RowPartition::nnz_balanced(&a, threads));
        Self { a, diag, vals32: Arc::new(OnceLock::new()), partition, threads }
    }

    /// The admitted matrix.
    pub fn matrix(&self) -> &CsrMatrix {
        &self.a
    }

    /// Vector length.
    pub fn n(&self) -> usize {
        self.a.n
    }

    /// Nonzero count.
    pub fn nnz(&self) -> usize {
        self.a.nnz()
    }

    /// The modeled HBM beats this entry occupies while resident.
    pub fn footprint_beats(&self) -> u64 {
        footprint_beats(self.n(), self.nnz())
    }

    /// A [`PreparedMatrix`] view over this entry's shared caches —
    /// nothing is re-derived or copied.
    pub fn plan(&self) -> PreparedMatrix<'_> {
        PreparedMatrix::from_shared(
            &self.a,
            Arc::clone(&self.diag),
            Arc::clone(&self.vals32),
            Arc::clone(&self.partition),
            self.threads,
        )
    }
}

/// One registry slot: the always-retained host matrix plus the
/// (evictable) resident derived state.
#[derive(Debug)]
struct Slot {
    a: Arc<CsrMatrix>,
    threads: usize,
    /// The derived state while resident; `None` after eviction.
    resident: Option<Arc<MatrixEntry>>,
    pinned: bool,
    /// LRU clock value of the last touch (admission or resolution).
    last_touch: u64,
    /// Cached [`footprint_beats`] of this matrix.
    footprint: u64,
}

#[derive(Debug, Default)]
struct Inner {
    slots: Vec<Slot>,
    /// Monotone touch clock driving LRU order (caller-thread only, so
    /// eviction order is a deterministic function of the call sequence).
    clock: u64,
    used_beats: u64,
    evictions: u64,
    readmissions: u64,
}

/// A point-in-time view of the registry's residency bookkeeping.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RegistryStats {
    /// Matrices admitted (slots, resident or not).
    pub admitted: usize,
    /// Slots currently resident.
    pub resident: usize,
    /// Slots currently pinned.
    pub pinned: usize,
    /// Beats held by resident entries.
    pub used_beats: u64,
    /// The configured budget (0 = unbounded).
    pub capacity_beats: u64,
    /// Evictions performed so far.
    pub evictions: u64,
    /// On-demand readmissions performed so far.
    pub readmissions: u64,
}

/// Registry of admitted matrices with LRU residency management.
///
/// Slots are append-only (ids stay stable forever) but the *derived
/// state* behind a slot comes and goes under the capacity budget; see
/// the [module docs](self) for the eviction/readmission contract.
///
/// ```
/// use callipepla::service::MatrixRegistry;
/// use callipepla::sparse::synth;
///
/// let mut reg = MatrixRegistry::new(); // unbounded capacity
/// let id = reg.admit(synth::laplace2d_shifted(100, 0.2), 1);
/// assert_eq!(reg.entry(id).n(), reg.entry(id).matrix().n);
/// assert_eq!(reg.len(), 1);
/// assert!(reg.is_resident(id));
/// ```
pub struct MatrixRegistry {
    tag: u32,
    capacity_beats: u64,
    inner: Mutex<Inner>,
    evict_hook: Option<EvictHook>,
}

impl std::fmt::Debug for MatrixRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let stats = self.stats();
        f.debug_struct("MatrixRegistry")
            .field("tag", &self.tag)
            .field("stats", &stats)
            .field("evict_hook", &self.evict_hook.is_some())
            .finish()
    }
}

impl Default for MatrixRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MatrixRegistry {
    /// An empty registry with an unbounded capacity budget.
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// An empty registry budgeting resident entries to `capacity_beats`
    /// HBM beats (`0` = unbounded).  Admission and resolution evict
    /// least-recently-used unpinned entries to stay under budget.
    pub fn with_capacity(capacity_beats: u64) -> Self {
        Self {
            tag: NEXT_REGISTRY_TAG.fetch_add(1, Ordering::Relaxed),
            capacity_beats,
            inner: Mutex::new(Inner::default()),
            evict_hook: None,
        }
    }

    /// Install the eviction callback (the service points this at the
    /// program cache).  At most one hook; installing replaces.
    pub fn set_evict_hook(&mut self, hook: EvictHook) {
        self.evict_hook = Some(hook);
    }

    /// The configured capacity budget in beats (0 = unbounded).
    pub fn capacity_beats(&self) -> u64 {
        self.capacity_beats
    }

    /// Admit a matrix: derive its solve state, get a stable id.  A
    /// budget that cannot hold it even after evicting everything
    /// evictable is a typed error (the slot is still *admitted* — the
    /// host matrix is retained and a later `try_entry` retries once
    /// room frees up).
    pub fn try_admit(
        &mut self,
        a: CsrMatrix,
        threads: usize,
    ) -> Result<MatrixId, RegistryError> {
        let a = Arc::new(a);
        let footprint = footprint_beats(a.n, a.nnz());
        let mut inner = self.inner.lock().expect("registry poisoned");
        let slot_ix = inner.slots.len();
        let id = MatrixId {
            tag: self.tag,
            slot: u32::try_from(slot_ix).expect("registry ids fit u32"),
        };
        inner.slots.push(Slot {
            a,
            threads: threads.max(1),
            resident: None,
            pinned: false,
            last_touch: 0,
            footprint,
        });
        self.make_resident(&mut inner, slot_ix, false)?;
        Ok(id)
    }

    /// Admit a matrix, panicking if the capacity budget cannot hold it
    /// (the pre-eviction API; use [`MatrixRegistry::try_admit`] to get
    /// the typed error instead).
    pub fn admit(&mut self, a: CsrMatrix, threads: usize) -> MatrixId {
        self.try_admit(a, threads)
            .unwrap_or_else(|e| panic!("matrix admission failed: {e}"))
    }

    /// Resolve an id to its (resident) entry, readmitting the derived
    /// state on demand if it was evicted — bitwise-invisible, see the
    /// [module docs](self).  The returned `Arc` keeps the entry alive
    /// for the caller even if the registry evicts it again.
    pub fn try_entry(&self, id: MatrixId) -> Result<Arc<MatrixEntry>, RegistryError> {
        let mut inner = self.inner.lock().expect("registry poisoned");
        let slot = self.check(id, inner.slots.len())?;
        self.make_resident(&mut inner, slot, true)
    }

    /// Resolve an id to its entry, panicking with a clear diagnostic on
    /// a foreign or unknown id (the typed form is
    /// [`MatrixRegistry::try_entry`]).
    pub fn entry(&self, id: MatrixId) -> Arc<MatrixEntry> {
        self.try_entry(id)
            .unwrap_or_else(|e| panic!("matrix id resolution failed: {e}"))
    }

    /// Pin an entry: make it resident (readmitting if needed) and
    /// exempt it from eviction until [`MatrixRegistry::unpin`].
    pub fn pin(&self, id: MatrixId) -> Result<(), RegistryError> {
        let mut inner = self.inner.lock().expect("registry poisoned");
        let slot = self.check(id, inner.slots.len())?;
        self.make_resident(&mut inner, slot, true)?;
        inner.slots[slot].pinned = true;
        Ok(())
    }

    /// Return a pinned entry to the LRU pool (no-op if not pinned).
    pub fn unpin(&self, id: MatrixId) -> Result<(), RegistryError> {
        let mut inner = self.inner.lock().expect("registry poisoned");
        let slot = self.check(id, inner.slots.len())?;
        inner.slots[slot].pinned = false;
        Ok(())
    }

    /// Whether an id's derived state is currently resident.
    pub fn is_resident(&self, id: MatrixId) -> bool {
        let inner = self.inner.lock().expect("registry poisoned");
        self.check(id, inner.slots.len())
            .map(|slot| inner.slots[slot].resident.is_some())
            .unwrap_or(false)
    }

    /// Ids in admission order.
    pub fn ids(&self) -> impl Iterator<Item = MatrixId> + '_ {
        let len = self.inner.lock().expect("registry poisoned").slots.len() as u32;
        let tag = self.tag;
        (0..len).map(move |slot| MatrixId { tag, slot })
    }

    /// Number of admitted matrices (resident or not).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("registry poisoned").slots.len()
    }

    /// Whether nothing has been admitted.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The current residency bookkeeping.
    pub fn stats(&self) -> RegistryStats {
        let inner = self.inner.lock().expect("registry poisoned");
        RegistryStats {
            admitted: inner.slots.len(),
            resident: inner.slots.iter().filter(|s| s.resident.is_some()).count(),
            pinned: inner.slots.iter().filter(|s| s.pinned).count(),
            used_beats: inner.used_beats,
            capacity_beats: self.capacity_beats,
            evictions: inner.evictions,
            readmissions: inner.readmissions,
        }
    }

    /// Validate an id against this registry.
    fn check(&self, id: MatrixId, admitted: usize) -> Result<usize, RegistryError> {
        if id.tag != self.tag {
            return Err(RegistryError::ForeignId { id, registry_tag: self.tag });
        }
        if id.index() >= admitted {
            return Err(RegistryError::UnknownId { id, admitted });
        }
        Ok(id.index())
    }

    /// Make a slot resident (touching its LRU stamp), evicting to make
    /// room under the budget.  `readmit` marks on-demand rederivations
    /// (everything but first admission) for the stats.
    fn make_resident(
        &self,
        inner: &mut Inner,
        slot: usize,
        readmit: bool,
    ) -> Result<Arc<MatrixEntry>, RegistryError> {
        inner.clock += 1;
        let now = inner.clock;
        inner.slots[slot].last_touch = now;
        if let Some(entry) = &inner.slots[slot].resident {
            return Ok(Arc::clone(entry));
        }
        let need = inner.slots[slot].footprint;
        self.ensure_room(inner, need, slot)?;
        let entry = Arc::new(MatrixEntry::new(
            Arc::clone(&inner.slots[slot].a),
            inner.slots[slot].threads,
        ));
        inner.slots[slot].resident = Some(Arc::clone(&entry));
        inner.used_beats += need;
        if readmit {
            inner.readmissions += 1;
            obs::SERVICE_REGISTRY_READMISSIONS.inc();
        }
        Ok(entry)
    }

    /// Evict LRU unpinned entries (never `exempt`) until `need` beats
    /// fit under the budget.
    fn ensure_room(
        &self,
        inner: &mut Inner,
        need: u64,
        exempt: usize,
    ) -> Result<(), RegistryError> {
        if self.capacity_beats == 0 {
            return Ok(());
        }
        while inner.used_beats + need > self.capacity_beats {
            let victim = inner
                .slots
                .iter()
                .enumerate()
                .filter(|(i, s)| *i != exempt && s.resident.is_some() && !s.pinned)
                .min_by_key(|(i, s)| (s.last_touch, *i))
                .map(|(i, _)| i);
            match victim {
                Some(v) => self.evict(inner, v),
                None => {
                    return Err(RegistryError::CapacityExhausted {
                        id: MatrixId { tag: self.tag, slot: exempt as u32 },
                        needed: need,
                        free: self.capacity_beats.saturating_sub(inner.used_beats),
                        capacity: self.capacity_beats,
                    })
                }
            }
        }
        Ok(())
    }

    /// Drop one slot's resident state (in-flight batches keep their
    /// `Arc`s; only the registry's reference goes) and notify the hook.
    fn evict(&self, inner: &mut Inner, v: usize) {
        inner.slots[v].resident = None;
        inner.used_beats -= inner.slots[v].footprint;
        inner.evictions += 1;
        obs::SERVICE_REGISTRY_EVICTIONS.inc();
        if let Some(hook) = &self.evict_hook {
            let n = inner.slots[v].a.n;
            let bucket = bucket_ceiling(n as u32);
            let bucket_still_resident = inner.slots.iter().enumerate().any(|(i, s)| {
                i != v && s.resident.is_some() && bucket_ceiling(s.a.n as u32) == bucket
            });
            hook(&EvictionNotice {
                id: MatrixId { tag: self.tag, slot: v as u32 },
                n,
                bucket,
                bucket_still_resident,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{jpcg_solve, SolveOptions};
    use crate::sparse::synth;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn entry_plans_share_caches_and_solve_bitwise() {
        let a = synth::laplace2d_shifted(400, 0.1);
        let reference = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        let entry = MatrixEntry::new(Arc::new(a), 2);
        // Two views, one shared lazy f32 cache: deriving through the
        // first fills it for the second.
        let p1 = entry.plan();
        let p2 = entry.plan();
        let v1 = p1.vals32().as_ptr();
        let v2 = p2.vals32().as_ptr();
        assert_eq!(v1, v2, "views share one f32 cache");
        let res = p2.solve(None, None, &SolveOptions::callipepla());
        assert_eq!(res.iters, reference.iters);
        assert!(res.x.iter().zip(&reference.x).all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn registry_ids_are_stable_and_ordered() {
        let mut reg = MatrixRegistry::new();
        let a = reg.admit(synth::laplace2d_shifted(100, 0.2), 1);
        let b = reg.admit(synth::laplace2d_shifted(150, 0.2), 1);
        assert_ne!(a, b);
        assert_eq!(reg.ids().collect::<Vec<_>>(), vec![a, b]);
        assert_eq!(reg.entry(b).n(), reg.entry(b).matrix().n);
    }

    #[test]
    fn foreign_ids_are_rejected_not_misresolved() {
        let mut reg1 = MatrixRegistry::new();
        let mut reg2 = MatrixRegistry::new();
        let id1 = reg1.admit(synth::laplace2d_shifted(100, 0.2), 1);
        let _id2 = reg2.admit(synth::laplace2d_shifted(150, 0.2), 1);
        // Slot 0 is in range on reg2 — the pre-fix code would silently
        // hand back reg2's 150-element matrix here.
        match reg2.try_entry(id1) {
            Err(RegistryError::ForeignId { id, .. }) => assert_eq!(id, id1),
            other => panic!("expected ForeignId, got {other:?}"),
        }
        let panic = catch_unwind(AssertUnwindSafe(|| reg2.entry(id1)))
            .expect_err("entry() must panic on a foreign id");
        let msg = panic
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()).unwrap());
        assert!(msg.contains("minted by registry"), "diagnostic names the cause: {msg}");
    }

    #[test]
    fn unknown_slots_are_a_typed_error() {
        let mut reg = MatrixRegistry::new();
        let id = reg.admit(synth::laplace2d_shifted(100, 0.2), 1);
        let bogus = MatrixId { tag: id.tag, slot: 7 };
        assert_eq!(
            reg.try_entry(bogus),
            Err(RegistryError::UnknownId { id: bogus, admitted: 1 })
        );
    }

    #[test]
    fn lru_eviction_and_readmission_are_bitwise_invisible() {
        let a = synth::laplace2d_shifted(100, 0.2);
        let b = synth::laplace2d_shifted(150, 0.2);
        let fp = footprint_beats(a.n, a.nnz()).max(footprint_beats(b.n, b.nnz()));
        // Budget for one matrix at a time: every switch evicts.
        let mut reg = MatrixRegistry::with_capacity(fp);
        let opts = SolveOptions::callipepla();
        let ra = jpcg_solve(&a, None, None, &opts);
        let id_a = reg.admit(a, 1);
        let id_b = reg.admit(b, 1); // evicts A
        assert!(!reg.is_resident(id_a));
        assert!(reg.is_resident(id_b));
        // Resolving A readmits it (evicting B) and solves bitwise.
        let entry_a = reg.entry(id_a);
        assert!(!reg.is_resident(id_b));
        let res = entry_a.plan().solve(None, None, &opts);
        assert_eq!(res.iters, ra.iters);
        assert!(res.x.iter().zip(&ra.x).all(|(u, v)| u.to_bits() == v.to_bits()));
        let stats = reg.stats();
        assert_eq!(stats.evictions, 2);
        assert_eq!(stats.readmissions, 1);
        assert!(stats.used_beats <= stats.capacity_beats);
    }

    #[test]
    fn lru_order_prefers_the_least_recently_touched_victim() {
        let a = synth::laplace2d_shifted(100, 0.2);
        let fp = footprint_beats(a.n, a.nnz());
        // Room for exactly two 100-element matrices.
        let mut reg = MatrixRegistry::with_capacity(2 * fp);
        let id_a = reg.admit(synth::laplace2d_shifted(100, 0.2), 1);
        let id_b = reg.admit(a, 1);
        let _ = reg.entry(id_a); // A is now more recent than B
        let id_c = reg.admit(synth::laplace2d_shifted(100, 0.2), 1);
        assert!(reg.is_resident(id_a), "recently-touched A survives");
        assert!(!reg.is_resident(id_b), "LRU B is the victim");
        assert!(reg.is_resident(id_c));
    }

    #[test]
    fn pinned_entries_never_evict_and_can_exhaust_capacity() {
        let a = synth::laplace2d_shifted(100, 0.2);
        let fp = footprint_beats(a.n, a.nnz());
        let mut reg = MatrixRegistry::with_capacity(fp);
        let id_a = reg.admit(a, 1);
        reg.pin(id_a).unwrap();
        // Nothing evictable: the second admission is a typed error …
        match reg.try_admit(synth::laplace2d_shifted(100, 0.2), 1) {
            Err(RegistryError::CapacityExhausted { .. }) => {}
            other => panic!("expected CapacityExhausted, got {other:?}"),
        }
        assert!(reg.is_resident(id_a));
        // … and the slot is still admitted: unpinning A lets the
        // now-evictable space serve the other slot on demand.
        reg.unpin(id_a).unwrap();
        let id_b = reg.ids().nth(1).unwrap();
        let entry_b = reg.entry(id_b);
        assert_eq!(entry_b.n(), 100);
        assert!(!reg.is_resident(id_a));
    }

    #[test]
    fn in_flight_arcs_outlive_eviction() {
        let a = synth::laplace2d_shifted(100, 0.2);
        let fp = footprint_beats(a.n, a.nnz());
        let mut reg = MatrixRegistry::with_capacity(fp);
        let id_a = reg.admit(a, 1);
        let held = reg.entry(id_a); // what a dispatched batch holds
        let _id_b = reg.admit(synth::laplace2d_shifted(100, 0.2), 1); // evicts A
        assert!(!reg.is_resident(id_a));
        // The held entry still plans and solves: eviction only dropped
        // the registry's reference.
        let res = held.plan().solve(None, None, &SolveOptions::callipepla());
        assert!(res.converged);
    }

    #[test]
    fn evict_hook_reports_bucket_sharing() {
        use std::sync::atomic::AtomicUsize;
        let notices = Arc::new(Mutex::new(Vec::new()));
        let fired = Arc::new(AtomicUsize::new(0));
        let a = synth::laplace2d_shifted(100, 0.2);
        let fp = footprint_beats(a.n, a.nnz());
        let mut reg = MatrixRegistry::with_capacity(2 * fp);
        let sink = Arc::clone(&notices);
        let count = Arc::clone(&fired);
        reg.set_evict_hook(Box::new(move |n| {
            sink.lock().unwrap().push(*n);
            count.fetch_add(1, Ordering::Relaxed);
        }));
        let id_a = reg.admit(a, 1);
        let _id_b = reg.admit(synth::laplace2d_shifted(100, 0.2), 1);
        let _id_c = reg.admit(synth::laplace2d_shifted(100, 0.2), 1); // evicts A
        assert_eq!(fired.load(Ordering::Relaxed), 1);
        let seen = notices.lock().unwrap();
        assert_eq!(seen[0].id, id_a);
        assert_eq!(seen[0].bucket, 1024);
        assert!(seen[0].bucket_still_resident, "B still holds the 1024 bucket");
    }

    #[test]
    fn footprint_model_counts_vectors_and_both_value_streams() {
        // 1024 elements: 128 beats per vector window; nnz f64 at 8 per
        // beat, f32 at 16 per beat.
        assert_eq!(footprint_beats(1024, 4096), 6 * 128 + 512 + 256);
        assert_eq!(footprint_beats(1, 1), 6 + 1 + 1);
    }
}
