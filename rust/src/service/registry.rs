//! The matrix registry: admit a matrix once, derive its solve state
//! once, serve it forever.
//!
//! A serving deployment sees many solves against few matrices (the
//! reservoir-simulation and lattice-QCD deployments of arXiv:2101.01745
//! and arXiv:2001.05218 are exactly this shape), so everything a solve
//! needs besides the right-hand side — the Jacobi diagonal, the
//! nnz-balanced row partition, the lazy f32 value view — is derived at
//! admission and shared from then on.  Entries are `Arc`-held so worker
//! threads keep a matrix alive for as long as its batches run.

use std::sync::{Arc, OnceLock};

use crate::engine::{PreparedMatrix, RowPartition};
use crate::sparse::CsrMatrix;

/// Handle to an admitted matrix (index into the registry, stable for
/// the registry's lifetime).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct MatrixId(pub(crate) u32);

impl MatrixId {
    /// The registry slot this id names.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for MatrixId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "A{}", self.0)
    }
}

/// One admitted matrix plus its derived solve state.  [`MatrixEntry::plan`]
/// hands out borrowing [`PreparedMatrix`] views whose caches are the
/// entry's own `Arc`s — building a view is O(1) and the lazy f32 view,
/// once derived by any worker, is filled for all.
#[derive(Debug)]
pub struct MatrixEntry {
    a: Arc<CsrMatrix>,
    diag: Arc<Vec<f64>>,
    vals32: Arc<OnceLock<Vec<f32>>>,
    partition: Arc<RowPartition>,
    threads: usize,
}

impl MatrixEntry {
    /// Derive the solve state for `a` with an SpMV thread budget of
    /// `threads` (>= 1) per plan view.
    pub fn new(a: Arc<CsrMatrix>, threads: usize) -> Self {
        let threads = threads.max(1);
        let diag = Arc::new(a.jacobi_diag());
        let partition = Arc::new(RowPartition::nnz_balanced(&a, threads));
        Self { a, diag, vals32: Arc::new(OnceLock::new()), partition, threads }
    }

    /// The admitted matrix.
    pub fn matrix(&self) -> &CsrMatrix {
        &self.a
    }

    /// Vector length.
    pub fn n(&self) -> usize {
        self.a.n
    }

    /// Nonzero count.
    pub fn nnz(&self) -> usize {
        self.a.nnz()
    }

    /// A [`PreparedMatrix`] view over this entry's shared caches —
    /// nothing is re-derived or copied.
    pub fn plan(&self) -> PreparedMatrix<'_> {
        PreparedMatrix::from_shared(
            &self.a,
            Arc::clone(&self.diag),
            Arc::clone(&self.vals32),
            Arc::clone(&self.partition),
            self.threads,
        )
    }
}

/// Append-only registry of admitted matrices.
///
/// ```
/// use callipepla::service::MatrixRegistry;
/// use callipepla::sparse::synth;
///
/// let mut reg = MatrixRegistry::new();
/// let id = reg.admit(synth::laplace2d_shifted(100, 0.2), 1);
/// assert_eq!(reg.entry(id).n(), reg.entry(id).matrix().n);
/// assert_eq!(reg.len(), 1);
/// ```
#[derive(Debug, Default)]
pub struct MatrixRegistry {
    entries: Vec<Arc<MatrixEntry>>,
}

impl MatrixRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit a matrix: derive its solve state once, get a stable id.
    pub fn admit(&mut self, a: CsrMatrix, threads: usize) -> MatrixId {
        let id = MatrixId(u32::try_from(self.entries.len()).expect("registry ids fit u32"));
        self.entries.push(Arc::new(MatrixEntry::new(Arc::new(a), threads)));
        id
    }

    /// The entry behind an id (panics on a foreign id — ids are only
    /// minted by [`MatrixRegistry::admit`] on this registry).
    pub fn entry(&self, id: MatrixId) -> &Arc<MatrixEntry> {
        &self.entries[id.index()]
    }

    /// Ids in admission order.
    pub fn ids(&self) -> impl Iterator<Item = MatrixId> + '_ {
        (0..self.entries.len() as u32).map(MatrixId)
    }

    /// Number of admitted matrices.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether nothing has been admitted.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{jpcg_solve, SolveOptions};
    use crate::sparse::synth;

    #[test]
    fn entry_plans_share_caches_and_solve_bitwise() {
        let a = synth::laplace2d_shifted(400, 0.1);
        let reference = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        let entry = MatrixEntry::new(Arc::new(a), 2);
        // Two views, one shared lazy f32 cache: deriving through the
        // first fills it for the second.
        let p1 = entry.plan();
        let p2 = entry.plan();
        let v1 = p1.vals32().as_ptr();
        let v2 = p2.vals32().as_ptr();
        assert_eq!(v1, v2, "views share one f32 cache");
        let res = p2.solve(None, None, &SolveOptions::callipepla());
        assert_eq!(res.iters, reference.iters);
        assert!(res.x.iter().zip(&reference.x).all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn registry_ids_are_stable_and_ordered() {
        let mut reg = MatrixRegistry::new();
        let a = reg.admit(synth::laplace2d_shifted(100, 0.2), 1);
        let b = reg.admit(synth::laplace2d_shifted(150, 0.2), 1);
        assert_ne!(a, b);
        assert_eq!(reg.ids().collect::<Vec<_>>(), vec![a, b]);
        assert_eq!(reg.entry(b).n(), reg.entry(b).matrix().n);
    }
}
