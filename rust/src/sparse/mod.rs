//! Sparse-matrix substrate: CSR/COO storage, Matrix-Market I/O, the
//! synthetic SPD benchmark suite standing in for Table 3, and the
//! Serpens-style packed non-zero streams fed to the SpMV module.

mod csr;
pub mod mtx;
pub mod stream;
pub mod synth;

pub use csr::{CooMatrix, CsrMatrix};
pub use stream::{pack_nnz_streams, pack_nnz_streams_cfg, NnzStream, PackedNnz, DEP_DIST_SERPENS, DEP_DIST_XCGSOLVER, NUM_CHANNELS, PES_PER_CHANNEL};
pub use synth::{suite36, MatrixSpec, SynthKind};
