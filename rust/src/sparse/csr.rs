//! COO / CSR sparse matrices (square, FP64 master copies).
//!
//! The FP64 copy is the single source of truth; precision schemes
//! (Table 1) derive their f32 views on demand via
//! [`CsrMatrix::vals_f32`] so every scheme sees *the same* rounding of
//! the same matrix — exactly what the FPGA does when it stores the nnz
//! stream once in a given precision.

/// Triplet-form sparse matrix; the assembly format for generators and
/// Matrix-Market ingestion.
#[derive(Debug, Clone, Default)]
pub struct CooMatrix {
    /// Matrix dimension (square).
    pub n: usize,
    /// Row index per triplet.
    pub rows: Vec<u32>,
    /// Column index per triplet.
    pub cols: Vec<u32>,
    /// Value per triplet.
    pub vals: Vec<f64>,
}

impl CooMatrix {
    /// An empty n x n triplet matrix.
    pub fn new(n: usize) -> Self {
        Self { n, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    /// Append one (row, col, value) triplet.
    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.n && c < self.n);
        self.rows.push(r as u32);
        self.cols.push(c as u32);
        self.vals.push(v);
    }

    /// Stored triplet count (duplicates not yet merged).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Sort by (row, col), summing duplicates, and convert to CSR.
    pub fn to_csr(&self) -> CsrMatrix {
        let mut order: Vec<u32> = (0..self.nnz() as u32).collect();
        order.sort_unstable_by_key(|&i| {
            (self.rows[i as usize], self.cols[i as usize])
        });
        let mut indptr = vec![0u32; self.n + 1];
        let mut indices = Vec::with_capacity(self.nnz());
        let mut vals: Vec<f64> = Vec::with_capacity(self.nnz());
        let (mut last_r, mut last_c) = (u32::MAX, u32::MAX);
        for &i in &order {
            let (r, c, v) = (
                self.rows[i as usize],
                self.cols[i as usize],
                self.vals[i as usize],
            );
            if r == last_r && c == last_c {
                *vals.last_mut().unwrap() += v; // merge duplicate
            } else {
                indptr[r as usize + 1] += 1;
                indices.push(c);
                vals.push(v);
                (last_r, last_c) = (r, c);
            }
        }
        for i in 0..self.n {
            indptr[i + 1] += indptr[i];
        }
        CsrMatrix { n: self.n, indptr, indices, vals }
    }
}

/// Compressed-sparse-row matrix, FP64 values.
#[derive(Debug, Clone)]
pub struct CsrMatrix {
    /// Matrix dimension (square).
    pub n: usize,
    /// `indptr[i]..indptr[i+1]` is the index range of row `i`. Length n+1.
    pub indptr: Vec<u32>,
    /// Column index per non-zero.
    pub indices: Vec<u32>,
    /// FP64 value per non-zero (the master copy).
    pub vals: Vec<f64>,
}

impl CsrMatrix {
    /// Stored non-zero count.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Row range helper.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[i] as usize, self.indptr[i + 1] as usize);
        (&self.indices[s..e], &self.vals[s..e])
    }

    /// Diagonal of A — the Jacobi preconditioner M (Alg. 1 input 2).
    /// Missing/zero diagonal entries are mapped to 1.0 so the left-divide
    /// module is always well defined (same guard XcgSolver applies).
    pub fn jacobi_diag(&self) -> Vec<f64> {
        let mut d = vec![1.0; self.n];
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                if *c as usize == i && *v != 0.0 {
                    d[i] = *v;
                }
            }
        }
        d
    }

    /// f32 view of the value stream: what HBM actually holds under
    /// Mix-V1/V2/V3 (Table 1).
    pub fn vals_f32(&self) -> Vec<f32> {
        self.vals.iter().map(|&v| v as f32).collect()
    }

    /// y = A x, straightforward FP64 reference (the "CPU golden" of
    /// Table 7).
    pub fn spmv_f64(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(y.len(), self.n);
        self.spmv_f64_rows(x, y, 0);
    }

    /// `spmv_f64` restricted to the contiguous row block
    /// `row_start..row_start + y_rows.len()`, writing into `y_rows`.
    /// Per-row accumulation order is identical to the full kernel, so a
    /// row partition of calls reproduces `spmv_f64` bitwise — the
    /// invariant the parallel engine ([`crate::engine`]) relies on.
    pub fn spmv_f64_rows(&self, x: &[f64], y_rows: &mut [f64], row_start: usize) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert!(row_start + y_rows.len() <= self.n);
        // Hot path (§Perf): bounds checks lifted out of the gather loop;
        // indices are validated at construction.
        for (j, yj) in y_rows.iter_mut().enumerate() {
            let i = row_start + j;
            let (s, e) = (self.indptr[i] as usize, self.indptr[i + 1] as usize);
            let mut acc = 0.0f64;
            for k in s..e {
                // SAFETY: k < nnz and indices[k] < n by CSR construction.
                unsafe {
                    acc += *self.vals.get_unchecked(k)
                        * x.get_unchecked(*self.indices.get_unchecked(k) as usize);
                }
            }
            *yj = acc;
        }
    }

    /// Non-zeros in row `i`.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        (self.indptr[i + 1] - self.indptr[i]) as usize
    }

    /// Contiguous nnz-balanced row partition into `parts` blocks:
    /// returns `parts + 1` row boundaries (`bounds[k]..bounds[k+1]` is
    /// block k).  Cut points are placed by binary search on the nnz
    /// prefix sum (`indptr`), so every block carries at most
    /// `nnz/parts + max_row_nnz` non-zeros — near-perfect balance
    /// whenever single rows are small against a block, the same
    /// split-by-work rule HBM SpMV accelerators use to feed their
    /// channel groups evenly.
    pub fn nnz_balanced_bounds(&self, parts: usize) -> Vec<usize> {
        let parts = parts.max(1);
        let total = self.nnz() as u64;
        let mut bounds = Vec::with_capacity(parts + 1);
        bounds.push(0usize);
        for k in 1..parts {
            let target = total * k as u64 / parts as u64;
            // First row boundary whose nnz prefix reaches the target.
            let cut = self.indptr.partition_point(|&p| (p as u64) < target);
            let prev = *bounds.last().unwrap();
            bounds.push(cut.clamp(prev, self.n));
        }
        bounds.push(self.n);
        bounds
    }

    /// Symmetry check (structure + values), used by tests and the mtx
    /// loader: JPCG requires a symmetric matrix.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                let j = *c as usize;
                let (jc, jv) = self.row(j);
                match jc.binary_search(&(i as u32)) {
                    Ok(k) => {
                        if (jv[k] - v).abs() > tol * v.abs().max(1.0) {
                            return false;
                        }
                    }
                    Err(_) => return false,
                }
            }
        }
        true
    }

    /// Bytes of one full matrix pass under a given nnz value width —
    /// feeds the HBM traffic model. 64-bit packed nnz for f32 values
    /// (14-bit col + 18-bit row + f32, §6), 128-bit for f64 (§2.3.3).
    pub fn stream_bytes(&self, fp64_vals: bool) -> u64 {
        let per = if fp64_vals { 16 } else { 8 };
        self.nnz() as u64 * per
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tri(n: usize) -> CsrMatrix {
        let mut coo = CooMatrix::new(n);
        for i in 0..n {
            coo.push(i, i, 2.0);
            if i > 0 {
                coo.push(i, i - 1, -1.0);
            }
            if i + 1 < n {
                coo.push(i, i + 1, -1.0);
            }
        }
        coo.to_csr()
    }

    #[test]
    fn coo_to_csr_sorts_and_merges() {
        let mut coo = CooMatrix::new(3);
        coo.push(2, 0, 1.0);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, 2.0); // duplicate -> merged
        coo.push(1, 2, 5.0);
        let csr = coo.to_csr();
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.row(0), (&[0u32][..], &[3.0][..]));
        assert_eq!(csr.row(1), (&[2u32][..], &[5.0][..]));
        assert_eq!(csr.row(2), (&[0u32][..], &[1.0][..]));
    }

    #[test]
    fn spmv_tridiagonal() {
        let a = tri(4);
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = [0.0; 4];
        a.spmv_f64(&x, &mut y);
        assert_eq!(y, [0.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn jacobi_diag_extracts_diagonal() {
        let a = tri(5);
        assert_eq!(a.jacobi_diag(), vec![2.0; 5]);
    }

    #[test]
    fn symmetric_detects_both_ways() {
        assert!(tri(6).is_symmetric(1e-12));
        let mut coo = CooMatrix::new(2);
        coo.push(0, 1, 3.0); // no (1,0) partner
        coo.push(0, 0, 1.0);
        coo.push(1, 1, 1.0);
        assert!(!coo.to_csr().is_symmetric(1e-12));
    }

    #[test]
    fn stream_bytes_mixed_halves_traffic() {
        let a = tri(100);
        assert_eq!(a.stream_bytes(true), 2 * a.stream_bytes(false));
    }

    #[test]
    fn spmv_rows_matches_full_kernel_bitwise() {
        let a = tri(97);
        let x: Vec<f64> = (0..a.n).map(|i| (i as f64 * 0.31).sin()).collect();
        let mut full = vec![0.0; a.n];
        a.spmv_f64(&x, &mut full);
        for bounds in [vec![0, 97], vec![0, 13, 40, 97], vec![0, 1, 96, 97]] {
            let mut piecewise = vec![0.0; a.n];
            for w in bounds.windows(2) {
                a.spmv_f64_rows(&x, &mut piecewise[w[0]..w[1]], w[0]);
            }
            assert!(
                full.iter().zip(&piecewise).all(|(u, v)| u.to_bits() == v.to_bits()),
                "row-block kernel diverged for bounds {bounds:?}"
            );
        }
    }

    #[test]
    fn nnz_balanced_bounds_cover_and_balance() {
        let a = tri(1000);
        for parts in [1, 2, 3, 7, 8] {
            let b = a.nnz_balanced_bounds(parts);
            assert_eq!(b.len(), parts + 1);
            assert_eq!((b[0], b[parts]), (0, a.n));
            assert!(b.windows(2).all(|w| w[0] <= w[1]), "non-monotone: {b:?}");
            let total: usize = b
                .windows(2)
                .map(|w| (a.indptr[w[1]] - a.indptr[w[0]]) as usize)
                .sum();
            assert_eq!(total, a.nnz());
            // Tridiagonal rows are tiny, so balance is near-perfect.
            let max = b
                .windows(2)
                .map(|w| (a.indptr[w[1]] - a.indptr[w[0]]) as usize)
                .max()
                .unwrap();
            let mean = a.nnz() as f64 / parts as f64;
            assert!((max as f64) <= mean + 3.0, "max={max} mean={mean}");
        }
    }

    #[test]
    fn nnz_balanced_bounds_more_parts_than_rows() {
        let a = tri(3);
        let b = a.nnz_balanced_bounds(8);
        assert_eq!(b.len(), 9);
        assert_eq!((b[0], b[8]), (0, 3));
        assert!(b.windows(2).all(|w| w[0] <= w[1]));
    }
}
