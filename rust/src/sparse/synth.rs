//! Synthetic SPD matrix suite standing in for the 36 SuiteSparse matrices
//! of Table 3 (DESIGN.md §Hardware-Adaptation: no network access to
//! SuiteSparse in this environment).
//!
//! Construction: a banded weighted graph Laplacian `L` plus a diagonal
//! shift `delta * I`.  `L` is symmetric positive *semi*-definite by
//! construction (diag == sum of |off-diag| per row), so `A = L + delta*I`
//! is SPD with smallest eigenvalue >= delta and largest ~= 2*max row
//! weight.  After Jacobi preconditioning the condition number scales like
//! 1/delta, and CG iteration count like 1/sqrt(delta) — so each Table-3
//! entry carries a `delta` *tuned from the paper's CPU iteration count*
//! (Table 7) to land the solver in the same convergence regime.  Matrix
//! dimension and nnz match Table 3 (at `scale == 1.0`).

use crate::util::Rng64;

use super::{CooMatrix, CsrMatrix};

/// Generator families, loosely matching the application classes the
/// paper's suite covers ("structural problems, thermal problems, ...").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthKind {
    /// 5-point 2-D Poisson stencil (thermal / 2D-3D class).
    Laplace2d,
    /// 7-point 3-D Poisson stencil.
    Laplace3d,
    /// Banded random graph Laplacian + delta*I (structural / FEM class).
    BandedSpd,
}

/// One Table-3 row: the paper matrix it stands in for plus the synthetic
/// recipe that reproduces its scale and difficulty.
#[derive(Debug, Clone)]
pub struct MatrixSpec {
    /// Mxx identifier used throughout the paper's tables.
    pub id: &'static str,
    /// SuiteSparse name of the matrix this stands in for.
    pub paper_name: &'static str,
    /// Paper's row/col count (Table 3).
    pub n: usize,
    /// Paper's nnz (Table 3).
    pub nnz: usize,
    /// Paper's CPU-FP64 JPCG iteration count (Table 7); 20_000 == did
    /// not converge within the cap.
    pub cpu_iters: u32,
    /// Which synthetic generator family reproduces it.
    pub kind: SynthKind,
}

impl MatrixSpec {
    /// Diagonal shift giving a Jacobi-CG iteration count in the regime of
    /// `cpu_iters` (calibrated: iters ≈ C / sqrt(delta) with C ≈ 13 for
    /// tau = 1e-12 on these generators; non-converging entries get a
    /// delta below the calibration floor).
    pub fn delta(&self) -> f64 {
        // Table-7 cap entries (ex9, olafu, bcsstk36, raefsky4) do not
        // reach 1e-12 on the real matrices.  Our synthetic spectra are
        // more clustered than the real FEM spectra, so CG resolves them
        // regardless of the shift; they are generated as the hardest
        // difficulty and the deviation is documented in EXPERIMENTS.md.
        let it = self.cpu_iters.max(20) as f64;
        let c = 10.0; // empirical: iters ~ C / sqrt(delta) on these generators
        (c / it).powi(2)
    }

    /// Edge-weight dynamic range in decades.  Non-converging entries
    /// (20K cap in Table 7) get an extreme range so the FP64 residual
    /// plateaus above 1e-12, like the real ex9/olafu/bcsstk36/raefsky4.
    pub fn weight_decades(&self) -> f64 {
        if self.cpu_iters >= 20_000 { 14.0 } else { 8.0 }
    }

    /// Generated size floor: CG converges in at most n steps, so a
    /// stand-in must have n >= ~3.5x the target iteration count for the
    /// convergence regime to be reproducible (capped at paper size).
    fn n_floor(&self) -> usize {
        ((3.5 * self.cpu_iters.min(20_000) as f64) as usize).min(self.n)
    }

    /// Generate the synthetic stand-in, optionally scaled down
    /// (`scale < 1.0` shrinks n and nnz proportionally — used by the
    /// default bench profile; `1.0` reproduces Table-3 sizes).
    pub fn generate(&self, scale: f64) -> CsrMatrix {
        let n = ((self.n as f64 * scale) as usize).max(self.n_floor()).max(64);
        // Keep the paper's nnz density at the generated size.
        let nnz = ((self.nnz as f64 * n as f64 / self.n as f64) as usize).max(4 * n);
        let seed = fxhash(self.id);
        match self.kind {
            SynthKind::Laplace2d => laplace2d_shifted(n, self.delta()),
            SynthKind::Laplace3d => laplace3d_shifted(n, self.delta()),
            SynthKind::BandedSpd => {
                banded_spd_decades(n, nnz, self.delta(), seed, self.weight_decades())
            }
        }
    }
}

fn fxhash(s: &str) -> u64 {
    // Tiny deterministic string hash for per-matrix seeds.
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

/// 2-D 5-point Poisson matrix of at least `n_target` unknowns, plus
/// `delta*I` (delta==0 gives the pure singularity-free Dirichlet stencil).
pub fn laplace2d_shifted(n_target: usize, delta: f64) -> CsrMatrix {
    let side = (n_target as f64).sqrt().ceil() as usize;
    let n = side * side;
    let mut coo = CooMatrix::new(n);
    for y in 0..side {
        for x in 0..side {
            let i = y * side + x;
            coo.push(i, i, 4.0 + delta);
            if x > 0 {
                coo.push(i, i - 1, -1.0);
            }
            if x + 1 < side {
                coo.push(i, i + 1, -1.0);
            }
            if y > 0 {
                coo.push(i, i - side, -1.0);
            }
            if y + 1 < side {
                coo.push(i, i + side, -1.0);
            }
        }
    }
    coo.to_csr()
}

/// 3-D 7-point Poisson matrix, shifted.
pub fn laplace3d_shifted(n_target: usize, delta: f64) -> CsrMatrix {
    let side = (n_target as f64).cbrt().ceil() as usize;
    let n = side * side * side;
    let mut coo = CooMatrix::new(n);
    let idx = |x: usize, y: usize, z: usize| (z * side + y) * side + x;
    for z in 0..side {
        for y in 0..side {
            for x in 0..side {
                let i = idx(x, y, z);
                coo.push(i, i, 6.0 + delta);
                if x > 0 {
                    coo.push(i, idx(x - 1, y, z), -1.0);
                }
                if x + 1 < side {
                    coo.push(i, idx(x + 1, y, z), -1.0);
                }
                if y > 0 {
                    coo.push(i, idx(x, y - 1, z), -1.0);
                }
                if y + 1 < side {
                    coo.push(i, idx(x, y + 1, z), -1.0);
                }
                if z > 0 {
                    coo.push(i, idx(x, y, z - 1), -1.0);
                }
                if z + 1 < side {
                    coo.push(i, idx(x, y, z + 1), -1.0);
                }
            }
        }
    }
    coo.to_csr()
}

/// Banded random weighted graph Laplacian + delta*I.
///
/// Each row gets ~`nnz_target/n - 1` off-diagonal partners within a band
/// (FEM meshes are banded after reordering), weights in (0, 1]; the
/// diagonal is the row's weight sum plus `delta`, making A an SPD
/// M-matrix whose Jacobi-preconditioned condition number ~ 1/delta.
pub fn banded_spd(n: usize, nnz_target: usize, delta: f64, seed: u64) -> CsrMatrix {
    banded_spd_decades(n, nnz_target, delta, seed, 8.0)
}

/// `banded_spd` with an explicit edge-weight dynamic range (decades).
pub fn banded_spd_decades(
    n: usize,
    nnz_target: usize,
    delta: f64,
    seed: u64,
    decades: f64,
) -> CsrMatrix {
    let mut rng = Rng64::seed_from_u64(seed);
    let per_row = ((nnz_target / n).saturating_sub(1) / 2).max(1);
    let band = (per_row * 8).max(16).min(n - 1);
    // Symmetric off-diagonal pattern: i ~ j, j in (i, i+band].
    let mut partners: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
    for i in 0..n {
        for _ in 0..per_row {
            let span = band.min(n - 1 - i);
            if span == 0 {
                continue;
            }
            let j = i + 1 + rng.gen_range(span);
            // Log-uniform weights spanning ~4 decades: real FEM/structural
            // matrices (nasa2910, gyro_k, ...) mix stiff and soft elements,
            // which is what fills the low end of the Jacobi-preconditioned
            // spectrum densely and drives CG into the thousands of
            // iterations Table 7 reports.
            let w = 10f64.powf(-decades * rng.gen_f64());
            partners[i].push((j as u32, w));
        }
    }
    // Random diagonal similarity scaling S A S (s in [0.5, 2]): keeps
    // SPD and the Jacobi-preconditioned spectrum, but destroys the
    // graph-Laplacian property A*ones = delta*ones — without it the
    // paper's b = all-ones RHS would be a near-eigenvector and CG would
    // converge unrealistically fast regardless of conditioning.
    let s: Vec<f64> = (0..n).map(|_| rng.gen_f64_range(0.5, 2.0)).collect();
    let mut coo = CooMatrix::new(n);
    let mut diag = vec![delta; n];
    for i in 0..n {
        for &(j, w) in &partners[i] {
            let j = j as usize;
            coo.push(i, j, -w * s[i] * s[j]);
            coo.push(j, i, -w * s[i] * s[j]);
            diag[i] += w;
            diag[j] += w;
        }
    }
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, *d * s[i] * s[i]);
    }
    coo.to_csr()
}

/// The 36-matrix suite of Table 3. `cpu_iters` comes from Table 7
/// (CPU row); kinds are assigned from the paper's application notes.
pub fn suite36() -> Vec<MatrixSpec> {
    use SynthKind::*;
    let t = |id, paper_name, n, nnz, cpu_iters, kind| MatrixSpec {
        id,
        paper_name,
        n,
        nnz,
        cpu_iters,
        kind,
    };
    vec![
        t("M1", "ex9", 3_363, 99_471, 20_000, BandedSpd),
        t("M2", "bcsstk15", 3_948, 117_816, 634, BandedSpd),
        t("M3", "bodyy4", 17_546, 121_550, 164, BandedSpd),
        t("M4", "ted_B", 10_605, 144_579, 26, BandedSpd),
        t("M5", "ted_B_unscaled", 10_605, 144_579, 26, BandedSpd),
        t("M6", "bcsstk24", 3_562, 159_910, 9_441, BandedSpd),
        t("M7", "nasa2910", 2_910, 174_296, 1_713, BandedSpd),
        t("M8", "s3rmt3m3", 5_357, 207_123, 15_692, BandedSpd),
        t("M9", "bcsstk28", 4_410, 219_024, 4_821, BandedSpd),
        t("M10", "s2rmq4m1", 5_489, 263_351, 1_750, BandedSpd),
        t("M11", "cbuckle", 13_681, 676_515, 1_266, BandedSpd),
        t("M12", "olafu", 16_146, 1_015_156, 20_000, BandedSpd),
        t("M13", "gyro_k", 17_361, 1_021_159, 12_956, BandedSpd),
        t("M14", "bcsstk36", 23_052, 1_143_140, 20_000, BandedSpd),
        t("M15", "msc10848", 10_848, 1_229_776, 5_615, BandedSpd),
        t("M16", "raefsky4", 19_779, 1_316_789, 20_000, BandedSpd),
        t("M17", "nd3k", 9_000, 3_279_690, 9_904, BandedSpd),
        t("M18", "nd6k", 18_000, 6_897_316, 11_816, BandedSpd),
        t("M19", "2cubes_sphere", 101_492, 1_647_264, 33, Laplace3d),
        t("M20", "cfd2", 123_440, 3_085_406, 8_419, BandedSpd),
        t("M21", "Dubcova3", 146_689, 3_636_643, 242, Laplace2d),
        t("M22", "ship_003", 121_728, 3_777_036, 6_151, BandedSpd),
        t("M23", "offshore", 259_789, 4_242_673, 2_224, Laplace3d),
        t("M24", "shipsec5", 179_860, 4_598_604, 5_507, BandedSpd),
        t("M25", "ecology2", 999_999, 4_995_991, 6_584, Laplace2d),
        t("M26", "tmt_sym", 726_713, 5_080_961, 4_903, Laplace2d),
        t("M27", "boneS01", 127_224, 5_516_602, 2_287, BandedSpd),
        t("M28", "hood", 220_542, 9_895_422, 6_424, BandedSpd),
        t("M29", "bmwcra_1", 148_770, 10_641_602, 5_902, BandedSpd),
        t("M30", "af_shell3", 504_855, 17_562_051, 3_906, BandedSpd),
        t("M31", "Fault_639", 638_802, 27_245_944, 9_879, BandedSpd),
        t("M32", "Emilia_923", 923_136, 40_373_538, 13_263, BandedSpd),
        t("M33", "Geo_1438", 1_437_960, 60_236_322, 2_054, BandedSpd),
        t("M34", "Serena", 1_391_349, 64_131_971, 1_299, BandedSpd),
        t("M35", "audikw_1", 943_695, 77_651_847, 7_638, BandedSpd),
        t("M36", "Flan_1565", 1_564_794, 114_165_372, 12_160, BandedSpd),
    ]
}

/// Look up a suite entry by its Mxx id or paper name.
pub fn find_spec(key: &str) -> Option<MatrixSpec> {
    suite36()
        .into_iter()
        .find(|s| s.id.eq_ignore_ascii_case(key) || s.paper_name.eq_ignore_ascii_case(key))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_36_entries_matching_table3() {
        let s = suite36();
        assert_eq!(s.len(), 36);
        assert_eq!(s[0].id, "M1");
        assert_eq!(s[35].paper_name, "Flan_1565");
        assert_eq!(s[35].nnz, 114_165_372);
        // Table 3 rows are sorted by nnz within each half.
        assert!(s.iter().take(18).zip(s.iter().take(18).skip(1)).all(|(a, b)| a.nnz <= b.nnz));
    }

    #[test]
    fn generated_matrices_are_spd_shaped() {
        for spec in suite36().into_iter().take(4) {
            let a = spec.generate(0.01);
            assert!(a.is_symmetric(1e-12), "{} not symmetric", spec.id);
            // SPD via similarity scaling of a diagonally-dominant core:
            // positive diagonal everywhere, and x'Ax > 0 on probes.
            for i in 0..a.n {
                let (cols, vals) = a.row(i);
                let diag = cols
                    .iter()
                    .zip(vals)
                    .find(|(c, _)| **c as usize == i)
                    .map(|(_, v)| *v)
                    .unwrap_or(0.0);
                assert!(diag > 0.0, "row {i} of {}", spec.id);
            }
            let mut rng = crate::util::Rng64::seed_from_u64(1);
            for _ in 0..3 {
                let x: Vec<f64> = (0..a.n).map(|_| rng.gen_normal()).collect();
                let mut ax = vec![0.0; a.n];
                a.spmv_f64(&x, &mut ax);
                let xtax: f64 = x.iter().zip(&ax).map(|(u, v)| u * v).sum();
                assert!(xtax > 0.0, "{} not positive definite", spec.id);
            }
        }
    }

    #[test]
    fn laplace2d_shape() {
        let a = laplace2d_shifted(100, 0.0);
        assert_eq!(a.n, 100);
        assert!(a.is_symmetric(0.0));
        assert_eq!(a.nnz(), 100 + 2 * 2 * 90); // 5-point, 10x10 grid
    }

    #[test]
    fn laplace3d_shape() {
        let a = laplace3d_shifted(27, 0.5);
        assert_eq!(a.n, 27);
        assert!(a.is_symmetric(0.0));
    }

    #[test]
    fn banded_nnz_near_target() {
        let a = banded_spd(1000, 20_000, 1e-3, 42);
        let ratio = a.nnz() as f64 / 20_000.0;
        assert!((0.5..=1.5).contains(&ratio), "nnz={} target=20000", a.nnz());
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = find_spec("M7").unwrap();
        let a = spec.generate(0.1);
        let b = spec.generate(0.1);
        assert_eq!(a.vals, b.vals);
        assert_eq!(a.indices, b.indices);
    }

    #[test]
    fn harder_specs_get_smaller_delta() {
        let easy = find_spec("M4").unwrap(); // 26 iters
        let hard = find_spec("M13").unwrap(); // 12956 iters
        assert!(hard.delta() < easy.delta());
    }
}
