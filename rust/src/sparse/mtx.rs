//! Matrix Market (.mtx) reader/writer — the interchange format of
//! SuiteSparse, so real Table-3 matrices can be dropped into the suite
//! when available (the synthetic generators stand in otherwise).
//!
//! Supports `matrix coordinate real {general,symmetric}` and
//! `pattern {general,symmetric}` (pattern entries get value 1.0), the
//! formats used by every matrix in Table 3.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::{CooMatrix, CsrMatrix};

/// Parse a Matrix Market file into CSR. Symmetric files are expanded to
/// full storage (both triangles), matching what the accelerator streams.
pub fn read_mtx(path: &Path) -> Result<CsrMatrix> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    read_mtx_from(BufReader::new(f))
}

/// [`read_mtx`] over any buffered reader (tests feed in-memory strings).
pub fn read_mtx_from<R: BufRead>(mut r: R) -> Result<CsrMatrix> {
    let mut header = String::new();
    r.read_line(&mut header)?;
    let h = header.trim().to_ascii_lowercase();
    if !h.starts_with("%%matrixmarket matrix coordinate") {
        bail!("unsupported MatrixMarket header: {}", header.trim());
    }
    let pattern = h.contains(" pattern");
    let symmetric = h.contains(" symmetric");
    if !pattern && !h.contains(" real") && !h.contains(" integer") {
        bail!("unsupported field type in header: {}", header.trim());
    }

    let mut line = String::new();
    // Skip comment lines.
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            bail!("EOF before size line");
        }
        let t = line.trim();
        if !t.is_empty() && !t.starts_with('%') {
            break;
        }
    }
    let mut it = line.split_whitespace();
    let nrows: usize = it.next().ok_or_else(|| anyhow!("bad size line"))?.parse()?;
    let ncols: usize = it.next().ok_or_else(|| anyhow!("bad size line"))?.parse()?;
    let nnz: usize = it.next().ok_or_else(|| anyhow!("bad size line"))?.parse()?;
    if nrows != ncols {
        bail!("JPCG needs a square matrix, got {nrows}x{ncols}");
    }

    let mut coo = CooMatrix::new(nrows);
    let mut seen = 0usize;
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            break;
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().ok_or_else(|| anyhow!("bad entry: {t}"))?.parse()?;
        let j: usize = it.next().ok_or_else(|| anyhow!("bad entry: {t}"))?.parse()?;
        let v: f64 = if pattern {
            1.0
        } else {
            it.next().ok_or_else(|| anyhow!("missing value: {t}"))?.parse()?
        };
        if i == 0 || j == 0 || i > nrows || j > ncols {
            bail!("1-based index out of range: {t}");
        }
        coo.push(i - 1, j - 1, v);
        if symmetric && i != j {
            coo.push(j - 1, i - 1, v);
        }
        seen += 1;
    }
    if seen != nnz {
        bail!("entry count mismatch: header says {nnz}, file has {seen}");
    }
    Ok(coo.to_csr())
}

/// Write CSR as `coordinate real general` (full storage).
pub fn write_mtx(a: &CsrMatrix, path: &Path) -> Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "{} {} {}", a.n, a.n, a.nnz())?;
    for i in 0..a.n {
        let (cols, vals) = a.row(i);
        for (c, v) in cols.iter().zip(vals) {
            writeln!(w, "{} {} {:.17e}", i + 1, c + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_general() {
        let src = "%%MatrixMarket matrix coordinate real general\n\
                   % comment\n\
                   2 2 3\n1 1 2.0\n1 2 -1.0\n2 2 2.0\n";
        let a = read_mtx_from(Cursor::new(src)).unwrap();
        assert_eq!(a.n, 2);
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.row(0), (&[0u32, 1][..], &[2.0, -1.0][..]));
    }

    #[test]
    fn symmetric_expands_both_triangles() {
        let src = "%%MatrixMarket matrix coordinate real symmetric\n\
                   2 2 2\n1 1 4.0\n2 1 -1.0\n";
        let a = read_mtx_from(Cursor::new(src)).unwrap();
        assert_eq!(a.nnz(), 3); // (0,0), (1,0), (0,1)
        assert!(a.is_symmetric(0.0));
    }

    #[test]
    fn pattern_gets_unit_values() {
        let src = "%%MatrixMarket matrix coordinate pattern symmetric\n\
                   2 2 2\n1 1\n2 1\n";
        let a = read_mtx_from(Cursor::new(src)).unwrap();
        assert_eq!(a.vals, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn rejects_rectangular() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 3 1\n1 1 1.0\n";
        assert!(read_mtx_from(Cursor::new(src)).is_err());
    }

    #[test]
    fn rejects_count_mismatch() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 5\n1 1 1.0\n";
        assert!(read_mtx_from(Cursor::new(src)).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let p = std::env::temp_dir().join(format!("callipepla_mtx_{}.mtx", std::process::id()));
        let a = {
            let mut coo = CooMatrix::new(3);
            coo.push(0, 0, 2.0);
            coo.push(1, 1, 3.0);
            coo.push(2, 0, -0.5);
            coo.to_csr()
        };
        write_mtx(&a, &p).unwrap();
        let b = read_mtx(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(a.vals, b.vals);
        assert_eq!(a.indices, b.indices);
    }
}
