//! Serpens-style packed non-zero streams (paper §6, Fig. 8).
//!
//! Callipepla's SpMV (module M1) streams non-zeros from 16 HBM channels
//! into 8 processing engines per channel.  Each 512-bit channel beat
//! carries 8 × 64-bit packed non-zeros:
//!
//! ```text
//!   63..50   49..32   31..0
//!   col:14   row:18   value:f32      (Mix-V3 / Serpens encoding)
//! ```
//!
//! Because the accumulator `y[row] += v * x[col]` has a read-after-write
//! hazard, a PE must not touch the same row twice within the accumulator
//! dependency distance.  Serpens solves this by **out-of-order scheduling**
//! of each PE's nnz queue with the *load-store* distance (short), padding
//! with no-ops only when nothing is schedulable; XcgSolver instead pads
//! by the FP-add latency (long) — and, per §7.5.1, under-estimates it,
//! which is both slower (more padding) and numerically unstable.  This
//! module implements the scheduler so the cycle model can charge the
//! *scheduled* stream length and the tests can replay streams to verify
//! the hazard guarantee.


use super::CsrMatrix;

/// HBM channels dedicated to nnz streaming (all three FPGA accelerators
/// in the paper allocate 16).
pub const NUM_CHANNELS: usize = 16;
/// PEs per channel: 512-bit beat / 64-bit packed nnz.
pub const PES_PER_CHANNEL: usize = 8;
/// X-memory (BRAM) depth: 14-bit col offset (§6: "a 14-bit column index").
pub const COL_WINDOW: usize = 1 << 14;
/// Y-memory (URAM) rows addressable: 18-bit row offset.
pub const ROW_WINDOW: usize = 1 << 18;
/// Serpens hazard distance: load-store dependency length.
pub const DEP_DIST_SERPENS: usize = 5;
/// XcgSolver pads by FP64-add latency (deeper, hence more padding).
pub const DEP_DIST_XCGSOLVER: usize = 14;

/// One 64-bit packed non-zero. `NOP` (all-ones col) is the padding beat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PackedNnz(pub u64);

impl PackedNnz {
    /// The padding beat (all-ones word).
    pub const NOP: PackedNnz = PackedNnz(u64::MAX);

    /// Pack (col offset, row offset, f32 value) into one 64-bit word.
    pub fn pack(col_off: u32, row_off: u32, val: f32) -> Self {
        debug_assert!(col_off < COL_WINDOW as u32);
        debug_assert!(row_off < ROW_WINDOW as u32);
        let bits = ((col_off as u64) << 50)
            | ((row_off as u64) << 32)
            | (val.to_bits() as u64);
        // All-ones col marks NOP; a real nnz never has col == 2^14-1 with
        // row == 2^18-1 and val == NaN-payload, but guard anyway.
        debug_assert_ne!(bits, u64::MAX);
        PackedNnz(bits)
    }

    /// Is this the padding beat?
    pub fn is_nop(self) -> bool {
        self == Self::NOP
    }

    /// 14-bit column offset within the tile's col window.
    pub fn col_off(self) -> u32 {
        (self.0 >> 50) as u32 & (COL_WINDOW as u32 - 1)
    }

    /// 18-bit row offset within the tile's row window.
    pub fn row_off(self) -> u32 {
        (self.0 >> 32) as u32 & (ROW_WINDOW as u32 - 1)
    }

    /// The f32 matrix value.
    pub fn val(self) -> f32 {
        f32::from_bits(self.0 as u32)
    }
}

/// The scheduled stream for one HBM channel: `beats[cycle][pe]`.
#[derive(Debug, Clone)]
pub struct ChannelStream {
    /// One beat per scheduled cycle: 8 packed nnz slots.
    pub beats: Vec<[PackedNnz; PES_PER_CHANNEL]>,
}

/// One (row-window, col-window) tile's worth of scheduled streams, plus
/// the window origins needed to reconstruct absolute indices.
#[derive(Debug, Clone)]
pub struct TileStream {
    /// First absolute row of the tile's row window.
    pub row_base: u32,
    /// First absolute column of the tile's col window.
    pub col_base: u32,
    /// The 16 per-channel scheduled streams.
    pub channels: Vec<ChannelStream>,
}

/// All tiles of a matrix, in processing order, plus stream statistics.
#[derive(Debug, Clone)]
pub struct NnzStream {
    /// Matrix dimension.
    pub n: usize,
    /// Tiles in processing order.
    pub tiles: Vec<TileStream>,
    /// Real non-zeros packed (== matrix nnz).
    pub nnz: usize,
    /// Total beat slots including padding NOPs.
    pub slots: usize,
    /// Dependency distance the scheduler enforced.
    pub dep_dist: usize,
}

impl NnzStream {
    /// Padding overhead: slots / nnz (1.0 == perfect packing).
    pub fn padding_factor(&self) -> f64 {
        self.slots as f64 / self.nnz.max(1) as f64
    }

    /// SpMV cycles for the cycle model: the longest channel in each tile,
    /// summed over tiles (channels in a tile run in lockstep off HBM).
    pub fn cycles(&self) -> u64 {
        self.tiles
            .iter()
            .map(|t| t.channels.iter().map(|c| c.beats.len()).max().unwrap_or(0) as u64)
            .sum()
    }

    /// Replay the scheduled streams: y = A x in Mix-V3 arithmetic
    /// (f32 value upcast to f64, f64 x / y).  Used by tests to prove the
    /// scheduler is a *permutation with padding* of the matrix and by
    /// the module-level SpMV (modules::compute::SpMvModule).
    pub fn replay_mixv3(&self, x: &[f64], y: &mut [f64]) {
        y.iter_mut().for_each(|v| *v = 0.0);
        for tile in &self.tiles {
            for ch in &tile.channels {
                for beat in &ch.beats {
                    for nz in beat {
                        if nz.is_nop() {
                            continue;
                        }
                        let r = (tile.row_base + nz.row_off()) as usize;
                        let c = (tile.col_base + nz.col_off()) as usize;
                        y[r] += nz.val() as f64 * x[c];
                    }
                }
            }
        }
    }

    /// Verify the RAW-hazard guarantee: within any channel, the same
    /// (pe, row) pair never reappears within `dep_dist` beats.  Returns
    /// the first violation if any.
    pub fn check_hazards(&self) -> Option<(usize, usize, u32)> {
        for tile in &self.tiles {
            for ch in &tile.channels {
                for pe in 0..PES_PER_CHANNEL {
                    let mut last_seen: std::collections::HashMap<u32, usize> =
                        std::collections::HashMap::new();
                    for (cyc, beat) in ch.beats.iter().enumerate() {
                        let nz = beat[pe];
                        if nz.is_nop() {
                            continue;
                        }
                        if let Some(&prev) = last_seen.get(&nz.row_off()) {
                            if cyc - prev < self.dep_dist {
                                return Some((pe, cyc, nz.row_off()));
                            }
                        }
                        last_seen.insert(nz.row_off(), cyc);
                    }
                }
            }
        }
        None
    }
}

/// Schedule a CSR matrix into per-channel, per-PE streams with the given
/// hazard distance.  Row `r` is owned by PE `(r / num_channels') % 8`...
/// — concretely: nnz of row r goes to channel `r % NUM_CHANNELS`, PE
/// `(r / NUM_CHANNELS) % PES_PER_CHANNEL`, the Serpens row-interleaving.
pub fn pack_nnz_streams(a: &CsrMatrix, dep_dist: usize) -> NnzStream {
    pack_nnz_streams_cfg(a, dep_dist, NUM_CHANNELS, PES_PER_CHANNEL)
}

/// Configurable variant (tests use small channel counts).
pub fn pack_nnz_streams_cfg(
    a: &CsrMatrix,
    dep_dist: usize,
    num_channels: usize,
    pes: usize,
) -> NnzStream {
    let mut tiles = Vec::new();
    let mut total_slots = 0usize;
    let mut row_base = 0usize;
    while row_base < a.n {
        let row_end = (row_base + ROW_WINDOW).min(a.n);
        let mut col_base = 0usize;
        while col_base < a.n {
            let col_end = (col_base + COL_WINDOW).min(a.n);
            // Gather this tile's nnz into per-(channel, pe) queues.
            let mut queues: Vec<Vec<Vec<PackedNnz>>> =
                vec![vec![Vec::new(); pes]; num_channels];
            let mut tile_nnz = 0usize;
            for r in row_base..row_end {
                let ch = r % num_channels;
                let pe = (r / num_channels) % pes;
                let (cols, vals) = a.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    let c = *c as usize;
                    if c < col_base || c >= col_end {
                        continue;
                    }
                    queues[ch][pe].push(PackedNnz::pack(
                        (c - col_base) as u32,
                        (r - row_base) as u32,
                        *v as f32,
                    ));
                    tile_nnz += 1;
                }
            }
            if tile_nnz == 0 {
                col_base = col_end;
                continue;
            }
            // Out-of-order schedule each (channel, pe) queue.
            let mut channels = Vec::with_capacity(num_channels);
            for chq in queues {
                let lanes: Vec<Vec<PackedNnz>> = chq
                    .into_iter()
                    .map(|q| schedule_lane(q, dep_dist))
                    .collect();
                let len = lanes.iter().map(Vec::len).max().unwrap_or(0);
                let mut beats = vec![[PackedNnz::NOP; PES_PER_CHANNEL]; len];
                for (pe, lane) in lanes.iter().enumerate() {
                    for (cyc, nz) in lane.iter().enumerate() {
                        beats[cyc][pe] = *nz;
                    }
                }
                total_slots += len * pes;
                channels.push(ChannelStream { beats });
            }
            tiles.push(TileStream {
                row_base: row_base as u32,
                col_base: col_base as u32,
                channels,
            });
            col_base = col_end;
        }
        row_base = row_end;
    }
    NnzStream { n: a.n, tiles, nnz: a.nnz(), slots: total_slots, dep_dist }
}

/// Greedy out-of-order scheduler for one PE lane: each cycle pick the
/// earliest queued nnz whose row was not issued in the last `dep_dist`
/// cycles; emit a NOP if none qualifies.  A sliding window over at most
/// `LOOKAHEAD` queue entries bounds the search (the FPGA uses a small
/// reorder window for the same reason).
fn schedule_lane(queue: Vec<PackedNnz>, dep_dist: usize) -> Vec<PackedNnz> {
    const LOOKAHEAD: usize = 32;
    let mut out = Vec::with_capacity(queue.len());
    let mut pending: std::collections::VecDeque<PackedNnz> = queue.into();
    // §Perf: the hazard check only needs the rows issued in the last
    // dep_dist cycles — a small ring buffer beats a HashMap of every
    // row ever issued (this function dominates stream-packing time).
    let mut recent: Vec<u32> = vec![u32::MAX; dep_dist.max(1)];
    let mut cycle = 0usize;
    while !pending.is_empty() {
        let mut issued = false;
        for k in 0..pending.len().min(LOOKAHEAD) {
            let row = pending[k].row_off();
            if !recent.contains(&row) {
                let nz = pending.remove(k).unwrap();
                let slot = cycle % recent.len();
                recent[slot] = row;
                out.push(nz);
                issued = true;
                break;
            }
        }
        if !issued {
            let slot = cycle % recent.len();
            recent[slot] = u32::MAX;
            out.push(PackedNnz::NOP);
        }
        cycle += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth;

    #[test]
    fn pack_roundtrip() {
        let nz = PackedNnz::pack(1234, 99999, -3.25);
        assert_eq!(nz.col_off(), 1234);
        assert_eq!(nz.row_off(), 99999);
        assert_eq!(nz.val(), -3.25);
        assert!(!nz.is_nop());
        assert!(PackedNnz::NOP.is_nop());
    }

    #[test]
    fn replay_matches_reference_spmv() {
        let a = synth::banded_spd(500, 5000, 1e-2, 1);
        let stream = pack_nnz_streams(&a, DEP_DIST_SERPENS);
        let x: Vec<f64> = (0..a.n).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut y = vec![0.0; a.n];
        stream.replay_mixv3(&x, &mut y);
        // Reference: f32-rounded values, f64 arithmetic (Mix-V3).
        let mut want = vec![0.0; a.n];
        for i in 0..a.n {
            let (cols, vals) = a.row(i);
            for (c, v) in cols.iter().zip(vals) {
                want[i] += (*v as f32) as f64 * x[*c as usize];
            }
        }
        for i in 0..a.n {
            assert!(
                (y[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                "row {i}: {} vs {}",
                y[i],
                want[i]
            );
        }
    }

    #[test]
    fn scheduler_respects_hazard_distance() {
        let a = synth::laplace2d_shifted(2500, 0.1);
        for dep in [DEP_DIST_SERPENS, DEP_DIST_XCGSOLVER] {
            let stream = pack_nnz_streams(&a, dep);
            assert_eq!(stream.check_hazards(), None, "dep={dep}");
        }
    }

    #[test]
    fn all_nnz_present_exactly_once() {
        let a = synth::banded_spd(300, 3000, 1e-2, 2);
        let stream = pack_nnz_streams(&a, DEP_DIST_SERPENS);
        let count: usize = stream
            .tiles
            .iter()
            .flat_map(|t| &t.channels)
            .flat_map(|c| &c.beats)
            .flat_map(|b| b.iter())
            .filter(|nz| !nz.is_nop())
            .count();
        assert_eq!(count, a.nnz());
    }

    #[test]
    fn longer_dep_distance_pads_more() {
        // §7.5.1: XcgSolver's FP-latency padding costs more slots than
        // Serpens' load-store distance.
        let a = synth::banded_spd(2000, 10_000, 1e-2, 3);
        let serpens = pack_nnz_streams(&a, DEP_DIST_SERPENS);
        let xcg = pack_nnz_streams(&a, DEP_DIST_XCGSOLVER);
        assert!(xcg.padding_factor() >= serpens.padding_factor());
        assert!(xcg.cycles() >= serpens.cycles());
    }

    #[test]
    fn multi_window_matrix_tiles_correctly() {
        // n > COL_WINDOW forces multiple column windows.
        let n = COL_WINDOW + 1000;
        let a = synth::laplace2d_shifted(n, 0.2);
        let stream = pack_nnz_streams(&a, DEP_DIST_SERPENS);
        assert!(stream.tiles.len() >= 2, "expected >=2 tiles, got {}", stream.tiles.len());
        let x: Vec<f64> = (0..a.n).map(|i| ((i % 7) as f64) - 3.0).collect();
        let mut y = vec![0.0; a.n];
        stream.replay_mixv3(&x, &mut y);
        let mut want = vec![0.0; a.n];
        for i in 0..a.n {
            let (cols, vals) = a.row(i);
            for (c, v) in cols.iter().zip(vals) {
                want[i] += (*v as f32) as f64 * x[*c as usize];
            }
        }
        for i in 0..a.n {
            assert!((y[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0));
        }
    }
}
