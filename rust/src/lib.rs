//! # Callipepla — stream-centric ISA + mixed-precision JPCG solver
//!
//! Reproduction of *Callipepla: Stream Centric Instruction Set and Mixed
//! Precision for Accelerating Conjugate Gradient Solver* (Song et al.,
//! FPGA '23) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper's FPGA is replaced by two orthogonal planes (DESIGN.md §5),
//! both driven by **one compiled instruction program** ([`program`]):
//!
//! * a **value plane** that runs the JPCG numerics for real — the
//!   [`coordinator`] dispatches the compiled Type-I/II/III steps through
//!   an instruction bus to a native interpreter ([`solver`] numerics,
//!   accelerated by the parallel execution [`engine`]) or to AOT-compiled
//!   JAX/Pallas HLO artifacts executed by the PJRT CPU client
//!   (`runtime`, behind the off-by-default `pjrt` feature);
//! * a **time plane** — a cycle-approximate model of the U280 HBM
//!   accelerator ([`hbm`], [`sim`]) whose phase graphs are *derived from
//!   the same compiled program* (`Dataflow::from_program`), so the two
//!   planes cannot drift.
//!
//! Layer map:
//!
//! | Layer | Where | Paper section |
//! |---|---|---|
//! | telemetry plane | [`obs`] (metric registry + catalog, deterministic event trace, Prometheus/JSON exposition) | §7 measurement discipline |
//! | service layer | [`service`] (matrix registry, bucketed program cache, coalescing batch scheduler) | serving extension of §4 |
//! | L3 coordinator | [`coordinator`] (controller + native interpreter) | §3, §4.3, Fig. 4 |
//! | instruction program | [`program`] (HBM memory map, compiled trips, bus), [`isa`], [`modules`], [`vsr`] | §4–§5 |
//! | time plane | [`sim`] (graphs derived from the program), [`hbm`] | §5.6–§5.7, §7 |
//! | execution engine | [`engine`] (nnz-balanced parallel SpMV, prepared-matrix batch solves) | §6 / Fig. 8 analogue |
//! | L2 JAX model | `python/compile/model.py` | Alg. 1 / Fig. 5 phases |
//! | L1 Pallas kernels | `python/compile/kernels/` | §6 mixed-precision SpMV |
//! | runtime | `runtime` (xla crate / PJRT, feature `pjrt`) | — |
//!
//! Since PR 3 the program layer is **multi-RHS**:
//! [`Program`](program::Program) compiles batched trips — one instruction stream vectorized over a `BatchId`
//! lane axis with per-RHS scalar slots and per-RHS converged exit — and
//! `PreparedMatrix::solve_batch` routes whole batches through
//! `Coordinator::solve_batch` on that one path (bitwise-identical per
//! RHS to lone [`jpcg_solve`] calls).  Since PR 4 the [`service`]
//! layer turns that into a serving system: a matrix registry, a
//! bucketed compiled-program cache, and a coalescing batch scheduler
//! on a persistent worker pool (`callipepla serve`, `docs/SERVICE.md`).
//! Since PR 5 batched dispatch is **lane-parallel**:
//! `Coordinator::solve_batch_parallel` fans each trip's per-lane
//! instruction streams across pool workers with trip barriers
//! preserved — bitwise identical to the sequential lane walk, which
//! remains the oracle (`PERF.md` §9).
//! Since PR 6 the batched SpMV is **true block-CG**: the matrix
//! streams once per batched iteration and feeds every live lane from
//! one interleaved lane-major pass
//! (`precision::spmv_scheme_rows_block`), with lane-grouped parallel
//! dots — still bitwise the per-lane walk, with the nnz traffic cut to
//! 1/L per RHS-iteration (`PERF.md` §10).
//! Since PR 7 that lane-major block is the **resident** vector
//! representation: `PreparedMatrix::solve_batch_block[_parallel]`
//! (`CoordinatorConfig::block` = `BlockMode::Resident`) keeps x/r/p/ap
//! in lane-major arenas from program issue to converged exit, runs the
//! vector trips batch-wide through bitwise block kernels
//! (`precision::axpy_block` and friends), and moves **zero** vector
//! elements across the block boundary per steady-state iteration —
//! measured by `precision::stats::vector_element_moves` against the
//! retained staged path (`BlockMode::Staged`, 2·n·L moves/iteration,
//! `PERF.md` §12).
//! Since PR 8 precision is **adaptive and replayable**: the third
//! bound-at-issue scalar is the precision scheme itself — a 3-bit
//! Type-I wire field stamped per lane at issue time — and
//! `precision::adaptive` supplies a deterministic controller
//! ([`precision::adaptive::AdaptivePolicy`]) that starts cheap
//! (Mix-V3), watches each lane's residual history, and escalates to
//! FP64 on stall or near convergence.  Every solve records a
//! [`precision::adaptive::PrecisionTrace`] (pass → scheme + reason)
//! that is serializable and replays bitwise
//! ([`solver::jpcg_solve_replay`]); because decisions are a pure
//! function of the rr sequence, all four dispatch paths emit identical
//! traces (`tests/adaptive_precision.rs`, `docs/PRECISION.md`).
//! Since PR 9 the stack has a unified **telemetry plane** ([`obs`]):
//! a dependency-free metrics registry (the `precision::stats` counter
//! walls now read through it), new instruments across the coordinator,
//! engine pool, program cache, and scheduler, a deterministic
//! event trace stamped with logical clocks (byte-identical across
//! replays — `tests/observability.rs`), and Prometheus/JSON exposition
//! through `serve --metrics-dump` / `--stats-json` and
//! `solve --profile` (`docs/OBSERVABILITY.md`).
//! The complete Type-I/II/III
//! instruction reference, wire encodings, and the batch-axis extension
//! live in `docs/ISA.md`; build/quickstart walkthroughs in the
//! top-level `README.md`.
//!
//! Performance notes (bench methodology, measured numbers, and the
//! bitwise-parallelism invariants) live in `PERF.md` at the repo root.
//!
//! # Quickstart
//!
//! ```
//! use callipepla::{jpcg_solve, SolveOptions};
//! use callipepla::sparse::synth;
//!
//! let a = synth::laplace2d_shifted(400, 0.1);
//! let res = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
//! assert!(res.converged);
//! ```

#![warn(missing_docs)]

pub mod accel;
pub mod bench_harness;
pub mod coordinator;
pub mod engine;
pub mod hbm;
pub mod isa;
pub mod metrics;
pub mod modules;
pub mod obs;
pub mod precision;
pub mod program;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod service;
pub mod sim;
pub mod solver;
pub mod sparse;
pub mod util;
pub mod vsr;

pub use engine::PreparedMatrix;
pub use precision::adaptive::{AdaptivePolicy, PrecisionMode, PrecisionTrace};
pub use precision::Scheme;
pub use solver::{jpcg_solve, SolveOptions, SolveResult};
pub use sparse::CsrMatrix;
