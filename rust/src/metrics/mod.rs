//! Metrics plane: throughput, fraction-of-peak, energy efficiency and
//! geomean aggregation — the quantities of Table 5 (§7.3 definitions:
//! throughput = flops / solver time; energy efficiency = throughput /
//! power; FoP = max throughput / peak throughput).

/// Geometric mean, skipping NaNs (failed cells, like XcgSolver's OOM
/// rows, are excluded the way the paper's geomeans exclude FAIL).
pub fn geomean(values: impl IntoIterator<Item = f64>) -> f64 {
    let mut log_sum = 0.0;
    let mut count = 0usize;
    for v in values {
        if v.is_finite() && v > 0.0 {
            log_sum += v.ln();
            count += 1;
        }
    }
    if count == 0 {
        f64::NAN
    } else {
        (log_sum / count as f64).exp()
    }
}

/// Throughput in GFLOP/s.
pub fn gflops(flops: u64, seconds: f64) -> f64 {
    flops as f64 / seconds / 1e9
}

/// Energy efficiency in GFLOP/J.
pub fn gflops_per_joule(gflops: f64, power_w: f64) -> f64 {
    gflops / power_w
}

/// Fraction of peak, in percent (§7.3: max achieved / peak).
pub fn fraction_of_peak_pct(max_gflops: f64, peak_gflops: f64) -> f64 {
    100.0 * max_gflops / peak_gflops
}

/// Min / max / geomean summary of a metric across the suite.
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    /// Smallest finite value.
    pub min: f64,
    /// Largest finite value.
    pub max: f64,
    /// Geometric mean of the finite values.
    pub geomean: f64,
}

/// Min / max / geomean over the finite entries of `values`.
pub fn summarize(values: &[f64]) -> Summary {
    let finite: Vec<f64> = values.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        return Summary { min: f64::NAN, max: f64::NAN, geomean: f64::NAN };
    }
    Summary {
        min: finite.iter().copied().fold(f64::INFINITY, f64::min),
        max: finite.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        geomean: geomean(finite),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean([1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean([2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_skips_nans_like_fail_cells() {
        assert!((geomean([1.0, f64::NAN, 4.0]) - 2.0).abs() < 1e-12);
        assert!(geomean([f64::NAN]).is_nan());
    }

    #[test]
    fn fop_definition() {
        // Paper: Callipepla max 43.71 GFLOP/s over 410 peak = 10.7%.
        let fop = fraction_of_peak_pct(43.71, 410.0);
        assert!((fop - 10.66).abs() < 0.05, "fop={fop}");
    }

    #[test]
    fn summary_handles_mixed() {
        let s = summarize(&[3.0, 1.0, f64::NAN, 9.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 9.0);
        assert!((s.geomean - 3.0).abs() < 1e-12);
    }
}
