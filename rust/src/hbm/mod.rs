//! HBM channel model (paper §4.2, §5.7, Table 2).
//!
//! The accelerator is bandwidth-matched: eq. (1) f = BW / r.  For a U280
//! (460 GB/s over 32 channels, 512-bit ports) the matching frequency is
//! 225 MHz; Callipepla closed timing at 221 MHz (Table 2), so the cycle
//! model charges one 64-byte beat per channel per cycle at the *achieved*
//! frequency of each accelerator.
//!
//! The double-channel design (§5.7): a read-modify-write vector served by
//! ONE channel pays read + write serially (the channel turns around);
//! with TWO channels in ping-pong (read v_t from ch0 while writing
//! v_{t+1} to ch1, swap next iteration) the read and write overlap and
//! the latency halves while still honouring the inter-iteration
//! dependency.

/// Beat width in bytes (512-bit AXI port, §2.3.3).
pub const BEAT_BYTES: u64 = 64;

/// Channel configuration for one long vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChannelMode {
    /// One channel: read and write serialize (Fig. 7c).
    Single,
    /// Ping-pong pair: read and write overlap (Fig. 7d/e).
    Double,
}

/// Physical HBM + clocking description of an accelerator build.
#[derive(Debug, Clone, Copy)]
pub struct HbmConfig {
    /// Total HBM channels on the part (U280: 32).
    pub channels: usize,
    /// Channels allocated to the SpMV nnz streams (16 on all three
    /// FPGA accelerators).
    pub nnz_channels: usize,
    /// Achieved accelerator frequency in Hz (Table 2).
    pub freq_hz: f64,
    /// Aggregate achievable memory bandwidth in bytes/s (Table 2).
    pub bandwidth_bps: f64,
    /// Vector read-modify-write channel policy.
    pub vector_mode: ChannelMode,
}

impl HbmConfig {
    /// Callipepla build: 221 MHz, 374 GB/s achieved, double channels.
    pub fn callipepla() -> Self {
        Self {
            channels: 32,
            nnz_channels: 16,
            freq_hz: 221e6,
            bandwidth_bps: 374e9,
            vector_mode: ChannelMode::Double,
        }
    }

    /// SerpensCG build: 238 MHz, 345 GB/s, single-channel vectors.
    pub fn serpenscg() -> Self {
        Self {
            channels: 32,
            nnz_channels: 16,
            freq_hz: 238e6,
            bandwidth_bps: 345e9,
            vector_mode: ChannelMode::Single,
        }
    }

    /// XcgSolver build: 250 MHz, 331 GB/s, single-channel vectors.
    pub fn xcgsolver() -> Self {
        Self {
            channels: 32,
            nnz_channels: 16,
            freq_hz: 250e6,
            bandwidth_bps: 331e9,
            vector_mode: ChannelMode::Single,
        }
    }

    /// Eq. (1): the frequency that matches per-channel bandwidth to one
    /// beat per cycle.
    pub fn matching_freq_hz(&self) -> f64 {
        (self.bandwidth_bps / self.channels as f64) / BEAT_BYTES as f64
    }

    /// Cycles to move `bytes` over one channel (one beat per cycle).
    pub fn stream_cycles(&self, bytes: u64) -> u64 {
        bytes.div_ceil(BEAT_BYTES)
    }

    /// Cycles for a vector that is both read and written in one phase,
    /// under the configured channel mode (§5.7): serialized on a single
    /// channel, overlapped on a double channel.
    pub fn rw_vector_cycles(&self, bytes_read: u64, bytes_written: u64) -> u64 {
        let r = self.stream_cycles(bytes_read);
        let w = self.stream_cycles(bytes_written);
        match self.vector_mode {
            ChannelMode::Single => r + w,
            ChannelMode::Double => r.max(w),
        }
    }

    /// Seconds per cycle.
    pub fn cycle_time(&self) -> f64 {
        1.0 / self.freq_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matching_frequency_is_225mhz_on_u280() {
        // §4.2: (460 GB/s / 32) / 64 B = 225 MHz.
        let cfg = HbmConfig { bandwidth_bps: 460e9, ..HbmConfig::callipepla() };
        let f = cfg.matching_freq_hz();
        assert!((f - 224.6e6).abs() < 1e6, "f = {f}");
    }

    #[test]
    fn stream_cycles_rounds_up() {
        let cfg = HbmConfig::callipepla();
        assert_eq!(cfg.stream_cycles(0), 0);
        assert_eq!(cfg.stream_cycles(1), 1);
        assert_eq!(cfg.stream_cycles(64), 1);
        assert_eq!(cfg.stream_cycles(65), 2);
    }

    #[test]
    fn double_channel_halves_rw_latency() {
        // §5.7: "we reduce the memory latency by half".
        let double = HbmConfig::callipepla();
        let single = HbmConfig { vector_mode: ChannelMode::Single, ..double };
        let bytes = 1 << 20;
        assert_eq!(
            single.rw_vector_cycles(bytes, bytes),
            2 * double.rw_vector_cycles(bytes, bytes)
        );
    }

    #[test]
    fn table2_builds_differ_as_specified() {
        assert!(HbmConfig::xcgsolver().freq_hz > HbmConfig::callipepla().freq_hz);
        assert!(HbmConfig::callipepla().bandwidth_bps > HbmConfig::serpenscg().bandwidth_bps);
        assert_eq!(HbmConfig::callipepla().vector_mode, ChannelMode::Double);
    }
}
