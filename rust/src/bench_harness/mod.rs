//! Micro-benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with median/mean/stddev reporting, plus the table
//! printers that regenerate the paper's tables from evaluation sweeps.

pub mod tables;
pub mod timing;

pub use timing::{bench, BenchResult};
