//! Tiny timing harness (criterion is unavailable in this offline
//! environment): warmup, N timed runs, median/mean/min statistics, and
//! a stable one-line report format the bench binaries print.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Timed runs (after warmup).
    pub runs: usize,
    /// Mean seconds per run.
    pub mean_s: f64,
    /// Median seconds per run.
    pub median_s: f64,
    /// Fastest run.
    pub min_s: f64,
    /// Slowest run.
    pub max_s: f64,
}

impl BenchResult {
    /// The stable one-line report the bench binaries print.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>6} runs  mean {:>12}  median {:>12}  min {:>12}",
            self.name,
            self.runs,
            human_time(self.mean_s),
            human_time(self.median_s),
            human_time(self.min_s),
        )
    }
}

/// Human-readable seconds.
pub fn human_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` with `warmup` untimed + `runs` timed invocations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, runs: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(runs);
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchResult {
        name: name.to_string(),
        runs: times.len(),
        mean_s: mean,
        median_s: times[times.len() / 2],
        min_s: times[0],
        max_s: *times.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_runs_and_orders_stats() {
        let mut n = 0u64;
        let r = bench("noop", 2, 11, || {
            n += 1;
            std::hint::black_box(n);
        });
        assert_eq!(n, 13); // 2 warmup + 11 timed
        assert_eq!(r.runs, 11);
        assert!(r.min_s <= r.median_s && r.median_s <= r.max_s);
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2.0).ends_with(" s"));
        assert!(human_time(2e-3).ends_with(" ms"));
        assert!(human_time(2e-6).ends_with(" us"));
        assert!(human_time(2e-9).ends_with(" ns"));
    }
}
