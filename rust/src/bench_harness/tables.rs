//! Table/figure regeneration: the sweeps and printers behind every
//! experiment in DESIGN.md §3 (Tables 3-7, Fig. 9).
//!
//! Everything here is pure library code so the CLI (`callipepla table4`)
//! and the bench binaries share one implementation.

use crate::accel::{self, resources, Accel, EvalResult};
use crate::metrics;
use crate::precision::Scheme;
use crate::solver::{jpcg_solve, SolveOptions, SolveResult};
use crate::sparse::{suite36, CsrMatrix, MatrixSpec};

/// One matrix's evaluation across all four accelerators.
pub struct MatrixEval {
    /// The Table-3 row evaluated.
    pub spec: MatrixSpec,
    /// Generated dimension (after scaling).
    pub n: usize,
    /// Generated nnz (after scaling).
    pub nnz: usize,
    /// CPU FP64 golden iteration count (Table 7 reference row).
    pub cpu_iters: u32,
    /// One [`EvalResult`] per accelerator, in [`Accel::ALL`] order.
    pub results: Vec<EvalResult>,
}

/// Sweep configuration.
#[derive(Debug, Clone, Copy)]
pub struct SweepConfig {
    /// Matrix scale factor (1.0 == paper-size, DESIGN.md §Hardware-Adaptation).
    pub scale: f64,
    /// Iteration cap (paper: 20 000).
    pub max_iters: u32,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self { scale: 0.02, max_iters: 20_000 }
    }
}

/// Evaluate one matrix on all accelerators (+ CPU golden).  The five
/// value-plane solves are independent, so they run on scoped threads.
pub fn eval_matrix(spec: &MatrixSpec, cfg: &SweepConfig) -> MatrixEval {
    let a = spec.generate(cfg.scale);
    let mut cpu_opts = SolveOptions::default();
    cpu_opts.max_iters = cfg.max_iters;
    let (cpu, results) = std::thread::scope(|s| {
        let cpu_h = s.spawn(|| jpcg_solve(&a, None, None, &cpu_opts));
        let handles: Vec<_> = Accel::ALL
            .into_iter()
            .map(|acc| {
                let a = &a;
                s.spawn(move || {
                    if acc.fails_oom_dims(spec.n, spec.nnz) {
                        // FAIL at paper scale (Table 4): reported even
                        // when the bench matrix is scaled down.
                        return accel::fail_result(acc);
                    }
                    let mut opts = acc.solve_options();
                    opts.max_iters = cfg.max_iters;
                    let solve = jpcg_solve(a, None, None, &opts);
                    // Value plane on the scaled matrix; time plane at
                    // paper-scale dims (see accel::evaluate_dims).
                    accel::evaluate_dims(acc, spec.n, spec.nnz, &solve)
                })
            })
            .collect();
        (
            cpu_h.join().expect("cpu solve"),
            handles.into_iter().map(|h| h.join().expect("accel solve")).collect::<Vec<_>>(),
        )
    });
    MatrixEval { spec: spec.clone(), n: a.n, nnz: a.nnz(), cpu_iters: cpu.iters, results }
}

/// Evaluate a subset (or all) of the 36-matrix suite.
pub fn eval_suite(ids: &[String], cfg: &SweepConfig) -> Vec<MatrixEval> {
    suite36()
        .iter()
        .filter(|s| ids.is_empty() || ids.iter().any(|i| i.eq_ignore_ascii_case(s.id)))
        .map(|s| eval_matrix(s, cfg))
        .collect()
}

fn by_accel<'e>(e: &'e MatrixEval, a: Accel) -> &'e EvalResult {
    e.results.iter().find(|r| r.accel == a).unwrap()
}

// ------------------------------------------------------------------ T3

/// Table 3: the benchmark-suite listing (id, stand-in name, n, nnz).
pub fn print_table3() -> String {
    let mut out = String::from(
        "Table 3: evaluated matrices (synthetic stand-ins; paper dims at scale=1.0)\n",
    );
    out.push_str(&format!("{:<5} {:<16} {:>10} {:>12} {:>10} {:>10}\n",
        "ID", "Matrix", "#Row", "NNZ", "CPU iters", "kind"));
    for s in suite36() {
        out.push_str(&format!(
            "{:<5} {:<16} {:>10} {:>12} {:>10} {:>10?}\n",
            s.id, s.paper_name, s.n, s.nnz, s.cpu_iters, s.kind
        ));
    }
    out
}

// ------------------------------------------------------------------ T4

/// Table 4: solver time per accelerator, with speedup vs XcgSolver.
pub fn print_table4(evals: &[MatrixEval]) -> String {
    let mut out = String::from("Table 4: solver time (s) and speedup vs XcgSolver\n");
    out.push_str(&format!(
        "{:<5} {:>12} {:>12} {:>8} {:>12} {:>8} {:>12} {:>8}\n",
        "ID", "XcgSolver", "SerpensCG", "spd", "Callipepla", "spd", "A100", "spd"
    ));
    let mut spd = vec![Vec::new(); 3];
    for e in evals {
        let xcg = by_accel(e, Accel::XcgSolver);
        let base = xcg.solver_seconds;
        let row: Vec<&EvalResult> =
            [Accel::SerpensCG, Accel::Callipepla, Accel::A100].iter().map(|&a| by_accel(e, a)).collect();
        let fmt_t = |r: &EvalResult| {
            if r.failed { "FAIL".to_string() } else { format!("{:.3e}", r.solver_seconds) }
        };
        let fmt_s = |r: &EvalResult| {
            if r.failed || xcg.failed {
                "-".to_string()
            } else {
                format!("{:.3}x", base / r.solver_seconds)
            }
        };
        out.push_str(&format!(
            "{:<5} {:>12} {:>12} {:>8} {:>12} {:>8} {:>12} {:>8}\n",
            e.spec.id,
            fmt_t(xcg),
            fmt_t(row[0]),
            fmt_s(row[0]),
            fmt_t(row[1]),
            fmt_s(row[1]),
            fmt_t(row[2]),
            fmt_s(row[2]),
        ));
        if !xcg.failed {
            for (k, r) in row.iter().enumerate() {
                if !r.failed {
                    spd[k].push(base / r.solver_seconds);
                }
            }
        }
    }
    out.push_str(&format!(
        "GeoMean speedup vs XcgSolver:  SerpensCG {:.3}x  Callipepla {:.3}x  A100 {:.3}x\n",
        metrics::geomean(spd[0].iter().copied()),
        metrics::geomean(spd[1].iter().copied()),
        metrics::geomean(spd[2].iter().copied()),
    ));
    out
}

// ------------------------------------------------------------------ T5

/// Table 5: throughput, fraction of peak, energy efficiency.
pub fn print_table5(evals: &[MatrixEval]) -> String {
    let mut out =
        String::from("Table 5: throughput (GFLOP/s), fraction of peak, energy eff. (GFLOP/J)\n");
    out.push_str(&format!(
        "{:<12} {:>8} {:>8} {:>8} {:>9} {:>7} | {:>9} {:>9} {:>9}\n",
        "Accel", "Peak", "Min", "Max", "GeoMean", "FoP%", "eff.Min", "eff.Max", "eff.GeoM"
    ));
    for acc in Accel::ALL {
        let spec = acc.spec();
        let g: Vec<f64> = evals
            .iter()
            .map(|e| by_accel(e, acc))
            .filter(|r| !r.failed)
            .map(|r| r.gflops)
            .collect();
        let eff: Vec<f64> = evals
            .iter()
            .map(|e| by_accel(e, acc))
            .filter(|r| !r.failed)
            .map(|r| r.gflops_per_joule)
            .collect();
        let gs = metrics::summarize(&g);
        let es = metrics::summarize(&eff);
        out.push_str(&format!(
            "{:<12} {:>8.0} {:>8.2} {:>8.2} {:>9.2} {:>6.2}% | {:>9.3e} {:>9.3e} {:>9.3e}\n",
            acc.name(),
            spec.peak_gflops,
            gs.min,
            gs.max,
            gs.geomean,
            metrics::fraction_of_peak_pct(gs.max, spec.peak_gflops),
            es.min,
            es.max,
            es.geomean,
        ));
    }
    out
}

// ------------------------------------------------------------------ T6

/// Table 6: FPGA resource utilization (derived + measured rows).
pub fn print_table6() -> String {
    let mut out = String::from("Table 6: FPGA resource utilization on the U280\n");
    for name in ["XcgSolver", "SerpensCG", "Callipepla"] {
        let r = resources::measured(name);
        let u = r.utilization();
        out.push_str(&format!(
            "{:<12} LUT {:>7} ({:>4.1}%)  FF {:>7} ({:>4.1}%)  DSP {:>5} ({:>4.1}%)  BRAM {:>4} ({:>4.1}%)  URAM {:>4} ({:>4.1}%)\n",
            name, u[0].1, u[0].2, u[1].1, u[1].2, u[2].1, u[2].2, u[3].1, u[3].2, u[4].1, u[4].2
        ));
    }
    let d = resources::callipepla_build();
    let u = d.utilization();
    out.push_str(&format!(
        "{:<12} LUT {:>7} ({:>4.1}%)  FF {:>7} ({:>4.1}%)  DSP {:>5} ({:>4.1}%)  BRAM {:>4} ({:>4.1}%)  URAM {:>4} ({:>4.1}%)\n",
        "(derived)", u[0].1, u[0].2, u[1].1, u[1].2, u[2].1, u[2].2, u[3].1, u[3].2, u[4].1, u[4].2
    ));
    out
}

// ------------------------------------------------------------------ T7

/// Table 7: iteration counts vs the CPU golden reference.
pub fn print_table7(evals: &[MatrixEval]) -> String {
    let mut out = String::from("Table 7: iteration counts and difference to CPU\n");
    out.push_str(&format!(
        "{:<5} {:>8} {:>10} {:>8} {:>11} {:>8} {:>9} {:>8}\n",
        "ID", "CPU", "XcgSolver", "diff", "Callipepla", "diff", "A100", "diff"
    ));
    for e in evals {
        let xcg = by_accel(e, Accel::XcgSolver);
        let cal = by_accel(e, Accel::Callipepla);
        let gpu = by_accel(e, Accel::A100);
        let diff = |r: &EvalResult| {
            if r.failed {
                "-".to_string()
            } else {
                format!("{:+}", r.iters as i64 - e.cpu_iters as i64)
            }
        };
        let it = |r: &EvalResult| {
            if r.failed { "FAIL".to_string() } else { r.iters.to_string() }
        };
        out.push_str(&format!(
            "{:<5} {:>8} {:>10} {:>8} {:>11} {:>8} {:>9} {:>8}\n",
            e.spec.id,
            e.cpu_iters,
            it(xcg),
            diff(xcg),
            it(cal),
            diff(cal),
            it(gpu),
            diff(gpu),
        ));
    }
    out
}

// ------------------------------------------------------------------ F9

/// Fig. 9: residual traces for one matrix under the five settings
/// (FP64, Mix-V1/V2/V3, Callipepla on-board == MixV3 + delay-buffer +
/// out-of-order).  Returns (label, csv) pairs.
pub fn fig9_traces(a: &CsrMatrix, max_iters: u32) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let run = |opts: SolveOptions| -> SolveResult {
        let opts = SolveOptions { record_trace: true, max_iters, ..opts };
        jpcg_solve(a, None, None, &opts)
    };
    let fp64 = run(SolveOptions::default());
    out.push(("fp64".to_string(), fp64.trace.to_csv(2000)));
    for scheme in [Scheme::MixV1, Scheme::MixV2, Scheme::MixV3] {
        let res = run(SolveOptions { scheme, ..SolveOptions::default() });
        out.push((scheme.name().to_string(), res.trace.to_csv(2000)));
    }
    let onboard = run(SolveOptions::callipepla());
    out.push(("callipepla_onboard".to_string(), onboard.trace.to_csv(2000)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth;

    fn quick_cfg() -> SweepConfig {
        SweepConfig { scale: 0.01, max_iters: 600 }
    }

    #[test]
    fn eval_suite_filters_by_id() {
        let evals = eval_suite(&["M4".to_string()], &quick_cfg());
        assert_eq!(evals.len(), 1);
        assert_eq!(evals[0].spec.id, "M4");
        assert_eq!(evals[0].results.len(), 4);
    }

    #[test]
    fn table4_reports_speedups_in_paper_direction() {
        let evals = eval_suite(&["M4".to_string(), "M3".to_string()], &quick_cfg());
        for e in &evals {
            let xcg = by_accel(e, Accel::XcgSolver);
            let cal = by_accel(e, Accel::Callipepla);
            assert!(cal.solver_seconds < xcg.solver_seconds, "{}", e.spec.id);
        }
        let txt = print_table4(&evals);
        assert!(txt.contains("GeoMean"));
    }

    #[test]
    fn table7_callipepla_tracks_cpu_closely() {
        let evals = eval_suite(&["M4".to_string()], &quick_cfg());
        let e = &evals[0];
        let cal = by_accel(e, Accel::Callipepla);
        assert!((cal.iters as i64 - e.cpu_iters as i64).abs() <= 3);
        let xcg = by_accel(e, Accel::XcgSolver);
        assert!(xcg.iters >= e.cpu_iters);
    }

    #[test]
    fn fig9_traces_have_five_settings() {
        let a = synth::banded_spd(800, 6_000, 1e-4, 51);
        let traces = fig9_traces(&a, 400);
        assert_eq!(traces.len(), 5);
        let labels: Vec<&str> = traces.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, ["fp64", "mixv1", "mixv2", "mixv3", "callipepla_onboard"]);
        for (_, csv) in &traces {
            assert!(csv.starts_with("iter,rr\n"));
            assert!(csv.lines().count() > 2);
        }
    }

    #[test]
    fn printers_do_not_panic_on_static_tables() {
        assert!(print_table3().contains("Flan_1565"));
        assert!(print_table6().contains("Callipepla"));
    }
}
