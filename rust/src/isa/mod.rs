//! The stream-centric instruction set (paper §4, Fig. 2).
//!
//! Three instruction types, all bit-packed exactly as the HLS structs:
//!
//! * **Type-I** `InstVCtrl` — tells a vector-control module whether to
//!   read/write a vector, where it lives in memory, its length, which
//!   destination module receives the stream (`q_id`, 3 bits), and which
//!   precision [`Scheme`] the trip decodes (3 bits, bound at issue time
//!   like alpha/beta — the adaptive-precision scalar of PR 8).
//! * **Type-II** `InstCmp` — triggers one computation module: vector
//!   length, a double-precision scalar (the only operand a module ever
//!   needs — modules are single-function, so there is no opcode), and
//!   the destination `q_id` for the output stream.
//! * **Type-III** `InstRdWr` — issued by a vector-control module to its
//!   memory module: read/write flags, base address, length.
//!
//! The design principles (§2.3.1): every instruction processes streams;
//! a module either produces or consumes streams; memory is decoupled
//! from compute so prefetching overlaps execution.


use crate::precision::Scheme;
use std::fmt;

/// Destination-queue index (ap_uint<3> in the HLS source).
pub type QId = u8;

/// Type-I: vector control instruction (Fig. 2 plus the precision
/// scalar).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InstVCtrl {
    /// Stream the vector in from memory this trip.
    pub rd: bool,
    /// Write the vector back to memory this trip.
    pub wr: bool,
    /// Base address in 64-byte beats (channel window + offset).
    pub base_addr: u32,
    /// Vector length in elements.
    pub len: u32,
    /// Destination module queue for the read stream.
    pub q_id: QId,
    /// Precision scheme the trip decodes, bound at issue time like
    /// alpha/beta (`Scheme::wire_code`, 3-bit field; codes 4..=7 are
    /// reserved and make [`InstVCtrl::decode`] fail explicitly).
    pub precision: Scheme,
}

/// A wire word whose bit pattern is not a valid instruction — today
/// that means a reserved code in the Type-I precision field.  Decoding
/// must surface this explicitly (never panic): traces and cross-tool
/// dumps are external inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeError {
    /// The reserved 3-bit precision code encountered (4..=7).
    pub precision_code: u8,
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "reserved Type-I precision code {} (valid: 0..=3)", self.precision_code)
    }
}

impl std::error::Error for DecodeError {}

/// Type-II: computation instruction (3 fields, Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstCmp {
    /// Stream length in elements.
    pub len: u32,
    /// The `double alpha` field: alpha for M3/M4, beta for M7, unused 0.0
    /// for the dot/divide modules.
    pub alpha: f64,
    /// Destination module queue for the output stream.
    pub q_id: QId,
}

/// Type-III: memory instruction (4 fields, Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InstRdWr {
    /// Read transfer.
    pub rd: bool,
    /// Write transfer.
    pub wr: bool,
    /// Base address in 64-byte beats.
    pub base_addr: u32,
    /// Transfer length in elements.
    pub len: u32,
}

/// Any instruction, for traces and the issue queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Instruction {
    /// A Type-I vector-control word.
    VCtrl(InstVCtrl),
    /// A Type-II computation word.
    Cmp(InstCmp),
    /// A Type-III memory word.
    RdWr(InstRdWr),
}

// ---------------------------------------------------------------------
// Bit-exact encodings.  The HLS structs are flat bit concatenations; we
// pack into u128 little-end-first in field order so the Rust encoding is
// a stable wire format for traces and golden tests.
//
//   InstVCtrl: rd:1 | wr:1 | base_addr:32 | len:32 | q_id:3
//              | precision:3                                  (72 bits)
//   InstCmp:   len:32 | alpha:64 | q_id:3                     (99 bits)
//   InstRdWr:  rd:1 | wr:1 | base_addr:32 | len:32            (66 bits)
//
// The precision field was appended in PR 8 (the adaptive-precision
// scalar).  Scheme::Fp64 encodes as 0, so a pre-PR-8 69-bit Type-I
// word decodes unchanged as an Fp64 trip; codes 4..=7 are reserved and
// decode to an explicit DecodeError.
// ---------------------------------------------------------------------

impl InstVCtrl {
    /// Pack into the 72-bit wire word (see the layout table above).
    pub fn encode(&self) -> u128 {
        (self.rd as u128)
            | (self.wr as u128) << 1
            | (self.base_addr as u128) << 2
            | (self.len as u128) << 34
            | (self.q_id as u128 & 0b111) << 66
            | (self.precision.wire_code() as u128) << 69
    }

    /// Unpack a 72-bit wire word.  Fails — explicitly, never by panic —
    /// on the reserved precision codes 4..=7.
    pub fn decode(bits: u128) -> Result<Self, DecodeError> {
        let code = (bits >> 69 & 0b111) as u8;
        let precision =
            Scheme::from_wire_code(code).ok_or(DecodeError { precision_code: code })?;
        Ok(Self {
            rd: bits & 1 != 0,
            wr: bits >> 1 & 1 != 0,
            base_addr: (bits >> 2) as u32,
            len: (bits >> 34) as u32,
            q_id: (bits >> 66 & 0b111) as u8,
            precision,
        })
    }
}

impl InstCmp {
    /// Pack into the 99-bit wire word (alpha as raw IEEE-754 bits).
    pub fn encode(&self) -> u128 {
        (self.len as u128)
            | (self.alpha.to_bits() as u128) << 32
            | (self.q_id as u128 & 0b111) << 96
    }

    /// Unpack a 99-bit wire word (alpha bits preserved exactly).
    pub fn decode(bits: u128) -> Self {
        Self {
            len: bits as u32,
            alpha: f64::from_bits((bits >> 32) as u64),
            q_id: (bits >> 96 & 0b111) as u8,
        }
    }
}

impl InstRdWr {
    /// Pack into the 66-bit wire word.
    pub fn encode(&self) -> u128 {
        (self.rd as u128)
            | (self.wr as u128) << 1
            | (self.base_addr as u128) << 2
            | (self.len as u128) << 34
    }

    /// Unpack a 66-bit wire word.
    pub fn decode(bits: u128) -> Self {
        Self {
            rd: bits & 1 != 0,
            wr: bits >> 1 & 1 != 0,
            base_addr: (bits >> 2) as u32,
            len: (bits >> 34) as u32,
        }
    }
}

/// Memory-write response (§4.2 "Scalar and memory response"): memory
/// modules acknowledge completed writes so the controller can maintain
/// consistency when modules read vectors another module just wrote.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemResponse {
    /// Beat address of the completed write.
    pub base_addr: u32,
    /// Elements written.
    pub len: u32,
}

/// Recorded instruction issue, for the time plane and for debugging.
///
/// Targets are interned `&'static str` ids (module names and the fixed
/// vector-control / memory module names baked into the compiled
/// program), so recording an instruction never allocates — a
/// long instruction-recorded solve costs one `Vec` push per issue.
#[derive(Debug, Clone, Default)]
pub struct InstTrace {
    /// (target module, instruction) pairs, in issue order.
    pub issued: Vec<(&'static str, Instruction)>,
}

impl InstTrace {
    /// Append one issued instruction.
    pub fn record(&mut self, target: &'static str, inst: Instruction) {
        self.issued.push((target, inst));
    }

    /// Number of instructions issued to `target`.
    pub fn count_for(&self, target: &str) -> usize {
        self.issued.iter().filter(|(t, _)| *t == target).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vctrl_roundtrip() {
        for precision in Scheme::ALL {
            let i = InstVCtrl {
                rd: true,
                wr: false,
                base_addr: 0xDEAD_BEEF,
                len: 1_000_000,
                q_id: 5,
                precision,
            };
            assert_eq!(InstVCtrl::decode(i.encode()), Ok(i));
        }
    }

    #[test]
    fn cmp_roundtrip_preserves_alpha_bits() {
        for alpha in [0.0, -0.0, 1.5e-300, f64::MAX, std::f64::consts::PI] {
            let i = InstCmp { len: 7, alpha, q_id: 3 };
            let d = InstCmp::decode(i.encode());
            assert_eq!(d.alpha.to_bits(), alpha.to_bits());
            assert_eq!(d.len, 7);
            assert_eq!(d.q_id, 3);
        }
    }

    #[test]
    fn rdwr_roundtrip() {
        let i = InstRdWr { rd: true, wr: true, base_addr: 42, len: 9 };
        assert_eq!(InstRdWr::decode(i.encode()), i);
    }

    #[test]
    fn qid_is_three_bits() {
        let i = InstVCtrl {
            rd: false,
            wr: false,
            base_addr: 0,
            len: 0,
            q_id: 7,
            precision: Scheme::Fp64,
        };
        assert_eq!(InstVCtrl::decode(i.encode()).unwrap().q_id, 7);
    }

    #[test]
    fn reserved_precision_codes_are_an_explicit_decode_error() {
        // Codes 4..=7 of the precision field are not schemes: decode
        // must return Err (never panic) and name the offending code.
        let base = InstVCtrl {
            rd: true,
            wr: false,
            base_addr: 0xDEAD_BEEF,
            len: 1_000_000,
            q_id: 5,
            precision: Scheme::Fp64,
        }
        .encode();
        for code in 4u8..=7 {
            let w = base | (code as u128) << 69;
            let err = InstVCtrl::decode(w).unwrap_err();
            assert_eq!(err, DecodeError { precision_code: code });
            assert!(err.to_string().contains(&code.to_string()));
        }
    }

    #[test]
    fn legacy_69_bit_words_decode_as_fp64_trips() {
        // Scheme::Fp64 has wire code 0, so every pre-precision-field
        // Type-I word is still a valid 72-bit word meaning "fp64 trip".
        let legacy = 0x14003d09037ab6fbbd_u128; // pre-PR-8 golden
        let d = InstVCtrl::decode(legacy).unwrap();
        assert_eq!(d.precision, Scheme::Fp64);
        assert_eq!(d.encode(), legacy);
    }

    // ------------------------------------------------------------------
    // Golden wire-format fixtures: the u128 bit patterns below pin the
    // encoding as a *stable contract* (trace files, cross-tool dumps),
    // not merely a round-trip-consistent one.  If any of these change,
    // the wire format changed — bump consumers deliberately.
    // ------------------------------------------------------------------

    #[test]
    fn golden_vctrl_encodings() {
        // precision = Fp64 (code 0) leaves the pre-PR-8 words intact...
        let read_only = InstVCtrl {
            rd: true,
            wr: false,
            base_addr: 0xDEAD_BEEF,
            len: 1_000_000,
            q_id: 5,
            precision: Scheme::Fp64,
        };
        assert_eq!(read_only.encode(), 0x14003d09037ab6fbbd_u128);
        // ...and the Mix codes land in bits 69..72.
        let mixv3 = InstVCtrl { precision: Scheme::MixV3, ..read_only };
        assert_eq!(mixv3.encode(), 0x74003d09037ab6fbbd_u128);
        let mixv1 = InstVCtrl { precision: Scheme::MixV1, ..read_only };
        assert_eq!(mixv1.encode(), 0x34003d09037ab6fbbd_u128);
        let read_write = InstVCtrl {
            rd: true,
            wr: true,
            base_addr: 0x0600_0000,
            len: 16_384,
            q_id: 2,
            precision: Scheme::MixV2,
        };
        assert_eq!(read_write.encode(), 0x480001000018000003_u128);
        assert_eq!(InstVCtrl::decode(0x14003d09037ab6fbbd_u128), Ok(read_only));
        assert_eq!(InstVCtrl::decode(0x74003d09037ab6fbbd_u128), Ok(mixv3));
        assert_eq!(InstVCtrl::decode(0x480001000018000003_u128), Ok(read_write));
    }

    #[test]
    fn golden_cmp_encodings() {
        let unit = InstCmp { len: 16_384, alpha: 1.0, q_id: 0 };
        assert_eq!(unit.encode(), 0x3ff000000000000000004000_u128);
        let neg_half = InstCmp { len: 7, alpha: -0.5, q_id: 3 };
        assert_eq!(neg_half.encode(), 0x3bfe000000000000000000007_u128);
        let pi = InstCmp { len: 4096, alpha: std::f64::consts::PI, q_id: 6 };
        assert_eq!(pi.encode(), 0x6400921fb54442d1800001000_u128);
        assert_eq!(InstCmp::decode(0x3bfe000000000000000000007_u128), neg_half);
    }

    #[test]
    fn golden_rdwr_encodings() {
        let rd = InstRdWr { rd: true, wr: false, base_addr: 42, len: 9 };
        assert_eq!(rd.encode(), 0x24000000a9_u128);
        let wr = InstRdWr { rd: false, wr: true, base_addr: 0x0440_0000, len: 100_000 };
        assert_eq!(wr.encode(), 0x61a8011000002_u128);
        assert_eq!(InstRdWr::decode(0x24000000a9_u128), rd);
        assert_eq!(InstRdWr::decode(0x61a8011000002_u128), wr);
    }

    // ------------------------------------------------------------------
    // Property tests: the golden fixtures above pin hand-picked points;
    // these pin the whole mapping.  Every field combination must
    // round-trip encode -> decode -> encode bit-exactly, and every
    // in-range wire word must decode -> encode back to itself (the
    // encoding is a bijection onto its bit range).  Seeded via
    // util::rng, so failures replay deterministically.
    // ------------------------------------------------------------------

    use crate::util::rng::Rng64;

    const PROPERTY_DRAWS: usize = 20_000;

    #[test]
    fn random_vctrl_roundtrip_is_bit_exact() {
        let mut rng = Rng64::seed_from_u64(0xCA11_15A1);
        for _ in 0..PROPERTY_DRAWS {
            let bits = rng.next_u64();
            let i = InstVCtrl {
                rd: bits & 1 != 0,
                wr: bits & 2 != 0,
                base_addr: rng.next_u64() as u32,
                len: rng.next_u64() as u32,
                q_id: (bits >> 2 & 0b111) as u8,
                precision: Scheme::from_wire_code((bits >> 5 & 0b11) as u8)
                    .expect("codes 0..=3 are always valid"),
            };
            let w = i.encode();
            assert!(w < 1u128 << 72, "Type-I words are 72 bits: {w:#x}");
            let d = InstVCtrl::decode(w).expect("a valid scheme code must decode");
            assert_eq!(d, i);
            assert_eq!(d.encode(), w, "re-encode must reproduce the wire word");
        }
    }

    #[test]
    fn random_cmp_roundtrip_preserves_every_alpha_bit_pattern() {
        // alpha is raw IEEE-754: infinities, subnormals and NaN
        // payloads are all legal wire content.  Compare bit patterns,
        // not floats — PartialEq would miss NaN == NaN.
        let mut rng = Rng64::seed_from_u64(0xCA11_15A2);
        for _ in 0..PROPERTY_DRAWS {
            let alpha_bits = rng.next_u64();
            let i = InstCmp {
                len: rng.next_u64() as u32,
                alpha: f64::from_bits(alpha_bits),
                q_id: (rng.next_u64() & 0b111) as u8,
            };
            let w = i.encode();
            assert!(w < 1u128 << 99, "Type-II words are 99 bits: {w:#x}");
            let d = InstCmp::decode(w);
            assert_eq!(d.alpha.to_bits(), alpha_bits);
            assert_eq!(d.len, i.len);
            assert_eq!(d.q_id, i.q_id);
            assert_eq!(d.encode(), w, "re-encode must reproduce the wire word");
        }
    }

    #[test]
    fn random_rdwr_roundtrip_is_bit_exact() {
        let mut rng = Rng64::seed_from_u64(0xCA11_15A3);
        for _ in 0..PROPERTY_DRAWS {
            let bits = rng.next_u64();
            let i = InstRdWr {
                rd: bits & 1 != 0,
                wr: bits & 2 != 0,
                base_addr: rng.next_u64() as u32,
                len: rng.next_u64() as u32,
            };
            let w = i.encode();
            assert!(w < 1u128 << 66, "Type-III words are 66 bits: {w:#x}");
            let d = InstRdWr::decode(w);
            assert_eq!(d, i);
            assert_eq!(d.encode(), w, "re-encode must reproduce the wire word");
        }
    }

    #[test]
    fn every_in_range_wire_word_is_a_valid_instruction_or_explicit_error() {
        // Type-II/III decode is total on the bit range and encode
        // inverts it.  Type-I decode is total *up to* the reserved
        // precision codes: a valid code round-trips, a reserved code is
        // a DecodeError naming that code — never a panic, never a
        // silent remap.
        let mut rng = Rng64::seed_from_u64(0xCA11_15A4);
        let wide = |r: &mut Rng64| (r.next_u64() as u128) << 64 | r.next_u64() as u128;
        let (mut ok, mut reserved) = (0u32, 0u32);
        for _ in 0..PROPERTY_DRAWS {
            let w = wide(&mut rng) & ((1u128 << 72) - 1);
            let code = (w >> 69 & 0b111) as u8;
            match InstVCtrl::decode(w) {
                Ok(d) => {
                    assert!(code <= 3);
                    assert_eq!(d.encode(), w);
                    ok += 1;
                }
                Err(e) => {
                    assert!(code > 3);
                    assert_eq!(e.precision_code, code);
                    reserved += 1;
                }
            }
            let w = wide(&mut rng) & ((1u128 << 99) - 1);
            assert_eq!(InstCmp::decode(w).encode(), w);
            let w = wide(&mut rng) & ((1u128 << 66) - 1);
            assert_eq!(InstRdWr::decode(w).encode(), w);
        }
        // The random draw must actually have exercised both outcomes.
        assert!(ok > 0 && reserved > 0, "ok={ok} reserved={reserved}");
    }

    #[test]
    fn trace_counts_per_target() {
        let mut t = InstTrace::default();
        t.record("M3", Instruction::Cmp(InstCmp { len: 1, alpha: 0.0, q_id: 0 }));
        t.record("M3", Instruction::Cmp(InstCmp { len: 2, alpha: 1.0, q_id: 0 }));
        t.record("VecCtrl-p", Instruction::VCtrl(InstVCtrl {
            rd: true, wr: false, base_addr: 0, len: 2, q_id: 1, precision: Scheme::MixV3,
        }));
        assert_eq!(t.count_for("M3"), 2);
        assert_eq!(t.count_for("VecCtrl-p"), 1);
    }
}
