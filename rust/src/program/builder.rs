//! The program builder: compiles the Fig. 4 controller schedule and the
//! Fig. 5/6 module schedules into typed instruction trips with real HBM
//! addresses, validating every on-chip reuse edge at build time.
//!
//! The Type-I/III steps are generated from the decentralized
//! vector-control FSMs of [`crate::modules::fsm`] — the FSMs *are* the
//! schedule (§5.5); the builder only walks their states and attaches
//! channels/addresses from the [`HbmMemoryMap`].  The Type-II steps
//! carry the stream endpoints of the Fig. 6 computation-module FSMs,
//! which is what lets the time plane derive its dataflow graphs from
//! the same instructions the value plane executes.

use crate::hbm::ChannelMode;
use crate::isa::{InstCmp, InstRdWr, InstVCtrl};
use crate::modules::fsm::{self, Endpoint};
use crate::precision::Scheme;
use crate::vsr::{self, Module, Phase, Vector};

use super::{
    edge_fifo_depth, pipe_depth, short_name, tap_stage, CompStep, HbmMemoryMap, PhaseProgram,
    Program, ReuseEdge, ScalarBind, ScalarRole, TripKind, VecStep,
};

/// Compile and validate the five-trip program for vectors of length `n`,
/// vectorized over `batch` right-hand-side lanes (the trips carry
/// lane-0 addresses; the memory map records the lane stride the bus
/// applies at issue time).
pub fn compile(n: u32, mode: ChannelMode, batch: super::BatchId) -> Program {
    let mem_map = HbmMemoryMap::new_batched(n, mode, batch);
    let phases = [
        build_steady(TripKind::Phase1, n, &mem_map),
        build_steady(TripKind::Phase2, n, &mem_map),
        build_steady(TripKind::Phase3, n, &mem_map),
    ];
    let init = build_init(n, &mem_map);
    let exit = build_exit(n, &mem_map);
    let prog = Program { n, batch, mem_map, init, phases, exit };
    validate(&prog);
    prog
}

/// Interned memory-module trace targets (one per vector-control module,
/// §4.2's decomposition) — recording never allocates.
fn mem_target(name: &'static str) -> &'static str {
    match name {
        "VecCtrl-p" => "VecCtrl-p/mem",
        "VecCtrl-r" => "VecCtrl-r/mem",
        "VecCtrl-x" => "VecCtrl-x/mem",
        "VecCtrl-ap" => "VecCtrl-ap/mem",
        "VecCtrl-M" => "VecCtrl-M/mem",
        other => other,
    }
}

fn make_vec_step(
    name: &'static str,
    vector: Vector,
    rd_to: Option<Module>,
    wr_from: Option<Module>,
    read_idx: usize,
    n: u32,
    map: &HbmMemoryMap,
) -> VecStep {
    let region = *map.region(vector).expect("vector-control step on an unmapped vector");
    let rd_channel = region.rd_channel(read_idx);
    let wr_channel = region.wr_channel(map.mode);
    // The Type-I carries the address the module streams *from* (or the
    // write-back address for write-only states, e.g. ap in Phase-1).
    let base_addr =
        if rd_to.is_some() { region.rd_addr(read_idx) } else { region.wr_addr(map.mode) };
    let q_id = rd_to.map(|m| m as u8).unwrap_or(0);
    // The compiled word carries the default scheme; like alpha/beta,
    // the live precision is bound per lane at issue time (the bus
    // re-stamps this field from its `Scalars`).
    let vctrl = InstVCtrl {
        rd: rd_to.is_some(),
        wr: wr_from.is_some(),
        base_addr,
        len: n,
        q_id,
        precision: Scheme::default(),
    };
    let rd_inst = rd_to.map(|_| InstRdWr {
        rd: true,
        wr: false,
        base_addr: region.rd_addr(read_idx),
        len: n,
    });
    let wr_inst = wr_from.map(|_| InstRdWr {
        rd: false,
        wr: true,
        base_addr: region.wr_addr(map.mode),
        len: n,
    });
    VecStep {
        name,
        mem_name: mem_target(name),
        vector,
        rd_to,
        wr_from,
        rd_channel,
        wr_channel,
        vctrl,
        rd_inst,
        wr_inst,
    }
}

fn make_comp_step(
    module: Module,
    n: u32,
    inputs: Vec<(Vector, Endpoint)>,
    outputs: Vec<(Vector, Endpoint)>,
) -> CompStep {
    let q_id = outputs
        .iter()
        .find_map(|(_, e)| match e {
            Endpoint::Module(d) => Some(*d as u8),
            _ => None,
        })
        .unwrap_or(0);
    let scalar = match module {
        Module::M2 => Some(ScalarRole::Pap),
        Module::M6 => Some(ScalarRole::Rz),
        Module::M8 => Some(ScalarRole::Rr),
        _ => None,
    };
    let bind = match module {
        Module::M3 | Module::M4 => ScalarBind::Alpha,
        Module::M7 => ScalarBind::Beta,
        _ => ScalarBind::Unbound,
    };
    CompStep {
        module,
        target: short_name(module),
        inst: InstCmp { len: n, alpha: 0.0, q_id },
        scalar,
        bind,
        inputs,
        outputs,
    }
}

/// Steady-state trips: vector-control steps straight from the Fig. 6
/// FSM states, computation steps from the per-module FSMs, in the
/// controller's issue order (M8 hoisted in Phase-2, Fig. 4 opt. 2).
fn build_steady(kind: TripKind, n: u32, map: &HbmMemoryMap) -> PhaseProgram {
    let phase = kind.phase().expect("steady trip has a phase");
    let fsms = [
        (fsm::vecctrl_p(), Vector::P),
        (fsm::vecctrl_r(), Vector::R),
        (fsm::vecctrl_x(), Vector::X),
        (fsm::vecctrl_ap(), Vector::Ap),
        (fsm::vecctrl_m(), Vector::M),
    ];
    let mut vec_steps = Vec::new();
    for (f, vector) in fsms {
        // A vector may visit a phase more than once (p is read for M1
        // and again for M2 in Phase-1); successive reads alternate the
        // channel pair.
        let mut read_idx = 0;
        for s in &f.states {
            if s.phase != phase {
                continue;
            }
            vec_steps.push(make_vec_step(f.name, vector, s.rd_to, s.wr_from, read_idx, n, map));
            if s.rd_to.is_some() {
                read_idx += 1;
            }
        }
    }
    let order: &[Module] = match phase {
        Phase::Phase1 => &[Module::M1, Module::M2],
        Phase::Phase2 => &[Module::M4, Module::M8, Module::M5, Module::M6],
        Phase::Phase3 => &[Module::M4, Module::M5, Module::M7, Module::M3],
    };
    let comp_steps: Vec<CompStep> = order
        .iter()
        .map(|&m| {
            let f = fsm::comp_fsm(m);
            let st = f
                .states
                .iter()
                .find(|s| s.phase == phase)
                .unwrap_or_else(|| panic!("{} has no {phase:?} state", short_name(m)));
            make_comp_step(m, n, st.inputs.clone(), st.outputs.clone())
        })
        .collect();
    let reuse_edges = extract_edges(&comp_steps);
    PhaseProgram { kind, vec_steps, comp_steps, reuse_edges }
}

/// The merged-init trip (Fig. 4, `rp = -1`): lines 1–5 on the steady
/// modules with alpha = 1 and beta = 0 pre-bound.  The host preloads b
/// into r's region, so M4 computes r = b - 1·(A x0) in place; M1 reads
/// x0 instead of p; M7's beta-0 update degenerates to the p = z copy;
/// x is untouched, r and p are written back.
fn build_init(n: u32, map: &HbmMemoryMap) -> PhaseProgram {
    use Endpoint::{Memory, Module as ModEp};
    use Module::*;
    use Vector::*;
    let vec_steps = vec![
        make_vec_step("VecCtrl-x", X, Some(M1), None, 0, n, map),
        make_vec_step("VecCtrl-r", R, Some(M4), Some(M5), 0, n, map),
        make_vec_step("VecCtrl-M", M, Some(M5), None, 0, n, map),
        make_vec_step("VecCtrl-p", P, None, Some(M7), 0, n, map),
    ];
    let comp_steps = vec![
        make_comp_step(M1, n, vec![(X, Memory)], vec![(Ap, ModEp(M4))]),
        make_comp_step(M4, n, vec![(R, Memory), (Ap, ModEp(M1))], vec![(R, ModEp(M5))]),
        make_comp_step(M8, n, vec![(R, ModEp(M6))], vec![]),
        make_comp_step(
            M5,
            n,
            vec![(M, Memory), (R, ModEp(M4))],
            vec![(Z, ModEp(M6)), (Z, ModEp(M7)), (R, ModEp(M6)), (R, Memory)],
        ),
        make_comp_step(M6, n, vec![(R, ModEp(M5)), (Z, ModEp(M5))], vec![(R, ModEp(M8))]),
        make_comp_step(M7, n, vec![(Z, ModEp(M5))], vec![(P, Memory)]),
    ];
    let reuse_edges = extract_edges(&comp_steps);
    PhaseProgram { kind: TripKind::Init, vec_steps, comp_steps, reuse_edges }
}

/// The converged-exit trip (Fig. 4 opt. 2): the hoisted M8 already
/// reported rr <= tau, so only M3 runs to finish x; p comes from memory
/// (M7 was skipped) and the new x is written back.
fn build_exit(n: u32, map: &HbmMemoryMap) -> PhaseProgram {
    use Endpoint::Memory;
    let vec_steps = vec![
        make_vec_step("VecCtrl-p", Vector::P, Some(Module::M3), None, 0, n, map),
        make_vec_step("VecCtrl-x", Vector::X, Some(Module::M3), Some(Module::M3), 0, n, map),
    ];
    let comp_steps = vec![make_comp_step(
        Module::M3,
        n,
        vec![(Vector::X, Memory), (Vector::P, Memory)],
        vec![(Vector::X, Memory)],
    )];
    let reuse_edges = extract_edges(&comp_steps);
    PhaseProgram { kind: TripKind::ConvergedExit, vec_steps, comp_steps, reuse_edges }
}

/// Collect the module-to-module stream edges of a trip, with the §5.6
/// skew/depth bookkeeping derived from the producer's tap stages.
fn extract_edges(comp_steps: &[CompStep]) -> Vec<ReuseEdge> {
    let mut edges = Vec::new();
    for c in comp_steps {
        for (v, ep) in &c.inputs {
            let Endpoint::Module(src) = ep else { continue };
            let producer = comp_steps
                .iter()
                .find(|s| s.module == *src)
                .unwrap_or_else(|| panic!("edge source {} missing from trip", short_name(*src)));
            let my = tap_stage(producer.module, *v);
            let max = producer
                .outputs
                .iter()
                .map(|(ov, _)| tap_stage(producer.module, *ov))
                .max()
                .unwrap_or(my);
            edges.push(ReuseEdge {
                producer: *src,
                consumer: c.module,
                vector: *v,
                skew: max - my,
                fifo_depth: edge_fifo_depth(producer, *v),
            });
        }
    }
    edges
}

/// Build-time validation: reuse-edge legality (§5.1/§5.2 via
/// [`vsr::edge_legal`]), the §5.6 fast-FIFO rule, address sanity, and
/// structural consistency (every memory input has a compiled read
/// routed to it, every write-back a producing module).
fn validate(prog: &Program) {
    prog.mem_map.check_no_overlap().expect("memory map overlap");
    for trip in prog.all_trips() {
        let label = trip.kind.label();
        let bound = trip.kind.bound_scalars();
        for e in &trip.reuse_edges {
            if let Err(block) =
                vsr::edge_legal(e.producer, e.consumer, e.vector, e.fifo_depth, e.skew, bound)
            {
                panic!("illegal reuse edge in {label}: {e:?} ({block:?})");
            }
            if e.skew > 0 {
                let need = vsr::min_fast_fifo_depth(pipe_depth(e.producer));
                assert!(
                    e.fifo_depth >= need,
                    "fast FIFO too shallow in {label}: {e:?} needs >= {need} (§5.6)"
                );
            }
        }
        for c in &trip.comp_steps {
            for (v, ep) in &c.inputs {
                match ep {
                    Endpoint::Memory => assert!(
                        trip.vec_steps.iter().any(|s| s.vector == *v && s.rd_to == Some(c.module)),
                        "{label}: no compiled read of {} for {}",
                        v.name(),
                        short_name(c.module)
                    ),
                    Endpoint::Module(src) => assert!(
                        trip.comp_steps.iter().any(|s| s.module == *src),
                        "{label}: {} consumes from {} which is not in the trip",
                        short_name(c.module),
                        short_name(*src)
                    ),
                    Endpoint::Controller => {}
                }
            }
        }
        for s in &trip.vec_steps {
            if let Some(m) = s.wr_from {
                assert!(
                    trip.comp_steps
                        .iter()
                        .any(|c| c.module == m
                            && c.outputs.contains(&(s.vector, Endpoint::Memory))),
                    "{label}: write-back of {} has no producing {} output",
                    s.vector.name(),
                    short_name(m)
                );
            }
            assert!(s.vctrl.q_id < 8, "q_id must fit ap_uint<3>");
        }
    }
}
