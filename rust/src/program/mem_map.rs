//! The HBM memory map: real channel + base-address assignments for the
//! long vectors (paper §4.2, §5.4, §5.7).
//!
//! A U280 exposes 32 HBM pseudo-channels, each a 256 MiB window of the
//! device address space.  Channels 0–15 carry the SpMV nnz streams
//! (§2.3.3); channel 16 holds the Jacobi diagonal M; the four
//! read-modify-write vectors (ap, p, x, r) each own a *channel pair*
//! for the §5.7 ping-pong (read v_t from one channel while writing
//! v_{t+1} to the other).  z is deliberately **not mapped**: the Fig. 5
//! schedule recomputes it on-chip (§5.3), which is exactly what frees
//! its channel pair.
//!
//! Addresses are in 64-byte *beats* (the 512-bit AXI transfer unit), so
//! the full 8 GiB device space fits the ISA's 32-bit address fields.

use crate::hbm::ChannelMode;
use crate::vsr::Vector;

/// Beats per 256 MiB channel window (256 MiB / 64 B).
pub const CHANNEL_WINDOW_BEATS: u32 = 1 << 22;
/// Channels reserved for the SpMV nnz streams.
pub const NNZ_CHANNELS: usize = 16;
/// Channel holding the Jacobi diagonal (read-only, never ping-ponged).
pub const CH_DIAG: usize = 16;
/// Total HBM pseudo-channels on the part.
pub const TOTAL_CHANNELS: usize = 32;
/// f64 lanes per beat.
pub const BEAT_LANES: u32 = 8;

/// One long vector's placement: a channel pair and a beat offset within
/// the channel window (the same offset is used in both channels of the
/// pair — the ping-pong alternates channels, not offsets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VectorRegion {
    pub vector: Vector,
    /// `[primary, pair]`; equal for single-channel vectors (the diagonal).
    pub channels: [usize; 2],
    /// Beat offset inside each channel window.
    pub offset_beats: u32,
    /// Vector length in f64 elements.
    pub elems: u32,
}

impl VectorRegion {
    /// Beats occupied in each channel of the pair.
    pub fn beats(&self) -> u32 {
        self.elems.div_ceil(BEAT_LANES)
    }

    pub fn bytes(&self) -> u64 {
        8 * self.elems as u64
    }

    /// Channel serving the `k`-th same-phase read: multiple readers of
    /// one vector alternate the pair so their streams overlap (the two
    /// p reads of Phase-1 run in parallel, Fig. 5).
    pub fn rd_channel(&self, k: usize) -> usize {
        self.channels[k % 2]
    }

    /// Global beat address of the `k`-th read.
    pub fn rd_addr(&self, k: usize) -> u32 {
        self.rd_channel(k) as u32 * CHANNEL_WINDOW_BEATS + self.offset_beats
    }

    /// Write channel under the configured mode: the pair channel when
    /// ping-ponging (read and write overlap, §5.7), the read channel
    /// when single (they serialize — the channel turns around).
    pub fn wr_channel(&self, mode: ChannelMode) -> usize {
        match mode {
            ChannelMode::Double => self.channels[1],
            ChannelMode::Single => self.channels[0],
        }
    }

    /// Global beat address of the write-back.
    pub fn wr_addr(&self, mode: ChannelMode) -> u32 {
        self.wr_channel(mode) as u32 * CHANNEL_WINDOW_BEATS + self.offset_beats
    }
}

/// The full map for one solve: every *stored* vector of Algorithm 1
/// gets a region; [`Vector::Z`] stays on-chip and has none.
#[derive(Debug, Clone)]
pub struct HbmMemoryMap {
    pub n: u32,
    pub mode: ChannelMode,
    regions: Vec<VectorRegion>,
}

impl HbmMemoryMap {
    /// Lay out vectors of length `n` under a channel policy.  Panics if
    /// a vector outgrows its 256 MiB channel window (n > 32 Mi doubles),
    /// which is far beyond the largest suite matrix.
    pub fn new(n: u32, mode: ChannelMode) -> Self {
        let region = |vector, primary: usize, pair: usize| VectorRegion {
            vector,
            channels: [primary, pair],
            offset_beats: 0,
            elems: n,
        };
        let regions = vec![
            region(Vector::M, CH_DIAG, CH_DIAG),
            region(Vector::Ap, 17, 18),
            region(Vector::P, 19, 20),
            region(Vector::X, 21, 22),
            region(Vector::R, 23, 24),
        ];
        for r in &regions {
            assert!(
                r.offset_beats + r.beats() <= CHANNEL_WINDOW_BEATS,
                "vector {} ({} elems) exceeds the 256 MiB channel window",
                r.vector.name(),
                r.elems
            );
        }
        Self { n, mode, regions }
    }

    /// The region of a stored vector; `None` for on-chip-only z.
    pub fn region(&self, v: Vector) -> Option<&VectorRegion> {
        self.regions.iter().find(|r| r.vector == v)
    }

    pub fn regions(&self) -> &[VectorRegion] {
        &self.regions
    }

    /// Every byte range two live vectors occupy in one channel must be
    /// disjoint (a vector may legitimately appear in two channels — its
    /// ping-pong pair — but never on top of another vector).
    pub fn check_no_overlap(&self) -> Result<(), String> {
        for (i, a) in self.regions.iter().enumerate() {
            for b in self.regions.iter().skip(i + 1) {
                for &ca in &a.channels {
                    for &cb in &b.channels {
                        if ca != cb {
                            continue;
                        }
                        let a0 = a.offset_beats as u64 * 64;
                        let a1 = a0 + a.bytes();
                        let b0 = b.offset_beats as u64 * 64;
                        let b1 = b0 + b.bytes();
                        if a0 < b1 && b0 < a1 {
                            return Err(format!(
                                "vectors {} and {} overlap in channel {ca}: \
                                 [{a0},{a1}) vs [{b0},{b1})",
                                a.vector.name(),
                                b.vector.name()
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vsr::onchip_only_vectors;

    #[test]
    fn no_two_live_vectors_overlap_in_a_channel() {
        for mode in [ChannelMode::Double, ChannelMode::Single] {
            let map = HbmMemoryMap::new(1_437_960, mode); // largest suite matrix
            map.check_no_overlap().unwrap();
        }
    }

    #[test]
    fn z_is_never_mapped_and_matches_vsr_analysis() {
        let map = HbmMemoryMap::new(10_000, ChannelMode::Double);
        assert!(map.region(Vector::Z).is_none(), "z lives on-chip (§5.3)");
        for v in onchip_only_vectors() {
            assert!(map.region(v).is_none(), "{} is on-chip only", v.name());
        }
        for v in [Vector::P, Vector::Ap, Vector::R, Vector::X, Vector::M] {
            assert!(map.region(v).is_some(), "{} must be stored", v.name());
        }
    }

    #[test]
    fn vectors_avoid_the_nnz_channels() {
        let map = HbmMemoryMap::new(4_096, ChannelMode::Double);
        for r in map.regions() {
            for &c in &r.channels {
                assert!(c >= NNZ_CHANNELS && c < TOTAL_CHANNELS, "{:?}", r);
            }
        }
    }

    #[test]
    fn ping_pong_channels_follow_the_mode() {
        let n = 8_192;
        let dbl = HbmMemoryMap::new(n, ChannelMode::Double);
        let sgl = HbmMemoryMap::new(n, ChannelMode::Single);
        let p_dbl = dbl.region(Vector::P).unwrap();
        let p_sgl = sgl.region(Vector::P).unwrap();
        // Double: write to the pair channel; single: turn the read
        // channel around.
        assert_ne!(p_dbl.wr_channel(ChannelMode::Double), p_dbl.rd_channel(0));
        assert_eq!(p_sgl.wr_channel(ChannelMode::Single), p_sgl.rd_channel(0));
        // Two same-phase reads alternate the pair either way.
        assert_ne!(p_dbl.rd_channel(0), p_dbl.rd_channel(1));
    }

    #[test]
    fn addresses_are_real_channel_windows() {
        let map = HbmMemoryMap::new(16_384, ChannelMode::Double);
        let r = map.region(Vector::R).unwrap();
        assert_eq!(r.rd_addr(0), 23 * CHANNEL_WINDOW_BEATS);
        assert_eq!(r.wr_addr(ChannelMode::Double), 24 * CHANNEL_WINDOW_BEATS);
        assert_eq!(r.beats(), 2_048);
    }
}
