//! The HBM memory map: real channel + base-address assignments for the
//! long vectors (paper §4.2, §5.4, §5.7).
//!
//! A U280 exposes 32 HBM pseudo-channels, each a 256 MiB window of the
//! device address space.  Channels 0–15 carry the SpMV nnz streams
//! (§2.3.3); channel 16 holds the Jacobi diagonal M; the four
//! read-modify-write vectors (ap, p, x, r) each own a *channel pair*
//! for the §5.7 ping-pong (read v_t from one channel while writing
//! v_{t+1} to the other).  z is deliberately **not mapped**: the Fig. 5
//! schedule recomputes it on-chip (§5.3), which is exactly what frees
//! its channel pair.
//!
//! Addresses are in 64-byte *beats* (the 512-bit AXI transfer unit), so
//! the full 8 GiB device space fits the ISA's 32-bit address fields.
//!
//! **Batch axis.**  A map built with [`HbmMemoryMap::new_batched`] lays
//! out `batch` right-hand-side *lanes* per channel pair: lane `k`'s
//! copy of each read-modify-write vector (ap, p, x, r) sits at beat
//! offset `k * lane_stride_beats` inside the same channel window.  The
//! Jacobi diagonal M and the nnz streams are **batch-invariant** — one
//! matrix serves every lane, which is exactly the traffic amortization
//! block-CG multi-RHS solvers are built around — and z still has no
//! region at all (§5.3).  The compiled instruction stream carries
//! lane-0 addresses; the instruction bus rebases them per lane at
//! issue time (see `crate::program::bus`).

use crate::hbm::ChannelMode;
use crate::vsr::Vector;

use super::BatchId;

/// Beats per 256 MiB channel window (256 MiB / 64 B).
pub const CHANNEL_WINDOW_BEATS: u32 = 1 << 22;
/// Channels reserved for the SpMV nnz streams.
pub const NNZ_CHANNELS: usize = 16;
/// Channel holding the Jacobi diagonal (read-only, never ping-ponged).
pub const CH_DIAG: usize = 16;
/// Total HBM pseudo-channels on the part.
pub const TOTAL_CHANNELS: usize = 32;
/// f64 lanes per beat.
pub const BEAT_LANES: u32 = 8;

/// One long vector's placement: a channel pair and a beat offset within
/// the channel window (the same offset is used in both channels of the
/// pair — the ping-pong alternates channels, not offsets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VectorRegion {
    /// The vector stored here.
    pub vector: Vector,
    /// `[primary, pair]`; equal for single-channel vectors (the diagonal).
    pub channels: [usize; 2],
    /// Beat offset inside each channel window.
    pub offset_beats: u32,
    /// Vector length in f64 elements.
    pub elems: u32,
}

impl VectorRegion {
    /// Beats occupied in each channel of the pair.
    pub fn beats(&self) -> u32 {
        self.elems.div_ceil(BEAT_LANES)
    }

    /// Bytes the vector occupies (8 per f64 element).
    pub fn bytes(&self) -> u64 {
        8 * self.elems as u64
    }

    /// Channel serving the `k`-th same-phase read: multiple readers of
    /// one vector alternate the pair so their streams overlap (the two
    /// p reads of Phase-1 run in parallel, Fig. 5).
    pub fn rd_channel(&self, k: usize) -> usize {
        self.channels[k % 2]
    }

    /// Global beat address of the `k`-th read.
    pub fn rd_addr(&self, k: usize) -> u32 {
        self.rd_channel(k) as u32 * CHANNEL_WINDOW_BEATS + self.offset_beats
    }

    /// Write channel under the configured mode: the pair channel when
    /// ping-ponging (read and write overlap, §5.7), the read channel
    /// when single (they serialize — the channel turns around).
    pub fn wr_channel(&self, mode: ChannelMode) -> usize {
        match mode {
            ChannelMode::Double => self.channels[1],
            ChannelMode::Single => self.channels[0],
        }
    }

    /// Global beat address of the write-back.
    pub fn wr_addr(&self, mode: ChannelMode) -> u32 {
        self.wr_channel(mode) as u32 * CHANNEL_WINDOW_BEATS + self.offset_beats
    }
}

/// The full map for one solve: every *stored* vector of Algorithm 1
/// gets a region; [`Vector::Z`] stays on-chip and has none.
///
/// A batched map ([`HbmMemoryMap::new_batched`]) additionally records
/// how many right-hand-side lanes share each channel pair and the beat
/// stride between consecutive lanes' regions.
#[derive(Debug, Clone)]
pub struct HbmMemoryMap {
    /// Vector length in f64 elements.
    pub n: u32,
    /// Channel policy (§5.7 ping-pong vs single-channel turnaround).
    pub mode: ChannelMode,
    /// Right-hand-side lanes laid out per channel pair (>= 1).
    pub batch: BatchId,
    /// Beat stride between consecutive lanes' vector regions.
    pub lane_stride_beats: u32,
    regions: Vec<VectorRegion>,
}

impl HbmMemoryMap {
    /// Lay out vectors of length `n` under a channel policy.  Panics if
    /// a vector outgrows its 256 MiB channel window (n > 32 Mi doubles),
    /// which is far beyond the largest suite matrix.
    pub fn new(n: u32, mode: ChannelMode) -> Self {
        Self::new_batched(n, mode, 1)
    }

    /// Lay out `batch` right-hand-side lanes of length `n` under a
    /// channel policy.  Lane `k`'s ap/p/x/r regions sit `k` strides into
    /// the shared channel windows; M is batch-invariant.  Panics when
    /// the lanes outgrow a 256 MiB channel window (use
    /// [`HbmMemoryMap::max_batch`] to size chunks).
    pub fn new_batched(n: u32, mode: ChannelMode, batch: BatchId) -> Self {
        assert!(batch >= 1, "a batched map needs at least one lane");
        let lane_stride_beats = n.div_ceil(BEAT_LANES);
        // (The per-region assert below reports the batch-1 case — a
        // single lane outgrowing its window — with the precise vector.)
        assert!(
            batch == 1 || batch as u64 * lane_stride_beats as u64 <= CHANNEL_WINDOW_BEATS as u64,
            "{batch} lanes of {n} elems exceed the 256 MiB channel window \
             (max_batch = {})",
            Self::max_batch(n)
        );
        let region = |vector, primary: usize, pair: usize| VectorRegion {
            vector,
            channels: [primary, pair],
            offset_beats: 0,
            elems: n,
        };
        let regions = vec![
            region(Vector::M, CH_DIAG, CH_DIAG),
            region(Vector::Ap, 17, 18),
            region(Vector::P, 19, 20),
            region(Vector::X, 21, 22),
            region(Vector::R, 23, 24),
        ];
        for r in &regions {
            assert!(
                r.offset_beats + r.beats() <= CHANNEL_WINDOW_BEATS,
                "vector {} ({} elems) exceeds the 256 MiB channel window",
                r.vector.name(),
                r.elems
            );
        }
        Self { n, mode, batch, lane_stride_beats, regions }
    }

    /// Most right-hand-side lanes of length `n` one channel window can
    /// hold: >= 1 whenever a single lane fits, 0 when even one lane
    /// outgrows the window (such an `n` cannot be mapped at all).
    pub fn max_batch(n: u32) -> BatchId {
        let stride = n.div_ceil(BEAT_LANES).max(1);
        CHANNEL_WINDOW_BEATS / stride
    }

    /// The lane-0 region of a stored vector; `None` for on-chip-only z.
    pub fn region(&self, v: Vector) -> Option<&VectorRegion> {
        self.regions.iter().find(|r| r.vector == v)
    }

    /// The region lane `k` of a stored vector occupies: the lane-0
    /// region shifted by `k` lane strides — except the batch-invariant
    /// diagonal M, which every lane shares.  `None` for z.
    pub fn lane_region(&self, v: Vector, lane: BatchId) -> Option<VectorRegion> {
        assert!(lane < self.batch, "lane {lane} out of range (batch {})", self.batch);
        let mut r = *self.region(v)?;
        if v != Vector::M {
            r.offset_beats += lane * self.lane_stride_beats;
        }
        Some(r)
    }

    /// Beat offset the instruction bus adds to lane `k`'s addresses for
    /// the per-RHS vectors (the shared M reads are never rebased).
    pub fn lane_offset_beats(&self, lane: BatchId) -> u32 {
        assert!(lane < self.batch, "lane {lane} out of range (batch {})", self.batch);
        lane * self.lane_stride_beats
    }

    /// The lane-0 regions, in layout order.
    pub fn regions(&self) -> &[VectorRegion] {
        &self.regions
    }

    /// Every byte range two live vectors occupy in one channel must be
    /// disjoint (a vector may legitimately appear in two channels — its
    /// ping-pong pair — but never on top of another vector).  Lanes of
    /// one vector are disjoint by construction (the lane stride covers
    /// a lane's beats exactly), so the check compares each vector's
    /// whole *batch footprint* — first lane start to last lane end —
    /// pairwise across vectors.
    pub fn check_no_overlap(&self) -> Result<(), String> {
        let footprint = |r: &VectorRegion| {
            let lanes = if r.vector == Vector::M { 1u64 } else { self.batch as u64 };
            let start = r.offset_beats as u64 * 64;
            let end = start + (lanes - 1) * self.lane_stride_beats as u64 * 64 + r.bytes();
            (start, end)
        };
        for (i, a) in self.regions.iter().enumerate() {
            for b in self.regions.iter().skip(i + 1) {
                for &ca in &a.channels {
                    for &cb in &b.channels {
                        if ca != cb {
                            continue;
                        }
                        let (a0, a1) = footprint(a);
                        let (b0, b1) = footprint(b);
                        if a0 < b1 && b0 < a1 {
                            return Err(format!(
                                "vectors {} and {} overlap in channel {ca}: \
                                 [{a0},{a1}) vs [{b0},{b1})",
                                a.vector.name(),
                                b.vector.name()
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vsr::onchip_only_vectors;

    #[test]
    fn no_two_live_vectors_overlap_in_a_channel() {
        for mode in [ChannelMode::Double, ChannelMode::Single] {
            let map = HbmMemoryMap::new(1_437_960, mode); // largest suite matrix
            map.check_no_overlap().unwrap();
        }
    }

    #[test]
    fn z_is_never_mapped_and_matches_vsr_analysis() {
        let map = HbmMemoryMap::new(10_000, ChannelMode::Double);
        assert!(map.region(Vector::Z).is_none(), "z lives on-chip (§5.3)");
        for v in onchip_only_vectors() {
            assert!(map.region(v).is_none(), "{} is on-chip only", v.name());
        }
        for v in [Vector::P, Vector::Ap, Vector::R, Vector::X, Vector::M] {
            assert!(map.region(v).is_some(), "{} must be stored", v.name());
        }
    }

    #[test]
    fn vectors_avoid_the_nnz_channels() {
        let map = HbmMemoryMap::new(4_096, ChannelMode::Double);
        for r in map.regions() {
            for &c in &r.channels {
                assert!(c >= NNZ_CHANNELS && c < TOTAL_CHANNELS, "{:?}", r);
            }
        }
    }

    #[test]
    fn ping_pong_channels_follow_the_mode() {
        let n = 8_192;
        let dbl = HbmMemoryMap::new(n, ChannelMode::Double);
        let sgl = HbmMemoryMap::new(n, ChannelMode::Single);
        let p_dbl = dbl.region(Vector::P).unwrap();
        let p_sgl = sgl.region(Vector::P).unwrap();
        // Double: write to the pair channel; single: turn the read
        // channel around.
        assert_ne!(p_dbl.wr_channel(ChannelMode::Double), p_dbl.rd_channel(0));
        assert_eq!(p_sgl.wr_channel(ChannelMode::Single), p_sgl.rd_channel(0));
        // Two same-phase reads alternate the pair either way.
        assert_ne!(p_dbl.rd_channel(0), p_dbl.rd_channel(1));
    }

    #[test]
    fn batched_lanes_are_disjoint_and_share_channels() {
        let n = 10_000;
        let map = HbmMemoryMap::new_batched(n, ChannelMode::Double, 6);
        map.check_no_overlap().unwrap();
        assert_eq!(map.lane_stride_beats, n.div_ceil(BEAT_LANES));
        let l0 = map.lane_region(Vector::P, 0).unwrap();
        let l3 = map.lane_region(Vector::P, 3).unwrap();
        assert_eq!(l0.channels, l3.channels, "lanes share the channel pair");
        assert_eq!(l3.offset_beats, 3 * map.lane_stride_beats);
        assert_eq!(map.lane_offset_beats(3), 3 * map.lane_stride_beats);
        // The diagonal is batch-invariant: every lane reads one copy.
        let m0 = map.lane_region(Vector::M, 0).unwrap();
        let m5 = map.lane_region(Vector::M, 5).unwrap();
        assert_eq!(m0.offset_beats, m5.offset_beats);
    }

    #[test]
    fn max_batch_bounds_the_lane_count() {
        // 4 Mi beats per window / 2048 beats per 16384-elem lane.
        assert_eq!(HbmMemoryMap::max_batch(16_384), CHANNEL_WINDOW_BEATS / 2_048);
        // A window-filling vector leaves room for exactly one lane; one
        // element more and nothing fits at all.
        assert_eq!(HbmMemoryMap::max_batch(8 * CHANNEL_WINDOW_BEATS), 1);
        assert_eq!(HbmMemoryMap::max_batch(8 * CHANNEL_WINDOW_BEATS + 1), 0);
        let n = 1_000;
        let cap = HbmMemoryMap::max_batch(n);
        let map = HbmMemoryMap::new_batched(n, ChannelMode::Single, cap);
        map.check_no_overlap().unwrap();
    }

    #[test]
    #[should_panic(expected = "exceed the 256 MiB channel window")]
    fn overfull_batch_panics() {
        let n = 1_000_000;
        let _ = HbmMemoryMap::new_batched(n, ChannelMode::Double, HbmMemoryMap::max_batch(n) + 1);
    }

    #[test]
    fn addresses_are_real_channel_windows() {
        let map = HbmMemoryMap::new(16_384, ChannelMode::Double);
        let r = map.region(Vector::R).unwrap();
        assert_eq!(r.rd_addr(0), 23 * CHANNEL_WINDOW_BEATS);
        assert_eq!(r.wr_addr(ChannelMode::Double), 24 * CHANNEL_WINDOW_BEATS);
        assert_eq!(r.beats(), 2_048);
    }
}
