//! The bucketed program cache: compile a [`Program`] once per matrix
//! *bucket*, reuse it for every solve that fits (the ROADMAP "one
//! program per matrix bucket" follow-up).
//!
//! §4's whole point is that one instruction stream "supports an
//! arbitrary problem" — the compiled trips depend only on the memory
//! map, not the matrix values — so recompiling per solve was pure
//! waste.  The cache keys programs by
//! `(bucket ceiling, channel mode, lane bucket)`:
//!
//! * **bucket ceiling** — `n` rounded up to the next power of two (at
//!   least [`MIN_BUCKET`]), so every size inside a bucket shares one
//!   program.  The [`HbmMemoryMap`](super::HbmMemoryMap) is sized to
//!   the ceiling and a smaller `n` is *rebased into it*: the value
//!   plane executes on the actual vectors (the interpreter never reads
//!   the compiled `len`), so a bucket program's results are **bitwise
//!   identical** to an exact-`n` program's (pinned in
//!   `tests/service.rs`).  Only the recorded addresses/beat counts
//!   carry the ceiling — the same conservatism a real deployment pays
//!   by provisioning HBM windows for the largest tenant in the bucket.
//! * **lane bucket** — the requested lane count rounded up to the next
//!   power of two (clamped to the bucket's
//!   [`HbmMemoryMap::max_batch`](super::HbmMemoryMap::max_batch)), so a
//!   partial flush of 5 right-hand sides reuses the 8-lane program
//!   instead of compiling a fresh 5-lane one.  Executing fewer live
//!   lanes than the program was compiled for is always legal — lanes
//!   are independent address windows.
//!
//! The cache is `Sync` (a mutexed map + atomic hit/miss counters) and
//! meant to be shared: one [`Arc<ProgramCache>`] serves every
//! [`Coordinator`](crate::coordinator::Coordinator) and every worker of
//! the [`service`](crate::service) layer.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::hbm::ChannelMode;

use super::mem_map::CHANNEL_WINDOW_BEATS;
use super::{BatchId, HbmMemoryMap, Program};

/// Smallest bucket ceiling: below this every program is the same size,
/// so finer buckets would only multiply compiles without saving memory.
pub const MIN_BUCKET: u32 = 1024;

/// f64 elements one 256 MiB channel window holds (the largest mappable
/// vector, hence the largest possible bucket ceiling).
const WINDOW_ELEMS: u32 = 8 * CHANNEL_WINDOW_BEATS;

/// The bucket ceiling `n` compiles under: the next power of two, at
/// least [`MIN_BUCKET`].  An `n` at or beyond the channel-window
/// capacity is returned unchanged (there is no headroom to round into —
/// and past the window the compile itself reports the precise error).
///
/// ```
/// use callipepla::program::cache::bucket_ceiling;
/// assert_eq!(bucket_ceiling(700), 1024);
/// assert_eq!(bucket_ceiling(1024), 1024);
/// assert_eq!(bucket_ceiling(1025), 2048);
/// assert_eq!(bucket_ceiling(100_000), 131_072);
/// ```
pub fn bucket_ceiling(n: u32) -> u32 {
    if n >= WINDOW_ELEMS {
        return n;
    }
    n.max(1).next_power_of_two().max(MIN_BUCKET)
}

/// The lane count a `lanes`-wide batch compiles under: the next power
/// of two, clamped to what the bucket's channel window can hold (and
/// never below the request itself — an over-window request is left to
/// the compile's own diagnostic).
pub fn lane_bucket(bucket_n: u32, lanes: BatchId) -> BatchId {
    let cap = HbmMemoryMap::max_batch(bucket_n).max(1);
    lanes.max(1).next_power_of_two().min(cap).max(lanes)
}

/// A shared, thread-safe memo of compiled [`Program`]s keyed by
/// `(bucket ceiling, channel mode, lane bucket)`.
///
/// ```
/// use std::sync::Arc;
/// use callipepla::hbm::ChannelMode;
/// use callipepla::program::ProgramCache;
///
/// let cache = Arc::new(ProgramCache::new());
/// let a = cache.get_batched(700, ChannelMode::Double, 3);
/// let b = cache.get_batched(900, ChannelMode::Double, 4);
/// // Same (1024, Double, 4) bucket: one compile served both.
/// assert!(Arc::ptr_eq(&a, &b));
/// assert_eq!((cache.misses(), cache.hits()), (1, 1));
/// ```
#[derive(Debug, Default)]
pub struct ProgramCache {
    /// Per-key compile slots.  The map mutex is held only to look up /
    /// insert a slot; the compile itself runs inside the slot's
    /// `OnceLock`, so a slow first-touch compile for one bucket never
    /// blocks hits (or first touches) on other buckets.
    map: Mutex<HashMap<(u32, ChannelMode, BatchId), Arc<OnceLock<Arc<Program>>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ProgramCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// The cached program for a single right-hand side of length `n`.
    pub fn get(&self, n: u32, mode: ChannelMode) -> Arc<Program> {
        self.get_batched(n, mode, 1)
    }

    /// The cached program serving `lanes` right-hand sides of length
    /// `n`: compiled at the bucket ceiling / lane bucket on the first
    /// request for that key (concurrent first requests block only each
    /// other, never other keys), shared ever after.  The returned
    /// program's `n` and `batch` are the *bucket* values — callers
    /// execute their actual (smaller or equal) problem inside it.
    pub fn get_batched(&self, n: u32, mode: ChannelMode, lanes: BatchId) -> Arc<Program> {
        let bucket = bucket_ceiling(n);
        let lanes = lane_bucket(bucket, lanes);
        let slot = {
            let mut map = self.map.lock().expect("program cache poisoned");
            Arc::clone(map.entry((bucket, mode, lanes)).or_default())
        };
        let mut compiled_here = false;
        let program = slot.get_or_init(|| {
            compiled_here = true;
            Arc::new(Program::compile_batched(bucket, mode, lanes))
        });
        if compiled_here {
            self.misses.fetch_add(1, Ordering::Relaxed);
            crate::obs::catalog::SERVICE_CACHE_MISSES.inc();
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
            crate::obs::catalog::SERVICE_CACHE_HITS.inc();
        }
        Arc::clone(program)
    }

    /// Lookups served from the memo.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that compiled a fresh program.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Drop every cached program compiled for one bucket ceiling (all
    /// channel modes and lane buckets), returning how many compiled
    /// programs were evicted.  The registry's eviction hook calls this
    /// when the *last resident matrix* of a bucket is evicted — a
    /// bucket program with no remaining tenant is dead weight.
    /// In-flight executions are untouched: they hold their own
    /// `Arc<Program>`, and a later request simply recompiles (bitwise
    /// the same program — compilation is a pure function of the key).
    pub fn evict_bucket(&self, bucket: u32) -> usize {
        let mut map = self.map.lock().expect("program cache poisoned");
        let mut compiled = 0;
        map.retain(|key, slot| {
            if key.0 != bucket {
                return true;
            }
            if slot.get().is_some() {
                compiled += 1;
            }
            false
        });
        if compiled > 0 {
            crate::obs::catalog::SERVICE_CACHE_EVICTIONS.add(compiled as u64);
        }
        compiled
    }

    /// Distinct compiled programs held.
    pub fn len(&self) -> usize {
        let map = self.map.lock().expect("program cache poisoned");
        map.values().filter(|slot| slot.get().is_some()).count()
    }

    /// Whether nothing has been compiled yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_rule_is_pow2_with_floor() {
        assert_eq!(bucket_ceiling(1), MIN_BUCKET);
        assert_eq!(bucket_ceiling(1024), 1024);
        assert_eq!(bucket_ceiling(1025), 2048);
        assert_eq!(bucket_ceiling(16_384), 16_384);
        assert_eq!(bucket_ceiling(1_437_960), 1 << 21);
        // At/above the window there is no rounding headroom.
        assert_eq!(bucket_ceiling(WINDOW_ELEMS), WINDOW_ELEMS);
        assert_eq!(bucket_ceiling(WINDOW_ELEMS + 3), WINDOW_ELEMS + 3);
    }

    #[test]
    fn lane_bucket_rounds_up_within_the_window() {
        assert_eq!(lane_bucket(1024, 1), 1);
        assert_eq!(lane_bucket(1024, 5), 8);
        assert_eq!(lane_bucket(1024, 8), 8);
        // 1024-elem lanes are 128 beats: 32768 lanes fill the window.
        let cap = HbmMemoryMap::max_batch(1024);
        assert_eq!(lane_bucket(1024, cap), cap);
    }

    #[test]
    fn same_bucket_shares_one_compile() {
        let cache = ProgramCache::new();
        let a = cache.get_batched(700, ChannelMode::Double, 3);
        let b = cache.get_batched(1000, ChannelMode::Double, 4);
        assert!(Arc::ptr_eq(&a, &b), "both live in the (1024, Double, 4) bucket");
        assert_eq!(a.n, 1024);
        assert_eq!(a.batch, 4);
        assert_eq!(cache.len(), 1);
        assert_eq!((cache.misses(), cache.hits()), (1, 1));
        // A different mode or lane bucket is a different program.
        let c = cache.get_batched(700, ChannelMode::Single, 3);
        assert!(!Arc::ptr_eq(&a, &c));
        let d = cache.get_batched(700, ChannelMode::Double, 9);
        assert_eq!(d.batch, 16);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn evict_bucket_drops_only_that_bucket() {
        let cache = ProgramCache::new();
        let a = cache.get_batched(700, ChannelMode::Double, 3); // (1024, Double, 4)
        let _ = cache.get_batched(700, ChannelMode::Single, 3); // (1024, Single, 4)
        let _ = cache.get_batched(2000, ChannelMode::Double, 3); // (2048, Double, 4)
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.evict_bucket(1024), 2, "both 1024 modes evicted");
        assert_eq!(cache.len(), 1);
        // The held Arc survives; a re-request recompiles the same key.
        assert_eq!(a.n, 1024);
        let b = cache.get_batched(700, ChannelMode::Double, 3);
        assert!(!Arc::ptr_eq(&a, &b), "fresh compile after eviction");
        assert_eq!((b.n, b.batch), (a.n, a.batch));
        assert_eq!(cache.evict_bucket(4096), 0, "empty bucket is a no-op");
    }

    #[test]
    fn single_rhs_get_is_the_lane_1_bucket() {
        let cache = ProgramCache::new();
        let a = cache.get(4_096, ChannelMode::Double);
        let b = cache.get_batched(4_096, ChannelMode::Double, 1);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.batch, 1);
    }
}
