//! The compiled instruction program: one control representation that
//! drives **both** planes (the reproduction's answer to paper §4–§5).
//!
//! [`Program::compile`] turns (vector length, channel policy) into five
//! typed instruction trips:
//!
//! * the **merged-init** trip (Fig. 4's `rp = -1` pass: lines 1–5 of
//!   Algorithm 1 run on the steady-state modules with alpha = 1 and
//!   beta = 0 pre-bound, r's region preloaded with b by the host),
//! * the three **steady-state phases** of Fig. 5, whose Type-I/III
//!   steps come from the decentralized vector-control FSMs
//!   ([`crate::modules::fsm`]) and whose Type-II steps carry the
//!   stream endpoints of the Fig. 6 computation-module FSMs, and
//! * the **converged-exit** trip (Fig. 4 opt. 2: M8 is hoisted before
//!   M5–M7, so a converged iteration runs M3 alone to finish x).
//!
//! Every instruction carries a *real* HBM address from the
//! [`HbmMemoryMap`], and every on-chip reuse edge is validated at build
//! time against [`crate::vsr::edge_legal`] (the §5.1/§5.2 rules) plus
//! the §5.6 FIFO-depth rule.  The value plane executes these exact
//! steps through [`bus::InstructionBus`]; the time plane derives its
//! cycle graphs from them via `Dataflow::from_program` — the two can no
//! longer drift.

pub mod builder;
pub mod bus;
pub mod cache;
pub mod mem_map;

pub use bus::{DispatchReturn, InstDispatch, InstructionBus, LaneSlice, Scalars, VectorFile};
pub use cache::{bucket_ceiling, ProgramCache};
pub use mem_map::{HbmMemoryMap, VectorRegion, CH_DIAG, NNZ_CHANNELS, TOTAL_CHANNELS};

use crate::hbm::ChannelMode;
use crate::isa::{InstCmp, InstRdWr, InstVCtrl};
use crate::modules::fsm::Endpoint;
use crate::vsr::{Module, Phase, Vector};

// ---------------------------------------------------------------------
// Module micro-architecture (II=1 pipeline shapes).  These are facts
// about the hardware modules, not about the schedule — the schedule is
// what the compiled steps carry.
// ---------------------------------------------------------------------

/// M5 left-divide pipeline depth (Fig. 7: L = 33).
pub const M5_DEPTH: usize = 33;
/// M6 forwards r after its 5-stage dot front-end.
pub const M6_DEPTH: usize = 5;
/// FP multiply-add pipelines (M3, M4, M7).
pub const FMA_DEPTH: usize = 8;
/// Default stream FIFO depth.
pub const STREAM_FIFO_DEPTH: usize = 64;

/// Pipeline depth of a module's streaming datapath.
pub fn pipe_depth(m: Module) -> usize {
    match m {
        Module::M5 => M5_DEPTH,
        Module::M6 => M6_DEPTH,
        Module::M3 | Module::M4 | Module::M7 => FMA_DEPTH,
        // M1 (SpMV) and the pure dots have no tapped pipeline.
        Module::M1 | Module::M2 | Module::M8 => 1,
    }
}

/// Stage at which a module taps `v` onto its output stream.  M5
/// consume-and-sends r at stage 0 (the copy that makes the Fig. 7
/// fast-FIFO analysis necessary); everything else emits at the end of
/// its pipeline.
pub fn tap_stage(m: Module, v: Vector) -> usize {
    match (m, v) {
        (Module::M5, Vector::R) => 0,
        _ => pipe_depth(m) - 1,
    }
}

/// FIFO depth for the edge carrying `vector` out of `step`: the §5.6
/// rule — an output tapped *earlier* than a sibling tap is the fast
/// stream and needs depth >= L + 1 to avoid the Fig. 7 deadlock;
/// everything else gets the default stream depth.
pub fn edge_fifo_depth(step: &CompStep, vector: Vector) -> usize {
    let my = tap_stage(step.module, vector);
    let max = step
        .outputs
        .iter()
        .map(|(v, _)| tap_stage(step.module, *v))
        .max()
        .unwrap_or(my);
    if my < max {
        pipe_depth(step.module) + 1
    } else {
        STREAM_FIFO_DEPTH
    }
}

/// Short trace-target id of a computation module ("M1".."M8").
pub fn short_name(m: Module) -> &'static str {
    match m {
        Module::M1 => "M1",
        Module::M2 => "M2",
        Module::M3 => "M3",
        Module::M4 => "M4",
        Module::M5 => "M5",
        Module::M6 => "M6",
        Module::M7 => "M7",
        Module::M8 => "M8",
    }
}

// ---------------------------------------------------------------------
// Compiled step types.
// ---------------------------------------------------------------------

/// Which controller trip a phase program belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TripKind {
    /// Merged init (Fig. 4, `rp = -1`): alpha = 1, beta = 0 pre-bound.
    Init,
    /// Steady-state Fig. 5 phase 1 (M1, M2).
    Phase1,
    /// Steady-state Fig. 5 phase 2 (M4, M8, M5, M6 — M8 hoisted).
    Phase2,
    /// Steady-state Fig. 5 phase 3 (M4, M5, M7, M3).
    Phase3,
    /// Converged exit: M3 alone finishes x (Fig. 4 opt. 2).
    ConvergedExit,
}

impl TripKind {
    /// Short lowercase id used in dumps and panics.
    pub fn label(self) -> &'static str {
        match self {
            TripKind::Init => "init",
            TripKind::Phase1 => "phase1",
            TripKind::Phase2 => "phase2",
            TripKind::Phase3 => "phase3",
            TripKind::ConvergedExit => "converged-exit",
        }
    }

    /// Scalars the controller has bound *before* this trip starts —
    /// what waives the §5.1 scalar-dependency rule for its reuse edges.
    pub fn bound_scalars(self) -> &'static [&'static str] {
        match self {
            // The merged init pre-binds alpha = 1 and beta = 0.
            TripKind::Init => &["alpha", "beta"],
            TripKind::Phase1 => &[],
            TripKind::Phase2 => &["alpha"],
            TripKind::Phase3 => &["alpha", "beta"],
            TripKind::ConvergedExit => &["alpha"],
        }
    }

    /// The Fig. 5 phase this trip instantiates, for the steady trips.
    pub fn phase(self) -> Option<Phase> {
        match self {
            TripKind::Phase1 => Some(Phase::Phase1),
            TripKind::Phase2 => Some(Phase::Phase2),
            TripKind::Phase3 => Some(Phase::Phase3),
            _ => None,
        }
    }
}

/// Scalar a dot module returns to the controller (§4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalarRole {
    /// M2's p . ap (the alpha denominator).
    Pap,
    /// M6's r . z (feeds beta).
    Rz,
    /// M8's r . r (the termination test).
    Rr,
}

/// Which controller scalar the bus binds into a Type-II `alpha` field
/// at issue time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalarBind {
    /// The module takes no scalar (dots, left-divide, SpMV).
    Unbound,
    /// Bind the live alpha (M3, M4).
    Alpha,
    /// Bind the live beta (M7).
    Beta,
}

/// One vector-control step: the Type-I instruction plus the Type-III
/// memory instruction(s) it decomposes into (§4.2's vector-flow
/// example), with real channels and addresses.
#[derive(Debug, Clone)]
pub struct VecStep {
    /// Vector-control module id ("VecCtrl-p" style trace target).
    pub name: &'static str,
    /// Its memory module's trace target ("VecCtrl-p/mem").
    pub mem_name: &'static str,
    /// The vector this step controls.
    pub vector: Vector,
    /// Module the read stream feeds, if the step reads.
    pub rd_to: Option<Module>,
    /// Module whose output the step writes back, if it writes.
    pub wr_from: Option<Module>,
    /// HBM channel serving the read.
    pub rd_channel: usize,
    /// HBM channel taking the write.
    pub wr_channel: usize,
    /// The compiled Type-I word.
    pub vctrl: InstVCtrl,
    /// The decomposed Type-III read, if any.
    pub rd_inst: Option<InstRdWr>,
    /// The decomposed Type-III write, if any.
    pub wr_inst: Option<InstRdWr>,
}

/// One computation step: the Type-II instruction plus the stream
/// endpoints (Fig. 6 f–m) that tell both planes where its inputs come
/// from and where its outputs go.
#[derive(Debug, Clone)]
pub struct CompStep {
    /// The computation module triggered.
    pub module: Module,
    /// Trace target ("M1".."M8").
    pub target: &'static str,
    /// `alpha` is a placeholder here; the bus binds the live scalar at
    /// issue time (the controller owns alpha/beta, §4.3).
    pub inst: InstCmp,
    /// Scalar this module returns to the controller, if it is a dot.
    pub scalar: Option<ScalarRole>,
    /// Controller scalar bound into the instruction at issue time.
    pub bind: ScalarBind,
    /// Input streams: (vector, where it comes from).
    pub inputs: Vec<(Vector, Endpoint)>,
    /// Output streams: (vector, where it goes).
    pub outputs: Vec<(Vector, Endpoint)>,
}

/// A module-to-module on-chip stream, with the §5.6 bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReuseEdge {
    /// Module emitting the stream.
    pub producer: Module,
    /// Module consuming it.
    pub consumer: Module,
    /// The vector streamed.
    pub vector: Vector,
    /// Stage gap to the producer's slowest sibling tap.
    pub skew: usize,
    /// Compiled FIFO depth (the §5.6 rule).
    pub fifo_depth: usize,
}

/// One trip's compiled instruction sequence.
#[derive(Debug, Clone)]
pub struct PhaseProgram {
    /// Which controller trip this is.
    pub kind: TripKind,
    /// Type-I steps (with their Type-III decompositions).
    pub vec_steps: Vec<VecStep>,
    /// Type-II steps, in controller issue order.
    pub comp_steps: Vec<CompStep>,
    /// The validated on-chip streams between the comp steps.
    pub reuse_edges: Vec<ReuseEdge>,
}

impl PhaseProgram {
    /// (reads, writes) this trip issues against HBM.
    pub fn access_counts(&self) -> (usize, usize) {
        let r = self.vec_steps.iter().filter(|s| s.rd_inst.is_some()).count();
        let w = self.vec_steps.iter().filter(|s| s.wr_inst.is_some()).count();
        (r, w)
    }
}

/// Identifies one right-hand-side lane of a batched program (also the
/// type of lane *counts*, e.g. [`Program::batch`]).
///
/// The batch axis never appears in the wire format: lane `k`'s
/// instructions are ordinary Type-I/II/III words whose addresses are
/// rebased by `k` lane strides and whose scalar fields carry lane `k`'s
/// live alpha / beta — the same ISA "supports an arbitrary problem"
/// argument of §4, extended to many problems per compiled stream.
pub type BatchId = u32;

/// The whole compiled program for one solve (or one batch of solves).
#[derive(Debug, Clone)]
pub struct Program {
    /// Vector length in f64 elements.
    pub n: u32,
    /// Right-hand-side lanes this program's trips are vectorized over
    /// (1 for a plain single-RHS program).
    pub batch: BatchId,
    /// The HBM layout every instruction address was drawn from.
    pub mem_map: HbmMemoryMap,
    /// The merged-init trip (Fig. 4, `rp = -1`).
    pub init: PhaseProgram,
    /// The three steady-state phase trips of Fig. 5.
    pub phases: [PhaseProgram; 3],
    /// The converged-exit trip (M3 alone finishes x).
    pub exit: PhaseProgram,
}

impl Program {
    /// Compile and validate the full five-trip program for one RHS.
    ///
    /// ```
    /// use callipepla::hbm::ChannelMode;
    /// use callipepla::program::Program;
    ///
    /// let prog = Program::compile(4_096, ChannelMode::Double);
    /// // Five trips, every reuse edge validated at build time.
    /// assert_eq!(prog.all_trips().len(), 5);
    /// // z is never mapped: it lives on-chip (§5.3).
    /// assert!(prog.mem_map.region(callipepla::vsr::Vector::Z).is_none());
    /// ```
    pub fn compile(n: u32, mode: ChannelMode) -> Program {
        builder::compile(n, mode, 1)
    }

    /// Compile one instruction stream vectorized over `batch` RHS lanes:
    /// the trips carry lane-0 addresses, the memory map lays the lanes
    /// out per channel pair, and the bus rebases per lane at issue time.
    /// Panics when the lanes outgrow a channel window
    /// ([`HbmMemoryMap::max_batch`] bounds the lane count).
    ///
    /// ```
    /// use callipepla::hbm::ChannelMode;
    /// use callipepla::program::Program;
    ///
    /// let prog = Program::compile_batched(4_096, ChannelMode::Double, 4);
    /// assert_eq!(prog.batch, 4);
    /// // Lane 2's per-RHS addresses sit two strides into the window.
    /// assert_eq!(prog.lane_offset_beats(2), 2 * prog.mem_map.lane_stride_beats);
    /// ```
    pub fn compile_batched(n: u32, mode: ChannelMode, batch: BatchId) -> Program {
        builder::compile(n, mode, batch)
    }

    /// The steady-state trip instantiating Fig. 5 phase `p`.
    pub fn phase(&self, p: Phase) -> &PhaseProgram {
        match p {
            Phase::Phase1 => &self.phases[0],
            Phase::Phase2 => &self.phases[1],
            Phase::Phase3 => &self.phases[2],
        }
    }

    /// All five trips in controller order.
    pub fn all_trips(&self) -> [&PhaseProgram; 5] {
        [&self.init, &self.phases[0], &self.phases[1], &self.phases[2], &self.exit]
    }

    /// Beat offset the bus adds to lane `lane`'s per-RHS addresses (the
    /// shared diagonal M is never rebased).
    pub fn lane_offset_beats(&self, lane: BatchId) -> u32 {
        self.mem_map.lane_offset_beats(lane)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vsr::{accesses_with_vsr, can_vsr, count_accesses, edge_legal, min_fast_fifo_depth};

    fn compiled() -> Program {
        Program::compile(16_384, ChannelMode::Double)
    }

    #[test]
    fn every_reuse_edge_in_every_trip_is_legal() {
        // Property-style sweep: several sizes, both channel modes, every
        // trip, every edge.
        for n in [1u32, 7, 1_000, 16_384, 1_000_000] {
            for mode in [ChannelMode::Double, ChannelMode::Single] {
                let prog = Program::compile(n, mode);
                for trip in prog.all_trips() {
                    let bound = trip.kind.bound_scalars();
                    for e in &trip.reuse_edges {
                        edge_legal(e.producer, e.consumer, e.vector, e.fifo_depth, e.skew, bound)
                            .unwrap_or_else(|b| {
                                panic!("illegal edge {e:?} in {}: {b:?}", trip.kind.label())
                            });
                        if e.skew > 0 {
                            assert!(
                                e.fifo_depth >= min_fast_fifo_depth(pipe_depth(e.producer)),
                                "fast FIFO under-provisioned: {e:?}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn phase2_chain_needs_no_waivers() {
        // The steady Phase-2 edges are the raw Fig. 5 chain: they must
        // pass can_vsr outright, with no bound-scalar waiver.
        let prog = compiled();
        let p2 = prog.phase(Phase::Phase2);
        assert!(!p2.reuse_edges.is_empty());
        for e in &p2.reuse_edges {
            can_vsr(e.producer, e.consumer, e.fifo_depth, e.skew)
                .unwrap_or_else(|b| panic!("phase2 edge {e:?}: {b:?}"));
        }
    }

    #[test]
    fn steady_state_accesses_reproduce_section_5_5() {
        let prog = compiled();
        // Per-phase multiset of (vector, rd, wr) against the §5.4 table.
        for (phase, want) in accesses_with_vsr() {
            let trip = prog.phase(phase);
            let mut got: Vec<(Vector, bool, bool)> = trip
                .vec_steps
                .iter()
                .map(|s| (s.vector, s.rd_inst.is_some(), s.wr_inst.is_some()))
                .collect();
            let mut want: Vec<(Vector, bool, bool)> =
                want.iter().map(|a| (a.vector, a.read, a.write)).collect();
            got.sort();
            want.sort();
            assert_eq!(got, want, "{phase:?}");
        }
        // Totals: 10 reads + 4 writes (§5.5, decentralized).
        let (mut r, mut w) = (0, 0);
        for p in &prog.phases {
            let (pr, pw) = p.access_counts();
            r += pr;
            w += pw;
        }
        assert_eq!((r, w), count_accesses(&accesses_with_vsr()));
    }

    #[test]
    fn instruction_addresses_come_from_the_memory_map() {
        let prog = compiled();
        prog.mem_map.check_no_overlap().unwrap();
        for trip in prog.all_trips() {
            for s in &trip.vec_steps {
                let region = prog.mem_map.region(s.vector).expect("stored vector");
                if let Some(rd) = s.rd_inst {
                    assert_eq!(rd.base_addr % mem_map::CHANNEL_WINDOW_BEATS, region.offset_beats);
                    assert_eq!(
                        (rd.base_addr / mem_map::CHANNEL_WINDOW_BEATS) as usize,
                        s.rd_channel
                    );
                    assert!(rd.base_addr != 0, "placeholder address survived compilation");
                }
                if let Some(wr) = s.wr_inst {
                    assert_eq!(
                        (wr.base_addr / mem_map::CHANNEL_WINDOW_BEATS) as usize,
                        s.wr_channel
                    );
                }
                assert_eq!(s.vctrl.len, prog.n);
            }
        }
    }

    #[test]
    fn z_never_appears_as_a_memory_access() {
        let prog = compiled();
        for trip in prog.all_trips() {
            assert!(
                trip.vec_steps.iter().all(|s| s.vector != Vector::Z),
                "z must stay on-chip in {}",
                trip.kind.label()
            );
        }
    }

    #[test]
    fn trip_shapes_match_fig4() {
        let prog = compiled();
        let mods = |t: &PhaseProgram| t.comp_steps.iter().map(|c| c.module).collect::<Vec<_>>();
        use Module::*;
        assert_eq!(mods(&prog.init), vec![M1, M4, M8, M5, M6, M7]);
        assert_eq!(mods(prog.phase(Phase::Phase1)), vec![M1, M2]);
        // M8 hoisted before M5/M6 (Fig. 4 opt. 2).
        assert_eq!(mods(prog.phase(Phase::Phase2)), vec![M4, M8, M5, M6]);
        assert_eq!(mods(prog.phase(Phase::Phase3)), vec![M4, M5, M7, M3]);
        assert_eq!(mods(&prog.exit), vec![M3]);
        // Init reads x0, b (via r's region) and M; writes r and p.
        assert_eq!(prog.init.access_counts(), (3, 2));
        assert_eq!(prog.exit.access_counts(), (2, 1));
    }

    #[test]
    fn batched_compile_shares_the_instruction_stream() {
        // One compiled stream serves every lane: the batched program's
        // trips are *identical* to the single-RHS program's (lane-0
        // addresses); only the memory map gains the lane axis.
        let single = Program::compile(10_000, ChannelMode::Double);
        let batched = Program::compile_batched(10_000, ChannelMode::Double, 7);
        assert_eq!(batched.batch, 7);
        for (s, b) in single.all_trips().iter().zip(batched.all_trips()) {
            assert_eq!(s.vec_steps.len(), b.vec_steps.len());
            for (sv, bv) in s.vec_steps.iter().zip(&b.vec_steps) {
                assert_eq!(sv.vctrl, bv.vctrl);
                assert_eq!(sv.rd_inst, bv.rd_inst);
                assert_eq!(sv.wr_inst, bv.wr_inst);
            }
            assert_eq!(s.reuse_edges, b.reuse_edges);
        }
        // Every lane's rebased addresses stay inside the channel window.
        batched.mem_map.check_no_overlap().unwrap();
        for lane in 0..batched.batch {
            let off = batched.lane_offset_beats(lane);
            for trip in batched.all_trips() {
                for s in &trip.vec_steps {
                    if s.vector == crate::vsr::Vector::M {
                        continue;
                    }
                    if let Some(rd) = s.rd_inst {
                        let rebased = rd.base_addr + off;
                        let region = batched.mem_map.lane_region(s.vector, lane).unwrap();
                        assert_eq!(rebased % mem_map::CHANNEL_WINDOW_BEATS, region.offset_beats);
                    }
                }
            }
        }
    }

    #[test]
    fn fast_fifo_depth_rule_is_applied_to_m5() {
        let prog = compiled();
        let p2 = prog.phase(Phase::Phase2);
        let fast = p2
            .reuse_edges
            .iter()
            .find(|e| e.producer == Module::M5 && e.vector == Vector::R)
            .expect("M5 r consume-and-send edge");
        assert_eq!(fast.fifo_depth, M5_DEPTH + 1, "Fig. 7(b): depth L+1");
        assert_eq!(fast.skew, M5_DEPTH - 1);
        let slow = p2
            .reuse_edges
            .iter()
            .find(|e| e.producer == Module::M5 && e.vector == Vector::Z)
            .expect("M5 z edge");
        assert_eq!(slow.fifo_depth, STREAM_FIFO_DEPTH);
    }
}
