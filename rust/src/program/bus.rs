//! The instruction bus: routes one compiled trip to the planes.
//!
//! Per trip the bus (1) issues the Type-I instructions to the
//! vector-control modules, each decomposing into Type-III read
//! instructions to its memory module (the prefetch side), (2) binds the
//! controller's live scalars into the Type-II batch and routes it to
//! the computation modules through [`InstDispatch`], and (3) issues the
//! Type-III write-backs, committing each staged vector and collecting a
//! [`MemResponse`] acknowledgement (§4.2 "scalar and memory response") —
//! the handshake that keeps a module reading a vector another module
//! just wrote consistent.
//!
//! The value-plane state lives in a [`VectorFile`]: *committed* vectors
//! model HBM contents, *staged* vectors model the on-chip streams of
//! the current trip.  Only a Type-III write moves staged bits into the
//! committed file — which is exactly why z (never written, §5.3) has no
//! committed slot at all.

use crate::coordinator::PhaseExecutor;
use crate::isa::{InstCmp, InstTrace, Instruction, MemResponse};
use crate::precision::Scheme;
use crate::vsr::{Module, Vector};

use super::{PhaseProgram, ScalarBind, TripKind};

/// The controller scalars live at a trip's issue time.
#[derive(Debug, Clone, Copy, Default)]
pub struct Scalars {
    /// Step length alpha (Alg. 1 line 8).
    pub alpha: f64,
    /// Direction coefficient beta (Alg. 1 line 13).
    pub beta: f64,
    /// Precision scheme this trip decodes — the third bound-at-issue
    /// scalar (PR 8).  Stamped into every Type-I word the trip issues;
    /// lanes of one batch may carry different schemes.
    pub scheme: Scheme,
}

/// Scalars a trip's dot modules returned to the controller.
#[derive(Debug, Clone, Copy, Default)]
pub struct DispatchReturn {
    /// M2's p . ap, when the trip ran M2.
    pub pap: Option<f64>,
    /// M6's r . z, when the trip ran M6.
    pub rz: Option<f64>,
    /// M8's r . r, when the trip ran M8.
    pub rr: Option<f64>,
}

/// Value-plane vector state: committed = HBM, staged = on-chip streams.
#[derive(Debug, Clone)]
pub struct VectorFile {
    /// The right-hand side (host memory; also preloaded into r).
    pub b: Vec<f64>,
    /// Committed x (HBM contents).
    pub x: Vec<f64>,
    /// Committed r.
    pub r: Vec<f64>,
    /// Committed p.
    pub p: Vec<f64>,
    /// Committed ap.
    pub ap: Vec<f64>,
    /// Staged x (this trip's on-chip stream).
    pub stage_x: Vec<f64>,
    /// Staged r.
    pub stage_r: Vec<f64>,
    /// Staged p.
    pub stage_p: Vec<f64>,
    /// Staged ap.
    pub stage_ap: Vec<f64>,
    /// z is on-chip only (§5.3): staged, never committed.
    pub stage_z: Vec<f64>,
    /// Block-CG handshake: a batch-wide SpMV already filled `stage_ap`
    /// for this lane's next M1, which consumes the staged stream (and
    /// clears the flag) instead of re-streaming the matrix.  In-band
    /// state, not wire format: the compiled M1 instruction is issued,
    /// traced, and write-acked exactly as before.
    pub block_ap_staged: bool,
    dirty: [bool; 4],
}

impl VectorFile {
    /// Host-side setup: x0 into x's region, b into *r's* region — the
    /// Fig. 4 merged init turns it into r = b - A x0 in place.
    pub fn new(b: &[f64], x0: &[f64]) -> Self {
        let n = b.len();
        Self {
            b: b.to_vec(),
            x: x0.to_vec(),
            r: b.to_vec(),
            p: vec![0.0; n],
            ap: vec![0.0; n],
            stage_x: vec![0.0; n],
            stage_r: vec![0.0; n],
            stage_p: vec![0.0; n],
            stage_ap: vec![0.0; n],
            stage_z: vec![0.0; n],
            block_ap_staged: false,
            dirty: [false; 4],
        }
    }

    /// Block-resident mode: the lane's vector data lives in the
    /// coordinator's interleaved lane-major block arenas for the whole
    /// solve, so this file holds **no elements at all** — the per-lane
    /// view is materialized only on fallback (executor declines the
    /// block protocol mid-solve) or at lane exit (the converged x is
    /// deinterleaved into `x` for the result).  The lane's bus still
    /// issues and acknowledges every compiled instruction against this
    /// file's addresses, so wire format, traces, and acks are identical
    /// to the per-lane path.
    pub fn resident() -> Self {
        Self {
            b: Vec::new(),
            x: Vec::new(),
            r: Vec::new(),
            p: Vec::new(),
            ap: Vec::new(),
            stage_x: Vec::new(),
            stage_r: Vec::new(),
            stage_p: Vec::new(),
            stage_ap: Vec::new(),
            stage_z: Vec::new(),
            block_ap_staged: false,
            dirty: [false; 4],
        }
    }

    fn dirty_idx(v: Vector) -> usize {
        match v {
            Vector::X => 0,
            Vector::R => 1,
            Vector::P => 2,
            Vector::Ap => 3,
            _ => panic!("{} has no committed slot", v.name()),
        }
    }

    /// Mark a staged vector as carrying this trip's output.
    pub fn mark_dirty(&mut self, v: Vector) {
        self.dirty[Self::dirty_idx(v)] = true;
    }

    /// Replace a staged vector wholesale (phase-granular backends).
    pub fn set_staged(&mut self, v: Vector, data: Vec<f64>) {
        match v {
            Vector::X => self.stage_x = data,
            Vector::R => self.stage_r = data,
            Vector::P => self.stage_p = data,
            Vector::Ap => self.stage_ap = data,
            Vector::Z => {
                self.stage_z = data;
                return; // on-chip only: no dirty bit, never committed
            }
            Vector::M => panic!("the diagonal is read-only"),
        }
        self.mark_dirty(v);
    }

    /// Retire a Type-III write: staged bits become the committed (HBM)
    /// contents.  Returns whether anything moved — a clean commit is a
    /// pure acknowledgement (e.g. a backend that already folded the
    /// write into an earlier trip).
    pub fn commit(&mut self, v: Vector) -> bool {
        let i = Self::dirty_idx(v);
        if !self.dirty[i] {
            return false;
        }
        match v {
            Vector::X => std::mem::swap(&mut self.x, &mut self.stage_x),
            Vector::R => std::mem::swap(&mut self.r, &mut self.stage_r),
            Vector::P => std::mem::swap(&mut self.p, &mut self.stage_p),
            Vector::Ap => std::mem::swap(&mut self.ap, &mut self.stage_ap),
            _ => unreachable!(),
        }
        self.dirty[i] = false;
        true
    }
}

/// A value-plane backend the bus can route a Type-II batch to.
///
/// `cmds` parallels `prog.comp_steps` with the controller scalars
/// already bound into each instruction's `alpha` field.  The native
/// backend interprets the batch instruction by instruction; a
/// phase-granular backend (the blanket [`PhaseExecutor`] impl, e.g.
/// PJRT) retires the whole batch as one artifact call.
pub trait InstDispatch {
    fn dispatch(
        &mut self,
        prog: &PhaseProgram,
        cmds: &[InstCmp],
        mem: &mut VectorFile,
    ) -> DispatchReturn;

    /// Block-CG SpMV over `lanes` interleaved lane-major vectors
    /// (`xs[col * lanes + lane]` -> `ys[row * lanes + lane]`): one pass
    /// over the matrix feeds every lane.  Return `true` to signal the
    /// results are valid — the coordinator then scatters `ys` into each
    /// lane's staged ap and the lanes' M1 instructions consume the
    /// staged stream instead of re-streaming the matrix.  The default
    /// declines (`false`), so backends without a batch kernel — the
    /// phase-granular [`PhaseExecutor`]s, the Serpens stream replay —
    /// transparently keep the per-lane SpMV.  An implementation must
    /// produce, per lane, bitwise the backend's own per-lane SpMV:
    /// batching is a traffic optimization, never a rounding change.
    fn batch_spmv(&mut self, _xs: &[f64], _ys: &mut [f64], _lanes: usize) -> bool {
        false
    }

    /// Rebind the precision scheme the backend's next SpMV decodes — a
    /// decode-width change, not a data move (the f32 value stream
    /// already exists beside the f64 one for the Mix schemes).  The
    /// adaptive-precision coordinator calls this before a trip whose
    /// bound scheme differs from the backend's.  The default ignores
    /// the bind: a backend that cannot switch simply keeps its built-in
    /// scheme (static-precision solves never call this).
    fn bind_scheme(&mut self, _scheme: Scheme) {}

    /// Scheme the backend's SpMV currently decodes.  Backends that
    /// honor [`bind_scheme`](Self::bind_scheme) must report the live
    /// binding; the default reports [`Scheme::default`].
    fn active_scheme(&self) -> Scheme {
        Scheme::default()
    }

    /// Whether this backend serves the **resident block vector ops**
    /// below — the batch-wide M2–M8 data plane of the coordinator's
    /// resident block mode.  The coordinator probes this once per chunk
    /// and degrades to staged / per-lane dispatch on `false` (the
    /// default), so the four ops are only ever called on a backend that
    /// advertised them; their defaults are unreachable.  An advertising
    /// backend must implement all four, each producing, per lane,
    /// bitwise its own per-lane module kernel — same contract as
    /// [`InstDispatch::batch_spmv`].
    fn block_vector_ops(&self) -> bool {
        false
    }

    /// Batch-wide M3/M4 axpy over an interleaved lane-major block:
    /// `ys[i·L + j] += alphas[j] · xs[i·L + j]` (`L = alphas.len()`).
    fn block_axpy(&mut self, _alphas: &[f64], _xs: &[f64], _ys: &mut [f64]) {
        unimplemented!("block_axpy called on a backend that does not advertise block_vector_ops")
    }

    /// Batch-wide M5 Jacobi left-divide: `zs[i·L + j] = rs[i·L + j] /
    /// m[i]`, the backend supplying its own diagonal `m` (the shared
    /// Vector::M region — one diagonal serves every lane).
    fn block_left_divide(&mut self, _rs: &[f64], _zs: &mut [f64], _lanes: usize) {
        unimplemented!(
            "block_left_divide called on a backend that does not advertise block_vector_ops"
        )
    }

    /// Batch-wide M7 direction update: `ps[i·L + j] = zs[i·L + j] +
    /// betas[j] · ps[i·L + j]` (`L = betas.len()`).
    fn block_update_p(&mut self, _betas: &[f64], _zs: &[f64], _ps: &mut [f64]) {
        unimplemented!(
            "block_update_p called on a backend that does not advertise block_vector_ops"
        )
    }

    /// Batch-wide M2/M6/M8 dot: `out[j] = <a lane j, b lane j>` for
    /// each of the `out.len()` lanes of two interleaved blocks, each
    /// lane's reduction bitwise the backend's per-lane dot.
    fn block_dots(&mut self, _a: &[f64], _b: &[f64], _out: &mut [f64]) {
        unimplemented!("block_dots called on a backend that does not advertise block_vector_ops")
    }
}

/// Scalar bound into module `m`'s instruction in this batch.  A missing
/// module is a compiled-program shape bug: fail fast rather than let a
/// silent 0.0 corrupt the solve.
fn bound_scalar(prog: &PhaseProgram, cmds: &[InstCmp], m: Module) -> f64 {
    prog.comp_steps
        .iter()
        .zip(cmds)
        .find(|(s, _)| s.module == m)
        .map(|(_, c)| c.alpha)
        .unwrap_or_else(|| {
            let trip = prog.kind.label();
            panic!("trip {trip} carries no {m:?} instruction to read a scalar from")
        })
}

/// Any [`PhaseExecutor`] (the PJRT artifact runtime, test doubles) is a
/// phase-granular instruction backend: the trip's Type-II batch maps to
/// one phase call, scalars are read back out of the bound instructions,
/// and results land in the staging file for the bus to commit.
impl<E: PhaseExecutor> InstDispatch for E {
    fn dispatch(
        &mut self,
        prog: &PhaseProgram,
        cmds: &[InstCmp],
        mem: &mut VectorFile,
    ) -> DispatchReturn {
        let mut ret = DispatchReturn::default();
        match prog.kind {
            TripKind::Init => {
                let (r, z, p, rz, rr) = self.init(&mem.x, &mem.b);
                let _ = z; // recomputed on-chip each phase (§5.3)
                mem.set_staged(Vector::R, r);
                mem.set_staged(Vector::P, p);
                ret.rz = Some(rz);
                ret.rr = Some(rr);
            }
            TripKind::Phase1 => {
                let (ap, pap) = self.phase1(&mem.p);
                mem.set_staged(Vector::Ap, ap);
                ret.pap = Some(pap);
            }
            TripKind::Phase2 => {
                let alpha = bound_scalar(prog, cmds, Module::M4);
                let (r1, rz, rr) = self.phase2(&mem.r, &mem.ap, alpha);
                // A phase-granular backend retires the r update here;
                // Phase-3's M4/M5 recompute (same inputs, same ops,
                // identical bits) is folded into its phase3 artifact,
                // so the Phase-3 write-back becomes a pure ack.
                mem.r = r1;
                ret.rz = Some(rz);
                ret.rr = Some(rr);
            }
            TripKind::Phase3 => {
                let alpha = bound_scalar(prog, cmds, Module::M3);
                let beta = bound_scalar(prog, cmds, Module::M7);
                let (p1, x1) = self.phase3(&mem.r, &mem.p, &mem.x, alpha, beta);
                mem.set_staged(Vector::P, p1);
                mem.set_staged(Vector::X, x1);
            }
            TripKind::ConvergedExit => {
                let alpha = bound_scalar(prog, cmds, Module::M3);
                let x1 = self.update_x_only(&mem.p, &mem.x, alpha);
                mem.set_staged(Vector::X, x1);
            }
        }
        ret
    }
}

/// The bus itself: owns the instruction trace and the ack counter.
#[derive(Debug, Default)]
pub struct InstructionBus {
    record: bool,
    trace: InstTrace,
    acks: Vec<MemResponse>,
    bound: Vec<InstCmp>,
}

impl InstructionBus {
    /// A fresh bus; `record` keeps a full [`InstTrace`] of every issue.
    pub fn new(record: bool) -> Self {
        Self { record, ..Default::default() }
    }

    /// Write acknowledgements collected so far (§4.2).
    pub fn acks(&self) -> &[MemResponse] {
        &self.acks
    }

    /// Drain the recorded instruction trace.
    pub fn take_trace(&mut self) -> InstTrace {
        std::mem::take(&mut self.trace)
    }

    /// Route one compiled trip: Type-I/III reads out, Type-II batch to
    /// the backend, Type-III write-backs committed and acknowledged.
    pub fn dispatch<D: InstDispatch>(
        &mut self,
        prog: &PhaseProgram,
        scalars: Scalars,
        exec: &mut D,
        mem: &mut VectorFile,
    ) -> DispatchReturn {
        self.dispatch_lane(prog, scalars, 0, exec, mem)
    }

    /// [`InstructionBus::dispatch`] for one lane of a batched program:
    /// the same compiled trip, with every per-RHS address (ap, p, x, r)
    /// rebased by `lane_offset_beats` at issue time and the lane's live
    /// scalars bound into the Type-II fields.  Reads of the shared
    /// diagonal M are **not** rebased — one matrix serves every lane,
    /// the block-CG traffic amortization the batch axis exists for.
    pub fn dispatch_lane<D: InstDispatch>(
        &mut self,
        prog: &PhaseProgram,
        scalars: Scalars,
        lane_offset_beats: u32,
        exec: &mut D,
        mem: &mut VectorFile,
    ) -> DispatchReturn {
        self.issue_reads(prog, lane_offset_beats, scalars.scheme);
        self.bind_cmds(prog, scalars);
        let ret = exec.dispatch(prog, &self.bound, mem);
        self.issue_writes(prog, lane_offset_beats, Some(mem));
        ret
    }

    /// Bookkeeping-only issue of one lane's trip for the **resident
    /// block path**: Type-I/III reads, Type-II binds, and Type-III
    /// write-back acks exactly as [`InstructionBus::dispatch_lane`] —
    /// same instructions, same rebased addresses, same trace, same ack
    /// sequence — but with no backend call and no [`VectorFile`]
    /// commits, because the lane's data plane runs batch-wide over the
    /// coordinator's lane-major arenas (whole-arena swaps play the
    /// commit role there).  This is what keeps the wire format and the
    /// §4.2 handshake observably unchanged while the element traffic
    /// moves to the block kernels.
    pub fn issue_lane(&mut self, prog: &PhaseProgram, scalars: Scalars, lane_offset_beats: u32) {
        self.issue_reads(prog, lane_offset_beats, scalars.scheme);
        self.bind_cmds(prog, scalars);
        self.issue_writes(prog, lane_offset_beats, None);
    }

    /// Stage 1 of a trip: trace the Type-I vector-control instructions
    /// and their Type-III read decompositions, with per-RHS addresses
    /// rebased by the lane offset (the shared diagonal M never rebases)
    /// and the lane's live precision scheme stamped into each Type-I
    /// word — same issue-time binding as alpha/beta in `bind_cmds`.
    fn issue_reads(&mut self, prog: &PhaseProgram, lane_offset_beats: u32, scheme: Scheme) {
        // Every trip — full dispatch or bookkeeping-only resident issue
        // — passes through here exactly once, so this is the one count
        // site for issued trips.
        crate::obs::catalog::PROGRAM_TRIPS_ISSUED.inc();
        let lane_off = |v: Vector| if v == Vector::M { 0 } else { lane_offset_beats };
        if self.record {
            for s in &prog.vec_steps {
                let mut vctrl = s.vctrl;
                vctrl.base_addr += lane_off(s.vector);
                vctrl.precision = scheme;
                self.trace.record(s.name, Instruction::VCtrl(vctrl));
                if let Some(mut rd) = s.rd_inst {
                    rd.base_addr += lane_off(s.vector);
                    self.trace.record(s.mem_name, Instruction::RdWr(rd));
                }
            }
        }
    }

    /// Stage 2 of a trip: bind the controller's live scalars into the
    /// Type-II batch (`self.bound`) and trace the bound instructions.
    fn bind_cmds(&mut self, prog: &PhaseProgram, scalars: Scalars) {
        self.bound.clear();
        for step in &prog.comp_steps {
            let mut inst = step.inst;
            inst.alpha = match step.bind {
                ScalarBind::Unbound => 0.0,
                ScalarBind::Alpha => scalars.alpha,
                ScalarBind::Beta => scalars.beta,
            };
            if self.record {
                self.trace.record(step.target, Instruction::Cmp(inst));
            }
            self.bound.push(inst);
        }
    }

    /// Stage 3 of a trip: issue the Type-III write-backs, committing the
    /// staged vectors when a [`VectorFile`] carries the lane's data
    /// (`None` on the resident path, where arena swaps commit instead)
    /// and collecting the [`MemResponse`] acks either way.
    fn issue_writes(
        &mut self,
        prog: &PhaseProgram,
        lane_offset_beats: u32,
        mut mem: Option<&mut VectorFile>,
    ) {
        let lane_off = |v: Vector| if v == Vector::M { 0 } else { lane_offset_beats };
        for s in &prog.vec_steps {
            if let Some(mut wr) = s.wr_inst {
                wr.base_addr += lane_off(s.vector);
                if self.record {
                    self.trace.record(s.mem_name, Instruction::RdWr(wr));
                }
                if let Some(m) = mem.as_deref_mut() {
                    m.commit(s.vector);
                }
                crate::obs::catalog::PROGRAM_WRITE_ACKS.inc();
                self.acks.push(MemResponse { base_addr: wr.base_addr, len: wr.len });
            }
        }
    }
}

/// One batch lane's *slice* of the dispatch state: its instruction bus
/// (trace + write acks), its value-plane [`VectorFile`], and its beat
/// offset in the batched memory map.  A slice shares nothing with the
/// other lanes of a batch — which is exactly what makes it the unit of
/// lane-parallel dispatch: a worker can drive one slice's trips while
/// other workers drive their own, and the per-lane arithmetic (hence
/// every bit of the result) is identical to the sequential lane walk.
#[derive(Debug)]
pub struct LaneSlice {
    /// The lane's instruction bus.
    pub bus: InstructionBus,
    /// The lane's value-plane vector state.
    pub mem: VectorFile,
    /// Beat offset of the lane's per-RHS regions
    /// ([`Program::lane_offset_beats`](super::Program::lane_offset_beats)).
    pub offset_beats: u32,
}

impl LaneSlice {
    /// A fresh slice for one lane: right-hand side `b`, start `x0`,
    /// `offset_beats` into the batched map; `record` keeps the full
    /// instruction trace.
    pub fn new(b: &[f64], x0: &[f64], offset_beats: u32, record: bool) -> Self {
        Self { bus: InstructionBus::new(record), mem: VectorFile::new(b, x0), offset_beats }
    }

    /// A slice for one lane of a **resident** block solve: the bus is
    /// live (every trip is issued and acked through it) but the
    /// [`VectorFile`] is the empty [`VectorFile::resident`] shell — the
    /// lane's elements live in the coordinator's block arenas until
    /// fallback or exit materializes them here.
    pub fn new_resident(offset_beats: u32, record: bool) -> Self {
        Self { bus: InstructionBus::new(record), mem: VectorFile::resident(), offset_beats }
    }

    /// Route one compiled trip for this lane
    /// (see [`InstructionBus::dispatch_lane`]).
    pub fn trip<D: InstDispatch>(
        &mut self,
        prog: &PhaseProgram,
        scalars: Scalars,
        exec: &mut D,
    ) -> DispatchReturn {
        self.bus.dispatch_lane(prog, scalars, self.offset_beats, exec, &mut self.mem)
    }

    /// Bookkeeping-only issue of one compiled trip for this lane
    /// (see [`InstructionBus::issue_lane`]): the resident block path's
    /// per-lane half — instructions and acks without data movement.
    pub fn issue(&mut self, prog: &PhaseProgram, scalars: Scalars) {
        self.bus.issue_lane(prog, scalars, self.offset_beats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::ChannelMode;
    use crate::program::Program;

    #[test]
    fn vector_file_commit_swaps_only_dirty_slots() {
        let b = vec![1.0, 2.0, 3.0];
        let mut vf = VectorFile::new(&b, &[0.0, 0.0, 0.0]);
        assert_eq!(vf.r, b, "r is preloaded with b (merged init)");
        assert!(!vf.commit(Vector::X), "clean commit is a pure ack");
        vf.set_staged(Vector::X, vec![9.0, 9.0, 9.0]);
        assert!(vf.commit(Vector::X));
        assert_eq!(vf.x, vec![9.0, 9.0, 9.0]);
        assert!(!vf.commit(Vector::X), "dirty bit cleared after commit");
    }

    #[test]
    fn bus_records_and_acks_one_trip() {
        let prog = Program::compile(64, ChannelMode::Double);
        let mut bus = InstructionBus::new(true);
        let mut mem = VectorFile::new(&[1.0; 64], &[0.0; 64]);

        // A do-nothing backend: the bus bookkeeping is what's under test.
        struct Null;
        impl InstDispatch for Null {
            fn dispatch(
                &mut self,
                _p: &PhaseProgram,
                _c: &[InstCmp],
                _m: &mut VectorFile,
            ) -> DispatchReturn {
                DispatchReturn::default()
            }
        }
        let p1 = prog.phase(crate::vsr::Phase::Phase1);
        bus.dispatch(p1, Scalars::default(), &mut Null, &mut mem);
        // Phase-1: 2 reads + 1 write + 2 Type-I + 2 Type-II.
        assert_eq!(bus.acks().len(), 1);
        let trace = bus.take_trace();
        assert_eq!(trace.count_for("M1"), 1);
        assert_eq!(trace.count_for("M2"), 1);
        assert_eq!(trace.count_for("VecCtrl-p"), 2);
        assert_eq!(trace.count_for("VecCtrl-p/mem"), 2);
        assert_eq!(trace.count_for("VecCtrl-ap/mem"), 1);
    }

    #[test]
    fn issue_binds_the_precision_scheme_into_every_type_i_word() {
        // The precision scalar is bound at issue time like alpha/beta:
        // whatever scheme the Scalars carry is what every traced Type-I
        // word of the trip reports, for all four schemes.
        struct Null;
        impl InstDispatch for Null {
            fn dispatch(
                &mut self,
                _p: &PhaseProgram,
                _c: &[InstCmp],
                _m: &mut VectorFile,
            ) -> DispatchReturn {
                DispatchReturn::default()
            }
        }
        let prog = Program::compile(64, ChannelMode::Double);
        for scheme in Scheme::ALL {
            let mut bus = InstructionBus::new(true);
            let mut mem = VectorFile::new(&[1.0; 64], &[0.0; 64]);
            for trip in prog.all_trips() {
                bus.dispatch(trip, Scalars { alpha: 0.5, beta: 0.25, scheme }, &mut Null, &mut mem);
            }
            let trace = bus.take_trace();
            let mut type_i = 0;
            for (_, inst) in &trace.issued {
                if let Instruction::VCtrl(v) = inst {
                    assert_eq!(v.precision, scheme, "Type-I word not stamped with {scheme:?}");
                    type_i += 1;
                }
            }
            assert!(type_i > 0, "the five trips must issue Type-I words");
        }
    }

    #[test]
    fn lane_slice_trip_is_dispatch_lane_on_the_bundled_state() {
        struct Null;
        impl InstDispatch for Null {
            fn dispatch(
                &mut self,
                _p: &PhaseProgram,
                _c: &[InstCmp],
                _m: &mut VectorFile,
            ) -> DispatchReturn {
                DispatchReturn::default()
            }
        }
        let prog = Program::compile_batched(64, ChannelMode::Double, 2);
        let off = prog.lane_offset_beats(1);
        let p1 = prog.phase(crate::vsr::Phase::Phase1);

        let mut slice = LaneSlice::new(&[1.0; 64], &[0.0; 64], off, true);
        slice.trip(p1, Scalars::default(), &mut Null);

        let mut bus = InstructionBus::new(true);
        let mut mem = VectorFile::new(&[1.0; 64], &[0.0; 64]);
        bus.dispatch_lane(p1, Scalars::default(), off, &mut Null, &mut mem);

        assert_eq!(slice.bus.acks(), bus.acks());
        assert_eq!(slice.bus.take_trace().issued, bus.take_trace().issued);
    }

    #[test]
    fn issue_lane_bookkeeping_is_bitwise_the_dispatch_lane_bookkeeping() {
        // The resident block path's contract: issuing a trip without a
        // backend produces exactly the trace and ack sequence of a full
        // dispatch — wire format unchanged, only the data plane moved.
        struct Null;
        impl InstDispatch for Null {
            fn dispatch(
                &mut self,
                _p: &PhaseProgram,
                _c: &[InstCmp],
                _m: &mut VectorFile,
            ) -> DispatchReturn {
                DispatchReturn::default()
            }
        }
        let prog = Program::compile_batched(64, ChannelMode::Double, 4);
        let off = prog.lane_offset_beats(2);
        let scalars = Scalars { alpha: 0.75, beta: -0.125, scheme: Scheme::MixV2 };
        for trip in prog.all_trips() {
            let mut full = InstructionBus::new(true);
            let mut mem = VectorFile::new(&[1.0; 64], &[0.0; 64]);
            full.dispatch_lane(trip, scalars, off, &mut Null, &mut mem);

            let mut issue_only = InstructionBus::new(true);
            issue_only.issue_lane(trip, scalars, off);

            assert_eq!(issue_only.acks(), full.acks(), "{} acks drifted", trip.kind.label());
            assert_eq!(
                issue_only.take_trace().issued,
                full.take_trace().issued,
                "{} trace drifted",
                trip.kind.label()
            );
        }
    }

    #[test]
    fn dispatch_lane_rebases_per_rhs_addresses_but_not_the_diagonal() {
        struct Null;
        impl InstDispatch for Null {
            fn dispatch(
                &mut self,
                _p: &PhaseProgram,
                _c: &[InstCmp],
                _m: &mut VectorFile,
            ) -> DispatchReturn {
                DispatchReturn::default()
            }
        }
        let prog = Program::compile_batched(64, ChannelMode::Double, 4);
        let off = prog.lane_offset_beats(3);
        assert!(off > 0);
        let mut bus = InstructionBus::new(true);
        let mut mem = VectorFile::new(&[1.0; 64], &[0.0; 64]);
        let p3 = prog.phase(crate::vsr::Phase::Phase3);
        bus.dispatch_lane(
            p3,
            Scalars { alpha: 0.5, beta: 0.25, scheme: Scheme::default() },
            off,
            &mut Null,
            &mut mem,
        );
        let trace = bus.take_trace();
        for (target, inst) in &trace.issued {
            let (vector, compiled_addr) = match p3
                .vec_steps
                .iter()
                .find(|s| s.name == *target || s.mem_name == *target)
            {
                Some(s) => (s.vector, s.vctrl.base_addr),
                None => continue, // Type-II targets carry no address
            };
            let addr = match inst {
                Instruction::VCtrl(v) => v.base_addr,
                Instruction::RdWr(m) => m.base_addr,
                Instruction::Cmp(_) => continue,
            };
            use crate::program::mem_map::CHANNEL_WINDOW_BEATS as W;
            if vector == Vector::M {
                assert_eq!(addr % W, 0, "the shared diagonal is never rebased");
            } else {
                // Rebased exactly one lane-3 stride past the compiled
                // lane-0 address (modulo the channel the word targets).
                assert_eq!(addr % W, (compiled_addr + off) % W);
            }
        }
        // The write acks came back with the rebased addresses too.
        use crate::program::mem_map::CHANNEL_WINDOW_BEATS as W;
        assert_eq!(bus.acks().len(), 3, "phase-3 writes back p, r, x");
        assert!(bus.acks().iter().all(|a| a.base_addr % W == off));
    }
}
