//! Processing-module layer: the Fig. 6 decentralized-scheduling FSMs and
//! the value-plane behaviours of modules M1–M8.
//!
//! The FSMs are data (state tables), not threads: the program builder
//! (`crate::program::builder`) walks their states to compile the
//! Type-I/III vector-control steps and the Type-II stream endpoints,
//! and the tests assert they encode exactly the schedules of Fig. 6.
//! The compute behaviours are the element-stream semantics each module
//! applies — what the native instruction interpreter dispatches.

pub mod compute;
pub mod fsm;

pub use compute::{ComputeModule, ModuleOutput};
pub use fsm::{CompState, Endpoint, ModuleFsm, VecCtrlState};
