//! The decentralized vector-scheduling FSMs of Fig. 6.
//!
//! §5.5: rather than one controller juggling 23 FIFOs, every vector
//! control module (a)–(e) and every computation module (f)–(m) owns a
//! small FSM that steps once per phase-visit.  The tables here are the
//! exact schedules drawn in Fig. 6; the coordinator advances them and
//! the tests pin them against the figure.

use crate::vsr::{Module, Phase, Vector};

/// Where a stream comes from / goes to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Endpoint {
    /// HBM, through the vector's memory module.
    Memory,
    /// An on-chip stream to/from another computation module.
    Module(Module),
    /// Scalar delivered to the global controller (dot modules).
    Controller,
}

/// One state of a vector-control FSM: what this vector does in one phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VecCtrlState {
    /// The Fig. 5 phase this state belongs to.
    pub phase: Phase,
    /// Read from memory toward this module (None = no read).
    pub rd_to: Option<Module>,
    /// Write to memory from this module (None = no write).
    pub wr_from: Option<Module>,
}

/// One state of a computation-module FSM (Fig. 6 f–m): input streams on
/// the left, output streams on the right.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompState {
    /// The Fig. 5 phase this state belongs to.
    pub phase: Phase,
    /// (vector, source).
    pub inputs: Vec<(Vector, Endpoint)>,
    /// (vector, destination).
    pub outputs: Vec<(Vector, Endpoint)>,
}

/// A whole FSM: the cyclic state list (one full cycle == one iteration).
#[derive(Debug, Clone)]
pub struct ModuleFsm<S> {
    /// The module's trace-target id.
    pub name: &'static str,
    /// One full cycle of states == one iteration.
    pub states: Vec<S>,
    /// Index of the state [`ModuleFsm::step`] returns next.
    pub current: usize,
}

impl<S: Clone> ModuleFsm<S> {
    /// An FSM starting at its first state.
    pub fn new(name: &'static str, states: Vec<S>) -> Self {
        Self { name, states, current: 0 }
    }

    /// Advance to the next state, wrapping at the end of the iteration.
    pub fn step(&mut self) -> &S {
        let s = &self.states[self.current];
        self.current = (self.current + 1) % self.states.len();
        s
    }

    /// The state [`ModuleFsm::step`] would return, without advancing.
    pub fn peek(&self) -> &S {
        &self.states[self.current]
    }

    /// True when a full iteration of states has been traversed.
    pub fn at_start(&self) -> bool {
        self.current == 0
    }
}

/// Fig. 6 (a): vector p — Rd->M1 (P1.1), Rd->M2 (P1.2), RdWr<->M7/M3 (P3).
pub fn vecctrl_p() -> ModuleFsm<VecCtrlState> {
    ModuleFsm::new(
        "VecCtrl-p",
        vec![
            VecCtrlState { phase: Phase::Phase1, rd_to: Some(Module::M1), wr_from: None },
            VecCtrlState { phase: Phase::Phase1, rd_to: Some(Module::M2), wr_from: None },
            VecCtrlState { phase: Phase::Phase3, rd_to: Some(Module::M7), wr_from: Some(Module::M7) },
        ],
    )
}

/// Fig. 6 (b): vector r — Rd->M4 (P2), RdWr<->M4/M5 (P3).
pub fn vecctrl_r() -> ModuleFsm<VecCtrlState> {
    ModuleFsm::new(
        "VecCtrl-r",
        vec![
            VecCtrlState { phase: Phase::Phase2, rd_to: Some(Module::M4), wr_from: None },
            VecCtrlState { phase: Phase::Phase3, rd_to: Some(Module::M4), wr_from: Some(Module::M5) },
        ],
    )
}

/// Fig. 6 (c): vector x — RdWr<->M3 (P3 only).
pub fn vecctrl_x() -> ModuleFsm<VecCtrlState> {
    ModuleFsm::new(
        "VecCtrl-x",
        vec![VecCtrlState { phase: Phase::Phase3, rd_to: Some(Module::M3), wr_from: Some(Module::M3) }],
    )
}

/// Fig. 6 (d): vector ap — Wr<-M1 (P1), Rd->M4 (P2), Rd->M4 (P3 recompute).
pub fn vecctrl_ap() -> ModuleFsm<VecCtrlState> {
    ModuleFsm::new(
        "VecCtrl-ap",
        vec![
            VecCtrlState { phase: Phase::Phase1, rd_to: None, wr_from: Some(Module::M1) },
            VecCtrlState { phase: Phase::Phase2, rd_to: Some(Module::M4), wr_from: None },
            VecCtrlState { phase: Phase::Phase3, rd_to: Some(Module::M4), wr_from: None },
        ],
    )
}

/// Fig. 6 (e): the Jacobi diagonal M — Rd->M5 in P2 and P3.
pub fn vecctrl_m() -> ModuleFsm<VecCtrlState> {
    ModuleFsm::new(
        "VecCtrl-M",
        vec![
            VecCtrlState { phase: Phase::Phase2, rd_to: Some(Module::M5), wr_from: None },
            VecCtrlState { phase: Phase::Phase3, rd_to: Some(Module::M5), wr_from: None },
        ],
    )
}

/// Fig. 6 (f)–(m): computation-module FSMs.
pub fn comp_fsm(m: Module) -> ModuleFsm<CompState> {
    use Endpoint::{Memory, Module as ModEp};
    use Vector::*;
    let fsm = |name, states| ModuleFsm::new(name, states);
    match m {
        Module::M1 => fsm(
            "M1:spmv",
            vec![CompState {
                phase: Phase::Phase1,
                inputs: vec![(P, Memory)],
                outputs: vec![(Ap, ModEp(Module::M2)), (Ap, Memory)],
            }],
        ),
        Module::M2 => fsm(
            "M2:dot-alpha",
            vec![CompState {
                phase: Phase::Phase1,
                inputs: vec![(P, Memory), (Ap, ModEp(Module::M1))],
                outputs: vec![], // scalar pap -> controller
            }],
        ),
        Module::M3 => fsm(
            "M3:update-x",
            vec![CompState {
                phase: Phase::Phase3,
                inputs: vec![(X, Memory), (P, ModEp(Module::M7))],
                outputs: vec![(X, Memory)],
            }],
        ),
        Module::M4 => fsm(
            "M4:update-r",
            vec![
                CompState {
                    phase: Phase::Phase2,
                    inputs: vec![(R, Memory), (Ap, Memory)],
                    outputs: vec![(R, ModEp(Module::M5))],
                },
                CompState {
                    phase: Phase::Phase3,
                    inputs: vec![(R, Memory), (Ap, Memory)],
                    outputs: vec![(R, ModEp(Module::M5))],
                },
            ],
        ),
        Module::M5 => fsm(
            "M5:left-divide",
            vec![
                // §5.5's worked example: state 1 (P2) sends z and r to M6;
                // state 2 (P3) sends z to M7 and r to memory.
                CompState {
                    phase: Phase::Phase2,
                    inputs: vec![(M, Memory), (R, ModEp(Module::M4))],
                    outputs: vec![(Z, ModEp(Module::M6)), (R, ModEp(Module::M6))],
                },
                CompState {
                    phase: Phase::Phase3,
                    inputs: vec![(M, Memory), (R, ModEp(Module::M4))],
                    outputs: vec![(Z, ModEp(Module::M7)), (R, Memory)],
                },
            ],
        ),
        Module::M6 => fsm(
            "M6:dot-rz",
            vec![CompState {
                phase: Phase::Phase2,
                inputs: vec![(R, ModEp(Module::M5)), (Z, ModEp(Module::M5))],
                outputs: vec![(R, ModEp(Module::M8))], // scalar rz -> controller
            }],
        ),
        Module::M7 => fsm(
            "M7:update-p",
            vec![CompState {
                phase: Phase::Phase3,
                inputs: vec![(Z, ModEp(Module::M5)), (P, Memory)],
                outputs: vec![(P, ModEp(Module::M3)), (P, Memory)],
            }],
        ),
        Module::M8 => fsm(
            "M8:dot-rr",
            vec![CompState {
                phase: Phase::Phase2,
                inputs: vec![(R, ModEp(Module::M6))],
                outputs: vec![], // scalar rr -> controller
            }],
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vsr::{accesses_with_vsr, count_accesses};

    #[test]
    fn vecctrl_fsms_match_fig6_state_counts() {
        assert_eq!(vecctrl_p().states.len(), 3);
        assert_eq!(vecctrl_r().states.len(), 2);
        assert_eq!(vecctrl_x().states.len(), 1);
        assert_eq!(vecctrl_ap().states.len(), 3);
        assert_eq!(vecctrl_m().states.len(), 2);
    }

    #[test]
    fn fsm_steps_cycle_per_iteration() {
        let mut p = vecctrl_p();
        assert!(p.at_start());
        p.step();
        p.step();
        p.step();
        assert!(p.at_start(), "3 states == one iteration for p");
    }

    /// The union of all FSM memory ops must equal the §5.5 access table
    /// (10 reads, 4 writes) — the FSMs *are* the decentralized encoding
    /// of that table.
    #[test]
    fn fsm_memory_ops_total_14_accesses() {
        let fsms = [vecctrl_p(), vecctrl_r(), vecctrl_x(), vecctrl_ap(), vecctrl_m()];
        let reads: usize = fsms.iter().flat_map(|f| &f.states).filter(|s| s.rd_to.is_some()).count();
        let writes: usize =
            fsms.iter().flat_map(|f| &f.states).filter(|s| s.wr_from.is_some()).count();
        let (r, w) = count_accesses(&accesses_with_vsr());
        assert_eq!((reads, writes), (r, w), "FSMs encode the Fig. 5 access schedule");
    }

    #[test]
    fn m5_states_match_paper_worked_example() {
        let fsm = comp_fsm(Module::M5);
        assert_eq!(fsm.states.len(), 2);
        let s1 = &fsm.states[0];
        assert_eq!(s1.phase, Phase::Phase2);
        assert!(s1.outputs.contains(&(Vector::Z, Endpoint::Module(Module::M6))));
        assert!(s1.outputs.contains(&(Vector::R, Endpoint::Module(Module::M6))));
        let s2 = &fsm.states[1];
        assert_eq!(s2.phase, Phase::Phase3);
        assert!(s2.outputs.contains(&(Vector::Z, Endpoint::Module(Module::M7))));
        assert!(s2.outputs.contains(&(Vector::R, Endpoint::Memory)));
    }

    #[test]
    fn phase2_chain_is_m4_m5_m6_m8() {
        // r flows M4 -> M5 -> M6 -> M8 without touching memory.
        let m5_in = &comp_fsm(Module::M5).states[0].inputs;
        assert!(m5_in.contains(&(Vector::R, Endpoint::Module(Module::M4))));
        let m6_in = &comp_fsm(Module::M6).states[0].inputs;
        assert!(m6_in.contains(&(Vector::R, Endpoint::Module(Module::M5))));
        let m8_in = &comp_fsm(Module::M8).states[0].inputs;
        assert!(m8_in.contains(&(Vector::R, Endpoint::Module(Module::M6))));
    }

    #[test]
    fn dot_modules_emit_no_vector_stream() {
        for m in [Module::M2, Module::M8] {
            for s in &comp_fsm(m).states {
                assert!(s.outputs.iter().all(|(_, e)| *e != Endpoint::Memory));
            }
        }
    }
}
