//! Value-plane behaviours of the eight computation modules, expressed as
//! whole-stream operations (the element-wise semantics each II=1
//! pipeline applies).  The coordinator composes these when it executes
//! an iteration natively; they are also the unit under test for the
//! module-level equivalence checks against the Pallas kernels' refs.

use crate::precision::{dot_delay_buffer, Scheme};
use crate::sparse::{CsrMatrix, NnzStream};

/// What a module hands back to the coordinator.
#[derive(Debug, Clone)]
pub enum ModuleOutput {
    /// A produced/updated vector (streamed onward or written back).
    Vector(Vec<f64>),
    /// A scalar delivered to the global controller.
    Scalar(f64),
}

/// A computation module: one function, no opcode (§4.1.2).
pub trait ComputeModule {
    /// The module's descriptive id.
    fn name(&self) -> &'static str;
}

/// M1 — SpMV over the packed nnz streams (Fig. 8).
pub struct SpMvModule<'a> {
    /// The scheduled Serpens nnz streams to replay.
    pub stream: &'a NnzStream,
}

impl<'a> SpMvModule<'a> {
    /// ap = A p via stream replay (Mix-V3 arithmetic: the stream carries
    /// f32 values, x / y are f64).
    pub fn run(&self, p: &[f64]) -> Vec<f64> {
        let mut ap = vec![0.0; self.stream.n];
        self.stream.replay_mixv3(p, &mut ap);
        ap
    }

    /// FP64 variant (SerpensCG / XcgSolver): same schedule, f64 values
    /// taken from the master matrix.
    pub fn run_fp64(&self, a: &CsrMatrix, p: &[f64]) -> Vec<f64> {
        let mut ap = vec![0.0; a.n];
        a.spmv_f64(p, &mut ap);
        ap
    }
}

impl ComputeModule for SpMvModule<'_> {
    fn name(&self) -> &'static str {
        "M1:spmv"
    }
}

/// M2/M6/M8 — delay-buffer dot product.
pub struct DotModule;

impl DotModule {
    /// a . b through the 8-lane delay buffer.
    pub fn run(&self, a: &[f64], b: &[f64]) -> f64 {
        dot_delay_buffer(a, b)
    }
}

impl ComputeModule for DotModule {
    fn name(&self) -> &'static str {
        "dot"
    }
}

/// M3/M4 — axpy update (M3: +alpha, M4: -alpha via the instruction's
/// alpha field).
pub struct AxpyModule;

impl AxpyModule {
    /// y += alpha * x, element-wise in index order.
    pub fn run(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }
}

impl ComputeModule for AxpyModule {
    fn name(&self) -> &'static str {
        "axpy"
    }
}

/// M5 — left divide: z = r / m element-wise.
pub struct LeftDivideModule;

impl LeftDivideModule {
    /// z = r / m, element-wise.
    pub fn run(&self, r: &[f64], m: &[f64], z: &mut [f64]) {
        for ((zi, ri), mi) in z.iter_mut().zip(r).zip(m) {
            *zi = ri / mi;
        }
    }
}

impl ComputeModule for LeftDivideModule {
    fn name(&self) -> &'static str {
        "M5:left-divide"
    }
}

/// M7 — update p: p' = z + beta p.
pub struct UpdatePModule;

impl UpdatePModule {
    /// p = z + beta * p, element-wise.
    pub fn run(&self, beta: f64, z: &[f64], p: &mut [f64]) {
        for (pi, zi) in p.iter_mut().zip(z) {
            *pi = zi + beta * *pi;
        }
    }
}

impl ComputeModule for UpdatePModule {
    fn name(&self) -> &'static str {
        "M7:update-p"
    }
}

/// Bytes a module moves per invocation on vectors of length n — feeds
/// the metrics plane (scheme affects only M1's stream, handled there).
pub fn vector_bytes_per_call(n: usize, _scheme: Scheme) -> u64 {
    8 * n as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{pack_nnz_streams, synth, DEP_DIST_SERPENS};

    #[test]
    fn spmv_module_matches_csr_reference() {
        let a = synth::banded_spd(600, 5000, 1e-2, 11);
        let stream = pack_nnz_streams(&a, DEP_DIST_SERPENS);
        let m1 = SpMvModule { stream: &stream };
        let p: Vec<f64> = (0..a.n).map(|i| ((i * 13) % 29) as f64 / 29.0).collect();
        let ap = m1.run(&p);
        // Mix-V3 reference.
        let mut want = vec![0.0; a.n];
        for i in 0..a.n {
            let (cols, vals) = a.row(i);
            for (c, v) in cols.iter().zip(vals) {
                want[i] += (*v as f32) as f64 * p[*c as usize];
            }
        }
        for i in 0..a.n {
            assert!((ap[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0));
        }
    }

    #[test]
    fn axpy_and_update_p_semantics() {
        let mut y = vec![1.0, 2.0, 3.0];
        AxpyModule.run(-0.5, &[2.0, 2.0, 2.0], &mut y);
        assert_eq!(y, vec![0.0, 1.0, 2.0]);
        let mut p = vec![1.0, 1.0];
        UpdatePModule.run(2.0, &[3.0, 4.0], &mut p);
        assert_eq!(p, vec![5.0, 6.0]);
    }

    #[test]
    fn left_divide_is_elementwise() {
        let mut z = vec![0.0; 3];
        LeftDivideModule.run(&[2.0, 9.0, -4.0], &[2.0, 3.0, 4.0], &mut z);
        assert_eq!(z, vec![1.0, 3.0, -1.0]);
    }
}
