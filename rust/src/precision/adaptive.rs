//! Adaptive mixed precision: a precision **controller**, not a fixed
//! scheme (ROADMAP item 2).
//!
//! Callipepla's Mix-V3 is static — one [`Scheme`] for the whole solve
//! (§6).  The richer design (Neko-mp's `cg_mp` `switch_iter`, and the
//! reduced-precision FPGA CG of Korcyl & Korcyl, arXiv:1811.03683) runs
//! early iterations cheap and escalates to FP64 only when convergence
//! stalls or the tolerance boundary nears.  This module implements that
//! as a *deterministic* policy over the per-iteration residual history:
//!
//! * [`AdaptivePolicy`] — the knobs: start scheme, escalation target,
//!   stall detector window/ratio, and the tolerance guard band.
//! * [`PrecisionController`] — the per-solve state machine.  It is fed
//!   the squared residual `rr` after every SpMV pass (the value M8
//!   already returns to the controller) and answers "which scheme does
//!   the *next* pass decode?".  Decisions are a pure function of the
//!   residual sequence, so every execution path — serial `jpcg_solve`,
//!   lane-parallel dispatch, staged and resident block-CG — emits the
//!   identical [`PrecisionTrace`] (pinned in `tests/adaptive_precision.rs`).
//! * [`PrecisionTrace`] — the per-solve record (pass → scheme + reason),
//!   serializable to CSV and **replayable**: a controller built with
//!   [`PrecisionController::replay`] reproduces the recorded schedule
//!   exactly, so a replayed solve reproduces `x` bitwise.
//!
//! A scheme switch is a *decode-width* change, not a data move: the f32
//! value stream already exists beside the f64 one for the Mix schemes
//! (`PreparedMatrix` caches both), so escalation just changes which
//! stream M1 consumes — and what the time plane charges per nnz
//! ([`PrecisionTrace::modeled_m1_bytes`]).

use super::Scheme;
use std::collections::VecDeque;

/// Knobs of the deterministic adaptive-precision policy.
///
/// The controller runs `start` until **either** trigger fires, then
/// switches to `escalate_to` for the rest of the solve (escalation is
/// sticky — precision only ever widens, mirroring Neko-mp's one-way
/// `switch_iter`):
///
/// * **Guard band** — the squared residual has come within a factor
///   `guard_band` of the solve tolerance (`rr <= guard_band * tol`):
///   the tolerance boundary nears, so the final approach runs at full
///   precision and converges like a pure-FP64 solve.
/// * **Stall** — progress over the last `stall_window` observations is
///   less than a factor `1 / stall_ratio` (`rr > stall_ratio *
///   rr[stall_window ago]`): reduced precision has stopped buying
///   convergence, so keeping it only burns iterations.
///   `stall_window = 0` disables the detector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptivePolicy {
    /// Scheme for the early, cheap iterations.
    pub start: Scheme,
    /// Scheme after escalation (sticky for the rest of the solve).
    pub escalate_to: Scheme,
    /// Stall detector lookback, in residual observations (0 = off).
    pub stall_window: u32,
    /// Escalate when `rr > stall_ratio * rr[stall_window ago]` — i.e.
    /// the squared residual dropped by less than `1 - stall_ratio` over
    /// the window.
    pub stall_ratio: f64,
    /// Escalate when `rr <= guard_band * tol` (tolerance approach).
    pub guard_band: f64,
}

impl Default for AdaptivePolicy {
    /// Callipepla-flavoured defaults: start on the shipping Mix-V3
    /// stream (half the nnz bytes), escalate to FP64 when within 100×
    /// of tolerance or when 8 iterations drop the squared residual by
    /// less than 10%.
    fn default() -> Self {
        Self {
            start: Scheme::MixV3,
            escalate_to: Scheme::Fp64,
            stall_window: 8,
            stall_ratio: 0.9,
            guard_band: 100.0,
        }
    }
}

impl AdaptivePolicy {
    /// Does any scheme this policy can select stream f32 matrix values
    /// (i.e. must the caller derive the f32 view of the matrix)?
    pub fn needs_f32(&self) -> bool {
        self.start.matrix_f32() || self.escalate_to.matrix_f32()
    }
}

/// How a solve chooses its per-pass precision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PrecisionMode {
    /// One fixed scheme for the whole solve (the paper's model).  In
    /// the coordinator this mode is *inert*: the executor keeps
    /// whatever scheme it was built with, exactly as before this mode
    /// existed.
    Static(Scheme),
    /// The deterministic residual-driven controller of this module.
    Adaptive(AdaptivePolicy),
}

impl Default for PrecisionMode {
    fn default() -> Self {
        PrecisionMode::Static(Scheme::default())
    }
}

/// Why a [`PrecisionEvent`] selected its scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwitchReason {
    /// Fixed-scheme solve: the one scheme it ran start to finish.
    Static,
    /// The policy's start scheme, in force from the init pass.
    Start,
    /// Escalated because `rr <= guard_band * tol`.
    GuardBand,
    /// Escalated because the stall detector fired.
    Stall,
}

impl SwitchReason {
    /// Short lowercase id (the CSV `reason` column).
    pub fn name(self) -> &'static str {
        match self {
            SwitchReason::Static => "static",
            SwitchReason::Start => "start",
            SwitchReason::GuardBand => "guard-band",
            SwitchReason::Stall => "stall",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "static" => Some(SwitchReason::Static),
            "start" => Some(SwitchReason::Start),
            "guard-band" => Some(SwitchReason::GuardBand),
            "stall" => Some(SwitchReason::Stall),
            _ => None,
        }
    }
}

/// One precision decision: from SpMV pass `pass` (inclusive) onward,
/// the solve decodes `scheme`.
///
/// Passes are numbered like the M1 trips: pass 0 is the merged-init
/// SpMV (`A·x0`), pass k ≥ 1 is iteration k's Phase-1 SpMV.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrecisionEvent {
    /// First SpMV pass executed under `scheme`.
    pub pass: u32,
    /// The scheme in force from that pass on.
    pub scheme: Scheme,
    /// What triggered the decision.
    pub reason: SwitchReason,
}

/// The per-solve precision record: an ordered list of change points.
///
/// Serializable ([`to_csv`](Self::to_csv) / [`from_csv`](Self::from_csv))
/// and replayable ([`PrecisionController::replay`]): re-running a solve
/// under a recorded trace reproduces `x` bitwise, because the schedule —
/// not the residuals — drives every decode-width choice.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct PrecisionTrace {
    events: Vec<PrecisionEvent>,
}

impl PrecisionTrace {
    /// Append a change point.  `pass` values must be non-decreasing
    /// (the controller appends in pass order).
    pub fn push(&mut self, event: PrecisionEvent) {
        debug_assert!(
            !self.events.last().is_some_and(|e| e.pass > event.pass),
            "precision events must be pushed in pass order"
        );
        self.events.push(event);
    }

    /// The recorded change points, in pass order.
    pub fn events(&self) -> &[PrecisionEvent] {
        &self.events
    }

    /// Number of change points (a static solve records exactly one).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True if nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Scheme in force for SpMV pass `pass`: the last event at or
    /// before it.  An empty trace (or a pass before the first event)
    /// falls back to the first event's scheme / [`Scheme::default`].
    pub fn scheme_at(&self, pass: u32) -> Scheme {
        let mut s = self.events.first().map_or(Scheme::default(), |e| e.scheme);
        for e in &self.events {
            if e.pass <= pass {
                s = e.scheme;
            } else {
                break;
            }
        }
        s
    }

    /// Did the solve ever switch scheme mid-flight?
    pub fn switched(&self) -> bool {
        self.events.len() > 1
    }

    /// Time-plane M1 traffic of a solve that ran `iters` iterations
    /// under this schedule: passes `0..=iters` each stream `nnz` values
    /// at the *active* scheme's [`Scheme::nnz_bytes`].  This is the
    /// quantity the adaptive Table-7 gate compares against static FP64
    /// (`(iters + 1) * nnz * 16`).
    pub fn modeled_m1_bytes(&self, nnz: u64, iters: u32) -> u64 {
        (0..=iters).map(|p| nnz * self.scheme_at(p).nnz_bytes()).sum()
    }

    /// Serialize as CSV (`pass,scheme,reason` header + one row per
    /// change point).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("pass,scheme,reason\n");
        for e in &self.events {
            out.push_str(&format!("{},{},{}\n", e.pass, e.scheme.name(), e.reason.name()));
        }
        out
    }

    /// Parse the [`to_csv`](Self::to_csv) format (header optional).
    /// Rejects unknown schemes/reasons and out-of-order passes.
    pub fn from_csv(text: &str) -> Result<Self, String> {
        let mut trace = PrecisionTrace::default();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line == "pass,scheme,reason" {
                continue;
            }
            let mut cols = line.split(',');
            let (pass, scheme, reason) = (cols.next(), cols.next(), cols.next());
            let (Some(pass), Some(scheme), Some(reason), None) = (pass, scheme, reason, cols.next())
            else {
                return Err(format!("line {}: expected `pass,scheme,reason`", ln + 1));
            };
            let pass: u32 =
                pass.trim().parse().map_err(|e| format!("line {}: bad pass: {e}", ln + 1))?;
            let scheme = Scheme::from_name(scheme.trim())
                .ok_or_else(|| format!("line {}: unknown scheme `{}`", ln + 1, scheme.trim()))?;
            let reason = SwitchReason::from_name(reason.trim())
                .ok_or_else(|| format!("line {}: unknown reason `{}`", ln + 1, reason.trim()))?;
            if trace.events.last().is_some_and(|e| e.pass > pass) {
                return Err(format!("line {}: passes must be non-decreasing", ln + 1));
            }
            trace.push(PrecisionEvent { pass, scheme, reason });
        }
        Ok(trace)
    }
}

/// Per-solve precision state machine.
///
/// Protocol (identical across every execution path — this is what makes
/// the trace deterministic):
///
/// 1. [`current`](Self::current) names the scheme for the next SpMV
///    pass.  Before any observation that is the pass-0 (init) scheme.
/// 2. After a pass's squared residual `rr` is known **and the solve
///    continues**, the driver calls [`observe`](Self::observe) exactly
///    once.  The controller may escalate; the change takes effect from
///    the next pass.  The final residual of a converged / iteration-
///    capped solve is *not* observed — no pass runs under it.
#[derive(Debug, Clone)]
pub struct PrecisionController {
    current: Scheme,
    observed: u32,
    trace: PrecisionTrace,
    kind: Kind,
}

#[derive(Debug, Clone)]
enum Kind {
    Fixed,
    Adaptive {
        policy: AdaptivePolicy,
        tol: f64,
        /// Last `stall_window` observed rr values, oldest first.
        hist: VecDeque<f64>,
        escalated: bool,
    },
    Replay { schedule: PrecisionTrace },
}

impl PrecisionController {
    /// A controller that never switches: the static schemes of Table 1.
    pub fn fixed(scheme: Scheme) -> Self {
        let mut trace = PrecisionTrace::default();
        trace.push(PrecisionEvent { pass: 0, scheme, reason: SwitchReason::Static });
        Self { current: scheme, observed: 0, trace, kind: Kind::Fixed }
    }

    /// The residual-driven controller.  `tol` is the solve's squared-
    /// residual tolerance (the guard band is relative to it).
    pub fn adaptive(policy: AdaptivePolicy, tol: f64) -> Self {
        let mut trace = PrecisionTrace::default();
        trace.push(PrecisionEvent { pass: 0, scheme: policy.start, reason: SwitchReason::Start });
        Self {
            current: policy.start,
            observed: 0,
            trace,
            kind: Kind::Adaptive {
                policy,
                tol,
                hist: VecDeque::with_capacity(policy.stall_window as usize + 1),
                escalated: false,
            },
        }
    }

    /// A controller that replays a recorded schedule instead of
    /// deciding: pass p runs `schedule.scheme_at(p)` regardless of the
    /// residuals.  Replaying the trace of a finished solve therefore
    /// reproduces its results bitwise.
    pub fn replay(schedule: &PrecisionTrace) -> Self {
        Self {
            current: schedule.scheme_at(0),
            observed: 0,
            trace: schedule.clone(),
            kind: Kind::Replay { schedule: schedule.clone() },
        }
    }

    /// The controller a [`PrecisionMode`] describes, given the solve
    /// tolerance and the scheme the executor would otherwise run.
    pub fn for_mode(mode: PrecisionMode, fallback: Scheme, tol: f64) -> Self {
        match mode {
            PrecisionMode::Static(_) => Self::fixed(fallback),
            PrecisionMode::Adaptive(policy) => Self::adaptive(policy, tol),
        }
    }

    /// Scheme the next SpMV pass must decode.
    pub fn current(&self) -> Scheme {
        self.current
    }

    /// Residual observations so far (== the index of the next pass).
    pub fn observed(&self) -> u32 {
        self.observed
    }

    /// Can this controller change scheme mid-solve (adaptive or
    /// replay)?  Fixed controllers are inert and never require the
    /// executor to rebind.
    pub fn is_adaptive(&self) -> bool {
        !matches!(self.kind, Kind::Fixed)
    }

    /// Feed the squared residual of the pass that just finished (call
    /// only if the solve continues — see the type-level protocol).
    pub fn observe(&mut self, rr: f64) {
        self.observed += 1;
        match &mut self.kind {
            Kind::Fixed => {}
            Kind::Replay { schedule } => {
                self.current = schedule.scheme_at(self.observed);
            }
            Kind::Adaptive { policy, tol, hist, escalated } => {
                let window = policy.stall_window as usize;
                let stalled = window > 0
                    && hist.len() == window
                    && rr > policy.stall_ratio * *hist.front().expect("non-empty window");
                if window > 0 {
                    if hist.len() == window {
                        hist.pop_front();
                    }
                    hist.push_back(rr);
                }
                if !*escalated {
                    let reason = if rr <= policy.guard_band * *tol {
                        Some(SwitchReason::GuardBand)
                    } else if stalled {
                        Some(SwitchReason::Stall)
                    } else {
                        None
                    };
                    if let Some(reason) = reason {
                        *escalated = true;
                        // A no-op escalation (escalate_to == start) is
                        // sticky but records nothing: the schedule did
                        // not change.
                        if policy.escalate_to != policy.start {
                            self.current = policy.escalate_to;
                            crate::obs::catalog::PRECISION_ESCALATIONS.inc();
                            self.trace.push(PrecisionEvent {
                                pass: self.observed,
                                scheme: policy.escalate_to,
                                reason,
                            });
                        }
                    }
                }
            }
        }
    }

    /// The recorded schedule so far.
    pub fn trace(&self) -> &PrecisionTrace {
        &self.trace
    }

    /// Consume the controller, yielding the schedule it recorded (for
    /// a replay controller: the schedule it replayed).
    pub fn into_trace(self) -> PrecisionTrace {
        self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_controller_never_switches_and_records_one_event() {
        let mut c = PrecisionController::fixed(Scheme::MixV2);
        for rr in [1e3, 1e-20, 5e2] {
            c.observe(rr);
            assert_eq!(c.current(), Scheme::MixV2);
        }
        assert!(!c.is_adaptive());
        let t = c.into_trace();
        assert_eq!(
            t.events(),
            &[PrecisionEvent { pass: 0, scheme: Scheme::MixV2, reason: SwitchReason::Static }]
        );
        assert!(!t.switched());
    }

    #[test]
    fn guard_band_escalates_on_tolerance_approach() {
        let policy = AdaptivePolicy { guard_band: 100.0, stall_window: 0, ..Default::default() };
        let mut c = PrecisionController::adaptive(policy, 1e-10);
        c.observe(1.0);
        assert_eq!(c.current(), Scheme::MixV3);
        c.observe(9.9e-9); // <= 100 * 1e-10
        assert_eq!(c.current(), Scheme::Fp64);
        let t = c.into_trace();
        assert_eq!(t.len(), 2);
        assert_eq!(
            t.events()[1],
            PrecisionEvent { pass: 2, scheme: Scheme::Fp64, reason: SwitchReason::GuardBand }
        );
        // Pass mapping: passes 0 and 1 ran MixV3, pass 2 on runs Fp64.
        assert_eq!(t.scheme_at(0), Scheme::MixV3);
        assert_eq!(t.scheme_at(1), Scheme::MixV3);
        assert_eq!(t.scheme_at(2), Scheme::Fp64);
        assert_eq!(t.scheme_at(99), Scheme::Fp64);
    }

    #[test]
    fn stall_detector_fires_after_a_flat_window() {
        let policy = AdaptivePolicy {
            stall_window: 3,
            stall_ratio: 0.5,
            guard_band: 0.0, // guard band off
            ..Default::default()
        };
        let mut c = PrecisionController::adaptive(policy, 1e-12);
        // Healthy progress: each window of 3 drops by > 2x.
        for rr in [8.0, 4.0, 2.0, 0.9] {
            c.observe(rr);
            assert_eq!(c.current(), Scheme::MixV3, "still converging at rr={rr}");
        }
        // Stall: 0.8 > 0.5 * rr[3 ago] = 0.5 * 4.0? No: 0.8 <= 2.0.
        c.observe(0.8);
        assert_eq!(c.current(), Scheme::MixV3);
        // 0.7 > 0.5 * 0.9 = 0.45 -> stalled.
        c.observe(0.7);
        assert_eq!(c.current(), Scheme::Fp64);
        let t = c.into_trace();
        assert_eq!(
            t.events()[1],
            PrecisionEvent { pass: 6, scheme: Scheme::Fp64, reason: SwitchReason::Stall }
        );
    }

    #[test]
    fn escalation_is_sticky() {
        let policy = AdaptivePolicy::default();
        let mut c = PrecisionController::adaptive(policy, 1e-2);
        c.observe(1e-3); // within guard band immediately
        assert_eq!(c.current(), Scheme::Fp64);
        c.observe(1e6); // residual explodes — stays escalated
        assert_eq!(c.current(), Scheme::Fp64);
        assert_eq!(c.into_trace().len(), 2);
    }

    #[test]
    fn degenerate_escalation_to_start_records_nothing() {
        let policy =
            AdaptivePolicy { start: Scheme::Fp64, escalate_to: Scheme::Fp64, ..Default::default() };
        let mut c = PrecisionController::adaptive(policy, 1e-2);
        c.observe(1e-9);
        c.observe(1e-9);
        assert_eq!(c.current(), Scheme::Fp64);
        let t = c.into_trace();
        assert_eq!(t.len(), 1, "no-op escalation must not add change points");
        assert_eq!(t.events()[0].reason, SwitchReason::Start);
    }

    #[test]
    fn replay_reproduces_a_recorded_schedule_without_residuals() {
        let policy = AdaptivePolicy { guard_band: 1e6, ..Default::default() };
        let mut live = PrecisionController::adaptive(policy, 1e-8);
        let residuals = [1.0, 0.5, 0.25, 1e-3, 1e-5];
        let mut live_schemes = vec![live.current()];
        for rr in residuals {
            live.observe(rr);
            live_schemes.push(live.current());
        }
        let trace = live.into_trace();

        let mut rep = PrecisionController::replay(&trace);
        let mut rep_schemes = vec![rep.current()];
        for _ in residuals {
            rep.observe(f64::NAN); // residuals must not matter
            rep_schemes.push(rep.current());
        }
        assert_eq!(live_schemes, rep_schemes);
        assert_eq!(rep.into_trace(), trace);
    }

    #[test]
    fn csv_roundtrip_and_rejects() {
        let policy = AdaptivePolicy::default();
        let mut c = PrecisionController::adaptive(policy, 1e-10);
        c.observe(1.0);
        c.observe(1e-9);
        let t = c.into_trace();
        let csv = t.to_csv();
        assert_eq!(PrecisionTrace::from_csv(&csv).unwrap(), t);
        // Header optional, whitespace tolerated.
        assert_eq!(PrecisionTrace::from_csv("0, mixv3, start\n").unwrap().len(), 1);
        assert!(PrecisionTrace::from_csv("0,fp128,static\n").is_err());
        assert!(PrecisionTrace::from_csv("0,fp64,because\n").is_err());
        assert!(PrecisionTrace::from_csv("5,fp64,static\n1,mixv3,stall\n").is_err());
        assert!(PrecisionTrace::from_csv("x,fp64,static\n").is_err());
    }

    #[test]
    fn modeled_m1_bytes_charges_by_active_scheme() {
        let mut t = PrecisionTrace::default();
        t.push(PrecisionEvent { pass: 0, scheme: Scheme::MixV3, reason: SwitchReason::Start });
        t.push(PrecisionEvent { pass: 3, scheme: Scheme::Fp64, reason: SwitchReason::Stall });
        // 10 iterations -> passes 0..=10: 3 at 8 B/nnz + 8 at 16 B/nnz.
        let nnz = 1000u64;
        assert_eq!(t.modeled_m1_bytes(nnz, 10), nnz * (3 * 8 + 8 * 16));
        // Static fp64 reference.
        let f = PrecisionController::fixed(Scheme::Fp64).into_trace();
        assert_eq!(f.modeled_m1_bytes(nnz, 10), nnz * 11 * 16);
    }

    #[test]
    fn policy_f32_need_covers_both_ends() {
        assert!(AdaptivePolicy::default().needs_f32());
        let all64 =
            AdaptivePolicy { start: Scheme::Fp64, escalate_to: Scheme::Fp64, ..Default::default() };
        assert!(!all64.needs_f32());
        let down =
            AdaptivePolicy { start: Scheme::Fp64, escalate_to: Scheme::MixV3, ..Default::default() };
        assert!(down.needs_f32());
    }
}
