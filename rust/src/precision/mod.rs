//! Precision lab: the four SpMV precision schemes of Table 1, the
//! cyclic-delay-buffer dot product (footnote 1), and the behavioural
//! model of XcgSolver's padded-zero accumulator instability (§7.5.1).
//!
//! The paper's rule (§6): mixed precision applies *only* to the SpMV;
//! main-loop vectors always stay FP64.  Each scheme therefore only
//! changes what the SpMV sees:
//!
//! | scheme  | A    | x    | y    |
//! |---------|------|------|------|
//! | Fp64    | f64  | f64  | f64  |
//! | MixV1   | f32  | f32  | f32  |
//! | MixV2   | f32  | f32  | f64  |
//! | MixV3   | f32  | f64  | f64  |  <- what Callipepla ships


use crate::sparse::CsrMatrix;

pub mod adaptive;

/// Instrumentation for the matrix-traffic story: how many matrix values
/// the SpMV kernels streamed on *this thread*.
///
/// The counter is thread-local on purpose: the tests that assert the
/// block-CG amortization (`tests/block_spmv.rs`) run serial-path solves
/// on one thread and measure deltas, and a process-global counter would
/// be polluted by unrelated tests running concurrently in the same
/// process.  Multithreaded kernel runs split their increments across
/// the worker threads, so treat the counter as a serial-path probe.
pub mod stats {
    use crate::obs::catalog::{PRECISION_MATRIX_VALUE_READS, PRECISION_VECTOR_ELEMENT_MOVES};

    /// Record `n` streamed matrix values (one per nnz touched).
    pub(crate) fn add_matrix_value_reads(n: u64) {
        PRECISION_MATRIX_VALUE_READS.add(n);
    }

    /// Matrix values streamed by SpMV kernels on this thread so far.
    /// Take a delta around the region under test.
    ///
    /// Since PR 9 the counter lives on the telemetry plane
    /// ([`crate::obs::catalog::PRECISION_MATRIX_VALUE_READS`], a
    /// [`crate::obs::LocalCounter`] that also keeps a process-global
    /// total for exposition); this function remains the thread-local
    /// delta view the counter-wall tests were written against.
    pub fn matrix_value_reads() -> u64 {
        PRECISION_MATRIX_VALUE_READS.local()
    }

    /// Record `n` vector elements copied across a block-layout boundary
    /// (per-lane vector ↔ interleaved lane-major block arena).
    pub(crate) fn add_vector_element_moves(n: u64) {
        PRECISION_VECTOR_ELEMENT_MOVES.add(n);
    }

    /// Vector elements moved across block-layout boundaries on this
    /// thread so far: the per-pass gather/scatter of the staged block
    /// SpMV path, plus the one-time interleave at resident-block entry
    /// and the deinterleave at lane exit / fallback.  Steady-state
    /// iterations of the *resident* block path contribute **zero** here
    /// — the arenas are read and written in place and commits are whole
    /// buffer swaps — while the staged path pays `2·n·lanes` per
    /// iteration (pinned in `tests/block_spmv.rs`).  Take a delta around
    /// the region under test; like [`matrix_value_reads`] it is
    /// thread-local, so measure serial-path solves on one thread (the
    /// registry total aggregates across threads for exposition).
    pub fn vector_element_moves() -> u64 {
        PRECISION_VECTOR_ELEMENT_MOVES.local()
    }
}

/// SpMV precision scheme (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Scheme {
    /// Default all-FP64 (XcgSolver, SerpensCG, GPU baselines).
    Fp64,
    /// All-FP32 SpMV: fails to converge on hard problems (Fig. 9).
    MixV1,
    /// f32 matrix + f32 input vector, f64 accumulate.
    MixV2,
    /// f32 matrix only — Callipepla's shipping scheme.
    #[default]
    MixV3,
}

impl Scheme {
    /// Every scheme, in Table-1 order.
    pub const ALL: [Scheme; 4] = [Scheme::Fp64, Scheme::MixV1, Scheme::MixV2, Scheme::MixV3];

    /// Short lowercase id (CLI `--scheme` values).
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Fp64 => "fp64",
            Scheme::MixV1 => "mixv1",
            Scheme::MixV2 => "mixv2",
            Scheme::MixV3 => "mixv3",
        }
    }

    /// Bytes per streamed non-zero: 128-bit for an FP64 nnz (32+32+64),
    /// 64-bit packed for an f32 nnz (14+18+32 -> one 64-bit word), §2.3.3/§6.
    pub fn nnz_bytes(self) -> u64 {
        match self {
            Scheme::Fp64 => 16,
            _ => 8,
        }
    }

    /// Does the matrix value stream hold f32?
    pub fn matrix_f32(self) -> bool {
        !matches!(self, Scheme::Fp64)
    }

    /// Inverse of [`name`](Self::name) (CLI / trace-CSV parsing).
    pub fn from_name(name: &str) -> Option<Scheme> {
        match name {
            "fp64" => Some(Scheme::Fp64),
            "mixv1" => Some(Scheme::MixV1),
            "mixv2" => Some(Scheme::MixV2),
            "mixv3" => Some(Scheme::MixV3),
            _ => None,
        }
    }

    /// This scheme's code in the 3-bit Type-I precision field (Table-1
    /// order).  Codes 4..=7 are reserved and must decode to an explicit
    /// error — see `isa::InstVCtrl::decode`.
    pub const fn wire_code(self) -> u8 {
        match self {
            Scheme::Fp64 => 0,
            Scheme::MixV1 => 1,
            Scheme::MixV2 => 2,
            Scheme::MixV3 => 3,
        }
    }

    /// Inverse of [`wire_code`](Self::wire_code); `None` for the
    /// reserved encodings.
    pub const fn from_wire_code(code: u8) -> Option<Scheme> {
        match code {
            0 => Some(Scheme::Fp64),
            1 => Some(Scheme::MixV1),
            2 => Some(Scheme::MixV2),
            3 => Some(Scheme::MixV3),
            _ => None,
        }
    }
}

/// Accumulation-order / accumulator-architecture model for the SpMV.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum AccumulatorModel {
    /// Exact sequential accumulation (CPU golden reference).
    #[default]
    Sequential,
    /// Serpens/Callipepla: out-of-order issue changes the accumulation
    /// order per row but stays in f64 — numerically benign.
    OutOfOrder,
    /// XcgSolver's padded-zero accumulator whose true dependency distance
    /// exceeds the FP-add-latency padding (§7.5.1): modelled as a
    /// deterministic relative perturbation of magnitude `eps` on each
    /// SpMV output element.  `eps = 3e-9` calibrated so Table-7
    /// iteration inflation lands in the paper's observed range
    /// (+10% .. +35%).
    PaddedUnstable { eps: f64 },
}

impl AccumulatorModel {
    /// The calibrated XcgSolver instability (§7.5.1).
    pub const XCGSOLVER: AccumulatorModel = AccumulatorModel::PaddedUnstable { eps: 3e-9 };
}

/// Deterministic per-element hash in [-1, 1) for the perturbation model.
#[inline]
fn signed_hash01(i: u64, salt: u64) -> f64 {
    let mut h = i.wrapping_mul(0x9E3779B97F4A7C15) ^ salt.wrapping_mul(0xD1B54A32D192ED03);
    h ^= h >> 31;
    h = h.wrapping_mul(0xBF58476D1CE4E5B9);
    h ^= h >> 29;
    (h >> 11) as f64 / (1u64 << 52) as f64 - 1.0
}

/// SpMV under a precision scheme + accumulator model.  `vals32` must be
/// the f32 view of `a.vals` (cached by the caller — deriving it is
/// O(nnz)); it is ignored (may be empty) for [`Scheme::Fp64`].  `salt`
/// feeds the PaddedUnstable perturbation (callers pass the iteration
/// number so the perturbation varies across iterations the way a
/// timing-dependent accumulator error would).
pub fn spmv_scheme(
    a: &CsrMatrix,
    vals32: &[f32],
    x: &[f64],
    y: &mut [f64],
    scheme: Scheme,
    acc: AccumulatorModel,
    salt: u64,
) {
    debug_assert_eq!(y.len(), a.n);
    spmv_scheme_rows(a, vals32, x, y, 0, scheme);
    apply_accumulator_model(y, acc, salt);
}

/// One scheme's SpMV restricted to the contiguous row block
/// `row_start..row_start + y_rows.len()`, writing into `y_rows`.
///
/// Every row's multiply-accumulate runs in exactly the order of the full
/// serial kernel, so covering `0..n` with disjoint row blocks — on any
/// number of threads — reproduces the serial output *bitwise*.  This is
/// the invariant that lets the parallel engine keep Table-7 iteration
/// counts untouched (see `PERF.md`).
pub fn spmv_scheme_rows(
    a: &CsrMatrix,
    vals32: &[f32],
    x: &[f64],
    y_rows: &mut [f64],
    row_start: usize,
    scheme: Scheme,
) {
    debug_assert!(row_start + y_rows.len() <= a.n);
    // Hard guard, not a debug_assert: the Mix-V3 arm indexes vals32 with
    // get_unchecked, so an undersized slice from safe code would be UB.
    assert!(
        !scheme.matrix_f32() || vals32.len() == a.nnz(),
        "vals32 must be the f32 view of a.vals for {scheme:?} (len {} != nnz {})",
        vals32.len(),
        a.nnz()
    );
    let span = a.indptr[row_start + y_rows.len()] - a.indptr[row_start];
    stats::add_matrix_value_reads(span as u64);
    match scheme {
        Scheme::Fp64 => {
            for (j, yj) in y_rows.iter_mut().enumerate() {
                let (cols, vals) = a.row(row_start + j);
                let mut s = 0.0f64;
                for (c, v) in cols.iter().zip(vals) {
                    s += v * x[*c as usize];
                }
                *yj = s;
            }
        }
        Scheme::MixV1 => {
            // All-f32 SpMV: x rounded to f32, f32 multiply-accumulate,
            // result widened at the end (vectors stay f64 outside).
            for (j, yj) in y_rows.iter_mut().enumerate() {
                let i = row_start + j;
                let (cols, _) = a.row(i);
                let (s, e) = (a.indptr[i] as usize, a.indptr[i + 1] as usize);
                let mut acc32 = 0.0f32;
                for (k, c) in (s..e).zip(cols) {
                    acc32 += vals32[k] * x[*c as usize] as f32;
                }
                *yj = acc32 as f64;
            }
        }
        Scheme::MixV2 => {
            // f32 matrix and f32-rounded x, but f64 accumulation.
            for (j, yj) in y_rows.iter_mut().enumerate() {
                let i = row_start + j;
                let (cols, _) = a.row(i);
                let (s, e) = (a.indptr[i] as usize, a.indptr[i + 1] as usize);
                let mut acc64 = 0.0f64;
                for (k, c) in (s..e).zip(cols) {
                    acc64 += vals32[k] as f64 * (x[*c as usize] as f32) as f64;
                }
                *yj = acc64;
            }
        }
        Scheme::MixV3 => {
            // f32 matrix upcast, full-f64 x and accumulation (Fig. 8).
            // Hot path (§Perf): bounds checks lifted out of the inner
            // gather loop — indices are validated at matrix build time.
            for (j, yj) in y_rows.iter_mut().enumerate() {
                let i = row_start + j;
                let (s, e) = (a.indptr[i] as usize, a.indptr[i + 1] as usize);
                let mut acc64 = 0.0f64;
                for k in s..e {
                    // SAFETY: k < nnz and indices[k] < n by CSR construction.
                    unsafe {
                        acc64 += *vals32.get_unchecked(k) as f64
                            * x.get_unchecked(*a.indices.get_unchecked(k) as usize);
                    }
                }
                *yj = acc64;
            }
        }
    }
}

/// Lane-block width of the unrolled inner loops in
/// [`spmv_scheme_rows_block`]: the lane loop is emitted as explicit
/// 4-wide blocks (one 256-bit SIMD vector of f64) plus a remainder.
pub const SPMV_LANE_BLOCK: usize = 4;

/// Block-CG SpMV: one pass over the CSR structure feeds **every** RHS
/// lane.  `xs` and `y_rows` are interleaved lane-major —
/// `xs[col * lanes + lane]`, `y_rows[(row - row_start) * lanes + lane]`
/// — so the per-nnz inner loop walks `lanes` contiguous f64s (emitted
/// as explicit [`SPMV_LANE_BLOCK`]-wide unrolled blocks, the PERF §7
/// SIMD row kernel).  Each streamed matrix value is read — and, under
/// the Mix schemes, decoded from f32 — exactly **once** regardless of
/// the lane count: matrix traffic per iteration is O(nnz), not
/// O(lanes · nnz), which is the whole block-CG amortization
/// (instrumented via [`stats::matrix_value_reads`]).
///
/// Bit contract: each lane's accumulation chain applies the same
/// products in the same nnz order as [`spmv_scheme_rows`] on that
/// lane's deinterleaved vector — the lane loop commutes with the nnz
/// loop only in *which register* accumulates, never in the order a
/// lane's own partial sums combine.  Every lane of the output is
/// therefore bitwise identical to a serial per-lane SpMV, for all four
/// schemes (pinned in the tests below), and a block-CG solve cannot
/// drift from the serial oracle.
pub fn spmv_scheme_rows_block(
    a: &CsrMatrix,
    vals32: &[f32],
    xs: &[f64],
    y_rows: &mut [f64],
    row_start: usize,
    lanes: usize,
    scheme: Scheme,
) {
    assert!(lanes > 0, "a block SpMV needs at least one lane");
    debug_assert_eq!(y_rows.len() % lanes, 0);
    let rows = y_rows.len() / lanes;
    debug_assert!(row_start + rows <= a.n);
    debug_assert_eq!(xs.len(), a.n * lanes);
    // Same hard guard as the serial kernel: the Mix-V3 arm uses
    // get_unchecked on vals32.
    assert!(
        !scheme.matrix_f32() || vals32.len() == a.nnz(),
        "vals32 must be the f32 view of a.vals for {scheme:?} (len {} != nnz {})",
        vals32.len(),
        a.nnz()
    );
    // One read (and one decode) per nnz, however many lanes ride along.
    let span = a.indptr[row_start + rows] - a.indptr[row_start];
    stats::add_matrix_value_reads(span as u64);

    // The f64-accumulating schemes accumulate straight into the row's
    // output slice; Mix-V1 needs an f32 scratch row to preserve the
    // serial kernel's f32 accumulation exactly.
    #[inline(always)]
    fn fma_lanes(acc: &mut [f64], xs: &[f64], base: usize, v: f64) {
        let lanes = acc.len();
        let mut j = 0;
        while j + SPMV_LANE_BLOCK <= lanes {
            acc[j] += v * xs[base + j];
            acc[j + 1] += v * xs[base + j + 1];
            acc[j + 2] += v * xs[base + j + 2];
            acc[j + 3] += v * xs[base + j + 3];
            j += SPMV_LANE_BLOCK;
        }
        while j < lanes {
            acc[j] += v * xs[base + j];
            j += 1;
        }
    }

    match scheme {
        Scheme::Fp64 => {
            for (jr, acc) in y_rows.chunks_exact_mut(lanes).enumerate() {
                let i = row_start + jr;
                let (s, e) = (a.indptr[i] as usize, a.indptr[i + 1] as usize);
                acc.fill(0.0);
                for k in s..e {
                    let v = a.vals[k];
                    fma_lanes(acc, xs, a.indices[k] as usize * lanes, v);
                }
            }
        }
        Scheme::MixV1 => {
            // All-f32 accumulate, widened once per row — lane for lane
            // the chain of the serial Mix-V1 kernel.
            let mut acc32 = vec![0.0f32; lanes];
            for (jr, out) in y_rows.chunks_exact_mut(lanes).enumerate() {
                let i = row_start + jr;
                let (s, e) = (a.indptr[i] as usize, a.indptr[i + 1] as usize);
                acc32.fill(0.0);
                for k in s..e {
                    let v = vals32[k];
                    let base = a.indices[k] as usize * lanes;
                    let mut j = 0;
                    while j + SPMV_LANE_BLOCK <= lanes {
                        acc32[j] += v * xs[base + j] as f32;
                        acc32[j + 1] += v * xs[base + j + 1] as f32;
                        acc32[j + 2] += v * xs[base + j + 2] as f32;
                        acc32[j + 3] += v * xs[base + j + 3] as f32;
                        j += SPMV_LANE_BLOCK;
                    }
                    while j < lanes {
                        acc32[j] += v * xs[base + j] as f32;
                        j += 1;
                    }
                }
                for (o, s32) in out.iter_mut().zip(&acc32) {
                    *o = *s32 as f64;
                }
            }
        }
        Scheme::MixV2 => {
            for (jr, acc) in y_rows.chunks_exact_mut(lanes).enumerate() {
                let i = row_start + jr;
                let (s, e) = (a.indptr[i] as usize, a.indptr[i + 1] as usize);
                acc.fill(0.0);
                for k in s..e {
                    // Decode once; x is re-rounded per lane (it differs
                    // per lane, so there is nothing to hoist).
                    let v = vals32[k] as f64;
                    let base = a.indices[k] as usize * lanes;
                    let mut j = 0;
                    while j + SPMV_LANE_BLOCK <= lanes {
                        acc[j] += v * (xs[base + j] as f32) as f64;
                        acc[j + 1] += v * (xs[base + j + 1] as f32) as f64;
                        acc[j + 2] += v * (xs[base + j + 2] as f32) as f64;
                        acc[j + 3] += v * (xs[base + j + 3] as f32) as f64;
                        j += SPMV_LANE_BLOCK;
                    }
                    while j < lanes {
                        acc[j] += v * (xs[base + j] as f32) as f64;
                        j += 1;
                    }
                }
            }
        }
        Scheme::MixV3 => {
            // f32 matrix upcast once per nnz, full-f64 lanes.  Bounds
            // checks lifted like the serial hot path: indices are
            // validated at matrix build time, and base + j < n·lanes
            // because indices[k] < n.
            for (jr, acc) in y_rows.chunks_exact_mut(lanes).enumerate() {
                let i = row_start + jr;
                let (s, e) = (a.indptr[i] as usize, a.indptr[i + 1] as usize);
                acc.fill(0.0);
                for k in s..e {
                    // SAFETY: k < nnz and indices[k] < n by CSR construction.
                    let (v, base) = unsafe {
                        (
                            *vals32.get_unchecked(k) as f64,
                            *a.indices.get_unchecked(k) as usize * lanes,
                        )
                    };
                    let mut j = 0;
                    while j + SPMV_LANE_BLOCK <= lanes {
                        // SAFETY: base + j + 3 < n·lanes (see above).
                        unsafe {
                            *acc.get_unchecked_mut(j) += v * xs.get_unchecked(base + j);
                            *acc.get_unchecked_mut(j + 1) += v * xs.get_unchecked(base + j + 1);
                            *acc.get_unchecked_mut(j + 2) += v * xs.get_unchecked(base + j + 2);
                            *acc.get_unchecked_mut(j + 3) += v * xs.get_unchecked(base + j + 3);
                        }
                        j += SPMV_LANE_BLOCK;
                    }
                    while j < lanes {
                        acc[j] += v * xs[base + j];
                        j += 1;
                    }
                }
            }
        }
    }
}

/// Apply the accumulator-architecture perturbation (§7.5.1) to a full
/// SpMV output.  Separated from the gather kernels so the parallel
/// engine can run the row blocks on threads and still apply the
/// whole-vector model in the serial path's exact element order.
pub fn apply_accumulator_model(y: &mut [f64], acc: AccumulatorModel, salt: u64) {
    if let AccumulatorModel::PaddedUnstable { eps } = acc {
        for (i, v) in y.iter_mut().enumerate() {
            *v += *v * eps * signed_hash01(i as u64, salt);
        }
    }
}

/// Number of f64 adder lanes in the FPGA's cyclic delay buffer
/// (footnote 1); must match `python/compile/kernels/dot.py::DELAY_LANES`.
pub const DELAY_LANES: usize = 8;

/// Dot product with the FPGA's two-phase delay-buffer structure:
/// Phase I accumulates element i into lane i % L (II=1); Phase II folds
/// the L lanes (II=5 tail on the FPGA, cost independent of n).
/// Reproduces the hardware's partial-sum grouping — and hence its exact
/// rounding — which is what makes the Callipepla rows of Table 7 differ
/// from the CPU by a handful of iterations.
pub fn dot_delay_buffer(a: &[f64], b: &[f64]) -> f64 {
    let mut lanes = [0.0f64; DELAY_LANES];
    let chunks = a.len() / DELAY_LANES;
    for k in 0..chunks {
        let base = k * DELAY_LANES;
        for l in 0..DELAY_LANES {
            lanes[l] += a[base + l] * b[base + l];
        }
    }
    for i in chunks * DELAY_LANES..a.len() {
        lanes[i % DELAY_LANES] += a[i] * b[i];
    }
    lanes.iter().sum()
}

/// Plain sequential dot (CPU golden).
pub fn dot_sequential(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

// ---------------------------------------------------------------------
// Streaming dot accumulators.
//
// The fused solver sweeps (solver::jpcg) fold the Phase-2 dots into the
// element-wise update loops instead of making separate n-length passes.
// Fusion is only legal if it cannot move a single bit of the result, so
// each accumulator reproduces — product by product, in element order —
// the exact reduction structure of its whole-array counterpart:
// `SeqDot` == `dot_sequential`, `DelayDot` == `dot_delay_buffer`
// (asserted bitwise in the tests below).
// ---------------------------------------------------------------------

/// A running dot product fed one element pair at a time, in index order.
pub trait DotAccumulator: Default {
    /// Accumulate the product `a * b` for the next element index.
    fn add(&mut self, a: f64, b: f64);
    /// Final reduction value.
    fn finish(&self) -> f64;
}

/// Sequential accumulation: bitwise-identical to [`dot_sequential`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SeqDot {
    acc: f64,
}

impl DotAccumulator for SeqDot {
    #[inline]
    fn add(&mut self, a: f64, b: f64) {
        self.acc += a * b;
    }

    #[inline]
    fn finish(&self) -> f64 {
        self.acc
    }
}

/// The FPGA's 8-lane cyclic delay buffer as a streaming accumulator:
/// element i lands in lane i % L and the lanes fold sequentially at the
/// end — bitwise-identical to [`dot_delay_buffer`], because each lane
/// sees the same partial products in the same order and the final fold
/// is the same left-to-right lane sum.
#[derive(Debug, Clone, Copy)]
pub struct DelayDot {
    lanes: [f64; DELAY_LANES],
    next: usize,
}

impl Default for DelayDot {
    fn default() -> Self {
        Self { lanes: [0.0; DELAY_LANES], next: 0 }
    }
}

impl DotAccumulator for DelayDot {
    #[inline]
    fn add(&mut self, a: f64, b: f64) {
        self.lanes[self.next] += a * b;
        self.next += 1;
        if self.next == DELAY_LANES {
            self.next = 0;
        }
    }

    #[inline]
    fn finish(&self) -> f64 {
        self.lanes.iter().sum()
    }
}

/// Whole-array dot through an accumulator type (used for the Phase-1
/// `pap` dot, which has no update loop to fuse into).
pub fn dot_with<D: DotAccumulator>(a: &[f64], b: &[f64]) -> f64 {
    let mut d = D::default();
    for (x, y) in a.iter().zip(b) {
        d.add(*x, *y);
    }
    d.finish()
}

// ---------------------------------------------------------------------
// Block vector kernels (resident block-CG, M2–M8 batched).
//
// Same proof strategy as `spmv_scheme_rows_block`: the lane loop only
// changes *which register* an operation lands in, never the order of a
// single lane's own operations.  Each kernel applies, for every lane j,
// exactly the element-order op sequence of its serial module
// counterpart (`modules::compute`): axpy `y[i] += alpha·x[i]`, left
// divide `z[i] = r[i]/m[i]`, update-p `p[i] = z[i] + beta·p[i]`, and the
// 8-lane delay-buffer dot.  Every lane of a block kernel's output is
// therefore bitwise the serial module run on that lane's deinterleaved
// vector (pinned in the tests below), which is what keeps the resident
// block coordinator behind the `jpcg_solve` oracle.
//
// All block buffers are interleaved lane-major — element i of lane j at
// index `i * lanes + j` — matching `spmv_scheme_rows_block`.  The
// element-wise kernels accept row sub-ranges implicitly (pass aligned
// sub-slices), which is how the engine parallelizes them over row
// blocks without touching per-lane op order.
// ---------------------------------------------------------------------

/// Block axpy (M3/M4): for every lane j, `ys[i·L+j] += alphas[j] · xs[i·L+j]`
/// in element order.  `lanes = alphas.len()`; `xs`/`ys` are aligned
/// lane-major (sub-)blocks with `len % lanes == 0`.
pub fn axpy_block(alphas: &[f64], xs: &[f64], ys: &mut [f64]) {
    let lanes = alphas.len();
    assert!(lanes > 0, "a block axpy needs at least one lane");
    assert_eq!(xs.len(), ys.len());
    debug_assert_eq!(ys.len() % lanes, 0);
    for (yr, xr) in ys.chunks_exact_mut(lanes).zip(xs.chunks_exact(lanes)) {
        for ((y, x), alpha) in yr.iter_mut().zip(xr).zip(alphas) {
            *y += alpha * x;
        }
    }
}

/// Block left divide (M5): for every lane j, `zs[i·L+j] = rs[i·L+j] / m[i]`
/// in element order.  `m` is the shared (per-row, lane-invariant) Jacobi
/// diagonal restricted to the same row range as the `rs`/`zs` sub-blocks:
/// `rs.len() == m.len() · lanes`.
pub fn left_divide_block(rs: &[f64], m: &[f64], zs: &mut [f64], lanes: usize) {
    assert!(lanes > 0, "a block left-divide needs at least one lane");
    assert_eq!(rs.len(), zs.len());
    assert_eq!(rs.len(), m.len() * lanes);
    for ((zr, rr), mi) in zs.chunks_exact_mut(lanes).zip(rs.chunks_exact(lanes)).zip(m) {
        for (z, r) in zr.iter_mut().zip(rr) {
            *z = r / mi;
        }
    }
}

/// Block update-p (M7): for every lane j,
/// `ps[i·L+j] = zs[i·L+j] + betas[j] · ps[i·L+j]` in element order.
/// `lanes = betas.len()`.
pub fn update_p_block(betas: &[f64], zs: &[f64], ps: &mut [f64]) {
    let lanes = betas.len();
    assert!(lanes > 0, "a block update-p needs at least one lane");
    assert_eq!(zs.len(), ps.len());
    debug_assert_eq!(ps.len() % lanes, 0);
    for (pr, zr) in ps.chunks_exact_mut(lanes).zip(zs.chunks_exact(lanes)) {
        for ((p, z), beta) in pr.iter_mut().zip(zr).zip(betas) {
            *p = z + beta * *p;
        }
    }
}

/// One lane of a block dot (M2/M6/M8): the 8-lane delay-buffer dot of
/// lane `lane`'s deinterleaved vectors — bitwise [`dot_delay_buffer`],
/// because the stride-`lanes` walk feeds [`DelayDot`] the same element
/// pairs in the same order.
pub fn dot_block_lane(a: &[f64], b: &[f64], lanes: usize, lane: usize) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(lane < lanes);
    let mut d = DelayDot::default();
    let mut k = lane;
    while k < a.len() {
        d.add(a[k], b[k]);
        k += lanes;
    }
    d.finish()
}

/// Block dot (M2/M6/M8): `out[j]` = the delay-buffer dot of lane j of
/// the interleaved blocks `a`/`b`.  `out.len()` sets the lane count.
/// Lanes are independent delay-buffer chains, so the engine parallelizes
/// this over the *lane* axis (a row split would reassociate a chain).
pub fn dot_block(a: &[f64], b: &[f64], out: &mut [f64]) {
    let lanes = out.len();
    assert!(lanes > 0, "a block dot needs at least one lane");
    assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len() % lanes, 0);
    for (j, o) in out.iter_mut().enumerate() {
        *o = dot_block_lane(a, b, lanes, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth;

    fn system(n: usize) -> (CsrMatrix, Vec<f32>, Vec<f64>) {
        let a = synth::banded_spd(n, 6 * n, 1e-2, 9);
        let v32 = a.vals_f32();
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).cos()).collect();
        (a, v32, x)
    }

    #[test]
    fn fp64_matches_reference() {
        let (a, v32, x) = system(200);
        let mut y1 = vec![0.0; a.n];
        let mut y2 = vec![0.0; a.n];
        a.spmv_f64(&x, &mut y1);
        spmv_scheme(&a, &v32, &x, &mut y2, Scheme::Fp64, AccumulatorModel::Sequential, 0);
        assert_eq!(y1, y2);
    }

    #[test]
    fn scheme_error_ordering_v1_worst_v3_best() {
        // ||y_scheme - y_fp64|| must decrease monotonically V1 -> V2 -> V3.
        let (a, v32, x) = system(400);
        let mut gold = vec![0.0; a.n];
        a.spmv_f64(&x, &mut gold);
        let err = |scheme| {
            let mut y = vec![0.0; a.n];
            spmv_scheme(&a, &v32, &x, &mut y, scheme, AccumulatorModel::Sequential, 0);
            y.iter().zip(&gold).map(|(u, v)| (u - v).powi(2)).sum::<f64>().sqrt()
        };
        let (e1, e2, e3) = (err(Scheme::MixV1), err(Scheme::MixV2), err(Scheme::MixV3));
        assert!(e1 > e2 && e2 > e3, "e1={e1:.3e} e2={e2:.3e} e3={e3:.3e}");
        assert!(e3 > 0.0); // f32 matrix still loses something
    }

    #[test]
    fn padded_unstable_perturbs_deterministically() {
        let (a, v32, x) = system(100);
        let mut y1 = vec![0.0; a.n];
        let mut y2 = vec![0.0; a.n];
        spmv_scheme(&a, &v32, &x, &mut y1, Scheme::Fp64, AccumulatorModel::XCGSOLVER, 3);
        spmv_scheme(&a, &v32, &x, &mut y2, Scheme::Fp64, AccumulatorModel::XCGSOLVER, 3);
        assert_eq!(y1, y2);
        let mut clean = vec![0.0; a.n];
        spmv_scheme(&a, &v32, &x, &mut clean, Scheme::Fp64, AccumulatorModel::Sequential, 0);
        let rel: f64 = y1
            .iter()
            .zip(&clean)
            .map(|(u, v)| ((u - v) / v.abs().max(1e-300)).abs())
            .fold(0.0, f64::max);
        assert!(rel > 0.0 && rel < 1e-7, "rel={rel:.3e}");
    }

    #[test]
    fn delay_buffer_dot_close_to_sequential() {
        let a: Vec<f64> = (0..1003).map(|i| ((i * 37) % 101) as f64 - 50.0).collect();
        let b: Vec<f64> = (0..1003).map(|i| ((i * 53) % 97) as f64 - 48.0).collect();
        let d1 = dot_delay_buffer(&a, &b);
        let d2 = dot_sequential(&a, &b);
        assert!((d1 - d2).abs() <= 1e-9 * d2.abs().max(1.0));
    }

    #[test]
    fn delay_buffer_matches_lane_grouping() {
        // Exact check against the same grouping computed straightforwardly.
        let a: Vec<f64> = (0..64).map(|i| (i as f64).sin()).collect();
        let b: Vec<f64> = (0..64).map(|i| (i as f64).cos()).collect();
        let mut lanes = [0.0f64; DELAY_LANES];
        for i in 0..64 {
            lanes[i % DELAY_LANES] += a[i] * b[i];
        }
        assert_eq!(dot_delay_buffer(&a, &b), lanes.iter().sum::<f64>());
    }

    #[test]
    fn streaming_accumulators_match_whole_array_dots_bitwise() {
        // Awkward length (not a multiple of DELAY_LANES) + magnitude
        // spread so any reassociation would flip low-order bits.
        let a: Vec<f64> = (0..1003)
            .map(|i| ((i * 37) % 101) as f64 * 10f64.powi((i % 7) as i32 - 3))
            .collect();
        let b: Vec<f64> = (0..1003).map(|i| ((i * 53) % 97) as f64 - 48.0).collect();
        assert_eq!(
            dot_with::<SeqDot>(&a, &b).to_bits(),
            dot_sequential(&a, &b).to_bits()
        );
        assert_eq!(
            dot_with::<DelayDot>(&a, &b).to_bits(),
            dot_delay_buffer(&a, &b).to_bits()
        );
    }

    #[test]
    fn scheme_rows_cover_matches_full_bitwise() {
        let (a, v32, x) = system(300);
        for scheme in Scheme::ALL {
            let mut full = vec![0.0; a.n];
            spmv_scheme_rows(&a, &v32, &x, &mut full, 0, scheme);
            let mut piecewise = vec![0.0; a.n];
            for w in [0usize, 37, 170, 299, a.n].windows(2) {
                spmv_scheme_rows(&a, &v32, &x, &mut piecewise[w[0]..w[1]], w[0], scheme);
            }
            assert!(
                full.iter().zip(&piecewise).all(|(u, v)| u.to_bits() == v.to_bits()),
                "scheme {scheme:?} row blocks diverged"
            );
        }
    }

    /// Interleave per-lane vectors into the lane-major block layout.
    fn interleave(vecs: &[Vec<f64>]) -> Vec<f64> {
        let (lanes, n) = (vecs.len(), vecs[0].len());
        let mut out = vec![0.0; n * lanes];
        for (j, v) in vecs.iter().enumerate() {
            for i in 0..n {
                out[i * lanes + j] = v[i];
            }
        }
        out
    }

    #[test]
    fn block_kernel_is_bitwise_the_serial_kernel_per_lane() {
        // The load-bearing invariant: every lane of the block output is
        // bit-for-bit the serial per-lane SpMV, at every lane count
        // (including the unroll remainders) and for all four schemes.
        let (a, v32, _) = system(300);
        for lanes in [1usize, 2, 3, 4, 5, 7, 8] {
            let xs: Vec<Vec<f64>> = (0..lanes)
                .map(|k| (0..a.n).map(|i| (i as f64 * 0.13 + k as f64).sin()).collect())
                .collect();
            let xi = interleave(&xs);
            for scheme in Scheme::ALL {
                let mut ys = vec![f64::NAN; a.n * lanes];
                spmv_scheme_rows_block(&a, &v32, &xi, &mut ys, 0, lanes, scheme);
                for (k, x) in xs.iter().enumerate() {
                    let mut want = vec![0.0; a.n];
                    spmv_scheme_rows(&a, &v32, x, &mut want, 0, scheme);
                    assert!(
                        (0..a.n).all(|i| ys[i * lanes + k].to_bits() == want[i].to_bits()),
                        "{scheme:?} lane {k} of {lanes} diverged from the serial kernel"
                    );
                }
            }
        }
    }

    #[test]
    fn block_kernel_row_blocks_cover_bitwise() {
        // Disjoint row blocks (the parallel engine's split) reproduce
        // the one-call output exactly, like the serial kernel's cover.
        let (a, v32, _) = system(300);
        let lanes = 5;
        let xs: Vec<Vec<f64>> = (0..lanes)
            .map(|k| (0..a.n).map(|i| (i as f64 * 0.07 + k as f64).cos()).collect())
            .collect();
        let xi = interleave(&xs);
        for scheme in Scheme::ALL {
            let mut full = vec![0.0; a.n * lanes];
            spmv_scheme_rows_block(&a, &v32, &xi, &mut full, 0, lanes, scheme);
            let mut piecewise = vec![0.0; a.n * lanes];
            for w in [0usize, 37, 170, 299, a.n].windows(2) {
                spmv_scheme_rows_block(
                    &a,
                    &v32,
                    &xi,
                    &mut piecewise[w[0] * lanes..w[1] * lanes],
                    w[0],
                    lanes,
                    scheme,
                );
            }
            assert!(
                full.iter().zip(&piecewise).all(|(u, v)| u.to_bits() == v.to_bits()),
                "scheme {scheme:?} block row blocks diverged"
            );
        }
    }

    #[test]
    fn matrix_value_reads_are_independent_of_lane_count() {
        // The amortization itself: one block call streams nnz values no
        // matter how many lanes ride along, while per-lane calls stream
        // lanes x nnz.
        let (a, v32, x) = system(200);
        let nnz = a.nnz() as u64;
        for lanes in [1usize, 3, 8] {
            let xi = interleave(&vec![x.clone(); lanes]);
            let mut ys = vec![0.0; a.n * lanes];
            let before = stats::matrix_value_reads();
            spmv_scheme_rows_block(&a, &v32, &xi, &mut ys, 0, lanes, Scheme::MixV3);
            assert_eq!(stats::matrix_value_reads() - before, nnz, "block kernel at {lanes} lanes");
        }
        let before = stats::matrix_value_reads();
        let mut y = vec![0.0; a.n];
        for _ in 0..3 {
            spmv_scheme_rows(&a, &v32, &x, &mut y, 0, Scheme::MixV3);
        }
        assert_eq!(stats::matrix_value_reads() - before, 3 * nnz, "per-lane path pays per lane");
    }

    #[test]
    fn block_vector_kernels_are_bitwise_the_serial_modules_per_lane() {
        // Every lane of every block vector kernel is bit-for-bit the
        // serial module (`modules::compute`) run on that lane's
        // deinterleaved vectors — the invariant that lets the resident
        // block coordinator batch the M2–M8 sweeps without leaving the
        // `jpcg_solve` oracle.  Magnitude spread so reassociation would
        // flip low-order bits; lane counts cover 1 and non-dividing n.
        use crate::modules::compute::{AxpyModule, DotModule, LeftDivideModule, UpdatePModule};
        let n = 1003;
        for lanes in [1usize, 2, 3, 5, 8] {
            let lane_vec = |salt: usize| -> Vec<Vec<f64>> {
                (0..lanes)
                    .map(|k| {
                        (0..n)
                            .map(|i| {
                                ((i * 37 + k * 11 + salt) % 101) as f64
                                    * 10f64.powi(((i + k) % 7) as i32 - 3)
                            })
                            .collect()
                    })
                    .collect()
            };
            let (xs, ys, zs, ps, rs) =
                (lane_vec(0), lane_vec(1), lane_vec(2), lane_vec(3), lane_vec(4));
            let m: Vec<f64> = (0..n).map(|i| 1.0 + ((i * 13) % 17) as f64).collect();
            let alphas: Vec<f64> = (0..lanes).map(|k| 0.25 - 0.75 * k as f64).collect();

            // axpy
            let xi = interleave(&xs);
            let mut yi = interleave(&ys);
            axpy_block(&alphas, &xi, &mut yi);
            for (k, (x, y)) in xs.iter().zip(&ys).enumerate() {
                let mut want = y.clone();
                AxpyModule.run(alphas[k], x, &mut want);
                assert!(
                    (0..n).all(|i| yi[i * lanes + k].to_bits() == want[i].to_bits()),
                    "axpy lane {k} of {lanes} diverged"
                );
            }

            // left divide
            let ri = interleave(&rs);
            let mut zi = vec![f64::NAN; n * lanes];
            left_divide_block(&ri, &m, &mut zi, lanes);
            for (k, r) in rs.iter().enumerate() {
                let mut want = vec![0.0; n];
                LeftDivideModule.run(r, &m, &mut want);
                assert!(
                    (0..n).all(|i| zi[i * lanes + k].to_bits() == want[i].to_bits()),
                    "left-divide lane {k} of {lanes} diverged"
                );
            }

            // update p
            let z2 = interleave(&zs);
            let mut pi = interleave(&ps);
            update_p_block(&alphas, &z2, &mut pi);
            for (k, (z, p)) in zs.iter().zip(&ps).enumerate() {
                let mut want = p.clone();
                UpdatePModule.run(alphas[k], z, &mut want);
                assert!(
                    (0..n).all(|i| pi[i * lanes + k].to_bits() == want[i].to_bits()),
                    "update-p lane {k} of {lanes} diverged"
                );
            }

            // dot
            let ai = interleave(&xs);
            let bi = interleave(&ys);
            let mut dots = vec![f64::NAN; lanes];
            dot_block(&ai, &bi, &mut dots);
            for (k, (x, y)) in xs.iter().zip(&ys).enumerate() {
                assert_eq!(
                    dots[k].to_bits(),
                    DotModule.run(x, y).to_bits(),
                    "dot lane {k} of {lanes} diverged"
                );
            }
        }
    }

    #[test]
    fn block_elementwise_kernels_cover_row_subranges_bitwise() {
        // Aligned sub-slices (the engine's row split) reproduce the
        // one-call output exactly — element-wise ops never cross rows.
        let (n, lanes) = (300, 5);
        let xs: Vec<Vec<f64>> = (0..lanes)
            .map(|k| (0..n).map(|i| (i as f64 * 0.11 + k as f64).sin()).collect())
            .collect();
        let ys: Vec<Vec<f64>> = (0..lanes)
            .map(|k| (0..n).map(|i| (i as f64 * 0.17 + k as f64).cos()).collect())
            .collect();
        let m: Vec<f64> = (0..n).map(|i| 2.0 + (i % 5) as f64).collect();
        let alphas: Vec<f64> = (0..lanes).map(|k| -0.5 + 0.3 * k as f64).collect();
        let (xi, yi) = (interleave(&xs), interleave(&ys));

        let mut full = yi.clone();
        axpy_block(&alphas, &xi, &mut full);
        let mut piecewise = yi.clone();
        for w in [0usize, 37, 170, 299, n].windows(2) {
            axpy_block(&alphas, &xi[w[0] * lanes..w[1] * lanes], &mut piecewise[w[0] * lanes..w[1] * lanes]);
        }
        assert!(full.iter().zip(&piecewise).all(|(u, v)| u.to_bits() == v.to_bits()));

        let mut full_z = vec![0.0; n * lanes];
        left_divide_block(&yi, &m, &mut full_z, lanes);
        let mut piece_z = vec![0.0; n * lanes];
        for w in [0usize, 37, 170, 299, n].windows(2) {
            left_divide_block(
                &yi[w[0] * lanes..w[1] * lanes],
                &m[w[0]..w[1]],
                &mut piece_z[w[0] * lanes..w[1] * lanes],
                lanes,
            );
        }
        assert!(full_z.iter().zip(&piece_z).all(|(u, v)| u.to_bits() == v.to_bits()));

        let mut full_p = yi.clone();
        update_p_block(&alphas, &xi, &mut full_p);
        let mut piece_p = yi.clone();
        for w in [0usize, 37, 170, 299, n].windows(2) {
            update_p_block(&alphas, &xi[w[0] * lanes..w[1] * lanes], &mut piece_p[w[0] * lanes..w[1] * lanes]);
        }
        assert!(full_p.iter().zip(&piece_p).all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn vector_element_move_counter_counts_and_deltas() {
        let before = stats::vector_element_moves();
        stats::add_vector_element_moves(123);
        stats::add_vector_element_moves(77);
        assert_eq!(stats::vector_element_moves() - before, 200);
    }

    #[test]
    fn nnz_bytes_table1() {
        assert_eq!(Scheme::Fp64.nnz_bytes(), 16);
        for s in [Scheme::MixV1, Scheme::MixV2, Scheme::MixV3] {
            assert_eq!(s.nnz_bytes(), 8);
        }
    }
}
