//! Precision lab: the four SpMV precision schemes of Table 1, the
//! cyclic-delay-buffer dot product (footnote 1), and the behavioural
//! model of XcgSolver's padded-zero accumulator instability (§7.5.1).
//!
//! The paper's rule (§6): mixed precision applies *only* to the SpMV;
//! main-loop vectors always stay FP64.  Each scheme therefore only
//! changes what the SpMV sees:
//!
//! | scheme  | A    | x    | y    |
//! |---------|------|------|------|
//! | Fp64    | f64  | f64  | f64  |
//! | MixV1   | f32  | f32  | f32  |
//! | MixV2   | f32  | f32  | f64  |
//! | MixV3   | f32  | f64  | f64  |  <- what Callipepla ships


use crate::sparse::CsrMatrix;

/// SpMV precision scheme (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Scheme {
    /// Default all-FP64 (XcgSolver, SerpensCG, GPU baselines).
    Fp64,
    /// All-FP32 SpMV: fails to converge on hard problems (Fig. 9).
    MixV1,
    /// f32 matrix + f32 input vector, f64 accumulate.
    MixV2,
    /// f32 matrix only — Callipepla's shipping scheme.
    #[default]
    MixV3,
}

impl Scheme {
    /// Every scheme, in Table-1 order.
    pub const ALL: [Scheme; 4] = [Scheme::Fp64, Scheme::MixV1, Scheme::MixV2, Scheme::MixV3];

    /// Short lowercase id (CLI `--scheme` values).
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Fp64 => "fp64",
            Scheme::MixV1 => "mixv1",
            Scheme::MixV2 => "mixv2",
            Scheme::MixV3 => "mixv3",
        }
    }

    /// Bytes per streamed non-zero: 128-bit for an FP64 nnz (32+32+64),
    /// 64-bit packed for an f32 nnz (14+18+32 -> one 64-bit word), §2.3.3/§6.
    pub fn nnz_bytes(self) -> u64 {
        match self {
            Scheme::Fp64 => 16,
            _ => 8,
        }
    }

    /// Does the matrix value stream hold f32?
    pub fn matrix_f32(self) -> bool {
        !matches!(self, Scheme::Fp64)
    }
}

/// Accumulation-order / accumulator-architecture model for the SpMV.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum AccumulatorModel {
    /// Exact sequential accumulation (CPU golden reference).
    #[default]
    Sequential,
    /// Serpens/Callipepla: out-of-order issue changes the accumulation
    /// order per row but stays in f64 — numerically benign.
    OutOfOrder,
    /// XcgSolver's padded-zero accumulator whose true dependency distance
    /// exceeds the FP-add-latency padding (§7.5.1): modelled as a
    /// deterministic relative perturbation of magnitude `eps` on each
    /// SpMV output element.  `eps = 3e-9` calibrated so Table-7
    /// iteration inflation lands in the paper's observed range
    /// (+10% .. +35%).
    PaddedUnstable { eps: f64 },
}

impl AccumulatorModel {
    /// The calibrated XcgSolver instability (§7.5.1).
    pub const XCGSOLVER: AccumulatorModel = AccumulatorModel::PaddedUnstable { eps: 3e-9 };
}

/// Deterministic per-element hash in [-1, 1) for the perturbation model.
#[inline]
fn signed_hash01(i: u64, salt: u64) -> f64 {
    let mut h = i.wrapping_mul(0x9E3779B97F4A7C15) ^ salt.wrapping_mul(0xD1B54A32D192ED03);
    h ^= h >> 31;
    h = h.wrapping_mul(0xBF58476D1CE4E5B9);
    h ^= h >> 29;
    (h >> 11) as f64 / (1u64 << 52) as f64 - 1.0
}

/// SpMV under a precision scheme + accumulator model.  `vals32` must be
/// the f32 view of `a.vals` (cached by the caller — deriving it is
/// O(nnz)); it is ignored (may be empty) for [`Scheme::Fp64`].  `salt`
/// feeds the PaddedUnstable perturbation (callers pass the iteration
/// number so the perturbation varies across iterations the way a
/// timing-dependent accumulator error would).
pub fn spmv_scheme(
    a: &CsrMatrix,
    vals32: &[f32],
    x: &[f64],
    y: &mut [f64],
    scheme: Scheme,
    acc: AccumulatorModel,
    salt: u64,
) {
    debug_assert_eq!(y.len(), a.n);
    spmv_scheme_rows(a, vals32, x, y, 0, scheme);
    apply_accumulator_model(y, acc, salt);
}

/// One scheme's SpMV restricted to the contiguous row block
/// `row_start..row_start + y_rows.len()`, writing into `y_rows`.
///
/// Every row's multiply-accumulate runs in exactly the order of the full
/// serial kernel, so covering `0..n` with disjoint row blocks — on any
/// number of threads — reproduces the serial output *bitwise*.  This is
/// the invariant that lets the parallel engine keep Table-7 iteration
/// counts untouched (see `PERF.md`).
pub fn spmv_scheme_rows(
    a: &CsrMatrix,
    vals32: &[f32],
    x: &[f64],
    y_rows: &mut [f64],
    row_start: usize,
    scheme: Scheme,
) {
    debug_assert!(row_start + y_rows.len() <= a.n);
    // Hard guard, not a debug_assert: the Mix-V3 arm indexes vals32 with
    // get_unchecked, so an undersized slice from safe code would be UB.
    assert!(
        !scheme.matrix_f32() || vals32.len() == a.nnz(),
        "vals32 must be the f32 view of a.vals for {scheme:?} (len {} != nnz {})",
        vals32.len(),
        a.nnz()
    );
    match scheme {
        Scheme::Fp64 => {
            for (j, yj) in y_rows.iter_mut().enumerate() {
                let (cols, vals) = a.row(row_start + j);
                let mut s = 0.0f64;
                for (c, v) in cols.iter().zip(vals) {
                    s += v * x[*c as usize];
                }
                *yj = s;
            }
        }
        Scheme::MixV1 => {
            // All-f32 SpMV: x rounded to f32, f32 multiply-accumulate,
            // result widened at the end (vectors stay f64 outside).
            for (j, yj) in y_rows.iter_mut().enumerate() {
                let i = row_start + j;
                let (cols, _) = a.row(i);
                let (s, e) = (a.indptr[i] as usize, a.indptr[i + 1] as usize);
                let mut acc32 = 0.0f32;
                for (k, c) in (s..e).zip(cols) {
                    acc32 += vals32[k] * x[*c as usize] as f32;
                }
                *yj = acc32 as f64;
            }
        }
        Scheme::MixV2 => {
            // f32 matrix and f32-rounded x, but f64 accumulation.
            for (j, yj) in y_rows.iter_mut().enumerate() {
                let i = row_start + j;
                let (cols, _) = a.row(i);
                let (s, e) = (a.indptr[i] as usize, a.indptr[i + 1] as usize);
                let mut acc64 = 0.0f64;
                for (k, c) in (s..e).zip(cols) {
                    acc64 += vals32[k] as f64 * (x[*c as usize] as f32) as f64;
                }
                *yj = acc64;
            }
        }
        Scheme::MixV3 => {
            // f32 matrix upcast, full-f64 x and accumulation (Fig. 8).
            // Hot path (§Perf): bounds checks lifted out of the inner
            // gather loop — indices are validated at matrix build time.
            for (j, yj) in y_rows.iter_mut().enumerate() {
                let i = row_start + j;
                let (s, e) = (a.indptr[i] as usize, a.indptr[i + 1] as usize);
                let mut acc64 = 0.0f64;
                for k in s..e {
                    // SAFETY: k < nnz and indices[k] < n by CSR construction.
                    unsafe {
                        acc64 += *vals32.get_unchecked(k) as f64
                            * x.get_unchecked(*a.indices.get_unchecked(k) as usize);
                    }
                }
                *yj = acc64;
            }
        }
    }
}

/// Apply the accumulator-architecture perturbation (§7.5.1) to a full
/// SpMV output.  Separated from the gather kernels so the parallel
/// engine can run the row blocks on threads and still apply the
/// whole-vector model in the serial path's exact element order.
pub fn apply_accumulator_model(y: &mut [f64], acc: AccumulatorModel, salt: u64) {
    if let AccumulatorModel::PaddedUnstable { eps } = acc {
        for (i, v) in y.iter_mut().enumerate() {
            *v += *v * eps * signed_hash01(i as u64, salt);
        }
    }
}

/// Number of f64 adder lanes in the FPGA's cyclic delay buffer
/// (footnote 1); must match `python/compile/kernels/dot.py::DELAY_LANES`.
pub const DELAY_LANES: usize = 8;

/// Dot product with the FPGA's two-phase delay-buffer structure:
/// Phase I accumulates element i into lane i % L (II=1); Phase II folds
/// the L lanes (II=5 tail on the FPGA, cost independent of n).
/// Reproduces the hardware's partial-sum grouping — and hence its exact
/// rounding — which is what makes the Callipepla rows of Table 7 differ
/// from the CPU by a handful of iterations.
pub fn dot_delay_buffer(a: &[f64], b: &[f64]) -> f64 {
    let mut lanes = [0.0f64; DELAY_LANES];
    let chunks = a.len() / DELAY_LANES;
    for k in 0..chunks {
        let base = k * DELAY_LANES;
        for l in 0..DELAY_LANES {
            lanes[l] += a[base + l] * b[base + l];
        }
    }
    for i in chunks * DELAY_LANES..a.len() {
        lanes[i % DELAY_LANES] += a[i] * b[i];
    }
    lanes.iter().sum()
}

/// Plain sequential dot (CPU golden).
pub fn dot_sequential(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

// ---------------------------------------------------------------------
// Streaming dot accumulators.
//
// The fused solver sweeps (solver::jpcg) fold the Phase-2 dots into the
// element-wise update loops instead of making separate n-length passes.
// Fusion is only legal if it cannot move a single bit of the result, so
// each accumulator reproduces — product by product, in element order —
// the exact reduction structure of its whole-array counterpart:
// `SeqDot` == `dot_sequential`, `DelayDot` == `dot_delay_buffer`
// (asserted bitwise in the tests below).
// ---------------------------------------------------------------------

/// A running dot product fed one element pair at a time, in index order.
pub trait DotAccumulator: Default {
    /// Accumulate the product `a * b` for the next element index.
    fn add(&mut self, a: f64, b: f64);
    /// Final reduction value.
    fn finish(&self) -> f64;
}

/// Sequential accumulation: bitwise-identical to [`dot_sequential`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SeqDot {
    acc: f64,
}

impl DotAccumulator for SeqDot {
    #[inline]
    fn add(&mut self, a: f64, b: f64) {
        self.acc += a * b;
    }

    #[inline]
    fn finish(&self) -> f64 {
        self.acc
    }
}

/// The FPGA's 8-lane cyclic delay buffer as a streaming accumulator:
/// element i lands in lane i % L and the lanes fold sequentially at the
/// end — bitwise-identical to [`dot_delay_buffer`], because each lane
/// sees the same partial products in the same order and the final fold
/// is the same left-to-right lane sum.
#[derive(Debug, Clone, Copy)]
pub struct DelayDot {
    lanes: [f64; DELAY_LANES],
    next: usize,
}

impl Default for DelayDot {
    fn default() -> Self {
        Self { lanes: [0.0; DELAY_LANES], next: 0 }
    }
}

impl DotAccumulator for DelayDot {
    #[inline]
    fn add(&mut self, a: f64, b: f64) {
        self.lanes[self.next] += a * b;
        self.next += 1;
        if self.next == DELAY_LANES {
            self.next = 0;
        }
    }

    #[inline]
    fn finish(&self) -> f64 {
        self.lanes.iter().sum()
    }
}

/// Whole-array dot through an accumulator type (used for the Phase-1
/// `pap` dot, which has no update loop to fuse into).
pub fn dot_with<D: DotAccumulator>(a: &[f64], b: &[f64]) -> f64 {
    let mut d = D::default();
    for (x, y) in a.iter().zip(b) {
        d.add(*x, *y);
    }
    d.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::synth;

    fn system(n: usize) -> (CsrMatrix, Vec<f32>, Vec<f64>) {
        let a = synth::banded_spd(n, 6 * n, 1e-2, 9);
        let v32 = a.vals_f32();
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).cos()).collect();
        (a, v32, x)
    }

    #[test]
    fn fp64_matches_reference() {
        let (a, v32, x) = system(200);
        let mut y1 = vec![0.0; a.n];
        let mut y2 = vec![0.0; a.n];
        a.spmv_f64(&x, &mut y1);
        spmv_scheme(&a, &v32, &x, &mut y2, Scheme::Fp64, AccumulatorModel::Sequential, 0);
        assert_eq!(y1, y2);
    }

    #[test]
    fn scheme_error_ordering_v1_worst_v3_best() {
        // ||y_scheme - y_fp64|| must decrease monotonically V1 -> V2 -> V3.
        let (a, v32, x) = system(400);
        let mut gold = vec![0.0; a.n];
        a.spmv_f64(&x, &mut gold);
        let err = |scheme| {
            let mut y = vec![0.0; a.n];
            spmv_scheme(&a, &v32, &x, &mut y, scheme, AccumulatorModel::Sequential, 0);
            y.iter().zip(&gold).map(|(u, v)| (u - v).powi(2)).sum::<f64>().sqrt()
        };
        let (e1, e2, e3) = (err(Scheme::MixV1), err(Scheme::MixV2), err(Scheme::MixV3));
        assert!(e1 > e2 && e2 > e3, "e1={e1:.3e} e2={e2:.3e} e3={e3:.3e}");
        assert!(e3 > 0.0); // f32 matrix still loses something
    }

    #[test]
    fn padded_unstable_perturbs_deterministically() {
        let (a, v32, x) = system(100);
        let mut y1 = vec![0.0; a.n];
        let mut y2 = vec![0.0; a.n];
        spmv_scheme(&a, &v32, &x, &mut y1, Scheme::Fp64, AccumulatorModel::XCGSOLVER, 3);
        spmv_scheme(&a, &v32, &x, &mut y2, Scheme::Fp64, AccumulatorModel::XCGSOLVER, 3);
        assert_eq!(y1, y2);
        let mut clean = vec![0.0; a.n];
        spmv_scheme(&a, &v32, &x, &mut clean, Scheme::Fp64, AccumulatorModel::Sequential, 0);
        let rel: f64 = y1
            .iter()
            .zip(&clean)
            .map(|(u, v)| ((u - v) / v.abs().max(1e-300)).abs())
            .fold(0.0, f64::max);
        assert!(rel > 0.0 && rel < 1e-7, "rel={rel:.3e}");
    }

    #[test]
    fn delay_buffer_dot_close_to_sequential() {
        let a: Vec<f64> = (0..1003).map(|i| ((i * 37) % 101) as f64 - 50.0).collect();
        let b: Vec<f64> = (0..1003).map(|i| ((i * 53) % 97) as f64 - 48.0).collect();
        let d1 = dot_delay_buffer(&a, &b);
        let d2 = dot_sequential(&a, &b);
        assert!((d1 - d2).abs() <= 1e-9 * d2.abs().max(1.0));
    }

    #[test]
    fn delay_buffer_matches_lane_grouping() {
        // Exact check against the same grouping computed straightforwardly.
        let a: Vec<f64> = (0..64).map(|i| (i as f64).sin()).collect();
        let b: Vec<f64> = (0..64).map(|i| (i as f64).cos()).collect();
        let mut lanes = [0.0f64; DELAY_LANES];
        for i in 0..64 {
            lanes[i % DELAY_LANES] += a[i] * b[i];
        }
        assert_eq!(dot_delay_buffer(&a, &b), lanes.iter().sum::<f64>());
    }

    #[test]
    fn streaming_accumulators_match_whole_array_dots_bitwise() {
        // Awkward length (not a multiple of DELAY_LANES) + magnitude
        // spread so any reassociation would flip low-order bits.
        let a: Vec<f64> = (0..1003)
            .map(|i| ((i * 37) % 101) as f64 * 10f64.powi((i % 7) as i32 - 3))
            .collect();
        let b: Vec<f64> = (0..1003).map(|i| ((i * 53) % 97) as f64 - 48.0).collect();
        assert_eq!(
            dot_with::<SeqDot>(&a, &b).to_bits(),
            dot_sequential(&a, &b).to_bits()
        );
        assert_eq!(
            dot_with::<DelayDot>(&a, &b).to_bits(),
            dot_delay_buffer(&a, &b).to_bits()
        );
    }

    #[test]
    fn scheme_rows_cover_matches_full_bitwise() {
        let (a, v32, x) = system(300);
        for scheme in Scheme::ALL {
            let mut full = vec![0.0; a.n];
            spmv_scheme_rows(&a, &v32, &x, &mut full, 0, scheme);
            let mut piecewise = vec![0.0; a.n];
            for w in [0usize, 37, 170, 299, a.n].windows(2) {
                spmv_scheme_rows(&a, &v32, &x, &mut piecewise[w[0]..w[1]], w[0], scheme);
            }
            assert!(
                full.iter().zip(&piecewise).all(|(u, v)| u.to_bits() == v.to_bits()),
                "scheme {scheme:?} row blocks diverged"
            );
        }
    }

    #[test]
    fn nnz_bytes_table1() {
        assert_eq!(Scheme::Fp64.nnz_bytes(), 16);
        for s in [Scheme::MixV1, Scheme::MixV2, Scheme::MixV3] {
            assert_eq!(s.nnz_bytes(), 8);
        }
    }
}
