//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the coordinator hot
//! path via the `xla` crate's CPU PJRT client.  Python never runs here —
//! the artifacts are compiled once by `make artifacts` and this module
//! is pure Rust + libxla_extension.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax's
//! serialized protos carry 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see aot.py and
//! /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::PhaseExecutor;
use crate::precision::Scheme;
use crate::sparse::CsrMatrix;
use crate::util::json::Json;

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub phase: String,
    pub scheme: String,
    pub n: usize,
    pub nnz_pad: usize,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {dir:?}/manifest.json — run `make artifacts`"))?;
        let j = Json::parse(&text)?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?;
        let mut artifacts = Vec::new();
        for a in arts {
            artifacts.push(ArtifactMeta {
                file: a.get("file").and_then(Json::as_str).unwrap_or_default().to_string(),
                phase: a.get("phase").and_then(Json::as_str).unwrap_or_default().to_string(),
                scheme: a.get("scheme").and_then(Json::as_str).unwrap_or_default().to_string(),
                n: a.get("n").and_then(Json::as_usize).unwrap_or(0),
                nnz_pad: a.get("nnz_pad").and_then(Json::as_usize).unwrap_or(0),
            });
        }
        Ok(Manifest { artifacts })
    }

    /// Smallest bucket fitting (n, nnz) for a scheme; buckets come from
    /// `python/compile/model.py::BUCKETS`.
    pub fn pick_bucket(&self, n: usize, nnz: usize, scheme: &str) -> Option<(usize, usize)> {
        self.artifacts
            .iter()
            .filter(|a| a.scheme == scheme && a.n >= n && a.nnz_pad >= nnz)
            .map(|a| (a.n, a.nnz_pad))
            .min()
    }
}

/// Compiled-executable cache keyed by (phase, scheme, n, nnz_pad).
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<(String, String, usize, usize), xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client and index the artifact directory.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, dir, manifest, cache: HashMap::new() })
    }

    /// Load + compile (cached) one phase executable.
    pub fn executable(
        &mut self,
        phase: &str,
        scheme: &str,
        n: usize,
        nnz_pad: usize,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (phase.to_string(), scheme.to_string(), n, nnz_pad);
        if !self.cache.contains_key(&key) {
            let file = format!("{phase}_{scheme}_n{n}_z{nnz_pad}.hlo.txt");
            let path = self.dir.join(&file);
            if !path.exists() {
                bail!("missing artifact {path:?} — run `make artifacts`");
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {file}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {file}: {e:?}"))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }
}

fn run_tuple(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    result.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))
}

fn lit_f64(v: &[f64]) -> xla::Literal {
    xla::Literal::vec1(v)
}

fn to_f64(l: &xla::Literal, n: usize) -> Result<Vec<f64>> {
    let v = l.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
    Ok(v[..n].to_vec())
}

fn to_scalar(l: &xla::Literal) -> Result<f64> {
    l.to_vec::<f64>()
        .map_err(|e| anyhow!("to_vec: {e:?}"))?
        .first()
        .copied()
        .ok_or_else(|| anyhow!("empty scalar literal"))
}

/// Executes the JPCG phases through the AOT artifacts: the L3-calls-L2/L1
/// path of the three-layer architecture.  Vectors are padded into the
/// selected bucket (padded nnz are (0,0,0.0) no-ops; padded vector lanes
/// hold zeros and the diagonal pad holds ones, so dots and divides are
/// unaffected — the contract tested in `python/tests/test_kernels.py`).
pub struct PjrtExecutor<'rt> {
    rt: &'rt mut PjrtRuntime,
    scheme: Scheme,
    n_real: usize,
    n_bucket: usize,
    nnz_bucket: usize,
    vals: xla::Literal,
    col: xla::Literal,
    row: xla::Literal,
    m: xla::Literal,
    /// Executable-call counter (metrics / tests).
    pub calls: u64,
}

impl<'rt> PjrtExecutor<'rt> {
    pub fn new(rt: &'rt mut PjrtRuntime, a: &CsrMatrix, scheme: Scheme) -> Result<Self> {
        let scheme_name = match scheme {
            Scheme::Fp64 => "fp64",
            Scheme::MixV3 => "mixv3",
            other => bail!("no artifacts for scheme {other:?} (fp64 / mixv3 only)"),
        };
        let (n_bucket, nnz_bucket) = rt
            .manifest
            .pick_bucket(a.n, a.nnz(), scheme_name)
            .ok_or_else(|| {
                anyhow!("no bucket fits n={} nnz={} — extend model.BUCKETS", a.n, a.nnz())
            })?;
        // COO streams, padded.
        let nnz = a.nnz();
        let mut col = vec![0i32; nnz_bucket];
        let mut row = vec![0i32; nnz_bucket];
        let mut k = 0usize;
        for i in 0..a.n {
            let (cols, _) = a.row(i);
            for c in cols {
                col[k] = *c as i32;
                row[k] = i as i32;
                k += 1;
            }
        }
        debug_assert_eq!(k, nnz);
        let vals = match scheme {
            Scheme::Fp64 => {
                let mut v = vec![0f64; nnz_bucket];
                v[..nnz].copy_from_slice(&a.vals);
                xla::Literal::vec1(&v)
            }
            _ => {
                let mut v = vec![0f32; nnz_bucket];
                for (dst, src) in v.iter_mut().zip(&a.vals) {
                    *dst = *src as f32;
                }
                xla::Literal::vec1(&v)
            }
        };
        let mut m = vec![1.0f64; n_bucket];
        m[..a.n].copy_from_slice(&a.jacobi_diag());
        Ok(Self {
            rt,
            scheme,
            n_real: a.n,
            n_bucket,
            nnz_bucket,
            vals,
            col: xla::Literal::vec1(&col),
            row: xla::Literal::vec1(&row),
            m: lit_f64(&m),
            calls: 0,
        })
    }

    fn scheme_name(&self) -> &'static str {
        match self.scheme {
            Scheme::Fp64 => "fp64",
            _ => "mixv3",
        }
    }

    fn pad(&self, v: &[f64]) -> xla::Literal {
        let mut out = vec![0.0f64; self.n_bucket];
        out[..v.len()].copy_from_slice(v);
        lit_f64(&out)
    }

    fn call(&mut self, phase: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let scheme = self.scheme_name();
        let exe = self
            .rt
            .executable(phase, scheme, self.n_bucket, self.nnz_bucket)?;
        self.calls += 1;
        run_tuple(exe, args)
    }
}

impl PhaseExecutor for PjrtExecutor<'_> {
    fn init(&mut self, x0: &[f64], b: &[f64]) -> (Vec<f64>, Vec<f64>, Vec<f64>, f64, f64) {
        let args = [
            self.vals.clone(),
            self.col.clone(),
            self.row.clone(),
            self.pad(x0),
            self.pad(b),
            self.m.clone(),
        ];
        let out = self.call("init", &args).expect("init artifact");
        let n = self.n_real;
        (
            to_f64(&out[0], n).unwrap(),
            to_f64(&out[1], n).unwrap(),
            to_f64(&out[2], n).unwrap(),
            to_scalar(&out[3]).unwrap(),
            to_scalar(&out[4]).unwrap(),
        )
    }

    fn phase1(&mut self, p: &[f64]) -> (Vec<f64>, f64) {
        let args = [
            self.vals.clone(),
            self.col.clone(),
            self.row.clone(),
            self.pad(p),
        ];
        let out = self.call("phase1", &args).expect("phase1 artifact");
        (to_f64(&out[0], self.n_real).unwrap(), to_scalar(&out[1]).unwrap())
    }

    fn phase2(&mut self, r: &[f64], ap: &[f64], alpha: f64) -> (Vec<f64>, f64, f64) {
        let args = [self.pad(r), self.pad(ap), self.m.clone(), xla::Literal::scalar(alpha)];
        let out = self.call("phase2", &args).expect("phase2 artifact");
        (
            to_f64(&out[0], self.n_real).unwrap(),
            to_scalar(&out[1]).unwrap(),
            to_scalar(&out[2]).unwrap(),
        )
    }

    fn phase3(
        &mut self,
        r: &[f64],
        p: &[f64],
        x: &[f64],
        alpha: f64,
        beta: f64,
    ) -> (Vec<f64>, Vec<f64>) {
        let args = [
            self.pad(r),
            self.m.clone(),
            self.pad(p),
            self.pad(x),
            xla::Literal::scalar(alpha),
            xla::Literal::scalar(beta),
        ];
        let out = self.call("phase3", &args).expect("phase3 artifact");
        (
            to_f64(&out[0], self.n_real).unwrap(),
            to_f64(&out[1], self.n_real).unwrap(),
        )
    }

    fn update_x_only(&mut self, p: &[f64], x: &[f64], alpha: f64) -> Vec<f64> {
        // No dedicated artifact: x' = x + alpha p on the coordinator
        // (scalar-weighted add is controller-side work in Fig. 4's exit
        // path; n is small relative to the solve).
        x.iter().zip(p).map(|(xi, pi)| xi + alpha * pi).collect()
    }
}

/// Default artifact directory: `$CALLIPEPLA_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("CALLIPEPLA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_picks_buckets() {
        let dir = std::env::temp_dir().join(format!("calli_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [
                {"file": "phase1_mixv3_n1024_z16384.hlo.txt", "phase": "phase1",
                 "scheme": "mixv3", "n": 1024, "nnz_pad": 16384},
                {"file": "phase1_mixv3_n4096_z131072.hlo.txt", "phase": "phase1",
                 "scheme": "mixv3", "n": 4096, "nnz_pad": 131072}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.pick_bucket(1000, 10_000, "mixv3"), Some((1024, 16384)));
        assert_eq!(m.pick_bucket(2000, 10_000, "mixv3"), Some((4096, 131072)));
        assert_eq!(m.pick_bucket(100_000, 10_000, "mixv3"), None);
        assert_eq!(m.pick_bucket(100, 100, "fp64"), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
