//! The global controller (paper §3, §4.3, Fig. 4).
//!
//! The controller owns every scalar (alpha, beta, rz, rr), issues the
//! stream-centric instructions to vector-control and computation
//! modules, and decides termination on the fly — the capability fixed
//! FPGA designs lack (§2.3.1).  The heavy vector work is delegated to a
//! [`PhaseExecutor`]: the native module implementations
//! ([`NativeExecutor`]) or the PJRT artifact runtime
//! (`runtime::PjrtExecutor`) — same control flow, different value plane.
//!
//! Fig. 4's two controller optimizations are reproduced:
//! 1. the merged init (`rp = -1` trip performs Alg. 1 lines 1-5 with the
//!    same modules), and
//! 2. M8 (dot rr) ordered before M5-M7 so a converged iteration skips
//!    the z-recompute and p-update, running only M3 to finish x.

use crate::isa::{InstCmp, InstRdWr, InstTrace, InstVCtrl, Instruction};
use crate::modules::fsm::{self, ModuleFsm, VecCtrlState};
use crate::precision::Scheme;
use crate::solver::ResidualTrace;
use crate::sparse::CsrMatrix;
use crate::vsr::Phase;

/// The three per-iteration phase computations + the init pass.  All
/// vectors FP64 (§6); the scheme only affects the executor's SpMV.
pub trait PhaseExecutor {
    /// Lines 1-5: returns (r, z, p, rz, rr) from x0 and b.
    fn init(&mut self, x0: &[f64], b: &[f64]) -> (Vec<f64>, Vec<f64>, Vec<f64>, f64, f64);
    /// Phase-1: (ap, pap) from p.
    fn phase1(&mut self, p: &[f64]) -> (Vec<f64>, f64);
    /// Phase-2: (r', rz_new, rr) from r, ap, alpha.
    fn phase2(&mut self, r: &[f64], ap: &[f64], alpha: f64) -> (Vec<f64>, f64, f64);
    /// Phase-3: (p', x') from r, p, x, alpha, beta (z recomputed inside).
    fn phase3(
        &mut self,
        r: &[f64],
        p: &[f64],
        x: &[f64],
        alpha: f64,
        beta: f64,
    ) -> (Vec<f64>, Vec<f64>);
    /// M3 alone (converged-exit path): x' = x + alpha p.
    fn update_x_only(&mut self, p: &[f64], x: &[f64], alpha: f64) -> Vec<f64>;
}

/// Controller configuration.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    pub tol: f64,
    pub max_iters: u32,
    pub record_trace: bool,
    /// Record every issued instruction (tests / time plane).
    pub record_instructions: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { tol: 1e-12, max_iters: 20_000, record_trace: false, record_instructions: false }
    }
}

/// Outcome of a coordinated solve.
#[derive(Debug)]
pub struct CoordResult {
    pub x: Vec<f64>,
    pub iters: u32,
    pub converged: bool,
    pub final_rr: f64,
    pub trace: ResidualTrace,
    pub instructions: InstTrace,
}

/// The global controller.
pub struct Coordinator {
    pub cfg: CoordinatorConfig,
    vec_fsms: Vec<ModuleFsm<VecCtrlState>>,
    insts: InstTrace,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Self {
        Self {
            cfg,
            vec_fsms: vec![
                fsm::vecctrl_p(),
                fsm::vecctrl_r(),
                fsm::vecctrl_x(),
                fsm::vecctrl_ap(),
                fsm::vecctrl_m(),
            ],
            insts: InstTrace::default(),
        }
    }

    /// Issue the Type-I / Type-III instructions for one phase according
    /// to each vector-control FSM (decentralized scheduling: the
    /// controller only nudges the FSMs; they emit their own memory
    /// instructions).
    fn issue_phase(&mut self, phase: Phase, n: u32, alpha: f64) {
        if !self.cfg.record_instructions {
            return;
        }
        for i in 0..self.vec_fsms.len() {
            let state = *self.vec_fsms[i].peek();
            if state.phase != phase {
                continue;
            }
            let name = self.vec_fsms[i].name;
            self.vec_fsms[i].step();
            let q_id = state.rd_to.map(|m| m as u8).unwrap_or(0);
            let vc = InstVCtrl {
                rd: state.rd_to.is_some(),
                wr: state.wr_from.is_some(),
                base_addr: 0,
                len: n,
                q_id,
            };
            self.insts.record(name, Instruction::VCtrl(vc));
            // The vector-control module decomposes into a Type-III
            // memory instruction (§4.2 vector-flow example).
            self.insts.record(
                &format!("{name}/mem"),
                Instruction::RdWr(InstRdWr {
                    rd: vc.rd,
                    wr: vc.wr,
                    base_addr: 0,
                    len: n,
                }),
            );
        }
        // Type-II computation instructions for the phase's modules.
        let mods: &[&str] = match phase {
            Phase::Phase1 => &["M1", "M2"],
            Phase::Phase2 => &["M4", "M8", "M5", "M6"], // M8 hoisted, Fig. 4
            Phase::Phase3 => &["M4", "M5", "M7", "M3"],
        };
        for m in mods {
            self.insts
                .record(m, Instruction::Cmp(InstCmp { len: n, alpha, q_id: 0 }));
        }
    }

    /// Run the Fig. 4 controller program to completion.
    pub fn solve<E: PhaseExecutor>(
        &mut self,
        exec: &mut E,
        b: &[f64],
        x0: &[f64],
    ) -> CoordResult {
        let n = b.len() as u32;
        let mut x = x0.to_vec();
        // Merged init: the rp = -1 trip of Fig. 4.
        let (mut r, _z, mut p, mut rz, mut rr) = exec.init(&x, b);
        let mut trace = ResidualTrace::new(self.cfg.record_trace);
        trace.push(rr);

        let mut iters = 0u32;
        let mut converged = rr <= self.cfg.tol;
        while iters < self.cfg.max_iters && !converged {
            // Phase 1.
            self.issue_phase(Phase::Phase1, n, 0.0);
            let (ap, pap) = exec.phase1(&p);
            let alpha = rz / pap; // scalar unit, line 8
            // Phase 2 (M8 result checked immediately: Fig. 4 opt 2).
            self.issue_phase(Phase::Phase2, n, alpha);
            let (r_new, rz_new, rr_new) = exec.phase2(&r, &ap, alpha);
            r = r_new;
            rr = rr_new;
            if rr <= self.cfg.tol {
                // Converged: skip M5-M7, run M3 alone to finish x.
                x = exec.update_x_only(&p, &x, alpha);
                iters += 1;
                trace.push(rr);
                converged = true;
                break;
            }
            // Phase 3.
            let beta = rz_new / rz; // scalar unit, line 13 coefficient
            self.issue_phase(Phase::Phase3, n, beta);
            let (p_new, x_new) = exec.phase3(&r, &p, &x, alpha, beta);
            p = p_new;
            x = x_new;
            rz = rz_new;
            iters += 1;
            trace.push(rr);
        }

        CoordResult {
            x,
            iters,
            converged,
            final_rr: rr,
            trace,
            instructions: std::mem::take(&mut self.insts),
        }
    }
}

// --------------------------------------------------------------------
// Native executor: the module implementations of modules::compute.
// --------------------------------------------------------------------

use crate::engine::PreparedMatrix;
use crate::modules::compute::{AxpyModule, DotModule, LeftDivideModule, SpMvModule, UpdatePModule};
use crate::sparse::{pack_nnz_streams, NnzStream, DEP_DIST_SERPENS};

/// Executes phases with the native module implementations, streaming the
/// SpMV through the scheduled Serpens nnz streams (Mix-V3) or CSR FP64.
/// Matrix-derived state (Jacobi diagonal, f32 values, row partition)
/// lives in a [`PreparedMatrix`] plan so it is derived once per matrix,
/// and the CSR FP64 path runs the engine's nnz-balanced parallel SpMV
/// (bitwise identical to the serial kernel).
pub struct NativeExecutor<'a> {
    pub a: &'a CsrMatrix,
    pub scheme: Scheme,
    stream: Option<NnzStream>,
    prep: PreparedMatrix<'a>,
}

impl<'a> NativeExecutor<'a> {
    pub fn new(a: &'a CsrMatrix, scheme: Scheme) -> Self {
        let threads =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_threads(a, scheme, threads)
    }

    /// Explicit thread budget for the CSR SpMV path (1 = serial).
    pub fn with_threads(a: &'a CsrMatrix, scheme: Scheme, threads: usize) -> Self {
        let stream = if scheme.matrix_f32() {
            Some(pack_nnz_streams(a, DEP_DIST_SERPENS))
        } else {
            None
        };
        Self { a, scheme, stream, prep: PreparedMatrix::new(a, threads) }
    }

    /// The underlying solve plan (partition, cached diagonal/values).
    pub fn plan(&self) -> &PreparedMatrix<'a> {
        &self.prep
    }

    fn spmv(&self, v: &[f64]) -> Vec<f64> {
        match &self.stream {
            Some(s) => SpMvModule { stream: s }.run(v),
            None => {
                let mut out = vec![0.0; self.a.n];
                self.prep.spmv(Scheme::Fp64, v, &mut out);
                out
            }
        }
    }
}

impl PhaseExecutor for NativeExecutor<'_> {
    fn init(&mut self, x0: &[f64], b: &[f64]) -> (Vec<f64>, Vec<f64>, Vec<f64>, f64, f64) {
        let ax = self.spmv(x0);
        let n = self.a.n;
        let mut r = vec![0.0; n];
        for i in 0..n {
            r[i] = b[i] - ax[i];
        }
        let mut z = vec![0.0; n];
        LeftDivideModule.run(&r, self.prep.diag(), &mut z);
        let p = z.clone();
        let rz = DotModule.run(&r, &z);
        let rr = DotModule.run(&r, &r);
        (r, z, p, rz, rr)
    }

    fn phase1(&mut self, p: &[f64]) -> (Vec<f64>, f64) {
        let ap = self.spmv(p);
        let pap = DotModule.run(p, &ap);
        (ap, pap)
    }

    fn phase2(&mut self, r: &[f64], ap: &[f64], alpha: f64) -> (Vec<f64>, f64, f64) {
        let mut r1 = r.to_vec();
        AxpyModule.run(-alpha, ap, &mut r1);
        let mut z = vec![0.0; r1.len()];
        LeftDivideModule.run(&r1, self.prep.diag(), &mut z);
        let rz = DotModule.run(&r1, &z);
        let rr = DotModule.run(&r1, &r1);
        (r1, rz, rr)
    }

    fn phase3(
        &mut self,
        r: &[f64],
        p: &[f64],
        x: &[f64],
        alpha: f64,
        beta: f64,
    ) -> (Vec<f64>, Vec<f64>) {
        // M4+M5 recompute z from the (already updated) r stream (§5.3).
        let mut z = vec![0.0; r.len()];
        LeftDivideModule.run(r, self.prep.diag(), &mut z);
        let mut x1 = x.to_vec();
        AxpyModule.run(alpha, p, &mut x1);
        let mut p1 = p.to_vec();
        UpdatePModule.run(beta, &z, &mut p1);
        (p1, x1)
    }

    fn update_x_only(&mut self, p: &[f64], x: &[f64], alpha: f64) -> Vec<f64> {
        let mut x1 = x.to_vec();
        AxpyModule.run(alpha, p, &mut x1);
        x1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{jpcg_solve, SolveOptions};
    use crate::sparse::synth;

    fn solve_native(a: &CsrMatrix, scheme: Scheme) -> CoordResult {
        let cfg = CoordinatorConfig { record_instructions: true, ..Default::default() };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::new(a, scheme);
        let b = vec![1.0; a.n];
        let x0 = vec![0.0; a.n];
        coord.solve(&mut exec, &b, &x0)
    }

    #[test]
    fn coordinator_converges_and_solves() {
        let a = synth::laplace2d_shifted(900, 0.05);
        let res = solve_native(&a, Scheme::MixV3);
        assert!(res.converged, "rr={}", res.final_rr);
        let mut ax = vec![0.0; a.n];
        a.spmv_f64(&res.x, &mut ax);
        let err = ax.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-4, "err={err}");
    }

    #[test]
    fn coordinator_matches_reference_solver_iterations() {
        // The coordinator's phase-split numerics must land within a few
        // iterations of the monolithic reference solver.
        let a = synth::banded_spd(1500, 12_000, 1e-4, 21);
        let coord = solve_native(&a, Scheme::MixV3);
        let refres = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        let diff = (coord.iters as i64 - refres.iters as i64).abs();
        assert!(diff <= 5, "coord={} ref={}", coord.iters, refres.iters);
    }

    #[test]
    fn fp64_scheme_uses_csr_path() {
        let a = synth::laplace2d_shifted(400, 0.1);
        let res = solve_native(&a, Scheme::Fp64);
        assert!(res.converged);
    }

    #[test]
    fn fp64_path_thread_count_is_bitwise_invisible() {
        // The engine-backed CSR SpMV must not move a single iteration.
        let a = synth::banded_spd(1_000, 8_000, 1e-4, 57);
        let cfg = CoordinatorConfig::default();
        let solve_t = |threads: usize| {
            let mut coord = Coordinator::new(cfg);
            let mut exec = NativeExecutor::with_threads(&a, Scheme::Fp64, threads);
            let b = vec![1.0; a.n];
            let x0 = vec![0.0; a.n];
            coord.solve(&mut exec, &b, &x0)
        };
        let serial = solve_t(1);
        let parallel = solve_t(8);
        assert_eq!(serial.iters, parallel.iters);
        assert!(serial
            .x
            .iter()
            .zip(&parallel.x)
            .all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn instruction_trace_counts_scale_with_iterations() {
        let a = synth::laplace2d_shifted(400, 0.1);
        let res = solve_native(&a, Scheme::MixV3);
        // One M1 Type-II instruction per iteration (phase 1).
        let m1 = res.instructions.count_for("M1");
        assert!(
            (m1 as i64 - res.iters as i64).abs() <= 1,
            "m1={m1} iters={}",
            res.iters
        );
        // VecCtrl-p issues one Type-I per phase it participates in.
        assert!(res.instructions.count_for("VecCtrl-p") >= m1);
    }

    #[test]
    fn early_exit_skips_phase3_modules() {
        let a = synth::laplace2d_shifted(400, 0.3); // converges quickly
        let res = solve_native(&a, Scheme::Fp64);
        assert!(res.converged);
        // On the converged iteration M7 was skipped: M7 count == iters-1.
        let m7 = res.instructions.count_for("M7");
        assert_eq!(m7 as u32, res.iters - 1, "M7 skipped on the final trip");
    }

    #[test]
    fn zero_b_converges_without_instructions() {
        let a = synth::laplace2d_shifted(100, 0.1);
        let cfg = CoordinatorConfig { record_instructions: true, ..Default::default() };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::new(&a, Scheme::MixV3);
        let res = coord.solve(&mut exec, &vec![0.0; a.n], &vec![0.0; a.n]);
        assert!(res.converged);
        assert_eq!(res.iters, 0);
        assert_eq!(res.instructions.count_for("M1"), 0);
    }
}
